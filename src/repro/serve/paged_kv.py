"""Paged KV cache: fixed-size physical pages + per-request block tables.

The allocator is append-only per request with a free list (vLLM-style).  For
long contexts the *logical -> physical* block table of a request is usually
monotone over long runs (allocation bursts), which is the paper's compressible
shape: ``compressed_table()`` stores it as a FITing-tree segment table and
``CompressedBlockTable.lookup`` resolves blocks with a bounded probe --
(524288 tokens / 128-token pages = 4096 entries -> a handful of segments when
allocation is contiguous; falls back to one segment per fragmented run).
"""
from __future__ import annotations

import dataclasses

import numpy as np



@dataclasses.dataclass
class PagedKVCache:
    """Physical page pool for one layer group.  Host-side bookkeeping;
    the device arrays are (n_pages, page, kv_heads, hd) gathered per step."""
    n_pages: int
    page_size: int

    def __post_init__(self):
        self.free = list(range(self.n_pages - 1, -1, -1))
        self.tables: dict[int, list[int]] = {}
        self._used: dict[int, int] = {}

    def alloc_request(self, rid: int):
        if rid in self.tables:
            raise KeyError(f"request {rid} already active")
        self.tables[rid] = []
        self._used[rid] = 0

    def append_token_capacity(self, rid: int, n_tokens: int) -> list[int]:
        """Ensure capacity for n_tokens more tokens; returns new page ids."""
        table = self.tables[rid]
        need_pages = -(-(self._used[rid] + n_tokens) // self.page_size) \
            - len(table)
        newly = []
        for _ in range(need_pages):
            if not self.free:
                raise MemoryError("KV pool exhausted")
            p = self.free.pop()
            table.append(p)
            newly.append(p)
        self._used[rid] += n_tokens
        return newly

    def release(self, rid: int):
        for p in self.tables.pop(rid):
            self.free.append(p)
        self._used.pop(rid, None)

    def physical_slots(self, rid: int, positions: np.ndarray) -> np.ndarray:
        """token position -> physical slot = page_id * page_size + offset."""
        table = np.asarray(self.tables[rid])
        return (table[positions // self.page_size] * self.page_size
                + positions % self.page_size)

    def utilization(self) -> float:
        return 1.0 - len(self.free) / self.n_pages


class CompressedBlockTable:
    """FITing-tree-compressed logical->physical block table (error=0 exact:
    contiguous runs collapse to one segment each)."""

    def __init__(self, table: list[int]):
        self.n = len(table)
        # index the (logical, physical) pairs: key = logical id, position =
        # physical id. Monotone runs compress; error=1 keeps probes exact
        # after rounding since physical ids are integers.
        self.runs_start_logical = []
        self.runs_start_physical = []
        self.runs_len = []
        i = 0
        while i < self.n:
            j = i
            while j + 1 < self.n and table[j + 1] == table[j] + 1:
                j += 1
            self.runs_start_logical.append(i)
            self.runs_start_physical.append(table[i])
            self.runs_len.append(j - i + 1)
            i = j + 1
        self.runs_start_logical = np.asarray(self.runs_start_logical)
        self.runs_start_physical = np.asarray(self.runs_start_physical)

    def size_bytes(self) -> int:
        return len(self.runs_len) * 24

    def lookup(self, logical: np.ndarray) -> np.ndarray:
        r = np.searchsorted(self.runs_start_logical, logical, "right") - 1
        return (self.runs_start_physical[r]
                + (logical - self.runs_start_logical[r]))


def compressed_table(pool: PagedKVCache, rid: int) -> CompressedBlockTable:
    return CompressedBlockTable(pool.tables[rid])
