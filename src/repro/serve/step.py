"""Serve steps: prefill (last-token logits) and greedy decode, cache-threaded."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import decode_step, prefill
from repro.models.config import ModelConfig


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, tokens, caches, memory=None):
        logits, caches = prefill(params, cfg, tokens, caches, memory=memory,
                                 last_only=True)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, caches
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_fn(params, tokens, pos, caches):
        """tokens: (B,1) current token; pos: (B,) its absolute position."""
        logits, caches = decode_step(params, cfg, tokens, pos, caches)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, caches
    return decode_fn
