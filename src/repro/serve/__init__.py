"""Serving steps, paged KV cache, batching, and index snapshot serving."""
from repro.index.sharded import ShardedIndexService, ShardSet, ShardStats

from .index_service import IndexService

__all__ = ["IndexService", "ShardSet", "ShardedIndexService", "ShardStats"]
