"""Serving steps, paged KV cache, batching, and index snapshot serving.

The SLO-driven construction path (``FitSpec`` -> ``open_index``) and the
typed query plane's result types (``PointResult``/``RangeResult``) are
re-exported from ``repro.index`` so serving code has one import."""
from repro.index.device import DeviceShardedService, DeviceShardSet
from repro.index.fit import FitSpec, IndexPlan, open_index
from repro.index.pipeline import (AsyncIndexService, PipelineClosed,
                                  PipelineOverloaded, open_pipeline)
from repro.index.query import PointResult, RangeResult
from repro.index.sharded import ShardedIndexService, ShardSet, ShardStats
from repro.index.telemetry import (DeviceMetrics, MetricsSnapshot, Monitor,
                                   Replanner, ServiceMetrics)

from .index_service import IndexService

__all__ = ["AsyncIndexService", "DeviceMetrics", "DeviceShardSet",
           "DeviceShardedService", "FitSpec", "IndexPlan", "IndexService",
           "MetricsSnapshot", "Monitor", "PipelineClosed",
           "PipelineOverloaded", "PointResult", "RangeResult", "Replanner",
           "ServiceMetrics", "ShardSet", "ShardedIndexService", "ShardStats",
           "open_index", "open_pipeline"]
