"""Serving steps, paged KV cache, batching."""
