"""Serving steps, paged KV cache, batching, and index snapshot serving."""
from .index_service import IndexService

__all__ = ["IndexService"]
