"""Serving steps, paged KV cache, batching, and index snapshot serving."""
from repro.index.sharded import ShardedIndexService, ShardStats

from .index_service import IndexService

__all__ = ["IndexService", "ShardedIndexService", "ShardStats"]
