"""Continuous batching: fixed decode slots, prefill-on-admit, evict-on-done.

A request arrives with a prompt; when a slot frees up the scheduler prefills
it (right-padded into the slot's ring caches via per-slot positions) and the
shared decode step advances every active slot one token per tick.  This is
the standard continuous-batching loop (Orca/vLLM) on top of model.prefill /
model.decode_step; slot caches are the per-slot slices of one batched cache
pytree, so the decode step stays a single jitted call.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_caches, prefill
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (L,) int32
    max_new: int = 32
    eos: int = -1                # -1: never
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    def __init__(self, cfg: ModelConfig, params, n_slots: int = 4,
                 cache_len: int = 512, dtype=jnp.float32):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.cache_len = cache_len
        self.caches = init_caches(cfg, n_slots, cache_len, dtype=dtype)
        self.slot_req: list[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int32)
        self.queue: deque[Request] = deque()
        self._decode = jax.jit(
            lambda p, t, pos, c: decode_step(p, cfg, t, pos, c))
        self.completed: list[Request] = []

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for s in range(self.n_slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.popleft()
                self._prefill_slot(s, req)

    def _prefill_slot(self, s: int, req: Request):
        """Prefill one slot: runs the model at batch=1 and writes the slot's
        cache slice (slot caches share the batch dim)."""
        one = init_caches(self.cfg, 1, self.cache_len,
                          dtype=jnp.float32)
        logits, one = prefill(self.params, self.cfg,
                              jnp.asarray(req.prompt[None], jnp.int32), one,
                              last_only=True)
        nxt = int(np.argmax(np.asarray(logits[0, -1])))
        req.out.append(nxt)
        self.caches = jax.tree.map(
            lambda full, new: full.at[:, s: s + 1].set(new), self.caches, one)
        self.slot_req[s] = req
        self.slot_pos[s] = req.prompt.shape[0]

    def tick(self):
        """One scheduler tick: admit waiting requests, decode one token for
        every active slot, retire finished requests."""
        self._admit()
        active = [s for s in range(self.n_slots) if self.slot_req[s]]
        if not active:
            return False
        tokens = np.zeros((self.n_slots, 1), np.int32)
        for s in active:
            tokens[s, 0] = self.slot_req[s].out[-1]
        logits, self.caches = self._decode(
            self.params, jnp.asarray(tokens),
            jnp.asarray(self.slot_pos), self.caches)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for s in active:
            req = self.slot_req[s]
            tok = int(nxt[s])
            req.out.append(tok)
            self.slot_pos[s] += 1
            if (len(req.out) >= req.max_new or tok == req.eos
                    or self.slot_pos[s] >= self.cache_len - 1):
                req.done = True
                self.completed.append(req)
                self.slot_req[s] = None
                self.slot_pos[s] = 0
        return True

    def run_until_drained(self, max_ticks: int = 10_000) -> int:
        ticks = 0
        while (self.queue or any(self.slot_req)) and ticks < max_ticks:
            self.tick()
            ticks += 1
        return ticks
