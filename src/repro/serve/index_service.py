"""Index serving: epoch-snapshot front end over the unified index core.

Composes the write path (mutable ``FITingTree``, Alg. 4 buffered inserts) with
the read path (immutable ``SegmentTable`` snapshots served by any
``repro.index.engine`` backend) the same way the LM serving stack threads
caches through steps: writers mutate, ``publish`` cuts an epoch, and the
serving handle swaps the snapshot atomically so in-flight lookups keep a
consistent view.

    svc = IndexService(keys, error=64, buffer_size=16, backend="pallas")
    svc.lookup(q)            # epoch 1 (built at construction)
    svc.insert(k); ...       # buffered; serving unaffected
    svc.publish()            # epoch 2: inserts now visible to every backend
"""
from __future__ import annotations

import numpy as np

from repro.core.tree import FITingTree
from repro.index.snapshot import ServingHandle, Snapshot, SnapshotPublisher


class IndexService:
    """One writable index + its serving handle, with optional auto-publish."""

    def __init__(self, keys: np.ndarray, error: int, *, buffer_size: int = 0,
                 payload: np.ndarray | None = None, mode: str = "paper",
                 backend: str = "numpy",
                 engine_opts: dict[str, dict] | None = None,
                 publish_every: int | None = None):
        if publish_every is not None and buffer_size == 0:
            raise ValueError("publish_every requires buffer_size > 0 "
                             "(a read-only service never republishes)")
        self.tree = FITingTree(keys, error=error, buffer_size=buffer_size,
                               mode=mode, payload=payload)
        self.default_backend = backend
        self.publisher = SnapshotPublisher(self.tree)
        self.handle = ServingHandle(engine_opts)
        self.publish_every = publish_every
        self._pending = 0
        self.handle.install(self.publisher.publish())

    # ------------------------------------------------------------- write path
    def insert(self, key: float, value=None) -> None:
        """Buffer an insert (Alg. 4).  Not visible to lookups until publish."""
        if self.tree.buffer_size == 0:
            raise ValueError("IndexService built read-only; pass "
                             "buffer_size > 0 to enable inserts")
        if value is not None and self.tree.payloads is None:
            raise ValueError("IndexService built without payloads (clustered "
                             "index); pass payload= at construction to store "
                             "values")
        self.tree.insert(key, value)
        self._pending += 1
        if self.publish_every is not None and self._pending >= self.publish_every:
            self.publish()

    def publish(self) -> Snapshot:
        """Cut a new epoch and swap it into serving atomically."""
        snap = self.publisher.publish()
        self.handle.install(snap)
        self._pending = 0
        return snap

    # -------------------------------------------------------------- read path
    def lookup(self, queries, backend: str | None = None) -> np.ndarray:
        """Rank of each query in the current epoch's key column, -1 if absent."""
        return self.handle.lookup(queries, backend or self.default_backend)

    @property
    def epoch(self) -> int:
        return self.handle.epoch

    @property
    def pending_inserts(self) -> int:
        """Inserts buffered since the last publish (invisible to serving)."""
        return self._pending
