"""Index serving: epoch-snapshot front end over the unified index core.

Composes the write path (mutable ``FITingTree``, Alg. 4 buffered inserts) with
the read path (immutable ``SegmentTable`` snapshots served by any
``repro.index.engine`` backend) the same way the LM serving stack threads
caches through steps: writers mutate, ``publish`` cuts an epoch, and the
serving handle swaps the snapshot atomically so in-flight lookups keep a
consistent view.

    svc = IndexService(keys, error=64, buffer_size=16, backend="pallas")
    svc.lookup(q)            # epoch 1 (built at construction)
    svc.insert(k); ...       # buffered; serving unaffected
    svc.publish()            # epoch 2: inserts now visible to every backend

``IndexService`` is the single-host form: a thin wrapper over a one-shard
``repro.index.sharded.ShardedIndexService`` (the N-shard generalization with
per-shard epochs and adaptive shard rebalancing lives there; re-exported by
``repro.serve``).  ``publish`` with zero pending inserts is a **no-op**
returning the current snapshot -- periodic publish-cadence loops need no
guard logic and idle ticks don't churn epoch numbers or engine caches.
Rebalancing is inherently a no-op with one shard; use the sharded service
directly when write skew matters.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import TYPE_CHECKING

import numpy as np

from repro.index.query import PointResult, RangeResult
from repro.index.sharded import ShardedIndexService, ShardStats
from repro.index.snapshot import Snapshot

if TYPE_CHECKING:  # runtime import is lazy (fit builds services via plans)
    from repro.index.fit import IndexPlan


class IndexService:
    """One writable index + its serving handle, with optional auto-publish.

    Plan-first construction (see ``repro.index.fit``): pass ``plan=`` to take
    error / buffer / backend / publish cadence / dispatch thresholds from a
    resolved ``IndexPlan`` (the shard count is forced to 1 -- this is the
    single-shard facade; ``fit.open_index`` picks the sharded service when
    the plan says so), or the raw expert knobs, which are wrapped in a
    trivially-resolved plan exposed as ``svc.plan``.
    """

    def __init__(self, keys: np.ndarray, error: int | None = None, *,
                 plan: IndexPlan | None = None, buffer_size: int | None = None,
                 payload: np.ndarray | None = None, mode: str = "paper",
                 backend: str | None = None,
                 engine_opts: dict[str, dict] | None = None,
                 publish_every: int | None = None,
                 skew_threshold: float = 2.0,
                 pending_weight: float = 1.0,
                 auto_rebalance: bool = False,
                 assume_sorted: bool = False,
                 monitor=None):
        n_shards = None
        if plan is None:
            n_shards = 1
        elif plan.n_shards != 1:
            plan = dataclasses.replace(plan, n_shards=1)
        # the rebalance-policy knobs are accepted (open_index passes them
        # through unconditionally) and inert: one shard never rebalances
        self._sharded = ShardedIndexService(
            keys, error, plan=plan, n_shards=n_shards,
            buffer_size=buffer_size, payload=payload, mode=mode,
            backend=backend, engine_opts=engine_opts,
            publish_every=publish_every, skew_threshold=skew_threshold,
            pending_weight=pending_weight, auto_rebalance=auto_rebalance,
            assume_sorted=assume_sorted, monitor=monitor)

    @classmethod
    def from_plan(cls, keys: np.ndarray, plan: IndexPlan, *,
                  payload: np.ndarray | None = None,
                  **service_kwargs) -> "IndexService":
        """Build from a resolved :class:`repro.index.fit.IndexPlan` (the
        ``fit.open_index`` path for one-shard plans)."""
        return cls(keys, plan=plan, payload=payload, **service_kwargs)

    @property
    def plan(self) -> IndexPlan:
        """The plan this service was built from (trivially resolved when
        constructed from raw knobs)."""
        return self._sharded.plan

    # ----------------------------------------------------- one-shard plumbing
    @property
    def tree(self):
        """The single shard's mutable FITingTree writer."""
        return self._sharded.writers[0]

    @property
    def publisher(self):
        return self._sharded.publishers[0]

    @property
    def handle(self):
        return self._sharded.handles[0]

    @property
    def default_backend(self) -> str:
        return self._sharded.default_backend

    @property
    def publish_every(self) -> int | None:
        return self._sharded.publish_every

    # ------------------------------------------------------------- write path
    def insert(self, key: float, value=None) -> None:
        """Buffer an insert (Alg. 4).  Not visible to lookups until publish.
        Read-only / no-payload misuse is rejected by the underlying service."""
        self._sharded.insert(key, value)

    def publish(self) -> Snapshot:
        """Cut a new epoch and swap it into serving atomically.

        With zero pending inserts this is a no-op: the installed snapshot is
        returned unchanged (same epoch), so cadence loops can call it
        unconditionally."""
        published = self._sharded.publish()
        return published.get(0, self.handle.current())

    # -------------------------------------------------------------- read path
    def lookup(self, queries, backend: str | None = None) -> np.ndarray:
        """Rank of each query in the current epoch's key column, -1 if absent."""
        return self._sharded.lookup(queries, backend)

    # ------------------------------------------------------ typed query plane
    # (see repro.index.query: every verb derives from the per-backend bounded
    # search primitive, so answers are backend-independent by construction)
    def search(self, queries, side: str = "left",
               backend: str | None = None) -> np.ndarray:
        """``searchsorted(keys, queries, side)`` insertion ranks in the
        current epoch's key column."""
        return self._sharded.search(queries, side, backend)

    def point(self, queries, backend: str | None = None) -> PointResult:
        """Typed membership: leftmost rank + found flag per query."""
        return self._sharded.point(queries, backend)

    def count(self, lo, hi, backend: str | None = None) -> np.ndarray:
        """Keys in the inclusive ``[lo, hi]`` ranges (vectorized)."""
        return self._sharded.count(lo, hi, backend)

    def range(self, lo, hi, *, materialize: bool = True,
              backend: str | None = None) -> RangeResult:
        """Inclusive ``[lo, hi]`` scan: global rank span + materialized keys
        (and payloads for a non-clustered index) from one pinned epoch."""
        return self._sharded.range(lo, hi, materialize=materialize,
                                   backend=backend)

    def predecessor(self, queries, backend: str | None = None) -> PointResult:
        """Rank of the largest key <= each query (rightmost occurrence)."""
        return self._sharded.predecessor(queries, backend)

    def successor(self, queries, backend: str | None = None) -> PointResult:
        """Rank of the smallest key >= each query (leftmost occurrence)."""
        return self._sharded.successor(queries, backend)

    def prewarm(self, backend: str | None = None,
                batch_sizes=None) -> None:
        """Build + compile the serving engines (and dispatch tiers) now, so
        the first batch -- e.g. the async pipeline's first coalesced flush --
        skips the lazy plan/compile latency spike."""
        self._sharded.prewarm(backend, batch_sizes=batch_sizes)

    @property
    def monitor(self):
        """The attached telemetry monitor (None when telemetry is off)."""
        return self._sharded.monitor

    def apply_plan(self, new_plan: "IndexPlan", *,
                   reshard: bool = True) -> "IndexPlan":
        """Hot-swap the served configuration (the ``Replanner`` path); the
        shard count stays 1 through this facade.  See
        ``ShardedIndexService.apply_plan``."""
        if new_plan.n_shards != 1:
            new_plan = dataclasses.replace(new_plan, n_shards=1)
        return self._sharded.apply_plan(new_plan, reshard=reshard)

    def metrics(self):
        """The typed observability snapshot (``MetricsSnapshot``); see
        ``ShardedIndexService.metrics``."""
        return dataclasses.replace(self._sharded.metrics(), service="index")

    def service_stats(self) -> dict:
        """Deprecated: use :meth:`metrics`.  Service-level observability
        incl. the per-shape query counters, derived field-for-field from the
        typed snapshot (RI006: no internal deprecated-surface calls)."""
        warnings.warn("IndexService.service_stats() is deprecated; use "
                      "metrics()", DeprecationWarning, stacklevel=2)
        m = self.metrics()
        return {"version": m.shard_set_version,
                "n_shards": m.n_shards,
                "imbalance": m.imbalance,
                "rebalances": m.rebalances,
                "rebalance_skipped": m.rebalance_skipped,
                "last_rebalance": m.last_rebalance,
                "pending_inserts": m.pending_inserts,
                "query_counts": m.query_counts}

    @property
    def epoch(self) -> int:
        return self.handle.epoch

    @property
    def pending_inserts(self) -> int:
        """Inserts buffered since the last publish (invisible to serving)."""
        return self._sharded.pending_inserts

    def stats(self):
        """Deprecated: use :meth:`metrics`\\ ``().shards``.  The single
        shard's observability sample in the legacy ``ShardStats`` shape."""
        warnings.warn("IndexService.stats() is deprecated; use "
                      "metrics().shards", DeprecationWarning, stacklevel=2)
        m = self.metrics()
        return [ShardStats(shard=s.shard, boundary=s.boundary, epoch=s.epoch,
                           n_segments=s.n_segments, n_keys=s.n_keys,
                           pending_inserts=s.pending_inserts,
                           snapshot_first_key=s.snapshot_first_key,
                           version=m.shard_set_version)
                for s in m.shards]
