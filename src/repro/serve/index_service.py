"""Index serving: epoch-snapshot front end over the unified index core.

Composes the write path (mutable ``FITingTree``, Alg. 4 buffered inserts) with
the read path (immutable ``SegmentTable`` snapshots served by any
``repro.index.engine`` backend) the same way the LM serving stack threads
caches through steps: writers mutate, ``publish`` cuts an epoch, and the
serving handle swaps the snapshot atomically so in-flight lookups keep a
consistent view.

    svc = IndexService(keys, error=64, buffer_size=16, backend="pallas")
    svc.lookup(q)            # epoch 1 (built at construction)
    svc.insert(k); ...       # buffered; serving unaffected
    svc.publish()            # epoch 2: inserts now visible to every backend

``IndexService`` is the single-host form: a thin wrapper over a one-shard
``repro.index.sharded.ShardedIndexService`` (the N-shard generalization with
per-shard epochs and adaptive shard rebalancing lives there; re-exported by
``repro.serve``).  ``publish`` with zero pending inserts is a **no-op**
returning the current snapshot -- periodic publish-cadence loops need no
guard logic and idle ticks don't churn epoch numbers or engine caches.
Rebalancing is inherently a no-op with one shard; use the sharded service
directly when write skew matters.
"""
from __future__ import annotations

import numpy as np

from repro.index.sharded import ShardedIndexService
from repro.index.snapshot import Snapshot


class IndexService:
    """One writable index + its serving handle, with optional auto-publish."""

    def __init__(self, keys: np.ndarray, error: int, *, buffer_size: int = 0,
                 payload: np.ndarray | None = None, mode: str = "paper",
                 backend: str = "numpy",
                 engine_opts: dict[str, dict] | None = None,
                 publish_every: int | None = None):
        self._sharded = ShardedIndexService(
            keys, error, n_shards=1, buffer_size=buffer_size, payload=payload,
            mode=mode, backend=backend, engine_opts=engine_opts,
            publish_every=publish_every)

    # ----------------------------------------------------- one-shard plumbing
    @property
    def tree(self):
        """The single shard's mutable FITingTree writer."""
        return self._sharded.writers[0]

    @property
    def publisher(self):
        return self._sharded.publishers[0]

    @property
    def handle(self):
        return self._sharded.handles[0]

    @property
    def default_backend(self) -> str:
        return self._sharded.default_backend

    @property
    def publish_every(self) -> int | None:
        return self._sharded.publish_every

    # ------------------------------------------------------------- write path
    def insert(self, key: float, value=None) -> None:
        """Buffer an insert (Alg. 4).  Not visible to lookups until publish.
        Read-only / no-payload misuse is rejected by the underlying service."""
        self._sharded.insert(key, value)

    def publish(self) -> Snapshot:
        """Cut a new epoch and swap it into serving atomically.

        With zero pending inserts this is a no-op: the installed snapshot is
        returned unchanged (same epoch), so cadence loops can call it
        unconditionally."""
        published = self._sharded.publish()
        return published.get(0, self.handle.current())

    # -------------------------------------------------------------- read path
    def lookup(self, queries, backend: str | None = None) -> np.ndarray:
        """Rank of each query in the current epoch's key column, -1 if absent."""
        return self._sharded.lookup(queries, backend)

    @property
    def epoch(self) -> int:
        return self.handle.epoch

    @property
    def pending_inserts(self) -> int:
        """Inserts buffered since the last publish (invisible to serving)."""
        return self._sharded.pending_inserts

    def stats(self):
        """The single shard's observability sample (see ShardStats)."""
        return self._sharded.stats()
