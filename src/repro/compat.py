"""Version-compatibility shims for jax public-API drift.

``shard_map`` became ``jax.shard_map`` (with ``check_vma``) in newer releases;
older jaxlibs only have ``jax.experimental.shard_map.shard_map`` (with the
same knob named ``check_rep``).  Import ``shard_map`` from here and always use
the new-style keyword.
"""
from __future__ import annotations

import jax

try:
    shard_map = jax.shard_map                      # jax >= 0.4.38
except AttributeError:                             # jax <= 0.4.37
    from jax.experimental.shard_map import shard_map as _experimental_shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kwargs):
        kwargs.setdefault("check_rep", check_vma)
        return _experimental_shard_map(f, mesh=mesh, in_specs=in_specs,
                                       out_specs=out_specs, **kwargs)
