"""Assigned architectures (public pool) as selectable configs: --arch <id>.

Each ``<id>.py`` module exports ``config() -> ModelConfig`` with the exact pool
dimensions.  ``reduced(cfg)`` shrinks any config to a CPU-smoke-test size of
the same family (same block pattern, tiny dims).  ``SHAPES`` defines the
assigned input-shape set; applicability skips are per DESIGN.md Sec. 4.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

from repro.models.config import ModelConfig, MoEConfig

ARCHS = [
    "gemma3-12b", "internlm2-1.8b", "gemma2-27b", "minicpm-2b", "arctic-480b",
    "qwen3-moe-235b-a22b", "llama-3.2-vision-11b", "recurrentgemma-9b",
    "xlstm-350m", "whisper-medium",
]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch.replace('-', '_').replace('.', '_')}")
    return mod.config()


def shape_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """(runs?, reason).  long_500k needs sub-quadratic / windowed attention."""
    if shape == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention architecture: O(seq) KV at 500k "
                       "decode exceeds any per-chip budget without windowed/"
                       "recurrent layers (DESIGN.md Sec. 4 skip list)")
    return True, ""


def reduced(cfg: ModelConfig, vocab: int = 512) -> ModelConfig:
    """Same family/pattern, smoke-test dims (runs a train step on 1 CPU core)."""
    moe = None
    if cfg.moe is not None:
        # capacity_factor 4.0: at smoke batch sizes the statistical routing
        # balance doesn't hold, so give headroom to avoid token drops
        moe = MoEConfig(n_experts=4, top_k=min(2, cfg.moe.top_k), d_expert=64,
                        dense_residual=cfg.moe.dense_residual,
                        capacity_factor=4.0)
    shrink = lambda stacks: tuple((unit, min(r, 2)) for unit, r in stacks)
    return dataclasses.replace(
        cfg,
        d_model=64, n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2) if
        cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16, d_ff=128, vocab=vocab,
        stacks=shrink(cfg.stacks),
        encoder_stacks=shrink(cfg.encoder_stacks),
        window=8, moe=moe, memory_len=16 if cfg.memory_len else 0,
        residual_scale=cfg.residual_scale if cfg.residual_scale is None
        else 0.25,
    )
