"""minicpm-2b [dense]: 40L llama-like with depth/width mu-P-style scaling and
the WSD schedule (train/schedules.py) [arXiv:2404.06395; hf]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm-2b", family="dense",
        d_model=2304, n_heads=36, n_kv_heads=36,
        d_ff=5760, vocab=122753,
        stacks=((("attn",), 40),),
        emb_scale=12.0, logit_scale=256.0 / 2304.0,
        residual_scale=1.4 / 40 ** 0.5,
        tie_embeddings=True,
    )
