"""whisper-medium [audio]: enc-dec, 24+24L; conv frontend is a STUB
(input_specs provides precomputed 1500-frame embeddings)
[arXiv:2212.04356; pool tier: unverified]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium", family="audio",
        d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=4096, vocab=51865,
        stacks=((("self+cross",), 24),),
        encoder_stacks=((("enc",), 24),),
        memory_len=1500, tie_embeddings=True,
    )
