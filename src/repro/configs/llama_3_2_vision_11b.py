"""llama-3.2-vision-11b [vlm]: 40L decoder, cross-attn to vision patches every
5th layer; vision frontend is a STUB (input_specs provides patch embeddings)
[hf:meta-llama/Llama-3.2-11B-Vision; pool tier: unverified]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b", family="vlm",
        d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=128256,
        # 40 layers = 8 x (4 self + 1 cross)
        stacks=((("attn",) * 4 + ("cross",), 8),),
        memory_len=1600,    # precomputed vision patch embeddings (stub)
        rope_theta=500_000.0, tie_embeddings=False,
    )
