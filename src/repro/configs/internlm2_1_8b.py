"""internlm2-1.8b [dense]: 24L GQA kv=8 [arXiv:2403.17297; hf]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-1.8b", family="dense",
        d_model=2048, n_heads=16, n_kv_heads=8,
        d_ff=8192, vocab=92544,
        stacks=((("attn",), 24),),
        rope_theta=1_000_000.0, tie_embeddings=False,
    )
