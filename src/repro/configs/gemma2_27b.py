"""gemma2-27b [dense]: 46L alternating local/global, logit softcaps
[arXiv:2408.00118; hf]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b", family="dense",
        d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
        d_ff=36864, vocab=256000,
        # 46 layers = 23 x (local + global)
        stacks=((("local", "attn"), 23),),
        window=4096, attn_softcap=50.0, final_softcap=30.0,
        post_norm=True, emb_scale=4608 ** 0.5, tie_embeddings=True,
        supports_long_context=True,   # half the layers are 4k-window local
    )
