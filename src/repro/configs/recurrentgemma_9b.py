"""recurrentgemma-9b [hybrid]: 38L, RG-LRU + local attention 2:1
[arXiv:2402.19427 (Griffin); pool tier: unverified]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b", family="hybrid",
        d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
        d_ff=12288, vocab=256000,
        # 38 layers = 12 x (rglru, rglru, local) + 2 rglru tail
        stacks=((("rglru", "rglru", "local"), 12), (("rglru",), 2)),
        window=2048, rglru_expand=1.0,
        emb_scale=4096 ** 0.5, tie_embeddings=True,
        supports_long_context=True,   # recurrent state is O(1) in seq
    )
