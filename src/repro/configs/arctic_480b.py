"""arctic-480b [moe]: 35L, 128-expert top-2 MoE in parallel with a dense
residual MLP (dense-MoE hybrid) [hf:Snowflake/snowflake-arctic-base]."""
from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b", family="moe",
        d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
        d_ff=4864, vocab=32000,
        stacks=((("moe",), 35),),
        moe=MoEConfig(n_experts=128, top_k=2, d_expert=4864,
                      dense_residual=True),
        tie_embeddings=False,
    )
