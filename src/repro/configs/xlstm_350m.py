"""xlstm-350m [ssm]: 24L sLSTM+mLSTM blocks (1 sLSTM per 4)
[arXiv:2405.04517; pool tier: unverified]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m", family="ssm",
        d_model=1024, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=50304,
        # 24 layers = 6 x (m, m, m, s)
        stacks=((("mlstm", "mlstm", "mlstm", "slstm"), 6),),
        mlstm_expand=2.0, slstm_proj=4.0 / 3.0,
        tie_embeddings=True,
        supports_long_context=True,   # recurrent state is O(1) in seq
    )
