"""qwen3-moe-235b-a22b [moe]: 94L, 128-expert top-8, qk-norm
[hf:Qwen/Qwen3-235B-A22B family]."""
from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b", family="moe",
        d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
        d_ff=1536, vocab=151936,
        stacks=((("moe",), 94),),
        moe=MoEConfig(n_experts=128, top_k=8, d_expert=1536),
        qk_norm=True, rope_theta=1_000_000.0,
        tie_embeddings=False,
    )
