"""gemma3-12b [dense]: 48L, 5:1 local:global, GQA kv=8, 128k ctx
[hf:google/gemma-3-12b family; pool entry verified-tier: unverified]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b", family="dense",
        d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
        d_ff=15360, vocab=262144,
        # 48 layers = 8 x (5 local + 1 global)
        stacks=((("local",) * 5 + ("attn",), 8),),
        window=1024, rope_theta=1_000_000.0,
        qk_norm=True, post_norm=True,
        emb_scale=3840 ** 0.5, tie_embeddings=True,
        supports_long_context=True,   # 5:1 local design targets 128k+
    )
