"""Sharded checkpointing: npz parts + msgpack manifest, async, atomic, elastic.

Layout:  <dir>/step_<N>/part_<k>.npz + manifest.msgpack + DONE marker.
  * atomic: written to step_<N>.tmp, fsync'd, renamed; readers only trust
    directories with a DONE marker -> a killed writer never corrupts state.
  * elastic re-mesh: leaves are saved as *logical* (unsharded) arrays; restore
    returns numpy trees the caller device_puts with the *current* mesh's
    NamedShardings -- a restart may use a different device count/topology.
  * async: save() can run in a background thread (training continues); the
    previous async save is joined first so at most one is in flight.
  * integrity: per-part crc32 in the manifest, verified on restore.
"""
from __future__ import annotations

import os
import pathlib
import shutil
import threading
import zlib
from typing import Any

import msgpack
import numpy as np
import jax


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str | os.PathLike, step: int, tree: Any,
         extra: dict | None = None, parts: int = 4) -> pathlib.Path:
    ckpt_dir = pathlib.Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, treedef = _flatten(tree)
    arrays = [np.asarray(l) for l in leaves]
    groups: list[list[int]] = [[] for _ in range(parts)]
    sizes = [0] * parts
    for i, a in enumerate(arrays):       # greedy size-balance across parts
        j = sizes.index(min(sizes))
        groups[j].append(i)
        sizes[j] += a.nbytes
    crcs = {}
    for j, idxs in enumerate(groups):
        path = tmp / f"part_{j}.npz"
        np.savez(path, **{f"leaf_{i}": arrays[i] for i in idxs})
        crcs[f"part_{j}.npz"] = zlib.crc32(path.read_bytes())
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(arrays),
        "leaf_part": {str(i): j for j, idxs in enumerate(groups)
                      for i in idxs},
        "shapes": [list(a.shape) for a in arrays],
        "dtypes": [str(a.dtype) for a in arrays],
        "crc32": crcs,
        "extra": extra or {},
    }
    (tmp / "manifest.msgpack").write_bytes(msgpack.packb(manifest))
    (tmp / "DONE").write_text("ok")
    for f in tmp.iterdir():              # fsync before rename
        fd = os.open(f, os.O_RDONLY)
        os.fsync(fd)
        os.close(fd)
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


class AsyncSaver:
    def __init__(self, ckpt_dir, keep_last: int = 3):
        self.ckpt_dir = pathlib.Path(ckpt_dir)
        self.keep_last = keep_last
        self._thread: threading.Thread | None = None

    def save(self, step: int, tree: Any, extra: dict | None = None):
        self.wait()
        # materialize to host *now* (cheap) so training can mutate buffers
        host = jax.tree.map(lambda l: np.asarray(l), tree)

        def run():
            save(self.ckpt_dir, step, host, extra)
            self._gc()

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.ckpt_dir.glob("step_*"))
        steps = [s for s in steps if (s / "DONE").exists()]
        for s in steps[: -self.keep_last]:
            shutil.rmtree(s, ignore_errors=True)


def latest_step(ckpt_dir) -> int | None:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    done = [int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*")
            if (p / "DONE").exists() and not p.name.endswith(".tmp")]
    return max(done) if done else None


def restore(ckpt_dir, step: int, like: Any) -> tuple[Any, dict]:
    """Returns (numpy tree shaped like `like`, extra).  Verifies crc32."""
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    manifest = msgpack.unpackb((d / "manifest.msgpack").read_bytes(),
                               strict_map_key=False)
    for name, crc in manifest["crc32"].items():
        got = zlib.crc32((d / name).read_bytes())
        if got != crc:
            raise IOError(f"checkpoint corruption: {name} crc {got} != {crc}")
    parts = {}
    for j in set(manifest["leaf_part"].values()):
        parts[j] = np.load(d / f"part_{j}.npz")
    leaves = []
    for i in range(manifest["n_leaves"]):
        j = manifest["leaf_part"][str(i)]
        leaves.append(parts[j][f"leaf_{i}"])
    _, treedef = jax.tree.flatten(like)
    tree = jax.tree.unflatten(treedef, leaves)
    return tree, manifest.get("extra", {})
