"""Sharded checkpoint save/restore."""
