"""Composable LM stack: blocks, configs, init/forward/decode drivers."""
from .config import ModelConfig, MoEConfig, simple_decoder
from .model import (decode_step, forward, init_caches, init_params, loss_fn,
                    prefill)

__all__ = ["ModelConfig", "MoEConfig", "simple_decoder", "init_params",
           "forward", "loss_fn", "init_caches", "prefill", "decode_step"]
