"""Model configuration: one dataclass covering all 10 assigned architectures.

A model is a list of *stacks*; each stack is a repeating *unit* of block types
scanned ``repeats`` times (params stacked on a leading repeat axis, O(1) HLO
size in depth).  Block types:

  attn          -- global causal GQA self-attention
  local         -- sliding-window causal GQA self-attention (cfg.window)
  cross         -- cross-attention to ``memory`` (vision patches / enc output)
  self+cross    -- decoder layer with self-attn then cross-attn (whisper dec)
  enc           -- bidirectional self-attention (whisper encoder)
  moe           -- attention + MoE FFN layer (cfg.moe)
  rglru         -- RecurrentGemma recurrent block (conv + RG-LRU)
  mlstm / slstm -- xLSTM blocks

Each unit position carries its own parameters; every non-recurrent block is
(norm -> mixer -> residual, norm -> ffn -> residual) unless the family says
otherwise (moe replaces the ffn; xlstm blocks have no separate ffn).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int           # per-expert FFN hidden dim
    dense_residual: bool = False   # arctic: dense MLP in parallel with MoE
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | vlm | hybrid | ssm | audio
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    stacks: Sequence[tuple[tuple[str, ...], int]]   # [(unit, repeats), ...]
    head_dim: Optional[int] = None  # default d_model // n_heads
    window: int = 1024              # sliding window for `local` blocks
    rope_theta: float = 10_000.0
    attn_softcap: Optional[float] = None    # gemma2: 50.0
    final_softcap: Optional[float] = None   # gemma2: 30.0
    qk_norm: bool = False                   # qwen3
    post_norm: bool = False                 # gemma2/3 sandwich norms
    emb_scale: Optional[float] = None       # gemma: sqrt(d); minicpm: 12
    logit_scale: Optional[float] = None     # minicpm: 1/(d/256)
    residual_scale: Optional[float] = None  # minicpm: 1.4/sqrt(L)
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    moe: Optional[MoEConfig] = None
    # enc-dec / multimodal frontends (STUBS: precomputed embeddings as inputs)
    encoder_stacks: Sequence[tuple[tuple[str, ...], int]] = ()
    memory_len: int = 0            # vision tokens / encoder frames fed to `cross`
    # serving
    supports_long_context: bool = False   # sub-quadratic / windowed; runs long_500k
    # RG-LRU / xLSTM dims
    rglru_expand: float = 1.5       # recurrent width = expand * d_model (griffin: 4/3..1.5)
    conv_width: int = 4
    mlstm_expand: float = 2.0
    slstm_proj: float = 4.0 / 3.0
    mlstm_chunk: int = 256

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_layers(self) -> int:
        return sum(len(u) * r for u, r in self.stacks) + \
               sum(len(u) * r for u, r in self.encoder_stacks)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for 6ND."""
        d, hd = self.d_model, self.hd
        n = self.vocab * d * (1 if self.tie_embeddings else 2)

        def block_params(btype: str) -> int:
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + \
                   self.n_heads * hd * d
            ffn = 3 * d * self.d_ff
            if btype in ("attn", "local", "enc"):
                return attn + ffn
            if btype == "cross":
                return attn + ffn
            if btype == "self+cross":
                return 2 * attn + ffn
            if btype == "moe":
                m = self.moe
                e = m.n_experts * 3 * d * m.d_expert
                dense = ffn if m.dense_residual else 0
                return attn + e + dense
            if btype == "rglru":
                w = int(self.rglru_expand * d)
                return 2 * d * w + self.conv_width * w + 3 * w + w * d + ffn
            if btype == "mlstm":
                w = int(self.mlstm_expand * d)
                return 2 * d * w + 3 * w * w // max(1, self.n_heads) + w * d
            if btype == "slstm":
                w = d
                return 4 * d * w + int(self.slstm_proj * d) * d * 2
            raise ValueError(btype)

        for stacks in (self.stacks, self.encoder_stacks):
            for unit, r in stacks:
                for bt in unit:
                    n += r * block_params(bt)
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of n_experts)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        m = self.moe
        d = self.d_model
        per_layer_all = m.n_experts * 3 * d * m.d_expert
        per_layer_active = m.top_k * 3 * d * m.d_expert
        n_moe_layers = sum(r * sum(1 for b in u if b == "moe")
                           for u, r in self.stacks)
        return full - n_moe_layers * (per_layer_all - per_layer_active)


def simple_decoder(name: str, n_layers: int, d_model: int, n_heads: int,
                   n_kv: int, d_ff: int, vocab: int, **kw) -> ModelConfig:
    return ModelConfig(name=name, family=kw.pop("family", "dense"),
                       d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
                       d_ff=d_ff, vocab=vocab,
                       stacks=((("attn",), n_layers),), **kw)
