"""Model driver: init / forward / prefill / decode over pattern-scanned stacks.

Params are stacked per repeating unit and scanned with ``jax.lax.scan`` (O(1)
HLO in depth -> fast 512-device SPMD compiles) with per-layer remat in train
mode.  Caches (KV rings / recurrent states) are scanned alongside params, so
prefill/decode work uniformly for attention, hybrid and SSM families.

Activation sharding: GSPMD does not reliably propagate the batch sharding
through while-loop carries (verified in the dry-run HLO: without constraints
the scan body runs with a replicated batch).  ``activation_sharding(mesh)``
installs a trace-time context; the forward pass re-anchors (B, T, D)
activations at the embed output, each scan-body entry, and the final hidden.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import blocks
from .blocks import Ctx
from .config import ModelConfig

Params = Any

from .act_ctx import activation_sharding, constrain_btd as _constrain_btd  # noqa: F401
# (activation_sharding re-exported here: launch/ imports it from models.model)


# ------------------------------------------------------------------ init
def init_block(btype: str, cfg: ModelConfig, key, dtype) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    ln = lambda: jnp.zeros((d,), dtype)
    if btype in ("attn", "local", "enc"):
        p = {"ln1": ln(), "attn": blocks.init_attention(cfg, ks[0], dtype=dtype),
             "ln2": ln(), "mlp": blocks.init_mlp(cfg, ks[1], dtype=dtype)}
    elif btype == "cross":
        p = {"ln1": ln(), "attn": blocks.init_attention(cfg, ks[0], cross=True,
                                                        dtype=dtype),
             "ln2": ln(), "mlp": blocks.init_mlp(cfg, ks[1], dtype=dtype)}
    elif btype == "self+cross":
        p = {"ln1": ln(), "attn": blocks.init_attention(cfg, ks[0], dtype=dtype),
             "lnc": ln(), "xattn": blocks.init_attention(cfg, ks[2], cross=True,
                                                         dtype=dtype),
             "ln2": ln(), "mlp": blocks.init_mlp(cfg, ks[1], dtype=dtype)}
    elif btype == "moe":
        p = {"ln1": ln(), "attn": blocks.init_attention(cfg, ks[0], dtype=dtype),
             "ln2": ln(), "moe": blocks.init_moe(cfg, ks[1], dtype=dtype)}
    elif btype == "rglru":
        p = {"ln1": ln(), "rec": blocks.init_rglru(cfg, ks[0], dtype=dtype),
             "ln2": ln(), "mlp": blocks.init_mlp(cfg, ks[1], dtype=dtype)}
    elif btype == "mlstm":
        p = {"ln1": ln(), "mix": blocks.init_mlstm(cfg, ks[0], dtype=dtype)}
    elif btype == "slstm":
        p = {"ln1": ln(), "mix": blocks.init_slstm(cfg, ks[0], dtype=dtype)}
    else:
        raise ValueError(btype)
    if cfg.post_norm and btype not in ("mlstm", "slstm"):
        p["ln1p"] = ln()
        p["ln2p"] = ln()
    return p


def _init_stacks(stacks, cfg, key, dtype):
    out = {}
    for si, (unit, r) in enumerate(stacks):
        key, sk = jax.random.split(key)
        def one_layer(k):
            kk = jax.random.split(k, len(unit))
            return {f"b{bi}": init_block(bt, cfg, kk[bi], dtype)
                    for bi, bt in enumerate(unit)}
        out[f"s{si}"] = jax.vmap(one_layer)(jax.random.split(sk, r))
    return out


def init_params(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> Params:
    k_emb, k_stacks, k_enc, k_un = jax.random.split(key, 4)
    p = {
        "embed": jax.random.normal(k_emb, (cfg.vocab, cfg.d_model), dtype) * 0.02,
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "stacks": _init_stacks(cfg.stacks, cfg, k_stacks, dtype),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = jax.random.normal(k_un, (cfg.d_model, cfg.vocab),
                                         dtype) * 0.02
    if cfg.encoder_stacks:
        p["enc_stacks"] = _init_stacks(cfg.encoder_stacks, cfg, k_enc, dtype)
        p["enc_final_norm"] = jnp.zeros((cfg.d_model,), dtype)
    return p


# ------------------------------------------------------------------ blocks
def apply_block(btype: str, p: dict, x, cfg: ModelConfig, ctx: Ctx):
    scale = cfg.residual_scale if cfg.residual_scale is not None else 1.0
    eps = cfg.norm_eps

    def residual(x, h, post_key):
        if cfg.post_norm and post_key in p:
            h = blocks.rmsnorm(p[post_key], h, eps)
        return x + scale * h

    if btype in ("attn", "local", "enc", "moe"):
        h = blocks.rmsnorm(p["ln1"], x, eps)
        h, cache = blocks.apply_attention(
            p["attn"], h, cfg, ctx, causal=(btype != "enc"),
            window=cfg.window if btype == "local" else None)
        x = residual(x, h, "ln1p")
        h = blocks.rmsnorm(p["ln2"], x, eps)
        h = blocks.apply_moe(p["moe"], h, cfg) if btype == "moe" else \
            blocks.apply_mlp(p["mlp"], h)
        x = residual(x, h, "ln2p")
        return x, cache
    if btype == "cross":
        h = blocks.rmsnorm(p["ln1"], x, eps)
        h, cache = blocks.apply_attention(p["attn"], h, cfg, ctx, cross=True)
        x = residual(x, h, "ln1p")
        h = blocks.rmsnorm(p["ln2"], x, eps)
        x = residual(x, blocks.apply_mlp(p["mlp"], h), "ln2p")
        return x, cache
    if btype == "self+cross":
        sub_self = Ctx(ctx.mode, ctx.pos, ctx.memory,
                       None if ctx.cache is None else ctx.cache["self"])
        h = blocks.rmsnorm(p["ln1"], x, eps)
        h, c_self = blocks.apply_attention(p["attn"], h, cfg, sub_self)
        x = x + scale * h
        sub_x = Ctx(ctx.mode, ctx.pos, ctx.memory,
                    None if ctx.cache is None else ctx.cache["cross"])
        h = blocks.rmsnorm(p["lnc"], x, eps)
        h, c_cross = blocks.apply_attention(p["xattn"], h, cfg, sub_x, cross=True)
        x = x + scale * h
        h = blocks.rmsnorm(p["ln2"], x, eps)
        x = x + scale * blocks.apply_mlp(p["mlp"], h)
        cache = None if ctx.cache is None and ctx.mode == "train" else \
            {"self": c_self, "cross": c_cross}
        return x, cache
    if btype == "rglru":
        h = blocks.rmsnorm(p["ln1"], x, eps)
        h, cache = blocks.apply_rglru(p["rec"], h, cfg, ctx)
        x = x + scale * h
        h = blocks.rmsnorm(p["ln2"], x, eps)
        x = x + scale * blocks.apply_mlp(p["mlp"], h)
        return x, cache
    if btype == "mlstm":
        h = blocks.rmsnorm(p["ln1"], x, eps)
        h, cache = blocks.apply_mlstm(p["mix"], h, cfg, ctx)
        return x + scale * h, cache
    if btype == "slstm":
        h = blocks.rmsnorm(p["ln1"], x, eps)
        h, cache = blocks.apply_slstm(p["mix"], h, cfg, ctx)
        return x + scale * h, cache
    raise ValueError(btype)


def _run_stacks(stack_params, stacks, x, cfg: ModelConfig, ctx_proto: Ctx,
                caches, remat: bool):
    new_caches = {}
    for si, (unit, r) in enumerate(stacks):
        sp = stack_params[f"s{si}"]
        sc = None if caches is None else caches[f"s{si}"]

        def body(carry, xs, unit=unit):
            xx = _constrain_btd(carry)
            lp, lc = xs
            ncs = {}
            for bi, bt in enumerate(unit):
                ctx = Ctx(ctx_proto.mode, ctx_proto.pos, ctx_proto.memory,
                          None if lc is None else lc[f"b{bi}"])
                xx, nc = apply_block(bt, lp[f"b{bi}"], xx, cfg, ctx)
                ncs[f"b{bi}"] = nc
            return _constrain_btd(xx), ncs

        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, ncs = jax.lax.scan(body, x, (sp, sc))
        new_caches[f"s{si}"] = ncs
    return x, new_caches


# ------------------------------------------------------------------ forward
def forward(params: Params, cfg: ModelConfig, tokens: jax.Array, *,
            memory: Optional[jax.Array] = None, mode: str = "train",
            pos: Optional[jax.Array] = None, caches=None, enc_caches=None,
            remat: bool = True, return_hidden: bool = False):
    """Returns (logits, new_caches).  tokens: (B, T) int32.

    ``memory``: precomputed frontend embeddings (B, M, D) -- vision patches
    (vlm) or audio frames (audio); run through encoder stacks if present.
    """
    b, t = tokens.shape
    x = params["embed"][tokens]
    if cfg.emb_scale is not None:
        x = x * jnp.asarray(cfg.emb_scale, x.dtype)
    x = _constrain_btd(x)
    if pos is None:
        pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))

    if cfg.encoder_stacks and memory is not None and enc_caches is None:
        mpos = jnp.broadcast_to(
            jnp.arange(memory.shape[1], dtype=jnp.int32)[None],
            memory.shape[:2])
        ectx = Ctx("train", mpos, None, None)
        memory, _ = _run_stacks(params["enc_stacks"], cfg.encoder_stacks,
                                memory, cfg, ectx, None, remat=(mode == "train"))
        memory = blocks.rmsnorm(params["enc_final_norm"], memory, cfg.norm_eps)
    elif enc_caches is not None:
        memory = enc_caches                     # precomputed encoder output

    ctx = Ctx(mode, pos, memory, None)
    x, new_caches = _run_stacks(params["stacks"], cfg.stacks, x, cfg, ctx,
                                caches, remat=(mode == "train" and remat))
    x = blocks.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    caches_out = new_caches if mode != "train" else None
    if return_hidden:
        return x, caches_out
    return unembed(params, cfg, x), caches_out


def unembed(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    un = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = x @ un
    if cfg.logit_scale is not None:
        logits = logits * cfg.logit_scale
    if cfg.final_softcap is not None:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    return logits


LOSS_CHUNK = 512  # sequence chunk for the vocab projection + xent


def loss_fn(params: Params, cfg: ModelConfig, tokens: jax.Array,
            memory: Optional[jax.Array] = None, remat: bool = True):
    """Next-token cross entropy, chunked over the sequence so the (B,C,V)
    logits of only one chunk are ever live (checkpointed scan body); a full
    (B,S,V) fp32 logits tensor at 256k vocab would be TBs at the train shape.
    """
    b, t1 = tokens.shape
    hidden, _ = forward(params, cfg, tokens, memory=memory, mode="train",
                        remat=remat, return_hidden=True)
    labels = jnp.roll(tokens, -1, axis=1)
    weights = jnp.concatenate([jnp.ones((t1 - 1,)), jnp.zeros((1,))]).astype(
        jnp.float32)
    c = LOSS_CHUNK if t1 % LOSS_CHUNK == 0 else t1
    nc = t1 // c

    @functools.partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def chunk_nll(h_c, y_c, w_c):
        logits = unembed(params, cfg, _constrain_btd(h_c)).astype(jnp.float32)
        from . import act_ctx
        if act_ctx.mesh() is not None and "model" not in act_ctx.dp_axes():
            logits = act_ctx.constrain(
                logits, P(act_ctx.dp_axes(), None, "model"))
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, y_c[..., None], axis=-1)[..., 0]
        return -jnp.sum(ll * w_c[None, :])

    hs = jnp.moveaxis(hidden.reshape(b, nc, c, -1), 1, 0)
    ys = jnp.moveaxis(labels.reshape(b, nc, c), 1, 0)
    ws = weights.reshape(nc, c)

    def body(acc, xs):
        return acc + chunk_nll(*xs), None

    total, _ = jax.lax.scan(body, jnp.zeros(()), (hs, ys, ws))
    return total / (b * (t1 - 1))


# ------------------------------------------------------------------ caches
def init_block_cache(btype: str, cfg: ModelConfig, batch: int, cache_len: int,
                     dtype=jnp.bfloat16):
    if btype in ("attn", "moe"):
        return blocks.init_attention_cache(cfg, batch, cache_len, dtype)
    if btype == "local":
        return blocks.init_attention_cache(cfg, batch,
                                           min(cfg.window, cache_len), dtype)
    if btype == "cross":
        kv, hd = cfg.n_kv_heads, cfg.hd
        return {"k": jnp.zeros((batch, cfg.memory_len, kv, hd), dtype),
                "v": jnp.zeros((batch, cfg.memory_len, kv, hd), dtype)}
    if btype == "self+cross":
        return {"self": init_block_cache("attn", cfg, batch, cache_len, dtype),
                "cross": init_block_cache("cross", cfg, batch, cache_len, dtype)}
    if btype == "rglru":
        return blocks.init_rglru_cache(cfg, batch, dtype)
    if btype == "mlstm":
        return blocks.init_mlstm_cache(cfg, batch)
    if btype == "slstm":
        return blocks.init_slstm_cache(cfg, batch)
    raise ValueError(btype)


def init_caches(cfg: ModelConfig, batch: int, cache_len: int,
                dtype=jnp.bfloat16):
    """Stacked (R, ...) caches per stack, matching the scan layout."""
    out = {}
    for si, (unit, r) in enumerate(cfg.stacks):
        layer = {f"b{bi}": init_block_cache(bt, cfg, batch, cache_len, dtype)
                 for bi, bt in enumerate(unit)}
        out[f"s{si}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (r,) + a.shape), layer)
    return out


def param_count(cfg: ModelConfig) -> int:
    """Exact parameter count via eval_shape (no allocation) -- feeds 6ND."""
    import numpy as np
    shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.key(0))
    return int(sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes)))


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token: full count minus inactive experts."""
    n = param_count(cfg)
    if cfg.moe is None:
        return n
    m = cfg.moe
    per_layer_inactive = (m.n_experts - m.top_k) * 3 * cfg.d_model * m.d_expert
    n_moe = sum(r * sum(1 for b in u if b == "moe") for u, r in cfg.stacks)
    return n - n_moe * per_layer_inactive


def decode_step(params: Params, cfg: ModelConfig, tokens: jax.Array,
                pos: jax.Array, caches, memory=None, enc_out=None):
    """One decode step.  tokens: (B, 1); pos: (B,) absolute positions."""
    logits, new_caches = forward(
        params, cfg, tokens, memory=memory, mode="decode",
        pos=pos[:, None], caches=caches, enc_caches=enc_out, remat=False)
    return logits, new_caches


def prefill(params: Params, cfg: ModelConfig, tokens: jax.Array,
            caches, memory=None, last_only: bool = False):
    """last_only=True returns only the final position's logits (the serving
    path: a full (B, 32k, 256k-vocab) logits tensor is never needed)."""
    hidden, new_caches = forward(params, cfg, tokens, memory=memory,
                                 mode="prefill", caches=caches, remat=False,
                                 return_hidden=True)
    if last_only:
        hidden = hidden[:, -1:]
    return unembed(params, cfg, hidden), new_caches
