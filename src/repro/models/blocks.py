"""Composable model blocks (functional: explicit param pytrees, no framework).

Every block follows ``apply_<x>(params, x, cfg, ctx) -> (x, new_cache)`` where
``ctx`` carries mode/positions/memory/cache.  Caches make prefill/decode work
for every family: KV rings for attention (global cache = ring of size S,
local = ring of size window), recurrent states for RG-LRU / xLSTM.

Recurrent blocks (mLSTM / sLSTM) are implemented in their *exact* paper
recurrence via lax.scan -- the faithful form; RG-LRU uses an associative scan
(parallel).  See DESIGN.md for the chunked/Pallas variants on real hardware.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from . import act_ctx
from .config import ModelConfig


@dataclasses.dataclass
class Ctx:
    mode: str                      # "train" | "prefill" | "decode"
    pos: jax.Array | None = None   # (B, T) absolute positions
    memory: jax.Array | None = None  # (B, M, D) cross-attn source (stub frontend)
    cache: Any = None              # per-layer cache pytree (prefill/decode)


Init = jax.nn.initializers.normal(stddev=0.02)

# bf16 on the wire (SPerf lever): jnp's default matmul accumulates to f32, and
# XLA hoists that convert above the TP partial-sum all-reduce -- putting f32
# activations on the interconnect.  preferred_element_type=bf16 keeps the dot
# output (and therefore the collective) in bf16: 2x fewer collective bytes.
# MXU accumulation is still f32 internally; only the cross-shard reduction is
# bf16 (standard practice, cf. MaxText).  Toggle for ablation via env.
import os as _os
WIRE_BF16 = _os.environ.get("REPRO_WIRE_F32", "") == ""


def mm(x, w):
    if WIRE_BF16 and x.dtype == jnp.bfloat16 and w.dtype == jnp.bfloat16:
        return jax.lax.dot_general(
            x, w, (((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.bfloat16)
    return x @ w


def _dense(key, shape, dtype):
    return Init(key, shape, dtype)


def rmsnorm(scale, x, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))
            ).astype(x.dtype)


def rope(x, pos, theta):
    """x: (B, T, H, hd), pos: (B, T) -> rotated."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos[..., None].astype(jnp.float32) * freqs       # (B, T, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1).astype(x.dtype)


# --------------------------------------------------------------------- attn
def init_attention(cfg: ModelConfig, key, cross: bool = False, dtype=jnp.bfloat16):
    d, hd, h, kv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 5)
    p = {
        "wq": _dense(ks[0], (d, h * hd), dtype),
        "wk": _dense(ks[1], (d, kv * hd), dtype),
        "wv": _dense(ks[2], (d, kv * hd), dtype),
        "wo": _dense(ks[3], (h * hd, d), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


Q_CHUNK = 512  # memory-efficient attention: peak logits = B*H*Q_CHUNK*S


def _attend_dense(q, k, v, mask, cfg: ModelConfig):
    """q: (B,T,H,hd); k,v: (B,S,Kv,hd); mask: (B,T,S) or (T,S). GQA-grouped."""
    b, t, h, hd = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    q = q.reshape(b, t, kv, g, hd)
    logits = jnp.einsum("btkgd,bskd->bkgts", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (cfg.hd ** -0.5)
    if cfg.attn_softcap is not None:
        logits = jnp.tanh(logits / cfg.attn_softcap) * cfg.attn_softcap
    m = mask if mask.ndim == 3 else mask[None]
    logits = jnp.where(m[:, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, t, h * hd).astype(v.dtype)


def _attend(q, k, v, mask, cfg: ModelConfig):
    """Query-chunked attention: O(Q_CHUNK * S) logits live at once instead of
    O(T * S) -- the XLA-side training twin of kernels/flash_attention.py
    (autodiff-able under remat); the scan keeps HLO and dry-run memory small."""
    b, t, h, hd = q.shape
    if t <= Q_CHUNK or t % Q_CHUNK != 0:
        return _attend_dense(q, k, v, mask, cfg)
    nc = t // Q_CHUNK
    qs = jnp.moveaxis(q.reshape(b, nc, Q_CHUNK, h, hd), 1, 0)
    if mask.ndim == 3:
        ms = jnp.moveaxis(mask.reshape(b, nc, Q_CHUNK, -1), 1, 0)
    else:
        ms = mask.reshape(nc, Q_CHUNK, -1)
    # checkpoint the chunk so backward recomputes the (chunk x S) probs
    # instead of storing every chunk's softmax (flash-attention residuals)
    body = jax.checkpoint(
        lambda args: _attend_dense(args[0], k, v, args[1], cfg))
    out = jax.lax.map(body, (qs, ms))
    return jnp.moveaxis(out, 0, 1).reshape(b, t, h * hd)


def apply_attention(p, x, cfg: ModelConfig, ctx: Ctx, *,
                    causal: bool = True, window: Optional[int] = None,
                    cross: bool = False):
    """Self- or cross-attention with ring caches for prefill/decode."""
    b, t, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = mm(x, p["wq"]).reshape(b, t, h, hd)
    if cross:
        mem = ctx.memory
        if ctx.cache is not None and "k" in ctx.cache and ctx.mode == "decode":
            k, v = ctx.cache["k"], ctx.cache["v"]
            new_cache = ctx.cache
        else:
            k = mm(mem, p["wk"]).reshape(b, -1, kv, hd)
            v = mm(mem, p["wv"]).reshape(b, -1, kv, hd)
            new_cache = {"k": k, "v": v}
        if cfg.qk_norm:
            q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
            k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
        mask = jnp.ones((t, k.shape[1]), bool)
        out = _attend(q, k, v, mask, cfg)
        return x_out(p, out, b, t), new_cache

    k = mm(x, p["wk"]).reshape(b, t, kv, hd)
    v = mm(x, p["wv"]).reshape(b, t, kv, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    pos = ctx.pos if ctx.pos is not None else \
        jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)

    if ctx.mode == "train" or ctx.cache is None or ctx.mode == "prefill":
        # batch-uniform positions in train/prefill -> a 2D (T,T) mask suffices
        ar = jnp.arange(t, dtype=jnp.int32)
        qp, kp = ar[:, None], ar[None, :]
        mask = jnp.ones((t, t), bool)
        if causal:
            mask &= kp <= qp
        if window is not None:
            mask &= kp > qp - window
        out = x_out(p, _attend(q, k, v, mask, cfg), b, t)
        if ctx.mode != "prefill" or ctx.cache is None:
            return out, None
        # fill the ring with the last min(T, L) tokens for subsequent decode
        # (a ring cannot hold the full prefill when T > L; queries above
        #  already attended the exact windowed mask)
        cache = ctx.cache
        L = cache["k"].shape[1]
        tw = min(t, L)
        slots = pos[:, t - tw:] % L
        new_cache = {
            "k": _ring_write(cache["k"], k[:, t - tw:], slots),
            "v": _ring_write(cache["v"], v[:, t - tw:], slots),
            "pos": cache["pos"].at[jnp.arange(b)[:, None], slots].set(
                pos[:, t - tw:]),
        }
        return out, new_cache

    # decode: ring cache (B, L, Kv, hd) + cache positions (B, L)
    cache = ctx.cache
    L = cache["k"].shape[1]
    slots = pos % L                                          # (B, T)
    ck = _ring_write(cache["k"], k, slots)
    cv = _ring_write(cache["v"], v, slots)
    cpos = cache["pos"].at[jnp.arange(b)[:, None], slots].set(pos)
    new_cache = {"k": ck, "v": cv, "pos": cpos}
    qp = pos[:, :, None]
    kp = cpos[:, None, :]                                    # (B,1,L)
    mask = kp >= 0
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= kp > qp - window
    return x_out(p, _attend(q, ck, cv, mask, cfg), b, t), new_cache


def _ring_write(buf, vals, slots):
    """buf: (B, L, ...), vals: (B, T, ...), slots: (B, T) -> scattered buf."""
    b = buf.shape[0]
    bi = jnp.arange(b)[:, None]
    return buf.at[bi, slots].set(vals.astype(buf.dtype))


def x_out(p, attn_out, b, t):
    return mm(attn_out, p["wo"])


def init_attention_cache(cfg: ModelConfig, batch: int, length: int,
                         dtype=jnp.bfloat16):
    kv, hd = cfg.n_kv_heads, cfg.hd
    return {"k": jnp.zeros((batch, length, kv, hd), dtype),
            "v": jnp.zeros((batch, length, kv, hd), dtype),
            "pos": jnp.full((batch, length), -1, jnp.int32)}


# ---------------------------------------------------------------------- ffn
def init_mlp(cfg: ModelConfig, key, dtype=jnp.bfloat16, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {"wi": _dense(ks[0], (d, f), dtype),
            "wg": _dense(ks[1], (d, f), dtype),
            "wo": _dense(ks[2], (f, d), dtype)}


def apply_mlp(p, x):
    return mm(jax.nn.silu(mm(x, p["wg"])) * mm(x, p["wi"]), p["wo"])


# ---------------------------------------------------------------------- moe
def init_moe(cfg: ModelConfig, key, dtype=jnp.bfloat16):
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_expert, m.n_experts
    ks = jax.random.split(key, 5)
    p = {"router": _dense(ks[0], (d, e), jnp.float32),
         "wi": _dense(ks[1], (e, d, f), dtype),
         "wg": _dense(ks[2], (e, d, f), dtype),
         "wo": _dense(ks[3], (e, f, d), dtype)}
    if m.dense_residual:
        p["dense"] = init_mlp(cfg, ks[4], dtype)
    return p


def apply_moe(p, x, cfg: ModelConfig):
    """Top-k MoE FFN.  Two implementations:

    * shard_map expert-parallel path (mesh context installed, experts divide
      `model`): every model-rank owns E/tp experts, activations stay
      replicated over `model` (they already are under 2D sharding), each rank
      gathers only its own experts' weights over `data` (ZeRO-style, ~param
      bytes), buckets its local tokens for its own experts, runs the dense
      expert einsum locally, and one psum over `model` combines.  Collectives
      per layer = weight gather + one (B_loc, S, D) all-reduce -- the XLA
      global-scatter path replicates (E, C, D) dispatch buffers and
      all-reduces them (measured ~50x more bytes on qwen3-moe;
      EXPERIMENTS.md SPerf cell A).
    * pure-XLA fallback (single-device tests, eager use, tiny meshes).
    """
    mesh = act_ctx.mesh()
    if (mesh is not None and "model" in mesh.axis_names
            and mesh.shape["model"] > 1
            and cfg.moe.n_experts % mesh.shape["model"] == 0
            and x.shape[0] % max(act_ctx.dp_size(), 1) == 0
            # decode (T==1): the per-step ZeRO weight gather would dwarf the
            # few active tokens -- GSPMD's dispatch wins there (measured:
            # arctic decode 0.04s vs 2.7s collective under EP)
            and x.shape[1] > 1):
        return _apply_moe_shardmap(p, x, cfg, mesh)
    return _apply_moe_xla(p, x, cfg)


def _bucket_and_run(xt, w, ids, wi, wg, wo, n_buckets, cap, bucket_of, dtype):
    """Slot assignments into (n_buckets, cap), run experts, combine back.
    bucket_of >= n_buckets marks an assignment as not-ours/dropped."""
    tk = ids.size
    k = ids.shape[-1]
    d = xt.shape[-1]
    flat_b = bucket_of.reshape(-1)
    order = jnp.argsort(flat_b, stable=True)
    sorted_b = flat_b[order]
    grp = (jnp.arange(tk, dtype=jnp.int32)
           - jnp.searchsorted(sorted_b, sorted_b, side="left").astype(jnp.int32))
    keep = (sorted_b < n_buckets) & (grp < cap)
    slot = jnp.where(keep, sorted_b * cap + grp, n_buckets * cap)
    tok = order // k
    buf = jnp.zeros((n_buckets * cap + 1, d), dtype).at[slot].set(
        jnp.where(keep[:, None], xt[tok], 0))
    xe = buf[: n_buckets * cap].reshape(n_buckets, cap, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg)) * \
        jnp.einsum("ecd,edf->ecf", xe, wi)
    ye = jnp.einsum("ecf,efd->ecd", h, wo).reshape(n_buckets * cap, d)
    back = jnp.where(keep[:, None],
                     ye[jnp.minimum(slot, n_buckets * cap - 1)], 0)
    w_sorted = w.reshape(-1)[order].astype(dtype)
    return jnp.zeros((xt.shape[0], d), dtype).at[tok].add(
        back * w_sorted[:, None])


def _apply_moe_shardmap(p, x, cfg: ModelConfig, mesh):
    m = cfg.moe
    b, s, d = x.shape
    dp = act_ctx.dp_axes()
    dp_size = max(act_ctx.dp_size(), 1)
    tp = mesh.shape["model"]
    e, k = m.n_experts, m.top_k
    e_loc = e // tp
    t_loc = (b // dp_size) * s
    cap = max(1, int(math.ceil(t_loc * k / e * m.capacity_factor)))

    x_spec = P(dp if dp else None, None, None)
    specs_in = [P("model", "data", None), P("model", "data", None),
                P("model", None, "data"), P("data", None), x_spec]

    def body(wi, wg, wo, router, x_loc):
        mi = jax.lax.axis_index("model")
        # ZeRO gather of this rank's expert weights over `data`
        wi = jax.lax.all_gather(wi, "data", axis=1, tiled=True)
        wg = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
        wo = jax.lax.all_gather(wo, "data", axis=2, tiled=True)
        router_f = jax.lax.all_gather(router, "data", axis=0, tiled=True)
        xt = x_loc.reshape(-1, d)
        probs = jax.nn.softmax(xt.astype(jnp.float32) @ router_f, axis=-1)
        w, ids = jax.lax.top_k(probs, k)                   # (t_loc, k)
        w = w / jnp.sum(w, axis=-1, keepdims=True)
        # assignments owned by this model-rank; others -> bucket e_loc (drop)
        local_e = ids - mi * e_loc
        bucket_of = jnp.where((local_e >= 0) & (local_e < e_loc),
                              local_e, e_loc)
        out = _bucket_and_run(xt, w, ids, wi, wg, wo, e_loc, cap,
                              bucket_of, x.dtype)
        out = jax.lax.psum(out, "model")
        return out.reshape(x_loc.shape)

    args = [p["wi"], p["wg"], p["wo"], p["router"].astype(x.dtype), x]
    fn = shard_map(body, mesh=mesh, in_specs=tuple(specs_in),
                   out_specs=x_spec, check_vma=False)
    out = fn(*args)
    if m.dense_residual:
        # dense residual OUTSIDE shard_map: GSPMD shards it once (computing
        # it per model-rank would 16x its FLOPs -- measured on arctic)
        out = out + apply_mlp(p["dense"], x)
    return out


def _apply_moe_xla(p, x, cfg: ModelConfig):
    """Sort-based top-k dispatch with static per-expert capacity (token-drop).

    FLOPs = T * top_k * capacity_factor * 3 * D * F * 2 (active params only)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    logits = (xt.astype(jnp.float32)) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, m.top_k)                   # (T, k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    e, k = m.n_experts, m.top_k
    cap = max(1, int(math.ceil(t * k / e * m.capacity_factor)))
    out = _bucket_and_run(xt, w, ids, p["wi"], p["wg"], p["wo"], e, cap,
                          ids, x.dtype)
    if m.dense_residual:
        out = out + apply_mlp(p["dense"], xt)
    return out.reshape(b, s, d)


# -------------------------------------------------------------------- rglru
def init_rglru(cfg: ModelConfig, key, dtype=jnp.bfloat16):
    d = cfg.d_model
    w = int(cfg.rglru_expand * d)
    ks = jax.random.split(key, 7)
    return {"wx": _dense(ks[0], (d, w), dtype),
            "wy": _dense(ks[1], (d, w), dtype),      # gate branch
            "conv": _dense(ks[2], (cfg.conv_width, w), dtype),
            "a_log": jnp.full((w,), 0.5, jnp.float32),
            "wgx": _dense(ks[3], (w, w), dtype),     # input gate
            "wga": _dense(ks[4], (w, w), dtype),     # recurrence gate
            "wo": _dense(ks[5], (w, d), dtype)}


def _linear_scan_impl(u, a, reverse=False):
    def combine(x, y):
        a1, u1 = x
        a2, u2 = y
        return a1 * a2, a2 * u1 + u2
    _, h = jax.lax.associative_scan(combine, (a, u), axis=1, reverse=reverse)
    return h


@jax.custom_vjp
def _rglru_scan(u, a):
    """h_t = a_t * h_{t-1} + u_t via associative scan.  u, a: (B, T, W) f32.

    Custom VJP: naive autodiff of associative_scan keeps O(log T) full-width
    intermediates live; the adjoint of a linear recurrence is just the same
    recurrence run backwards (g_t = dh_t + a_{t+1} g_{t+1}), so the backward
    pass costs one more scan and the residuals are exactly (a, h)."""
    return _linear_scan_impl(u, a)


def _rglru_scan_fwd(u, a):
    h = _linear_scan_impl(u, a)
    return h, (a, h)


def _rglru_scan_bwd(res, g):
    a, h = res
    a_next = jnp.concatenate([a[:, 1:], jnp.ones_like(a[:, :1])], axis=1)
    gacc = _linear_scan_impl(g, a_next, reverse=True)
    h_prev = jnp.concatenate([jnp.zeros_like(h[:, :1]), h[:, :-1]], axis=1)
    return gacc, gacc * h_prev


_rglru_scan.defvjp(_rglru_scan_fwd, _rglru_scan_bwd)


def apply_rglru(p, x, cfg: ModelConfig, ctx: Ctx):
    """RecurrentGemma recurrent block: proj -> causal conv -> RG-LRU -> gate."""
    b, t, d = x.shape
    u = x @ p["wx"]                                          # (B,T,W)
    gate = jax.nn.gelu(x @ p["wy"])
    cache = ctx.cache or {}
    cw = cfg.conv_width
    if ctx.mode == "decode" and "conv" in cache:
        hist = jnp.concatenate([cache["conv"], u], axis=1)   # (B, cw-1+T, W)
    else:
        hist = jnp.pad(u, ((0, 0), (cw - 1, 0), (0, 0)))
    conv = sum(hist[:, i: i + t] * p["conv"][i][None, None]
               for i in range(cw))
    ga = jax.nn.sigmoid(conv @ p["wga"])
    gx = jax.nn.sigmoid(conv @ p["wgx"])
    c = 8.0
    log_a = (-c * jax.nn.softplus(p["a_log"])[None, None]
             * ga.astype(jnp.float32))
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.clip(1.0 - a ** 2, 1e-12, None))
    un = (gx * conv).astype(jnp.float32) * mult
    if ctx.mode == "decode" and "h" in cache:
        h0 = cache["h"]
        h = a[:, 0] * h0 + un[:, 0]
        hs = h[:, None]
    else:
        hs = _rglru_scan(un, a)
        h = hs[:, -1]
    new_cache = {"conv": hist[:, -(cw - 1):] if cw > 1 else hist[:, :0],
                 "h": h} if ctx.mode != "train" else None
    y = (hs.astype(x.dtype) * gate) @ p["wo"]
    return y, new_cache


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    w = int(cfg.rglru_expand * cfg.d_model)
    return {"conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
            "h": jnp.zeros((batch, w), jnp.float32)}


# -------------------------------------------------------------------- xlstm
def init_mlstm(cfg: ModelConfig, key, dtype=jnp.bfloat16):
    d = cfg.d_model
    w = int(cfg.mlstm_expand * d)
    ks = jax.random.split(key, 8)
    return {"wu": _dense(ks[0], (d, w), dtype),
            "wg": _dense(ks[1], (d, w), dtype),
            "wq": _dense(ks[2], (w, w), dtype),
            "wk": _dense(ks[3], (w, w), dtype),
            "wv": _dense(ks[4], (w, w), dtype),
            "wi": _dense(ks[5], (w, cfg.n_heads), dtype),
            "wf": _dense(ks[6], (w, cfg.n_heads), dtype),
            "wo": _dense(ks[7], (w, d), dtype)}


def _mlstm_sequential(q, k, v, log_i, log_f, c0, n0, m0):
    """Exact stabilized recurrence (decode path + chunkwise test oracle).
    q,k,v: (B,T,H,hd) f32; log_i/log_f: (B,T,H) f32."""

    def step(carry, inp):
        c, n, m = carry
        qt, kt, vt, li, lf = inp
        m_new = jnp.maximum(lf + m, li)
        f_ = jnp.exp(lf + m - m_new)[..., None]              # (B,H,1)
        i_ = jnp.exp(li - m_new)[..., None]
        n = f_ * n + i_ * kt
        c = f_[..., None] * c + i_[..., None] * (vt[..., :, None] *
                                                 kt[..., None, :])
        num = jnp.einsum("bhij,bhj->bhi", c, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, qt)), 1.0)
        return (c, n, m_new), num / den[..., None]

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (q, k, v, log_i, log_f))
    (cT, nT, mT), hs = jax.lax.scan(step, (c0, n0, m0), xs)
    return jnp.moveaxis(hs, 0, 1), (cT, nT, mT)


def _mlstm_chunk(carry, inp):
    """One chunk of the stabilized chunkwise-parallel mLSTM (the form real
    kernels use: BPTT stores O(T/L) inter-chunk states, not O(T) matrices).

    q,k,v: (B,H,L,hd) f32; log_i/log_f: (B,H,L) f32; carry (C, n, m)."""
    c_in, n_in, m_in = carry
    q, k, v, log_i, log_f = inp
    L = q.shape[2]
    b_cum = jnp.cumsum(log_f, axis=-1)                       # inclusive decay
    # intra-chunk pairwise log-weights: b_t - b_j + log_i_j for j <= t
    dmat = (b_cum[..., :, None] - b_cum[..., None, :] + log_i[..., None, :])
    causal = jnp.tril(jnp.ones((L, L), bool))
    dmat = jnp.where(causal, dmat, -jnp.inf)
    m_intra = jnp.max(dmat, axis=-1)                         # (B,H,L)
    m_inter = m_in[..., None] + b_cum                        # (B,H,L)
    m_t = jnp.maximum(m_inter, m_intra)
    d = jnp.exp(dmat - m_t[..., None])                       # (B,H,L,L)
    r = jnp.exp(m_inter - m_t)                               # (B,H,L)
    scores = jnp.einsum("bhtd,bhjd->bhtj", q, k) * d
    num = (jnp.einsum("bhtj,bhjd->bhtd", scores, v)
           + r[..., None] * jnp.einsum("bhij,bhtj->bhti", c_in, q))
    den = (jnp.sum(scores, axis=-1)
           + r * jnp.einsum("bhj,bhtj->bht", n_in, q))
    h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    # chunk-exit state
    B_L = b_cum[..., -1]
    m_out = jnp.maximum(m_in + B_L,
                        jnp.max(B_L[..., None] - b_cum + log_i, axis=-1))
    w = jnp.exp(B_L[..., None] - b_cum + log_i - m_out[..., None])  # (B,H,L)
    decay = jnp.exp(m_in + B_L - m_out)
    c_out = (decay[..., None, None] * c_in
             + jnp.einsum("bhj,bhjv,bhjk->bhvk", w, v, k))
    n_out = decay[..., None] * n_in + jnp.einsum("bhj,bhjk->bhk", w, k)
    return (c_out, n_out, m_out), h


def apply_mlstm(p, x, cfg: ModelConfig, ctx: Ctx):
    """mLSTM (xLSTM Sec. 2.3): chunkwise-parallel stabilized form for
    train/prefill (chunk = cfg.mlstm_chunk), exact recurrence for decode.
    tests/test_xlstm_forms.py asserts the two forms agree.

    State per head: C (hd,hd) matrix memory, n (hd,), m () stabilizer."""
    b, t, d = x.shape
    h = cfg.n_heads
    u = x @ p["wu"]
    gate = jax.nn.silu(x @ p["wg"])
    w = u.shape[-1]
    hd = w // h
    q = (u @ p["wq"]).reshape(b, t, h, hd).astype(jnp.float32)
    k = ((u @ p["wk"]) / math.sqrt(hd)).reshape(b, t, h, hd).astype(jnp.float32)
    v = (u @ p["wv"]).reshape(b, t, h, hd).astype(jnp.float32)
    log_i = jnp.clip(u @ p["wi"], -10.0, 10.0).astype(jnp.float32)   # (B,T,H)
    log_f = jax.nn.log_sigmoid((u @ p["wf"]).astype(jnp.float32))

    cache = ctx.cache or {}
    if "C" in cache:
        c0, n0, m0 = cache["C"], cache["n"], cache["m"]
    else:
        c0 = jnp.zeros((b, h, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, h, hd), jnp.float32)
        m0 = jnp.full((b, h), -jnp.inf, jnp.float32)

    L = cfg.mlstm_chunk
    if t == 1 or (ctx.mode == "decode"):
        hs, (cT, nT, mT) = _mlstm_sequential(q, k, v, log_i, log_f, c0, n0, m0)
    else:
        # pad T to a chunk multiple; padded steps get log_i=-inf (no effect)
        tp = (t + L - 1) // L * L
        pad = tp - t
        def padt(a, fill=0.0):
            return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2),
                           constant_values=fill)
        qh = jnp.moveaxis(padt(q), 2, 1)                     # (B,H,Tp,hd)
        kh = jnp.moveaxis(padt(k), 2, 1)
        vh = jnp.moveaxis(padt(v), 2, 1)
        lih = jnp.moveaxis(padt(log_i, -jnp.inf), 2, 1)      # (B,H,Tp)
        lfh = jnp.moveaxis(padt(log_f), 2, 1)
        nch = tp // L
        split = lambda a: jnp.moveaxis(
            a.reshape(a.shape[0], a.shape[1], nch, L, *a.shape[3:]), 2, 0)
        xs = (split(qh), split(kh), split(vh), split(lih), split(lfh))
        chunk_body = jax.checkpoint(
            _mlstm_chunk, policy=jax.checkpoint_policies.nothing_saveable)
        (cT, nT, mT), hs_c = jax.lax.scan(chunk_body, (c0, n0, m0), xs)
        # (nch,B,H,L,hd) -> (B,H,Tp,hd) -> (B,T,H,hd)
        hs = jnp.moveaxis(jnp.moveaxis(hs_c, 0, 2).reshape(b, h, tp, hd),
                          1, 2)[:, :t]
    out = hs.reshape(b, t, w).astype(x.dtype)
    new_cache = ({"C": cT, "n": nT, "m": mT} if ctx.mode != "train" else None)
    return (out * gate) @ p["wo"], new_cache


def init_mlstm_cache(cfg: ModelConfig, batch: int):
    w = int(cfg.mlstm_expand * cfg.d_model)
    hd = w // cfg.n_heads
    return {"C": jnp.zeros((batch, cfg.n_heads, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, cfg.n_heads, hd), jnp.float32),
            "m": jnp.full((batch, cfg.n_heads), -jnp.inf, jnp.float32)}


def init_slstm(cfg: ModelConfig, key, dtype=jnp.bfloat16):
    d = cfg.d_model
    f = int(cfg.slstm_proj * d)
    ks = jax.random.split(key, 6)
    return {"wz": _dense(ks[0], (d, d), dtype),
            "wi": _dense(ks[1], (d, d), dtype),
            "wf": _dense(ks[2], (d, d), dtype),
            "wo": _dense(ks[3], (d, d), dtype),
            "up": _dense(ks[4], (d, f), dtype),
            "down": _dense(ks[5], (f, d), dtype)}


def apply_slstm(p, x, cfg: ModelConfig, ctx: Ctx):
    """sLSTM (xLSTM Sec. 2.2): scalar memory, exp input gating, stabilized."""
    b, t, d = x.shape
    z = jnp.tanh(x @ p["wz"]).astype(jnp.float32)
    log_i = jnp.clip(x @ p["wi"], -10, 10).astype(jnp.float32)
    log_f = jax.nn.log_sigmoid((x @ p["wf"]).astype(jnp.float32))
    o = jax.nn.sigmoid(x @ p["wo"]).astype(jnp.float32)

    cache = ctx.cache or {}
    if "c" in cache:
        c0, n0, m0 = cache["c"], cache["n"], cache["m"]
    else:
        c0 = jnp.zeros((b, d), jnp.float32)
        n0 = jnp.zeros((b, d), jnp.float32)
        m0 = jnp.full((b, d), -jnp.inf, jnp.float32)

    def step(carry, inp):
        c, n, m = carry
        zt, li, lf, ot = inp
        m_new = jnp.maximum(lf + m, li)
        f_ = jnp.exp(lf + m - m_new)
        i_ = jnp.exp(li - m_new)
        c = f_ * c + i_ * zt
        n = f_ * n + i_
        h = ot * c / jnp.maximum(n, 1.0)
        return (c, n, m_new), h

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (z, log_i, log_f, o))
    (cT, nT, mT), hs = jax.lax.scan(step, (c0, n0, m0), xs)
    out = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    new_cache = ({"c": cT, "n": nT, "m": mT} if ctx.mode != "train" else None)
    y = out @ p["up"]
    return jax.nn.gelu(y) @ p["down"], new_cache


def init_slstm_cache(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    z = lambda: jnp.zeros((batch, d), jnp.float32)
    return {"c": z(), "n": z(), "m": jnp.full((batch, d), -jnp.inf, jnp.float32)}
