"""Trace-time activation-sharding context (shared by model.py and blocks.py).

GSPMD does not reliably propagate batch sharding through while-loop carries,
and it prefers activation all-reduces over weight gathers inside the MoE
einsums (measured: 10x more bytes on qwen3-moe).  Blocks re-anchor the
intents explicitly through this context; with no context installed every
helper is a no-op (single-device tests and eager use are unaffected).
"""
from __future__ import annotations

import contextlib

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

_MESH = None
_DP_AXES = ("pod", "data")


@contextlib.contextmanager
def activation_sharding(mesh, dp_axes=("pod", "data")):
    global _MESH, _DP_AXES
    prev = (_MESH, _DP_AXES)
    _MESH, _DP_AXES = mesh, tuple(dp_axes)
    try:
        yield
    finally:
        _MESH, _DP_AXES = prev


def mesh():
    return _MESH


def dp_axes():
    m = _MESH
    return tuple(a for a in _DP_AXES if a in m.axis_names) if m else ()


def dp_size() -> int:
    m = _MESH
    if m is None:
        return 1
    return int(np.prod([m.shape[a] for a in dp_axes()]))


def constrain(x, spec: P):
    """with_sharding_constraint if a mesh context is installed and every
    sharded dim divides; otherwise identity."""
    m = _MESH
    if m is None:
        return x
    fixed = []
    for dim, ax in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec))):
        if ax is None:
            fixed.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        axes = tuple(a for a in axes if a in m.axis_names)
        size = int(np.prod([m.shape[a] for a in axes])) if axes else 1
        fixed.append((axes if len(axes) > 1 else axes[0])
                     if axes and size > 1 and dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(m, P(*fixed)))


def constrain_btd(x):
    """(B, ...) batch over the dp axes (the residual-stream anchor)."""
    m = _MESH
    if m is None:
        return x
    axes = dp_axes()
    size = dp_size()
    b = x.shape[0]
    if size > 1 and b % size == 0:
        return constrain(x, P(axes))
    if "data" in m.axis_names and m.shape["data"] > 1 and \
            b % m.shape["data"] == 0:
        return constrain(x, P("data"))
    return x
