"""Sec. 6 cost model: pick the error threshold from a latency SLA or space budget.

Implements the paper's two models verbatim plus a TPU-roofline variant
(DESIGN.md Sec. 2): on TPU the router lives in VMEM (free of HBM traffic) and a
lookup pays one HBM->VMEM DMA of the +-error window, so the latency model is a
bandwidth term instead of a cache-miss count.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import numpy as np

from .segmentation import shrinking_cone


@dataclasses.dataclass(frozen=True)
class CostParams:
    c_ns: float = 50.0        # random-access / cache-miss penalty (paper Sec. 7.4: 50ns)
    fanout: int = 16          # b, router fanout
    fill: float = 0.5         # f, tree fill ratio (Sec. 6.2)
    buffer_size: int = 16     # buff


@dataclasses.dataclass(frozen=True)
class TPUCostParams:
    hbm_gbps: float = 819.0   # v5e HBM bandwidth
    dma_setup_ns: float = 600.0   # fixed DMA issue latency
    vmem_step_ns: float = 3.0     # per router level probe in VMEM
    bytes_per_key: int = 8


def latency_ns(error: int, n_segments: int, p: CostParams) -> float:
    """Paper Eq. (1), Sec. 6.1: c * [log_b(S_e) + log2(e) + log2(buff)]."""
    tree = math.log(max(n_segments, 2), p.fanout)
    seg = math.log2(max(error, 2))
    buf = math.log2(max(p.buffer_size, 2))
    return p.c_ns * (tree + seg + buf)


def size_bytes(error: int, n_segments: int, p: CostParams) -> float:
    """Paper Eq. (1), Sec. 6.2: f*S_e*log_b(S_e)*16B + S_e*24B (pessimistic).

    The tree height term is clamped to >= 1 (a one-node tree still stores its
    S_e entries), keeping the bound pessimistic for tiny segment counts."""
    s = max(n_segments, 2)
    return p.fill * s * max(1.0, math.log(s, p.fanout)) * 16.0 + s * 24.0


def latency_ns_tpu(error: int, n_segments: int, p: TPUCostParams,
                   router_levels: int | None = None) -> float:
    """TPU adaptation: router probes in VMEM + one window DMA from HBM."""
    levels = router_levels or max(1, math.ceil(math.log(max(n_segments, 2), 16)))
    window_bytes = (2 * error + 2) * p.bytes_per_key
    return p.dma_setup_ns + levels * p.vmem_step_ns + window_bytes / p.hbm_gbps


def learn_segments_fn(keys: np.ndarray, errors: Sequence[int],
                      sample: int | None = 200_000) -> Callable[[int], int]:
    """Sec. 6: 'learned for a specific dataset' -- segment at each candidate error
    (optionally on a contiguous sample, scaled back up) and interpolate log-log."""
    keys = np.asarray(keys, np.float64)
    scale = 1.0
    if sample is not None and keys.shape[0] > sample:
        scale = keys.shape[0] / sample
        keys = keys[: sample]
    es, ss = [], []
    for e in sorted(set(int(e) for e in errors)):
        segs = shrinking_cone(keys, e)
        es.append(e)
        ss.append(max(1, segs.n_segments) * scale)
    log_e, log_s = np.log(np.array(es, float)), np.log(np.array(ss, float))

    def fn(error: int) -> int:
        le = math.log(max(1, error))
        return int(round(math.exp(np.interp(le, log_e, log_s))))

    return fn


def choose_error_for_latency(l_req_ns: float, segments_fn: Callable[[int], int],
                             candidates: Sequence[int], p: CostParams) -> int | None:
    """Sec. 6.1 Eq. (2): smallest-size index meeting the latency requirement."""
    best, best_size = None, float("inf")
    for e in candidates:
        s = segments_fn(e)
        if latency_ns(e, s, p) <= l_req_ns:
            sz = size_bytes(e, s, p)
            if sz < best_size:
                best, best_size = e, sz
    return best


def choose_error_for_space(s_req_bytes: float, segments_fn: Callable[[int], int],
                           candidates: Sequence[int], p: CostParams) -> int | None:
    """Sec. 6.2 Eq. (2): fastest index within the storage budget."""
    best, best_lat = None, float("inf")
    for e in candidates:
        s = segments_fn(e)
        if size_bytes(e, s, p) <= s_req_bytes:
            lat = latency_ns(e, s, p)
            if lat < best_lat:
                best, best_lat = e, lat
    return best
