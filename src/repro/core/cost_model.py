"""Sec. 6 cost model: pick the error threshold from a latency SLA or space budget.

Implements the paper's two models verbatim plus a TPU-roofline variant
(DESIGN.md Sec. 2): on TPU the router lives in VMEM (free of HBM traffic) and a
lookup pays one HBM->VMEM DMA of the +-error window, so the latency model is a
bandwidth term instead of a cache-miss count.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import numpy as np

from .segmentation import shrinking_cone


@dataclasses.dataclass(frozen=True)
class CostParams:
    c_ns: float = 50.0        # random-access / cache-miss penalty (paper Sec. 7.4: 50ns)
    fanout: int = 16          # b, router fanout
    fill: float = 0.5         # f, tree fill ratio (Sec. 6.2)
    buffer_size: int = 16     # buff
    scan_ns_per_row: float = 0.5  # sequential page-scan marginal (range queries)


@dataclasses.dataclass(frozen=True)
class TPUCostParams:
    hbm_gbps: float = 819.0   # v5e HBM bandwidth
    dma_setup_ns: float = 600.0   # fixed DMA issue latency
    vmem_step_ns: float = 3.0     # per router level probe in VMEM
    bytes_per_key: int = 8
    launch_ns: float = 25_000.0   # host->device dispatch of one jitted call
    plan_ns: float = 75_000.0     # Pallas prelude: bucketing argsort + scatter


def latency_ns(error: int, n_segments: int, p: CostParams) -> float:
    """Paper Eq. (1), Sec. 6.1: c * [log_b(S_e) + log2(e) + log2(buff)]."""
    tree = math.log(max(n_segments, 2), p.fanout)
    seg = math.log2(max(error, 2))
    buf = math.log2(max(p.buffer_size, 2))
    return p.c_ns * (tree + seg + buf)


def size_bytes(error: int, n_segments: int, p: CostParams) -> float:
    """Paper Eq. (1), Sec. 6.2: f*S_e*log_b(S_e)*16B + S_e*24B (pessimistic).

    The tree height term is clamped to >= 1 (a one-node tree still stores its
    S_e entries), keeping the bound pessimistic for tiny segment counts."""
    s = max(n_segments, 2)
    return p.fill * s * max(1.0, math.log(s, p.fanout)) * 16.0 + s * 24.0


# VMEM router fanout on device (v5e: one 16-wide vector compare per level);
# shared by latency_ns_tpu and tier_cost_curves so the planner's candidate
# scoring and its dispatch-threshold crossings use the same router model.
TPU_ROUTER_FANOUT = 16


def latency_ns_tpu(error: int, n_segments: int, p: TPUCostParams,
                   router_levels: int | None = None) -> float:
    """TPU adaptation: router probes in VMEM + one window DMA from HBM."""
    levels = router_levels or max(1, math.ceil(
        math.log(max(n_segments, 2), TPU_ROUTER_FANOUT)))
    window_bytes = (2 * error + 2) * p.bytes_per_key
    return p.dma_setup_ns + levels * p.vmem_step_ns + window_bytes / p.hbm_gbps


# ----------------------------------------------------------- range-scan model
def scan_ns_per_row_tpu(p: TPUCostParams) -> float:
    """Sequential scan marginal on TPU: rows stream at HBM bandwidth."""
    return p.bytes_per_key / p.hbm_gbps


def range_latency_ns(error: int, n_segments: int, p: CostParams,
                     scan_rows: float) -> float:
    """Range-scan latency: the clustered layout answers a range with one
    predecessor search (the paper's Eq. 1 point cost locates the scan start)
    plus a sequential page scan -- fixed predecessor cost + per-row scan
    marginal."""
    return latency_ns(error, n_segments, p) + scan_rows * p.scan_ns_per_row


def range_latency_ns_tpu(error: int, n_segments: int, p: TPUCostParams,
                         scan_rows: float) -> float:
    """TPU form of :func:`range_latency_ns`: predecessor DMA + streamed rows."""
    return (latency_ns_tpu(error, n_segments, p)
            + scan_rows * scan_ns_per_row_tpu(p))


def learn_segments_fn(keys: np.ndarray, errors: Sequence[int],
                      sample: int | None = 200_000) -> Callable[[int], int]:
    """Sec. 6: 'learned for a specific dataset' -- segment at each candidate error
    (optionally on a contiguous sample, scaled back up) and interpolate log-log."""
    keys = np.asarray(keys, np.float64)
    scale = 1.0
    if sample is not None and keys.shape[0] > sample:
        scale = keys.shape[0] / sample
        keys = keys[: sample]
    es, ss = [], []
    for e in sorted(set(int(e) for e in errors)):
        segs = shrinking_cone(keys, e)
        es.append(e)
        ss.append(max(1, segs.n_segments) * scale)
    log_e, log_s = np.log(np.array(es, float)), np.log(np.array(ss, float))

    def fn(error: int) -> int:
        le = math.log(max(1, error))
        return int(round(math.exp(np.interp(le, log_e, log_s))))

    return fn


def choose_error_for_latency(l_req_ns: float, segments_fn: Callable[[int], int],
                             candidates: Sequence[int], p: CostParams,
                             latency_fn: Callable[[int, int], float] | None = None
                             ) -> int | None:
    """Sec. 6.1 Eq. (2): smallest-size index meeting the latency requirement.

    ``latency_fn(error, n_segments)`` substitutes a different latency model
    (e.g. the TPU roofline :func:`latency_ns_tpu`) while the size side stays
    the paper's Eq. 1 metadata accounting; ``None`` means the paper model."""
    lat = latency_fn or (lambda e, s: latency_ns(e, s, p))
    best, best_size = None, float("inf")
    for e in candidates:
        s = segments_fn(e)
        if lat(e, s) <= l_req_ns:
            sz = size_bytes(e, s, p)
            if sz < best_size:
                best, best_size = e, sz
    return best


def choose_error_for_space(s_req_bytes: float, segments_fn: Callable[[int], int],
                           candidates: Sequence[int], p: CostParams,
                           latency_fn: Callable[[int, int], float] | None = None
                           ) -> int | None:
    """Sec. 6.2 Eq. (2): fastest index within the storage budget.

    ``latency_fn`` as in :func:`choose_error_for_latency`."""
    lat = latency_fn or (lambda e, s: latency_ns(e, s, p))
    best, best_lat = None, float("inf")
    for e in candidates:
        s = segments_fn(e)
        if size_bytes(e, s, p) <= s_req_bytes:
            l = lat(e, s)
            if l < best_lat:
                best, best_lat = e, l
    return best


# ------------------------------------------------------- dispatch tier curves
def tier_cost_curves(error: int, n_segments: int,
                     cpu: CostParams | None = None,
                     tpu: TPUCostParams | None = None,
                     range_fraction: float = 0.0,
                     scan_rows: float = 0.0
                     ) -> dict[str, tuple[float, float]]:
    """Modeled batched-lookup cost per dispatch tier: ``{tier: (fixed_ns,
    per_query_ns)}`` so a batch of ``n`` queries costs ``fixed + n * per``.

    The three tiers of ``repro.index.engine.DispatchEngine`` trade fixed cost
    against marginal cost, and both sides come from the Sec. 6 models:

    * ``small`` (host numpy): no dispatch cost; each query pays the paper's
      Eq. 1 host latency (:func:`latency_ns`) minus its buffer-scan term --
      the dispatch tiers serve a *published snapshot*, whose lookups never
      touch write-side insert buffers.
    * ``medium`` (xla-bisect): one device launch plus the DMA issue latency
      up front; each query then pays ``log2(2e+2)`` single-element probes at
      VMEM speed (the bisect touches one key per halving step).
    * ``large`` (pallas): the launch plus the plan/bucketing prelude up
      front; each query's +-error window is then streamed through the
      compare-reduce kernel at HBM bandwidth.

    ``range_fraction``/``scan_rows`` fold a scan-heavy workload into the
    marginal costs: that fraction of queries additionally scans ``scan_rows``
    rows, at the host's sequential-scan rate on the ``small`` tier and at HBM
    bandwidth on the device tiers -- scans amortize the device launch faster
    than point probes, so the crossings shift left as ``range_fraction``
    grows."""
    cpu = cpu or CostParams()
    tpu = tpu or TPUCostParams()
    steps = math.ceil(math.log2(2 * max(error, 1) + 2))
    window_bytes = (2 * error + 2) * tpu.bytes_per_key
    levels = max(1, math.ceil(
        math.log(max(n_segments, 2), TPU_ROUTER_FANOUT)))
    host_ns = (latency_ns(error, n_segments, cpu)
               - cpu.c_ns * math.log2(max(cpu.buffer_size, 2)))
    host_scan = range_fraction * scan_rows * cpu.scan_ns_per_row
    dev_scan = range_fraction * scan_rows * scan_ns_per_row_tpu(tpu)
    return {
        "small": (0.0, host_ns + host_scan),
        "medium": (tpu.launch_ns + tpu.dma_setup_ns,
                   steps * tpu.vmem_step_ns + levels * tpu.vmem_step_ns
                   + dev_scan),
        "large": (tpu.launch_ns + tpu.dma_setup_ns + tpu.plan_ns,
                  window_bytes / tpu.hbm_gbps + tpu.vmem_step_ns + dev_scan),
    }


def curve_crossings(curves: dict[str, tuple[float, float]]) -> tuple[int, int]:
    """``(small_max, large_min)`` where the per-tier affine cost curves cross.

    ``curves`` maps the three ``DispatchEngine`` tiers to ``(fixed_ns,
    per_query_ns)`` pairs -- modeled (:func:`tier_cost_curves`), measured
    (:func:`fit_tier_curves`), or a mixture.  ``small_max`` is the largest
    batch the host tier still wins (the medium tier's fixed launch cost
    amortizes beyond it); ``large_min`` the smallest batch where the large
    tier's extra plan cost pays for its lower marginal cost.  Degenerate
    slopes (a tier whose marginal cost is not strictly better than its
    predecessor's) push the crossing to the extreme, so the invariant
    ``0 <= small_max < large_min`` always holds."""
    (f_s, p_s), (f_m, p_m), (f_l, p_l) = (
        curves["small"], curves["medium"], curves["large"])
    if p_s > p_m:
        small_max = max(1, int((f_m - f_s) / (p_s - p_m)))
    else:                  # host never loses per-query: keep batches on host
        small_max = 1 << 30
    if p_m > p_l:
        large_min = max(small_max + 1, int(math.ceil((f_l - f_m) / (p_m - p_l))))
    else:                  # pallas never wins per-query: effectively disabled
        large_min = max(small_max + 1, 1 << 31)
    return small_max, large_min


def dispatch_thresholds(error: int, n_segments: int,
                        cpu: CostParams | None = None,
                        tpu: TPUCostParams | None = None,
                        range_fraction: float = 0.0,
                        scan_rows: float = 0.0) -> tuple[int, int]:
    """Cost-model-calibrated ``(small_max, large_min)`` for ``DispatchEngine``:
    the batch sizes where the modeled per-tier latency curves cross (see
    :func:`curve_crossings`).  ``range_fraction``/``scan_rows`` make the
    crossings scan-aware (see :func:`tier_cost_curves`)."""
    return curve_crossings(tier_cost_curves(error, n_segments, cpu, tpu,
                                            range_fraction, scan_rows))


# ------------------------------------------- device-plane exchange strategies
def exchange_cost_ns(strategy: str, batch: int, n_devices: int, error: int,
                     n_segments: int, p: TPUCostParams | None = None,
                     *, slack: float = 2.0) -> float:
    """Modeled wall cost of one device-sharded ``search`` collective round.

    Two exchange strategies move a batch of queries across a ``D``-device
    mesh (``repro.index.device``):

    * ``"allgather"``: one gather of the full batch; every device then
      answers all ``batch`` queries against its local shard and a ``psum``
      combines the per-shard ranks.  Cheap to launch, but per-device work
      is the *whole* batch -- it never shrinks as devices are added.
    * ``"a2a"``: queries are bucketed to their owning shard (a host-style
      argsort prelude, ``plan_ns``), exchanged with ``all_to_all``,
      answered locally, and exchanged back -- three collective hops, but
      per-device work is only ``slack * batch / D`` queries.

    Per-query search work on a shard is the TPU roofline's window cost over
    the shard's (smaller) segment slice; the DMA-issue constant stays a
    fixed per-hop cost rather than a per-query one."""
    p = p or TPUCostParams()
    d = max(1, n_devices)
    s_local = max(1, math.ceil(max(1, n_segments) / d))
    per_q = latency_ns_tpu(error, s_local, p) - p.dma_setup_ns
    wire = p.bytes_per_key / p.hbm_gbps
    if strategy == "allgather":
        return (p.launch_ns + p.dma_setup_ns + batch * wire + batch * per_q)
    if strategy == "a2a":
        routed = slack * batch / d
        return (p.launch_ns + p.plan_ns
                + 2 * (p.dma_setup_ns + routed * wire) + routed * per_q)
    raise ValueError(f"unknown exchange strategy {strategy!r}")


def choose_exchange(batch: int, n_devices: int, error: int, n_segments: int,
                    p: TPUCostParams | None = None,
                    *, slack: float = 2.0) -> str:
    """Pick the cheaper exchange strategy for a representative batch size.

    Small batches amortize nothing: the a2a path's bucketing prelude and
    extra hops dominate, so ``allgather`` wins.  Past the crossover the
    ``slack/D < 1`` per-device work reduction pays for the hops and ``a2a``
    wins.  On a single device there is nothing to exchange -- allgather
    degenerates to a local search and always wins."""
    if n_devices <= 1:
        return "allgather"
    a = exchange_cost_ns("allgather", batch, n_devices, error, n_segments, p,
                         slack=slack)
    b = exchange_cost_ns("a2a", batch, n_devices, error, n_segments, p,
                         slack=slack)
    return "a2a" if b < a else "allgather"


def exchange_crossover_batch(n_devices: int, error: int, n_segments: int,
                             p: TPUCostParams | None = None,
                             *, slack: float = 2.0,
                             max_batch: int = 1 << 22) -> int | None:
    """Smallest power-of-two batch where ``a2a`` beats ``allgather`` (for
    ``plan().explain()`` audits), or ``None`` if it never does below
    ``max_batch``."""
    if n_devices <= 1:
        return None
    b = 1
    while b <= max_batch:
        if choose_exchange(b, n_devices, error, n_segments, p,
                           slack=slack) == "a2a":
            return b
        b *= 2
    return None


# ----------------------------------------------- measured-curve re-calibration
def fit_tier_curves(samples: dict[str, np.ndarray | Sequence],
                    min_samples: int = 8
                    ) -> dict[str, tuple[float, float]]:
    """Least-squares re-fit of the per-tier affine cost curves from measured
    ``(batch_size, wall_ns)`` samples (e.g. a telemetry ``Monitor``'s
    ``tier.*`` channels): ``{tier: (fixed_ns, per_query_ns)}``.

    To keep one-off spikes (first-call compiles, scheduler hiccups) from
    skewing the fixed/marginal split, the line is fit through the *median*
    latency per distinct batch size, weighted by how often that size was
    seen.  Tiers with fewer than ``min_samples`` rows or fewer than two
    distinct batch sizes are omitted -- callers fall back to the modeled
    curve (:func:`tier_cost_curves`) for those.  Coefficients are clamped
    non-negative (a latency curve cannot slope down)."""
    out: dict[str, tuple[float, float]] = {}
    for tier, rows in samples.items():
        a = np.asarray(rows, np.float64).reshape(-1, 2)
        if a.shape[0] < min_samples:
            continue
        sizes = np.unique(a[:, 0])
        if sizes.size < 2:
            continue
        med = np.array([np.median(a[a[:, 0] == s, 1]) for s in sizes])
        wts = np.array([float((a[:, 0] == s).sum()) for s in sizes])
        per, fixed = np.polyfit(sizes, med, 1, w=np.sqrt(wts))
        out[tier] = (max(float(fixed), 0.0), max(float(per), 0.0))
    return out


def refit_params(curves: dict[str, tuple[float, float]],
                 error: int, n_segments: int,
                 cpu: CostParams | None = None,
                 tpu: TPUCostParams | None = None
                 ) -> tuple[CostParams, TPUCostParams]:
    """Invert measured tier curves back into ``(CostParams, TPUCostParams)``.

    The inverse of :func:`tier_cost_curves` at the serving configuration
    ``(error, n_segments)``: each measured coefficient pins the model
    parameter that produces it, so re-running the Sec. 6 planner with the
    returned params reproduces the measured curves (modulo non-negativity
    clamps).  Tiers absent from ``curves`` leave their parameters at the
    prior's value; ``cpu``/``tpu`` default to the hand-tuned constants."""
    cpu = cpu or CostParams()
    tpu = tpu or TPUCostParams()
    steps = math.ceil(math.log2(2 * max(error, 1) + 2))
    window_bytes = (2 * error + 2) * tpu.bytes_per_key
    levels = max(1, math.ceil(
        math.log(max(n_segments, 2), TPU_ROUTER_FANOUT)))
    if "small" in curves:
        # host marginal = c_ns * (log_b(S_e) + log2(e)): snapshot lookups pay
        # no buffer-scan term (see tier_cost_curves)
        denom = (math.log(max(n_segments, 2), cpu.fanout)
                 + math.log2(max(error, 2)))
        cpu = dataclasses.replace(
            cpu, c_ns=max(curves["small"][1] / max(denom, 1e-9), 1e-3))
    if "medium" in curves:
        fixed, per = curves["medium"]
        tpu = dataclasses.replace(
            tpu,
            launch_ns=max(fixed - tpu.dma_setup_ns, 0.0),
            vmem_step_ns=max(per / (steps + levels), 1e-6))
    if "large" in curves:
        fixed, per = curves["large"]
        tpu = dataclasses.replace(
            tpu,
            plan_ns=max(fixed - tpu.launch_ns - tpu.dma_setup_ns, 0.0),
            hbm_gbps=window_bytes / max(per - tpu.vmem_step_ns, 1e-6))
    return cpu, tpu


def calibrate(keys: np.ndarray, engine=None, *,
              errors: Sequence[int] = (16, 256), batch: int = 1024,
              repeats: int = 3, safety: float = 1.3) -> CostParams:
    """One-shot micro-calibration of ``CostParams.c_ns`` against this host.

    Seeds the Sec. 6 latency model from a measurement instead of the paper's
    hand-tuned 50ns constant: builds a published-snapshot table at each
    anchor ``error``, times a ``batch``-sized host lookup (best of
    ``repeats``), and solves Eq. 1 for the ``c_ns`` that reproduces it --
    ``measured_per_query = c_ns * (log_b(S_e) + log2(e))`` (no buffer term:
    snapshots carry no insert buffer).  The worst anchor times ``safety``
    keeps the model an upper bound across the error sweep, which is what
    planner SLA admission (``choose_error_for_latency``) needs.

    ``engine`` substitutes a lookup callable ``engine(queries)`` timed in
    place of the host ``numpy_lookup``; by default the host tier is measured,
    matching the paper's cache-miss model."""
    from repro.index.table import SegmentTable, numpy_lookup  # lazy: no cycle
    import time
    keys = np.asarray(keys, np.float64)
    if not np.all(np.diff(keys) >= 0):
        keys = np.sort(keys, kind="stable")
    q = np.resize(keys, max(int(batch), 1))
    worst = 0.0
    for e in sorted(set(int(e) for e in errors)):
        table = SegmentTable.from_keys(keys, e, assume_sorted=True)
        fn = engine if engine is not None else (
            lambda qq, t=table: numpy_lookup(t, qq))
        fn(q)  # warm caches / compiles before timing
        best = float("inf")
        for _ in range(max(int(repeats), 1)):
            t0 = time.perf_counter_ns()
            fn(q)
            best = min(best, time.perf_counter_ns() - t0)
        per_query = best / q.size
        denom = (math.log(max(table.n_segments, 2), CostParams.fanout)
                 + math.log2(max(e, 2)))
        worst = max(worst, per_query / max(denom, 1e-9))
    return dataclasses.replace(CostParams(), c_ns=max(worst * safety, 1e-3))
