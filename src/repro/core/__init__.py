"""FITing-Tree core: the paper's contribution (segmentation, index, cost model)."""
from .segmentation import (Segments, max_segments_bound, optimal_segmentation,
                           shrinking_cone, shrinking_cone_py, verify_segments)
from .tree import FITingTree, PackedRouter
from .cost_model import (CostParams, TPUCostParams, choose_error_for_latency,
                         choose_error_for_space, latency_ns, latency_ns_tpu,
                         learn_segments_fn, size_bytes)
from .jax_index import (DeviceIndex, build_device_index, lookup,
                        predict_positions, range_count, rescale_keys)
from . import datasets

__all__ = [
    "Segments", "shrinking_cone", "shrinking_cone_py", "optimal_segmentation",
    "verify_segments", "max_segments_bound", "FITingTree", "PackedRouter",
    "CostParams", "TPUCostParams", "latency_ns", "latency_ns_tpu", "size_bytes",
    "learn_segments_fn", "choose_error_for_latency", "choose_error_for_space",
    "DeviceIndex", "build_device_index", "lookup", "predict_positions",
    "range_count", "rescale_keys", "datasets",
]
