"""FITing-Tree core: the paper's contribution (segmentation, index, cost model).

The host-side modules (segmentation, tree, cost model, datasets) are pure
numpy and imported eagerly; the device-side names from ``jax_index`` resolve
lazily (PEP 562) so host-only code never pulls in jax.
"""
from .segmentation import (Segments, max_segments_bound, optimal_segmentation,
                           shrinking_cone, shrinking_cone_py, verify_segments)
from .tree import FITingTree, PackedRouter
from .cost_model import (CostParams, TPUCostParams, choose_error_for_latency,
                         choose_error_for_space, dispatch_thresholds,
                         latency_ns, latency_ns_tpu, learn_segments_fn,
                         range_latency_ns, range_latency_ns_tpu,
                         scan_ns_per_row_tpu, size_bytes, tier_cost_curves)
from . import datasets

_JAX_INDEX_NAMES = {"DeviceIndex", "build_device_index", "lookup",
                    "predict_positions", "range_count", "rescale_keys"}

__all__ = [
    "Segments", "shrinking_cone", "shrinking_cone_py", "optimal_segmentation",
    "verify_segments", "max_segments_bound", "FITingTree", "PackedRouter",
    "CostParams", "TPUCostParams", "latency_ns", "latency_ns_tpu", "size_bytes",
    "learn_segments_fn", "choose_error_for_latency", "choose_error_for_space",
    "dispatch_thresholds", "tier_cost_curves", "range_latency_ns",
    "range_latency_ns_tpu", "scan_ns_per_row_tpu",
    "datasets", *sorted(_JAX_INDEX_NAMES),
]


def __getattr__(name):
    if name in _JAX_INDEX_NAMES:
        from . import jax_index
        return getattr(jax_index, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
