"""Synthetic datasets shaped like the paper's evaluation data (Sec. 7.1.1).

The paper's Weblogs / IoT / Maps datasets are not redistributable, so we generate
synthetic keys with the same *distributional shape*:

  * ``iot_like``      -- event timestamps with strong diurnal + weekend periodicity
                         (inhomogeneous Poisson; Fig. 1 / Fig. 8 "IoT" shape).
  * ``weblogs_like``  -- request timestamps with multi-scale periodicity
                         (daily x weekly x seasonal rate modulation).
  * ``maps_like``     -- longitudes: near-linear with density bumps (cities).
  * ``step_data``     -- the adversarial fixed-step function of Sec. 7.2 / Fig. 9a.
  * ``lognormal_keys``/ ``uniform_keys`` / ``zipf_gaps`` -- classic learned-index
                         microbenchmark distributions.

All return a sorted float64 array of keys (duplicates possible where noted).
``non_linearity_ratio`` implements the Fig. 8 metric.
"""
from __future__ import annotations

import numpy as np

from .segmentation import shrinking_cone

DAY = 86400.0


def _inhomogeneous_poisson(n: int, rate_fn, t_end: float, rng: np.random.Generator,
                           rate_max: float) -> np.ndarray:
    """Thinning sampler; returns ~n sorted event times in [0, t_end]."""
    # Draw ~25% extra candidates, thin, then trim/top-up deterministically.
    m = int(n * 1.3) + 64
    out = np.empty(0, np.float64)
    while out.shape[0] < n:
        cand = np.sort(rng.uniform(0.0, t_end, size=m))
        keep = rng.uniform(0.0, rate_max, size=m) < rate_fn(cand)
        out = np.concatenate([out, cand[keep]])
        m = max(1024, int((n - out.shape[0]) * 2.5))
    out = np.sort(out)
    idx = np.linspace(0, out.shape[0] - 1, n).astype(np.int64)
    return out[idx]


def iot_like(n: int = 1_000_000, days: float = 120.0, seed: int = 0) -> np.ndarray:
    """Diurnal + weekend periodicity: busy 9am-6pm weekdays, quiet nights/weekends."""
    rng = np.random.default_rng(seed)
    t_end = days * DAY

    def rate(t):
        hour = (t % DAY) / 3600.0
        dow = (t // DAY) % 7
        day_part = np.exp(-0.5 * ((hour - 13.5) / 3.2) ** 2)  # daytime bump
        weekday = np.where(dow < 5, 1.0, 0.15)
        return 0.05 + 2.0 * day_part * weekday

    return _inhomogeneous_poisson(n, rate, t_end, rng, rate_max=2.05)


def weblogs_like(n: int = 1_000_000, days: float = 365.0, seed: int = 1) -> np.ndarray:
    """Multi-scale periodicity: diurnal x weekly x school-year seasonality."""
    rng = np.random.default_rng(seed)
    t_end = days * DAY

    def rate(t):
        hour = (t % DAY) / 3600.0
        dow = (t // DAY) % 7
        doy = (t / DAY) % 365.0
        diurnal = 0.25 + np.exp(-0.5 * ((hour - 15.0) / 4.0) ** 2)
        weekly = np.where(dow < 5, 1.0, 0.45)
        season = 0.5 + 0.5 * (np.cos(2 * np.pi * (doy - 45) / 365.0) ** 2)
        return 0.02 + diurnal * weekly * season

    return _inhomogeneous_poisson(n, rate, t_end, rng, rate_max=1.8)


def maps_like(n: int = 1_000_000, seed: int = 2) -> np.ndarray:
    """Longitude-like: mostly uniform with gaussian 'city' clusters; near-linear CDF."""
    rng = np.random.default_rng(seed)
    n_uniform = int(n * 0.72)
    base = rng.uniform(-180.0, 180.0, size=n_uniform)
    n_city = n - n_uniform
    centers = rng.uniform(-170.0, 170.0, size=40)
    weights = rng.dirichlet(np.ones(40))
    assign = rng.choice(40, size=n_city, p=weights)
    cities = centers[assign] + rng.normal(0.0, 0.8, size=n_city)
    keys = np.clip(np.concatenate([base, cities]), -180.0, 180.0)
    return np.sort(keys)


def step_data(n: int = 1_000_000, step: int = 100, jump: float = 1e4,
              within: float = 1.0, seed: int = 3) -> np.ndarray:
    """Sec. 7.2 worst case: groups of ``step`` positions whose keys sit in a tight
    cluster, followed by a large key jump (Fig. 9a). error < step => one segment
    per step; error >= step => a single segment suffices."""
    rng = np.random.default_rng(seed)
    n_steps = (n + step - 1) // step
    bases = np.arange(n_steps, dtype=np.float64) * jump
    offs = np.sort(rng.uniform(0.0, within, size=(n_steps, step)), axis=1)
    keys = (bases[:, None] + offs).reshape(-1)[:n]
    return keys


def lognormal_keys(n: int = 1_000_000, sigma: float = 2.0, seed: int = 4) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.sort(rng.lognormal(mean=0.0, sigma=sigma, size=n) * 1e6)


def uniform_keys(n: int = 1_000_000, lo: float = 0.0, hi: float = 1e9,
                 seed: int = 5) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.sort(rng.uniform(lo, hi, size=n))


def zipf_gaps(n: int = 1_000_000, a: float = 1.4, seed: int = 6) -> np.ndarray:
    """Keys whose successive gaps are Zipf-distributed (heavy-tailed bursts)."""
    rng = np.random.default_rng(seed)
    gaps = rng.zipf(a, size=n).astype(np.float64)
    return np.cumsum(gaps)


DATASETS = {
    "iot": iot_like,
    "weblogs": weblogs_like,
    "maps": maps_like,
    "lognormal": lognormal_keys,
    "uniform": uniform_keys,
    "zipf": zipf_gaps,
}


def non_linearity_ratio(keys: np.ndarray, error: int) -> float:
    """Fig. 8 metric: S_e normalized by the worst case #segments at that error.

    Worst case = a dataset of the same size with periodicity equal to the error,
    i.e. ceil(n / (error+1)) segments (Theorem 3.1 lower bound on segment size).
    """
    segs = shrinking_cone(keys, error)
    n = keys.shape[0]
    worst = np.ceil(n / (error + 1.0))
    return segs.n_segments / worst
