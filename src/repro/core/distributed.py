"""Range-partitioned FITing-Tree across a device mesh (DESIGN.md Sec. 5).

The key space is split into equal-count contiguous shards; each device owns one
shard's sorted keys plus its own segment table.  A tiny replicated *router* --
the first key of every shard -- is itself the top level of the paper's
structure recursed once.  Batched queries are exchanged with collectives inside
``shard_map``:

  * ``lookup_allgather`` -- every shard sees every query (robust to any skew;
    costs D*Q query bytes on the interconnect, fine for small Q);
  * ``lookup_a2a``       -- queries are bucketed by owner shard and exchanged
    with all_to_all using a slack factor (the production path; overflow beyond
    slack is answered by a follow-up allgather pass in the caller if needed --
    returned mask marks dropped queries).

Both return global ranks (-1 if absent).  Tests run under
XLA_FLAGS=--xla_force_host_platform_device_count=8 in a subprocess.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map as _shard_map
from repro.index.sharded import pack_shard_tables
from repro.index.table import build_shard_tables

from .jax_index import DeviceIndex, lookup


class ShardedIndex(NamedTuple):
    seg_start: jax.Array   # (D, S_max) f32, padded with +inf
    slope: jax.Array       # (D, S_max) f32
    base: jax.Array        # (D, S_max) i32
    seg_end: jax.Array     # (D, S_max) i32
    keys: jax.Array        # (D, M) f32 -- equal-count shards
    boundaries: jax.Array  # (D,) f32 replicated router: first key per shard
    error: int


def build_sharded_index(keys: np.ndarray, error: int, n_shards: int,
                        mesh: Mesh | None = None, axis: str = "data") -> ShardedIndex:
    keys = np.asarray(keys, np.float64)
    n = keys.shape[0]
    m = n // n_shards
    # equal shards; tail handled by caller.  One canonical SegmentTable per
    # shard (local ranks) -- the same construction every other layer uses --
    # padded into the rectangular device layout by the shared bridge.
    tables = build_shard_tables(keys, error, n_shards)
    shards = keys[: m * n_shards].reshape(n_shards, m)
    packed = pack_shard_tables(tables)

    arrays = dict(
        seg_start=jnp.asarray(packed.seg_start, jnp.float32),
        slope=jnp.asarray(packed.slope, jnp.float32),
        base=jnp.asarray(packed.base, jnp.int32),
        seg_end=jnp.asarray(packed.seg_end, jnp.int32),
        keys=jnp.asarray(shards, jnp.float32),
        boundaries=jnp.asarray(packed.boundaries, jnp.float32),
    )
    if mesh is not None:
        shard = NamedSharding(mesh, P(axis, None))
        repl = NamedSharding(mesh, P())
        arrays = {k: jax.device_put(v, repl if k == "boundaries" else shard)
                  for k, v in arrays.items()}
    return ShardedIndex(error=int(error), **arrays)


def _local_index(si: ShardedIndex) -> DeviceIndex:
    """Inside shard_map every (D, ...) block is (1, ...): squeeze to a local index."""
    return DeviceIndex(
        seg_start=si.seg_start[0], slope=si.slope[0], base=si.base[0],
        seg_end=si.seg_end[0], keys=si.keys[0], error=si.error)


def lookup_allgather(si: ShardedIndex, queries: jax.Array, mesh: Mesh,
                     axis: str = "data") -> jax.Array:
    """Every shard answers the full query set; one psum combines the answers."""
    d = mesh.shape[axis]
    m = si.keys.shape[1]

    @partial(_shard_map, mesh=mesh,
             in_specs=(P(axis, None), P(axis, None), P(axis, None), P(axis, None),
                       P(axis, None), P(), P(axis)),
             out_specs=P(axis))
    def impl(seg_start, slope, base, seg_end, keys, boundaries, q_local):
        me = jax.lax.axis_index(axis)
        q_all = jax.lax.all_gather(q_local, axis, tiled=True)       # (Q_total,)
        local = DeviceIndex(seg_start[0], slope[0], base[0], seg_end[0],
                            keys[0], si.error)
        lo_b = boundaries[me]
        hi_b = jnp.where(me == d - 1, jnp.inf, boundaries[jnp.minimum(me + 1, d - 1)])
        mine = (q_all >= lo_b) & (q_all < hi_b)
        mine = mine | ((me == 0) & (q_all < boundaries[0]))
        local_rank = lookup(local, q_all)                           # -1 if absent
        global_rank = jnp.where(local_rank >= 0, local_rank + me * m, -1)
        contrib = jnp.where(mine, global_rank, 0)
        owned = jnp.where(mine, 1, 0)
        total = jax.lax.psum(contrib, axis)
        owners = jax.lax.psum(owned, axis)
        result = jnp.where(owners > 0, total, -1)
        # slice this device's chunk back out
        q_per = q_local.shape[0]
        return jax.lax.dynamic_slice_in_dim(result, me * q_per, q_per)

    return impl(si.seg_start, si.slope, si.base, si.seg_end, si.keys,
                si.boundaries, queries)


def lookup_a2a(si: ShardedIndex, queries: jax.Array, mesh: Mesh,
               axis: str = "data", slack: float = 2.0
               ) -> tuple[jax.Array, jax.Array]:
    """Bucketed all_to_all exchange (production path).

    Each device buckets its local queries by owner shard into D buckets of
    capacity ceil(Q/D * slack) (padded with +inf sentinels), exchanges buckets
    with all_to_all, answers the queries it owns, and reverses the exchange.
    Returns (ranks, ok) where ok=False marks queries dropped by bucket
    overflow (caller may re-ask via lookup_allgather).
    """
    d = mesh.shape[axis]
    m = si.keys.shape[1]
    q_per = queries.shape[0] // d
    cap = int(np.ceil(q_per / d * slack))

    @partial(_shard_map, mesh=mesh,
             in_specs=(P(axis, None), P(axis, None), P(axis, None), P(axis, None),
                       P(axis, None), P(), P(axis)),
             out_specs=(P(axis), P(axis)))
    def impl(seg_start, slope, base, seg_end, keys, boundaries, q_local):
        me = jax.lax.axis_index(axis)
        local = DeviceIndex(seg_start[0], slope[0], base[0], seg_end[0],
                            keys[0], si.error)
        owner = jnp.clip(jnp.searchsorted(boundaries, q_local, side="right") - 1,
                         0, d - 1)                                   # (q,)
        # slot each query into its bucket (capacity cap) via a stable sort
        order = jnp.argsort(owner, stable=True)
        sorted_owner = owner[order]
        rank_in_bkt = jnp.arange(q_local.shape[0]) - jnp.searchsorted(
            sorted_owner, sorted_owner, side="left")
        ok_sorted = rank_in_bkt < cap
        buckets = jnp.full((d, cap), jnp.inf, q_local.dtype)
        src_pos = jnp.full((d, cap), -1, jnp.int32)
        slot = jnp.clip(rank_in_bkt, 0, cap - 1)
        buckets = buckets.at[sorted_owner, slot].set(
            jnp.where(ok_sorted, q_local[order], jnp.inf))
        src_pos = src_pos.at[sorted_owner, slot].set(
            jnp.where(ok_sorted, order.astype(jnp.int32), -1))
        # exchange: after a2a, row j of `incoming` is what device j sent to me
        incoming = jax.lax.all_to_all(buckets, axis, split_axis=0,
                                      concat_axis=0, tiled=True)     # (d, cap)
        flat = incoming.reshape(-1)
        ans = lookup(local, flat)
        ans = jnp.where(jnp.isinf(flat), -1, ans)
        ans = jnp.where(ans >= 0, ans + me * m, -1).reshape(d, cap)
        # reverse exchange
        back = jax.lax.all_to_all(ans, axis, split_axis=0,
                                  concat_axis=0, tiled=True).reshape(d, cap)
        result = jnp.full(q_local.shape, -1, jnp.int32)
        okq = jnp.zeros(q_local.shape, bool)
        # scatter answers back to original slots
        flat_src = src_pos.reshape(-1)
        flat_back = back.reshape(-1)
        good = flat_src >= 0
        result = result.at[jnp.clip(flat_src, 0, None)].max(
            jnp.where(good, flat_back, -1))
        okq = okq.at[jnp.clip(flat_src, 0, None)].max(good)
        return result, okq

    return impl(si.seg_start, si.slope, si.base, si.seg_end, si.keys,
                si.boundaries, queries)
