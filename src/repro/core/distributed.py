"""Range-partitioned FITing-Tree across a device mesh: compatibility wrapper.

The canonical implementation now lives in ``repro.index.device``: the
``shard_map`` collective kernels exist once
(``sharded_lookup_allgather`` / ``sharded_lookup_a2a``, plus the two-sided
``sharded_search_*`` rank primitives they derive from), and the *served*
plane -- delta epoch publish, the versioned ``DeviceShardSet`` manifest,
a2a overflow resolution, telemetry -- is ``DeviceShardedService``.  This
module keeps the seed-era public surface (``ShardedIndex``,
``build_sharded_index``, ``lookup_allgather``, ``lookup_a2a``) as thin
wrappers over those kernels, the same treatment as ``core/jax_index.py``.

Semantics are unchanged for the seed layout (equal-count shards, unique
keys): global rank of each query, -1 if absent.  ``lookup_a2a`` still
returns the legacy ``(ranks, ok)`` pair where ``ok=False`` marks queries
dropped by bucket overflow under skew -- callers re-ask via
``lookup_allgather``, or use ``DeviceShardedService``, which performs that
follow-up pass itself.  The psum-based kernels are additionally exact when
duplicate runs straddle shard cuts (the old ownership-mask implementation
was not).  Tests run under
XLA_FLAGS=--xla_force_host_platform_device_count=8 in a subprocess.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.index.device import sharded_lookup_a2a, sharded_lookup_allgather
from repro.index.sharded import pack_shard_tables
from repro.index.table import build_shard_tables


class ShardedIndex(NamedTuple):
    seg_start: jax.Array   # (D, S_max) f32, padded with +inf
    slope: jax.Array       # (D, S_max) f32
    base: jax.Array        # (D, S_max) i32
    seg_end: jax.Array     # (D, S_max) i32
    keys: jax.Array        # (D, M) f32 -- equal-count shards
    boundaries: jax.Array  # (D,) f32 replicated router: first key per shard
    error: int


def build_sharded_index(keys: np.ndarray, error: int, n_shards: int,
                        mesh: Mesh | None = None, axis: str = "data") -> ShardedIndex:
    keys = np.asarray(keys, np.float64)
    n = keys.shape[0]
    m = n // n_shards
    # equal shards; tail handled by caller.  One canonical SegmentTable per
    # shard (local ranks) -- the same construction every other layer uses --
    # padded into the rectangular device layout by the shared bridge.
    tables = build_shard_tables(keys, error, n_shards)
    shards = keys[: m * n_shards].reshape(n_shards, m)
    packed = pack_shard_tables(tables)

    arrays = dict(
        seg_start=jnp.asarray(packed.seg_start, jnp.float32),
        slope=jnp.asarray(packed.slope, jnp.float32),
        base=jnp.asarray(packed.base, jnp.int32),
        seg_end=jnp.asarray(packed.seg_end, jnp.int32),
        keys=jnp.asarray(shards, jnp.float32),
        boundaries=jnp.asarray(packed.boundaries, jnp.float32),
    )
    if mesh is not None:
        shard = NamedSharding(mesh, P(axis, None))
        repl = NamedSharding(mesh, P())
        arrays = {k: jax.device_put(v, repl if k == "boundaries" else shard)
                  for k, v in arrays.items()}
    return ShardedIndex(error=int(error), **arrays)


def _seed_layout(si: ShardedIndex, d: int):
    """The seed layout's implied row metadata: equal-count shards (every row
    fully live) and the prefix offsets ``arange(d) * m``."""
    m = si.keys.shape[1]
    n_local = jnp.full((d,), m, jnp.int32)
    offsets = jnp.arange(d, dtype=jnp.int32) * m
    return n_local, offsets


def lookup_allgather(si: ShardedIndex, queries: jax.Array, mesh: Mesh,
                     axis: str = "data") -> jax.Array:
    """Every shard answers the full query set; one psum combines the answers.

    Deprecated entry point: delegates to
    :func:`repro.index.device.sharded_lookup_allgather` (use
    ``DeviceShardedService`` for the served plane)."""
    n_local, _ = _seed_layout(si, mesh.shape[axis])
    return sharded_lookup_allgather(
        si.seg_start, si.slope, si.base, si.seg_end, si.keys, n_local,
        queries, mesh=mesh, axis=axis, error=si.error)


def lookup_a2a(si: ShardedIndex, queries: jax.Array, mesh: Mesh,
               axis: str = "data", slack: float = 2.0
               ) -> tuple[jax.Array, jax.Array]:
    """Bucketed all_to_all exchange; returns the legacy ``(ranks, ok)`` pair.

    Deprecated entry point: delegates to
    :func:`repro.index.device.sharded_lookup_a2a`.  ``ok=False`` marks
    queries dropped by bucket overflow under skew beyond ``slack`` -- the
    caller may re-ask those via :func:`lookup_allgather`;
    ``DeviceShardedService`` performs that follow-up pass itself, so the
    mask never reaches *its* callers."""
    n_local, offsets = _seed_layout(si, mesh.shape[axis])
    return sharded_lookup_a2a(
        si.seg_start, si.slope, si.base, si.seg_end, si.keys, n_local,
        offsets, si.boundaries, queries, mesh=mesh, axis=axis,
        error=si.error, slack=slack)
