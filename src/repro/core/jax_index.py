"""Device-side (JAX) FITing-Tree: immutable arrays + batched lookups.

This is the TPU-native form of the index (DESIGN.md Sec. 2): the segment table
is a handful of dense arrays small enough for VMEM; the sorted key column stays
in HBM; a batched lookup is

    sid   = searchsorted(seg_start, q) - 1            # router (VMEM)
    pred  = base[sid] + (q - seg_start[sid]) * slope  # VPU FMA
    rank  = bounded search in keys[pred-e : pred+e]   # one HBM window per query

Two bounded-search strategies are provided (both O(error) bounded):
  * ``window``  -- gather the 2e+2 window and compare-reduce (vector friendly;
                   what the Pallas kernel does in VMEM);
  * ``bisect``  -- log2(2e) halving steps of single gathers (fewer bytes for
                   large e; what a CPU would do).

float32 keys: interpolation subtracts the segment start *before* rounding, so
provided per-segment key spans stay < 2^24 the f32 math is exact for integer
keys; ``rescale_keys`` maps arbitrary float64 keys into a safe range.
"""
from __future__ import annotations

from typing import Literal, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .segmentation import Segments, shrinking_cone


class DeviceIndex(NamedTuple):
    seg_start: jax.Array  # (S,) f32  first key of each segment
    slope: jax.Array      # (S,) f32
    base: jax.Array       # (S,) i32  global position of segment start
    seg_end: jax.Array    # (S,) i32  global position one past the segment end
    keys: jax.Array       # (N,) f32  the sorted key column (HBM resident)
    error: int            # static


def build_device_index(keys: np.ndarray, error: int,
                       segs: Segments | None = None) -> DeviceIndex:
    keys = np.asarray(keys)
    if segs is None:
        segs = shrinking_cone(keys.astype(np.float64), error)
    base = np.asarray(segs.base, np.int64)
    seg_end = np.concatenate([base[1:], [keys.shape[0]]])
    return DeviceIndex(
        seg_start=jnp.asarray(segs.start_key, jnp.float32),
        slope=jnp.asarray(segs.slope, jnp.float32),
        base=jnp.asarray(base, jnp.int32),
        seg_end=jnp.asarray(seg_end, jnp.int32),
        keys=jnp.asarray(keys, jnp.float32),
        error=int(error),
    )


def rescale_keys(keys: np.ndarray) -> tuple[np.ndarray, float, float]:
    """Affine-map keys into [0, 2^23] so f32 interpolation stays exact-ish."""
    lo, hi = float(keys[0]), float(keys[-1])
    scale = (2.0 ** 23) / max(hi - lo, 1.0)
    return (keys - lo) * scale, lo, scale


def predict_positions(idx: DeviceIndex, queries: jax.Array) -> jax.Array:
    """Interpolated (approximate) global positions; error <= idx.error by Eq. 1.

    Predictions are clamped to the segment's position range so queries falling
    in inter-segment key gaps cannot overshoot (their true rank is the next
    segment's base, which stays inside the clamped +-error window)."""
    sid = jnp.clip(jnp.searchsorted(idx.seg_start, queries, side="right") - 1,
                   0, idx.seg_start.shape[0] - 1)
    local = (queries - idx.seg_start[sid]) * idx.slope[sid]
    pred = idx.base[sid] + jnp.round(local).astype(jnp.int32)
    return jnp.clip(pred, idx.base[sid], idx.seg_end[sid])


def lookup(idx: DeviceIndex, queries: jax.Array,
           strategy: Literal["window", "bisect"] = "window") -> jax.Array:
    """Batched point lookup.  Returns the rank (global position) of each query
    in ``idx.keys`` or -1 if absent.  jit-safe; ``error`` is static."""
    n = idx.keys.shape[0]
    pred = predict_positions(idx, queries)
    e = idx.error
    if strategy == "window":
        w = 2 * e + 2
        start = jnp.clip(pred - e, 0, jnp.maximum(n - w, 0)).astype(jnp.int32)
        offs = start[:, None] + jnp.arange(w, dtype=jnp.int32)[None, :]
        vals = idx.keys[jnp.minimum(offs, n - 1)]
        lt = (vals < queries[:, None]).sum(axis=1).astype(jnp.int32)
        rank = start + lt
        hit = (vals == queries[:, None]).any(axis=1)
        return jnp.where(hit, rank, -1)
    # bisect: lo/hi halving on the clipped window
    lo = jnp.clip(pred - e, 0, n).astype(jnp.int32)
    hi = jnp.clip(pred + e + 1, 0, n).astype(jnp.int32)
    steps = int(np.ceil(np.log2(2 * e + 2)))
    def body(_, lh):
        lo, hi = lh
        mid = (lo + hi) // 2
        v = idx.keys[jnp.minimum(mid, n - 1)]
        go = (v < queries) & (lo < hi)
        return jnp.where(go, mid + 1, lo), jnp.where(go, hi, mid)
    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    ok = (lo < n) & (idx.keys[jnp.minimum(lo, n - 1)] == queries)
    return jnp.where(ok, lo, -1)


def bound(idx: DeviceIndex, q: jax.Array, side: Literal["left", "right"] = "left"
          ) -> jax.Array:
    """Batched lower/upper bound rank via the bounded bisect (O(log error))."""
    n = idx.keys.shape[0]
    pred = predict_positions(idx, q)
    lo = jnp.clip(pred - idx.error, 0, n).astype(jnp.int32)
    hi = jnp.clip(pred + idx.error + 1, 0, n).astype(jnp.int32)
    steps = int(np.ceil(np.log2(2 * idx.error + 2)))

    def body(_, lh):
        l, h = lh
        mid = (l + h) // 2
        v = idx.keys[jnp.minimum(mid, n - 1)]
        go = ((v < q) if side == "left" else (v <= q)) & (l < h)
        return jnp.where(go, mid + 1, l), jnp.where(go, h, mid)

    l, _ = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return l


def range_count(idx: DeviceIndex, lo_q: jax.Array, hi_q: jax.Array) -> jax.Array:
    """Batched range-count: #keys in [lo_q, hi_q] (duplicates included)."""
    return bound(idx, hi_q, "right") - bound(idx, lo_q, "left")
