"""Device-side (JAX) FITing-Tree: thin compatibility wrapper.

The canonical implementation now lives in ``repro.index``: the segment
geometry is a ``SegmentTable`` (repro.index.table) and the batched bounded
searches -- the ``window`` / ``bisect`` strategies described below -- exist
once, in ``repro.index.engine`` (``xla_lookup``).  This module keeps the
original public surface (``DeviceIndex``, ``build_device_index``, ``lookup``,
``predict_positions``) plus the rank primitives built on top of it
(``bound``, ``range_count``).

Two bounded-search strategies (both O(error) bounded):
  * ``window``  -- gather the 2e+2 window and compare-reduce (vector friendly;
                   what the Pallas kernel does in VMEM);
  * ``bisect``  -- log2(2e) halving steps of single gathers (fewer bytes for
                   large e; what a CPU would do).

float32 keys: interpolation subtracts the segment start *before* rounding, so
provided per-segment key spans stay < 2^24 the f32 math is exact for integer
keys; ``rescale_keys`` maps arbitrary float64 keys into a safe range.
"""
from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.index.engine import (DeviceIndex, device_index, predict_positions,
                                xla_lookup, xla_search)
from repro.index.table import SegmentTable

from .segmentation import Segments

__all__ = ["DeviceIndex", "build_device_index", "rescale_keys",
           "predict_positions", "lookup", "bound", "range_count"]


def build_device_index(keys: np.ndarray, error: int,
                       segs: Segments | None = None) -> DeviceIndex:
    """Segment (if needed) and convert to the f32 device form."""
    table = SegmentTable.from_keys(np.asarray(keys), error, segs=segs,
                                   assume_sorted=True)
    return device_index(table)


def rescale_keys(keys: np.ndarray) -> tuple[np.ndarray, float, float]:
    """Affine-map keys into [0, 2^23] so f32 interpolation stays exact-ish."""
    lo, hi = float(keys[0]), float(keys[-1])
    scale = (2.0 ** 23) / max(hi - lo, 1.0)
    return (keys - lo) * scale, lo, scale


def lookup(idx: DeviceIndex, queries: jax.Array,
           strategy: Literal["window", "bisect"] = "window") -> jax.Array:
    """Batched point lookup.  Returns the rank (global position) of each query
    in ``idx.keys`` or -1 if absent.  jit-safe; ``error`` is static."""
    return xla_lookup(idx, queries, strategy)


def bound(idx: DeviceIndex, q: jax.Array, side: Literal["left", "right"] = "left"
          ) -> jax.Array:
    """Batched lower/upper bound rank: thin wrapper over the query plane's
    device primitive (``repro.index.engine.xla_search``, O(log error)
    bounded bisect + duplicate snap).  The snap is the fix the historical
    in-module bisect lacked: a duplicate run straddling the routed segment
    (or longer than the window) now resolves to the exact global rank on
    both sides, matching ``np.searchsorted`` and every other backend."""
    return xla_search(idx, q, side, "bisect")


def range_count(idx: DeviceIndex, lo_q: jax.Array, hi_q: jax.Array) -> jax.Array:
    """Batched range-count: #keys in the inclusive [lo_q, hi_q] (duplicates
    included).  Thin wrapper over the query plane's contract: leftmost rank
    at ``lo_q``, rightmost at ``hi_q``, inverted ranges count 0 instead of
    going negative."""
    return jnp.maximum(
        xla_search(idx, hi_q, "right") - xla_search(idx, lo_q, "left"), 0)
