"""FITing-Tree / A-Tree: the host-side index structure (Secs. 2, 4, 5).

Layout (clustered index, Fig. 2):
  * table data is partitioned into *variable-sized pages*, one per segment;
  * per segment we keep (start_key, slope) -- 24B of metadata in the paper's
    accounting -- organized in an array-packed router (the paper's inner B+ tree;
    see DESIGN.md Sec. 2 for why pointer-chasing is replaced by packed arrays);
  * each page carries a bounded sorted insert buffer (Sec. 5); the segmentation
    error budget is transparently err_seg = error - buffer_size so the
    user-visible bound still holds when elements sit in the buffer.

Lookup (Alg. 3): router -> segment, interpolate, binary-search the +-err window
of the page, then the buffer.  Insert (Alg. 4): append to the buffer; on
overflow merge + re-run ShrinkingCone and splice the new segments in.

A non-clustered index (Fig. 3) is the same structure over the *sorted key
column* with a parallel payload array per page (pointers into the table).
"""
from __future__ import annotations

import bisect
import dataclasses
import math

import numpy as np

from repro.index.table import (SegmentTable, numpy_lookup, numpy_search,
                               route_keys)

from .segmentation import Mode, Segments, shrinking_cone


class PackedRouter:
    """Array-packed static B+-tree over segment start keys.

    Semantically equivalent to searchsorted over the leaf array (tests assert
    this); exists to make the paper's log_b(S) tree-search term concrete:
    ``height`` and ``size_bytes`` feed the Sec. 6 cost model.
    """

    def __init__(self, leaf_keys: np.ndarray, fanout: int = 16):
        self.fanout = fanout
        self.levels: list[np.ndarray] = [np.asarray(leaf_keys, np.float64)]
        while self.levels[-1].shape[0] > fanout:
            self.levels.append(self.levels[-1][::fanout])
        self.levels.reverse()  # levels[0] = root

    @property
    def height(self) -> int:
        return len(self.levels)

    def size_bytes(self) -> int:
        # 8B key + 8B pointer per entry, all levels (pessimistic, like Sec. 6.2)
        return int(sum(lvl.shape[0] for lvl in self.levels) * 16)

    def descend(self, keys: np.ndarray) -> np.ndarray:
        """Batched level-by-level descent (what the TPU kernel does)."""
        keys = np.asarray(keys, np.float64)
        node = np.zeros(keys.shape[0], dtype=np.int64)
        b = self.fanout
        for d, lvl in enumerate(self.levels):
            lo = node * b
            hi = np.minimum(lo + b, lvl.shape[0])
            # branchless binary search inside each node slice
            child = lo.copy()
            span = int(np.max(hi - lo)) if lvl.shape[0] else 0
            steps = max(1, math.ceil(math.log2(max(2, span))))
            lo_i, hi_i = lo.copy(), hi.copy()
            for _ in range(steps + 1):
                mid = (lo_i + hi_i) // 2
                mid_c = np.minimum(mid, lvl.shape[0] - 1)
                go_right = (lvl[mid_c] <= keys) & (lo_i < hi_i)
                lo_i = np.where(go_right, mid + 1, lo_i)
                hi_i = np.where(go_right, hi_i, mid)
            child = np.maximum(lo_i - 1, 0)
            node = child
        return node


def _merge_sorted(page: np.ndarray, run: np.ndarray,
                  pl_page: np.ndarray | None = None,
                  pl_run: np.ndarray | None = None
                  ) -> tuple[np.ndarray, np.ndarray | None]:
    """Stable two-way merge of two sorted key arrays (+ parallel payloads).

    ``page`` elements come first among equal keys (side="right"), matching
    the Alg. 4 buffer-merge semantics."""
    merged = np.empty(page.shape[0] + run.shape[0], np.float64)
    pos = np.searchsorted(page, run, side="right") + np.arange(run.shape[0])
    mask = np.zeros(merged.shape[0], bool)
    mask[pos] = True
    merged[mask] = run
    merged[~mask] = page
    pl_merged = None
    if pl_page is not None:
        pl_merged = np.empty(merged.shape[0], pl_page.dtype)
        pl_merged[mask] = pl_run
        pl_merged[~mask] = pl_page
    return merged, pl_merged


def _paginate(arr: np.ndarray, pl: np.ndarray | None, segs: Segments
              ) -> tuple[list[np.ndarray], list[np.ndarray] | None]:
    """Slice a merged sorted run into per-segment pages (+ payload pages)."""
    bounds = np.concatenate([segs.base, [arr.shape[0]]]).astype(np.int64)
    pages = [arr[bounds[i]:bounds[i + 1]] for i in range(segs.n_segments)]
    pl_pages = (None if pl is None else
                [pl[bounds[i]:bounds[i + 1]] for i in range(segs.n_segments)])
    return pages, pl_pages


def _empty_segments(error: int) -> Segments:
    """One degenerate zero-count segment: keeps routing well-defined for an
    empty tree (mirrors ``SegmentTable.empty``)."""
    return Segments(start_key=np.zeros(1, np.float64),
                    slope=np.zeros(1, np.float64),
                    base=np.zeros(1, np.int64),
                    count=np.zeros(1, np.int64), error=int(error))


class FITingTree:
    """The paper's index.  ``error`` is the user-visible max-error bound."""

    def __init__(self, keys: np.ndarray, error: int, buffer_size: int = 0,
                 mode: Mode = "paper", payload: np.ndarray | None = None,
                 fanout: int = 16, assume_sorted: bool = False):
        keys = np.asarray(keys, np.float64)
        if not assume_sorted:
            order = np.argsort(keys, kind="stable")
            keys = keys[order]
            if payload is not None:
                payload = np.asarray(payload)[order]
        if buffer_size >= error:
            raise ValueError("buffer_size must be < error (Sec. 5)")
        self.error = int(error)
        self.buffer_size = int(buffer_size)
        self.err_seg = int(error - buffer_size) if buffer_size else int(error)
        self.mode: Mode = mode
        self.fanout = fanout
        self.clustered = payload is None

        segs = (_empty_segments(self.err_seg) if keys.shape[0] == 0 else
                shrinking_cone(keys, self.err_seg, mode=mode))
        self._init_pages(keys, payload, segs)

    # ------------------------------------------------------------------ build
    def _init_pages(self, keys, payload, segs: Segments):
        table = SegmentTable.from_segments(keys, segs, error=self.err_seg)
        self.start_keys = table.start_key.copy()
        self.slopes = table.slope.copy()
        self.pages = [table.page(i) for i in range(table.n_segments)]
        self.payloads = (None if payload is None else
                         [payload[table.base[i]:table.seg_end[i]]
                          for i in range(table.n_segments)])
        self.buffers: list[list[float]] = [[] for _ in range(table.n_segments)]
        self.buf_payloads: list[list] = [[] for _ in range(table.n_segments)]
        self.router = PackedRouter(self.start_keys, self.fanout)
        self._flat_cache = None
        self._table_cache: SegmentTable | None = table

    # ----------------------------------------------------------------- sizing
    @property
    def n_segments(self) -> int:
        return len(self.pages)

    @property
    def n_keys(self) -> int:
        return int(sum(p.shape[0] for p in self.pages) + sum(len(b) for b in self.buffers))

    def index_size_bytes(self) -> int:
        """Sec. 6.2 accounting: segment metadata + router (tree) size."""
        return self.n_segments * 24 + self.router.size_bytes()

    # ----------------------------------------------------------------- lookup
    def _segment_of(self, key: float) -> int:
        return int(route_keys(self.start_keys, key))

    def _window(self, sid: int, key: float) -> tuple[int, int, int]:
        page = self.pages[sid]
        pred = (key - self.start_keys[sid]) * self.slopes[sid]
        pred_i = int(round(pred))
        lo = max(0, pred_i - self.err_seg)
        hi = min(page.shape[0], pred_i + self.err_seg + 1)
        return lo, hi, pred_i

    def lookup(self, key: float):
        """Alg. 3.  Returns (segment_id, offset, payload|None) or None if absent."""
        sid = self._segment_of(key)
        page = self.pages[sid]
        lo, hi, _ = self._window(sid, key)
        off = lo + int(np.searchsorted(page[lo:hi], key, side="left"))
        if off < hi and off < page.shape[0] and page[off] == key:
            val = None if self.payloads is None else self.payloads[sid][off]
            return (sid, off, val)
        buf = self.buffers[sid]
        j = bisect.bisect_left(buf, key)
        if j < len(buf) and buf[j] == key:
            val = None if self.payloads is None else self.buf_payloads[sid][j]
            return (sid, -(j + 1), val)
        return None

    def lookup_batch(self, keys: np.ndarray) -> np.ndarray:
        """Vectorized membership probe over the *pages* (buffers excluded; the
        benchmark path).  Delegates to the canonical numpy backend over the
        page snapshot: interpolate then log2(2*err) halving steps, exactly as
        the TPU kernel does.  Returns the global rank of each found key, -1 if
        absent from pages."""
        return numpy_lookup(self.as_table(), keys)

    def _flat_view(self):
        if getattr(self, "_flat_cache", None) is None:
            counts = np.asarray([p.shape[0] for p in self.pages], np.int64)
            bases = np.concatenate([[0], np.cumsum(counts)[:-1]])
            self._flat_cache = (np.concatenate(self.pages), bases)
        return self._flat_cache

    def as_table(self, epoch: int = 0) -> SegmentTable:
        """Immutable SegmentTable over the current pages (buffers excluded).

        The table satisfies Eq. 1 with the segmentation budget err_seg, so any
        ``repro.index.engine`` backend can serve it.  Cached until the next
        mutation; the returned snapshot never aliases mutable state."""
        if getattr(self, "_table_cache", None) is None:
            flat, bases = self._flat_view()
            counts = np.asarray([p.shape[0] for p in self.pages], np.int64)
            self._table_cache = SegmentTable(
                start_key=self.start_keys.copy(), slope=self.slopes.copy(),
                base=bases.astype(np.int64),
                seg_end=(bases + counts).astype(np.int64),
                keys=flat, error=self.err_seg)
        t = self._table_cache
        return t if t.epoch == epoch else dataclasses.replace(t, epoch=epoch)

    def payload_column(self) -> np.ndarray | None:
        """Payload column parallel to ``as_table().keys`` (pages only --
        callers that need buffered payloads flush first, as the publisher
        does).  None for a clustered index; always a fresh array, so a
        snapshot holding it never aliases mutable tree state."""
        if self.payloads is None:
            return None
        return np.concatenate(self.payloads) if self.payloads else \
            np.empty(0)

    def range_query(self, lo_key: float, hi_key: float) -> np.ndarray:
        """Sec. 4.2 range scan: thin wrapper over the typed query plane.

        The page half delegates to the plane's bounded rank search
        (``repro.index.table.numpy_search`` -- the ``[lo, hi]``-inclusive
        contract of ``repro.index.query``: leftmost rank at ``lo``, rightmost
        at ``hi``), which also fixes the legacy scan's blind spot: it started
        at ``lo_key``'s *routed* segment, silently dropping duplicates of
        ``lo_key`` whose run began in an earlier segment.  Buffered inserts
        (invisible to the page snapshot) merge on top, as before."""
        if hi_key < lo_key:
            return np.empty(0, np.float64)
        table = self.as_table()
        bounds = np.asarray([lo_key, hi_key], np.float64)
        lo_rank = int(numpy_search(table, bounds[:1], "left")[0])
        hi_rank = max(int(numpy_search(table, bounds[1:], "right")[0]), lo_rank)
        out = [table.keys[lo_rank:hi_rank]]
        for sid in self.dirty_segments():
            buf = self.buffers[sid]
            i = bisect.bisect_left(buf, lo_key)
            j = bisect.bisect_right(buf, hi_key)
            if i < j:
                out.append(np.asarray(buf[i:j], np.float64))
        return np.sort(np.concatenate(out))

    # ----------------------------------------------------------------- insert
    def insert(self, key: float, value=None) -> None:
        """Alg. 4: buffer the key; merge + re-segment on overflow."""
        if self.buffer_size == 0:
            raise ValueError("tree built read-only (buffer_size=0)")
        sid = self._segment_of(key)
        buf = self.buffers[sid]
        j = bisect.bisect_left(buf, key)
        buf.insert(j, key)
        if self.payloads is not None:
            self.buf_payloads[sid].insert(j, value)
        self._flat_cache = None
        self._table_cache = None
        if len(buf) >= self.buffer_size:
            self._merge_segment(sid)

    def dirty_segments(self) -> list[int]:
        """Segments whose insert buffer holds keys not yet merged into pages."""
        return [sid for sid, buf in enumerate(self.buffers) if buf]

    def flush(self) -> int:
        """Merge every non-empty insert buffer into its page (Alg. 4 lines
        5-9 applied per dirty segment), re-segmenting only those runs.  The
        publish path (repro.index.snapshot); returns #segments re-fit.

        All splices land in one pass (one metadata reconcat + one router
        rebuild), so the cost is O(dirty work + S), not O(dirty * S)."""
        dirty = set(self.dirty_segments())
        if not dirty:
            return 0
        pages, payloads, buffers, buf_pls = [], [], [], []
        start_keys, slopes = [], []
        for sid in range(self.n_segments):
            if sid in dirty:
                new_pages, new_payloads, segs = self._refit_segment(sid)
                pages += new_pages
                buffers += [[] for _ in range(segs.n_segments)]
                buf_pls += [[] for _ in range(segs.n_segments)]
                if new_payloads is not None:
                    payloads += new_payloads
                start_keys.append(segs.start_key)
                slopes.append(segs.slope)
            else:
                pages.append(self.pages[sid])
                buffers.append(self.buffers[sid])
                buf_pls.append(self.buf_payloads[sid])
                if self.payloads is not None:
                    payloads.append(self.payloads[sid])
                start_keys.append(self.start_keys[sid:sid + 1])
                slopes.append(self.slopes[sid:sid + 1])
        self.pages = pages
        self.buffers = buffers
        self.buf_payloads = buf_pls
        if self.payloads is not None:
            self.payloads = payloads
        self.start_keys = np.concatenate(start_keys)
        self.slopes = np.concatenate(slopes)
        self.router = PackedRouter(self.start_keys, self.fanout)
        self._flat_cache = None
        self._table_cache = None
        return len(dirty)

    def _refit_segment(self, sid: int):
        """Alg. 4 lines 5-7: merge sid's buffer into its page and re-run
        ShrinkingCone on the merged run.  Pure: returns (pages, payloads|None,
        segs) for the k >= 1 replacement segments without mutating the tree."""
        page = self.pages[sid]
        buf = np.asarray(self.buffers[sid], np.float64)
        pl_page = None if self.payloads is None else self.payloads[sid]
        pl_buf = (None if pl_page is None else
                  np.asarray(self.buf_payloads[sid], dtype=pl_page.dtype))
        merged, pl_merged = _merge_sorted(page, buf, pl_page, pl_buf)
        segs = shrinking_cone(merged, self.err_seg, mode=self.mode)
        new_pages, new_payloads = _paginate(merged, pl_merged, segs)
        return new_pages, new_payloads, segs

    def _merge_segment(self, sid: int) -> None:
        """Alg. 4 lines 5-9: replace one overflowed segment in place (the
        insert hot path; flush() batches the same refit across segments)."""
        new_pages, new_payloads, segs = self._refit_segment(sid)
        k = segs.n_segments
        self.pages[sid:sid + 1] = new_pages
        self.buffers[sid:sid + 1] = [[] for _ in range(k)]
        self.buf_payloads[sid:sid + 1] = [[] for _ in range(k)]
        if self.payloads is not None:
            self.payloads[sid:sid + 1] = new_payloads
        self.start_keys = np.concatenate([
            self.start_keys[:sid], segs.start_key, self.start_keys[sid + 1:]])
        self.slopes = np.concatenate([
            self.slopes[:sid], segs.slope, self.slopes[sid + 1:]])
        self.router = PackedRouter(self.start_keys, self.fanout)
        self._flat_cache = None
        self._table_cache = None

    # ----------------------------------------------- shard migration (splice)
    def extract_range(self, lo_key: float, hi_key: float
                      ) -> tuple[np.ndarray, np.ndarray | None]:
        """Remove and return every key in ``[lo_key, hi_key)`` (+ payloads).

        The donor half of shard rebalancing: buffers are flushed first so the
        page view is complete, segments fully inside the range are handed
        over wholesale, and a segment only partially covered is re-segmented
        over its surviving keys (everything else keeps its fitted line, so
        Eq. 1 still holds with err_seg).  Returns ``(keys, payloads)`` sorted
        ascending; ``payloads`` is ``None`` for a clustered index.  Extracting
        everything leaves a valid empty tree that ``splice_run`` / ``insert``
        can refill."""
        if hi_key < lo_key:        # inverted slices would duplicate keys
            raise ValueError(f"inverted extract range: [{lo_key}, {hi_key})")
        self.flush()
        out_k: list[np.ndarray] = []
        out_p: list[np.ndarray] = []
        pages, payloads, start_keys, slopes = [], [], [], []
        for sid in range(self.n_segments):
            page = self.pages[sid]
            a = int(np.searchsorted(page, lo_key, side="left"))
            b = int(np.searchsorted(page, hi_key, side="left"))
            pl = None if self.payloads is None else self.payloads[sid]
            if a == b:                               # untouched: keep the fit
                pages.append(page)
                start_keys.append(self.start_keys[sid:sid + 1])
                slopes.append(self.slopes[sid:sid + 1])
                if pl is not None:
                    payloads.append(pl)
                continue
            out_k.append(page[a:b].copy())
            if pl is not None:
                out_p.append(pl[a:b].copy())
            rest = np.concatenate([page[:a], page[b:]])
            if rest.shape[0] == 0:                   # fully extracted: drop
                continue
            rest_pl = None if pl is None else np.concatenate([pl[:a], pl[b:]])
            segs = shrinking_cone(rest, self.err_seg, mode=self.mode)
            pgs, pls = _paginate(rest, rest_pl, segs)
            pages += pgs
            start_keys.append(segs.start_key)
            slopes.append(segs.slope)
            if pls is not None:
                payloads += pls
        if not pages:                                # tree is now empty
            pages = [np.empty(0, np.float64)]
            start_keys = [np.zeros(1, np.float64)]
            slopes = [np.zeros(1, np.float64)]
            if self.payloads is not None:
                payloads = [out_p[0][:0]]
        self.pages = pages
        if self.payloads is not None:
            self.payloads = payloads
        self.buffers = [[] for _ in pages]           # flush() emptied them
        self.buf_payloads = [[] for _ in pages]
        self.start_keys = np.concatenate(start_keys)
        self.slopes = np.concatenate(slopes)
        self.router = PackedRouter(self.start_keys, self.fanout)
        self._flat_cache = None
        self._table_cache = None
        keys_out = (np.concatenate(out_k) if out_k else
                    np.empty(0, np.float64))
        pl_out = (None if self.payloads is None else
                  np.concatenate(out_p) if out_p else
                  self.payloads[0][:0])
        return keys_out, pl_out

    def splice_run(self, keys: np.ndarray,
                   payload: np.ndarray | None = None) -> None:
        """Merge a sorted key run (+ payloads) into the tree in bulk.

        The receiving half of shard rebalancing: only the segments whose key
        range overlaps the run are merged and re-segmented (Alg. 4 lines 5-9
        applied to the spliced span); every other segment keeps its fitted
        line.  Unlike ``insert`` this does not require an insert buffer, so
        read-only trees can be rebalanced too."""
        keys = np.asarray(keys, np.float64)
        if self.clustered and payload is not None:
            raise ValueError("tree built without payloads (clustered index); "
                             "cannot splice a payload run")
        if not self.clustered and payload is None:
            raise ValueError("non-clustered tree: splice_run needs the "
                             "payload run alongside the keys")
        if keys.shape[0] == 0:
            return
        if payload is not None and len(payload) != keys.shape[0]:
            raise ValueError("payload run length must match the key run")
        self.flush()
        if self.n_keys == 0:                         # refill an emptied tree
            segs = shrinking_cone(keys, self.err_seg, mode=self.mode)
            self._init_pages(keys.copy(), payload, segs)
            return
        s0 = self._segment_of(float(keys[0]))
        s1 = self._segment_of(float(keys[-1]))
        span = np.concatenate(self.pages[s0:s1 + 1])
        pl_span = (None if self.payloads is None else
                   np.concatenate(self.payloads[s0:s1 + 1]))
        pl_run = (None if payload is None else
                  np.asarray(payload, dtype=pl_span.dtype))
        merged, pl_merged = _merge_sorted(span, keys, pl_span, pl_run)
        segs = shrinking_cone(merged, self.err_seg, mode=self.mode)
        k = segs.n_segments
        pgs, pls = _paginate(merged, pl_merged, segs)
        self.pages[s0:s1 + 1] = pgs
        self.buffers[s0:s1 + 1] = [[] for _ in range(k)]
        self.buf_payloads[s0:s1 + 1] = [[] for _ in range(k)]
        if self.payloads is not None:
            self.payloads[s0:s1 + 1] = pls
        self.start_keys = np.concatenate([
            self.start_keys[:s0], segs.start_key, self.start_keys[s1 + 1:]])
        self.slopes = np.concatenate([
            self.slopes[:s0], segs.slope, self.slopes[s1 + 1:]])
        self.router = PackedRouter(self.start_keys, self.fanout)
        self._flat_cache = None
        self._table_cache = None

    # ------------------------------------------------------------ invariants
    def max_abs_error(self) -> float:
        """Verify Eq. 1 over every page element (buffers are covered by the
        err_seg + buffer_size <= error budget, Sec. 5).  Delegates to the
        canonical check on the page snapshot."""
        return self.as_table().max_abs_error()
