"""Error-bounded piece-wise-linear segmentation (FITing-Tree / A-Tree, Secs. 3.2-3.4).

A *segment* is a maximal run of (key, position) points such that every point is
within `error` positions of the line through the segment's first and last point
(the E-infinity objective of Sec. 3.1, Eq. 1).

Implements:
  * ``shrinking_cone``      -- Alg. 2 (greedy one-pass, O(n) time / O(1) state),
                               numpy-accelerated with adaptive chunking.
  * ``shrinking_cone_py``   -- line-by-line readable reference of Alg. 2 (tests
                               cross-check the fast version against this).
  * ``optimal_segmentation``-- Alg. 1 (DP, O(n^2) time via cumulative cone rows).
  * ``Segments``            -- the packed array output (start_key, slope, base, count).
  * ``verify_segments``     -- vectorized check of the error invariant (Eq. 1).

Modes:
  * ``mode="paper"``   (default): a point joins a segment iff the *endpoint-defined*
    slope lies inside the cone (this is the paper's Alg. 2 / Fig. 5 semantics; the
    final segment slope is the slope to the last point, which Theorem-3.1-style
    argument shows respects the bound for every interior point).
  * ``mode="clamped"`` (beyond-paper): a point joins iff its feasible slope interval
    intersects the cone; the final slope is the endpoint slope clamped into the
    remaining cone.  Strictly-no-worse segment lengths; see EXPERIMENTS.md SPerf.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import numpy as np

Mode = Literal["paper", "clamped"]

_INF = np.inf


@dataclasses.dataclass(frozen=True)
class Segments:
    """Packed piece-wise-linear index: position ~ base[s] + (key - start_key[s]) * slope[s]."""

    start_key: np.ndarray  # (S,) float64 -- first key of each segment
    slope: np.ndarray      # (S,) float64 -- positions per key unit
    base: np.ndarray       # (S,) int64   -- position of the first key of the segment
    count: np.ndarray      # (S,) int64   -- number of elements covered
    error: int             # the bound the segmentation was built with

    @property
    def n_segments(self) -> int:
        return int(self.start_key.shape[0])

    def size_bytes(self) -> int:
        """Paper Sec. 6.2: 24B of metadata per segment (start key, slope, pointer)."""
        return self.n_segments * 24

    def predict(self, keys: np.ndarray) -> np.ndarray:
        """Vectorized predicted positions for (sorted or unsorted) query keys."""
        keys = np.asarray(keys, dtype=np.float64)
        sid = np.searchsorted(self.start_key, keys, side="right") - 1
        sid = np.clip(sid, 0, self.n_segments - 1)
        pred = self.base[sid] + (keys - self.start_key[sid]) * self.slope[sid]
        return pred

    def segment_of(self, keys: np.ndarray) -> np.ndarray:
        sid = np.searchsorted(self.start_key, np.asarray(keys, np.float64), side="right") - 1
        return np.clip(sid, 0, self.n_segments - 1)


def _finalize(xs: np.ndarray, starts: np.ndarray, error: int,
              slopes: np.ndarray | None = None) -> Segments:
    """Build the packed Segments from start indices (and optional explicit slopes)."""
    starts = np.asarray(starts, dtype=np.int64)
    n = xs.shape[0]
    ends = np.empty_like(starts)
    ends[:-1] = starts[1:] - 1
    ends[-1] = n - 1
    x0 = xs[starts]
    x1 = xs[ends]
    dx = x1 - x0
    dy = (ends - starts).astype(np.float64)
    if slopes is None:
        with np.errstate(divide="ignore", invalid="ignore", over="ignore"):
            slopes = np.where(dx > 0, dy / np.where(dx > 0, dx, 1.0), 0.0)
    # subnormal key spans can overflow the slope to inf; a clamped slope keeps
    # predictions finite and within the bound ((k - start) <= dx, so
    # pred <= dx * SLOPE_MAX stays ~0 for such segments)
    slopes = np.clip(np.nan_to_num(np.asarray(slopes, np.float64),
                                   posinf=1e300, neginf=0.0), 0.0, 1e300)
    return Segments(
        start_key=x0.astype(np.float64),
        slope=np.asarray(slopes, np.float64),
        base=starts,
        count=(ends - starts + 1),
        error=int(error),
    )


def shrinking_cone_py(xs: np.ndarray, error: int, mode: Mode = "paper") -> Segments:
    """Readable reference implementation of Alg. 2 (ShrinkingCone).

    ``xs`` must be sorted ascending (duplicates allowed); positions are 0..n-1.
    """
    xs = np.asarray(xs, dtype=np.float64)
    n = xs.shape[0]
    if n == 0:
        raise ValueError("empty key array")
    starts = [0]
    clamped_slopes = []  # only used in mode="clamped"
    ox, oy = xs[0], 0.0          # cone origin (Alg. 2 line 3)
    sl_hi, sl_lo = _INF, 0.0     # Alg. 2 lines 1-2
    last = 0
    for i in range(1, n):
        x, y = xs[i], float(i)
        dx, dy = x - ox, y - oy
        if dx == 0.0:
            ok = dy <= error      # duplicate key: any slope predicts oy; need |dy|<=err
            if ok:
                last = i
                continue
            s = _INF
            lo_cand = hi_cand = _INF
        else:
            s = dy / dx
            hi_cand = (dy + error) / dx
            lo_cand = (dy - error) / dx
            ok = (sl_lo <= s <= sl_hi) if mode == "paper" else (
                lo_cand <= sl_hi and hi_cand >= sl_lo)
        if ok:
            sl_hi = min(sl_hi, hi_cand)
            sl_lo = max(sl_lo, lo_cand)
            last = i
        else:  # Alg. 2 lines 8-10: close the segment, new cone at (x, y)
            if mode == "clamped":
                clamped_slopes.append(_close_slope(xs, starts[-1], last, sl_lo, sl_hi))
            starts.append(i)
            ox, oy = x, y
            sl_hi, sl_lo = _INF, 0.0
            last = i
    if mode == "clamped":
        clamped_slopes.append(_close_slope(xs, starts[-1], last, sl_lo, sl_hi))
        return _finalize(xs, np.array(starts), error, np.array(clamped_slopes))
    return _finalize(xs, np.array(starts), error)


def _close_slope(xs, s0, s1, sl_lo, sl_hi) -> float:
    """Endpoint slope clamped into the final cone (mode="clamped")."""
    dx = xs[s1] - xs[s0]
    if dx <= 0:
        return 0.0
    with np.errstate(over="ignore", divide="ignore"):
        s = (s1 - s0) / dx
    if not np.isfinite(s):
        s = 1e300            # subnormal span: see _finalize slope clamp
    hi = sl_hi if np.isfinite(sl_hi) else s
    return float(min(max(s, sl_lo), max(hi, sl_lo), 1e300))


def shrinking_cone(xs: np.ndarray, error: int, mode: Mode = "paper") -> Segments:
    """numpy-accelerated Alg. 2 with adaptive chunking.

    Sequentially scans the keys but evaluates the cone update in vectorized
    chunks; on a segment break the chunk restarts at the break point with a
    small chunk that grows geometrically (exponential-search style), so the
    overhead stays O(1)x even when segments are short.
    """
    xs = np.asarray(xs, dtype=np.float64)
    n = xs.shape[0]
    if n == 0:
        raise ValueError("empty key array")
    ys = np.arange(n, dtype=np.float64)
    starts: list[int] = [0]
    slopes: list[float] = []
    use_clamped = mode == "clamped"

    cur = 0          # origin index of the open segment
    pos = 1          # next index to examine
    sl_hi, sl_lo = _INF, 0.0
    chunk = 64
    CHUNK_MAX = 8192
    while pos < n:
        hi = min(n, pos + chunk)
        dx = xs[pos:hi] - xs[cur]
        dy = ys[pos:hi] - ys[cur]
        dup = dx == 0.0
        with np.errstate(divide="ignore", invalid="ignore", over="ignore"):
            s = np.where(dup, _INF, dy / np.where(dup, 1.0, dx))
            hi_cand = np.where(dup, _INF, (dy + error) / np.where(dup, 1.0, dx))
            lo_cand = np.where(dup, -_INF, (dy - error) / np.where(dup, 1.0, dx))
        # cone state *before* adding element i = cumulative over previous elements
        hi_acc = np.minimum.accumulate(np.concatenate(([sl_hi], hi_cand))[:-1])
        lo_acc = np.maximum.accumulate(np.concatenate(([sl_lo], lo_cand))[:-1])
        if use_clamped:
            ok = np.where(dup, dy <= error, (lo_cand <= hi_acc) & (hi_cand >= lo_acc))
        else:
            ok = np.where(dup, dy <= error, (lo_acc <= s) & (s <= hi_acc))
        bad = np.nonzero(~ok)[0]
        if bad.size == 0:
            sl_hi = min(sl_hi, float(np.min(hi_cand)))
            sl_lo = max(sl_lo, float(np.max(lo_cand)))
            pos = hi
            chunk = min(CHUNK_MAX, chunk * 2)
        else:
            b = int(bad[0])
            if b > 0:
                sl_hi = min(sl_hi, float(np.min(hi_cand[:b])))
                sl_lo = max(sl_lo, float(np.max(lo_cand[:b])))
            brk = pos + b
            if use_clamped:
                slopes.append(_close_slope(xs, cur, brk - 1, sl_lo, sl_hi))
            starts.append(brk)
            cur = brk
            pos = brk + 1
            sl_hi, sl_lo = _INF, 0.0
            chunk = 64
    if use_clamped:
        slopes.append(_close_slope(xs, cur, n - 1, sl_lo, sl_hi))
        return _finalize(xs, np.array(starts), error, np.array(slopes))
    return _finalize(xs, np.array(starts), error)


def optimal_segmentation(xs: np.ndarray, error: int,
                         return_segments: bool = False) -> int | Segments:
    """Alg. 1: DP over 'minimum segments covering keys[0..k]'.

    O(n^2) time via one cumulative-cone numpy row per start index j;
    O(n) memory.  Segments are endpoint-defined (Sec. 3.1 design choice).
    Rows terminate early once the cone is permanently empty.
    """
    xs = np.asarray(xs, dtype=np.float64)
    n = xs.shape[0]
    ys = np.arange(n, dtype=np.float64)
    INF32 = np.iinfo(np.int32).max
    # T[k] = min #segments covering xs[0..k-1]; T[0] = 0 sentinel.
    T = np.full(n + 1, INF32, dtype=np.int64)
    T[0] = 0
    parent = np.full(n, -1, dtype=np.int64)
    CHUNK = 2048
    for j in range(n):
        if T[j] == INF32:
            continue
        cost = T[j] + 1
        # singleton segment [j, j]
        if cost < T[j + 1]:
            T[j + 1] = cost
            parent[j] = j
        # extend the row in chunks; stop as soon as the cone dies
        sl_hi, sl_lo = _INF, 0.0
        pos = j + 1
        while pos < n:
            hi = min(n, pos + CHUNK)
            dx = xs[pos:hi] - xs[j]
            dy = ys[pos:hi] - ys[j]
            dup = dx == 0.0
            with np.errstate(divide="ignore", invalid="ignore", over="ignore"):
                s = np.where(dup, _INF, dy / np.where(dup, 1.0, dx))
                hi_cand = np.where(dup, np.where(dy <= error, _INF, -_INF),
                                   (dy + error) / np.where(dup, 1.0, dx))
                lo_cand = np.where(dup, -_INF, (dy - error) / np.where(dup, 1.0, dx))
            # cone over *interior* points (exclusive of the endpoint k)
            hi_acc = np.minimum.accumulate(np.concatenate(([sl_hi], hi_cand))[:-1])
            lo_acc = np.maximum.accumulate(np.concatenate(([sl_lo], lo_cand))[:-1])
            feasible = np.where(dup, dy <= error, (lo_acc <= s) & (s <= hi_acc))
            alive = hi_acc >= lo_acc  # monotone non-increasing
            feasible &= alive
            ks = np.nonzero(feasible)[0]
            if ks.size:
                tgt = pos + ks + 1  # T index for covering keys up to pos+ks
                upd = cost < T[tgt]
                T[tgt[upd]] = cost
                parent[pos + ks[upd]] = j
            if not alive[-1] or (min(float(np.min(hi_cand)), sl_hi)
                                 < max(float(np.max(lo_cand)), sl_lo)):
                break
            sl_hi = min(sl_hi, float(np.min(hi_cand)))
            sl_lo = max(sl_lo, float(np.max(lo_cand)))
            pos = hi
    n_opt = int(T[n])
    if not return_segments:
        return n_opt
    # reconstruct boundaries
    bounds = []
    k = n - 1
    while k >= 0:
        j = int(parent[k])
        bounds.append(j)
        k = j - 1
    return _finalize(xs, np.array(sorted(bounds)), error)


def verify_segments(xs: np.ndarray, segs: Segments) -> float:
    """Max |pred_pos - true_pos| over every element (Eq. 1). Must be <= segs.error.

    Each element is evaluated against its *containing* segment (the paper's
    per-segment guarantee).  With duplicate keys spanning a segment boundary a
    key-based assignment would be ambiguous, but lookups remain correct: the
    rightmost segment whose start <= k always contains an occurrence of k.
    """
    xs = np.asarray(xs, np.float64)
    n = xs.shape[0]
    true = np.arange(n, dtype=np.float64)
    sid = np.searchsorted(segs.base, true, side="right") - 1
    pred = segs.base[sid] + (xs - segs.start_key[sid]) * segs.slope[sid]
    return float(np.max(np.abs(pred - true)))


def max_segments_bound(n_keys: int, n_elems: int, error: int) -> float:
    """Sec. 3.4 guarantee: #segments <= min(|keys|/2, |D|/(error+1))."""
    return min(n_keys / 2.0, n_elems / (error + 1.0)) + 1.0
