"""Learned-index-backed data pipeline."""
