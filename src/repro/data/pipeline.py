"""Learned-index-backed training data pipeline (DESIGN.md Sec. 3, layer 2).

A tokenized corpus is one long token stream plus a sorted array of document
boundary offsets (cumulative token counts) -- exactly the monotone step
function of the paper's Fig. 1.  Addressing *global token position ->
(document, offset)* is a predecessor query; instead of a dense 8-bytes-per-doc
offset table (8 GB/host at 1B docs), a FITing-tree over the boundaries gives
bounded-probe lookups from a few-MB segment table (error picked by the Sec. 6
cost model against a latency budget).

The pipeline is deterministic (seeded affine permutation over samples),
host-shardable (host h takes sample indices == h mod n_hosts), and
checkpointable (state == step); a background thread prefetches batches.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np

from repro.core.segmentation import Segments, shrinking_cone
from repro.core.cost_model import CostParams, choose_error_for_latency, \
    learn_segments_fn


@dataclasses.dataclass
class Corpus:
    tokens: np.ndarray        # (N,) int32 -- the concatenated token stream
    boundaries: np.ndarray    # (D+1,) int64 -- cumulative doc offsets, [0]=0

    @property
    def n_tokens(self) -> int:
        return int(self.tokens.shape[0])

    @property
    def n_docs(self) -> int:
        return int(self.boundaries.shape[0] - 1)


def synthetic_corpus(n_tokens: int = 2_000_000, vocab: int = 32_000,
                     mean_doc: float = 600.0, seed: int = 0) -> Corpus:
    """Zipf tokens, lognormal doc lengths -- shaped like a web corpus."""
    rng = np.random.default_rng(seed)
    tokens = (rng.zipf(1.3, size=n_tokens).astype(np.int64) % (vocab - 2)) + 2
    lengths = np.maximum(8, rng.lognormal(np.log(mean_doc), 1.0,
                                          size=max(8, int(n_tokens * 2 / mean_doc)))
                         .astype(np.int64))
    cum = np.cumsum(lengths)
    cut = int(np.searchsorted(cum, n_tokens))
    boundaries = np.concatenate([[0], cum[:cut], [n_tokens]])
    boundaries = np.unique(boundaries[boundaries <= n_tokens])
    return Corpus(tokens=tokens.astype(np.int32), boundaries=boundaries)


class DocIndex:
    """FITing-tree over document boundaries: position -> (doc id, offset).

    ``error`` defaults to the Sec. 6 cost-model choice for a 2us probe budget;
    the probe is interpolation + a <=2*error-wide local search (one cache/DMA
    window), never a full binary search over D documents."""

    def __init__(self, boundaries: np.ndarray, error: int | None = None):
        self.boundaries = np.asarray(boundaries, np.float64)
        if error is None:
            cands = [64, 256, 1024, 4096]
            fn = learn_segments_fn(self.boundaries, cands, sample=None)
            error = choose_error_for_latency(2_000.0, fn, cands,
                                             CostParams(c_ns=100.0)) or 256
        self.error = int(error)
        self.segs: Segments = shrinking_cone(self.boundaries, self.error)

    def index_size_bytes(self) -> int:
        return self.segs.n_segments * 24

    def doc_of(self, pos: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Vectorized predecessor query with the bounded window (Alg. 3)."""
        pos = np.asarray(pos, np.float64)
        pred = self.segs.predict(pos)
        n = self.boundaries.shape[0]
        lo = np.clip(pred.astype(np.int64) - self.error, 0, n - 1)
        hi = np.clip(pred.astype(np.int64) + self.error + 2, 1, n)
        # bounded branchless bisect (same loop the TPU kernel runs)
        steps = int(np.ceil(np.log2(2 * self.error + 3)))
        for _ in range(steps):
            mid = (lo + hi) // 2
            go = self.boundaries[np.minimum(mid, n - 1)] <= pos
            lo = np.where(go & (lo < hi), mid + 1, lo)
            hi = np.where(go, hi, mid)
        doc = np.maximum(lo - 1, 0)
        off = pos.astype(np.int64) - self.boundaries[doc].astype(np.int64)
        return doc.astype(np.int64), off


@dataclasses.dataclass
class PipelineConfig:
    seq_len: int = 1024
    batch_size: int = 8            # host-local
    n_hosts: int = 1
    host_id: int = 0
    seed: int = 1234
    prefetch: int = 2


class DataPipeline:
    """Deterministic, resumable sample iterator over a Corpus."""

    def __init__(self, corpus: Corpus, cfg: PipelineConfig,
                 doc_index: DocIndex | None = None):
        self.corpus = corpus
        self.cfg = cfg
        self.doc_index = doc_index or DocIndex(corpus.boundaries)
        self.n_samples = (corpus.n_tokens - 1) // (cfg.seq_len + 1)
        # odd multiplier -> affine permutation over Z_n (deterministic shuffle)
        rng = np.random.default_rng(cfg.seed)
        self.mult = int(rng.integers(1, self.n_samples // 2) * 2 + 1)
        self.offset = int(rng.integers(0, self.n_samples))
        self._q: queue.Queue = queue.Queue(maxsize=cfg.prefetch)
        self._thread = None
        self._stop = threading.Event()

    # ------------------------------------------------------------ addressing
    def _sample_ids(self, step: int) -> np.ndarray:
        c = self.cfg
        base = step * c.batch_size * c.n_hosts + c.host_id * c.batch_size
        idx = (base + np.arange(c.batch_size)) % self.n_samples
        return (idx * self.mult + self.offset) % self.n_samples

    def batch_at(self, step: int) -> dict:
        """(B, T+1) tokens + (B,) doc ids of each window start (metadata)."""
        c = self.cfg
        ids = self._sample_ids(step)
        starts = ids * (c.seq_len + 1)
        rows = starts[:, None] + np.arange(c.seq_len + 1)[None]
        toks = self.corpus.tokens[rows]
        docs, offs = self.doc_index.doc_of(starts)
        return {"tokens": toks.astype(np.int32), "docs": docs, "offsets": offs}

    # ------------------------------------------------------------- prefetch
    def start(self, from_step: int):
        def worker():
            s = from_step
            while not self._stop.is_set():
                try:
                    self._q.put((s, self.batch_at(s)), timeout=0.2)
                    s += 1
                except queue.Full:
                    continue
        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        while True:
            yield self._q.get()

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2)

    # ------------------------------------------------------------ checkpoint
    def state_dict(self) -> dict:
        return {"seed": self.cfg.seed, "mult": self.mult,
                "offset": self.offset}

    def check_state(self, st: dict):
        assert st["mult"] == self.mult and st["offset"] == self.offset, \
            "pipeline permutation mismatch: corpus/seed changed across resume"
