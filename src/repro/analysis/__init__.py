"""Static invariant analyzer + runtime concurrency sanitizer.

Module map (see ROADMAP.md and docs/INVARIANTS.md):
  contracts.py  -- the machine-readable contract declarations (frozen
                   classes, pinned fields, host-only modules, hot-path
                   marker, the global LOCK_ORDER) shared by both layers
  invariants.py -- AST static checker, rules RI001-RI007 with
                   ``# repro: allow[RULE]`` suppression
  cli.py        -- ``python -m repro.analysis src/ [--strict]``
  sanitizer.py  -- opt-in runtime layer (``REPRO_SANITIZE=1``):
                   freeze-on-publish helpers, the per-verb ``PinTracker``,
                   and the lock-order watchdog behind ``make_lock``

``contracts`` and ``sanitizer`` are import-light (pure stdlib) so the
serving modules can depend on them without cost; the checker is only
imported by the CLI and tests.  Names below resolve lazily (PEP 562).
"""
_CONTRACT_NAMES = {"FROZEN_CLASSES", "HOST_ONLY_MODULES", "LOCK_ORDER",
                   "LOCK_RANK", "hot_path"}
_INVARIANT_NAMES = {"Analyzer", "RULES", "Violation", "check_source"}
_SANITIZER_NAMES = {"LockOrderError", "PinViolation", "enabled", "freeze",
                    "lock_graph_edges", "make_lock", "make_rlock",
                    "observe_pin", "pin_scope", "published_array",
                    "set_enabled"}

__all__ = sorted(_CONTRACT_NAMES | _INVARIANT_NAMES | _SANITIZER_NAMES)


def __getattr__(name):
    if name in _CONTRACT_NAMES:
        from . import contracts
        return getattr(contracts, name)
    if name in _INVARIANT_NAMES:
        from . import invariants
        return getattr(invariants, name)
    if name in _SANITIZER_NAMES:
        from . import sanitizer
        return getattr(sanitizer, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
