"""Machine-readable serving-stack contracts (the *declarations* the tools read).

This module is the single source of truth shared by the static checker
(``repro.analysis.invariants``) and the runtime sanitizer
(``repro.analysis.sanitizer``).  It is deliberately dependency-free (pure
stdlib, no numpy/jax) so host-only modules can import ``hot_path`` without
pulling anything heavy, and so ``python -m repro.analysis`` runs on a bare
interpreter.

Contracts declared here:

* ``FROZEN_CLASSES``      -- value types that are immutable after construction
                             (RI001: no attribute writes outside builders).
* ``FROZEN_SETATTR_ALLOW``-- the builder allowlist: (module suffix, function)
                             pairs that may use ``object.__setattr__`` on a
                             frozen instance (caches filled exactly once).
* ``PINNED_FIELDS`` / ``PINNED_SUFFIXES`` -- swap-on-publish handle fields
                             that read paths must dereference at most once per
                             method (RI002: pin a local, then use the local).
* ``FROZEN_ARRAY_FIELDS`` -- array attributes published inside snapshots /
                             tables; no in-place numpy mutation (RI003).
* ``HOST_ONLY_MODULES`` / ``ACCEL_IMPORT_ROOTS`` -- modules that must stay
                             importable without jax, and the import roots that
                             would (transitively) pull jax in (RI004).
* ``HOT_PATH_FORBIDDEN_CALLS`` -- call roots banned under ``@hot_path``
                             (RI005, alongside any lock acquisition).
* ``DEPRECATED_CALLS``    -- legacy dict-shaped stats surfaces kept only for
                             external callers (RI006: internal code uses the
                             typed ``metrics()`` tree).
* ``LOCK_ORDER``          -- the global partial order (outermost first) every
                             ``threading`` lock in the serving stack must be
                             acquired in (RI007 statically, the sanitizer's
                             watchdog at runtime).
"""
from __future__ import annotations

from typing import Callable, TypeVar

F = TypeVar("F", bound=Callable)


def hot_path(fn: F) -> F:
    """Mark ``fn`` as a serving hot path: no lock acquisition, no logging,
    no heap-allocating diagnostics (RI005).  Runtime no-op; the static
    checker keys off the decorator name and the sanitizer off the attribute."""
    fn.__hot_path__ = True
    return fn


# --------------------------------------------------------------------- RI001
# Value types whose instances are immutable once constructed.  Everything a
# reader thread can reach through a published snapshot must be in this set.
FROZEN_CLASSES = frozenset({
    "SegmentTable", "Snapshot", "ShardSet", "IndexPlan", "PlanCandidate",
    "PackedShardTables", "PointResult", "RangeResult", "ShardStats",
    "Segments",
    # typed metrics tree (read-only views handed to callers)
    "TierMetrics", "ShardMetrics", "PipelineMetrics", "ServiceMetrics",
    "MetricsSnapshot", "LsmMetrics",
    # LSM tiered write plane: the atomic level manifest and its parts
    "LevelSet", "Run", "MemView",
    # device serving plane: the device-resident manifest + its metrics node
    "DeviceShardSet", "DeviceMetrics",
})

# Builder allowlist: (module path suffix, qualified function name) pairs that
# may call ``object.__setattr__`` on a frozen instance *outside* the class's
# own ``__init__``/``__post_init__`` (self-construction is always allowed).
# Keep this list short and each entry a write-once cache.
FROZEN_SETATTR_ALLOW = frozenset({
    # one-shot device-form cache hung off the (host) SegmentTable
    ("repro/index/engine.py", "device_index"),
})

# --------------------------------------------------------------------- RI002
# Swap-on-publish handle fields: read paths must bind the current value to a
# local exactly once ("pin"), then work off the local, or two reads may span
# a concurrent publish and observe a torn pair of versions.
PINNED_FIELDS = frozenset({"_shard_set", "_state", "_level_set",
                           "_device_set"})
PINNED_SUFFIXES = ("_handle", "_snapshot")

# --------------------------------------------------------------------- RI003
# Array attributes reachable from a published Snapshot / SegmentTable /
# ShardSet; in-place numpy mutation through any of these is a data race.
FROZEN_ARRAY_FIELDS = frozenset({
    "keys", "start_key", "slope", "base", "seg_end", "payload", "boundaries",
    "count", "tombstones", "shadow_keys", "shadow_cum", "offsets",
})
# ndarray methods that mutate in place.
INPLACE_NDARRAY_METHODS = frozenset({
    "fill", "sort", "partition", "put", "resize", "setfield", "itemset",
    "byteswap",
})

# --------------------------------------------------------------------- RI004
# Modules that the host-only tree path imports; they must never import jax
# (directly or through a jax-at-module-scope repro module) at module scope.
HOST_ONLY_MODULES = (
    "repro/index/table.py",
    "repro/index/query.py",
    "repro/index/telemetry.py",
    "repro/core/tree.py",
    "repro/core/segmentation.py",
    "repro/core/cost_model.py",
)
# Import roots that pull jax in at module scope (transitively included).
ACCEL_IMPORT_ROOTS = (
    "jax", "jaxlib",
    "repro.compat",
    "repro.kernels", "repro.models",
    "repro.index.engine", "repro.index.snapshot", "repro.index.sharded",
    "repro.index.pipeline", "repro.index.fit", "repro.index.lsm",
    "repro.index.device",
    "repro.core.jax_index", "repro.core.distributed",
)

# --------------------------------------------------------------------- RI005
# Call roots banned inside ``@hot_path`` functions (heap-allocating logging /
# diagnostics); lock acquisition is banned structurally, not by name.
HOT_PATH_FORBIDDEN_CALLS = frozenset({
    "print", "open", "logging", "warnings", "traceback",
})

# --------------------------------------------------------------------- RI006
# Deprecated dict-shaped surfaces; internal code must use ``metrics()``.
DEPRECATED_CALLS = frozenset({"stats", "service_stats", "pipeline_stats"})

# --------------------------------------------------------------------- RI007
# The global lock order, outermost first.  A thread holding lock i may only
# acquire locks j > i.  Names are ``ClassName.attr`` (matching both the
# static graph keys and the names passed to ``sanitizer.make_lock``).
LOCK_ORDER = (
    "Compactor._lock",                   # one merge in flight (outermost:
                                         # the merge section swaps manifests
                                         # via the LSM write lock)
    "DeviceShardedService._write_lock",  # device publish wraps host publish
    "ShardedIndexService._write_lock",   # writer serialisation
    "LsmIndexService._write_lock",       # LSM writer / manifest swap
    "AsyncIndexService._lock",           # pipeline queue state
    "Memtable._lock",                    # memtable mutate / view build
    "ServingHandle._lock",               # per-shard install swap
    "DispatchEngine._lock",              # lazy tier-engine build
    "DeviceShardedService._fn_lock",     # lazy collective-kernel build
    "_DeviceEngine._search_lock",        # lazy search-kernel build
    "Monitor._make_lock",                # channel-ring creation
    "JSONLBackend._io_lock",             # telemetry sink flush
    "DeviceShardedService._counts_lock",  # device verb counters
    "ShardedIndexService._counts_lock",  # verb counters
    "LsmIndexService._counts_lock",      # LSM verb counters (innermost)
)

LOCK_RANK = {name: i for i, name in enumerate(LOCK_ORDER)}
