"""AST-based static checker for the serving-stack invariants (RI001-RI007).

Pure stdlib.  Each rule has an error code, precise ``file:line`` reporting,
and per-line suppression via a trailing ``# repro: allow[RI00x]`` comment
(comma-separated codes; place it on the *first* line of the offending
statement).  The contracts themselves (which classes are frozen, the lock
order, the host-only module list, ...) live in ``repro.analysis.contracts``.

Rules
-----
RI001  no attribute assignment / ``del`` on frozen-contract instances
       (``SegmentTable``, ``Snapshot``, ``ShardSet``, ``IndexPlan``, result
       types) outside their own ``__init__``/``__post_init__`` or the
       declared builder allowlist (``object.__setattr__`` included).
RI002  no double-deref of a swap-on-publish handle field (``_shard_set``,
       ``_state``, ``*_handle``, ``*_snapshot``) within one function -- pin
       the current value to a local once, then use the local.
RI003  no in-place numpy mutation (``x[...] = ``, ``+=``, ``.sort()``,
       ``.fill()``, ...) on arrays reached through a snapshot/table field.
RI004  no module-scope import of jax (or a module that pulls jax in) from a
       host-only module; ``if TYPE_CHECKING:`` blocks are exempt.
RI005  no lock acquisition and no heap-allocating logging/diagnostics in
       functions marked ``@hot_path``.
RI006  no internal calls to the deprecated ``stats()`` / ``service_stats()``
       / ``pipeline_stats()`` dict surfaces -- use ``metrics()``.
RI007  every lock attribute is acquired consistently with the declared
       global order (``contracts.LOCK_ORDER``); any cycle in the observed
       static acquisition graph is an error.

Usage::

    from repro.analysis.invariants import Analyzer, check_source
    violations = check_source(src_text, "repro/index/table.py")
    # or over a tree:
    analyzer = Analyzer()
    analyzer.check_paths(["src/"])
    for v in analyzer.violations:
        print(v)
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path

from . import contracts

RULES = {
    "RI001": "attribute mutation of a frozen-contract instance",
    "RI002": "double-deref of a swap-on-publish handle field",
    "RI003": "in-place numpy mutation of a published array",
    "RI004": "accelerator import at module scope in a host-only module",
    "RI005": "lock acquisition or logging inside a @hot_path function",
    "RI006": "internal call to a deprecated stats() dict surface",
    "RI007": "lock acquisition order inconsistent with the declared order",
}

_ALLOW_RE = re.compile(r"#\s*repro:\s*allow\[([A-Za-z0-9_,\s]+)\]")
_LOCK_NAME_RE = re.compile(r"lock", re.IGNORECASE)


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


def _allow_map(source: str) -> dict[int, set[str]]:
    """line number -> set of rule codes suppressed on that line."""
    out: dict[int, set[str]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _ALLOW_RE.search(text)
        if m:
            out[i] = {c.strip().upper() for c in m.group(1).split(",")
                      if c.strip()}
    return out


def _norm(path: str) -> str:
    return path.replace("\\", "/")


def _module_package(path: str) -> list[str]:
    """Dotted package path of the *directory* holding ``path`` (best effort:
    anchored at the last ``repro`` component; fixtures without one get [])."""
    parts = _norm(path).split("/")[:-1]
    for i in range(len(parts) - 1, -1, -1):
        if parts[i] == "repro":
            return parts[i:]
    return []


def _attr_root(node: ast.AST) -> str | None:
    """Leftmost ``Name`` of a (possibly dotted) expression, if any."""
    while isinstance(node, ast.Attribute):
        node = node.value
    if isinstance(node, ast.Name):
        return node.id
    return None


def _ann_class(ann: ast.AST | None) -> str | None:
    """Class name out of a simple annotation (``T``, ``"T"``, ``m.T``)."""
    if ann is None:
        return None
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        return ann.value.split(".")[-1].strip()
    if isinstance(ann, ast.Name):
        return ann.id
    if isinstance(ann, ast.Attribute):
        return ann.attr
    return None


def _is_pinned_field(attr: str) -> bool:
    return (attr in contracts.PINNED_FIELDS
            or attr.endswith(contracts.PINNED_SUFFIXES))


class _FunctionChecker(ast.NodeVisitor):
    """Per-function pass: RI001/RI002/RI003/RI005/RI006 + RI007 edges."""

    def __init__(self, owner: "_FileChecker", func: ast.AST,
                 class_name: str | None):
        self.owner = owner
        self.func = func
        self.class_name = class_name
        self.qualname = (f"{class_name}.{func.name}" if class_name
                         else func.name)
        self.hot = any(
            (isinstance(d, ast.Name) and d.id == "hot_path")
            or (isinstance(d, ast.Attribute) and d.attr == "hot_path")
            for d in func.decorator_list)
        # RI001: locals inferred to hold frozen-contract instances
        self.frozen_vars: dict[str, str] = {}
        for arg in [*func.args.posonlyargs, *func.args.args,
                    *func.args.kwonlyargs]:
            cls = _ann_class(arg.annotation)
            if cls in contracts.FROZEN_CLASSES:
                self.frozen_vars[arg.arg] = cls
        if class_name in contracts.FROZEN_CLASSES:
            self.frozen_vars["self"] = class_name
        self.in_frozen_init = (class_name in contracts.FROZEN_CLASSES
                               and func.name in ("__init__", "__post_init__"))
        # RI002: (base expr, field) -> first-read line
        self.pin_reads: dict[tuple[str, str], int] = {}
        # RI003: local aliases of published arrays -> source expr
        self.aliases: dict[str, str] = {}
        # RI007: innermost-last stack of lock names held syntactically
        self.lock_stack: list[str] = []

    def report(self, rule: str, node: ast.AST, message: str) -> None:
        self.owner.report(rule, node, message)

    # -- helpers -----------------------------------------------------------
    def _frozen_class_of(self, expr: ast.AST) -> str | None:
        if isinstance(expr, ast.Name):
            return self.frozen_vars.get(expr.id)
        return None

    def _protected(self, expr: ast.AST) -> str | None:
        """Published-array expression? (``<base>.keys`` or an alias of one)"""
        if (isinstance(expr, ast.Attribute)
                and expr.attr in contracts.FROZEN_ARRAY_FIELDS):
            return ast.unparse(expr)
        if isinstance(expr, ast.Name) and expr.id in self.aliases:
            return self.aliases[expr.id]
        return None

    def _check_store_target(self, target: ast.AST, node: ast.AST,
                            augmented: bool = False) -> None:
        """RI001 (frozen attr store) + RI003 (subscript store) on one
        assignment target."""
        if isinstance(target, ast.Attribute):
            cls = self._frozen_class_of(target.value)
            if cls is not None and not self.in_frozen_init:
                self.report("RI001", node,
                            f"assignment to {ast.unparse(target)} mutates "
                            f"frozen {cls} (build a new instance instead)")
            if augmented and self._protected(target):
                self.report("RI003", node,
                            f"in-place update of published array "
                            f"{ast.unparse(target)}")
        elif isinstance(target, ast.Subscript):
            src = self._protected(target.value)
            if src is not None:
                self.report("RI003", node,
                            f"in-place write through published array {src}")
        elif isinstance(target, ast.Name):
            # `k += 1` through an alias is in-place on the published array
            # (plain `k = ...` merely rebinds the name and is fine)
            if augmented and target.id in self.aliases:
                self.report("RI003", node,
                            f"in-place update of published array "
                            f"{self.aliases[target.id]} via alias "
                            f"{target.id}")
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._check_store_target(elt, node, augmented)

    # -- statements --------------------------------------------------------
    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            self._check_store_target(t, node)
        # track frozen-constructor locals and published-array aliases
        if len(node.targets) == 1 and isinstance(node.targets[0], ast.Name):
            name = node.targets[0].id
            self.frozen_vars.pop(name, None)
            self.aliases.pop(name, None)
            v = node.value
            if isinstance(v, ast.Call):
                cls = None
                if isinstance(v.func, ast.Name):
                    cls = v.func.id
                elif isinstance(v.func, ast.Attribute):
                    cls = v.func.attr
                if cls in contracts.FROZEN_CLASSES:
                    self.frozen_vars[name] = cls
            else:
                src = self._protected(v)
                if src is not None:
                    self.aliases[name] = src
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        self._check_store_target(node.target, node)
        if isinstance(node.target, ast.Name):
            cls = _ann_class(node.annotation)
            if cls in contracts.FROZEN_CLASSES:
                self.frozen_vars[node.target.id] = cls
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._check_store_target(node.target, node, augmented=True)
        self.generic_visit(node)

    def visit_Delete(self, node: ast.Delete) -> None:
        for t in node.targets:
            if isinstance(t, ast.Attribute):
                cls = self._frozen_class_of(t.value)
                if cls is not None:
                    self.report("RI001", node,
                                f"del {ast.unparse(t)} mutates frozen {cls}")
            elif isinstance(t, ast.Subscript):
                src = self._protected(t.value)
                if src is not None:
                    self.report("RI003", node,
                                f"in-place delete through published array "
                                f"{src}")
        self.generic_visit(node)

    def visit_With(self, node: ast.With) -> None:
        self._visit_with(node)

    def visit_AsyncWith(self, node: ast.AsyncWith) -> None:
        self._visit_with(node)

    def _visit_with(self, node: ast.With | ast.AsyncWith) -> None:
        acquired: list[str] = []
        for item in node.items:
            name = self._lock_name(item.context_expr)
            if name is None:
                continue
            if self.hot:
                self.report("RI005", node,
                            f"@hot_path {self.qualname} acquires lock "
                            f"{name}")
            for held in self.lock_stack + acquired:
                if held != name:
                    self.owner.lock_edge(held, name, node)
            acquired.append(name)
            for expr in (item.context_expr,):
                self.visit(expr)  # still scan the expr itself
        self.lock_stack.extend(acquired)
        for stmt in node.body:
            self.visit(stmt)
        del self.lock_stack[len(self.lock_stack) - len(acquired):]

    def _lock_name(self, expr: ast.AST) -> str | None:
        """Canonical lock identity for a with-context expression, or None."""
        target = expr
        if isinstance(target, ast.Call):  # e.g. threading.Lock() inline
            target = target.func
        if isinstance(target, ast.Attribute):
            if not _LOCK_NAME_RE.search(target.attr):
                return None
            root = _attr_root(target)
            if root in ("self", "cls") and self.class_name:
                return f"{self.class_name}.{target.attr}"
            return f"{root}.{target.attr}" if root else target.attr
        if isinstance(target, ast.Name) and _LOCK_NAME_RE.search(target.id):
            return target.id
        return None

    # -- expressions -------------------------------------------------------
    def visit_Attribute(self, node: ast.Attribute) -> None:
        if isinstance(node.ctx, ast.Load) and _is_pinned_field(node.attr):
            key = (ast.unparse(node.value), node.attr)
            first = self.pin_reads.get(key)
            if first is None:
                self.pin_reads[key] = node.lineno
            else:
                self.report(
                    "RI002", node,
                    f"{key[0]}.{node.attr} dereferenced again in "
                    f"{self.qualname} (first read at line {first}); bind a "
                    f"pinned local once and reuse it")
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        # RI001: object.__setattr__ outside the builder allowlist
        if (isinstance(func, ast.Attribute) and func.attr == "__setattr__"
                and isinstance(func.value, ast.Name)
                and func.value.id == "object"):
            # a frozen class initialising *itself* is construction, not
            # mutation: object.__setattr__(self, ...) in __init__/__post_init__
            self_init = (
                self.func.name in ("__init__", "__post_init__", "__new__")
                and bool(node.args)
                and isinstance(node.args[0], ast.Name)
                and node.args[0].id in ("self", "cls"))
            if not self_init and not self.owner.setattr_allowed(self.qualname):
                self.report("RI001", node,
                            f"object.__setattr__ outside the builder "
                            f"allowlist (in {self.qualname})")
        if isinstance(func, ast.Attribute):
            # RI003: in-place ndarray methods on published arrays
            if func.attr in contracts.INPLACE_NDARRAY_METHODS:
                src = self._protected(func.value)
                if src is None and ast.unparse(func.value) == "np.ndarray":
                    src = (self._protected(node.args[0])
                           if node.args else None)
                if src is not None:
                    self.report("RI003", node,
                                f"in-place {func.attr}() on published "
                                f"array {src}")
            if func.attr == "copyto" and node.args:
                src = self._protected(node.args[0])
                if src is not None:
                    self.report("RI003", node,
                                f"np.copyto into published array {src}")
            # RI006: deprecated dict surfaces
            if func.attr in contracts.DEPRECATED_CALLS:
                self.report("RI006", node,
                            f".{func.attr}() is deprecated inside the repo; "
                            f"use the typed metrics() tree")
            # RI005: explicit acquire in a hot path
            if self.hot and func.attr == "acquire":
                self.report("RI005", node,
                            f"@hot_path {self.qualname} calls .acquire()")
        if self.hot:
            root = _attr_root(func)
            if root in contracts.HOT_PATH_FORBIDDEN_CALLS:
                self.report("RI005", node,
                            f"@hot_path {self.qualname} calls {root} "
                            f"(heap-allocating diagnostic)")
            elif root == "threading":
                self.report("RI005", node,
                            f"@hot_path {self.qualname} constructs a "
                            f"threading primitive")
        self.generic_visit(node)

    # nested defs get their own checker; don't descend with this one's state
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        if node is not self.func:
            self.owner.check_function(node, self.class_name)
        else:
            self.generic_visit(node)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self.visit_FunctionDef(node)  # type: ignore[arg-type]

    def run(self) -> None:
        for stmt in self.func.body:
            self.visit(stmt)


class _FileChecker:
    def __init__(self, analyzer: "Analyzer", path: str, source: str,
                 tree: ast.Module):
        self.analyzer = analyzer
        self.path = _norm(path)
        self.tree = tree
        self.allow = _allow_map(source)
        self.violations: list[Violation] = []

    def report(self, rule: str, node: ast.AST, message: str) -> None:
        line = getattr(node, "lineno", 0)
        if rule in self.allow.get(line, ()):  # suppressed in source
            return
        self.violations.append(Violation(rule, self.path, line, message))

    def setattr_allowed(self, qualname: str) -> bool:
        return any(self.path.endswith(suffix) and qualname == q
                   for suffix, q in contracts.FROZEN_SETATTR_ALLOW)

    def lock_edge(self, outer: str, inner: str, node: ast.AST) -> None:
        if (outer in contracts.LOCK_RANK and inner in contracts.LOCK_RANK
                and contracts.LOCK_RANK[outer] > contracts.LOCK_RANK[inner]):
            self.report("RI007", node,
                        f"acquires {inner} while holding {outer}, against "
                        f"the declared order in contracts.LOCK_ORDER")
        self.analyzer.lock_edges.setdefault(
            (outer, inner), (self.path, getattr(node, "lineno", 0)))

    # -- traversal ---------------------------------------------------------
    def check(self) -> list[Violation]:
        self._check_module_imports()
        self._walk_body(self.tree.body, class_name=None)
        return self.violations

    def _walk_body(self, body: list[ast.stmt],
                   class_name: str | None) -> None:
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.check_function(stmt, class_name)
            elif isinstance(stmt, ast.ClassDef):
                self._walk_body(stmt.body, class_name=stmt.name)
            elif isinstance(stmt, (ast.If, ast.Try, ast.With)):
                inner = [*getattr(stmt, "body", [])]
                for attr in ("orelse", "finalbody"):
                    inner.extend(getattr(stmt, attr, []))
                for h in getattr(stmt, "handlers", []):
                    inner.extend(h.body)
                self._walk_body(inner, class_name)

    def check_function(self, func: ast.AST, class_name: str | None) -> None:
        _FunctionChecker(self, func, class_name).run()

    # -- RI004 -------------------------------------------------------------
    def _check_module_imports(self) -> None:
        if not any(self.path.endswith(m) for m in contracts.HOST_ONLY_MODULES):
            return
        pkg = _module_package(self.path)
        for stmt in self._module_scope_stmts(self.tree.body):
            if isinstance(stmt, ast.Import):
                for alias in stmt.names:
                    self._check_import_name(alias.name, stmt)
            elif isinstance(stmt, ast.ImportFrom):
                name = self._resolve_from(stmt, pkg)
                if name:
                    self._check_import_name(name, stmt)

    def _module_scope_stmts(self, body: list[ast.stmt]):
        """Module-level statements, descending into plain if/try blocks but
        not into ``if TYPE_CHECKING:`` guards (annotation-only imports)."""
        for stmt in body:
            if isinstance(stmt, ast.If):
                test = ast.unparse(stmt.test)
                if "TYPE_CHECKING" in test:
                    yield from self._module_scope_stmts(stmt.orelse)
                    continue
                yield from self._module_scope_stmts(stmt.body)
                yield from self._module_scope_stmts(stmt.orelse)
            elif isinstance(stmt, ast.Try):
                inner = [*stmt.body, *stmt.orelse, *stmt.finalbody]
                for h in stmt.handlers:
                    inner.extend(h.body)
                yield from self._module_scope_stmts(inner)
            else:
                yield stmt

    def _resolve_from(self, stmt: ast.ImportFrom,
                      pkg: list[str]) -> str | None:
        if stmt.level == 0:
            return stmt.module
        if not pkg:
            return stmt.module  # fixture without a repro anchor: best effort
        base = pkg[: len(pkg) - (stmt.level - 1)]
        return ".".join([*base, stmt.module] if stmt.module else base)

    def _check_import_name(self, name: str, stmt: ast.stmt) -> None:
        for root in contracts.ACCEL_IMPORT_ROOTS:
            if name == root or name.startswith(root + "."):
                self.report(
                    "RI004", stmt,
                    f"host-only module imports {name} at module scope "
                    f"(pulls in the accelerator stack); import lazily "
                    f"inside the function that needs it")
                return


class Analyzer:
    """Whole-run driver: per-file rules plus the global RI007 lock graph."""

    def __init__(self) -> None:
        self.violations: list[Violation] = []
        self.errors: list[str] = []  # unparsable files
        # (outer, inner) -> first (path, line) observed
        self.lock_edges: dict[tuple[str, str], tuple[str, int]] = {}

    def check_source(self, source: str, path: str) -> list[Violation]:
        try:
            tree = ast.parse(source, filename=path)
        except SyntaxError as exc:
            self.errors.append(f"{path}: syntax error: {exc}")
            return []
        found = _FileChecker(self, path, source, tree).check()
        self.violations.extend(found)
        return found

    def check_paths(self, paths: list[str]) -> None:
        for path in paths:
            p = Path(path)
            files = (sorted(p.rglob("*.py")) if p.is_dir() else [p])
            for f in files:
                if "__pycache__" in f.parts:
                    continue
                self.check_source(f.read_text(encoding="utf-8"), str(f))

    def finish(self) -> list[Violation]:
        """Run-level checks (RI007 cycle detection).  Call once, at the end."""
        cycle = _find_cycle({a: {b for (x, b) in self.lock_edges if x == a}
                             for (a, _b) in self.lock_edges})
        if cycle:
            path, line = self.lock_edges[(cycle[0], cycle[1])]
            self.violations.append(Violation(
                "RI007", path, line,
                "lock-order cycle in the static acquisition graph: "
                + " -> ".join([*cycle, cycle[0]])))
        return self.violations


def _find_cycle(graph: dict[str, set[str]]) -> list[str] | None:
    state: dict[str, int] = {}  # 1 = on stack, 2 = done
    stack: list[str] = []

    def dfs(n: str) -> list[str] | None:
        state[n] = 1
        stack.append(n)
        for m in graph.get(n, ()):
            if state.get(m) == 1:
                return stack[stack.index(m):]
            if state.get(m, 0) == 0:
                found = dfs(m)
                if found:
                    return found
        stack.pop()
        state[n] = 2
        return None

    for node in list(graph):
        if state.get(node, 0) == 0:
            found = dfs(node)
            if found:
                return found
    return None


def check_source(source: str, path: str = "<fixture>.py") -> list[Violation]:
    """One-shot convenience for tests: per-file rules + RI007 finish pass."""
    analyzer = Analyzer()
    analyzer.check_source(source, path)
    return analyzer.finish()
