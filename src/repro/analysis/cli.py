"""Command-line front end: ``python -m repro.analysis [paths] [--strict]``.

Exit status: 0 when every checked file is clean, 1 when violations were
found, 2 on usage / unreadable-input errors.  ``--strict`` additionally
fails (exit 1) on unparsable files instead of skipping them with a warning
-- CI runs ``python -m repro.analysis src/ --strict``.
"""
from __future__ import annotations

import argparse
import sys

from .invariants import RULES, Analyzer


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static invariant checker for the repro serving stack "
                    "(rules RI001-RI007; suppress a line with "
                    "'# repro: allow[RI00x]').")
    parser.add_argument("paths", nargs="*", default=["src/"],
                        help="files or directories to check (default: src/)")
    parser.add_argument("--strict", action="store_true",
                        help="also fail on unparsable files")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule table and exit")
    parser.add_argument("-q", "--quiet", action="store_true",
                        help="suppress the summary line")
    args = parser.parse_args(argv)

    if args.list_rules:
        for code in sorted(RULES):
            print(f"{code}  {RULES[code]}")
        return 0

    analyzer = Analyzer()
    try:
        analyzer.check_paths(args.paths)
    except OSError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2
    violations = analyzer.finish()

    for v in violations:
        print(v)
    for err in analyzer.errors:
        print(f"warning: {err}", file=sys.stderr)
    if not args.quiet:
        print(f"repro.analysis: {len(violations)} violation(s) "
              f"in {len(args.paths)} path(s)"
              + (f", {len(analyzer.errors)} unparsable file(s)"
                 if analyzer.errors else ""),
              file=sys.stderr)
    if violations:
        return 1
    if args.strict and analyzer.errors:
        return 1
    return 0


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
