"""Opt-in runtime sanitizer for the serving stack (``REPRO_SANITIZE=1``).

Three independent checks, all free (a flag read) when disabled:

* **Freeze-on-publish** -- publish paths call :func:`freeze` /
  :func:`published_array` on every array that escapes into a ``Snapshot`` /
  ``SegmentTable`` / ``ShardSet``, setting ``writeable=False`` so any latent
  in-place mutation raises ``ValueError`` at the write site instead of
  corrupting a served epoch.  Freezing is *unconditional* (immutability is
  the contract, not a debug mode); the sanitizer flag only controls the
  tracker/watchdog layers below.

* **PinTracker** -- each sharded query verb opens a :func:`pin_scope`; every
  dereference of the live ``ShardSet`` inside the verb reports the pinned
  version via :func:`observe_pin`.  Seeing two distinct versions within one
  scope means the verb re-read the handle across a concurrent publish (a
  torn read) and raises :class:`PinViolation`.

* **Lock-order watchdog** -- :func:`make_lock` / :func:`make_rlock` return
  plain ``threading`` locks when the sanitizer is off, and order-checking
  wrappers when on.  The wrappers keep a per-thread stack of held locks,
  record every (held -> acquiring) edge, and raise :class:`LockOrderError`
  when an acquisition contradicts ``contracts.LOCK_ORDER`` or creates a
  cycle in the observed runtime graph -- the runtime cross-check of the
  static RI007 rule.

Enable with ``REPRO_SANITIZE=1`` in the environment (the test suite turns
it on by default via ``tests/conftest.py``; benches leave it off).
"""
from __future__ import annotations

import contextlib
import os
import threading

from . import contracts

__all__ = [
    "enabled", "set_enabled", "freeze", "published_array",
    "pin_scope", "observe_pin", "PinViolation",
    "make_lock", "make_rlock", "LockOrderError", "lock_graph_edges",
]


def _env_enabled() -> bool:
    return os.environ.get("REPRO_SANITIZE", "") not in ("", "0", "false",
                                                        "False", "no")


class _State:
    __slots__ = ("enabled",)

    def __init__(self) -> None:
        self.enabled = _env_enabled()


_STATE = _State()


def enabled() -> bool:
    return _STATE.enabled


def set_enabled(on: bool) -> bool:
    """Flip the sanitizer (tests); returns the previous value."""
    prev = _STATE.enabled
    _STATE.enabled = bool(on)
    return prev


# ---------------------------------------------------------------------------
# freeze-on-publish
# ---------------------------------------------------------------------------
def freeze(arr):
    """Mark ``arr`` immutable in place; returns ``arr`` (None passes through).

    Views that do not own their data are copied first: freezing a view only
    protects the view, while the caller's base buffer would stay writeable --
    the copy both closes that hole and un-aliases caller scratch buffers.
    """
    if arr is None or not hasattr(arr, "flags"):
        return arr
    if arr.flags.writeable:
        if not arr.flags.owndata and arr.base is not None \
                and getattr(arr.base, "flags", None) is not None \
                and arr.base.flags.writeable:
            arr = arr.copy()
        arr.flags.writeable = False
    return arr


def published_array(arr):
    """Alias of :func:`freeze` for publish-path call sites (reads as intent)."""
    return freeze(arr)


# ---------------------------------------------------------------------------
# PinTracker
# ---------------------------------------------------------------------------
class PinViolation(AssertionError):
    """A query verb observed two distinct ShardSet versions end-to-end."""


class _PinTracker(threading.local):
    def __init__(self) -> None:
        self.scopes: list[tuple[str, set]] = []


_PINS = _PinTracker()


class _PinScope:
    __slots__ = ("verb",)

    def __init__(self, verb: str) -> None:
        self.verb = verb

    def __enter__(self) -> "_PinScope":
        _PINS.scopes.append((self.verb, set()))
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        verb, versions = _PINS.scopes.pop()
        if exc_type is None and len(versions) > 1:
            raise PinViolation(
                f"query verb {verb!r} touched {len(versions)} ShardSet "
                f"versions {sorted(versions)}; pin the shard set once per "
                f"operation (bind a local, then use the local)")
        return False


class _NullScope:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SCOPE = _NullScope()


def pin_scope(verb: str):
    """Context for one sharded query verb; no-op unless sanitizing."""
    if not _STATE.enabled:
        return _NULL_SCOPE
    return _PinScope(verb)


def observe_pin(version) -> None:
    """Record a ShardSet version seen by the innermost open verb scope."""
    if _STATE.enabled and _PINS.scopes:
        _PINS.scopes[-1][1].add(version)


# ---------------------------------------------------------------------------
# lock-order watchdog
# ---------------------------------------------------------------------------
class LockOrderError(RuntimeError):
    """Runtime lock acquisition contradicted the declared/observed order."""


class _Held(threading.local):
    def __init__(self) -> None:
        self.stack: list[str] = []


_HELD = _Held()
_GRAPH_LOCK = threading.Lock()
_GRAPH: dict[str, set] = {}  # observed runtime edges: held -> {acquired}


def lock_graph_edges() -> list[tuple[str, str]]:
    """Snapshot of the observed runtime acquisition edges (for tests/debug)."""
    with _GRAPH_LOCK:
        return sorted((a, b) for a, bs in _GRAPH.items() for b in bs)


def _reaches(graph: dict[str, set], src: str, dst: str) -> bool:
    seen, todo = set(), [src]
    while todo:
        n = todo.pop()
        if n == dst:
            return True
        if n in seen:
            continue
        seen.add(n)
        todo.extend(graph.get(n, ()))
    return False


def _check_order(name: str) -> None:
    """Validate acquiring ``name`` given this thread's held stack."""
    rank = contracts.LOCK_RANK.get(name)
    for held in _HELD.stack:
        if held == name:
            continue
        held_rank = contracts.LOCK_RANK.get(held)
        if (rank is not None and held_rank is not None
                and held_rank > rank):
            raise LockOrderError(
                f"acquiring {name} while holding {held} contradicts the "
                f"declared order in repro.analysis.contracts.LOCK_ORDER")
        with _GRAPH_LOCK:
            # adding held -> name: a pre-existing name ->* held path = cycle
            if _reaches(_GRAPH, name, held):
                raise LockOrderError(
                    f"lock-order cycle: acquiring {name} while holding "
                    f"{held}, but {name} -> ... -> {held} was already "
                    f"observed at runtime")
            _GRAPH.setdefault(held, set()).add(name)


class _SanitizedLock:
    """Order-checking wrapper compatible with ``with``/``Condition`` use."""

    __slots__ = ("_name", "_lock", "_reentrant")

    def __init__(self, name: str, reentrant: bool) -> None:
        self._name = name
        self._reentrant = reentrant
        self._lock = threading.RLock() if reentrant else threading.Lock()

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        if not (self._reentrant and self._name in _HELD.stack):
            _check_order(self._name)
        got = self._lock.acquire(blocking, timeout)
        if got:
            _HELD.stack.append(self._name)
        return got

    def release(self) -> None:
        self._lock.release()
        # remove the innermost occurrence (re-entrant locks stack names)
        for i in range(len(_HELD.stack) - 1, -1, -1):
            if _HELD.stack[i] == self._name:
                del _HELD.stack[i]
                break

    def locked(self) -> bool:
        inner = getattr(self._lock, "locked", None)
        return inner() if inner is not None else False

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.release()

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"<SanitizedLock {self._name}>"


def make_lock(name: str):
    """A ``threading.Lock`` (plain when off, order-checked when sanitizing).

    ``name`` must be the canonical ``ClassName.attr`` identity used by
    ``contracts.LOCK_ORDER`` and the static RI007 graph.
    """
    if not _STATE.enabled:
        return threading.Lock()
    return _SanitizedLock(name, reentrant=False)


def make_rlock(name: str):
    """Re-entrant variant of :func:`make_lock`."""
    if not _STATE.enabled:
        return threading.RLock()
    return _SanitizedLock(name, reentrant=True)
