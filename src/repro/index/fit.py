"""Declarative SLO-driven index construction: ``FitSpec`` -> ``IndexPlan`` ->
:func:`open_index`.

The paper's headline knob is *not* ``error`` -- it is the SLO (Sec. 6): "a
cost model that helps determine an appropriate error parameter given either
(1) a lookup latency requirement (e.g., 500ns) or (2) a storage budget
(e.g., 100MB)".  This module makes that the front door of the library.
Instead of hand-picking ``error``, shard counts, and dispatch thresholds, a
caller writes down what they *want*:

    spec = FitSpec(latency_budget_ns=500.0)          # or storage_budget_bytes
    svc = open_index(keys, spec)                     # IndexService or sharded
    svc.insert(k); svc.publish(); svc.lookup(q)

and the planner resolves it through the Sec. 6 cost model
(:func:`repro.core.cost_model.learn_segments_fn` +
``choose_error_for_latency``/``choose_error_for_space``) into a concrete,
auditable :class:`IndexPlan`: the error parameter, the shard count (from
insert-rate and key-count heuristics), the default engine backend (from the
expected batch-size distribution), and the cost-model-calibrated
``DispatchEngine`` tier thresholds (:func:`repro.core.cost_model.
dispatch_thresholds` -- the batch sizes where the modeled per-tier latency
curves cross).  ``IndexPlan.explain()`` reports the predicted latency/size of
every candidate error so the choice can be reviewed before anything is built.

The split is deliberate: ``plan()`` is pure (numpy + the cost model, no jax,
no construction), so a plan can be computed offline from a key sample,
serialized alongside the spec (``FitSpec.to_json``), and reviewed; only
:func:`open_index` builds serving state.  Both ``IndexService`` and
``ShardedIndexService`` also accept a plan directly (``from_plan`` /
``plan=``), and their raw-knob constructors now delegate through a trivially
resolved plan, so "what configuration is this service actually running?" has
one answer: ``svc.plan``.

An infeasible budget raises :class:`InfeasibleSpecError` naming the tightest
achievable value instead of silently degrading.
"""
from __future__ import annotations

import dataclasses
import json
import math

import numpy as np

from repro.core.cost_model import (CostParams, TPUCostParams, choose_exchange,
                                   choose_error_for_latency,
                                   choose_error_for_space,
                                   dispatch_thresholds,
                                   exchange_crossover_batch, latency_ns,
                                   latency_ns_tpu, learn_segments_fn,
                                   range_latency_ns, range_latency_ns_tpu,
                                   scan_ns_per_row_tpu, size_bytes)

# Default error sweep: the paper's Sec. 7 evaluation range (powers of two so
# learn_segments_fn interpolates log-log between measured segmentations).
DEFAULT_CANDIDATE_ERRORS: tuple[int, ...] = (
    8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096, 8192, 16384)

# Shard-count heuristics (plan() docstring explains both):
_SHARD_TARGET_KEYS = 2_000_000       # per-shard publish stays tens of ms
_SHARD_TARGET_INSERTS_PER_S = 50_000  # one writer absorbs this much traffic
_MAX_PLANNED_SHARDS = 64


class InfeasibleSpecError(ValueError):
    """No candidate error satisfies the spec's budget.

    Carries the objective (``"latency"`` / ``"space"``), the requested
    budget, and the tightest achievable value over the candidate sweep so
    callers can relax the spec programmatically."""

    def __init__(self, objective: str, budget: float, tightest: float,
                 unit: str, note: str = ""):
        self.objective = objective
        self.budget = budget
        self.tightest = tightest
        super().__init__(
            f"no candidate error satisfies the {objective} budget "
            f"{budget:g} {unit}; the tightest achievable {objective} over "
            f"the candidate sweep is {tightest:g} {unit} -- relax the "
            f"budget to at least that, widen candidate_errors, or switch "
            f"objective{note}")


@dataclasses.dataclass(frozen=True)
class FitSpec:
    """What the caller wants from the index, not how to build it.

    Exactly one of the three objectives must be set:

    * ``latency_budget_ns`` -- Sec. 6.1: the smallest index meeting this
      per-lookup latency requirement.
    * ``storage_budget_bytes`` -- Sec. 6.2: the fastest index whose segment
      metadata fits this budget.
    * ``error`` -- expert escape hatch: pin the error parameter directly
      (the planner still resolves shards/backend/thresholds around it).

    Workload hints (all optional) steer the rest of the plan:

    * ``batch_sizes`` -- a sample of expected lookup batch sizes; picks the
      default backend (all-small -> numpy, all-large -> pallas, mixed ->
      dispatch).
    * ``insert_rate`` -- expected inserts/second; drives the shard count
      (independent per-shard epoch streams absorb write traffic) and the
      auto-publish cadence.
    * ``write_heavy`` -- tri-state write-mode override.  ``True`` plans the
      LSM tiered write path (``repro.index.lsm``: memtable -> learned runs ->
      background compaction) regardless of the buffer math; ``False`` pins
      the paper's in-place Alg. 4 buffer path (and an error=1 plan under
      inserts stays a loud failure); ``None`` (default) lets the planner
      decide -- it falls back to LSM exactly when the resolved error leaves
      no room for an insert buffer but the spec promises write traffic.
    * ``duplicate_density`` -- expected fraction of duplicated keys in
      [0, 1); caps the shard count (duplicate-safe cuts need at least one
      distinct key run per shard).
    * ``range_fraction`` -- expected fraction of queries that are range
      scans (in [0, 1]); folds the range-scan cost term (fixed predecessor
      cost + ``range_scan_rows`` x per-row scan marginal) into every
      candidate's predicted latency and into the dispatch-threshold
      crossings, so scan-heavy workloads plan a coarser error / earlier
      device dispatch than point-only ones.
    * ``range_scan_rows`` -- expected rows returned per range scan (the
      selectivity hint the scan term multiplies).
    * ``key_sample`` -- a representative key sample, so a plan can be
      computed (and the spec shipped in a config file) before the full key
      set exists; ``plan(None, spec)`` uses it.  ``n_keys_hint`` scales the
      sample back up to the production key count for the shard heuristic.
    * ``device_count`` -- serve from a device mesh: the plan pins one shard
      per device (``backend="device"``, :class:`repro.index.device.
      DeviceShardedService`) and scores the collective exchange strategy
      (allgather vs bucketed all_to_all) via the cost model on the expected
      batch sizes.  Incompatible with ``write_heavy=True`` (the LSM plane
      is host-resident).

    ``hardware`` selects the latency model: ``"cpu"`` is the paper's Eq. 1
    cache-miss model (:class:`CostParams`), ``"tpu"`` the roofline DMA model
    (:class:`TPUCostParams`); the matching params field overrides the
    defaults.  ``to_json``/``from_json`` round-trip the whole spec for
    config-file-driven serving.
    """

    latency_budget_ns: float | None = None
    storage_budget_bytes: float | None = None
    error: int | None = None
    # workload hints
    batch_sizes: tuple[int, ...] | None = None
    insert_rate: float = 0.0
    write_heavy: bool | None = None
    duplicate_density: float = 0.0
    range_fraction: float = 0.0
    range_scan_rows: int = 256
    key_sample: tuple[float, ...] | None = None
    n_keys_hint: int | None = None
    device_count: int | None = None
    # hardware profile
    hardware: str = "cpu"
    cpu_params: CostParams = CostParams()
    tpu_params: TPUCostParams = TPUCostParams()
    # planner knobs
    candidate_errors: tuple[int, ...] = DEFAULT_CANDIDATE_ERRORS
    segment_sample: int | None = 200_000

    def __post_init__(self):
        objectives = {"latency_budget_ns": self.latency_budget_ns,
                      "storage_budget_bytes": self.storage_budget_bytes,
                      "error": self.error}
        set_names = [k for k, v in objectives.items() if v is not None]
        if len(set_names) != 1:
            given = ", ".join(set_names) if set_names else "none"
            raise ValueError(
                "FitSpec needs exactly one objective: pass latency_budget_ns"
                " (a lookup SLO, e.g. 500.0), OR storage_budget_bytes (an "
                "index size budget, e.g. 100e6), OR error (expert: pin the "
                f"paper's error parameter); got {given}")
        if self.latency_budget_ns is not None and self.latency_budget_ns <= 0:
            raise ValueError(f"latency_budget_ns must be > 0, got "
                             f"{self.latency_budget_ns!r} (it is a per-lookup"
                             " budget in nanoseconds)")
        if self.storage_budget_bytes is not None \
                and self.storage_budget_bytes <= 0:
            raise ValueError(f"storage_budget_bytes must be > 0, got "
                             f"{self.storage_budget_bytes!r} (it is an index-"
                             "metadata budget in bytes)")
        if self.error is not None and self.error < 1:
            raise ValueError(f"error must be >= 1, got {self.error!r}")
        if self.insert_rate < 0:
            raise ValueError(f"insert_rate must be >= 0, got "
                             f"{self.insert_rate!r}")
        if self.write_heavy is not None \
                and not isinstance(self.write_heavy, bool):
            raise ValueError(f"write_heavy must be True, False or None (let "
                             f"the planner decide), got {self.write_heavy!r}")
        if not 0.0 <= self.duplicate_density < 1.0:
            raise ValueError(f"duplicate_density must be in [0, 1), got "
                             f"{self.duplicate_density!r}")
        if not 0.0 <= self.range_fraction <= 1.0:
            raise ValueError(f"range_fraction must be in [0, 1], got "
                             f"{self.range_fraction!r} (it is the expected "
                             "fraction of queries that are range scans)")
        if self.range_scan_rows < 1:
            raise ValueError(f"range_scan_rows must be >= 1, got "
                             f"{self.range_scan_rows!r} (expected rows per "
                             "range scan)")
        if self.device_count is not None and self.device_count < 1:
            raise ValueError(f"device_count must be >= 1, got "
                             f"{self.device_count!r} (the number of devices "
                             "the plan fans the shard layout over)")
        if self.device_count is not None and self.write_heavy:
            raise ValueError(
                "device_count is incompatible with write_heavy=True: the LSM "
                "tiered write plane is host-resident, while a device plan "
                "serves from device-installed snapshots; drop one of the two "
                "hints")
        if self.key_sample is not None and len(self.key_sample) == 0:
            raise ValueError("key_sample must be non-empty when given (pass "
                             "None to require keys at plan time)")
        if self.batch_sizes is not None and (
                len(self.batch_sizes) == 0
                or any(b < 1 for b in self.batch_sizes)):
            raise ValueError("batch_sizes must be a non-empty sequence of "
                             f"positive batch sizes, got {self.batch_sizes!r}")
        if self.hardware not in ("cpu", "tpu"):
            raise ValueError(f"hardware must be 'cpu' or 'tpu', got "
                             f"{self.hardware!r}")
        if len(self.candidate_errors) == 0 \
                or any(e < 1 for e in self.candidate_errors):
            raise ValueError("candidate_errors must be a non-empty sequence "
                             "of errors >= 1")
        if self.segment_sample is not None and self.segment_sample < 1:
            raise ValueError(f"segment_sample must be >= 1 (or None for the "
                             f"full key set), got {self.segment_sample!r}")
        # normalize sequence fields to tuples of plain Python scalars (numpy
        # arrays and np.int64/np.float64 elements are natural inputs here)
        # so to_json never trips on non-serializable types and
        # from_json(to_json(s)) == s holds structurally
        if self.batch_sizes is not None:
            object.__setattr__(self, "batch_sizes",
                               tuple(int(b) for b in self.batch_sizes))
        if self.key_sample is not None:
            object.__setattr__(self, "key_sample",
                               tuple(float(k) for k in self.key_sample))
        object.__setattr__(self, "candidate_errors",
                           tuple(int(e) for e in self.candidate_errors))

    # ---------------------------------------------------------- serialization
    def to_json(self) -> str:
        """Serialize for config files; ``from_json`` restores an equal spec."""
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "FitSpec":
        d = json.loads(text)
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown FitSpec fields in JSON: "
                            f"{sorted(unknown)}")
        for pname, pcls in (("cpu_params", CostParams),
                            ("tpu_params", TPUCostParams)):
            if d.get(pname) is not None:
                pknown = {f.name for f in dataclasses.fields(pcls)}
                punknown = set(d[pname]) - pknown
                if punknown:
                    raise ValueError(f"unknown FitSpec fields in JSON under "
                                     f"{pname}: {sorted(punknown)}")
                d[pname] = pcls(**d[pname])
        for name in ("batch_sizes", "key_sample", "candidate_errors"):
            if d.get(name) is not None:
                d[name] = tuple(d[name])
        return cls(**d)

    # ---------------------------------------------------------------- helpers
    @property
    def objective(self) -> str:
        if self.latency_budget_ns is not None:
            return "latency"
        if self.storage_budget_bytes is not None:
            return "space"
        return "error"


@dataclasses.dataclass(frozen=True)
class PlanCandidate:
    """One row of the planner's audit trail: a candidate error's prediction."""
    error: int
    n_segments: int
    latency_ns: float
    size_bytes: float
    feasible: bool     # meets the budget (always True for objective="error")
    chosen: bool


@dataclasses.dataclass(frozen=True)
class IndexPlan:
    """A fully resolved index configuration -- every knob the constructors
    need, plus the audit trail that justifies it.

    Produced by :func:`plan` (cost-model resolution of a :class:`FitSpec`)
    or :meth:`from_knobs` (trivial resolution of raw expert knobs, so the
    legacy constructors also carry a plan).  ``small_max``/``large_min`` are
    the dispatch tier thresholds; ``None`` means "let ``DispatchEngine``
    derive them from the cost model at build time" (the trivial-plan case).
    """

    error: int
    n_shards: int = 1
    buffer_size: int = 0
    backend: str = "numpy"
    small_max: int | None = None
    large_min: int | None = None
    publish_every: int | None = None
    # write mode: "inplace" is the paper's Alg. 4 per-tree delta buffer;
    # "lsm" routes writes through the tiered memtable -> learned-run ->
    # compaction plane (repro.index.lsm), sized by the two knobs below.
    write_mode: str = "inplace"
    memtable_capacity: int | None = None
    level_fanout: int | None = None
    # async-pipeline knobs (repro.index.pipeline.AsyncIndexService): fuse
    # queued queries once flush_threshold of them are waiting (the planner
    # sets it to the large-tier dispatch crossing, so fused batches ride the
    # fast tier), flush a partial batch after max_wait_us, and bound the
    # request queue at queue_depth queries.  None = derive at pipeline build.
    flush_threshold: int | None = None
    max_wait_us: float | None = None
    queue_depth: int | None = None
    # device plane (repro.index.device.DeviceShardedService): serve from a
    # device-resident packed shard layout, one shard per device.  exchange
    # names the shard_map collective strategy for the search fan-out:
    # "allgather" (every device scores the full batch, psum-reduced),
    # "a2a" (owner-routed bucketed all_to_all with slack capacity), or
    # "auto" (per-call cost-model choice on the batch size).
    device_count: int | None = None
    exchange: str | None = None
    # provenance / audit trail
    objective: str = "raw"           # latency | space | error | raw
    budget: float | None = None
    hardware: str = "cpu"
    n_keys: int = 0                  # keys the plan was computed over
    candidates: tuple[PlanCandidate, ...] = ()
    spec: FitSpec | None = None
    # revision story: 0 = the plan open_index()/plan() produced; every
    # replace() (and every Replanner hot-swap) bumps it, so `svc.plan`
    # always names the currently-served revision and explain() diffs are
    # auditable instead of knobs mutating in place.
    revision: int = 0

    def __post_init__(self):
        if self.error < 1:
            raise ValueError(f"plan error must be >= 1, got {self.error}")
        if self.revision < 0:
            raise ValueError(f"revision must be >= 0, got {self.revision}")
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")
        if (self.small_max is None) != (self.large_min is None):
            raise ValueError("small_max and large_min must be set together "
                             "(or both None to defer to the cost model)")
        if self.write_mode not in ("inplace", "lsm"):
            raise ValueError(f"write_mode must be 'inplace' or 'lsm', got "
                             f"{self.write_mode!r}")
        if self.memtable_capacity is not None and self.memtable_capacity < 2:
            raise ValueError(f"memtable_capacity must be >= 2, got "
                             f"{self.memtable_capacity}")
        if self.level_fanout is not None and self.level_fanout < 2:
            raise ValueError(f"level_fanout must be >= 2, got "
                             f"{self.level_fanout}")
        if self.write_mode == "lsm" and self.n_shards != 1:
            raise ValueError("an lsm-mode plan is single-service (the level "
                             "structure absorbs write traffic instead of "
                             f"shard fan-out); got n_shards={self.n_shards}")
        if self.device_count is not None and self.device_count < 1:
            raise ValueError(f"device_count must be >= 1, got "
                             f"{self.device_count}")
        if self.exchange is not None \
                and self.exchange not in ("allgather", "a2a", "auto"):
            raise ValueError(f"exchange must be 'allgather', 'a2a' or 'auto'"
                             f" (or None), got {self.exchange!r}")
        if self.device_count is not None and self.write_mode == "lsm":
            raise ValueError("a device plan cannot use the lsm write mode: "
                             "the tiered write plane is host-resident")
        if self.flush_threshold is not None and self.flush_threshold < 1:
            raise ValueError(f"flush_threshold must be >= 1, got "
                             f"{self.flush_threshold}")
        if self.max_wait_us is not None and self.max_wait_us <= 0:
            raise ValueError(f"max_wait_us must be > 0, got "
                             f"{self.max_wait_us}")
        if self.queue_depth is not None and self.flush_threshold is not None \
                and self.queue_depth < self.flush_threshold:
            raise ValueError(f"queue_depth ({self.queue_depth}) must be >= "
                             f"flush_threshold ({self.flush_threshold})")

    @classmethod
    def from_knobs(cls, error: int, *, n_shards: int = 1, buffer_size: int = 0,
                   backend: str = "numpy",
                   publish_every: int | None = None,
                   write_mode: str = "inplace",
                   memtable_capacity: int | None = None,
                   level_fanout: int | None = None) -> "IndexPlan":
        """Trivial resolution: wrap raw expert knobs as a plan (no cost-model
        run; dispatch thresholds stay cost-model-derived at build time)."""
        return cls(error=int(error), n_shards=int(n_shards),
                   buffer_size=int(buffer_size), backend=backend,
                   publish_every=publish_every, write_mode=write_mode,
                   memtable_capacity=memtable_capacity,
                   level_fanout=level_fanout, objective="raw")

    # --------------------------------------------------------------- revision
    def replace(self, **knobs) -> "IndexPlan":
        """A new frozen plan with ``knobs`` applied and ``revision`` bumped.

        The only sanctioned way to derive a changed configuration from a
        served plan: the original stays immutable, the successor carries
        ``revision + 1``, and ``explain()`` on both sides gives an auditable
        before/after.  ``revision`` itself cannot be passed."""
        if "revision" in knobs:
            raise ValueError("revision is managed by replace(); it always "
                             "becomes the source plan's revision + 1")
        unknown = set(knobs) - {f.name for f in dataclasses.fields(self)}
        if unknown:
            raise ValueError(f"unknown IndexPlan knobs: {sorted(unknown)}")
        return dataclasses.replace(self, revision=self.revision + 1, **knobs)

    # ------------------------------------------------------------ constructor
    def merge_engine_opts(self, engine_opts: dict[str, dict] | None
                          ) -> dict[str, dict] | None:
        """Fold the planned dispatch thresholds into ``engine_opts`` (caller-
        provided opts win; a trivial plan adds nothing)."""
        if self.small_max is None:
            return engine_opts
        opts = {k: dict(v) for k, v in (engine_opts or {}).items()}
        d = opts.setdefault("dispatch", {})
        d.setdefault("small_max", self.small_max)
        d.setdefault("large_min", self.large_min)
        return opts

    # ------------------------------------------------------------------ audit
    def explain(self) -> str:
        """Human-readable report: the chosen configuration and the predicted
        latency/size of every candidate error (chosen and rejected)."""
        head = f"IndexPlan: objective={self.objective}"
        if self.budget is not None:
            unit = "ns" if self.objective == "latency" else "B"
            head += f" (budget {self.budget:g} {unit})"
        head += f", hardware={self.hardware}, planned over {self.n_keys} keys"
        head += f", revision={self.revision}"
        lines = [
            head,
            f"  error={self.error}  n_shards={self.n_shards}  "
            f"buffer_size={self.buffer_size}  backend={self.backend}  "
            f"publish_every={self.publish_every}",
        ]
        if self.write_mode == "lsm":
            if self.spec is not None and self.spec.write_heavy:
                why = "spec declares write_heavy=True"
            elif self.spec is not None and self.spec.insert_rate > 0:
                why = (f"error={self.error} leaves no Alg. 4 insert buffer "
                       f"yet the spec promises insert_rate="
                       f"{self.spec.insert_rate:g}/s")
            else:
                why = "requested via raw knobs"
            lines.append(
                f"  write mode: lsm ({why}) -- memtable of "
                f"{self.memtable_capacity} keys spills into size-tiered "
                f"learned runs, compaction merges {self.level_fanout} runs "
                f"per level off the serving path")
        if self.small_max is not None:
            lines.append(
                f"  dispatch tiers (cost-model crossings): host <= "
                f"{self.small_max} < device-bisect < {self.large_min} <= "
                f"pallas")
        if self.device_count is not None:
            line = (f"  device plane: {self.device_count} device(s), one "
                    f"shard each; exchange={self.exchange}")
            if self.exchange in ("allgather", "a2a") \
                    and self.device_count > 1:
                seg = next((c.n_segments for c in self.candidates
                            if c.chosen), None)
                if seg is None:  # raw plan: rough worst-case segmentation
                    seg = max(1, math.ceil(max(1, self.n_keys)
                                           / (2 * self.error)))
                per_dev = max(1, math.ceil(seg / self.device_count))
                tpu = (self.spec.tpu_params if self.spec is not None
                       else TPUCostParams())
                cross = exchange_crossover_batch(
                    self.device_count, self.error, per_dev, tpu)
                line += (" (a2a never wins under the model)" if cross is None
                         else f" (modeled a2a crossover ~{cross} "
                              f"queries/batch)")
            lines.append(line)
        if self.flush_threshold is not None:
            lines.append(
                f"  async pipeline: coalesce {self.flush_threshold} queued "
                f"queries into one fused batch (or flush after "
                f"{self.max_wait_us:g} us), queue bounded at "
                f"{self.queue_depth} queries")
        if self.spec is not None and self.spec.range_fraction > 0:
            lines.append(
                f"  scan-heavy workload: range_fraction="
                f"{self.spec.range_fraction:g} x ~{self.spec.range_scan_rows}"
                f" rows/scan folded into every candidate latency and the "
                f"dispatch crossings")
        if self.candidates:
            lines.append("  candidates (predicted by the Sec. 6 model):")
            lines.append("    error  segments  latency_ns    size_bytes")
            for c in self.candidates:
                mark = "chosen" if c.chosen else (
                    "" if c.feasible else "infeasible")
                lines.append(
                    f"    {c.error:>5d}  {c.n_segments:>8d}  "
                    f"{c.latency_ns:>10.1f}  {c.size_bytes:>12.0f}  {mark}")
        return "\n".join(lines)


def _resolve_keys(keys, spec: FitSpec, assume_sorted: bool) -> np.ndarray:
    if keys is not None:
        arr = np.asarray(keys, np.float64).ravel()
    elif spec.key_sample is not None:
        arr = np.asarray(spec.key_sample, np.float64)
    else:
        raise ValueError("plan() needs keys (or a FitSpec.key_sample to plan "
                         "from a representative sample)")
    if arr.shape[0] == 0:
        raise ValueError("cannot plan over an empty key set")
    return arr if assume_sorted else np.sort(arr, kind="stable")


def _plan_shards(spec: FitSpec, n_keys: int) -> int:
    """Shard-count heuristic: enough shards that (a) each holds at most
    ~_SHARD_TARGET_KEYS (bounds per-shard publish cost) and (b) each absorbs
    at most ~_SHARD_TARGET_INSERTS_PER_S of the expected write traffic
    (independent epoch streams keep a write-hot range from blocking reads on
    the rest); capped by the duplicate-safe cut requirement (>= 1 distinct
    run per shard) and _MAX_PLANNED_SHARDS."""
    total = max(n_keys, spec.n_keys_hint or 0)
    size_shards = math.ceil(total / _SHARD_TARGET_KEYS)
    write_shards = (math.ceil(spec.insert_rate / _SHARD_TARGET_INSERTS_PER_S)
                    if spec.insert_rate > 0 else 1)
    n = max(1, size_shards, write_shards)
    distinct = max(1, int(total * (1.0 - spec.duplicate_density)))
    return min(n, distinct, _MAX_PLANNED_SHARDS)


def planned_buffer(error: int) -> int:
    """Per-segment Alg. 4 insert buffer the planner pairs with ``error``: a
    quarter of the error budget (err_seg = error - buffer keeps the
    user-visible bound, Sec. 5).  Every planned service is writable when the
    budget allows it; error=1 leaves no room."""
    if error < 2:
        return 0
    return min(max(2, error // 4), error - 1)


def _plan_buffer(spec: FitSpec, error: int) -> int:
    """The chosen error's buffer, with the write-traffic conflict made loud
    (an error=1 plan cannot honor a promised insert rate).  Only reachable
    when the spec pins ``write_heavy=False``; the default tri-state resolves
    this case to the LSM write mode instead (:func:`_plan_write_mode`)."""
    buffer = planned_buffer(error)
    if buffer == 0 and spec.insert_rate > 0:
        raise ValueError(
            "the resolved error=1 leaves no room for an Alg. 4 insert "
            "buffer (buffer_size < error, Sec. 5), but the spec promises "
            f"insert_rate={spec.insert_rate:g}/s; relax the budget so a "
            "larger error is chosen, drop the insert_rate hint for a "
            "read-only index, or lift write_heavy=False so the planner can "
            "fall back to the LSM write mode")
    return buffer


# LSM sizing: spill roughly every _LSM_SPILL_PERIOD_S of expected ingest so
# runs stay re-fit-sized, clamped to keep memtable writes O(small memmove).
_LSM_SPILL_PERIOD_S = 0.25
_LSM_MEMTABLE_MIN = 1024
_LSM_MEMTABLE_MAX = 65_536
_LSM_DEFAULT_FANOUT = 4


def _plan_write_mode(spec: FitSpec, error: int) -> str:
    """Resolve the tri-state ``write_heavy`` hint: explicit wins; unset
    falls back to LSM exactly when the in-place path would be a planning
    error (no Alg. 4 buffer fits yet inserts are promised)."""
    if spec.write_heavy is False:
        return "inplace"
    if spec.write_heavy:
        return "lsm"
    if spec.insert_rate > 0 and planned_buffer(error) == 0:
        return "lsm"
    return "inplace"


def _plan_memtable(spec: FitSpec) -> int:
    """Memtable capacity from the promised ingest: ~one spill per
    ``_LSM_SPILL_PERIOD_S`` at ``insert_rate``, clamped."""
    if spec.insert_rate <= 0:
        return _LSM_MEMTABLE_MIN * 4
    cap = int(spec.insert_rate * _LSM_SPILL_PERIOD_S)
    return min(max(cap, _LSM_MEMTABLE_MIN), _LSM_MEMTABLE_MAX)


def _effective_scorers(spec: FitSpec, segments_fn):
    """Per-candidate ``(eff_segments, eff_latency)`` scoring the
    configuration :func:`plan` would actually *build*, not the bare error:
    the insert buffer is carved out of the error budget (Sec. 5), so the
    tree segments -- and the served snapshot routes and window-searches --
    at ``err_seg = error - planned_buffer(error)`` (more segments, smaller
    windows than the bare error), and the paper's buffer-scan term uses the
    planned buffer.  Snapshot serving never scans write-side buffers during
    lookups (they are invisible until publish), so that term is pure
    pessimism: a budget met under this scoring is met by the built index.

    A ``range_fraction`` workload blends the range-scan cost term in: that
    fraction of queries pays the range model (predecessor locate + per-row
    scan over ``range_scan_rows`` rows) instead of the point model, so a
    scan-heavy spec is scored -- and budgeted -- on the workload it will
    actually serve."""
    rf, rows = spec.range_fraction, spec.range_scan_rows

    def eff_error(e: int) -> int:
        return max(1, e - planned_buffer(e))

    def eff_segments(e: int) -> int:
        return segments_fn(eff_error(e))

    if spec.hardware == "tpu":
        def eff_latency(e: int, s: int) -> float:
            point = latency_ns_tpu(eff_error(e), s, spec.tpu_params)
            if rf == 0.0:
                return point
            rng = range_latency_ns_tpu(eff_error(e), s, spec.tpu_params, rows)
            return (1.0 - rf) * point + rf * rng
    else:
        def eff_latency(e: int, s: int) -> float:
            p = dataclasses.replace(spec.cpu_params,
                                    buffer_size=planned_buffer(e))
            point = latency_ns(eff_error(e), s, p)
            if rf == 0.0:
                return point
            rng = range_latency_ns(eff_error(e), s, p, rows)
            return (1.0 - rf) * point + rf * rng

    return eff_segments, eff_latency


def _scan_term_ns(spec: FitSpec) -> float:
    """The workload's amortized range-scan contribution to per-query latency
    (the error-independent part: fraction x rows x per-row marginal)."""
    per_row = (scan_ns_per_row_tpu(spec.tpu_params)
               if spec.hardware == "tpu" else
               spec.cpu_params.scan_ns_per_row)
    return spec.range_fraction * spec.range_scan_rows * per_row


def _plan_backend(spec: FitSpec, small_max: int, large_min: int) -> str:
    """Default backend from the expected batch-size distribution: a workload
    living entirely inside one tier skips the dispatch layer."""
    if not spec.batch_sizes:
        return "dispatch"
    lo, hi = min(spec.batch_sizes), max(spec.batch_sizes)
    if hi <= small_max:
        return "numpy"
    if lo >= large_min:
        return "pallas"
    if lo > small_max and hi < large_min:
        return "xla-bisect"
    return "dispatch"


def plan(keys, spec: FitSpec, *, assume_sorted: bool = False) -> IndexPlan:
    """Resolve a :class:`FitSpec` against ``keys`` (or the spec's own
    ``key_sample``) into a concrete :class:`IndexPlan`.

    Pure planning: learns the error->segments curve for this data
    (:func:`learn_segments_fn`), scores every candidate error under the
    spec's hardware latency model, picks the error via the paper's Sec. 6
    choosers (smallest size meeting a latency budget / fastest within a
    space budget / pinned), then derives the shard count, insert buffer,
    default backend, auto-publish cadence, and the cost-model-calibrated
    dispatch tier thresholds.  Raises :class:`InfeasibleSpecError` (naming
    the tightest achievable budget) when no candidate fits.
    ``assume_sorted=True`` skips the sort-copy of ``keys`` (results are
    garbage if they are not actually sorted).
    """
    arr = _resolve_keys(keys, spec, assume_sorted)
    cands = tuple(sorted(set(int(e) for e in spec.candidate_errors)))
    if spec.error is not None and spec.error not in cands:
        cands = tuple(sorted((*cands, int(spec.error))))
    segments_fn = learn_segments_fn(arr, cands, sample=spec.segment_sample)
    eff_segments, eff_latency = _effective_scorers(spec, segments_fn)
    p = spec.cpu_params

    rows = [(e, eff_segments(e)) for e in cands]
    lats = {e: eff_latency(e, s) for e, s in rows}
    sizes = {e: size_bytes(e, s, p) for e, s in rows}

    budget: float | None = None
    if spec.objective == "latency":
        budget = float(spec.latency_budget_ns)
        chosen = choose_error_for_latency(budget, eff_segments, cands, p,
                                          latency_fn=eff_latency)
        if chosen is None:
            tightest = min(lats.values())
            note = ""
            scan = _scan_term_ns(spec)
            if scan >= tightest / 2:
                # the budget is lost to scanning, not to locating: say so
                note = (f"; note the range-scan term alone contributes "
                        f"{scan:g} ns of that (range_fraction="
                        f"{spec.range_fraction:g} x range_scan_rows="
                        f"{spec.range_scan_rows} rows), which no error "
                        f"parameter can reduce -- lower the scan "
                        f"selectivity hints or budget for the scans")
            raise InfeasibleSpecError("latency", budget, tightest, "ns",
                                      note=note)
        feasible = {e: lats[e] <= budget for e, _ in rows}
    elif spec.objective == "space":
        budget = float(spec.storage_budget_bytes)
        chosen = choose_error_for_space(budget, eff_segments, cands, p,
                                        latency_fn=eff_latency)
        if chosen is None:
            raise InfeasibleSpecError("space", budget, min(sizes.values()),
                                      "bytes")
        feasible = {e: sizes[e] <= budget for e, _ in rows}
    else:
        chosen = int(spec.error)
        feasible = {e: True for e, _ in rows}

    write_mode = _plan_write_mode(spec, chosen)
    if write_mode == "lsm":
        # no Alg. 4 buffer exists on the tiered path: the memtable is the
        # write absorber and compaction the re-fit cadence
        buffer_size = 0
        memtable_capacity = _plan_memtable(spec)
        level_fanout = _LSM_DEFAULT_FANOUT
    else:
        buffer_size = _plan_buffer(spec, chosen)
        memtable_capacity = None
        level_fanout = None
    n_segments = eff_segments(chosen)
    # thresholds for the table the engine will actually see: a published
    # snapshot carries err_seg as its error (tree.as_table), and
    # DispatchEngine derives from table.error/n_segments
    small_max, large_min = dispatch_thresholds(
        max(1, chosen - buffer_size), n_segments,
        spec.cpu_params, spec.tpu_params,
        range_fraction=spec.range_fraction, scan_rows=spec.range_scan_rows)
    # LSM plans stay single-service: the level structure absorbs the write
    # traffic the shard heuristic would otherwise fan out over epochs
    n_shards = 1 if write_mode == "lsm" else _plan_shards(spec, arr.shape[0])
    backend = _plan_backend(spec, small_max, large_min)
    device_count = None
    exchange = None
    if spec.device_count is not None:
        if write_mode == "lsm":
            raise ValueError(
                "the spec resolved to the lsm write mode (insert_rate="
                f"{spec.insert_rate:g}/s with no Alg. 4 buffer at error="
                f"{chosen}) but also asks for device_count="
                f"{spec.device_count}; the tiered write plane is "
                "host-resident -- relax the budget so a buffered error is "
                "chosen, or drop one of the two hints")
        # one shard per device, still capped by the duplicate-safe cut
        # requirement (each device needs at least one distinct key run)
        total = max(arr.shape[0], spec.n_keys_hint or 0)
        distinct = max(1, int(total * (1.0 - spec.duplicate_density)))
        device_count = min(int(spec.device_count), distinct)
        n_shards = device_count
        backend = "device"
        # score the collective exchange at the largest expected batch (the
        # a2a crossover favors big batches: routed work is ~slack*Q/D per
        # device vs the full Q under allgather)
        rep_batch = max(spec.batch_sizes) if spec.batch_sizes else 4096
        exchange = choose_exchange(rep_batch, device_count,
                                   max(1, chosen - buffer_size), n_segments,
                                   spec.tpu_params)
    # auto-publish roughly once per second of expected write traffic, kept
    # inside sane bounds; read-only workloads publish manually (the lsm
    # cadence drives spill/compaction maintenance through the same knob)
    publish_every = None
    if spec.insert_rate > 0 and (buffer_size > 0 or write_mode == "lsm"):
        publish_every = int(min(max(spec.insert_rate, 64), 65_536))
    # async-pipeline knobs: fuse once a flush earns the large (fused) tier,
    # bound the wait for a partial batch, and give the queue a few flushes of
    # headroom (see repro.index.pipeline for the serving semantics)
    from .pipeline import DEFAULT_MAX_WAIT_US, DEFAULT_QUEUE_DEPTH_FLUSHES
    flush_threshold = int(large_min)
    max_wait_us = DEFAULT_MAX_WAIT_US
    queue_depth = DEFAULT_QUEUE_DEPTH_FLUSHES * flush_threshold

    candidates = tuple(
        PlanCandidate(error=e, n_segments=s, latency_ns=lats[e],
                      size_bytes=sizes[e], feasible=feasible[e],
                      chosen=(e == chosen))
        for e, s in rows)
    return IndexPlan(error=chosen, n_shards=n_shards,
                     buffer_size=buffer_size, backend=backend,
                     small_max=small_max, large_min=large_min,
                     publish_every=publish_every, write_mode=write_mode,
                     memtable_capacity=memtable_capacity,
                     level_fanout=level_fanout,
                     flush_threshold=flush_threshold,
                     max_wait_us=max_wait_us, queue_depth=queue_depth,
                     device_count=device_count, exchange=exchange,
                     objective=spec.objective,
                     budget=budget, hardware=spec.hardware,
                     n_keys=int(arr.shape[0]), candidates=candidates,
                     spec=spec)


def open_index(keys, spec_or_plan: "FitSpec | IndexPlan", *,
               payload: np.ndarray | None = None, **service_kwargs):
    """The single SLO-driven entry point: plan (if needed) and build.

    Returns a ``DeviceShardedService`` for a ``backend="device"`` plan, an
    ``LsmIndexService`` for a ``write_mode="lsm"`` plan, an ``IndexService``
    for a one-shard plan, else a ``ShardedIndexService`` -- all ready for
    the full insert -> publish -> lookup cycle with no raw knob supplied by
    the caller.  Extra
    ``service_kwargs`` (e.g. ``skew_threshold``, ``auto_rebalance``,
    ``mode``) pass through to the service constructor.
    """
    if keys is None:
        raise ValueError("open_index needs the real key array; plan(None, "
                         "spec) is the offline half that works from a "
                         "FitSpec.key_sample")
    if not service_kwargs.get("assume_sorted", False):
        # sort exactly once here: plan() needs sorted keys and the service
        # would otherwise re-sort the same array at construction
        keys = np.asarray(keys, np.float64).ravel()
        order = np.argsort(keys, kind="stable")
        keys = keys[order]
        if payload is not None:
            payload = np.asarray(payload)[order]
        service_kwargs["assume_sorted"] = True
    resolved = (plan(keys, spec_or_plan, assume_sorted=True)
                if isinstance(spec_or_plan, FitSpec) else spec_or_plan)
    if not isinstance(resolved, IndexPlan):
        raise TypeError(f"open_index needs a FitSpec or IndexPlan, got "
                        f"{type(spec_or_plan).__name__}")
    # lazy: the services import this module for their plan= constructors
    if resolved.backend == "device":
        from .device import DeviceShardedService
        return DeviceShardedService.from_plan(keys, resolved, payload=payload,
                                              **service_kwargs)
    if resolved.write_mode == "lsm":
        from .lsm import LsmIndexService
        return LsmIndexService.from_plan(keys, resolved, payload=payload,
                                         **service_kwargs)
    if resolved.n_shards > 1:
        from .sharded import ShardedIndexService
        return ShardedIndexService.from_plan(keys, resolved, payload=payload,
                                             **service_kwargs)
    from repro.serve import IndexService
    return IndexService.from_plan(keys, resolved, payload=payload,
                                  **service_kwargs)


def brute_force_choice(keys, spec: FitSpec) -> int:
    """Reference oracle for tests: exhaustively score every candidate with
    the same models and apply the Sec. 6 selection rule directly (no chooser
    functions, no interpolation shortcuts beyond the shared segments_fn)."""
    arr = _resolve_keys(keys, spec, assume_sorted=False)
    cands = tuple(sorted(set(int(e) for e in spec.candidate_errors)))
    segments_fn = learn_segments_fn(arr, cands, sample=spec.segment_sample)
    eff_segments, eff_latency = _effective_scorers(spec, segments_fn)
    scored = [(e, eff_latency(e, eff_segments(e)),
               size_bytes(e, eff_segments(e), spec.cpu_params))
              for e in cands]
    if spec.objective == "latency":
        ok = [(sz, e) for e, lat, sz in scored
              if lat <= spec.latency_budget_ns]
        if not ok:
            raise InfeasibleSpecError("latency", spec.latency_budget_ns,
                                      min(lat for _, lat, _ in scored), "ns")
        return min(ok)[1]
    if spec.objective == "space":
        ok = [(lat, e) for e, lat, sz in scored
              if sz <= spec.storage_budget_bytes]
        if not ok:
            raise InfeasibleSpecError("space", spec.storage_budget_bytes,
                                      min(sz for _, _, sz in scored), "bytes")
        return min(ok)[1]
    return int(spec.error)
