"""Async serving pipeline: request coalescing into the fast tier + a
background publish/rebalance cadence.

The Sec. 6 cost model (and ``BENCH_smoke``'s measured tier curves) say the
same thing: per-query cost collapses when lookups ride the large-batch tier
-- the fixed cost of a call (python dispatch, device launch, kernel plan)
amortizes over the batch, and the fused compare-reduce path has an
order-of-magnitude lower marginal cost than the scalar host path.  Yet every
caller of ``IndexService.lookup`` pays the tier *their own* batch size earns:
a thousand concurrent callers probing one key each run a thousand scalar
lookups instead of one fused batch of a thousand.

:class:`AsyncIndexService` closes that gap.  It is a front door over any
index service (``IndexService`` / ``ShardedIndexService``) that

* **coalesces**: concurrent callers submit point/search queries into a
  bounded queue (:meth:`lookup_async` / :meth:`search_async`, each returning
  a ``concurrent.futures.Future``); a flusher thread fuses everything queued
  into ONE batch the moment the planned dispatch threshold is reached
  (``flush_threshold``, by default the plan's ``large_min`` -- the batch size
  where the modeled Pallas-tier latency curve wins) or a deadline expires
  (``max_wait_us``, so a trickle of traffic is never parked forever), then
  scatters per-caller slices back through the futures.  Heavy traffic from
  many small callers therefore lands on the fused large-batch tier
  *naturally*, with per-caller latency bounded by the deadline;
* **maintains**: a daemon cadence thread takes ``publish()`` (a no-op when
  clean) and the ``auto_rebalance`` skew check off the request path, honoring
  the plan's publish cadence (``IndexPlan.publish_every`` -- resolved against
  the spec's expected insert rate into a time interval) instead of running
  re-segmentation inline on whichever unlucky caller's insert trips the
  counter;
* **prewarms**: on start (opt-out via ``prewarm=False``) every dispatch tier
  engine is built and compiled eagerly (:meth:`DispatchEngine.prewarm`), so
  the first coalesced batch does not eat the Pallas plan/compile latency as
  a p99 spike.

Consistency: a fused flush is one ordinary batched call on the underlying
service, so every answer is bit-identical to the caller running the same
batch alone -- coalescing changes *when* work runs, never what it returns.

Failure semantics are loud: an exception inside a fused call fails exactly
the futures of that batch; a crash of the flusher or cadence thread is
recorded and re-raised to every subsequent submitter and to :meth:`close`
(a silently dead maintenance loop is an unbounded staleness bug).

Lifecycle::

    pipe = open_pipeline(keys, FitSpec(latency_budget_ns=500.0))
    f = pipe.lookup_async(qs)          # Future; batch-submit is the same call
    pipe.lookup(qs)                    # sync facade: submit + .result()
    pipe.close()                       # drain in-flight futures, stop threads

or as a context manager (``with open_pipeline(...) as pipe:``).  ``close``
is idempotent; submissions after close raise :class:`PipelineClosed`.

Backpressure: the queue is bounded (``queue_depth`` queries).  A submit
that would overflow it blocks until a flush makes room, up to ``timeout``
(then :class:`PipelineOverloaded`) -- an unbounded queue would just move the
overload into memory and tail latency.  A single submission of
``flush_threshold`` or more queries bypasses the queue entirely and runs
fused inline on the caller's thread: it already earns the fast tier alone,
and parking it would only add deadline latency for no batching win.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import warnings
from concurrent.futures import Future
from typing import TYPE_CHECKING

import numpy as np

from repro.analysis.sanitizer import make_lock

from .telemetry import (CH_FLUSH, CH_QUEUE_DEPTH, CH_SOJOURN, FLUSH_DEADLINE,
                        FLUSH_DRAIN, FLUSH_INLINE, FLUSH_THRESHOLD, Monitor,
                        PipelineMetrics, Replanner, ServiceMetrics)

if TYPE_CHECKING:   # the service types are duck-typed at runtime
    from .fit import FitSpec, IndexPlan

# Fallbacks when neither the caller nor the plan pins a knob.
DEFAULT_FLUSH_THRESHOLD = 1024     # ~ a modeled large_min for mid-size tables
DEFAULT_MAX_WAIT_US = 200.0        # trickle traffic flushes 5000x/s
DEFAULT_QUEUE_DEPTH_FLUSHES = 8    # queue_depth = 8 flushes of headroom


class PipelineClosed(RuntimeError):
    """The pipeline is closed (or its maintenance loop died); see cause."""


class PipelineOverloaded(RuntimeError):
    """The bounded request queue stayed full past the submit timeout."""


class _Request:
    """One caller's queued submission: queries + the future to resolve.
    ``t_enq`` stamps the enqueue time so the flusher can report per-request
    sojourn (queue wait + fused service call) to the monitor."""
    __slots__ = ("queries", "shape", "future", "t_enq")

    def __init__(self, queries: np.ndarray, shape: tuple[int, ...],
                 future: Future):
        self.queries = queries
        self.shape = shape
        self.future = future
        self.t_enq = time.perf_counter_ns()


class AsyncIndexService:
    """Coalescing async front door + maintenance cadence over an index service.

    ``service`` is an ``IndexService`` or ``ShardedIndexService`` (anything
    with ``lookup(queries, backend)`` / ``search(queries, side, backend)`` /
    ``publish()`` and a ``plan``).  Knobs default from ``service.plan``:

    * ``flush_threshold`` -- fuse and dispatch once this many queries are
      queued; default ``plan.flush_threshold`` (the planner sets it to the
      plan's ``large_min`` dispatch crossing), else ``plan.large_min``, else
      :data:`DEFAULT_FLUSH_THRESHOLD`.
    * ``max_wait_us`` -- oldest-request deadline in microseconds; a partial
      batch flushes when it expires.  Default ``plan.max_wait_us`` else
      :data:`DEFAULT_MAX_WAIT_US`.
    * ``queue_depth`` -- bound on queued queries across callers; submits
      block (then raise :class:`PipelineOverloaded`) when it is full.
      Default ``plan.queue_depth`` else ``8 x flush_threshold``.
    * ``publish_interval_s`` -- cadence-thread period.  Default: the plan's
      ``publish_every`` (an insert count) divided by the spec's expected
      ``insert_rate`` (inserts/s), i.e. the time the planner expects that
      many inserts to take; ``None`` when the plan has no cadence (read-only
      plan) -- the cadence thread then only runs if a period is passed
      explicitly.
    * ``prewarm`` -- build + compile every serving engine (and every
      dispatch tier) before accepting traffic, so the first fused flush does
      not pay plan/compile latency.

    Threads start in the constructor; ``close()`` (or the context manager)
    drains queued requests, completes their futures, and joins the threads.
    """

    def __init__(self, service, *, flush_threshold: int | None = None,
                 max_wait_us: float | None = None,
                 queue_depth: int | None = None,
                 publish_interval_s: float | None = None,
                 backend: str | None = None,
                 pad_batches: bool = True,
                 prewarm: bool = True,
                 monitor: Monitor | None = None,
                 replanner: Replanner | None = None):
        plan = getattr(service, "plan", None)
        # telemetry defaults to the service's monitor so the pipeline channels
        # (queue depth / flush cause / sojourn) land next to the tier samples
        self.monitor = monitor if monitor is not None \
            else getattr(service, "monitor", None)
        self.replanner = replanner
        if replanner is not None:
            replanner.pipeline = self     # replan swaps reach the flush knobs
            if publish_interval_s is None:
                # the replanner rides the maintenance cadence: make sure the
                # cadence thread exists even for a read-only plan
                publish_interval_s = replanner.interval_s
        if flush_threshold is None:
            flush_threshold = getattr(plan, "flush_threshold", None)
        if flush_threshold is None:
            flush_threshold = getattr(plan, "large_min", None)
        if flush_threshold is None:
            flush_threshold = DEFAULT_FLUSH_THRESHOLD
        if max_wait_us is None:
            max_wait_us = getattr(plan, "max_wait_us", None)
        if max_wait_us is None:
            max_wait_us = DEFAULT_MAX_WAIT_US
        if queue_depth is None:
            queue_depth = getattr(plan, "queue_depth", None)
        if queue_depth is None:
            queue_depth = DEFAULT_QUEUE_DEPTH_FLUSHES * int(flush_threshold)
        if publish_interval_s is None:
            publish_interval_s = _plan_publish_interval(plan)
        if flush_threshold < 1:
            raise ValueError(f"flush_threshold must be >= 1, got "
                             f"{flush_threshold!r}")
        if max_wait_us <= 0:
            raise ValueError(f"max_wait_us must be > 0, got {max_wait_us!r}")
        if queue_depth < flush_threshold:
            raise ValueError(f"queue_depth ({queue_depth}) must be >= "
                             f"flush_threshold ({flush_threshold}); a queue "
                             "that can never hold a full batch flushes only "
                             "on the deadline")
        if publish_interval_s is not None and publish_interval_s <= 0:
            raise ValueError(f"publish_interval_s must be > 0 (or None for "
                             f"no cadence), got {publish_interval_s!r}")

        self.service = service
        self.flush_threshold = int(flush_threshold)
        self.max_wait_us = float(max_wait_us)
        self.queue_depth = int(queue_depth)
        self.publish_interval_s = publish_interval_s
        self.backend = backend
        self.pad_batches = bool(pad_batches)

        # queue state: per-verb buckets so each flush fuses like with like
        # ("lookup" and each ("search", side) fuse separately -- a fused call
        # must be one service call).  All mutations under _lock; _space wakes
        # blocked submitters, _work wakes the flusher.
        self._lock = make_lock("AsyncIndexService._lock")
        self._space = threading.Condition(self._lock)
        self._work = threading.Condition(self._lock)
        self._buckets: dict[tuple, list[_Request]] = {}
        self._queued = 0                 # total queries across buckets
        self._oldest: float | None = None  # monotonic enqueue time of oldest
        self._closed = False
        self._fatal: BaseException | None = None

        # stats (under _lock)
        self._stats = {"flushes": 0, "threshold_flushes": 0,
                       "deadline_flushes": 0, "drain_flushes": 0,
                       "inline_batches": 0, "coalesced_queries": 0,
                       "max_fused_batch": 0, "publishes": 0,
                       "maintenance_ticks": 0, "compactions": 0}

        if prewarm:
            self.prewarm()

        self._stop_event = threading.Event()
        self._flusher = threading.Thread(target=self._flush_loop,
                                         name="index-pipeline-flush",
                                         daemon=True)
        self._flusher.start()
        self._maintenance = None
        if self.publish_interval_s is not None:
            self._maintenance = threading.Thread(
                target=self._maintenance_loop,
                name="index-pipeline-maintenance", daemon=True)
            self._maintenance.start()

    # ------------------------------------------------------------------ submit
    def lookup_async(self, queries, timeout: float | None = None) -> Future:
        """Queue a point-lookup batch; the Future resolves to the same ranks
        ``service.lookup(queries)`` would return (global ranks, -1 absent)."""
        return self._submit(("lookup",), queries, timeout)

    def search_async(self, queries, side: str = "left",
                     timeout: float | None = None) -> Future:
        """Queue an insertion-rank search (the query plane's primitive);
        resolves to ``service.search(queries, side)``."""
        if side not in ("left", "right"):
            raise ValueError(f"side must be 'left' or 'right', got {side!r}")
        return self._submit(("search", side), queries, timeout)

    def lookup(self, queries, timeout: float | None = None) -> np.ndarray:
        """Sync facade: submit and wait (``lookup_async(...).result()``)."""
        return self.lookup_async(queries, timeout).result(timeout)

    def search(self, queries, side: str = "left",
               timeout: float | None = None) -> np.ndarray:
        """Sync facade over :meth:`search_async`."""
        return self.search_async(queries, side, timeout).result(timeout)

    def _submit(self, kind: tuple, queries, timeout: float | None) -> Future:
        q = np.asarray(queries, np.float64)
        shape = q.shape
        q = np.atleast_1d(q).ravel()
        fut: Future = Future()
        if q.size == 0:
            fut.set_result(np.empty(shape, np.int64))
            return fut
        if q.size >= self.flush_threshold:
            # already a fast-tier batch on its own: run fused inline rather
            # than occupying the whole queue and delaying everyone else
            self._check_open()
            with self._lock:
                self._stats["inline_batches"] += 1
            if self.monitor is not None:
                self.monitor.record(CH_FLUSH, FLUSH_INLINE, int(q.size))
            try:
                fut.set_result(self._run(kind, q).reshape(shape))
            except BaseException as exc:  # surfaced via the future
                fut.set_exception(exc)
            return fut
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            self._raise_if_dead_locked()
            while self._queued + q.size > self.queue_depth:
                remaining = None
                if deadline is not None:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        raise PipelineOverloaded(
                            f"request queue full ({self._queued}/"
                            f"{self.queue_depth} queries) for {timeout:g}s; "
                            "the flusher is not keeping up with arrivals -- "
                            "raise queue_depth, lower max_wait_us, or shed "
                            "load")
                self._space.wait(remaining)
                self._raise_if_dead_locked()
            self._buckets.setdefault(kind, []).append(_Request(q, shape, fut))
            self._queued += q.size
            if self._oldest is None:
                self._oldest = time.monotonic()
                self._work.notify()   # arm the flusher's deadline timer
            if self._queued >= self.flush_threshold:
                self._work.notify()
        return fut

    # --------------------------------------------------------------- the flush
    def _run(self, kind: tuple, fused: np.ndarray) -> np.ndarray:
        """One fused service call.  ``pad_batches`` pads the fused batch to
        its power-of-two bucket (repeating the first query; the tail is
        sliced off) so the device backends see a *bounded set of shapes* --
        without it every distinct flush size is a fresh jit compile and
        prewarming could never cover the steady state."""
        n = fused.shape[0]
        if self.pad_batches:
            m = _bucket_size(n)
            if m > n:
                fused = np.concatenate(
                    [fused, np.full(m - n, fused[0], np.float64)])
        if kind[0] == "lookup":
            out = np.asarray(self.service.lookup(fused, self.backend),
                             np.int64)
        else:
            out = np.asarray(self.service.search(fused, kind[1], self.backend),
                             np.int64)
        return out[:n]

    def _take_batches(self) -> list[tuple[tuple, list[_Request]]]:
        """Under _lock: claim everything queued and reset the queue."""
        batches = [(k, reqs) for k, reqs in self._buckets.items() if reqs]
        self._buckets = {}
        self._queued = 0
        self._oldest = None
        if batches:
            self._space.notify_all()
        return batches

    def _flush(self, batches: list[tuple[tuple, list[_Request]]],
               cause: int = FLUSH_DRAIN) -> None:
        """Fuse each verb bucket into one service call; scatter per-caller
        slices back through the futures.  An exception fails exactly the
        futures of the batch that raised it.  ``cause`` is the flush-trigger
        code (:data:`FLUSH_THRESHOLD`/`FLUSH_DEADLINE`/`FLUSH_DRAIN`)
        recorded per fused bucket on the monitor, alongside each resolved
        request's sojourn (enqueue -> result) -- both off the caller path."""
        mon = self.monitor
        for kind, reqs in batches:
            fused = (reqs[0].queries if len(reqs) == 1
                     else np.concatenate([r.queries for r in reqs]))
            with self._lock:
                self._stats["flushes"] += 1
                self._stats["coalesced_queries"] += int(fused.size)
                self._stats["max_fused_batch"] = max(
                    self._stats["max_fused_batch"], int(fused.size))
            if mon is not None:
                mon.record(CH_FLUSH, cause, int(fused.size))
            try:
                out = self._run(kind, fused)
            except BaseException as exc:
                for r in reqs:
                    r.future.set_exception(exc)
                continue
            off = 0
            for r in reqs:
                n = r.queries.size
                r.future.set_result(out[off:off + n].reshape(r.shape))
                off += n
            if mon is not None:
                now = time.perf_counter_ns()
                for r in reqs:
                    mon.record(CH_SOJOURN, now - r.t_enq)

    def _flush_loop(self) -> None:
        try:
            while True:
                with self._lock:
                    cause = FLUSH_DRAIN
                    while True:
                        if self._closed:
                            break
                        now = time.monotonic()
                        if self._queued >= self.flush_threshold:
                            self._stats["threshold_flushes"] += 1
                            cause = FLUSH_THRESHOLD
                            break
                        if self._oldest is not None:
                            expires = self._oldest + self.max_wait_us * 1e-6
                            if now >= expires:
                                self._stats["deadline_flushes"] += 1
                                cause = FLUSH_DEADLINE
                                break
                            self._work.wait(expires - now)
                        else:
                            self._work.wait()
                    if self._closed:
                        return          # close() drains under its own lock
                    if self.monitor is not None:
                        self.monitor.record(CH_QUEUE_DEPTH, self._queued)
                    batches = self._take_batches()
                self._flush(batches, cause)
        except BaseException as exc:     # pragma: no cover - defensive
            self._record_fatal(exc)

    # ------------------------------------------------------------- maintenance
    def _maintenance_loop(self) -> None:
        """Periodic publish (no-op when clean) + the service's auto_rebalance
        check, off the request path.  A crash is fatal to the pipeline and
        re-raised to subsequent submitters and close()."""
        assert self.publish_interval_s is not None
        stop = self._stop_event
        last_epoch = getattr(self.service, "epoch", None)
        try:
            while not stop.wait(self.publish_interval_s):
                result = self.service.publish()
                compacted = 0
                if isinstance(result, dict):     # sharded: {sid: Snapshot};
                    did_publish = bool(result)   # lsm: maintenance summary
                    compacted = result.get("compacted", 0) \
                        if result else 0         # cadence-driven merges
                else:                            # IndexService: a Snapshot,
                    did_publish = result.epoch != last_epoch  # same on no-op
                    last_epoch = result.epoch
                with self._lock:
                    self._stats["maintenance_ticks"] += 1
                    if did_publish:
                        self._stats["publishes"] += 1
                    if compacted:
                        self._stats["compactions"] += compacted
                if self.replanner is not None:
                    # measured telemetry -> re-fit -> (maybe) hot-swap, all on
                    # this thread; rate-limited by the replanner's interval
                    self.replanner.step()
        except BaseException as exc:
            self._record_fatal(exc)

    def _record_fatal(self, exc: BaseException) -> None:
        with self._lock:
            if self._fatal is None:
                self._fatal = exc
            self._closed = True
            batches = self._take_batches()
            self._space.notify_all()
            self._work.notify_all()
        for _, reqs in batches:
            for r in reqs:
                r.future.set_exception(exc)

    # --------------------------------------------------------------- lifecycle
    def prewarm(self, backend: str | None = None) -> None:
        """Build and compile the serving engines before taking traffic (see
        ``ShardedIndexService.prewarm`` / ``DispatchEngine.prewarm``): the
        first coalesced flush then skips the jit/plan latency spike.
        Compilation happens at the threshold's batch bucket -- the exact
        shape a threshold flush dispatches (``pad_batches`` keeps the shape
        set bounded, so this one compile covers the steady state)."""
        sizes = (_bucket_size(self.flush_threshold),) if self.pad_batches \
            else (self.flush_threshold,)
        self.service.prewarm(backend or self.backend, batch_sizes=sizes)

    def publish(self):
        """Manual publish passthrough (the cadence thread's tick, on demand)."""
        return self.service.publish()

    # ---------------------------------------------------------- reconfiguring
    def apply_knobs(self, *, flush_threshold: int | None = None,
                    max_wait_us: float | None = None,
                    queue_depth: int | None = None) -> None:
        """Hot-swap the coalescing knobs (None keeps the current value).
        Validated together under the queue lock -- the same invariants as
        construction -- then both conditions wake: blocked submitters re-check
        the new depth, the flusher re-arms against the new threshold and
        deadline.  In-flight futures are untouched."""
        with self._lock:
            ft = (self.flush_threshold if flush_threshold is None
                  else int(flush_threshold))
            mw = self.max_wait_us if max_wait_us is None else float(max_wait_us)
            qd = self.queue_depth if queue_depth is None else int(queue_depth)
            if ft < 1:
                raise ValueError(f"flush_threshold must be >= 1, got {ft!r}")
            if mw <= 0:
                raise ValueError(f"max_wait_us must be > 0, got {mw!r}")
            if qd < ft:
                raise ValueError(f"queue_depth ({qd}) must be >= "
                                 f"flush_threshold ({ft})")
            self.flush_threshold, self.max_wait_us, self.queue_depth = \
                ft, mw, qd
            self._work.notify_all()
            self._space.notify_all()

    def apply_plan(self, plan: "IndexPlan", *, prewarm: bool = False) -> None:
        """Adopt a (re)planned configuration's pipeline knobs -- the
        ``Replanner`` swap path.  Missing plan knobs keep their current
        values; a plan that moves the threshold without pinning a depth gets
        ``DEFAULT_QUEUE_DEPTH_FLUSHES``x headroom (never shrinking the
        current depth below the new threshold's requirement).  The publish
        cadence re-resolves when the maintenance thread is running.  Pass
        ``prewarm=True`` to compile the new threshold's batch bucket before
        the next flush."""
        ft = plan.flush_threshold
        if ft is None:
            ft = plan.large_min
        qd = plan.queue_depth
        if qd is None and ft is not None:
            qd = max(self.queue_depth,
                     DEFAULT_QUEUE_DEPTH_FLUSHES * int(ft))
        self.apply_knobs(flush_threshold=ft, max_wait_us=plan.max_wait_us,
                         queue_depth=qd)
        if self._maintenance is not None:
            interval = _plan_publish_interval(plan)
            if interval is not None:
                self.publish_interval_s = interval  # read every cadence tick
        if prewarm:
            self.prewarm()

    @property
    def closed(self) -> bool:
        return self._closed

    def _check_open(self) -> None:
        with self._lock:
            self._raise_if_dead_locked()

    def _raise_if_dead_locked(self) -> None:
        if self._fatal is not None:
            raise PipelineClosed("pipeline maintenance died; see the "
                                 "cause") from self._fatal
        if self._closed:
            raise PipelineClosed("pipeline is closed")

    def pipeline_stats(self) -> dict:
        """Deprecated: use :meth:`metrics`\\ ``().pipeline``.  The legacy
        counter dict (flushes by trigger, fused batch sizes, knobs)."""
        warnings.warn("AsyncIndexService.pipeline_stats() is deprecated; "
                      "use metrics().pipeline", DeprecationWarning,
                      stacklevel=2)
        return dataclasses.asdict(self._pipeline_metrics())

    def _pipeline_metrics(self) -> PipelineMetrics:
        with self._lock:
            stats = dict(self._stats)
            queued = self._queued
        rp = self.replanner
        return PipelineMetrics(
            **stats, queued=queued, flush_threshold=self.flush_threshold,
            max_wait_us=self.max_wait_us, queue_depth=self.queue_depth,
            replans=0 if rp is None else rp.replans)

    def close(self, timeout: float = 10.0) -> None:
        """Drain queued requests (their futures complete), stop both threads,
        and re-raise the first maintenance/flush crash if one happened.
        Idempotent; safe to call from ``with``-exit after an error."""
        with self._lock:
            already = self._closed
            self._closed = True
            batches = self._take_batches()
            self._work.notify_all()
            self._space.notify_all()
            if batches:
                self._stats["drain_flushes"] += 1
        if batches:
            self._flush(batches)
        self._stop_event.set()
        if not already:
            self._flusher.join(timeout)
            if self._maintenance is not None:
                self._maintenance.join(timeout)
        if self._fatal is not None:
            raise PipelineClosed("pipeline maintenance died; see the "
                                 "cause") from self._fatal

    def __enter__(self) -> "AsyncIndexService":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # don't mask an in-flight exception with the close-time re-raise
        try:
            self.close()
        except PipelineClosed:
            if exc_type is None:
                raise

    # ----------------------------------------------------------- observability
    def metrics(self) -> ServiceMetrics:
        """The wrapped service's typed snapshot with the pipeline's counters
        and knobs attached as :class:`PipelineMetrics` -- the one
        observability surface for the whole serving stack."""
        return dataclasses.replace(self.service.metrics(),
                                   pipeline=self._pipeline_metrics())

    def service_stats(self) -> dict:
        """Deprecated: use :meth:`metrics`.  The wrapped service's legacy
        dict plus the pipeline counters, derived from the typed snapshot."""
        warnings.warn("AsyncIndexService.service_stats() is deprecated; "
                      "use metrics()", DeprecationWarning, stacklevel=2)
        m = self.metrics()
        return {"version": m.shard_set_version,
                "n_shards": m.n_shards,
                "imbalance": m.imbalance,
                "rebalances": m.rebalances,
                "rebalance_skipped": m.rebalance_skipped,
                "last_rebalance": m.last_rebalance,
                "pending_inserts": m.pending_inserts,
                "query_counts": m.query_counts,
                "pipeline": dataclasses.asdict(m.pipeline)}


def _bucket_size(n: int) -> int:
    """The power-of-two batch bucket ``n`` pads into (floor 16, so tiny
    deadline flushes share a handful of shapes instead of one each)."""
    return max(16, 1 << (int(n) - 1).bit_length())


def _plan_publish_interval(plan) -> float | None:
    """Resolve a plan's count-based publish cadence into a time period using
    the spec's expected insert rate: publish_every inserts at insert_rate
    inserts/s take publish_every/insert_rate seconds.  None when the plan has
    no cadence or no rate to resolve it against."""
    if plan is None or getattr(plan, "publish_every", None) is None:
        return None
    spec = getattr(plan, "spec", None)
    rate = getattr(spec, "insert_rate", 0.0) if spec is not None else 0.0
    if rate and rate > 0:
        return max(plan.publish_every / rate, 1e-3)
    return 1.0     # cadence requested but no rate hint: 1s ticks are cheap


def open_pipeline(keys, spec_or_plan: "FitSpec | IndexPlan", *,
                  payload: np.ndarray | None = None,
                  flush_threshold: int | None = None,
                  max_wait_us: float | None = None,
                  queue_depth: int | None = None,
                  publish_interval_s: float | None = None,
                  prewarm: bool = True,
                  replan_interval_s: float | None = None,
                  **service_kwargs) -> AsyncIndexService:
    """SLO-driven construction of the whole serving pipeline: resolve the
    spec (``fit.plan``), build the service (``fit.open_index``), and wrap it
    in the coalescing front door with the plan's pipeline knobs.  Extra
    ``service_kwargs`` pass through to the service constructor (notably
    ``monitor=Monitor()`` to turn telemetry on).  ``replan_interval_s``
    additionally attaches a :class:`repro.index.telemetry.Replanner` on the
    maintenance cadence (requires a monitor), closing the measure -> re-fit
    -> re-plan loop."""
    from .fit import open_index
    svc = open_index(keys, spec_or_plan, payload=payload, **service_kwargs)
    replanner = None
    if replan_interval_s is not None:
        replanner = Replanner(svc, interval_s=replan_interval_s)
    return AsyncIndexService(svc, flush_threshold=flush_threshold,
                             max_wait_us=max_wait_us, queue_depth=queue_depth,
                             publish_interval_s=publish_interval_s,
                             prewarm=prewarm, replanner=replanner)
