"""Publish-aware sharded serving: per-shard epochs over the unified core.

``ShardedIndexService`` owns N key-partitioned ``FITingTree`` writers -- the
paper's structure recursed once, with the replicated shard-boundary router
(:func:`repro.index.table.shard_boundaries`) as the top level.  Each shard has
its *own* write->publish->serve pipeline from ``repro.index.snapshot``:

    shard d:  FITingTree  --publish-->  Snapshot(epoch_d)  --install-->  handle_d

so epochs advance independently.  ``insert`` routes to the owning shard;
``publish`` re-segments and republishes **only dirty shards** (shards with
buffered inserts since their last publish), and each shard's ``ServingHandle``
swaps atomically -- a slow or write-hot shard never blocks reads on the
others, and a clean shard's epoch number is untouched by its neighbours'
publishes.

Reads return *global* ranks: shard runs are contiguous in key order, so a
query's global rank is its local rank plus the summed key counts of the
preceding shards' current snapshots.  Cross-shard reads are per-shard
consistent (each lookup pins one shard snapshot); a batch spanning shards may
observe different shards at different epochs -- exactly the contract the
per-shard publish cadence buys.

**Adaptive rebalancing.**  Boundaries are not frozen at construction: a
write-hot key range makes one shard grow without bound, its publishes get
slower, and its lookup windows dominate tail latency.  ``rebalance()``
detects skew from the write-side loads (keys per shard plus
``pending_weight``-scaled unpublished inserts, against ``skew_threshold``),
recuts duplicate-safe equal-count boundaries over the merged current key
view, migrates key runs (and payloads) between the ``FITingTree`` writers via
their ``extract_range``/``splice_run`` path, republishes every shard into
*fresh* serving handles, and swaps the whole routing view -- boundaries and
handles together -- as one immutable versioned :class:`ShardSet` with a
single reference assignment (the same discipline as
``ServingHandle.install``).  An in-flight lookup that pinned the old
``ShardSet`` keeps a fully consistent boundaries+snapshots view; it can never
mix old routing with new offsets.  Pass ``auto_rebalance=True`` to trigger
the check after every ``publish()``.

``stats()`` exposes per-shard observability (epoch, segment count, key count,
pending inserts, the routing cut *and* the installed snapshot's actual first
key) and ``service_stats()`` the service-level view (ShardSet version,
rebalance counters, current imbalance) for cadence tuning and dashboards.

``pack_shard_tables`` is the shared builder bridge: it pads a list of
per-shard ``SegmentTable``s into rectangular (D, S_max) metadata arrays, the
form both the collective-based device path (``repro.core.distributed``) and
any future multi-host serving tier consume.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
import warnings
from typing import TYPE_CHECKING, NamedTuple, Sequence

import numpy as np

from repro.analysis import sanitizer
from repro.analysis.contracts import hot_path
from repro.index.table import (SegmentTable, route_keys, shard_boundaries,
                               shard_partition)

from .query import PointResult, RangeResult, check_range, check_side
from .snapshot import ServingHandle, Snapshot, SnapshotPublisher
from .telemetry import (CH_PUBLISH, CH_QUERY_MIX, CH_REBALANCE,
                        CH_SERVED_KEYS, CH_SHARD_LOAD, CH_SKEW, Monitor,
                        ServiceMetrics, ShardMetrics, tier_metrics)

if TYPE_CHECKING:  # runtime import is lazy (fit builds services via plans)
    from .fit import IndexPlan

# every Nth lookup/search call contributes a key sample to the served-keys
# reservoir (CH_SERVED_KEYS); keeps the hot-path telemetry cost amortized
_KEY_SAMPLE_EVERY = 8
_KEY_SAMPLE_WIDTH = 64


def _inject_monitor(engine_opts: dict[str, dict],
                    monitor: Monitor | None) -> dict[str, dict]:
    """Thread the service's monitor into the dispatch-engine kwargs (the
    per-tier latency hook) without mutating the caller's / the plan's dict."""
    if monitor is None:
        return engine_opts
    opts = {k: dict(v) for k, v in (engine_opts or {}).items()}
    opts.setdefault("dispatch", {})["monitor"] = monitor
    return opts


class PackedShardTables(NamedTuple):
    """Rectangular (D, S_max) numpy form of D per-shard segment tables.

    Rows are padded so every shard routes correctly in isolation: start keys
    pad with +inf (never routed to -- searchsorted lands on the last real
    segment), slopes with 0, and base/seg_end with the shard's own key count
    (an empty trailing window).
    """
    seg_start: np.ndarray   # (D, S_max) f64, +inf padded
    slope: np.ndarray       # (D, S_max) f64, 0 padded
    base: np.ndarray        # (D, S_max) i64, n_keys padded
    seg_end: np.ndarray     # (D, S_max) i64, n_keys padded
    boundaries: np.ndarray  # (D,) f64 first key per shard (the router)
    s_max: int


def pack_shard_tables(tables: Sequence[SegmentTable]) -> PackedShardTables:
    """Pad per-shard segment metadata into the rectangular device layout.

    An *empty* shard inherits the next non-empty shard's first key as its
    boundary (it owns an empty key range just below its successor), keeping
    ``boundaries`` non-decreasing -- the ``route_keys`` precondition.  A bare
    +inf for a non-tail empty shard would break the sort and misroute every
    query at or above it.  Trailing empty shards keep +inf: no finite query
    ever routes to them.  A query equal to an inherited boundary routes to
    the *last* shard with that boundary (searchsorted side="right"), i.e. the
    non-empty owner."""
    d = len(tables)
    s_max = max(t.n_segments for t in tables)
    seg_start = np.full((d, s_max), np.inf, np.float64)
    slope = np.zeros((d, s_max), np.float64)
    base = np.empty((d, s_max), np.int64)
    seg_end = np.empty((d, s_max), np.int64)
    boundaries = np.empty((d,), np.float64)
    for i, t in enumerate(tables):
        s = t.n_segments
        seg_start[i, :s] = t.start_key
        slope[i, :s] = t.slope
        base[i, :s] = t.base
        base[i, s:] = t.n_keys
        seg_end[i, :s] = t.seg_end
        seg_end[i, s:] = t.n_keys
        boundaries[i] = t.keys[0] if t.n_keys else np.inf
    for i in range(d - 2, -1, -1):      # backfill empty interior boundaries
        if tables[i].n_keys == 0:
            boundaries[i] = boundaries[i + 1]
    # the packed form is a published view shared across device bridges:
    # freeze it like any snapshot so in-place edits raise at the write site
    return PackedShardTables(
        sanitizer.published_array(seg_start), sanitizer.published_array(slope),
        sanitizer.published_array(base), sanitizer.published_array(seg_end),
        sanitizer.published_array(boundaries), s_max)


@dataclasses.dataclass(frozen=True)
class ShardSet:
    """One immutable, versioned routing view: boundaries + serving handles.

    Published as a whole with a single reference assignment
    (``service._shard_set = ShardSet(...)``), mirroring
    ``ServingHandle.install``: a reader that pinned a ``ShardSet`` resolves
    routing, snapshots, and rank offsets against that one object, so a
    concurrent rebalance can never make it mix old boundaries with new
    handles (or vice versa).  Regular publishes reuse the current set's
    handles (boundaries are unchanged); a rebalance always builds fresh
    handles so retired sets keep serving their own epoch consistently."""
    version: int
    boundaries: np.ndarray               # (D,) f64 router cuts
    handles: tuple[ServingHandle, ...]   # one per shard, same order

    def __post_init__(self):
        # published = immutable: a reader that pinned this set must never see
        # its routing column change underneath it (freeze copies scratch views)
        object.__setattr__(self, "boundaries",
                           sanitizer.published_array(self.boundaries))


@dataclasses.dataclass(frozen=True)
class ShardStats:
    """One shard's observable serving state (a point-in-time sample).

    ``boundary`` is the *router* cut -- the first key routed to this shard
    under the current ``ShardSet`` (shard 0 also takes everything below it);
    this is the value that routes.  ``snapshot_first_key`` is the installed
    snapshot's actual first key, which drifts below/above the cut between
    publishes (inserts land by routing, so shard 0's snapshot can start
    below its cut) -- report both, dashboard the drift, trust ``boundary``
    for routing.  ``snapshot_first_key`` is NaN for an empty snapshot."""
    shard: int                # shard id (position in key order)
    boundary: float           # router cut (this one routes)
    epoch: int                # epoch of the shard's installed snapshot
    n_segments: int           # segments in the installed snapshot
    n_keys: int               # keys served by the installed snapshot
    pending_inserts: int      # inserts buffered since this shard's last publish
    snapshot_first_key: float = float("nan")  # installed snapshot's first key
    version: int = 1          # ShardSet version the sample was taken from


class ShardedIndexService:
    """N key-partitioned writable indexes, each with its own epoch stream.

    Construction partitions the (sorted) build keys into equal-count
    contiguous shards (:func:`shard_partition`; cuts snap to unique-key run
    starts and the tail stays in the last shard -- nothing is dropped) and
    publishes epoch 1 on every shard.  From then on writes and publishes are
    per-shard:

        svc = ShardedIndexService(keys, error=64, n_shards=8, buffer_size=16)
        svc.insert(k)          # routed to the owning shard, buffered (Alg. 4)
        svc.publish()          # republishes ONLY dirty shards; clean shards
                               # keep their snapshot and epoch number
        svc.lookup(q)          # global ranks, any engine backend
        svc.rebalance()        # recut boundaries if shard growth skewed

    ``backend`` may be any registered engine, including ``"dispatch"`` (the
    batch-size-aware tier router in ``repro.index.engine``).

    Construction is plan-first (see ``repro.index.fit``): pass ``plan=`` (an
    ``IndexPlan``, e.g. from ``fit.plan(keys, FitSpec(...))``) and the
    service takes its error / shard count / buffer / backend / publish
    cadence / dispatch thresholds from it; or pass the raw expert knobs,
    which are wrapped in a trivially-resolved plan so ``svc.plan`` always
    answers "what configuration is this service running?".
    :meth:`from_plan` is the classmethod form used by ``fit.open_index``.

    Rebalancing knobs: ``skew_threshold`` is the max/mean keys-per-shard
    ratio above which :meth:`rebalance` acts (:meth:`needs_rebalance`);
    ``pending_weight`` scales unpublished per-shard insert counts into the
    load metric (pressure forecast: a shard with heavy in-flight traffic is
    treated as still growing); ``auto_rebalance=True`` runs the check after
    every :meth:`publish`.
    """

    def __init__(self, keys: np.ndarray, error: int | None = None, *,
                 plan: "IndexPlan | None" = None, n_shards: int | None = None,
                 buffer_size: int | None = None,
                 payload: np.ndarray | None = None,
                 mode: str = "paper", backend: str | None = None,
                 engine_opts: dict[str, dict] | None = None,
                 publish_every: int | None = None,
                 skew_threshold: float = 2.0,
                 pending_weight: float = 1.0,
                 auto_rebalance: bool = False,
                 assume_sorted: bool = False,
                 monitor: Monitor | None = None):
        # lazy: repro.core.tree imports repro.index.table at module level
        from repro.core.tree import FITingTree
        from .fit import IndexPlan

        raw = {"error": error, "n_shards": n_shards,
               "buffer_size": buffer_size, "backend": backend,
               "publish_every": publish_every}
        if plan is None:
            if error is None:
                raise TypeError("pass error=... (expert knobs) or plan=... "
                                "(an IndexPlan from repro.index.fit)")
            plan = IndexPlan.from_knobs(
                error=error,
                n_shards=4 if n_shards is None else n_shards,
                buffer_size=0 if buffer_size is None else buffer_size,
                backend="numpy" if backend is None else backend,
                publish_every=publish_every)
        else:
            clashing = sorted(k for k, v in raw.items() if v is not None)
            if clashing:
                raise TypeError("pass either the raw knobs or plan=, not "
                                f"both -- the plan already fixes "
                                f"{', '.join(clashing)}")
        self.plan = plan
        error, n_shards = plan.error, plan.n_shards
        buffer_size, backend = plan.buffer_size, plan.backend
        publish_every = plan.publish_every
        self.monitor = monitor
        engine_opts = _inject_monitor(plan.merge_engine_opts(engine_opts),
                                      monitor)

        if publish_every is not None and buffer_size == 0:
            raise ValueError("publish_every requires buffer_size > 0 "
                             "(a read-only service never republishes)")
        if skew_threshold < 1.0:
            raise ValueError("skew_threshold must be >= 1.0 "
                             "(max/mean load ratio; 1.0 is perfectly even)")
        keys = np.asarray(keys, np.float64)
        if not assume_sorted:
            order = np.argsort(keys, kind="stable")
            keys = keys[order]
            if payload is not None:
                payload = np.asarray(payload)[order]

        self.error = int(error)
        self.buffer_size = int(buffer_size)
        self.default_backend = backend
        self.publish_every = publish_every
        self.has_payload = payload is not None
        self._mode = mode
        # serializes the mutators (insert/publish/rebalance/apply_plan);
        # re-entrant because insert -> publish -> rebalance nests, and a
        # Replanner swap may land while a cadence publish holds the lock.
        # Readers never take it: they pin the immutable ShardSet instead.
        self._write_lock = sanitizer.make_rlock(
            "ShardedIndexService._write_lock")
        self._sample_ctr = itertools.count()
        self.skew_threshold = float(skew_threshold)
        self.pending_weight = float(pending_weight)
        self.auto_rebalance = bool(auto_rebalance)
        self._engine_opts = engine_opts
        self._rebalances = 0
        self._rebalance_skipped = 0
        self._last_rebalance: dict | None = None
        # per-shape query counters (queries for point-shaped verbs, scans for
        # range, bound-pairs for count) -- see service_stats().  Guarded by a
        # lock: dict `+=` is a read-modify-write, and the async front door
        # (repro.index.pipeline) drives these verbs from many threads --
        # unlocked increments lose updates under that concurrency.
        self._counts_lock = sanitizer.make_lock(
            "ShardedIndexService._counts_lock")
        self._query_counts = {"points": 0, "ranges": 0, "counts": 0,
                              "predecessors": 0, "successors": 0,
                              "searches": 0}

        bounds, splits = shard_partition(keys, n_shards)
        offsets = np.concatenate(
            [[0], np.cumsum([s.shape[0] for s in splits])[:-1]]).astype(np.int64)
        self.writers = [
            FITingTree(split, error=error, buffer_size=buffer_size, mode=mode,
                       payload=(None if payload is None else
                                payload[offsets[d]:offsets[d] + split.shape[0]]),
                       assume_sorted=True)
            for d, split in enumerate(splits)]
        self.publishers = [SnapshotPublisher(t) for t in self.writers]
        handles = tuple(ServingHandle(engine_opts) for _ in self.writers)
        self._pending = [0] * n_shards
        for pub, handle in zip(self.publishers, handles):
            handle.install(pub.publish())     # epoch 1 everywhere
        self._shard_set = ShardSet(version=1, boundaries=bounds,
                                   handles=handles)

    @classmethod
    def from_plan(cls, keys: np.ndarray, plan: "IndexPlan", *,
                  payload: np.ndarray | None = None,
                  **service_kwargs) -> "ShardedIndexService":
        """Build from a resolved :class:`repro.index.fit.IndexPlan` (the
        ``fit.open_index`` path).  ``service_kwargs`` are the serving-policy
        knobs the plan does not fix (``skew_threshold``, ``pending_weight``,
        ``auto_rebalance``, ``mode``, ``engine_opts``, ``assume_sorted``)."""
        return cls(keys, plan=plan, payload=payload, **service_kwargs)

    # ------------------------------------------------------------------ shape
    def _pin_shard_set(self) -> ShardSet:
        """THE read-path pin: one reference read of the live routing view.
        Every query verb goes through here exactly once per operation (RI002)
        and reports the pinned version to the sanitizer's PinTracker, which
        asserts no verb mixes two ShardSet versions end-to-end."""
        ss = self._shard_set
        sanitizer.observe_pin(ss.version)
        return ss

    @property
    def n_shards(self) -> int:
        return len(self.writers)

    @property
    def shard_set(self) -> ShardSet:
        """The current immutable routing view (pin it for consistency)."""
        return self._shard_set

    @property
    def boundaries(self) -> np.ndarray:
        """Router cuts of the current ShardSet (first key per shard)."""
        return self._shard_set.boundaries

    @property
    def handles(self) -> tuple[ServingHandle, ...]:
        """Serving handles of the current ShardSet (one per shard)."""
        return self._shard_set.handles

    @property
    def pending_inserts(self) -> int:
        """Total inserts buffered across shards since their last publishes."""
        return sum(self._pending)

    def shard_of(self, key: float) -> int:
        """The shard owning ``key`` (route through the boundary router)."""
        return int(route_keys(self._shard_set.boundaries, np.float64(key)))

    def epochs(self) -> list[int]:
        """Current epoch per shard (independent streams)."""
        return [h.epoch for h in self._shard_set.handles]

    def metrics(self) -> ServiceMetrics:
        """The typed observability snapshot (:class:`repro.index.telemetry.
        ServiceMetrics`): ShardSet version, served plan revision, rebalance
        counters, current write-side imbalance, per-shape query counters
        (``points`` covers ``lookup``/``point``, ``ranges`` counts scans,
        ``counts`` counts bound pairs, ``searches`` the raw primitive -- for
        checking a deployed ``FitSpec.range_fraction`` against reality), one
        :class:`ShardMetrics` row per shard (epoch, size, pending writes,
        routing cut, snapshot first key, write-side load) and -- when a
        monitor is attached -- the measured per-tier cost profile."""
        ss = self._shard_set
        loads = self.shard_loads()
        with self._counts_lock:
            counts = dict(self._query_counts)
        shards = []
        for d, (handle, pend) in enumerate(zip(ss.handles, self._pending)):
            snap = handle.current()
            first = float(snap.table.keys[0]) if snap.n_keys else float("nan")
            shards.append(ShardMetrics(
                shard=d, boundary=float(ss.boundaries[d]), epoch=snap.epoch,
                n_segments=snap.table.n_segments, n_keys=snap.n_keys,
                pending_inserts=pend, snapshot_first_key=first,
                load=float(loads[d]) if d < loads.size else 0.0))
        return ServiceMetrics(
            service="sharded", shard_set_version=ss.version,
            plan_revision=self.plan.revision, n_shards=self.n_shards,
            imbalance=self.imbalance(), rebalances=self._rebalances,
            rebalance_skipped=self._rebalance_skipped,
            last_rebalance=self._last_rebalance,
            pending_inserts=self.pending_inserts, query_counts=counts,
            shards=tuple(shards), tiers=tier_metrics(self.monitor))

    def stats(self) -> list[ShardStats]:
        """Deprecated: use :meth:`metrics`\\ ``().shards``.  Per-shard
        observability sample in the legacy ``ShardStats`` shape."""
        warnings.warn("ShardedIndexService.stats() is deprecated; use "
                      "metrics().shards", DeprecationWarning, stacklevel=2)
        m = self.metrics()
        return [ShardStats(shard=s.shard, boundary=s.boundary, epoch=s.epoch,
                           n_segments=s.n_segments, n_keys=s.n_keys,
                           pending_inserts=s.pending_inserts,
                           snapshot_first_key=s.snapshot_first_key,
                           version=m.shard_set_version)
                for s in m.shards]

    def service_stats(self) -> dict:
        """Deprecated: use :meth:`metrics`.  The legacy service-level dict,
        derived field-for-field from the typed snapshot."""
        warnings.warn("ShardedIndexService.service_stats() is deprecated; "
                      "use metrics()", DeprecationWarning, stacklevel=2)
        m = self.metrics()
        return {"version": m.shard_set_version,
                "n_shards": m.n_shards,
                "imbalance": m.imbalance,
                "rebalances": m.rebalances,
                "rebalance_skipped": m.rebalance_skipped,
                "last_rebalance": m.last_rebalance,
                "pending_inserts": m.pending_inserts,
                "query_counts": m.query_counts}

    def _count(self, shape: str, n: int) -> None:
        """Atomic query-counter bump (verbs run concurrently under the async
        front door; an unlocked ``dict +=`` would lose updates)."""
        with self._counts_lock:
            self._query_counts[shape] += n

    def prewarm(self, backend: str | None = None,
                batch_sizes: Sequence[int] | None = None) -> None:
        """Build (and, for device backends, compile) every shard's engine for
        ``backend`` before serving traffic -- called by the async pipeline on
        start so the first coalesced batch skips the lazy plan/compile spike.
        ``batch_sizes`` are the batch shapes to compile at (jit caches are
        shape-specialized); with several shards a fused batch splits by
        routing, so the per-shard shapes are exact only for one shard --
        prewarm then still pays the per-tier compile for the common shapes.
        Engines without a ``prewarm`` (custom registered backends) are just
        built."""
        backend = backend or self.default_backend
        for handle in self._shard_set.handles:
            eng = handle.engine(backend)
            warm = getattr(eng, "prewarm", None)
            if warm is not None:
                warm(batch_sizes=batch_sizes)

    # ------------------------------------------------------------- write path
    def insert(self, key: float, value=None) -> None:
        """Buffer an insert in the owning shard (Alg. 4).  Invisible to
        lookups until that shard publishes."""
        if self.buffer_size == 0:
            raise ValueError("service built read-only; pass buffer_size > 0 "
                             "to enable inserts")
        if value is not None and not self.has_payload:
            raise ValueError("service built without payloads (clustered "
                             "index); pass payload= at construction to store "
                             "values")
        with self._write_lock:
            sid = self.shard_of(key)
            self.writers[sid].insert(key, value)
            self._pending[sid] += 1
            if self.publish_every is not None and \
                    self.pending_inserts >= self.publish_every:
                self.publish()

    def _shard_dirty(self, sid: int) -> bool:
        """Unpublished writes on shard ``sid``: service-routed inserts,
        direct writer inserts still in Alg. 4 buffers, or direct inserts
        already merged into pages (visible as a key-count drift between the
        writer and the installed snapshot)."""
        return (self._pending[sid] > 0
                or bool(self.writers[sid].dirty_segments())
                or self.writers[sid].n_keys
                != self._shard_set.handles[sid].current().n_keys)

    def publish(self, shards: Sequence[int] | None = None,
                force: bool = False) -> dict[int, Snapshot]:
        """Cut a new epoch on every dirty shard; leave clean shards untouched.

        A shard is dirty when it has unpublished writes -- whether routed
        through :meth:`insert` or applied directly to its ``FITingTree``
        writer.  Pass ``shards`` to restrict the sweep, ``force=True`` to
        republish clean shards too (cadence-loop safe either way: with
        nothing dirty this is a no-op returning ``{}``).  Returns the newly
        installed snapshots keyed by shard id.

        With ``auto_rebalance=True`` a skew check runs after the sweep and
        may recut boundaries (see :meth:`rebalance`); a recut that is
        impossible (fewer distinct keys than shards) is skipped and counted
        in ``service_stats()['rebalance_skipped']``.
        """
        with self._write_lock:
            t0 = time.perf_counter_ns()
            ss = self._shard_set
            targets = range(self.n_shards) if shards is None else shards
            published: dict[int, Snapshot] = {}
            for sid in targets:
                if not force and not self._shard_dirty(sid):
                    continue
                snap = self.publishers[sid].publish()
                ss.handles[sid].install(snap)
                self._pending[sid] = 0
                published[sid] = snap
            if self.auto_rebalance and published and self.needs_rebalance():
                try:
                    self.rebalance()
                except ValueError:   # < n_shards distinct keys: no safe recut
                    self._rebalance_skipped += 1
            if published and self.monitor is not None:
                self._record_publish(len(published),
                                     time.perf_counter_ns() - t0)
            return published

    def _record_publish(self, n_published: int, wall_ns: int) -> None:
        """Publish-cadence telemetry: duration, skew, per-shard load, and the
        cumulative query-shape mix (the Replanner's range-fraction input)."""
        mon = self.monitor
        mon.record(CH_PUBLISH, n_published, wall_ns)
        mon.record(CH_SKEW, self.imbalance())
        for d, load in enumerate(self.shard_loads()):
            mon.record(CH_SHARD_LOAD, d, float(load))
        # copy under the lock, record after releasing it: Monitor.record
        # takes Monitor._make_lock, which ranks *above* _counts_lock in
        # contracts.LOCK_ORDER -- recording while holding the counter lock
        # is exactly the inversion the runtime watchdog exists to catch
        with self._counts_lock:
            c = dict(self._query_counts)
        mon.record(CH_QUERY_MIX, c["points"], c["ranges"], c["counts"],
                   c["predecessors"], c["successors"], c["searches"])

    # ------------------------------------------------------------- rebalance
    def shard_loads(self) -> np.ndarray:
        """Write-side load per shard: the writer's current key count (pages +
        Alg. 4 buffers) plus ``pending_weight`` x its unpublished service
        inserts -- the pending term forecasts continued pressure on a
        write-hot shard before its next publish."""
        loads = np.array([w.n_keys for w in self.writers], np.float64)
        return loads + self.pending_weight * np.asarray(self._pending,
                                                        np.float64)

    def imbalance(self) -> float:
        """Max/mean of :meth:`shard_loads` (1.0 = perfectly even)."""
        loads = self.shard_loads()
        mean = float(loads.mean())
        return float(loads.max() / mean) if mean > 0 else 1.0

    def needs_rebalance(self) -> bool:
        """True when the load imbalance exceeds ``skew_threshold``."""
        return self.n_shards > 1 and self.imbalance() > self.skew_threshold

    def rebalance(self, force: bool = False) -> dict | None:
        """Recut shard boundaries to equal counts and migrate the key runs.

        No-op (returns ``None``) when balanced, unless ``force=True``.
        Otherwise: flush every writer, recut duplicate-safe equal-count
        boundaries over the merged current key view (raises ``ValueError``
        when the view has fewer distinct keys than shards), move the key
        runs that changed owner between writers via
        ``extract_range``/``splice_run`` (payloads travel with their keys),
        republish every shard into *fresh* serving handles, and publish the
        new routing view atomically as the next :class:`ShardSet` version.
        Readers never block: an in-flight lookup keeps the old set, whose
        retired snapshots still serve their own epochs correctly.

        Returns a summary dict (also kept in ``service_stats()``):
        version, keys moved, and the imbalance before/after.
        """
        with self._write_lock:
            return self._rebalance_locked(force)

    def _rebalance_locked(self, force: bool) -> dict | None:
        if self.n_shards == 1:
            return None
        before = self.imbalance()
        if not force and before <= self.skew_threshold:
            return None
        t0 = time.perf_counter_ns()
        ss = self._shard_set    # one pinned read, reused through the swap
        for w in self.writers:
            w.flush()
        merged = np.concatenate([w.as_table().keys for w in self.writers])
        new_bounds = shard_boundaries(merged, self.n_shards)
        if not force and np.array_equal(new_bounds, ss.boundaries):
            # the recut cannot help (duplicate-snapped cuts already match the
            # current ones): nothing would move, so skip the churn of
            # republishing every shard; counted for observability
            self._rebalance_skipped += 1
            return None

        n = self.n_shards
        moves_k: list[list[np.ndarray]] = [[] for _ in range(n)]
        moves_p: list[list[np.ndarray]] = [[] for _ in range(n)]
        moved = 0
        for d, w in enumerate(self.writers):
            parts = []
            if d > 0:                # keys now owned by an earlier shard
                parts.append(w.extract_range(-np.inf, new_bounds[d]))
            if d + 1 < n:            # keys now owned by a later shard
                parts.append(w.extract_range(new_bounds[d + 1], np.inf))
            for part_k, part_p in parts:
                if part_k.shape[0] == 0:
                    continue
                tgt = route_keys(new_bounds, part_k)
                for t in np.unique(tgt):
                    sel = tgt == t
                    moves_k[t].append(part_k[sel])
                    if part_p is not None:
                        moves_p[t].append(part_p[sel])
                    moved += int(sel.sum())
        for t in range(n):
            if not moves_k[t]:
                continue
            run = np.concatenate(moves_k[t])
            pl = np.concatenate(moves_p[t]) if moves_p[t] else None
            order = np.argsort(run, kind="stable")
            self.writers[t].splice_run(run[order],
                                       None if pl is None else pl[order])

        new_handles = tuple(ServingHandle(self._engine_opts)
                            for _ in self.writers)
        for pub, handle in zip(self.publishers, new_handles):
            handle.install(pub.publish())
        new_set = ShardSet(version=ss.version + 1, boundaries=new_bounds,
                           handles=new_handles)
        # the swap: one reference assignment publishes boundaries + handles
        self._shard_set = new_set
        self._pending = [0] * n
        self._rebalances += 1
        self._last_rebalance = {
            "version": new_set.version, "moved_keys": moved,
            "imbalance_before": before, "imbalance_after": self.imbalance()}
        if self.monitor is not None:
            self.monitor.record(CH_REBALANCE, moved,
                                time.perf_counter_ns() - t0)
        return self._last_rebalance

    # ------------------------------------------------------------- replanning
    def apply_plan(self, new_plan: "IndexPlan", *,
                   reshard: bool = True) -> "IndexPlan":
        """Hot-swap the served configuration to ``new_plan`` (a
        ``plan.replace(...)`` revision -- the ``Replanner`` path, also usable
        directly).  Never tears a reader: every path ends in a single
        reference assignment of a fresh versioned :class:`ShardSet`, exactly
        the rebalance discipline, so an in-flight lookup keeps serving its
        pinned view.

        Threshold/backend-only changes are *lightweight*: fresh serving
        handles with the new engine opts (new dispatch cut-overs, new
        monitor-threaded tiers) are installed over the **current snapshots**
        -- no re-segmentation, no epoch reset.  A change to ``error`` /
        ``buffer_size`` / (with ``reshard=True``) ``n_shards`` is
        *structural*: writers are flushed, the merged key+payload view is
        re-partitioned and re-segmented under the new knobs, and every shard
        restarts its epoch stream at 1 (the shard count clamps to the
        distinct-key count, like construction).  Returns the plan actually
        served (``svc.plan``), which reflects any clamping."""
        with self._write_lock:
            # preserve caller-supplied engine opts, but let the new plan's
            # dispatch thresholds win over the old plan's stale ones
            base = {k: dict(v)
                    for k, v in (self._engine_opts or {}).items()}
            disp = base.get("dispatch")
            if disp is not None:
                for k in ("small_max", "large_min", "monitor"):
                    disp.pop(k, None)
            engine_opts = _inject_monitor(new_plan.merge_engine_opts(base),
                                          self.monitor)
            structural = (int(new_plan.error) != self.error
                          or int(new_plan.buffer_size) != self.buffer_size
                          or (reshard
                              and int(new_plan.n_shards) != self.n_shards))
            if structural:
                new_plan = self._rebuild(new_plan, engine_opts, reshard)
            else:
                ss = self._shard_set
                handles = tuple(ServingHandle(engine_opts)
                                for _ in ss.handles)
                for old, new in zip(ss.handles, handles):
                    new.install(old.current())
                self._shard_set = ShardSet(version=ss.version + 1,
                                           boundaries=ss.boundaries,
                                           handles=handles)
                if new_plan.n_shards != self.n_shards:
                    new_plan = dataclasses.replace(new_plan,
                                                   n_shards=self.n_shards)
            self.plan = new_plan
            self.error = int(new_plan.error)
            self.buffer_size = int(new_plan.buffer_size)
            self.default_backend = new_plan.backend
            self.publish_every = (new_plan.publish_every
                                  if new_plan.buffer_size > 0 else None)
            self._engine_opts = engine_opts
            return self.plan

    def _rebuild(self, new_plan: "IndexPlan", engine_opts: dict,
                 reshard: bool) -> "IndexPlan":
        """Structural re-open under the write lock: merge every writer's
        current keys (+payloads), re-partition, re-segment with the new
        error/buffer, publish epoch 1 everywhere, swap one fresh ShardSet."""
        from repro.core.tree import FITingTree
        for w in self.writers:
            w.flush()
        keys = np.concatenate([w.as_table().keys for w in self.writers])
        payload = (np.concatenate([w.payload_column()
                                   for w in self.writers])
                   if self.has_payload else None)
        n_shards = int(new_plan.n_shards) if reshard else self.n_shards
        if keys.size == 0:
            n_shards = 1
        elif n_shards > 1:           # same clamp as shard_partition's safety
            distinct = 1 + int(np.count_nonzero(np.diff(keys) != 0))
            n_shards = max(1, min(n_shards, distinct))
        error = int(new_plan.error)
        buffer_size = int(new_plan.buffer_size)
        bounds, splits = shard_partition(keys, n_shards)
        offsets = np.concatenate(
            [[0], np.cumsum([s.shape[0] for s in splits])[:-1]]
        ).astype(np.int64)
        writers = [
            FITingTree(split, error=error, buffer_size=buffer_size,
                       mode=self._mode,
                       payload=(None if payload is None else
                                payload[offsets[d]:offsets[d]
                                        + split.shape[0]]),
                       assume_sorted=True)
            for d, split in enumerate(splits)]
        publishers = [SnapshotPublisher(t) for t in writers]
        handles = tuple(ServingHandle(engine_opts) for _ in writers)
        for pub, handle in zip(publishers, handles):
            handle.install(pub.publish())     # epoch 1 everywhere (restart)
        version = self._shard_set.version + 1
        self.writers = writers
        self.publishers = publishers
        self._pending = [0] * n_shards
        # the swap: readers pin either the old complete view or this one
        self._shard_set = ShardSet(version=version, boundaries=bounds,
                                   handles=handles)
        if n_shards != new_plan.n_shards:
            new_plan = dataclasses.replace(new_plan, n_shards=n_shards)
        return new_plan

    # -------------------------------------------------------------- read path
    def lookup(self, queries, backend: str | None = None) -> np.ndarray:
        """Global rank of each query across the current shard snapshots, -1
        if absent.  Queries are routed to their owning shard and answered by
        that shard's engine; local ranks are lifted to global ranks with the
        preceding shards' snapshot key counts.

        The ``ShardSet`` is pinned once (a single reference read), then all
        shard engines are pinned from it up front, so the routing, the
        offsets and the answers come from one self-consistent view even if a
        publish or rebalance lands mid-batch (engines are cached per snapshot
        per backend inside each handle, so pinning is an O(1) dict hit after
        the first call)."""
        backend = backend or self.default_backend
        self._count("points", int(np.size(queries)))
        self._sample_keys(queries)
        with sanitizer.pin_scope("lookup"):
            ss = self._pin_shard_set()              # pin the routing view
            if len(ss.handles) == 1:                # the IndexService path
                return ss.handles[0].lookup(queries, backend)
            engines = [h.engine(backend) for h in ss.handles]
            q = np.asarray(queries, np.float64)
            sid = route_keys(ss.boundaries, q)
            sizes = [e.table.n_keys for e in engines]
            offsets = np.concatenate([[0],
                                      np.cumsum(sizes)[:-1]]).astype(np.int64)
            out = np.full(q.shape, -1, np.int64)
            for d in np.unique(sid):
                mask = sid == d
                local = np.asarray(engines[d].lookup(q[mask]), np.int64)
                out[mask] = np.where(local >= 0, local + offsets[d], -1)
            return out

    # ------------------------------------------------------ typed query plane
    def _pin_view(self, backend: str | None):
        """Pin ONE consistent read view: the current ShardSet, plus each
        shard's (snapshot, engine) resolved from the same per-handle pin, so
        routing, rank offsets, materialized keys/payloads and answers all
        come from a single epoch combination -- a concurrent publish or
        rebalance can never tear a scan that already pinned its view."""
        backend = backend or self.default_backend
        ss = self._pin_shard_set()
        states = [h._pin() for h in ss.handles]
        engines = [h._engine_from(st, backend)
                   for h, st in zip(ss.handles, states)]
        snaps = [st[0] for st in states]
        sizes = np.asarray([s.n_keys for s in snaps], np.int64)
        offsets = np.concatenate([[0], np.cumsum(sizes)[:-1]]).astype(np.int64)
        return ss, snaps, engines, offsets, int(sizes.sum())

    def _search_view(self, view, queries, side: str) -> np.ndarray:
        """Global insertion ranks against a pinned view: route each query,
        bounded-search its shard, lift by the preceding snapshot key counts.
        Exact because shard cuts are duplicate-safe: no run straddles a
        shard, so local searchsorted + offset == global searchsorted."""
        ss, _, engines, offsets, _ = view
        q = np.asarray(queries, np.float64)
        sid = route_keys(ss.boundaries, q)
        out = np.empty(q.shape, np.int64)
        for d in np.unique(sid):
            mask = sid == d
            out[mask] = np.asarray(engines[d].search(q[mask], side),
                                   np.int64) + offsets[d]
        return out

    def search(self, queries, side: str = "left",
               backend: str | None = None) -> np.ndarray:
        """Global ``searchsorted(all_keys, queries, side)`` insertion ranks
        across the current shard snapshots (the query plane's primitive)."""
        check_side(side)
        self._count("searches", int(np.size(queries)))
        self._sample_keys(queries)
        with sanitizer.pin_scope("search"):
            return self._search_view(self._pin_view(backend), queries, side)

    @hot_path
    def _sample_keys(self, queries) -> None:
        """Contribute every ``_KEY_SAMPLE_EVERY``-th call's leading queries
        to the served-keys reservoir -- the Replanner's re-plan key set.  One
        attribute read + None check when no monitor is attached."""
        mon = self.monitor
        if mon is not None and next(self._sample_ctr) % _KEY_SAMPLE_EVERY == 0:
            q = np.asarray(queries, np.float64).ravel()
            mon.record_many(CH_SERVED_KEYS, q[:_KEY_SAMPLE_WIDTH])

    def point(self, queries, backend: str | None = None) -> PointResult:
        """Typed membership: global leftmost rank + found flag per query."""
        with sanitizer.pin_scope("point"):
            view = self._pin_view(backend)
            _, _, engines, offsets, _ = view
            ss = view[0]
            q = np.asarray(queries, np.float64)
            self._count("points", int(q.size))
            sid = route_keys(ss.boundaries, q)
            rank = np.full(q.shape, -1, np.int64)
            found = np.zeros(q.shape, bool)
            for d in np.unique(sid):
                mask = sid == d
                res = engines[d].point(q[mask])
                found[mask] = res.found
                rank[mask] = np.where(res.found, res.rank + offsets[d], -1)
            return PointResult(rank=rank, found=found)

    def count(self, lo, hi, backend: str | None = None) -> np.ndarray:
        """Keys in the inclusive ``[lo, hi]`` ranges (vectorized), resolved
        against one pinned view so both bounds see the same epochs."""
        with sanitizer.pin_scope("count"):
            view = self._pin_view(backend)
            lo = np.asarray(lo, np.float64)
            hi = np.asarray(hi, np.float64)
            counts = np.maximum(self._search_view(view, hi, "right")
                                - self._search_view(view, lo, "left"), 0)
            self._count("counts", int(counts.size))
            return counts.astype(np.int64)

    def range(self, lo, hi, *, materialize: bool = True,
              backend: str | None = None) -> RangeResult:
        """Inclusive ``[lo, hi]`` scan stitched across shards: the span may
        start mid-shard A and end mid-shard D; per-shard local spans lift to
        one global ``[lo_rank, hi_rank)`` via the pinned snapshot key counts,
        and materialized keys (and payloads, for a non-clustered index)
        concatenate in shard order -- all against the one pinned ShardSet,
        so a concurrent rebalance never tears the scan."""
        lo, hi = check_range(lo, hi)
        with sanitizer.pin_scope("range"):
            return self._range_pinned(lo, hi, materialize=materialize,
                                      backend=backend)

    def _range_pinned(self, lo, hi, *, materialize: bool,
                      backend: str | None) -> RangeResult:
        view = self._pin_view(backend)
        ss, snaps, engines, offsets, _ = view
        self._count("ranges", 1)
        lo_rank = int(self._search_view(view, np.asarray([lo]), "left")[0])
        hi_rank = max(int(self._search_view(view, np.asarray([hi]),
                                            "right")[0]), lo_rank)
        keys = payload = None
        if materialize:
            d0 = int(route_keys(ss.boundaries, np.float64(lo)))
            d1 = int(route_keys(ss.boundaries, np.float64(hi)))
            k_parts, p_parts = [], []
            for d in range(d0, d1 + 1):
                n_d = snaps[d].n_keys
                a = max(int(lo_rank - offsets[d]), 0) if d == d0 else 0
                b = min(int(hi_rank - offsets[d]), n_d) if d == d1 else n_d
                if b <= a:
                    continue
                k_parts.append(snaps[d].table.keys[a:b])
                if snaps[d].payload is not None:
                    p_parts.append(snaps[d].payload[a:b])
            keys = (np.concatenate(k_parts) if k_parts
                    else np.empty(0, np.float64))
            if self.has_payload:
                payload = (np.concatenate(p_parts) if p_parts
                           else np.empty(0))
        return RangeResult(lo=lo, hi=hi, lo_rank=lo_rank, hi_rank=hi_rank,
                           keys=keys, payload=payload)

    def predecessor(self, queries, backend: str | None = None) -> PointResult:
        """Global rank of the largest key <= each query (rightmost
        occurrence), found=False where every key is above the query."""
        with sanitizer.pin_scope("predecessor"):
            view = self._pin_view(backend)
            q = np.asarray(queries, np.float64)
            self._count("predecessors", int(q.size))
            rank = self._search_view(view, q, "right") - 1
            found = rank >= 0
            return PointResult(rank=np.where(found, rank, -1), found=found)

    def successor(self, queries, backend: str | None = None) -> PointResult:
        """Global rank of the smallest key >= each query (leftmost
        occurrence), found=False where every key is below the query."""
        with sanitizer.pin_scope("successor"):
            view = self._pin_view(backend)
            q = np.asarray(queries, np.float64)
            self._count("successors", int(q.size))
            rank = self._search_view(view, q, "left")
            found = rank < view[4]
            return PointResult(rank=np.where(found, rank, -1), found=found)
