"""Publish-aware sharded serving: per-shard epochs over the unified core.

``ShardedIndexService`` owns N key-partitioned ``FITingTree`` writers -- the
paper's structure recursed once, with the replicated shard-boundary router
(:func:`repro.index.table.shard_boundaries`) as the top level.  Each shard has
its *own* write->publish->serve pipeline from ``repro.index.snapshot``:

    shard d:  FITingTree  --publish-->  Snapshot(epoch_d)  --install-->  handle_d

so epochs advance independently.  ``insert`` routes to the owning shard;
``publish`` re-segments and republishes **only dirty shards** (shards with
buffered inserts since their last publish), and each shard's ``ServingHandle``
swaps atomically -- a slow or write-hot shard never blocks reads on the
others, and a clean shard's epoch number is untouched by its neighbours'
publishes.

Reads return *global* ranks: shard runs are contiguous in key order, so a
query's global rank is its local rank plus the summed key counts of the
preceding shards' current snapshots.  Cross-shard reads are per-shard
consistent (each lookup pins one shard snapshot); a batch spanning shards may
observe different shards at different epochs -- exactly the contract the
per-shard publish cadence buys.

``stats()`` exposes per-shard observability (epoch, segment count, key count,
pending inserts) for cadence tuning and dashboards.

``pack_shard_tables`` is the shared builder bridge: it pads a list of
per-shard ``SegmentTable``s into rectangular (D, S_max) metadata arrays, the
form both the collective-based device path (``repro.core.distributed``) and
any future multi-host serving tier consume.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import numpy as np

from repro.index.table import SegmentTable, route_keys, shard_partition

from .snapshot import ServingHandle, Snapshot, SnapshotPublisher


class PackedShardTables(NamedTuple):
    """Rectangular (D, S_max) numpy form of D per-shard segment tables.

    Rows are padded so every shard routes correctly in isolation: start keys
    pad with +inf (never routed to -- searchsorted lands on the last real
    segment), slopes with 0, and base/seg_end with the shard's own key count
    (an empty trailing window).
    """
    seg_start: np.ndarray   # (D, S_max) f64, +inf padded
    slope: np.ndarray       # (D, S_max) f64, 0 padded
    base: np.ndarray        # (D, S_max) i64, n_keys padded
    seg_end: np.ndarray     # (D, S_max) i64, n_keys padded
    boundaries: np.ndarray  # (D,) f64 first key per shard (the router)
    s_max: int


def pack_shard_tables(tables: Sequence[SegmentTable]) -> PackedShardTables:
    """Pad per-shard segment metadata into the rectangular device layout."""
    d = len(tables)
    s_max = max(t.n_segments for t in tables)
    seg_start = np.full((d, s_max), np.inf, np.float64)
    slope = np.zeros((d, s_max), np.float64)
    base = np.empty((d, s_max), np.int64)
    seg_end = np.empty((d, s_max), np.int64)
    boundaries = np.empty((d,), np.float64)
    for i, t in enumerate(tables):
        s = t.n_segments
        seg_start[i, :s] = t.start_key
        slope[i, :s] = t.slope
        base[i, :s] = t.base
        base[i, s:] = t.n_keys
        seg_end[i, :s] = t.seg_end
        seg_end[i, s:] = t.n_keys
        boundaries[i] = t.keys[0] if t.n_keys else np.inf
    return PackedShardTables(seg_start, slope, base, seg_end, boundaries, s_max)


@dataclasses.dataclass(frozen=True)
class ShardStats:
    """One shard's observable serving state (a point-in-time sample)."""
    shard: int            # shard id (position in key order)
    boundary: float       # first key routed here (shard 0 also takes below)
    epoch: int            # epoch of the shard's installed snapshot
    n_segments: int       # segments in the installed snapshot
    n_keys: int           # keys served by the installed snapshot
    pending_inserts: int  # inserts buffered since this shard's last publish


class ShardedIndexService:
    """N key-partitioned writable indexes, each with its own epoch stream.

    Construction partitions the (sorted) build keys into equal-count
    contiguous shards (:func:`shard_partition`; the tail stays in the last
    shard -- nothing is dropped) and publishes epoch 1 on every shard.  From
    then on writes and publishes are per-shard:

        svc = ShardedIndexService(keys, error=64, n_shards=8, buffer_size=16)
        svc.insert(k)          # routed to the owning shard, buffered (Alg. 4)
        svc.publish()          # republishes ONLY dirty shards; clean shards
                               # keep their snapshot and epoch number
        svc.lookup(q)          # global ranks, any engine backend

    ``backend`` may be any registered engine, including ``"dispatch"`` (the
    batch-size-aware tier router in ``repro.index.engine``).
    """

    def __init__(self, keys: np.ndarray, error: int, *, n_shards: int = 4,
                 buffer_size: int = 0, payload: np.ndarray | None = None,
                 mode: str = "paper", backend: str = "numpy",
                 engine_opts: dict[str, dict] | None = None,
                 publish_every: int | None = None,
                 assume_sorted: bool = False):
        # lazy: repro.core.tree imports repro.index.table at module level
        from repro.core.tree import FITingTree

        if publish_every is not None and buffer_size == 0:
            raise ValueError("publish_every requires buffer_size > 0 "
                             "(a read-only service never republishes)")
        keys = np.asarray(keys, np.float64)
        if not assume_sorted:
            order = np.argsort(keys, kind="stable")
            keys = keys[order]
            if payload is not None:
                payload = np.asarray(payload)[order]

        self.error = int(error)
        self.buffer_size = int(buffer_size)
        self.default_backend = backend
        self.publish_every = publish_every
        self.has_payload = payload is not None

        self.boundaries, splits = shard_partition(keys, n_shards)
        offsets = np.concatenate(
            [[0], np.cumsum([s.shape[0] for s in splits])[:-1]]).astype(np.int64)
        self.writers = [
            FITingTree(split, error=error, buffer_size=buffer_size, mode=mode,
                       payload=(None if payload is None else
                                payload[offsets[d]:offsets[d] + split.shape[0]]),
                       assume_sorted=True)
            for d, split in enumerate(splits)]
        self.publishers = [SnapshotPublisher(t) for t in self.writers]
        self.handles = [ServingHandle(engine_opts) for _ in self.writers]
        self._pending = [0] * n_shards
        for pub, handle in zip(self.publishers, self.handles):
            handle.install(pub.publish())     # epoch 1 everywhere

    # ------------------------------------------------------------------ shape
    @property
    def n_shards(self) -> int:
        return len(self.writers)

    @property
    def pending_inserts(self) -> int:
        """Total inserts buffered across shards since their last publishes."""
        return sum(self._pending)

    def shard_of(self, key: float) -> int:
        """The shard owning ``key`` (route through the boundary router)."""
        return int(route_keys(self.boundaries, np.float64(key)))

    def epochs(self) -> list[int]:
        """Current epoch per shard (independent streams)."""
        return [h.epoch for h in self.handles]

    def stats(self) -> list[ShardStats]:
        """Per-shard observability sample: epoch, size, pending writes."""
        out = []
        for d, (handle, pend) in enumerate(zip(self.handles, self._pending)):
            snap = handle.current()
            out.append(ShardStats(
                shard=d, boundary=float(self.boundaries[d]), epoch=snap.epoch,
                n_segments=snap.table.n_segments, n_keys=snap.n_keys,
                pending_inserts=pend))
        return out

    # ------------------------------------------------------------- write path
    def insert(self, key: float, value=None) -> None:
        """Buffer an insert in the owning shard (Alg. 4).  Invisible to
        lookups until that shard publishes."""
        if self.buffer_size == 0:
            raise ValueError("service built read-only; pass buffer_size > 0 "
                             "to enable inserts")
        if value is not None and not self.has_payload:
            raise ValueError("service built without payloads (clustered "
                             "index); pass payload= at construction to store "
                             "values")
        sid = self.shard_of(key)
        self.writers[sid].insert(key, value)
        self._pending[sid] += 1
        if self.publish_every is not None and \
                self.pending_inserts >= self.publish_every:
            self.publish()

    def _shard_dirty(self, sid: int) -> bool:
        """Unpublished writes on shard ``sid``: service-routed inserts,
        direct writer inserts still in Alg. 4 buffers, or direct inserts
        already merged into pages (visible as a key-count drift between the
        writer and the installed snapshot)."""
        return (self._pending[sid] > 0
                or bool(self.writers[sid].dirty_segments())
                or self.writers[sid].n_keys != self.handles[sid].current().n_keys)

    def publish(self, shards: Sequence[int] | None = None,
                force: bool = False) -> dict[int, Snapshot]:
        """Cut a new epoch on every dirty shard; leave clean shards untouched.

        A shard is dirty when it has unpublished writes -- whether routed
        through :meth:`insert` or applied directly to its ``FITingTree``
        writer.  Pass ``shards`` to restrict the sweep, ``force=True`` to
        republish clean shards too (cadence-loop safe either way: with
        nothing dirty this is a no-op returning ``{}``).  Returns the newly
        installed snapshots keyed by shard id.
        """
        targets = range(self.n_shards) if shards is None else shards
        published: dict[int, Snapshot] = {}
        for sid in targets:
            if not force and not self._shard_dirty(sid):
                continue
            snap = self.publishers[sid].publish()
            self.handles[sid].install(snap)
            self._pending[sid] = 0
            published[sid] = snap
        return published

    # -------------------------------------------------------------- read path
    def lookup(self, queries, backend: str | None = None) -> np.ndarray:
        """Global rank of each query across the current shard snapshots, -1
        if absent.  Queries are routed to their owning shard and answered by
        that shard's engine; local ranks are lifted to global ranks with the
        preceding shards' snapshot key counts.

        All shard engines are pinned up front, so the offsets and the answers
        come from one self-consistent set of snapshots even if a publish
        lands mid-batch (engines are cached per snapshot per backend inside
        each handle, so pinning is an O(1) dict hit after the first call)."""
        backend = backend or self.default_backend
        if self.n_shards == 1:                      # the IndexService path
            return self.handles[0].lookup(queries, backend)
        engines = [h.engine(backend) for h in self.handles]
        q = np.asarray(queries, np.float64)
        sid = route_keys(self.boundaries, q)
        sizes = [e.table.n_keys for e in engines]
        offsets = np.concatenate([[0], np.cumsum(sizes)[:-1]]).astype(np.int64)
        out = np.full(q.shape, -1, np.int64)
        for d in np.unique(sid):
            mask = sid == d
            local = np.asarray(engines[d].lookup(q[mask]), np.int64)
            out[mask] = np.where(local >= 0, local + offsets[d], -1)
        return out
