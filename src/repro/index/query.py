"""The typed query plane: point / range / count / predecessor / successor.

The paper's clustered page layout makes the index a *rank oracle over a
sorted key column* -- which answers far more than point membership: the
predecessor search that locates a rank also locates the start of a range
scan, and two of them bound any ``[lo, hi]`` span.  Until this module, that
machinery was stranded in legacy paths (``core/tree.range_query``,
``core/jax_index.range_count``) that bypassed the unified engine/snapshot/
sharded layers; now every verb derives from **one** backend primitive:

    search(queries, side)  ->  searchsorted(keys, queries, side) ranks

implemented per backend (numpy / xla-window / xla-bisect / pallas /
dispatch) as a bounded-window rank search -- the same interpolate-then-
bisect hot path as point lookups, generalized to both sides (see
``numpy_search`` / ``xla_search`` / ``pallas_search``).  The verbs here are
pure derivations, so all backends return identical answers by construction,
including duplicate runs and empty ranges:

    point(q)         rank of q's leftmost occurrence, found flag
    range(lo, hi)    global [lo_rank, hi_rank) span of the inclusive
                     [lo, hi] key range + optional materialized keys
    count(lo, hi)    hi_rank - lo_rank without materializing anything
    predecessor(q)   rank of the largest key <= q (rightmost occurrence)
    successor(q)     rank of the smallest key >= q (leftmost occurrence)

Boundary contract (the one all legacy paths now share): a range is
``[lo, hi]``-**inclusive**, resolved as the *leftmost* rank at ``lo``
(``side="left"``) and one past the *rightmost* rank at ``hi``
(``side="right"``), so duplicates of both endpoints are fully inside the
span; ``hi < lo`` and out-of-domain bounds degrade to empty spans, never
negative counts.

``QueryVerbs`` is mixed into every engine (``repro.index.engine``);
``ServingHandle``, ``IndexService`` and ``ShardedIndexService`` lift the
same verbs through snapshots and shards (the sharded form stitches
per-shard spans to global ranks, pinned to one ``ShardSet``).  This module
is numpy-only: no jax import, so the host path stays accelerator-free.
"""
from __future__ import annotations

import dataclasses

import numpy as np

SIDES = ("left", "right")


@dataclasses.dataclass(frozen=True)
class PointResult:
    """A batch of point-shaped answers (point / predecessor / successor).

    ``rank`` is the global rank of each answer key, -1 where ``found`` is
    False (absent key / no predecessor below the column / no successor
    above it).  For duplicated keys ``point`` and ``successor`` report the
    *leftmost* occurrence, ``predecessor`` the *rightmost* -- the occurrence
    nearest the query from its side."""
    rank: np.ndarray    # (Q,) i64, -1 where not found
    found: np.ndarray   # (Q,) bool

    @property
    def n_found(self) -> int:
        return int(self.found.sum())


@dataclasses.dataclass(frozen=True)
class RangeResult:
    """One inclusive ``[lo, hi]`` key-range scan over a snapshot.

    ``[lo_rank, hi_rank)`` is the global rank span (leftmost rank at ``lo``,
    one past the rightmost at ``hi``); ``count`` its length.  ``keys`` is
    the materialized sorted key run when the scan was issued with
    ``materialize=True`` (else None); ``payload`` the parallel payload run
    when the serving layer has a payload column (non-clustered index) --
    engines over a bare ``SegmentTable`` always return ``payload=None``."""
    lo: float
    hi: float
    lo_rank: int
    hi_rank: int
    keys: np.ndarray | None = None
    payload: np.ndarray | None = None

    @property
    def count(self) -> int:
        return self.hi_rank - self.lo_rank

    @property
    def empty(self) -> bool:
        return self.hi_rank <= self.lo_rank


def check_side(side: str) -> str:
    if side not in SIDES:
        raise ValueError(f"side must be 'left' or 'right', got {side!r}")
    return side


def check_range(lo, hi) -> tuple[float, float]:
    lo, hi = float(lo), float(hi)
    if np.isnan(lo) or np.isnan(hi):
        raise ValueError(f"range bounds must not be NaN, got [{lo}, {hi}]")
    return lo, hi


def merge_sorted_sources(parts_keys, parts_payload=None):
    """Stable k-way merge of per-source sorted key slices (the multi-level
    fan-in materializer: memtable + LSM runs, or any overlapping sources).

    Each element of ``parts_keys`` is a sorted array; the merged key column
    is globally sorted and, among *equal* keys, source order is preserved --
    pass sources newest-first and duplicates surface newest-first, the
    newest-level-wins contract the tiered write plane materializes ranges
    under.  ``parts_payload`` (parallel slices) rides the same permutation;
    returns ``(keys, payload-or-None)``."""
    keys = (np.concatenate([np.asarray(p, np.float64) for p in parts_keys])
            if parts_keys else np.empty(0, np.float64))
    order = np.argsort(keys, kind="stable")
    merged = keys[order]
    if parts_payload is None:
        return merged, None
    return merged, np.concatenate(parts_payload)[order]


class QueryVerbs:
    """Derives every typed verb from ``self.search(queries, side)``.

    Mixed into the engines (which also provide ``self.table``); any object
    with those two attributes gets the full query plane for free, and all
    implementations agree because there is nothing backend-specific left to
    disagree about."""

    def point(self, queries) -> PointResult:
        """Membership + leftmost rank: the typed form of ``lookup``."""
        q = np.asarray(queries, np.float64)
        rank = self.search(q, "left")
        keys = self.table.keys
        n = keys.shape[0]
        found = (rank < n) & (n > 0)
        if n > 0:
            found &= keys[np.minimum(rank, n - 1)] == q
        return PointResult(rank=np.where(found, rank, -1), found=found)

    def count(self, lo, hi) -> np.ndarray:
        """Keys in the inclusive ``[lo, hi]`` ranges (vectorized; broadcast
        ``lo``/``hi``).  Inverted or out-of-domain ranges count 0."""
        lo = np.asarray(lo, np.float64)
        hi = np.asarray(hi, np.float64)
        return np.maximum(self.search(hi, "right") - self.search(lo, "left"),
                          0).astype(np.int64)

    def range(self, lo, hi, *, materialize: bool = True) -> RangeResult:
        """Scan one inclusive ``[lo, hi]`` key range: global rank span plus
        (optionally) the materialized key run."""
        lo, hi = check_range(lo, hi)
        lo_rank = int(self.search(np.asarray([lo]), "left")[0])
        hi_rank = max(int(self.search(np.asarray([hi]), "right")[0]), lo_rank)
        keys = None
        if materialize:
            keys = self.table.keys[lo_rank:hi_rank].copy()
        return RangeResult(lo=lo, hi=hi, lo_rank=lo_rank, hi_rank=hi_rank,
                           keys=keys)

    def predecessor(self, queries) -> PointResult:
        """Rank of the largest key <= each query (rightmost occurrence),
        found=False where the whole column is above the query."""
        q = np.asarray(queries, np.float64)
        rank = self.search(q, "right") - 1
        found = rank >= 0
        return PointResult(rank=np.where(found, rank, -1), found=found)

    def successor(self, queries) -> PointResult:
        """Rank of the smallest key >= each query (leftmost occurrence),
        found=False where the whole column is below the query."""
        q = np.asarray(queries, np.float64)
        rank = self.search(q, "left")
        found = rank < self.table.n_keys
        return PointResult(rank=np.where(found, rank, -1), found=found)
