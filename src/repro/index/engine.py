"""`LookupEngine`: one bounded-window search implementation per backend.

The paper's hot path -- route, interpolate, binary-search the +-error window
-- used to be hand-rolled four times (host tree, XLA index, Pallas wrapper,
sharded serving).  It now exists exactly once per backend, behind a registry:

    numpy       host vectorized bounded bisect over the f64 key column
    xla-window  gather the 2e+2 window and compare-reduce (VPU friendly)
    xla-bisect  log2(2e) halving steps of single gathers (fewer bytes, big e)
    pallas      bucketed compare-reduce TPU kernel with XLA-bisect fallback

``make_engine(table, backend=...)`` returns an engine whose ``lookup`` maps a
query batch to global ranks (-1 if absent; the *leftmost* rank for duplicated
keys -- every backend snaps a hit whose left neighbour equals the query to
the run start, see ``snap_leftmost``, so ranks are segmentation-independent).
Every backend also implements the typed query plane's primitive
``search(queries, side="left"|"right")`` -- the same bounded-window machinery
generalized to insertion ranks (``np.searchsorted`` semantics, with
``snap_side`` repairing duplicate runs that extend past the window) -- from
which ``repro.index.query`` derives point / range / count / predecessor /
successor uniformly across backends.
Backends return identical ranks for any key column whose keys and queries
are exact in f32 (e.g. integer keys < 2^24, the serving regime -- see
rescale_keys): the ``numpy`` backend compares in f64 while the device
backends compare in f32, so a query that is only f32-equal to a stored key
can differ in membership across that boundary.  ``DeviceIndex`` is the f32 device form of a
``SegmentTable`` (re-exported by repro.core.jax_index for compatibility).
"""
from __future__ import annotations

import functools
import time
from typing import Callable, Literal, NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import hot_path
from repro.analysis.sanitizer import make_lock

from .query import QueryVerbs
from .table import SegmentTable, numpy_lookup, numpy_search


class DeviceIndex(NamedTuple):
    """f32/i32 device form of a SegmentTable (arrays VMEM/HBM friendly)."""
    seg_start: jax.Array  # (S,) f32  first key of each segment
    slope: jax.Array      # (S,) f32
    base: jax.Array       # (S,) i32  global position of segment start
    seg_end: jax.Array    # (S,) i32  one past the segment end
    keys: jax.Array       # (N,) f32  the sorted key column (HBM resident)
    error: int            # static


def device_index(table: SegmentTable) -> DeviceIndex:
    """Convert (and cache on the table -- snapshots are shared by engines)."""
    dev = getattr(table, "_device_cache", None)
    if dev is None:
        dev = DeviceIndex(
            seg_start=jnp.asarray(table.start_key, jnp.float32),
            slope=jnp.asarray(table.slope, jnp.float32),
            base=jnp.asarray(table.base, jnp.int32),
            seg_end=jnp.asarray(table.seg_end, jnp.int32),
            keys=jnp.asarray(table.keys, jnp.float32),
            error=int(table.error),
        )
        object.__setattr__(table, "_device_cache", dev)  # frozen dataclass
    return dev


# --------------------------------------------------------------------- device
def snap_leftmost(keys: jax.Array, queries: jax.Array, rank: jax.Array,
                  hit: jax.Array) -> jax.Array:
    """Snap duplicate hits to the leftmost occurrence (device mirror of the
    ``numpy_lookup`` fix): when a found rank's left neighbour still equals
    the query, the duplicate run straddles a segment boundary and the
    window search returned an in-segment rank.  ``lax.cond`` skips the
    full-column bisect entirely unless some query actually needs it, so the
    duplicate-free fast path pays one extra gather."""
    need = hit & (rank > 0) & (keys[jnp.maximum(rank - 1, 0)] == queries)
    fixed = jax.lax.cond(
        jnp.any(need),
        lambda: jnp.searchsorted(keys, queries, side="left").astype(rank.dtype),
        lambda: rank)
    return jnp.where(need, fixed, rank)


def snap_side(keys: jax.Array, queries: jax.Array, rank: jax.Array,
              side: str) -> jax.Array:
    """Side-generalized duplicate snap for insertion-rank searches (the
    ``search`` primitive): a bounded window parks inside a duplicate run that
    extends past it, which is detectable from the landing position alone --
    for ``side="left"`` the left neighbour still equals the query, for
    ``side="right"`` the landing key itself does.  ``lax.cond`` skips the
    full-column searchsorted unless some query actually needs it (the same
    fast-path discipline as :func:`snap_leftmost`)."""
    n = keys.shape[0]
    if side == "left":
        need = (rank > 0) & (keys[jnp.maximum(rank - 1, 0)] == queries)
    else:
        need = (rank < n) & (keys[jnp.minimum(rank, n - 1)] == queries)
    fixed = jax.lax.cond(
        jnp.any(need),
        lambda: jnp.searchsorted(keys, queries, side=side).astype(rank.dtype),
        lambda: rank)
    return jnp.where(need, fixed, rank)


def predict_positions(idx: DeviceIndex, queries: jax.Array) -> jax.Array:
    """Interpolated (approximate) global positions; error <= idx.error by Eq. 1.

    Device mirror of SegmentTable.predict: route, FMA, clamp into the owning
    segment's position range so inter-segment gap queries cannot overshoot."""
    sid = jnp.clip(jnp.searchsorted(idx.seg_start, queries, side="right") - 1,
                   0, idx.seg_start.shape[0] - 1)
    local = (queries - idx.seg_start[sid]) * idx.slope[sid]
    pred = idx.base[sid] + jnp.round(local).astype(jnp.int32)
    return jnp.clip(pred, idx.base[sid], idx.seg_end[sid])


def xla_lookup(idx: DeviceIndex, queries: jax.Array,
               strategy: Literal["window", "bisect"] = "window") -> jax.Array:
    """Batched point lookup, rank or -1.  jit-safe; ``error`` is static."""
    n = idx.keys.shape[0]
    pred = predict_positions(idx, queries)
    e = idx.error
    if strategy == "window":
        w = 2 * e + 2
        start = jnp.clip(pred - e, 0, jnp.maximum(n - w, 0)).astype(jnp.int32)
        offs = start[:, None] + jnp.arange(w, dtype=jnp.int32)[None, :]
        vals = idx.keys[jnp.minimum(offs, n - 1)]
        lt = (vals < queries[:, None]).sum(axis=1).astype(jnp.int32)
        rank = start + lt
        hit = (vals == queries[:, None]).any(axis=1)
        rank = snap_leftmost(idx.keys, queries, rank, hit)
        return jnp.where(hit, rank, -1)
    # bisect: lo/hi halving on the clipped window
    lo = jnp.clip(pred - e, 0, n).astype(jnp.int32)
    hi = jnp.clip(pred + e + 1, 0, n).astype(jnp.int32)
    steps = int(np.ceil(np.log2(2 * e + 2)))

    def body(_, lh):
        lo, hi = lh
        mid = (lo + hi) // 2
        v = idx.keys[jnp.minimum(mid, n - 1)]
        go = (v < queries) & (lo < hi)
        return jnp.where(go, mid + 1, lo), jnp.where(go, hi, mid)

    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    ok = (lo < n) & (idx.keys[jnp.minimum(lo, n - 1)] == queries)
    lo = snap_leftmost(idx.keys, queries, lo, ok)
    return jnp.where(ok, lo, -1)


def xla_search(idx: DeviceIndex, queries: jax.Array, side: str = "left",
               strategy: Literal["window", "bisect"] = "bisect") -> jax.Array:
    """Batched bounded-window rank search: the device mirror of
    :func:`repro.index.table.numpy_search` (f32 compares).  Returns the
    insertion rank of every query -- ``searchsorted(keys, q, side)`` -- via
    the interpolated +-error window; jit-safe, ``error``/``side``/``strategy``
    static.

    ``window`` counts the in-window keys strictly below (``side="left"``) or
    at-or-below (``side="right"``) each query; ``bisect`` runs log2(2e+2)
    halving steps with the side's comparison.  Both end with
    :func:`snap_side`, so duplicate runs extending past the window still
    resolve to the exact global rank."""
    if side not in ("left", "right"):
        raise ValueError(f"side must be 'left' or 'right', got {side!r}")
    n = idx.keys.shape[0]
    pred = predict_positions(idx, queries)
    e = idx.error
    if strategy == "window":
        w = 2 * e + 2
        start = jnp.clip(pred - e, 0, jnp.maximum(n - w, 0)).astype(jnp.int32)
        offs = start[:, None] + jnp.arange(w, dtype=jnp.int32)[None, :]
        valid = offs < n                       # clamped gathers replicate the
        vals = idx.keys[jnp.minimum(offs, n - 1)]  # last key: mask them out
        if side == "left":
            cmp = vals < queries[:, None]
        else:
            cmp = vals <= queries[:, None]
        rank = start + (valid & cmp).sum(axis=1).astype(jnp.int32)
        return snap_side(idx.keys, queries, rank, side)
    lo = jnp.clip(pred - e, 0, n).astype(jnp.int32)
    hi = jnp.clip(pred + e + 1, 0, n).astype(jnp.int32)
    steps = int(np.ceil(np.log2(2 * e + 2)))

    def body(_, lh):
        lo, hi = lh
        mid = (lo + hi) // 2
        v = idx.keys[jnp.minimum(mid, n - 1)]
        ok = (v < queries) if side == "left" else (v <= queries)
        go = ok & (lo < hi)
        return jnp.where(go, mid + 1, lo), jnp.where(go, hi, mid)

    lo, _ = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return snap_side(idx.keys, queries, lo, side)


# --------------------------------------------------------------------- pallas
def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


class LookupPlan(NamedTuple):
    """Static kernel geometry for a (N, error) pair."""
    kb: int         # key block size
    window: int     # 2*error + 2
    n_blocks: int
    n_pad: int


def make_plan(n_keys: int, error: int) -> LookupPlan:
    window = 2 * error + 2
    kb = max(128, _round_up(window, 128))
    n_pad = _round_up(max(n_keys, kb), kb)
    return LookupPlan(kb=kb, window=window, n_blocks=n_pad // kb, n_pad=n_pad)


def pad_keys(keys: jax.Array, plan: LookupPlan) -> jax.Array:
    pad = plan.n_pad - keys.shape[0]
    return jnp.pad(keys.astype(jnp.float32), (0, pad), constant_values=jnp.inf)


def _pallas_bucketize(idx: DeviceIndex, queries: jax.Array, plan: LookupPlan,
                      qcap: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """The XLA prelude shared by :func:`pallas_lookup` and
    :func:`pallas_search`: router + interpolation -> window starts -> queries
    bucketed by the key block their window starts in.  Returns ``(q_b,
    qlo_b, src_b)``: per-block query values (+inf filler), global window
    starts, and source indices (-1 filler; a query missing from ``src_b``
    overflowed its bucket and must be answered by the caller's fallback)."""
    nq = queries.shape[0]
    pred = predict_positions(idx, queries)
    qlo = jnp.clip(pred - idx.error, 0, plan.n_pad - plan.window).astype(jnp.int32)
    blk = qlo // plan.kb                                    # owning key block
    order = jnp.argsort(blk, stable=True)
    blk_s = blk[order]
    slot = jnp.arange(nq, dtype=jnp.int32) - jnp.searchsorted(
        blk_s, blk_s, side="left").astype(jnp.int32)        # rank within bucket
    ok = slot < qcap
    q_b = jnp.full((plan.n_blocks, qcap), jnp.inf, jnp.float32)
    qlo_b = jnp.zeros((plan.n_blocks, qcap), jnp.int32)
    src_b = jnp.full((plan.n_blocks, qcap), -1, jnp.int32)
    slot_c = jnp.where(ok, slot, qcap - 1)
    q_b = q_b.at[blk_s, slot_c].set(jnp.where(ok, queries[order], jnp.inf))
    qlo_b = qlo_b.at[blk_s, slot_c].set(jnp.where(ok, qlo[order], 0))
    src_b = src_b.at[blk_s, slot_c].set(jnp.where(ok, order.astype(jnp.int32), -1))
    return q_b, qlo_b, src_b


def pallas_lookup(idx: DeviceIndex, queries: jax.Array, *, qcap: int = 256,
                  interpret: bool = True, fallback: bool = True) -> jax.Array:
    """Batched point lookup via the Pallas kernel.  Returns ranks (-1 absent).

    XLA prelude (router + interpolation + bucketing) -> Pallas compare-reduce
    kernel -> scatter-back + bisect fallback for bucket overflow.  ``idx.error``
    must be a Python int (it sizes the kernel window), so jit this via a
    closure over ``idx`` rather than passing it as a traced argument."""
    # lazy: repro.kernels imports this module for its thin wrappers
    from repro.kernels.fitting_lookup import fitting_lookup_pallas

    plan = make_plan(int(idx.keys.shape[0]), int(idx.error))
    keys_padded = pad_keys(idx.keys, plan)
    nq = queries.shape[0]
    queries = queries.astype(jnp.float32)
    q_b, qlo_b, src_b = _pallas_bucketize(idx, queries, plan, qcap)

    # --- Pallas kernel over key blocks
    rank_b, found_b = fitting_lookup_pallas(
        keys_padded, q_b, qlo_b, kb=plan.kb, window=plan.window,
        interpret=interpret)

    # --- scatter back
    res = jnp.full((nq,), jnp.iinfo(jnp.int32).min, jnp.int32)
    flat_src = src_b.reshape(-1)
    flat_ans = jnp.where(found_b.reshape(-1), rank_b.reshape(-1), -1)
    good = flat_src >= 0
    res = res.at[jnp.clip(flat_src, 0, None)].max(
        jnp.where(good, flat_ans, jnp.iinfo(jnp.int32).min))
    answered = res > jnp.iinfo(jnp.int32).min
    res = jnp.where(answered, res, -1)

    if fallback:
        # bucket-overflow queries (never bucketed) answered by the XLA bisect
        # path; lax.cond skips the work entirely when nothing overflowed.
        was_bucketed = jnp.zeros((nq,), bool).at[jnp.clip(flat_src, 0, None)].max(good)
        need = ~was_bucketed
        fb = jax.lax.cond(jnp.any(need),
                          lambda: xla_lookup(idx, queries, "bisect"),
                          lambda: res)
        res = jnp.where(need, fb, res)
    return snap_leftmost(idx.keys, queries, res, res >= 0)


def pallas_search(idx: DeviceIndex, queries: jax.Array, side: str = "left", *,
                  qcap: int = 256, interpret: bool = True) -> jax.Array:
    """Batched insertion-rank search via the Pallas compare-reduce kernel.

    Same XLA prelude (router + interpolation + bucketing) and kernel geometry
    as :func:`pallas_lookup`; the kernel's masked compare-reduce simply counts
    with the side's comparison (``<`` for left, ``<=`` for right) so
    ``rank = window_start + count`` is the searchsorted insertion rank.
    Bucket-overflow queries fall back to the XLA bisect search; the final
    :func:`snap_side` resolves duplicate runs extending past the window."""
    if side not in ("left", "right"):
        raise ValueError(f"side must be 'left' or 'right', got {side!r}")
    # lazy: repro.kernels imports this module for its thin wrappers
    from repro.kernels.fitting_lookup import fitting_lookup_pallas

    plan = make_plan(int(idx.keys.shape[0]), int(idx.error))
    keys_padded = pad_keys(idx.keys, plan)
    nq = queries.shape[0]
    queries = queries.astype(jnp.float32)
    q_b, qlo_b, src_b = _pallas_bucketize(idx, queries, plan, qcap)

    rank_b, _ = fitting_lookup_pallas(
        keys_padded, q_b, qlo_b, kb=plan.kb, window=plan.window,
        interpret=interpret, side=side)

    res = jnp.full((nq,), jnp.iinfo(jnp.int32).min, jnp.int32)
    flat_src = src_b.reshape(-1)
    flat_ans = rank_b.reshape(-1)
    good = flat_src >= 0
    res = res.at[jnp.clip(flat_src, 0, None)].max(
        jnp.where(good, flat_ans, jnp.iinfo(jnp.int32).min))
    need = res == jnp.iinfo(jnp.int32).min       # bucket-overflow queries
    fb = jax.lax.cond(jnp.any(need),
                      lambda: xla_search(idx, queries, side, "bisect"),
                      lambda: res)
    res = jnp.where(need, fb, res)
    return snap_side(idx.keys, queries, res, side)


# ------------------------------------------------------------------- registry
@runtime_checkable
class LookupEngine(Protocol):
    """A compiled lookup path over one immutable SegmentTable snapshot.

    Every registered backend also implements the query plane's primitive
    ``search(queries, side)`` (insertion ranks) and, via the
    :class:`repro.index.query.QueryVerbs` mixin, the typed verbs derived
    from it (``point`` / ``range`` / ``count`` / ``predecessor`` /
    ``successor``)."""
    backend: str
    table: SegmentTable

    def lookup(self, queries) -> np.ndarray:
        """Global rank of each query, -1 if absent (host array out)."""
        ...

    def search(self, queries, side: str = "left") -> np.ndarray:
        """``searchsorted(keys, queries, side)`` insertion ranks (host array
        out): the one primitive every typed query verb derives from."""
        ...


_BACKENDS: dict[str, Callable[..., LookupEngine]] = {}


def register_backend(name: str):
    def deco(cls):
        cls.backend = name
        _BACKENDS[name] = cls
        return cls
    return deco


def available_backends() -> list[str]:
    return sorted(_BACKENDS)


def make_engine(table: SegmentTable, backend: str = "numpy", **opts) -> LookupEngine:
    """The one constructor every layer (ops, distributed, serving, benchmarks)
    goes through to get a lookup path."""
    try:
        cls = _BACKENDS[backend]
    except KeyError:
        raise ValueError(f"unknown backend {backend!r}; "
                         f"available: {available_backends()}") from None
    return cls(table, **opts)


def _prewarm_queries(table: SegmentTable, size: int) -> np.ndarray:
    """A representative warm-up batch: real keys cycled to ``size`` (real
    keys exercise the same routing/window paths production queries will)."""
    sample = np.asarray(table.keys[: min(table.n_keys, size)], np.float64)
    return np.resize(sample, size)


@register_backend("numpy")
class NumpyEngine(QueryVerbs):
    def __init__(self, table: SegmentTable):
        self.table = table
        self.fn = functools.partial(numpy_lookup, table)

    def lookup(self, queries) -> np.ndarray:
        return self.fn(queries)

    def search(self, queries, side: str = "left") -> np.ndarray:
        return numpy_search(self.table, queries, side)

    def prewarm(self, batch_sizes=None) -> None:
        """No-op: the host path has nothing to compile."""


class _DeviceEngine(QueryVerbs):
    """Shared scaffolding: convert the table once, jit a closure over it.

    ``self.fn`` is the jitted point-lookup; ``_search_impl(queries, side=)``
    is the backend's un-jitted search primitive, jitted lazily per side on
    first use (``side`` is static: it picks the comparison op)."""

    def __init__(self, table: SegmentTable):
        self.table = table
        self.index = device_index(table)
        self._search_fns: dict[str, Callable] = {}
        self._search_lock = make_lock("_DeviceEngine._search_lock")

    def lookup(self, queries) -> np.ndarray:
        if self.table.n_keys == 0:   # gathers on a 0-length device array are
            q = np.asarray(queries)  # undefined; an empty table always misses
            return np.full(q.shape, -1, np.int64)
        return np.asarray(self.fn(jnp.asarray(queries, jnp.float32)))

    def search(self, queries, side: str = "left") -> np.ndarray:
        if side not in ("left", "right"):
            raise ValueError(f"side must be 'left' or 'right', got {side!r}")
        if self.table.n_keys == 0:   # empty table: every rank is 0
            return np.zeros(np.asarray(queries).shape, np.int64)
        fn = self._search_fns.get(side)
        if fn is None:
            with self._search_lock:  # don't jit the same side twice
                fn = self._search_fns.get(side)
                if fn is None:
                    fn = jax.jit(functools.partial(self._search_impl,
                                                   side=side))
                    self._search_fns[side] = fn
        out = np.asarray(fn(jnp.asarray(queries, jnp.float32)))
        return out.astype(np.int64)

    def prewarm(self, batch_sizes=None) -> None:
        """Trace + compile the lookup and both search sides now, at the
        given batch sizes (jit caches are shape-specialized: a compile only
        helps batches of the same size).  Default one representative size."""
        if self.table.n_keys == 0:
            return
        for size in batch_sizes or (256,):
            q = _prewarm_queries(self.table, int(size))
            self.lookup(q)
            self.search(q, "left")
            self.search(q, "right")


@register_backend("xla-window")
class XlaWindowEngine(_DeviceEngine):
    def __init__(self, table: SegmentTable):
        super().__init__(table)
        self.fn = jax.jit(functools.partial(xla_lookup, self.index,
                                            strategy="window"))
        self._search_impl = functools.partial(xla_search, self.index,
                                              strategy="window")


@register_backend("xla-bisect")
class XlaBisectEngine(_DeviceEngine):
    def __init__(self, table: SegmentTable):
        super().__init__(table)
        self.fn = jax.jit(functools.partial(xla_lookup, self.index,
                                            strategy="bisect"))
        self._search_impl = functools.partial(xla_search, self.index,
                                              strategy="bisect")


@register_backend("pallas")
class PallasEngine(_DeviceEngine):
    def __init__(self, table: SegmentTable, *, qcap: int = 256,
                 interpret: bool = True, fallback: bool = True):
        super().__init__(table)
        self.fn = jax.jit(functools.partial(pallas_lookup, self.index,
                                            qcap=qcap, interpret=interpret,
                                            fallback=fallback))
        self._search_impl = functools.partial(pallas_search, self.index,
                                              qcap=qcap, interpret=interpret)


@register_backend("dispatch")
class DispatchEngine(QueryVerbs):
    """Batch-size-aware backend dispatch over one snapshot.

    The backends trade fixed cost against per-query cost: numpy wins for tiny
    probes (no device round trip), the XLA bisect wins for medium batches
    (log2(2e) gathers amortize the launch), and the Pallas plan/bucketing path
    wins for large fan-out (compare-reduce over VMEM-resident key blocks).
    ``DispatchEngine`` routes each ``lookup`` batch to the tier its size puts
    it in:

        size <= small_max          -> ``small``   (default numpy)
        small_max < size < large_min -> ``medium`` (default xla-bisect)
        size >= large_min          -> ``large``    (default pallas)

    Tier engines are built lazily on first use and cached for the lifetime of
    this engine (i.e. of the snapshot), so a serving handle swap retires them
    together with the table.  Every tier returns identical ranks for exact-f32
    workloads (see the module docstring), so dispatch is semantics-preserving.

    ``small_max``/``large_min`` default to ``None``: the thresholds are then
    derived from the Sec. 6 cost model for *this table's* error and segment
    count (:func:`repro.core.cost_model.dispatch_thresholds` -- the batch
    sizes where the modeled per-tier latency curves cross), so the breakpoints
    track the data instead of being magic constants.  Pass explicit values to
    pin them (e.g. from a measured sweep or an ``IndexPlan``).

    ``monitor`` (a ``repro.index.telemetry.Monitor``) turns on per-tier
    telemetry: every routed ``lookup``/``search`` records ``(batch_size,
    wall_ns)`` on the ``tier.<small|medium|large>`` channel, which is exactly
    the sample shape ``repro.core.cost_model.fit_tier_curves`` re-fits the
    tier cost curves from.  ``None`` (the default) keeps the hot path
    record-free.
    """

    def __init__(self, table: SegmentTable, *, small_max: int | None = None,
                 large_min: int | None = None, small: str = "numpy",
                 medium: str = "xla-bisect", large: str = "pallas",
                 engine_opts: dict[str, dict] | None = None,
                 monitor=None):
        if small_max is None and large_min is None:
            # lazy: keep jax-module import light; cost_model is numpy-only
            from repro.core.cost_model import dispatch_thresholds
            small_max, large_min = dispatch_thresholds(table.error,
                                                       table.n_segments)
        if small_max is None or large_min is None:
            raise ValueError("pass both small_max and large_min, or neither "
                             "(None defers both to the cost model)")
        if not 0 <= small_max < large_min:
            raise ValueError(f"need 0 <= small_max < large_min, got "
                             f"{small_max=} {large_min=}")
        for tier in (small, medium, large):
            if tier == "dispatch":
                raise ValueError("dispatch cannot delegate to itself")
        self.table = table
        self.small_max = int(small_max)
        self.large_min = int(large_min)
        self.tiers = {"small": small, "medium": medium, "large": large}
        self.monitor = monitor
        self._engine_opts = engine_opts or {}
        self._engines: dict[str, LookupEngine] = {}
        self._lock = make_lock("DispatchEngine._lock")

    def tier_for(self, batch_size: int) -> str:
        """The tier (``small``/``medium``/``large``) a batch routes to."""
        if batch_size <= self.small_max:
            return "small"
        if batch_size < self.large_min:
            return "medium"
        return "large"

    def backend_for(self, batch_size: int) -> str:
        """The tier backend a batch of ``batch_size`` queries dispatches to."""
        return self.tiers[self.tier_for(batch_size)]

    def engine_for(self, batch_size: int) -> LookupEngine:
        name = self.backend_for(batch_size)
        eng = self._engines.get(name)
        if eng is None:
            with self._lock:           # don't jit the same tier twice
                eng = self._engines.get(name)
                if eng is None:
                    eng = make_engine(self.table, name,
                                      **self._engine_opts.get(name, {}))
                    self._engines[name] = eng
        return eng

    @hot_path
    def lookup(self, queries) -> np.ndarray:
        n = int(np.size(queries))
        eng = self.engine_for(n)
        mon = self.monitor
        if mon is None:
            return eng.lookup(queries)
        t0 = time.perf_counter_ns()
        out = eng.lookup(queries)
        # channel name matches repro.index.telemetry.CH_TIER_PREFIX
        mon.record("tier." + self.tier_for(n), n, time.perf_counter_ns() - t0)
        return out

    @hot_path
    def search(self, queries, side: str = "left") -> np.ndarray:
        """The query plane's primitive, routed by batch size exactly like
        ``lookup`` (every tier returns identical insertion ranks for exact-f32
        workloads, so dispatch stays semantics-preserving)."""
        n = int(np.size(queries))
        eng = self.engine_for(n)
        mon = self.monitor
        if mon is None:
            return eng.search(queries, side)
        t0 = time.perf_counter_ns()
        out = eng.search(queries, side)
        mon.record("tier." + self.tier_for(n), n, time.perf_counter_ns() - t0)
        return out

    def prewarm(self, batch_sizes=None) -> None:
        """Opt-in eager tier construction + compilation.

        Tier engines are normally built lazily on first use, which makes the
        first large batch after a snapshot swap eat the Pallas/XLA
        plan-and-compile latency as a p99 spike.  ``prewarm`` pays that cost
        up front: for each batch size (default: one representative size per
        tier) the owning tier engine is built and its lookup/search paths
        compiled at exactly that shape.  Called by the async pipeline on
        start with its flush-bucket sizes."""
        if batch_sizes is None:
            batch_sizes = [self.large_min]
            if self.small_max >= 1:
                batch_sizes.append(self.small_max)
            if self.small_max + 1 < self.large_min:
                batch_sizes.append(self.small_max + 1)
        for size in batch_sizes:
            eng = self.engine_for(int(size))
            warm = getattr(eng, "prewarm", None)
            if warm is not None:
                warm(batch_sizes=(int(size),))
