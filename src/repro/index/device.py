"""Device-sharded serving plane: replicated router, ``shard_map`` fan-out,
delta epoch publish (ROADMAP open item 1).

The paper's recursive structure -- a tiny top-level router over per-partition
linear segments -- maps directly onto a device mesh: the shard-boundary
router is *replicated* (every device holds the (D,) cut column), each
device owns one shard's packed segment table and sorted key column, and the
two-sided bounded-window ``search`` primitive runs under ``shard_map`` with
one of two exchange strategies:

* ``"allgather"`` -- every device gathers the full query batch, answers it
  against its local shard, and a ``psum`` of the per-shard insertion ranks
  yields the exact global rank: over contiguous sorted shard runs,
  ``searchsorted(all_keys, q) == sum_d searchsorted(shard_d, q)``.  No
  ownership masks, duplicate-safe by construction, two collectives total.
* ``"a2a"`` -- queries are bucketed to their *owning* shard by the
  replicated router (duplicate-safe serving cuts guarantee
  owner-local rank + prefix offset == global rank), exchanged with
  ``all_to_all`` under a slack-capacity factor, answered locally, and
  exchanged back.  Bucket overflow beyond slack is **resolved inside the
  service** by a follow-up allgather pass over just the overflowed queries
  -- the dropped-query mask never leaks to callers.

``DeviceShardedService`` wraps the existing ``ShardedIndexService`` write
path (insert routing, Alg. 4 buffers, per-shard epoch publish, rebalance)
and installs snapshots onto devices as an immutable versioned
:class:`DeviceShardSet` -- the same single-reference-swap / pinned-reader
discipline as ``ShardSet`` and the LSM ``LevelSet``.  Publishes are **delta
uploads**: the manifest keeps per-shard epoch fingerprints, and a publish
that dirtied one shard re-transfers only that shard's padded table row via
``jax.device_put`` on the owning device; the clean D-1 rows' device buffers
are *reused* (same buffer identity) through
``jax.make_array_from_single_device_arrays``.  Rows are padded to capacity
(``s_cap``/``m_cap``, headroom over the current maxima) so steady-state
publishes stay delta-eligible and shape-stable (no jit retrace); cap
overflow or a boundary change (rebalance / structural replan) falls back to
a full re-pack with fresh headroom.

All five query verbs stay bit-identical to the numpy oracle under the f32
key contract (exact for f32-representable keys, e.g. integers < 2^24 --
the same contract as every device backend in ``repro.index.engine``).

Runs on CPU via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(see ``tests/_device_check.py``); the collectives are the same on real
accelerator meshes.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from functools import partial
from typing import TYPE_CHECKING, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.analysis import sanitizer
from repro.compat import shard_map as _shard_map
from repro.core.cost_model import choose_exchange
from repro.index.table import route_keys

from .engine import DeviceIndex, xla_search
from .query import PointResult, RangeResult, check_range, check_side
from .sharded import ShardedIndexService
from .snapshot import Snapshot
from .telemetry import (CH_DEVICE_COLLECTIVE, CH_DEVICE_OVERFLOW,
                        CH_DEVICE_PUBLISH, XCHG_A2A, XCHG_ALLGATHER,
                        DeviceMetrics, Monitor)

if TYPE_CHECKING:   # runtime import is lazy (fit builds services via plans)
    from .fit import IndexPlan

_EXCHANGES = ("allgather", "a2a", "auto")


# --------------------------------------------------------- shard_map kernels
def sharded_search_allgather(seg_start, slope, base, seg_end, keys, n_local,
                             queries, *, mesh: Mesh, axis: str = "data",
                             error: int, side: str = "left"):
    """Global insertion ranks by psum of per-shard local ranks.

    Each device all-gathers the query batch, runs the bounded-window
    ``xla_search`` against its (+inf padded) local shard, and a ``psum``
    sums the local ranks: shard runs are contiguous in key order, so the
    sum *is* the global ``searchsorted`` rank -- duplicate runs straddling
    a shard cut included (a sum needs no ownership decision).  Padded +inf
    keys are never counted for finite queries, so capacity padding is
    invisible to the answer."""
    @partial(_shard_map, mesh=mesh,
             in_specs=(P(axis, None), P(axis, None), P(axis, None),
                       P(axis, None), P(axis, None), P(axis), P(axis)),
             out_specs=P(axis))
    def impl(seg_start, slope, base, seg_end, keys, n_loc, q_local):
        me = jax.lax.axis_index(axis)
        q_all = jax.lax.all_gather(q_local, axis, tiled=True)     # (Q_total,)
        idx = DeviceIndex(seg_start[0], slope[0], base[0], seg_end[0],
                          keys[0], error)
        r = xla_search(idx, q_all, side, "bisect").astype(jnp.int32)
        r = jnp.where(n_loc[0] > 0, r, 0)       # empty-shard row: all padding
        total = jax.lax.psum(r, axis)
        q_per = q_local.shape[0]
        return jax.lax.dynamic_slice_in_dim(total, me * q_per, q_per)

    return impl(seg_start, slope, base, seg_end, keys, n_local, queries)


def sharded_search_a2a(seg_start, slope, base, seg_end, keys, n_local,
                       offsets, boundaries, queries, *, mesh: Mesh,
                       axis: str = "data", error: int, side: str = "left",
                       slack: float = 2.0):
    """Owner-bucketed ``all_to_all`` insertion-rank search.

    Each device routes its local queries through the replicated boundary
    router, slots them into D buckets of capacity ``ceil(Q/D^2 * slack)``
    (+inf sentinel padding), exchanges buckets, answers the queries it owns
    (local rank + its replicated prefix ``offsets`` entry == global rank,
    because serving cuts are duplicate-safe: no equal-key run straddles a
    shard), and reverses the exchange.  Returns ``(ranks, ok)`` where
    ``ok=False`` marks queries dropped by bucket overflow under skew --
    ``DeviceShardedService`` resolves those with a follow-up allgather pass
    so callers never see the mask."""
    d = mesh.shape[axis]
    q_per = queries.shape[0] // d
    cap = max(1, int(np.ceil(q_per / d * slack)))

    @partial(_shard_map, mesh=mesh,
             in_specs=(P(axis, None), P(axis, None), P(axis, None),
                       P(axis, None), P(axis, None), P(axis), P(), P(),
                       P(axis)),
             out_specs=(P(axis), P(axis)))
    def impl(seg_start, slope, base, seg_end, keys, n_loc, offs, bounds,
             q_local):
        me = jax.lax.axis_index(axis)
        idx = DeviceIndex(seg_start[0], slope[0], base[0], seg_end[0],
                          keys[0], error)
        owner = jnp.clip(jnp.searchsorted(bounds, q_local, side="right") - 1,
                         0, d - 1)
        # slot each query into its owner bucket via one stable sort
        order = jnp.argsort(owner, stable=True)
        sorted_owner = owner[order]
        rank_in_bkt = jnp.arange(q_local.shape[0]) - jnp.searchsorted(
            sorted_owner, sorted_owner, side="left")
        ok_sorted = rank_in_bkt < cap
        buckets = jnp.full((d, cap), jnp.inf, q_local.dtype)
        src_pos = jnp.full((d, cap), -1, jnp.int32)
        slot = jnp.clip(rank_in_bkt, 0, cap - 1)
        buckets = buckets.at[sorted_owner, slot].set(
            jnp.where(ok_sorted, q_local[order], jnp.inf))
        src_pos = src_pos.at[sorted_owner, slot].set(
            jnp.where(ok_sorted, order.astype(jnp.int32), -1))
        # exchange: after a2a, row j of `incoming` is what device j sent me
        incoming = jax.lax.all_to_all(buckets, axis, split_axis=0,
                                      concat_axis=0, tiled=True)
        flat = incoming.reshape(-1)
        r = xla_search(idx, flat, side, "bisect").astype(jnp.int32)
        r = jnp.where(n_loc[0] > 0, r, 0) + offs[me]
        back = jax.lax.all_to_all(r.reshape(d, cap), axis, split_axis=0,
                                  concat_axis=0, tiled=True).reshape(d, cap)
        # scatter answers back to original slots; sentinel slots carry
        # src_pos=-1 and contribute a harmless 0 to the max (ranks are >= 0)
        flat_src = src_pos.reshape(-1)
        good = flat_src >= 0
        result = jnp.zeros(q_local.shape, jnp.int32).at[
            jnp.clip(flat_src, 0, None)].max(
            jnp.where(good, back.reshape(-1), 0))
        okq = jnp.zeros(q_local.shape, bool).at[
            jnp.clip(flat_src, 0, None)].max(good)
        return result, okq

    return impl(seg_start, slope, base, seg_end, keys, n_local, offsets,
                boundaries, queries)


def sharded_lookup_allgather(seg_start, slope, base, seg_end, keys, n_local,
                             queries, *, mesh: Mesh, axis: str = "data",
                             error: int):
    """Point semantics over the allgather search kernel: leftmost rank where
    the key is present (``right > left``), -1 where absent.  Two collective
    rounds; the back-compat target for ``repro.core.distributed``."""
    args = (seg_start, slope, base, seg_end, keys, n_local, queries)
    kw = dict(mesh=mesh, axis=axis, error=error)
    left = sharded_search_allgather(*args, side="left", **kw)
    right = sharded_search_allgather(*args, side="right", **kw)
    return jnp.where(right > left, left, -1)


def sharded_lookup_a2a(seg_start, slope, base, seg_end, keys, n_local,
                       offsets, boundaries, queries, *, mesh: Mesh,
                       axis: str = "data", error: int, slack: float = 2.0):
    """Point semantics over the a2a search kernel; returns ``(ranks, ok)``
    with ``ok=False`` marking bucket-overflow drops (the legacy
    ``lookup_a2a`` contract -- the service path resolves the mask itself)."""
    args = (seg_start, slope, base, seg_end, keys, n_local, offsets,
            boundaries, queries)
    kw = dict(mesh=mesh, axis=axis, error=error, slack=slack)
    left, ok_l = sharded_search_a2a(*args, side="left", **kw)
    right, ok_r = sharded_search_a2a(*args, side="right", **kw)
    return jnp.where(right > left, left, -1), ok_l & ok_r


# ------------------------------------------------------------- the manifest
@dataclasses.dataclass(frozen=True)
class DeviceShardSet:
    """One immutable, versioned device-resident serving view.

    Published with a single reference assignment
    (``service._device_set = DeviceShardSet(...)``) and pinned once per
    verb, exactly the ``ShardSet`` discipline: a reader resolves routing,
    device arrays, rank offsets and host-side materialization against this
    one object, so a concurrent (delta) publish can never tear a batch.

    ``snapshots`` pins the host epoch each device row was packed from --
    the per-shard dirtiness fingerprint for delta publish (a host publish
    always installs a *new* ``Snapshot`` object) and the materialization
    source for ``range``.  ``s_cap``/``m_cap`` are the padded row
    capacities; rows are re-shipped in place while the new tables fit, so
    array shapes (and jit caches) are stable across delta publishes."""
    version: int
    host_version: int                   # ShardSet.version this was built from
    error: int
    n_keys: int                         # total keys served
    n_segments: int                     # total segments across shards
    s_cap: int                          # padded segment columns per row
    m_cap: int                          # padded key columns per row
    boundaries: np.ndarray              # (D,) f64 router cuts (host copy)
    offsets: np.ndarray                 # (D,) i64 global-rank prefix offsets
    snapshots: tuple[Snapshot, ...]     # pinned host snapshots, one per shard
    epochs: tuple[int, ...]             # per-shard epoch fingerprints
    d_seg_start: jax.Array              # (D, s_cap) f32 sharded, +inf padded
    d_slope: jax.Array                  # (D, s_cap) f32 sharded
    d_base: jax.Array                   # (D, s_cap) i32 sharded
    d_seg_end: jax.Array                # (D, s_cap) i32 sharded
    d_keys: jax.Array                   # (D, m_cap) f32 sharded, +inf padded
    d_n_local: jax.Array                # (D,) i32 sharded: live keys per row
    d_offsets: jax.Array                # (D,) i32 replicated prefix offsets
    d_boundaries: jax.Array             # (D,) f32 replicated router

    def __post_init__(self):
        # published = immutable: freeze the host-side columns a pinned
        # reader routes/lifts with (the device arrays are immutable already)
        object.__setattr__(self, "boundaries",
                           sanitizer.published_array(self.boundaries))
        object.__setattr__(self, "offsets",
                           sanitizer.published_array(self.offsets))

    @property
    def n_devices(self) -> int:
        return len(self.snapshots)

    def row_bytes(self) -> int:
        """Device-resident bytes of ONE shard row (sharded arrays only)."""
        return int(4 * self.s_cap * 4 + self.m_cap * 4 + 4)

    def replicated_bytes(self) -> int:
        """Bytes of the replicated router + offsets on ONE device."""
        return int(self.n_devices * (4 + 4))


def _pack_row(table, s_cap: int, m_cap: int):
    """One shard's padded device row: +inf start-key / key padding, 0 slope,
    n_keys base/seg_end (an empty trailing window) -- the
    ``pack_shard_tables`` scheme widened to capacity, in device dtypes."""
    s, n = table.n_segments, table.n_keys
    seg_start = np.full(s_cap, np.inf, np.float32)
    slope = np.zeros(s_cap, np.float32)
    base = np.full(s_cap, n, np.int32)
    seg_end = np.full(s_cap, n, np.int32)
    seg_start[:s] = table.start_key
    slope[:s] = table.slope
    base[:s] = table.base
    seg_end[:s] = table.seg_end
    keys = np.full(m_cap, np.inf, np.float32)
    keys[:n] = table.keys
    return seg_start, slope, base, seg_end, keys, n


# ------------------------------------------------------------- the service
class DeviceShardedService:
    """``ShardedIndexService`` write path, device-resident read path.

    Construction partitions the keys into ``device_count`` contiguous
    shards (one host ``ShardedIndexService`` with the same cuts owns the
    writers/publishers) and uploads the packed layout onto a 1-D device
    mesh.  From then on:

        svc = DeviceShardedService(keys, error=64, device_count=8,
                                   buffer_size=16)
        svc.insert(k)        # routed + buffered on the host writer (Alg. 4)
        svc.publish()        # host epoch cut, then a DELTA upload: only
                             # dirty shards' rows are re-shipped on device
        svc.search(q)        # shard_map collective search, global ranks
        svc.lookup(q)        # and the full typed verb surface

    ``exchange`` picks the collective strategy: ``"allgather"`` (robust,
    per-device work is the whole batch), ``"a2a"`` (owner-routed,
    per-device work shrinks with D; slack overflow resolved internally via
    a follow-up allgather pass), or ``"auto"`` (per-batch cost-model
    crossover, :func:`repro.core.cost_model.choose_exchange`).

    Requires ``jax.device_count() >= device_count`` (CI forces 8 host
    devices via XLA_FLAGS) and at least ``device_count`` distinct keys.
    """

    def __init__(self, keys: np.ndarray, error: int | None = None, *,
                 plan: "IndexPlan | None" = None,
                 device_count: int | None = None,
                 buffer_size: int | None = None,
                 publish_every: int | None = None,
                 exchange: str | None = None,
                 payload: np.ndarray | None = None,
                 mesh: Mesh | None = None, axis: str = "data",
                 slack: float = 2.0, headroom: float = 0.5,
                 skew_threshold: float = 2.0, pending_weight: float = 1.0,
                 mode: str = "paper", assume_sorted: bool = False,
                 monitor: Monitor | None = None):
        from .fit import IndexPlan

        raw = {"error": error, "device_count": device_count,
               "buffer_size": buffer_size, "publish_every": publish_every,
               "exchange": exchange}
        if plan is None:
            if error is None:
                raise TypeError("pass error=... (expert knobs) or plan=... "
                                "(an IndexPlan from repro.index.fit)")
            d = int(device_count) if device_count is not None \
                else jax.device_count()
            plan = dataclasses.replace(
                IndexPlan.from_knobs(
                    error=error, n_shards=d,
                    buffer_size=0 if buffer_size is None else buffer_size,
                    backend="device", publish_every=publish_every),
                device_count=d,
                exchange="allgather" if exchange is None else exchange)
        else:
            clashing = sorted(k for k, v in raw.items() if v is not None)
            if clashing:
                raise TypeError("pass either the raw knobs or plan=, not "
                                f"both -- the plan already fixes "
                                f"{', '.join(clashing)}")
        if plan.backend != "device":
            raise ValueError(f"DeviceShardedService needs backend='device', "
                             f"plan has {plan.backend!r}")
        d = int(plan.device_count or plan.n_shards)
        if len(jax.devices()) < d:
            raise ValueError(f"device_count={d} exceeds the {len(jax.devices())} "
                             "available devices (CPU runs force more via "
                             "XLA_FLAGS=--xla_force_host_platform_device_"
                             f"count={d})")
        if plan.exchange is not None and plan.exchange not in _EXCHANGES:
            raise ValueError(f"exchange must be one of {_EXCHANGES}, got "
                             f"{plan.exchange!r}")
        self.plan = plan
        self.exchange = plan.exchange or "allgather"
        self.publish_every = plan.publish_every
        self.monitor = monitor
        self.slack = float(slack)
        self.headroom = float(headroom)
        self._axis = axis
        self._mesh = mesh if mesh is not None else Mesh(
            np.asarray(jax.devices()[:d]), (axis,))
        self._devices = list(np.asarray(self._mesh.devices).ravel())
        self._shard_spec = NamedSharding(self._mesh, P(axis, None))
        self._row_spec = NamedSharding(self._mesh, P(axis))
        self._repl_spec = NamedSharding(self._mesh, P())

        # the host write plane: same cuts, same writers, numpy verbs kept as
        # the bit-identity oracle.  Plain dataclasses.replace (not
        # plan.replace) so the host plan keeps the device plan's revision;
        # the device service runs the publish cadence itself.
        host_plan = dataclasses.replace(plan, backend="numpy", n_shards=d,
                                        publish_every=None, device_count=None,
                                        exchange=None)
        self._host = ShardedIndexService(
            keys, plan=host_plan, payload=payload, mode=mode,
            skew_threshold=skew_threshold, pending_weight=pending_weight,
            assume_sorted=assume_sorted, monitor=monitor)

        # ranks *before* the host service's write lock: device mutators wrap
        # the host ones (publish -> host.publish under both locks)
        self._write_lock = sanitizer.make_rlock(
            "DeviceShardedService._write_lock")
        self._fn_lock = sanitizer.make_lock("DeviceShardedService._fn_lock")
        self._counts_lock = sanitizer.make_lock(
            "DeviceShardedService._counts_lock")
        self._fns: dict = {}
        self._query_counts = {"points": 0, "ranges": 0, "counts": 0,
                              "predecessors": 0, "successors": 0,
                              "searches": 0}
        self._publishes = 0
        self._delta_publishes = 0
        self._full_publishes = 0
        self._bytes_uploaded = 0
        self._bytes_full_equivalent = 0
        self._xchg_counts = {"allgather": 0, "a2a": 0}
        self._overflow_queries = 0
        self._collective_wall_ns = 0.0
        ds0 = self._full_set(version=1)
        self._device_set = ds0
        self._account_publish(ds0, self._full_bytes(ds0), full=True,
                              dirty=d, wall_ns=0)

    @classmethod
    def from_plan(cls, keys: np.ndarray, plan: "IndexPlan", *,
                  payload: np.ndarray | None = None,
                  **service_kwargs) -> "DeviceShardedService":
        """Build from a resolved ``IndexPlan`` (the ``fit.open_index`` path
        for ``backend='device'``)."""
        return cls(keys, plan=plan, payload=payload, **service_kwargs)

    # ------------------------------------------------------------------ shape
    @property
    def host(self) -> ShardedIndexService:
        """The wrapped host write plane (writers, publishers, rebalancer)."""
        return self._host

    @property
    def n_devices(self) -> int:
        return len(self._devices)

    @property
    def n_shards(self) -> int:
        return self._host.n_shards

    @property
    def device_set(self) -> DeviceShardSet:
        """The current immutable device manifest (pin it for consistency)."""
        return self._device_set

    @property
    def boundaries(self) -> np.ndarray:
        return self._host.boundaries

    @property
    def pending_inserts(self) -> int:
        return self._host.pending_inserts

    def shard_of(self, key: float) -> int:
        return self._host.shard_of(key)

    def epochs(self) -> list[int]:
        return self._host.epochs()

    def imbalance(self) -> float:
        return self._host.imbalance()

    def needs_rebalance(self) -> bool:
        return self._host.needs_rebalance()

    def _pin_device_set(self) -> DeviceShardSet:
        """THE read-path pin: one reference read of the live device manifest
        per verb (RI002); the pinned version is reported to the sanitizer's
        PinTracker, which asserts no verb mixes two manifests end-to-end."""
        ds = self._device_set
        sanitizer.observe_pin(ds.version)
        return ds

    def _count(self, shape: str, n: int) -> None:
        with self._counts_lock:
            self._query_counts[shape] += n

    # ------------------------------------------------------------ build/upload
    def _caps_for(self, snaps: Sequence[Snapshot]) -> tuple[int, int]:
        """Padded row capacities with headroom over the current maxima, so
        steady-state inserts re-publish into the same shapes (delta-eligible,
        no retrace); the +8/+64 floors keep tiny shards delta-able too."""
        s_max = max(s.table.n_segments for s in snaps)
        m_max = max(s.n_keys for s in snaps)
        s_cap = int(np.ceil(max(s_max, 1) * (1.0 + self.headroom))) + 8
        m_cap = int(np.ceil(max(m_max, 1) * (1.0 + self.headroom))) + 64
        return s_cap, m_cap

    def _manifest_arrays(self, snaps, host_version: int, version: int,
                         s_cap: int, m_cap: int, device_arrays
                         ) -> DeviceShardSet:
        boundaries = np.asarray(self._host.boundaries, np.float64)
        sizes = np.asarray([s.n_keys for s in snaps], np.int64)
        offsets = np.concatenate([[0], np.cumsum(sizes)[:-1]]).astype(np.int64)
        return DeviceShardSet(
            version=version, host_version=host_version,
            error=int(self._host.error), n_keys=int(sizes.sum()),
            n_segments=int(sum(s.table.n_segments for s in snaps)),
            s_cap=s_cap, m_cap=m_cap, boundaries=boundaries, offsets=offsets,
            snapshots=tuple(snaps),
            epochs=tuple(s.epoch for s in snaps),
            d_offsets=jax.device_put(offsets.astype(np.int32),
                                     self._repl_spec),
            d_boundaries=jax.device_put(boundaries.astype(np.float32),
                                        self._repl_spec),
            **device_arrays)

    def _full_set(self, version: int) -> DeviceShardSet:
        """Pack every shard's snapshot and upload the whole layout (build,
        rebalance, structural replan, or capacity overflow)."""
        host_ss = self._host.shard_set
        snaps = [h.current() for h in host_ss.handles]
        s_cap, m_cap = self._caps_for(snaps)
        rows = [_pack_row(s.table, s_cap, m_cap) for s in snaps]
        stacked = [np.stack([r[i] for r in rows]) for i in range(5)]
        n_local = np.asarray([r[5] for r in rows], np.int32)
        seg_start, slope, base, seg_end, keys = [
            jax.device_put(a, self._shard_spec) for a in stacked]
        return self._manifest_arrays(
            snaps, host_ss.version, version, s_cap, m_cap,
            dict(d_seg_start=seg_start, d_slope=slope, d_base=base,
                 d_seg_end=seg_end, d_keys=keys,
                 d_n_local=jax.device_put(n_local, self._row_spec)))

    def _swap_rows(self, old: jax.Array, dirty_rows: dict[int, np.ndarray]
                   ) -> jax.Array:
        """Rebuild a sharded array reusing the clean rows' existing device
        buffers and ``device_put``-ing only the dirty rows onto their owning
        devices -- the delta-upload primitive.  Buffer identity of clean
        rows is preserved (asserted in tests via unsafe_buffer_pointer)."""
        bufs: dict[int, jax.Array] = {}
        for s in old.addressable_shards:
            bufs[int(s.index[0].start or 0)] = s.data
        for r, row in dirty_rows.items():
            bufs[r] = jax.device_put(row[None, ...] if row.ndim else
                                     np.asarray([row]), self._devices[r])
        arrays = [bufs[r] for r in range(len(self._devices))]
        return jax.make_array_from_single_device_arrays(
            old.shape, old.sharding, arrays)

    def _delta_set(self, cur: DeviceShardSet, snaps: list[Snapshot],
                   dirty: list[int]) -> DeviceShardSet:
        """Delta upload: re-pack ONLY the dirty shards' rows into the current
        capacities and swap them in; clean rows keep their device buffers."""
        rows = {d: _pack_row(snaps[d].table, cur.s_cap, cur.m_cap)
                for d in dirty}
        names = ("d_seg_start", "d_slope", "d_base", "d_seg_end", "d_keys")
        device_arrays = {
            name: self._swap_rows(getattr(cur, name),
                                  {d: r[i] for d, r in rows.items()})
            for i, name in enumerate(names)}
        device_arrays["d_n_local"] = self._swap_rows(
            cur.d_n_local, {d: np.int32(r[5]) for d, r in rows.items()})
        return self._manifest_arrays(snaps, cur.host_version,
                                     cur.version + 1, cur.s_cap, cur.m_cap,
                                     device_arrays)

    def _full_bytes(self, ds: DeviceShardSet) -> int:
        return ds.row_bytes() * ds.n_devices + \
            ds.replicated_bytes() * ds.n_devices

    def _account_publish(self, ds: DeviceShardSet, up_bytes: int, *,
                         full: bool, dirty: int, wall_ns: int) -> None:
        self._publishes += 1
        if full:
            self._full_publishes += 1
        else:
            self._delta_publishes += 1
        self._bytes_uploaded += up_bytes
        self._bytes_full_equivalent += self._full_bytes(ds)
        if self.monitor is not None:
            self.monitor.record(CH_DEVICE_PUBLISH, dirty, up_bytes, wall_ns,
                                1 if full else 0)

    def _sync_locked(self) -> None:
        """Reconcile the device manifest with the host serving state: delta
        upload when only snapshots moved and the new tables fit the current
        capacities; full re-pack on a boundary change (rebalance/replan),
        shard-count change, or capacity overflow.  Ends in the single
        reference assignment that publishes the new manifest."""
        t0 = time.perf_counter_ns()
        cur = self._device_set
        host_ss = self._host.shard_set
        snaps = [h.current() for h in host_ss.handles]
        structural = (host_ss.version != cur.host_version
                      or len(snaps) != len(cur.snapshots)
                      or max(s.table.n_segments for s in snaps) > cur.s_cap
                      or max(s.n_keys for s in snaps) > cur.m_cap)
        if structural:
            new = self._full_set(cur.version + 1)
            self._device_set = new
            self._account_publish(new, self._full_bytes(new), full=True,
                                  dirty=len(snaps),
                                  wall_ns=time.perf_counter_ns() - t0)
            return
        dirty = [d for d in range(len(snaps))
                 if snaps[d] is not cur.snapshots[d]]
        if not dirty:
            return
        new = self._delta_set(cur, snaps, dirty)
        # dirty rows' bytes + the re-shipped replicated offsets/router
        up = new.row_bytes() * len(dirty) + \
            new.replicated_bytes() * new.n_devices
        self._device_set = new
        self._account_publish(new, up, full=False, dirty=len(dirty),
                              wall_ns=time.perf_counter_ns() - t0)

    # ------------------------------------------------------------- write path
    def insert(self, key: float, value=None) -> None:
        """Buffer an insert in the owning shard's host writer (Alg. 4);
        invisible on device until that shard publishes."""
        with self._write_lock:
            self._host.insert(key, value)
            if self.publish_every is not None and \
                    self._host.pending_inserts >= self.publish_every:
                self.publish()

    def publish(self, shards: Sequence[int] | None = None,
                force: bool = False) -> dict[int, Snapshot]:
        """Cut new host epochs on dirty shards, then delta-upload exactly
        those shards' device rows.  Clean shards keep their epoch *and*
        their device buffers.  Returns the newly installed snapshots."""
        with self._write_lock:
            published = self._host.publish(shards, force=force)
            self._sync_locked()
            return published

    def rebalance(self, force: bool = False) -> dict | None:
        """Recut boundaries on the host plane (migrating key runs between
        writers), then re-upload the full device layout -- a boundary change
        invalidates every row's routing, so there is no delta to take."""
        with self._write_lock:
            info = self._host.rebalance(force)
            if info is not None:
                self._sync_locked()
            return info

    def apply_plan(self, new_plan: "IndexPlan", *,
                   reshard: bool = False) -> "IndexPlan":
        """Hot-swap the served configuration (the ``Replanner`` path).  The
        shard count is pinned to the device count (``reshard`` only
        re-segments; it never changes D -- a mesh is not resizable at
        runtime), exchange/device hints carry over unless the new plan sets
        its own, and the device layout is fully re-uploaded."""
        with self._write_lock:
            host_plan = dataclasses.replace(
                new_plan, backend="numpy", n_shards=self.n_devices,
                publish_every=None, device_count=None, exchange=None)
            applied = self._host.apply_plan(host_plan, reshard=False)
            self.plan = dataclasses.replace(
                new_plan, backend="device", n_shards=applied.n_shards,
                device_count=self.n_devices,
                exchange=new_plan.exchange or self.exchange)
            self.exchange = self.plan.exchange
            self.publish_every = (self.plan.publish_every
                                  if self.plan.buffer_size > 0 else None)
            self._sync_locked()
            return self.plan

    # -------------------------------------------------------------- read path
    def _kernel(self, kind: str, side: str, error: int):
        """The jitted collective for (strategy, side, error), cached under
        ``_fn_lock``.  Device arrays enter as *arguments* (not closures), so
        a delta publish swaps buffers without retracing; a capacity change
        retraces naturally through the new shapes."""
        key = (kind, side, error)
        with self._fn_lock:
            fn = self._fns.get(key)
            if fn is None:
                mesh, axis, slack = self._mesh, self._axis, self.slack
                if kind == "ag":
                    def fn(seg_start, slope, base, seg_end, keys, n_local, q):
                        return sharded_search_allgather(
                            seg_start, slope, base, seg_end, keys, n_local,
                            q, mesh=mesh, axis=axis, error=error, side=side)
                else:
                    def fn(seg_start, slope, base, seg_end, keys, n_local,
                           offsets, boundaries, q):
                        return sharded_search_a2a(
                            seg_start, slope, base, seg_end, keys, n_local,
                            offsets, boundaries, q, mesh=mesh, axis=axis,
                            error=error, side=side, slack=slack)
                fn = jax.jit(fn)
                self._fns[key] = fn
        return fn

    def _pad(self, flat: np.ndarray) -> np.ndarray:
        """Pad to a device-divisible batch with a finite filler (padding
        lanes compute real-but-discarded ranks; +inf would be routed to the
        last shard, which is also fine -- finite keeps the a2a buckets
        honest about real skew only)."""
        d = self.n_devices
        q_per = max(1, -(-flat.size // d))
        if flat.size == q_per * d:
            return flat
        out = np.zeros(q_per * d, np.float32)
        out[:flat.size] = flat
        return out

    def _search_set(self, ds: DeviceShardSet, queries,
                    side: str) -> np.ndarray:
        """Global insertion ranks against a pinned manifest.  The exchange
        strategy is the service's (or the per-batch cost-model choice under
        ``"auto"``); a2a bucket overflow is resolved here with a follow-up
        allgather pass over just the overflowed queries."""
        q = np.asarray(queries, np.float64)
        flat = q.astype(np.float32).ravel()
        if flat.size == 0:
            return np.empty(q.shape, np.int64)
        strategy = self.exchange
        if strategy == "auto":
            strategy = choose_exchange(flat.size, ds.n_devices, ds.error,
                                       ds.n_segments)
        if ds.n_devices == 1:
            strategy = "allgather"
        t0 = time.perf_counter_ns()
        shard_args = (ds.d_seg_start, ds.d_slope, ds.d_base, ds.d_seg_end,
                      ds.d_keys, ds.d_n_local)
        if strategy == "a2a":
            ranks_d, ok_d = self._kernel("a2a", side, ds.error)(
                *shard_args, ds.d_offsets, ds.d_boundaries, self._pad(flat))
            ranks = np.asarray(ranks_d, np.int64)[:flat.size]
            miss = ~np.asarray(ok_d)[:flat.size]
            n_miss = int(miss.sum())
            if n_miss:
                # the follow-up pass the a2a contract promises: overflowed
                # queries re-ask via allgather, which cannot drop anything
                sub = self._kernel("ag", side, ds.error)(
                    *shard_args, self._pad(flat[miss]))
                ranks[miss] = np.asarray(sub, np.int64)[:n_miss]
                with self._counts_lock:
                    self._overflow_queries += n_miss
                if self.monitor is not None:
                    self.monitor.record(CH_DEVICE_OVERFLOW, n_miss)
        else:
            ranks = np.asarray(self._kernel("ag", side, ds.error)(
                *shard_args, self._pad(flat)), np.int64)[:flat.size]
        wall = time.perf_counter_ns() - t0
        with self._counts_lock:
            self._xchg_counts[strategy] += 1
            self._collective_wall_ns += wall
        if self.monitor is not None:
            self.monitor.record(
                CH_DEVICE_COLLECTIVE,
                XCHG_A2A if strategy == "a2a" else XCHG_ALLGATHER,
                flat.size, wall)
        return ranks.reshape(q.shape)

    def search(self, queries, side: str = "left") -> np.ndarray:
        """Global ``searchsorted(all_keys, queries, side)`` insertion ranks
        (f32 key compares) via one collective round on the device mesh."""
        check_side(side)
        self._count("searches", int(np.size(queries)))
        with sanitizer.pin_scope("device.search"):
            return self._search_set(self._pin_device_set(), queries, side)

    def lookup(self, queries) -> np.ndarray:
        """Global rank of each query, -1 if absent (found == some key equals
        the query in f32, i.e. right rank > left rank)."""
        self._count("points", int(np.size(queries)))
        with sanitizer.pin_scope("device.lookup"):
            ds = self._pin_device_set()
            left = self._search_set(ds, queries, "left")
            right = self._search_set(ds, queries, "right")
            return np.where(right > left, left, -1)

    def point(self, queries) -> PointResult:
        """Typed membership: global leftmost rank + found flag per query."""
        self._count("points", int(np.size(queries)))
        with sanitizer.pin_scope("device.point"):
            ds = self._pin_device_set()
            left = self._search_set(ds, queries, "left")
            right = self._search_set(ds, queries, "right")
            found = right > left
            return PointResult(rank=np.where(found, left, -1), found=found)

    def count(self, lo, hi) -> np.ndarray:
        """Keys in the inclusive ``[lo, hi]`` ranges (vectorized), both
        bounds resolved against one pinned manifest."""
        with sanitizer.pin_scope("device.count"):
            ds = self._pin_device_set()
            lo = np.asarray(lo, np.float64)
            hi = np.asarray(hi, np.float64)
            counts = np.maximum(self._search_set(ds, hi, "right")
                                - self._search_set(ds, lo, "left"), 0)
            self._count("counts", int(counts.size))
            return counts.astype(np.int64)

    def predecessor(self, queries) -> PointResult:
        """Global rank of the largest key <= each query (rightmost)."""
        self._count("predecessors", int(np.size(queries)))
        with sanitizer.pin_scope("device.predecessor"):
            ds = self._pin_device_set()
            rank = self._search_set(ds, queries, "right") - 1
            found = rank >= 0
            return PointResult(rank=np.where(found, rank, -1), found=found)

    def successor(self, queries) -> PointResult:
        """Global rank of the smallest key >= each query (leftmost)."""
        self._count("successors", int(np.size(queries)))
        with sanitizer.pin_scope("device.successor"):
            ds = self._pin_device_set()
            rank = self._search_set(ds, queries, "left")
            found = rank < ds.n_keys
            return PointResult(rank=np.where(found, rank, -1), found=found)

    def range(self, lo, hi, *, materialize: bool = True) -> RangeResult:
        """Inclusive ``[lo, hi]`` scan: the rank span comes from the device
        collectives, the materialized keys/payloads from the SAME pinned
        manifest's host snapshots -- one epoch combination end to end."""
        lo, hi = check_range(lo, hi)
        with sanitizer.pin_scope("device.range"):
            ds = self._pin_device_set()
            self._count("ranges", 1)
            lo_rank = int(self._search_set(ds, np.asarray([lo]), "left")[0])
            hi_rank = max(int(self._search_set(ds, np.asarray([hi]),
                                               "right")[0]), lo_rank)
            keys = payload = None
            if materialize:
                d0 = int(route_keys(ds.boundaries, np.float64(lo)))
                d1 = int(route_keys(ds.boundaries, np.float64(hi)))
                k_parts, p_parts = [], []
                for d in range(d0, d1 + 1):
                    snap = ds.snapshots[d]
                    off = int(ds.offsets[d])
                    a = max(lo_rank - off, 0) if d == d0 else 0
                    b = (min(hi_rank - off, snap.n_keys) if d == d1
                         else snap.n_keys)
                    if b <= a:
                        continue
                    k_parts.append(snap.table.keys[a:b])
                    if snap.payload is not None:
                        p_parts.append(snap.payload[a:b])
                keys = (np.concatenate(k_parts) if k_parts
                        else np.empty(0, np.float64))
                if self._host.has_payload:
                    payload = (np.concatenate(p_parts) if p_parts
                               else np.empty(0))
            return RangeResult(lo=lo, hi=hi, lo_rank=lo_rank,
                               hi_rank=hi_rank, keys=keys, payload=payload)

    def prewarm(self, batch_sizes: Sequence[int] | None = None) -> None:
        """Compile the collective kernels for both sides (and both
        strategies when the service may use a2a) at the given batch shapes
        before serving traffic."""
        for n in (batch_sizes or (self.n_devices,)):
            probe = np.zeros(int(n), np.float64)
            self.search(probe, side="left")
            self.search(probe, side="right")

    # ------------------------------------------------------------ observability
    def metrics(self):
        """The typed snapshot: the host plane's tree (shards, rebalances,
        imbalance) re-rooted at ``service="device"`` with this service's
        query counters and the :class:`DeviceMetrics` node -- manifest
        shape, per-device resident bytes, the delta-upload fraction, and
        the exchange-strategy counters."""
        base = self._host.metrics()
        ds = self._device_set
        with self._counts_lock:
            counts = dict(self._query_counts)
            xchg = dict(self._xchg_counts)
            overflow = self._overflow_queries
            wall = self._collective_wall_ns
        dm = DeviceMetrics(
            device_set_version=ds.version, n_devices=ds.n_devices,
            exchange=self.exchange, s_cap=ds.s_cap, m_cap=ds.m_cap,
            per_device_bytes=tuple(ds.row_bytes() + ds.replicated_bytes()
                                   for _ in range(ds.n_devices)),
            replicated_bytes=ds.replicated_bytes(),
            publishes=self._publishes,
            delta_publishes=self._delta_publishes,
            full_publishes=self._full_publishes,
            bytes_uploaded=self._bytes_uploaded,
            bytes_full_equivalent=self._bytes_full_equivalent,
            delta_fraction=(self._bytes_uploaded
                            / self._bytes_full_equivalent
                            if self._bytes_full_equivalent else 1.0),
            allgather_calls=xchg["allgather"], a2a_calls=xchg["a2a"],
            a2a_overflow_queries=overflow, collective_wall_ns=wall)
        return dataclasses.replace(base, service="device",
                                   plan_revision=self.plan.revision,
                                   query_counts=counts, device=dm)

    def stats(self) -> list:
        """Deprecated: use :meth:`metrics`\\ ``().shards``."""
        warnings.warn("DeviceShardedService.stats() is deprecated; use "
                      "metrics().shards", DeprecationWarning, stacklevel=2)
        return list(self.metrics().shards)
