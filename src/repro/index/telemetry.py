"""Telemetry plane + online re-planning: measure the served workload,
re-calibrate the Sec. 6 cost model, hot-swap the plan.

Two halves close the measure -> re-fit -> re-plan loop that the static
planner (``repro.index.fit``) leaves open:

* :class:`Monitor` -- an append-only named-channel recorder.  The hot path
  is a lock-free ring buffer write (a preallocated slot list plus an atomic
  ``itertools.count`` cursor; the GIL makes the two-step append safe, and a
  racing writer at worst overwrites one slot -- last writer wins, which is
  exactly the semantics a fixed-capacity telemetry ring wants).  Recording
  hooks are threaded through the serving stack:

      DispatchEngine        tier.<small|medium|large>: (batch_size, wall_ns)
      AsyncIndexService     pipeline.queue_depth / pipeline.flush (cause,
                            fused batch size) / pipeline.sojourn (ns)
      ShardedIndexService   service.publish / service.rebalance (wall ns),
                            service.shard_load, service.skew,
                            service.query_mix, served.keys (query samples)

  Backends are pluggable: :class:`MemoryBackend` (default, rings only) and
  :class:`JSONLBackend` (same rings; ``flush()`` appends rows recorded since
  the last flush as JSON lines -- IO happens only on flush, never on the
  record path).

* :class:`Replanner` -- the feedback controller.  It re-fits the per-tier
  fixed+marginal cost coefficients from the measured ``tier.*`` samples
  (:func:`repro.core.cost_model.fit_tier_curves`, least squares over
  (batch_size, ns) points), inverts them into calibrated
  ``CostParams``/``TPUCostParams`` (:func:`repro.core.cost_model.
  refit_params`), re-runs ``fit.plan()`` against a reservoir of served keys,
  and -- only when the predicted win over the *observed* batch mix clears a
  hysteresis bar -- hot-swaps the dispatch thresholds, pipeline flush knobs
  and shard count through ``ShardedIndexService.apply_plan`` /
  ``AsyncIndexService.apply_plan``.  Swaps run off the request path (the
  pipeline's maintenance cadence thread calls :meth:`Replanner.step`), and
  both apply paths publish a fresh immutable ``ShardSet`` with one reference
  assignment, so pinned readers never see a torn config.  After a swap the
  thresholds sit at the measured curve crossings, so the next proposal's win
  is ~0 and the hysteresis bar keeps the controller from flapping.

The typed observability surface lives here too: :class:`ServiceMetrics`
(alias :data:`MetricsSnapshot`) is the versioned dataclass tree --
``ServiceMetrics -> ShardMetrics / TierMetrics / PipelineMetrics`` -- that
``metrics()`` returns on every service and on the pipeline, with a
``to_json``/``from_json`` round-trip for dashboards; the legacy ``stats()``
/ ``service_stats()`` dict surfaces are thin deprecated wrappers over it.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import time

import numpy as np

from repro.analysis.contracts import hot_path
from repro.analysis.sanitizer import make_lock
from repro.core.cost_model import (CostParams, TPUCostParams, curve_crossings,
                                   fit_tier_curves, refit_params,
                                   tier_cost_curves)

# ------------------------------------------------------------ channel names
# One constant per recording hook, so producers (engine/pipeline/sharded
# hooks) and consumers (Replanner, tier_metrics, dashboards) agree on names.
CH_TIER_PREFIX = "tier."            # + small|medium|large: (batch, wall_ns)
CH_SERVED_KEYS = "served.keys"      # vector rows: sampled query keys
CH_PUBLISH = "service.publish"      # (shards_published, wall_ns)
CH_REBALANCE = "service.rebalance"  # (moved_keys, wall_ns)
CH_SHARD_LOAD = "service.shard_load"  # (shard, load)
CH_SKEW = "service.skew"            # (imbalance,)
CH_QUERY_MIX = "service.query_mix"  # (points, ranges, counts, preds, succs,
                                    #  searches) cumulative at publish time
CH_QUEUE_DEPTH = "pipeline.queue_depth"  # (queued_queries,)
CH_FLUSH = "pipeline.flush"         # (cause, fused_batch)
CH_SOJOURN = "pipeline.sojourn"     # (ns,) per-request enqueue->resolve
CH_REPLAN = "replan"                # (applied, win, small_max, large_min,
                                    #  n_shards)
CH_MEMTABLE = "lsm.memtable"        # (keys, tombstones, capacity) occupancy
CH_SPILL = "lsm.spill"              # (spilled_keys, wall_ns)
CH_COMPACT = "lsm.compaction"       # (runs_merged, merged_keys, wall_ns)
CH_READ_AMP = "lsm.read_amp"        # (fan_in_sources,) sampled per verb
CH_RUN_COUNT = "lsm.runs"           # (n_runs,) after each manifest swap
CH_DEVICE_PUBLISH = "device.publish"  # (dirty_shards, bytes, wall_ns, full)
CH_DEVICE_COLLECTIVE = "device.collective"  # (strategy, batch, wall_ns)
CH_DEVICE_OVERFLOW = "device.overflow"  # (overflow_queries,) a2a slack misses

# device.collective strategy codes
XCHG_ALLGATHER, XCHG_A2A = 0, 1

# pipeline.flush cause codes
FLUSH_THRESHOLD, FLUSH_DEADLINE, FLUSH_DRAIN, FLUSH_INLINE = 0, 1, 2, 3

METRICS_SCHEMA_VERSION = 1

_TIERS = ("small", "medium", "large")


class _Ring:
    """Fixed-capacity append-only ring: the Monitor's hot-path store.

    ``append`` is two steps -- take a cursor ticket (``itertools.count`` is
    atomic under the GIL) and assign the slot -- with no lock.  Concurrent
    appenders can interleave, in which case the later assignment to a slot
    wins; a reader snapshotting mid-append can see a row slightly older than
    the cursor claims.  Both are acceptable for telemetry (bounded loss,
    never a torn Python object: slot assignment is one reference store).

    ``kind`` is fixed by the first record: "scalar" rows are equal-width
    tuples (``values()`` -> an (n, width) array), "vector" rows are small
    arrays (``values()`` -> their 1-D concatenation, e.g. sampled keys).
    """

    __slots__ = ("capacity", "rows", "kind", "_ctr", "total")

    def __init__(self, capacity: int, kind: str):
        self.capacity = int(capacity)
        self.rows: list = [None] * self.capacity
        self.kind = kind
        self._ctr = itertools.count()
        self.total = 0          # rows ever appended (monotonic, approximate
        #                         under racing appends -- telemetry-grade)

    @hot_path
    def append(self, row) -> None:
        i = next(self._ctr)
        self.rows[i % self.capacity] = row
        self.total = i + 1

    def snapshot(self) -> list:
        """Ring contents oldest-first (a shallow copy; rows are immutable)."""
        n = self.total
        if n <= self.capacity:
            return [r for r in self.rows[:n] if r is not None]
        cut = n % self.capacity
        return [r for r in self.rows[cut:] + self.rows[:cut] if r is not None]

    def values(self) -> np.ndarray:
        rows = self.snapshot()
        if self.kind == "vector":
            if not rows:
                return np.empty(0, np.float64)
            return np.concatenate([np.asarray(r, np.float64).ravel()
                                   for r in rows])
        if not rows:
            return np.empty((0, 0), np.float64)
        return np.asarray(rows, np.float64)


class MemoryBackend:
    """In-memory channel store: one ring per channel, nothing else."""

    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity!r}")
        self.capacity = int(capacity)

    def make_ring(self, name: str, kind: str) -> _Ring:
        return _Ring(self.capacity, kind)

    def flush(self, channels: dict[str, _Ring]) -> int:
        """Nothing to persist; returns 0 rows written."""
        return 0

    def close(self, channels: dict[str, _Ring]) -> None:
        pass


class JSONLBackend(MemoryBackend):
    """Ring store + JSON-lines persistence on ``flush()``.

    The record path is identical to :class:`MemoryBackend` (ring write, no
    IO).  ``flush()`` appends every row recorded since the previous flush as
    one JSON line ``{"ch": name, "i": row_index, "v": [...]}``; rows that
    fell off the ring between flushes are skipped and counted in
    ``dropped``.  Not a hot-path sink -- flush from the maintenance cadence
    or at close."""

    def __init__(self, path, capacity: int = 4096):
        super().__init__(capacity)
        self.path = str(path)
        self.dropped = 0
        self._flushed: dict[str, int] = {}
        self._io_lock = make_lock("JSONLBackend._io_lock")

    def flush(self, channels: dict[str, _Ring]) -> int:
        written = 0
        with self._io_lock, open(self.path, "a") as f:
            for name, ring in sorted(channels.items()):
                total = ring.total
                done = self._flushed.get(name, 0)
                if total <= done:
                    continue
                start = max(done, total - ring.capacity)
                self.dropped += start - done
                rows = ring.snapshot()[-(total - start):]
                for i, row in enumerate(rows, start=start):
                    vals = (np.asarray(row, np.float64).ravel().tolist()
                            if ring.kind == "vector" else
                            [float(v) for v in row])
                    f.write(json.dumps({"ch": name, "i": i, "v": vals}) + "\n")
                    written += 1
                self._flushed[name] = total
        return written

    def close(self, channels: dict[str, _Ring]) -> None:
        self.flush(channels)


class Monitor:
    """Append-only named-channel telemetry recorder.

    ``record(name, *values)`` appends one fixed-width row to ``name``'s ring
    (the width is fixed by the first record); ``record_many(name, values)``
    appends one small *array* row (e.g. a sample of served query keys) to a
    vector channel.  Both are lock-free slot writes (see :class:`_Ring`) --
    cheap enough for the lookup hot path -- and both are no-ops while
    ``enabled`` is False, so a monitor can be installed permanently and
    toggled.

    Readers (``channel()``/``channels()``/``count()``) snapshot the rings;
    they are meant for the maintenance thread / dashboards, not the hot
    path.  ``backend`` picks the store: the default :class:`MemoryBackend`
    keeps rings only, :class:`JSONLBackend` also persists on ``flush()``.
    """

    def __init__(self, backend: MemoryBackend | None = None, *,
                 capacity: int | None = None):
        if backend is None:
            backend = MemoryBackend(4096 if capacity is None else capacity)
        elif capacity is not None:
            raise ValueError("pass capacity through the backend when giving "
                             "one explicitly (Monitor(JSONLBackend(path, "
                             "capacity=...)))")
        self.backend = backend
        self.enabled = True
        self._channels: dict[str, _Ring] = {}
        self._make_lock = make_lock("Monitor._make_lock")

    # ------------------------------------------------------------- hot path
    @hot_path
    def record(self, name: str, *values) -> None:
        """Append one scalar row to ``name`` (width fixed by first record)."""
        if not self.enabled:
            return
        ring = self._channels.get(name)
        if ring is None:
            ring = self._make(name, "scalar")
        ring.append(values)

    @hot_path
    def record_many(self, name: str, values) -> None:
        """Append one array row (a *sample*, e.g. served keys) to ``name``."""
        if not self.enabled:
            return
        ring = self._channels.get(name)
        if ring is None:
            ring = self._make(name, "vector")
        ring.append(np.array(values, np.float64).ravel())

    def _make(self, name: str, kind: str) -> _Ring:
        with self._make_lock:
            ring = self._channels.get(name)
            if ring is None:
                ring = self.backend.make_ring(name, kind)
                self._channels[name] = ring
        return ring

    # -------------------------------------------------------------- readers
    def channels(self) -> list[str]:
        """Sorted names of every channel that has recorded at least once."""
        return sorted(self._channels)

    def channel(self, name: str) -> np.ndarray:
        """Channel contents, oldest-first: an (n, width) array for scalar
        channels, the 1-D sample concatenation for vector channels; empty
        when the channel does not exist."""
        ring = self._channels.get(name)
        return np.empty((0, 0), np.float64) if ring is None else ring.values()

    def count(self, name: str) -> int:
        """Rows ever recorded on ``name`` (including rows the ring dropped)."""
        ring = self._channels.get(name)
        return 0 if ring is None else ring.total

    def tier_samples(self) -> dict[str, np.ndarray]:
        """The ``tier.*`` channels keyed by bare tier name -- the exact input
        shape :func:`repro.core.cost_model.fit_tier_curves` consumes."""
        out = {}
        for tier in _TIERS:
            rows = self.channel(CH_TIER_PREFIX + tier)
            if rows.size:
                out[tier] = rows
        return out

    # ------------------------------------------------------------ lifecycle
    def flush(self) -> int:
        """Persist through the backend (JSONL appends; memory is a no-op)."""
        return self.backend.flush(self._channels)

    def close(self) -> None:
        self.backend.close(self._channels)

    def clear(self, name: str | None = None) -> None:
        """Drop one channel's ring (or all of them): a fresh measurement
        window, e.g. after a re-plan swap invalidates old samples."""
        with self._make_lock:
            if name is None:
                self._channels = {}
            else:
                self._channels.pop(name, None)


# ==================================================================== metrics
@dataclasses.dataclass(frozen=True)
class TierMetrics:
    """One dispatch tier's measured serving profile (from the ``tier.*``
    telemetry channels).  ``fixed_ns``/``per_query_ns`` are the least-squares
    re-fit of the tier's affine cost curve (None until the channel holds
    enough samples at two distinct batch sizes)."""
    tier: str
    calls: int
    queries: int
    mean_batch: float
    mean_ns: float
    fixed_ns: float | None = None
    per_query_ns: float | None = None


@dataclasses.dataclass(frozen=True)
class ShardMetrics:
    """One shard's serving state (the typed form of ``ShardStats``, plus the
    write-side load the rebalancer steers by)."""
    shard: int
    boundary: float
    epoch: int
    n_segments: int
    n_keys: int
    pending_inserts: int
    snapshot_first_key: float = float("nan")
    load: float = 0.0


@dataclasses.dataclass(frozen=True)
class PipelineMetrics:
    """The async front door's counters and current knobs (the typed form of
    ``AsyncIndexService.pipeline_stats()``)."""
    flushes: int = 0
    threshold_flushes: int = 0
    deadline_flushes: int = 0
    drain_flushes: int = 0
    inline_batches: int = 0
    coalesced_queries: int = 0
    max_fused_batch: int = 0
    publishes: int = 0
    maintenance_ticks: int = 0
    queued: int = 0
    flush_threshold: int = 0
    max_wait_us: float = 0.0
    queue_depth: int = 0
    replans: int = 0
    compactions: int = 0


@dataclasses.dataclass(frozen=True)
class LsmMetrics:
    """The tiered write plane's node in the metrics tree (``lsm.*``
    channels + the current ``LevelSet`` shape).

    ``run_counts``/``run_keys`` are per-level (index 0 = freshest spills);
    ``read_amplification`` is the measured mean fan-in width per verb when a
    monitor is attached, else the current worst case ``1 + n_runs``."""
    level_set_version: int
    memtable_keys: int
    memtable_tombstones: int
    memtable_capacity: int
    n_runs: int
    n_levels: int
    run_counts: tuple[int, ...]
    run_keys: tuple[int, ...]
    live_keys: int
    spills: int
    compactions: int
    read_amplification: float


@dataclasses.dataclass(frozen=True)
class DeviceMetrics:
    """The device-sharded serving plane's node in the metrics tree
    (``device.*`` channels + the current ``DeviceShardSet`` shape).

    ``per_device_bytes`` is the resident packed-table footprint per device
    row (sharded arrays only; the replicated router is counted once in
    ``replicated_bytes``).  ``delta_fraction`` is the byte ratio actually
    uploaded vs the full-republish equivalent over the service lifetime --
    the headline number the delta-publish path exists to shrink."""
    device_set_version: int
    n_devices: int
    exchange: str
    s_cap: int
    m_cap: int
    per_device_bytes: tuple[int, ...]
    replicated_bytes: int
    publishes: int
    delta_publishes: int
    full_publishes: int
    bytes_uploaded: int
    bytes_full_equivalent: int
    delta_fraction: float
    allgather_calls: int
    a2a_calls: int
    a2a_overflow_queries: int
    collective_wall_ns: float


@dataclasses.dataclass(frozen=True)
class ServiceMetrics:
    """The one typed, versioned observability snapshot (``MetricsSnapshot``).

    Returned by ``metrics()`` on ``IndexService``, ``ShardedIndexService``
    and ``AsyncIndexService`` (the pipeline fills ``pipeline``); the legacy
    ``stats()``/``service_stats()`` dict surfaces derive from it.
    ``schema_version`` gates consumers across releases; ``plan_revision`` is
    the served ``IndexPlan.revision``, so dashboards can correlate a metric
    shift with the replan that caused it."""
    service: str
    shard_set_version: int
    plan_revision: int
    n_shards: int
    imbalance: float
    rebalances: int
    rebalance_skipped: int
    last_rebalance: dict | None
    pending_inserts: int
    query_counts: dict
    shards: tuple[ShardMetrics, ...] = ()
    tiers: tuple[TierMetrics, ...] = ()
    pipeline: PipelineMetrics | None = None
    lsm: LsmMetrics | None = None
    device: DeviceMetrics | None = None
    schema_version: int = METRICS_SCHEMA_VERSION

    def to_json(self) -> str:
        """Serialize the whole tree; ``from_json`` restores an equal
        snapshot (dataclass equality, NaN-free fields compare equal)."""
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "ServiceMetrics":
        d = json.loads(text)
        got = d.pop("schema_version", None)
        if got != METRICS_SCHEMA_VERSION:
            raise ValueError(f"unsupported metrics schema_version {got!r} "
                             f"(this build reads {METRICS_SCHEMA_VERSION})")
        d["shards"] = tuple(ShardMetrics(**s) for s in d.get("shards", ()))
        d["tiers"] = tuple(TierMetrics(**t) for t in d.get("tiers", ()))
        if d.get("pipeline") is not None:
            d["pipeline"] = PipelineMetrics(**d["pipeline"])
        if d.get("lsm") is not None:
            lsm = dict(d["lsm"])
            lsm["run_counts"] = tuple(lsm.get("run_counts", ()))
            lsm["run_keys"] = tuple(lsm.get("run_keys", ()))
            d["lsm"] = LsmMetrics(**lsm)
        if d.get("device") is not None:
            dev = dict(d["device"])
            dev["per_device_bytes"] = tuple(dev.get("per_device_bytes", ()))
            d["device"] = DeviceMetrics(**dev)
        return cls(**d)


MetricsSnapshot = ServiceMetrics   # the tree's public root alias


def tier_metrics(monitor: Monitor | None,
                 min_samples: int = 8) -> tuple[TierMetrics, ...]:
    """Summarize a monitor's ``tier.*`` channels into :class:`TierMetrics`
    rows (empty without a monitor or recorded dispatch traffic)."""
    if monitor is None:
        return ()
    samples = monitor.tier_samples()
    curves = fit_tier_curves(samples, min_samples=min_samples)
    out = []
    for tier in _TIERS:
        rows = samples.get(tier)
        if rows is None:
            continue
        fit = curves.get(tier)
        out.append(TierMetrics(
            tier=tier,
            calls=monitor.count(CH_TIER_PREFIX + tier),
            queries=int(rows[:, 0].sum()),
            mean_batch=float(rows[:, 0].mean()),
            mean_ns=float(rows[:, 1].mean()),
            fixed_ns=None if fit is None else fit[0],
            per_query_ns=None if fit is None else fit[1]))
    return tuple(out)


# ================================================================== replanner
class Replanner:
    """Feedback controller: measured telemetry -> re-calibrated cost model ->
    hot-swapped :class:`repro.index.fit.IndexPlan`.

    ``service`` is an ``IndexService`` or ``ShardedIndexService`` carrying a
    ``monitor`` (or pass one explicitly); attach to an ``AsyncIndexService``
    via its ``replanner=`` argument and the maintenance cadence thread calls
    :meth:`step` off the request path.

    One :meth:`replan` pass:

    1. re-fit the per-tier (fixed, marginal) cost coefficients from the
       measured ``tier.*`` samples; tiers without enough samples keep the
       modeled curve, so partial telemetry degrades gracefully;
    2. invert the merged curves into calibrated ``CostParams`` /
       ``TPUCostParams`` and re-run ``fit.plan()`` over a reservoir of
       *served* keys (falling back to the stored snapshots when no key
       samples were recorded) with the observed range fraction folded in;
    3. score the fresh thresholds against the served plan's over the
       *observed* batch-size mix under the merged curves.  Only a predicted
       mean-cost win above ``hysteresis`` (a fraction, e.g. 0.15 = 15%)
       applies the swap -- and because an applied swap moves the thresholds
       onto the measured crossings, the next pass predicts ~0 win, so the
       controller cannot flap under measurement noise;
    4. apply through ``service.apply_plan`` (new engine opts + fresh
       ``ShardSet`` swap; shard-count changes rebuild the writers) and
       ``pipeline.apply_plan`` (flush knobs), bumping ``plan.revision`` via
       ``IndexPlan.replace`` so the change is auditable.

    An infeasible re-plan (the calibrated model proves the original budget
    unachievable on this host) falls back to re-tuning around the currently
    served error instead of killing the maintenance loop.  The serving
    backend family is never changed by a replan: moving the thresholds
    already re-routes the traffic, and keeping ``dispatch`` keeps the
    telemetry flowing.
    """

    def __init__(self, service, monitor: Monitor | None = None, *,
                 interval_s: float = 5.0, hysteresis: float = 0.15,
                 min_tier_samples: int = 8, max_plan_keys: int = 65_536,
                 reshard: bool = True):
        monitor = monitor or getattr(service, "monitor", None)
        if monitor is None:
            raise ValueError("Replanner needs a Monitor: build the service "
                             "with monitor=Monitor() (so the dispatch tiers "
                             "record) or pass one explicitly")
        if hysteresis < 0:
            raise ValueError(f"hysteresis must be >= 0, got {hysteresis!r}")
        if interval_s <= 0:
            raise ValueError(f"interval_s must be > 0, got {interval_s!r}")
        self.service = service
        self.monitor = monitor
        self.interval_s = float(interval_s)
        self.hysteresis = float(hysteresis)
        self.min_tier_samples = int(min_tier_samples)
        self.max_plan_keys = int(max_plan_keys)
        self.reshard = bool(reshard)
        self.pipeline = None          # bound by AsyncIndexService(replanner=)
        self.checks = 0               # proposals evaluated
        self.replans = 0              # proposals applied
        self.last_win: float | None = None
        self._last_step: float | None = None

    # ------------------------------------------------------------- measured
    def measured_curves(self) -> dict[str, tuple[float, float]]:
        """Per-tier least-squares (fixed_ns, per_query_ns) from telemetry."""
        return fit_tier_curves(self.monitor.tier_samples(),
                               min_samples=self.min_tier_samples)

    def observed_batch_sizes(self) -> np.ndarray:
        """The served batch-size mix (every recorded dispatch call)."""
        sizes = [rows[:, 0] for rows in self.monitor.tier_samples().values()]
        if sizes:
            return np.concatenate(sizes).astype(np.int64)
        return np.empty(0, np.int64)

    def served_keys(self) -> np.ndarray:
        """Reservoir of served query keys (the ``served.keys`` samples),
        falling back to the stored snapshot keys when none were recorded --
        a re-plan always has *some* representative key set."""
        keys = self.monitor.channel(CH_SERVED_KEYS)
        if keys.size == 0:
            handles = getattr(self.service, "handles", None)
            if handles is None:
                handles = (self.service.handle,)
            keys = np.concatenate([h.current().table.keys for h in handles])
        keys = np.asarray(keys, np.float64).ravel()
        if keys.size > self.max_plan_keys:
            stride = int(np.ceil(keys.size / self.max_plan_keys))
            keys = keys[::stride]
        return keys

    # ------------------------------------------------------------- proposal
    def propose(self):
        """One controller pass without applying: returns ``(new_plan, win)``
        or ``None`` when there is nothing to propose yet (no measured tier
        samples or no served keys)."""
        # lazy: fit pulls in the planner stack; keep telemetry import-light
        import dataclasses as dc

        from .fit import FitSpec, InfeasibleSpecError
        from .fit import plan as fit_plan

        cur = self.service.plan
        measured = self.measured_curves()
        if not measured:
            return None
        snap = self.service.metrics()
        n_segments = max(1, sum(s.n_segments for s in snap.shards))
        eff_error = max(1, cur.error - cur.buffer_size)
        spec0 = cur.spec if cur.spec is not None else FitSpec(error=cur.error)
        model = tier_cost_curves(eff_error, n_segments, spec0.cpu_params,
                                 spec0.tpu_params,
                                 range_fraction=spec0.range_fraction,
                                 scan_rows=spec0.range_scan_rows)
        curves = {**model, **measured}

        cpu2, tpu2 = refit_params(curves, eff_error, n_segments,
                                  spec0.cpu_params, spec0.tpu_params)
        qc = snap.query_counts
        shaped = qc.get("points", 0) + qc.get("ranges", 0)
        rf = (min(qc.get("ranges", 0) / shaped, 0.99) if shaped > 0
              else spec0.range_fraction)
        spec2 = dc.replace(spec0, cpu_params=cpu2, tpu_params=tpu2,
                           range_fraction=rf)
        keys = self.served_keys()
        if keys.size == 0:
            return None
        try:
            fresh = fit_plan(keys, spec2)
        except InfeasibleSpecError:
            # calibration proved the original budget unachievable here:
            # re-tune around the served error rather than dying
            spec2 = dc.replace(spec2, latency_budget_ns=None,
                               storage_budget_bytes=None, error=cur.error)
            fresh = fit_plan(keys, spec2)

        mix = self.observed_batch_sizes()
        if mix.size == 0:
            mix = np.asarray(spec2.batch_sizes or (1, 64, 4096), np.int64)
        old_sm, old_lm = cur.small_max, cur.large_min
        if old_sm is None:    # trivial plan: the engine derived model curves
            old_sm, old_lm = curve_crossings(model)
        win = self._mix_win(curves, mix, (old_sm, old_lm),
                            (fresh.small_max, fresh.large_min))

        n_shards = fresh.n_shards if self.reshard else cur.n_shards
        new_plan = cur.replace(
            error=fresh.error, n_shards=n_shards,
            buffer_size=fresh.buffer_size,
            small_max=fresh.small_max, large_min=fresh.large_min,
            publish_every=(fresh.publish_every if fresh.buffer_size > 0
                           else None),
            flush_threshold=fresh.flush_threshold,
            max_wait_us=fresh.max_wait_us, queue_depth=fresh.queue_depth,
            objective=fresh.objective, budget=fresh.budget,
            hardware=fresh.hardware, n_keys=fresh.n_keys,
            candidates=fresh.candidates, spec=spec2)
        return new_plan, win

    @staticmethod
    def _mix_win(curves, mix, old_th, new_th) -> float:
        """Predicted fractional mean-cost win of routing the observed batch
        mix with ``new_th`` instead of ``old_th`` under ``curves``."""
        def mean_cost(small_max, large_min):
            total = 0.0
            for b in mix:
                b = int(b)
                tier = ("small" if b <= small_max else
                        "medium" if b < large_min else "large")
                fixed, per = curves[tier]
                total += fixed + per * b
            return total / max(len(mix), 1)

        old_cost = mean_cost(*old_th)
        new_cost = mean_cost(*new_th)
        return (old_cost - new_cost) / old_cost if old_cost > 0 else 0.0

    # ---------------------------------------------------------------- apply
    def replan(self, force: bool = False):
        """One full controller pass: propose, gate on hysteresis, apply.

        Returns the newly served plan when a swap happened, else ``None``
        (nothing measured yet, or the predicted win did not clear the bar;
        ``force=True`` skips the bar, not the measurement)."""
        proposal = self.propose()
        if proposal is None:
            return None
        new_plan, win = proposal
        self.checks += 1
        self.last_win = win
        if not force and win <= self.hysteresis:
            self.monitor.record(CH_REPLAN, 0.0, win,
                                float(new_plan.small_max or -1),
                                float(new_plan.large_min or -1),
                                float(new_plan.n_shards))
            return None
        self.service.apply_plan(new_plan, reshard=self.reshard)
        served = self.service.plan       # apply may clamp (e.g. shard count)
        pipe = self.pipeline
        if pipe is not None:
            pipe.apply_plan(served)
        self.replans += 1
        self.monitor.record(CH_REPLAN, 1.0, win,
                            float(served.small_max or -1),
                            float(served.large_min or -1),
                            float(served.n_shards))
        return served

    def step(self, now: float | None = None):
        """Rate-limited :meth:`replan` -- the maintenance cadence hook.  At
        most one controller pass per ``interval_s``; cheap to call often."""
        now = time.monotonic() if now is None else now
        if self._last_step is not None \
                and now - self._last_step < self.interval_s:
            return None
        self._last_step = now
        return self.replan()
