"""`SegmentTable`: the canonical, immutable form of the FITing-Tree index.

Every layer of the repo (host tree, XLA index, Pallas kernel plan, sharded
serving) used to build its own copy of the segment geometry; this module is now
the single source of truth.  A table is four parallel segment arrays plus the
sorted key column:

    position(k) ~ base[s] + (k - start_key[s]) * slope[s],   s = route(k)

with the paper's Eq. 1 guarantee |position(k) - true_rank(k)| <= error for
every key present in ``keys``.

The *router* -- rightmost segment whose start key is <= k -- is implemented
exactly once, in :func:`route_keys`; the host tree, the numpy engine and (in
f32 form) the device engines in ``repro.index.engine`` all defer to this
module's semantics.

This module is deliberately numpy-only (no jax import) so host-side code can
use it without touching an accelerator runtime; device conversion lives in
``repro.index.engine``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING

import numpy as np

from repro.analysis.sanitizer import published_array

if TYPE_CHECKING:  # avoid a module-level cycle with repro.core
    from repro.core.segmentation import Mode, Segments


def route_keys(start_keys: np.ndarray, queries) -> np.ndarray:
    """THE router (Alg. 3 line 1): rightmost segment with start_key <= q.

    Queries below the first start key clamp to segment 0, above the last to
    the final segment.  All other route implementations in the repo must agree
    with this one (the device engines mirror it in f32).
    """
    sid = np.searchsorted(start_keys, queries, side="right") - 1
    return np.clip(sid, 0, start_keys.shape[0] - 1)


@dataclasses.dataclass(frozen=True)
class SegmentTable:
    """Immutable packed index: segment metadata + the sorted key column.

    ``error`` is the bound the segmentation satisfies over ``keys`` (for a
    tree with an insert buffer this is the *segmentation* budget err_seg, so
    the user-visible bound still holds; see tree.py Sec. 5 notes).  ``epoch``
    tags published snapshots (see repro.index.snapshot); 0 means "built from
    scratch".
    """

    start_key: np.ndarray  # (S,) f64  first key of each segment
    slope: np.ndarray      # (S,) f64  positions per key unit
    base: np.ndarray       # (S,) i64  global rank of the segment's first key
    seg_end: np.ndarray    # (S,) i64  one past the segment's last rank
    keys: np.ndarray       # (N,) f64  the sorted key column
    error: int
    epoch: int = 0

    def __post_init__(self):
        # enforce the class contract at construction, not just by convention:
        # every array a reader can reach through a table is non-writeable, so
        # a latent in-place mutation raises ValueError at the write site.
        # Views of caller-writeable scratch buffers are copied first (freezing
        # only the view would leave the base writable -- and alias it).
        for name in ("start_key", "slope", "base", "seg_end", "keys"):
            object.__setattr__(self, name, published_array(getattr(self, name)))

    # ----------------------------------------------------------- construction
    @classmethod
    def from_segments(cls, keys: np.ndarray, segs: "Segments",
                      error: int | None = None, epoch: int = 0) -> "SegmentTable":
        """Package a ShrinkingCone/DP output and its key column as a table.

        The key column is always copied: a table must never alias a buffer
        the caller (or the mutable tree) could write through."""
        keys = np.array(keys, np.float64, copy=True)
        base = np.asarray(segs.base, np.int64)
        seg_end = np.concatenate([base[1:], [keys.shape[0]]]).astype(np.int64)
        return cls(
            start_key=np.asarray(segs.start_key, np.float64),
            slope=np.asarray(segs.slope, np.float64),
            base=base,
            seg_end=seg_end,
            keys=keys,
            error=int(segs.error if error is None else error),
            epoch=int(epoch),
        )

    @classmethod
    def from_keys(cls, keys: np.ndarray, error: int, *, mode: "Mode" = "paper",
                  segs: "Segments | None" = None, assume_sorted: bool = False,
                  epoch: int = 0) -> "SegmentTable":
        """Segment ``keys`` (Alg. 2) and build the table in one step."""
        from repro.core.segmentation import shrinking_cone  # lazy: no cycle
        keys = np.asarray(keys, np.float64)
        if keys.shape[0] == 0:
            return cls.empty(error, epoch=epoch)
        if not assume_sorted:
            keys = np.sort(keys, kind="stable")
        if segs is None:
            segs = shrinking_cone(keys, error, mode=mode)
        return cls.from_segments(keys, segs, error=error, epoch=epoch)

    @classmethod
    def empty(cls, error: int, epoch: int = 0) -> "SegmentTable":
        """Zero-key table: one degenerate segment with an empty [0, 0) rank
        range, so routing and windows stay well-defined (every lookup misses).
        Zero segments would break ``route_keys`` (clip would wrap to -1)."""
        return cls(
            start_key=np.zeros(1, np.float64), slope=np.zeros(1, np.float64),
            base=np.zeros(1, np.int64), seg_end=np.zeros(1, np.int64),
            keys=np.empty(0, np.float64), error=int(error), epoch=int(epoch))

    # ----------------------------------------------------------------- sizing
    @property
    def n_segments(self) -> int:
        return int(self.start_key.shape[0])

    @property
    def n_keys(self) -> int:
        return int(self.keys.shape[0])

    def size_bytes(self) -> int:
        """Sec. 6.2 accounting: 24B of metadata per segment."""
        return self.n_segments * 24

    # ----------------------------------------------------------------- lookup
    def route(self, queries) -> np.ndarray:
        return route_keys(self.start_key, np.asarray(queries, np.float64))

    def _locate(self, queries) -> tuple[np.ndarray, np.ndarray]:
        """Route + interpolate: (segment id, predicted rank clamped into the
        owning segment's range so gap queries cannot overshoot).  The one
        prediction implementation (the device path mirrors it in f32)."""
        q = np.asarray(queries, np.float64)
        sid = self.route(q)
        local = np.rint((q - self.start_key[sid]) * self.slope[sid])
        pred = self.base[sid] + local.astype(np.int64)
        return sid, np.clip(pred, self.base[sid], self.seg_end[sid])

    def predict(self, queries) -> np.ndarray:
        """Predicted global ranks; within ``error`` of the true rank (Eq. 1)."""
        return self._locate(queries)[1]

    def window(self, queries) -> tuple[np.ndarray, np.ndarray]:
        """Per-query [lo, hi) rank window guaranteed to contain any present key."""
        sid, pred = self._locate(queries)
        lo = np.maximum(self.base[sid], pred - self.error)
        hi = np.minimum(self.seg_end[sid], pred + self.error + 1)
        return lo.astype(np.int64), hi.astype(np.int64)

    def page(self, sid: int) -> np.ndarray:
        """The sid-th segment's slice of the key column (a view)."""
        return self.keys[self.base[sid]:self.seg_end[sid]]

    # ------------------------------------------------------------ invariants
    def max_abs_error(self) -> float:
        """Eq. 1 check: max |predicted - true| rank over every stored key,
        each evaluated against its containing segment."""
        n = self.n_keys
        if n == 0:
            return 0.0
        true = np.arange(n, dtype=np.float64)
        sid = np.searchsorted(self.base, true, side="right") - 1
        pred = self.base[sid] + (self.keys - self.start_key[sid]) * self.slope[sid]
        return float(np.max(np.abs(pred - true)))


def numpy_lookup(table: SegmentTable, queries) -> np.ndarray:
    """Host bounded bisect over the f64 key column (the ``numpy`` engine
    backend and the tree's batch path): interpolate then log2(2*err) halving
    steps inside the window.  Returns global ranks -- the *leftmost*
    occurrence for duplicated keys -- and -1 if absent."""
    q = np.asarray(queries, np.float64)
    keys = table.keys
    n = keys.shape[0]
    if n == 0:                      # empty table: every probe misses
        return np.full(q.shape, -1, np.int64)
    lo, hi = table.window(q)
    steps = max(1, math.ceil(math.log2(2 * table.error + 2)))
    for _ in range(steps):
        mid = (lo + hi) // 2
        mid_c = np.minimum(mid, max(n - 1, 0))
        go_right = (keys[mid_c] < q) & (lo < hi)
        lo = np.where(go_right, mid + 1, lo)
        hi = np.where(go_right, hi, mid)
    ok = (lo < n) & (keys[np.minimum(lo, max(n - 1, 0))] == q)
    # a duplicate run straddling a segment boundary clamps the window to the
    # routed (rightmost) segment, so the bisect lands on the in-segment
    # leftmost; snap such hits to the global leftmost occurrence (rare: only
    # when the left neighbour is also equal to the query)
    fix = ok & (lo > 0) & (keys[np.maximum(lo - 1, 0)] == q)
    if np.any(fix):
        hits = np.flatnonzero(fix)      # bisect only the queries that need it
        lo = lo.copy()
        lo.flat[hits] = np.searchsorted(keys, q.flat[hits], side="left")
    return np.where(ok, lo, -1).astype(np.int64)


def numpy_search(table: SegmentTable, queries, side: str = "left") -> np.ndarray:
    """Host bounded-window rank search: the ``numpy`` backend's primitive for
    the typed query plane (see ``repro.index.query``).

    Returns ``np.searchsorted(table.keys, queries, side=side)`` -- the
    insertion rank of every query -- computed with the same interpolate +
    log2(2*err) halving steps as :func:`numpy_lookup` instead of a full-column
    bisect.  ``side="left"`` is the rank of the first key >= q (the leftmost
    occurrence when q is present), ``side="right"`` one past the last key
    <= q; every query verb (point / range / count / predecessor / successor)
    derives from these two.

    The +-error window only bounds ranks of *in-window* insertion points; a
    duplicate run straddling the routed segment (or longer than the window)
    parks the bounded result inside the run, which the side-specific snap at
    the end detects (left: the left neighbour still equals q; right: the
    landing key itself still equals q) and repairs with a full ``searchsorted``
    over just the flagged queries -- the generalization of the
    ``numpy_lookup`` leftmost fix to both sides.
    """
    if side not in ("left", "right"):
        raise ValueError(f"side must be 'left' or 'right', got {side!r}")
    q = np.asarray(queries, np.float64)
    keys = table.keys
    n = keys.shape[0]
    if n == 0:                      # empty table: every rank is 0
        return np.zeros(q.shape, np.int64)
    if q.size <= 8:
        # tiny probes (range/predecessor bounds are 1-2 queries): one C-level
        # full-column bisect costs less than the ~log2(2e) vectorized loop
        # iterations below ever could in numpy dispatch overhead alone;
        # same contract, so the window path stays the batch implementation
        return np.searchsorted(keys, q, side=side).astype(np.int64)
    lo, hi = table.window(q)
    steps = max(1, math.ceil(math.log2(2 * table.error + 2)))
    for _ in range(steps):
        mid = (lo + hi) // 2
        mid_c = np.minimum(mid, max(n - 1, 0))
        if side == "left":
            go_right = (keys[mid_c] < q) & (lo < hi)
        else:
            go_right = (keys[mid_c] <= q) & (lo < hi)
        lo = np.where(go_right, mid + 1, lo)
        hi = np.where(go_right, hi, mid)
    if side == "left":
        fix = (lo > 0) & (keys[np.maximum(lo - 1, 0)] == q)
    else:
        fix = (lo < n) & (keys[np.minimum(lo, n - 1)] == q)
    if np.any(fix):
        hits = np.flatnonzero(fix)
        lo = lo.copy()
        lo.flat[hits] = np.searchsorted(keys, q.flat[hits], side=side)
    return lo.astype(np.int64)


def shard_cut_indices(keys: np.ndarray, n_shards: int) -> np.ndarray:
    """Duplicate-safe equal-count cut indices into sorted ``keys``.

    Returns ``(n_shards,)`` strictly increasing indices with ``cuts[0] == 0``;
    shard d owns ``keys[cuts[d]:cuts[d+1]]``.  Each cut starts at an
    equal-count target (``d * n // n_shards``) and is *snapped to the start of
    the unique-key run containing it*, so a run of duplicate keys never
    straddles two shards.  Without the snap, the boundary router (which sends
    a query to the rightmost shard whose first key is <= it) and the partition
    would disagree on duplicated boundary keys and sharded lookups would lose
    the leftmost-rank contract of the single-table engines.

    When snapping left would collide with the previous cut (a duplicate run
    longer than a shard), the cut advances to the next unique-run start
    instead; raises ``ValueError`` when ``keys`` has fewer distinct values
    than ``n_shards`` (no duplicate-safe partition into non-empty shards
    exists)."""
    keys = np.asarray(keys, np.float64)
    n = keys.shape[0]
    if n_shards < 1:
        raise ValueError("n_shards must be >= 1")
    if n < n_shards:
        raise ValueError(f"cannot cut {n} keys into "
                         f"{n_shards} non-empty shards")
    # first index of every distinct-key run (keys sorted => runs contiguous)
    run_starts = np.flatnonzero(
        np.concatenate(([True], keys[1:] != keys[:-1])))
    u = run_starts.shape[0]
    if u < n_shards:
        raise ValueError(f"cannot cut {u} distinct keys into {n_shards} "
                         f"duplicate-safe non-empty shards")
    m = n // n_shards
    cuts = np.zeros(n_shards, np.int64)
    prev = 0                        # index into run_starts of the last cut
    for j in range(1, n_shards):
        pos = int(np.searchsorted(run_starts, j * m, side="right")) - 1
        # stay ahead of the previous cut, and leave one distinct run start
        # for every remaining shard (both bounds are always satisfiable
        # because u >= n_shards)
        pos = min(max(pos, prev + 1), u - (n_shards - j))
        cuts[j] = run_starts[pos]
        prev = pos
    return cuts


def shard_boundaries(keys: np.ndarray, n_shards: int) -> np.ndarray:
    """Equal-count cut points: the first key owned by each shard.

    These are the replicated top-level router of the sharded index -- the
    paper's structure recursed once.  Routing a query through them with
    :func:`route_keys` names its owning shard; queries below the first cut
    clamp to shard 0, so the partition is total over the key space.  Cuts are
    duplicate-safe (see :func:`shard_cut_indices`): a boundary is always the
    first occurrence of its key, so equal keys all route to, and live in,
    the same shard."""
    keys = np.asarray(keys, np.float64)
    return keys[shard_cut_indices(keys, n_shards)].copy()


def shard_partition(keys: np.ndarray, n_shards: int
                    ) -> tuple[np.ndarray, list[np.ndarray]]:
    """Range-partition sorted ``keys`` into ``n_shards`` contiguous runs.

    Returns ``(boundaries, splits)`` where ``boundaries`` are the
    :func:`shard_boundaries` cuts and ``splits[d]`` is shard d's key run.
    Unlike :func:`build_shard_tables` nothing is dropped: the tail beyond the
    equal-count cut lands in the last shard, so ``concat(splits) == keys``
    and a shard's global rank offset is the summed length of its
    predecessors.  Cuts snap to unique-key run starts
    (:func:`shard_cut_indices`), so no duplicate run straddles a shard."""
    keys = np.asarray(keys, np.float64)
    cuts = shard_cut_indices(keys, n_shards)
    return keys[cuts].copy(), np.split(keys, cuts[1:])


def build_shard_tables(keys: np.ndarray, error: int, n_shards: int,
                       mode: "Mode" = "paper") -> list[SegmentTable]:
    """Equal-count contiguous range partition: one independent SegmentTable per
    shard (local ranks).  The tail beyond ``n_shards * (n // n_shards)`` is
    dropped, as in the original sharded builder (callers handle it); the
    serving-side partition that keeps every key is :func:`shard_partition`.
    Cuts here are *rectangular*, not duplicate-safe: the (D, M) device layout
    requires equal shard sizes, so the distributed path assumes distinct keys
    (its tests and datasets are duplicate-free)."""
    keys = np.asarray(keys, np.float64)
    m = keys.shape[0] // n_shards
    shards = keys[: m * n_shards].reshape(n_shards, m)
    return [SegmentTable.from_keys(s, error, mode=mode, assume_sorted=True)
            for s in shards]
