"""LSM-style tiered write plane: memtable -> sorted learned runs -> compaction.

The paper's Alg. 4 delta-buffer absorbs *moderate* insert rates: every
``publish()`` re-segments the whole tree, so a write-dominated workload pays a
full re-fit per buffer fill and read latency degrades with ingest.  This
module adds the missing tier structure (ROADMAP open item 2): writes land in a
small mutable **memtable**, full memtables **spill** into immutable sorted
**runs** -- each an error-bounded ``SegmentTable`` wrapped in the existing
``Snapshot``/``ServingHandle`` epoch machinery -- and a size-tiered
**Compactor** merges runs in the background, re-fitting segments strictly off
the serving path.

    writes -->  Memtable (bounded, sorted in place)
                   | spill (full)                       newest
                   v                                      |
                Run[L0] Run[L0] ... --merge-->  Run[L1] ...  Run[Lk]
                                                          |
                                                        oldest

**One atomic manifest.**  The whole level structure -- memtable reference plus
the newest-first run list -- lives in one immutable versioned
:class:`LevelSet`, swapped with a single reference assignment exactly like
``ShardSet``: readers pin ``self._level_set`` once per verb and keep a fully
consistent view while spills and compactions publish new manifests next to
them.  A spill never mutates the memtable a pinned reader is looking at; it
*abandons* it (the new ``LevelSet`` carries a fresh empty memtable) so the old
view stays frozen in place.

**Fan-in reads.**  All query verbs generalize the cross-shard leftmost-rank
merge: a global rank is the sum of per-source ``searchsorted`` ranks over the
memtable and every live run, minus the occurrences *shadowed* by newer
tombstones.  Deletes append a tombstone key that hides every occurrence in
strictly older runs; upserts are an atomic delete+insert, so the newest level
wins.  Shadow corrections are precomputed when a ``LevelSet`` is built
(``Run.shadow_keys`` / ``Run.shadow_cum`` prefix counts), which keeps the verb
path to pure vectorized ``searchsorted`` arithmetic -- exact because all
occurrences of a tombstoned key compare equal, so side semantics are
preserved.

Plan integration: ``fit.plan`` resolves ``write_mode="lsm"`` for write-heavy
specs (or when ``error`` leaves no room for an Alg. 4 buffer) and sizes
``memtable_capacity`` / ``level_fanout``; ``open_index`` then builds this
service.  ``publish()`` is the maintenance verb the async pipeline cadence
already drives: it spills an overfull memtable and runs one compaction step,
returning a dict (``{}`` when idle) the pipeline counts as publish activity.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import TYPE_CHECKING, NamedTuple, Sequence

import numpy as np

from repro.analysis import sanitizer

from .query import (PointResult, RangeResult, check_range, check_side,
                    merge_sorted_sources)
from .snapshot import ServingHandle, Snapshot
from .telemetry import (CH_COMPACT, CH_MEMTABLE, CH_QUERY_MIX, CH_READ_AMP,
                        CH_RUN_COUNT, CH_SPILL, LsmMetrics, Monitor,
                        ServiceMetrics, tier_metrics)

if TYPE_CHECKING:  # runtime import is lazy (fit builds services via plans)
    from .fit import IndexPlan

DEFAULT_MEMTABLE_CAPACITY = 4096
DEFAULT_LEVEL_FANOUT = 4

# every Nth verb call records its fan-in width (CH_READ_AMP); amortized like
# the sharded service's served-keys sampling
_AMP_SAMPLE_EVERY = 8

_EMPTY_KEYS = np.empty(0, dtype=np.float64)
_ZERO_CUM = np.zeros(1, dtype=np.int64)


def _inject_monitor(engine_opts: dict[str, dict] | None,
                    monitor: Monitor | None) -> dict[str, dict]:
    """Thread the service's monitor into the dispatch-engine kwargs (the
    per-tier latency hook) without mutating the caller's / the plan's dict."""
    opts = {k: dict(v) for k, v in (engine_opts or {}).items()}
    if monitor is not None:
        opts.setdefault("dispatch", {})["monitor"] = monitor
    return opts


def _sorted_unique(values) -> np.ndarray:
    arr = np.asarray(sorted(values), dtype=np.float64)
    return arr if arr.size else _EMPTY_KEYS


class MemtableFullError(RuntimeError):
    """Insert hit a full memtable outside the service's spill loop."""


# ---------------------------------------------------------------------------
# memtable
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MemView:
    """Immutable point-in-time view of a memtable (the spill/read interface).

    ``keys`` is sorted ascending; ``tombstones`` is sorted unique.  Arrays are
    frozen copies -- safe to hand to a ``SegmentTable`` or hold across a
    concurrent writer.
    """
    keys: np.ndarray
    payload: np.ndarray | None
    tombstones: np.ndarray
    version: int

    def __post_init__(self) -> None:
        object.__setattr__(self, "keys", sanitizer.published_array(self.keys))
        object.__setattr__(self, "tombstones",
                           sanitizer.published_array(self.tombstones))
        if self.payload is not None:
            object.__setattr__(self, "payload",
                               sanitizer.published_array(self.payload))

    @property
    def n_keys(self) -> int:
        return int(self.keys.size)


class Memtable:
    """Bounded sorted in-place write buffer: the mutable L0 of the LSM tree.

    Keys live in a preallocated float64 buffer kept sorted by memmove-style
    slice shifts (O(capacity) per write -- the capacity is small by design,
    sized by the planner so a spill fires every few hundred ms of expected
    ingest).  Deletes remove live occurrences *and* record the key in a
    tombstone set that shadows older runs until compaction retires it.

    Readers call :meth:`view` for an immutable ``MemView``; the view is
    cached and only rebuilt after a mutation, so a read-heavy phase costs one
    copy total.  All mutators take ``Memtable._lock``; the service additionally
    serializes writers under its own write lock, so this lock only guards
    against view() racing a mutator.
    """

    def __init__(self, capacity: int,
                 payload_dtype: np.dtype | None = None) -> None:
        if capacity < 2:
            raise ValueError(f"memtable capacity must be >= 2, got {capacity}")
        self.capacity = int(capacity)
        self._lock = sanitizer.make_lock("Memtable._lock")
        self._buf = np.empty(self.capacity, dtype=np.float64)
        self._pbuf = (None if payload_dtype is None
                      else np.empty(self.capacity, dtype=payload_dtype))
        self._n = 0
        self._tombs: set[float] = set()
        self._version = 0
        self._cached_view: MemView | None = None

    # -- occupancy ---------------------------------------------------------
    @property
    def size(self) -> int:
        return self._n

    @property
    def tombstone_count(self) -> int:
        return len(self._tombs)

    @property
    def room(self) -> int:
        return self.capacity - self._n

    def is_full(self) -> bool:
        """Spill trigger: key buffer full, or the tombstone set has grown to
        capacity (tombstones occupy the spill run, so they count)."""
        return self._n >= self.capacity or len(self._tombs) >= self.capacity

    def is_empty(self) -> bool:
        return self._n == 0 and not self._tombs

    # -- mutators ----------------------------------------------------------
    def insert(self, key: float, value=None) -> None:
        with self._lock:
            self._insert_locked(key, value)

    def insert_many(self, keys, values=None) -> None:
        """Vectorized batch insert (one stable two-way merge, not N shifts).

        The batch must fit in the remaining room; the service chunks larger
        batches around spills.
        """
        with self._lock:
            batch = np.asarray(keys, dtype=np.float64).ravel()
            if batch.size == 0:
                return
            if self._n + batch.size > self.capacity:
                raise MemtableFullError(
                    f"batch of {batch.size} overflows memtable "
                    f"({self._n}/{self.capacity} used)")
            order = np.argsort(batch, kind="stable")
            incoming = batch[order]
            current = self._buf[:self._n]
            slots = (np.searchsorted(current, incoming, side="right")
                     + np.arange(incoming.size))
            merged = np.empty(self._n + incoming.size, dtype=np.float64)
            mask = np.zeros(merged.size, dtype=bool)
            mask[slots] = True
            merged[mask] = incoming
            merged[~mask] = current
            if self._pbuf is not None:
                vals = (np.zeros(batch.size, dtype=self._pbuf.dtype)
                        if values is None
                        else np.asarray(values).ravel()[order])
                pmerged = np.empty(merged.size, dtype=self._pbuf.dtype)
                pmerged[mask] = vals
                pmerged[~mask] = self._pbuf[:self._n]
                self._pbuf[:merged.size] = pmerged
            self._buf[:merged.size] = merged
            self._n = merged.size
            self._dirty_locked()

    def delete(self, key: float) -> int:
        """Remove live occurrences of ``key`` here and tombstone it for every
        strictly older run.  Returns the number of memtable occurrences
        removed (the shadowed run occurrences are unknowable without a
        read)."""
        with self._lock:
            return self._delete_locked(key)

    def upsert(self, key: float, value=None) -> None:
        """Atomic delete+insert: afterwards exactly one live occurrence of
        ``key`` exists across all levels, carrying ``value``."""
        with self._lock:
            self._delete_locked(key)
            self._insert_locked(key, value)

    def _insert_locked(self, key: float, value) -> None:
        if self._n >= self.capacity:
            raise MemtableFullError(
                f"memtable full ({self.capacity} keys); spill first")
        k = float(key)
        pos = int(np.searchsorted(self._buf[:self._n], k, side="right"))
        self._buf[pos + 1:self._n + 1] = self._buf[pos:self._n].copy()
        self._buf[pos] = k
        if self._pbuf is not None:
            self._pbuf[pos + 1:self._n + 1] = self._pbuf[pos:self._n].copy()
            self._pbuf[pos] = 0 if value is None else value
        self._n += 1
        self._dirty_locked()

    def _delete_locked(self, key: float) -> int:
        k = float(key)
        lo = int(np.searchsorted(self._buf[:self._n], k, side="left"))
        hi = int(np.searchsorted(self._buf[:self._n], k, side="right"))
        removed = hi - lo
        if removed:
            self._buf[lo:self._n - removed] = self._buf[hi:self._n].copy()
            if self._pbuf is not None:
                self._pbuf[lo:self._n - removed] = \
                    self._pbuf[hi:self._n].copy()
            self._n -= removed
        self._tombs.add(k)
        self._dirty_locked()
        return removed

    def _dirty_locked(self) -> None:
        self._version += 1
        self._cached_view = None

    # -- readers -----------------------------------------------------------
    def view(self) -> MemView:
        """Immutable snapshot of the current contents (cached until the next
        mutation)."""
        cached = self._cached_view
        if cached is not None:
            return cached
        with self._lock:
            cached = self._cached_view
            if cached is None:
                cached = MemView(
                    keys=self._buf[:self._n].copy(),
                    payload=(None if self._pbuf is None
                             else self._pbuf[:self._n].copy()),
                    tombstones=_sorted_unique(self._tombs),
                    version=self._version)
                self._cached_view = cached
            return cached


# ---------------------------------------------------------------------------
# runs and the level manifest
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Run:
    """One immutable sorted learned run: a published ``Snapshot`` plus the
    tombstones it carries and the shadow corrections applied *to* it.

    ``tombstones`` are the deletes this run absorbed when it was spilled or
    merged; they hide matching occurrences in every **strictly older** run (a
    key re-inserted after the delete spills into this same run and is not its
    own victim).  ``shadow_keys``/``shadow_cum`` are the precomputed inverse:
    the sorted unique tombstone keys of all strictly *newer* runs, with
    ``shadow_cum[i]`` = occurrences of ``shadow_keys[:i]`` in this run --
    recomputed by :func:`_with_shadows` whenever the run list changes, so the
    verb path subtracts shadowed ranks with two ``searchsorted`` calls.
    """
    snapshot: Snapshot
    handle: ServingHandle
    tombstones: np.ndarray
    level: int
    run_id: int
    shadow_keys: np.ndarray
    shadow_cum: np.ndarray

    def __post_init__(self) -> None:
        object.__setattr__(self, "tombstones",
                           sanitizer.published_array(self.tombstones))
        object.__setattr__(self, "shadow_keys",
                           sanitizer.published_array(self.shadow_keys))
        object.__setattr__(self, "shadow_cum",
                           sanitizer.published_array(self.shadow_cum))

    @property
    def n_keys(self) -> int:
        return self.snapshot.n_keys

    @property
    def n_shadowed(self) -> int:
        """Occurrences in this run hidden by newer runs' tombstones."""
        return int(self.shadow_cum[-1])

    @property
    def live_keys(self) -> int:
        return self.n_keys - self.n_shadowed


@dataclasses.dataclass(frozen=True)
class LevelSet:
    """The atomic level manifest: one memtable + runs ordered newest-first.

    Swapped whole with a single reference assignment (``ShardSet``
    discipline): a reader that pinned version N keeps N's memtable object and
    run tuple even while a spill/compaction publishes N+1 -- the memtable in
    an old manifest is *abandoned* by the spill, never mutated, so the pinned
    view stays internally consistent.
    """
    version: int
    memtable: Memtable
    runs: tuple[Run, ...]

    @property
    def n_runs(self) -> int:
        return len(self.runs)

    def run_levels(self) -> tuple[int, ...]:
        """Distinct levels present, ascending (0 = freshest spills)."""
        return tuple(sorted({r.level for r in self.runs}))

    def runs_per_level(self) -> tuple[int, ...]:
        """Run count for each level from 0 through the deepest occupied."""
        if not self.runs:
            return ()
        deepest = max(r.level for r in self.runs)
        counts = [0] * (deepest + 1)
        for r in self.runs:
            counts[r.level] += 1
        return tuple(counts)

    def keys_per_level(self) -> tuple[int, ...]:
        if not self.runs:
            return ()
        deepest = max(r.level for r in self.runs)
        totals = [0] * (deepest + 1)
        for r in self.runs:
            totals[r.level] += r.n_keys
        return tuple(totals)


def _occurrence_cum(run_keys: np.ndarray, probe: np.ndarray) -> np.ndarray:
    """Prefix occurrence counts: out[i] = occurrences of probe[:i] in
    run_keys (length ``probe.size + 1``, out[0] == 0)."""
    if probe.size == 0:
        return _ZERO_CUM
    lo = np.searchsorted(run_keys, probe, side="left")
    hi = np.searchsorted(run_keys, probe, side="right")
    out = np.empty(probe.size + 1, dtype=np.int64)
    out[0] = 0
    np.cumsum(hi - lo, out=out[1:])
    return out


def _with_shadows(runs: Sequence[Run]) -> tuple[Run, ...]:
    """Recompute every run's shadow arrays for a newest-first ordering.

    Each run is shadowed by the union of tombstones of all strictly newer
    runs.  Returns fresh ``Run`` objects (``dataclasses.replace``) sharing the
    snapshots and serving handles -- engines stay warm across reshadowing.
    """
    out: list[Run] = []
    newer_tombs: set[float] = set()
    for run in runs:
        if newer_tombs:
            shadow_keys = _sorted_unique(newer_tombs)
            shadow_cum = _occurrence_cum(run.snapshot.table.keys, shadow_keys)
        else:
            shadow_keys, shadow_cum = _EMPTY_KEYS, _ZERO_CUM
        out.append(dataclasses.replace(run, shadow_keys=shadow_keys,
                                       shadow_cum=shadow_cum))
        newer_tombs.update(run.tombstones.tolist())
    return tuple(out)


class _LsmView(NamedTuple):
    """One pinned, internally consistent read view (one verb invocation)."""
    level_set: LevelSet
    mem: MemView
    engines: tuple
    # per-run memtable-tombstone corrections: (extra_keys, extra_cum), the
    # live-memtable tombstones not already in the run's shadow_keys
    extras: tuple
    total: int  # live occurrences across all sources


# ---------------------------------------------------------------------------
# compactor
# ---------------------------------------------------------------------------
class Compactor:
    """Size-tiered background merge: K runs on one level -> one run a level
    deeper, re-fit off the serving path.

    ``step()`` picks the shallowest level holding >= ``fanout`` runs, merges
    the whole group under ``Compactor._lock`` (the expensive part: tombstone
    application, stable key merge, ``SegmentTable.from_keys`` re-fit) without
    touching the service write lock, then swaps the manifest in a brief
    critical section that reconciles any runs spilled meanwhile.  Tombstones
    merging into the oldest run are retired -- nothing older exists for them
    to shadow.  ``start()`` runs steps on a daemon cadence for standalone use;
    under the async pipeline the maintenance loop drives ``service.publish()``
    which calls ``step()`` directly.
    """

    def __init__(self, service: "LsmIndexService", *, fanout: int = 4,
                 interval_s: float = 0.05) -> None:
        self.service = service
        self.fanout = max(2, int(fanout))
        self.interval_s = float(interval_s)
        self._lock = sanitizer.make_lock("Compactor._lock")
        self.compactions = 0
        self._thread: threading.Thread | None = None
        self._stop_event = threading.Event()
        self._fatal: BaseException | None = None
        # test seam: called once per merged group inside the (slow) merge
        # section, before the manifest swap -- lets the race test widen the
        # compaction window deterministically
        self._merge_hook = None

    def pick(self, runs: Sequence[Run]) -> list[Run] | None:
        """The merge group: all runs on the shallowest level with >= fanout
        of them (newest-first order preserved), or None."""
        by_level: dict[int, list[Run]] = {}
        for r in runs:
            by_level.setdefault(r.level, []).append(r)
        for level in sorted(by_level):
            if len(by_level[level]) >= self.fanout:
                return by_level[level]
        return None

    def step(self) -> int:
        """One compaction pass; returns the number of runs merged (0 =
        nothing to do)."""
        with self._lock:
            svc = self.service
            level_set = svc._level_set
            group = self.pick(level_set.runs)
            if group is None:
                return 0
            # valid at swap time too: concurrent spills only *prepend* newer
            # runs, so "nothing is older than the group's tail" cannot flip
            drop_tombstones = group[-1] is level_set.runs[-1]
            if self._merge_hook is not None:
                self._merge_hook()
            merged = svc._build_merged_run(group, drop_tombstones)
            svc._swap_merged(group, merged)
            self.compactions += 1
            return len(group)

    # -- background cadence ------------------------------------------------
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop_event.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="lsm-compactor")
        self._thread.start()

    def stop(self) -> None:
        self._stop_event.set()
        thread = self._thread
        if thread is not None:
            thread.join(timeout=5.0)
            self._thread = None
        if self._fatal is not None:
            fatal, self._fatal = self._fatal, None
            raise fatal

    def _loop(self) -> None:
        while not self._stop_event.wait(self.interval_s):
            try:
                self.step()
            except BaseException as exc:  # surfaced by stop()
                self._fatal = exc
                return


# ---------------------------------------------------------------------------
# the service
# ---------------------------------------------------------------------------
class LsmIndexService:
    """Tiered write-optimized serving: the LSM counterpart to the per-tree
    Alg. 4 buffer, behind the same verb surface as ``IndexService`` /
    ``ShardedIndexService``.

    Construction mirrors the sharded service: pass the raw knobs *or* a
    resolved ``IndexPlan`` (``write_mode="lsm"``), not both.  Bulk keys load
    into a single run at the level matching their size (so the planner's
    fanout policy doesn't immediately merge a large base run with fresh
    spills); subsequent writes flow memtable -> spill -> compaction.

    Thread contract: all writers serialize on ``_write_lock``; readers are
    lock-free against the manifest (one pinned ``LevelSet`` reference per
    verb) and only touch per-run handle locks when an engine is first built.
    ``publish()`` is safe to drive from the async pipeline's maintenance
    thread concurrently with both.
    """

    def __init__(self, keys=None, error: int | None = None, *,
                 plan: "IndexPlan | None" = None,
                 memtable_capacity: int | None = None,
                 level_fanout: int | None = None,
                 payload=None, mode: str = "paper",
                 backend: str | None = None,
                 engine_opts: dict[str, dict] | None = None,
                 publish_every: int | None = None,
                 assume_sorted: bool = False,
                 monitor: Monitor | None = None,
                 background_compaction: bool = False,
                 compact_interval_s: float = 0.05,
                 # accepted for knob-compat with the other services
                 # (open_index passes through user kwargs); inert here
                 skew_threshold: float = 2.0, pending_weight: float = 1.0,
                 auto_rebalance: bool = False) -> None:
        from .fit import IndexPlan
        raw = {"error": error, "backend": backend,
               "publish_every": publish_every,
               "memtable_capacity": memtable_capacity,
               "level_fanout": level_fanout}
        if plan is None:
            if error is None:
                raise TypeError("pass error=... (raw knobs) or plan=...")
            plan = IndexPlan.from_knobs(
                error=error, backend=backend or "numpy",
                publish_every=publish_every, write_mode="lsm",
                memtable_capacity=memtable_capacity,
                level_fanout=level_fanout)
        else:
            clashing = sorted(k for k, v in raw.items() if v is not None)
            if clashing:
                raise TypeError(
                    f"pass either the raw knobs or plan=, not both -- the "
                    f"plan already fixes {', '.join(clashing)}")
        self.plan = plan
        self.error = int(plan.error)
        self.memtable_capacity = int(plan.memtable_capacity
                                     or DEFAULT_MEMTABLE_CAPACITY)
        self.level_fanout = int(plan.level_fanout or DEFAULT_LEVEL_FANOUT)
        self.default_backend = plan.backend
        self.monitor = monitor
        self._mode = mode
        self._engine_opts = _inject_monitor(plan.merge_engine_opts(
            engine_opts), monitor)
        self._write_lock = sanitizer.make_rlock("LsmIndexService._write_lock")
        self._counts_lock = sanitizer.make_lock(
            "LsmIndexService._counts_lock")
        self._query_counts = {"points": 0, "ranges": 0, "counts": 0,
                              "predecessors": 0, "successors": 0,
                              "searches": 0}
        self._amp_counter = itertools.count()
        self._run_seq = 0
        self._spills = 0
        self.compactor = Compactor(self, fanout=self.level_fanout,
                                   interval_s=compact_interval_s)

        base = np.asarray([] if keys is None else keys,
                          dtype=np.float64).ravel()
        pay = None
        if payload is not None:
            pay = np.asarray(payload).ravel()
            if pay.size != base.size:
                raise ValueError(
                    f"payload length {pay.size} != key length {base.size}")
        self.has_payload = payload is not None
        self._payload_dtype = None if pay is None else pay.dtype
        if base.size and not assume_sorted:
            order = np.argsort(base, kind="stable")
            base = base[order]
            if pay is not None:
                pay = pay[order]
        runs: tuple[Run, ...] = ()
        if base.size:
            runs = (self._make_run(base, pay,
                                   level=self._bulk_level(base.size),
                                   tombstones=_EMPTY_KEYS),)
        self._level_set = LevelSet(version=1, memtable=self._fresh_memtable(),
                                   runs=runs)
        if background_compaction:
            self.compactor.start()

    # -- construction helpers ---------------------------------------------
    @classmethod
    def from_plan(cls, keys, plan: "IndexPlan", **service_kwargs
                  ) -> "LsmIndexService":
        """Build from a resolved ``IndexPlan`` (``fit.open_index`` path)."""
        return cls(keys, plan=plan, **service_kwargs)

    def _fresh_memtable(self) -> Memtable:
        return Memtable(self.memtable_capacity,
                        payload_dtype=self._payload_dtype)

    def _bulk_level(self, n_keys: int) -> int:
        """Level whose size class fits a bulk run: capacity * fanout^L."""
        level, size_class = 0, self.memtable_capacity
        while n_keys > size_class:
            level += 1
            size_class *= self.level_fanout
        return level

    def _make_run(self, run_keys: np.ndarray, run_payload, *, level: int,
                  tombstones: np.ndarray) -> Run:
        """Fit + publish one immutable run (keys already sorted).  Shadow
        arrays start empty; ``_with_shadows`` fills them when the run joins a
        manifest."""
        self._run_seq += 1
        epoch = self._run_seq
        # an empty-key run (a spill of pure deletes) still publishes: its
        # tombstones keep shadowing older runs without live keys of its own
        snapshot = Snapshot.from_arrays(run_keys, self.error,
                                        payload=run_payload, epoch=epoch,
                                        mode=self._mode, assume_sorted=True)
        handle = ServingHandle(self._engine_opts)
        handle.install(snapshot)
        # build the default engine here, on the write/compaction path, so the
        # first reader against a fresh run never pays engine construction
        handle.engine(self.default_backend)
        return Run(snapshot=snapshot, handle=handle, tombstones=tombstones,
                   level=level, run_id=epoch, shadow_keys=_EMPTY_KEYS,
                   shadow_cum=_ZERO_CUM)

    # -- manifest access ---------------------------------------------------
    def _pin_level_set(self) -> LevelSet:
        level_set = self._level_set
        sanitizer.observe_pin(level_set.version)
        return level_set

    @property
    def level_set(self) -> LevelSet:
        """The current manifest (itself immutable; safe to hold)."""
        return self._pin_level_set()

    @property
    def version(self) -> int:
        return self._pin_level_set().version

    # -- write path --------------------------------------------------------
    def _writable_memtable(self) -> Memtable:
        """Current memtable with room for at least one write; spills first
        when full.  Caller holds ``_write_lock``."""
        level_set = self._level_set
        if level_set.memtable.is_full():
            level_set = self._spill_locked(level_set)
        return level_set.memtable

    def insert(self, key: float, value=None) -> None:
        if value is not None and not self.has_payload:
            raise ValueError("service built without payload; insert(key) only")
        with self._write_lock:
            self._writable_memtable().insert(key, value)

    def insert_many(self, keys, values=None) -> int:
        """Bulk ingest: vectorized memtable merges, spilling between chunks.
        Returns the number of keys ingested."""
        batch = np.asarray(keys, dtype=np.float64).ravel()
        vals = None
        if values is not None:
            if not self.has_payload:
                raise ValueError(
                    "service built without payload; insert_many(keys) only")
            vals = np.asarray(values).ravel()
            if vals.size != batch.size:
                raise ValueError(
                    f"values length {vals.size} != keys length {batch.size}")
        done = 0
        with self._write_lock:
            while done < batch.size:
                memtable = self._writable_memtable()
                take = min(memtable.room, batch.size - done)
                memtable.insert_many(
                    batch[done:done + take],
                    None if vals is None else vals[done:done + take])
                done += take
        return done

    def delete(self, key: float) -> None:
        """Delete every live occurrence of ``key`` across all levels
        (memtable occurrences eagerly, run occurrences via tombstone)."""
        with self._write_lock:
            self._writable_memtable().delete(key)

    def upsert(self, key: float, value=None) -> None:
        """Atomic delete+insert: one live occurrence remains, newest value
        wins across every level."""
        if value is not None and not self.has_payload:
            raise ValueError("service built without payload; upsert(key) only")
        with self._write_lock:
            self._writable_memtable().upsert(key, value)

    # -- spill -------------------------------------------------------------
    def spill(self) -> int:
        """Force the memtable into a fresh L0 run (test/bench control knob;
        the write path spills automatically on full).  Returns the number of
        keys spilled."""
        with self._write_lock:
            level_set = self._level_set
            if level_set.memtable.is_empty():
                return 0
            spilled = level_set.memtable.size
            self._spill_locked(level_set)
            return spilled

    def _spill_locked(self, level_set: LevelSet) -> LevelSet:
        """Freeze the memtable into a new L0 run and publish the successor
        manifest.  Caller holds ``_write_lock`` and passes its pinned
        manifest; the old memtable is abandoned (pinned readers keep it),
        never mutated."""
        t0 = time.perf_counter_ns()
        view = level_set.memtable.view()
        run = self._make_run(view.keys, view.payload, level=0,
                             tombstones=view.tombstones)
        runs = _with_shadows((run,) + level_set.runs)
        self._level_set = successor = LevelSet(
            version=level_set.version + 1,
            memtable=self._fresh_memtable(), runs=runs)
        self._spills += 1
        monitor = self.monitor
        if monitor is not None:
            monitor.record(CH_SPILL, float(view.n_keys),
                           float(time.perf_counter_ns() - t0))
            monitor.record(CH_RUN_COUNT, float(len(runs)))
        return successor

    # -- compaction --------------------------------------------------------
    def compact(self, max_steps: int = 1) -> int:
        """Run up to ``max_steps`` compaction passes now (foreground);
        returns total runs merged."""
        merged = 0
        for _ in range(max_steps):
            step = self.compactor.step()
            if step == 0:
                break
            merged += step
        return merged

    def _build_merged_run(self, group: Sequence[Run],
                          drop_tombstones: bool) -> Run:
        """Merge a newest-first run group into one run a level deeper.

        Within the group a newer member's tombstones permanently delete older
        members' occurrences; occurrences shadowed by runs *outside* (newer
        than) the group are kept -- those tombstones stay live and reshadow
        the merged run at swap.  Runs on the compactor thread holding only
        ``Compactor._lock``; touches no service state besides ``_run_seq``
        (guarded by being the only compaction in flight).
        """
        t0 = time.perf_counter_ns()
        kill = _EMPTY_KEYS
        parts_k: list[np.ndarray] = []
        parts_p: list[np.ndarray] = []
        tombs: set[float] = set()
        for run in group:
            run_keys = run.snapshot.table.keys
            if kill.size and run_keys.size:
                live = ~np.isin(run_keys, kill)
                parts_k.append(run_keys[live])
                if self.has_payload:
                    parts_p.append(run.snapshot.payload[live])
            else:
                parts_k.append(run_keys)
                if self.has_payload:
                    parts_p.append(run.snapshot.payload)
            tombs.update(run.tombstones.tolist())
            kill = _sorted_unique(tombs)
        # stable merge keeps newest-first order among equal keys, preserving
        # the fan-in's duplicate payload ordering after the merge
        merged_keys, merged_payload = merge_sorted_sources(
            parts_k, parts_p if self.has_payload else None)
        run = self._make_run(
            merged_keys, merged_payload, level=group[0].level + 1,
            tombstones=_EMPTY_KEYS if drop_tombstones else _sorted_unique(
                tombs))
        monitor = self.monitor
        if monitor is not None:
            monitor.record(CH_COMPACT, float(len(group)),
                           float(merged_keys.size),
                           float(time.perf_counter_ns() - t0))
        return run

    def _swap_merged(self, group: Sequence[Run], merged: Run) -> None:
        """Publish the post-compaction manifest: replace the group with the
        merged run in place, reconciling runs spilled since the group was
        picked (spills only prepend, so group members are matched by
        run_id)."""
        group_ids = {r.run_id for r in group}
        with self._write_lock:
            level_set = self._level_set
            runs: list[Run] = []
            placed = False
            for run in level_set.runs:
                if run.run_id in group_ids:
                    if not placed:
                        runs.append(merged)
                        placed = True
                else:
                    runs.append(run)
            if not placed:  # group vanished? impossible, but stay safe
                runs.append(merged)
            self._level_set = LevelSet(version=level_set.version + 1,
                                       memtable=level_set.memtable,
                                       runs=_with_shadows(runs))
            monitor = self.monitor
            if monitor is not None:
                monitor.record(CH_RUN_COUNT, float(len(runs)))

    # -- maintenance (pipeline duck-type) ----------------------------------
    def publish(self) -> dict:
        """One maintenance tick: spill if the memtable is full (writes
        normally spill inline; this catches tombstone-only fills and idle
        flushes) and run one compaction step.  Returns ``{}`` when there was
        nothing to do -- the async pipeline counts truthy results as publish
        activity."""
        out: dict[str, int] = {}
        spilled = self._maybe_spill()
        if spilled:
            out["spilled"] = spilled
        merged = self.compact()
        if merged:
            out["compacted"] = merged
        monitor = self.monitor
        if monitor is not None:
            self._record_occupancy()
        return out

    def _maybe_spill(self) -> int:
        with self._write_lock:
            level_set = self._level_set
            memtable = level_set.memtable
            if not memtable.is_full():
                return 0
            spilled = memtable.size
            self._spill_locked(level_set)
            return spilled

    def _record_occupancy(self) -> None:
        level_set = self._level_set
        memtable = level_set.memtable
        monitor = self.monitor
        if monitor is not None:
            monitor.record(CH_MEMTABLE, float(memtable.size),
                           float(memtable.tombstone_count),
                           float(memtable.capacity))

    # -- read path ---------------------------------------------------------
    def _pin_view(self, backend: str | None = None) -> _LsmView:
        """Pin one consistent manifest and prebuild per-run corrections for
        the verb math (engines, newer-run shadows are already on the runs;
        live memtable tombstones are folded in here, deduplicated against
        each run's shadow_keys so nothing is subtracted twice)."""
        chosen = backend or self.default_backend
        level_set = self._pin_level_set()
        mem = level_set.memtable.view()
        engines = tuple(r.handle.engine(chosen) for r in level_set.runs)
        extras = []
        total = mem.n_keys
        for run in level_set.runs:
            if mem.tombstones.size:
                extra_keys = np.setdiff1d(mem.tombstones, run.shadow_keys,
                                          assume_unique=True)
                extra_cum = _occurrence_cum(run.snapshot.table.keys,
                                            extra_keys)
            else:
                extra_keys, extra_cum = _EMPTY_KEYS, _ZERO_CUM
            extras.append((extra_keys, extra_cum))
            total += run.live_keys - int(extra_cum[-1])
        monitor = self.monitor
        if monitor is not None and next(self._amp_counter) \
                % _AMP_SAMPLE_EVERY == 0:
            monitor.record(CH_READ_AMP, float(1 + len(engines)))
        return _LsmView(level_set=level_set, mem=mem, engines=engines,
                        extras=tuple(extras), total=total)

    def _search_view(self, view: _LsmView, queries, side: str) -> np.ndarray:
        """Global live ranks: leftmost-rank fan-in over memtable + runs with
        shadowed occurrences subtracted (same merge the cross-shard stitcher
        performs over contiguous shards, generalized to overlapping
        sources)."""
        flat = np.asarray(queries, dtype=np.float64).ravel()
        ranks = np.searchsorted(view.mem.keys, flat,
                                side=side).astype(np.int64)
        for run, engine, (extra_keys, extra_cum) in zip(
                view.level_set.runs, view.engines, view.extras):
            local = np.asarray(engine.search(flat, side),
                               dtype=np.int64).ravel()
            if run.shadow_keys.size:
                local = local - run.shadow_cum[
                    np.searchsorted(run.shadow_keys, flat, side=side)]
            if extra_keys.size:
                local = local - extra_cum[
                    np.searchsorted(extra_keys, flat, side=side)]
            ranks += local
        return ranks

    def _count(self, verb: str, n: int = 1) -> None:
        with self._counts_lock:
            self._query_counts[verb] += n

    def _record_mix(self, verb_idx: int) -> None:
        monitor = self.monitor
        if monitor is not None:
            monitor.record(CH_QUERY_MIX, float(verb_idx))

    # -- verbs -------------------------------------------------------------
    def search(self, queries, side: str = "left",
               backend: str | None = None) -> np.ndarray:
        """Global live rank(s) of ``queries`` across every level."""
        check_side(side)
        with sanitizer.pin_scope("search"):
            view = self._pin_view(backend)
            arr = np.asarray(queries, dtype=np.float64)
            ranks = self._search_view(view, arr, side)
        self._count("searches", max(int(arr.size), 1))
        self._record_mix(5)
        return ranks.reshape(arr.shape) if arr.shape != ranks.shape else ranks

    def lookup(self, queries, backend: str | None = None) -> np.ndarray:
        """Leftmost live ranks (vector alias the pipeline fuses on)."""
        return self.search(queries, "left", backend)

    def point(self, query: float, backend: str | None = None) -> PointResult:
        """Membership + leftmost live rank.  With duplicates and tombstones
        in play, existence is the rank gap right-left at the query key."""
        with sanitizer.pin_scope("point"):
            view = self._pin_view(backend)
            q = np.asarray([query], dtype=np.float64)
            lo = int(self._search_view(view, q, "left")[0])
            hi = int(self._search_view(view, q, "right")[0])
        self._count("points")
        self._record_mix(0)
        return PointResult(rank=lo if hi > lo else -1, found=hi > lo)

    def count(self, lo: float, hi: float,
              backend: str | None = None) -> int:
        """Live occurrences in the inclusive key range [lo, hi]."""
        with sanitizer.pin_scope("count"):
            view = self._pin_view(backend)
            bounds = np.asarray([lo, hi], dtype=np.float64)
            lo_rank = int(self._search_view(view, bounds[:1], "left")[0])
            hi_rank = int(self._search_view(view, bounds[1:], "right")[0])
        self._count("counts")
        self._record_mix(2)
        return max(hi_rank - lo_rank, 0)

    def range(self, lo: float, hi: float,
              backend: str | None = None) -> RangeResult:
        """Materialized inclusive range scan: live keys (sorted) and, when
        the service carries payload, values ordered newest-source-first among
        duplicate keys."""
        check_range(lo, hi)
        with sanitizer.pin_scope("range"):
            view = self._pin_view(backend)
            bounds = np.asarray([lo, hi], dtype=np.float64)
            lo_rank = int(self._search_view(view, bounds[:1], "left")[0])
            hi_rank = max(int(self._search_view(view, bounds[1:],
                                                "right")[0]), lo_rank)
            keys_out, payload_out = self._materialize_range(view, lo, hi)
        self._count("ranges")
        self._record_mix(1)
        return RangeResult(lo=lo, hi=hi, lo_rank=lo_rank, hi_rank=hi_rank,
                           keys=keys_out, payload=payload_out)

    def _materialize_range(self, view: _LsmView, lo: float, hi: float):
        """Collect live in-range slices source by source (memtable first,
        then newest->oldest runs), drop shadowed occurrences, and stable-merge
        so duplicates surface newest-first."""
        bounds = np.asarray([lo, hi], dtype=np.float64)
        parts_k: list[np.ndarray] = []
        parts_p: list[np.ndarray] = []
        a = int(np.searchsorted(view.mem.keys, bounds[0], side="left"))
        b = int(np.searchsorted(view.mem.keys, bounds[1], side="right"))
        parts_k.append(view.mem.keys[a:b])
        if self.has_payload:
            parts_p.append(view.mem.payload[a:b])
        for run, engine, (extra_keys, _) in zip(
                view.level_set.runs, view.engines, view.extras):
            a = int(np.asarray(engine.search(bounds[:1], "left")).ravel()[0])
            b = int(np.asarray(engine.search(bounds[1:], "right")).ravel()[0])
            b = max(b, a)
            run_slice = run.snapshot.table.keys[a:b]
            if run_slice.size == 0:
                continue
            live = np.ones(run_slice.size, dtype=bool)
            if run.shadow_keys.size:
                live &= ~np.isin(run_slice, run.shadow_keys)
            if extra_keys.size:
                live &= ~np.isin(run_slice, extra_keys)
            parts_k.append(run_slice[live])
            if self.has_payload:
                parts_p.append(run.snapshot.payload[a:b][live])
        return merge_sorted_sources(parts_k,
                                    parts_p if self.has_payload else None)

    def predecessor(self, query: float,
                    backend: str | None = None) -> PointResult:
        """Largest live key <= query, as its global rank."""
        with sanitizer.pin_scope("predecessor"):
            view = self._pin_view(backend)
            q = np.asarray([query], dtype=np.float64)
            rank = int(self._search_view(view, q, "right")[0]) - 1
        self._count("predecessors")
        self._record_mix(3)
        return PointResult(rank=rank, found=rank >= 0)

    def successor(self, query: float,
                  backend: str | None = None) -> PointResult:
        """Smallest live key >= query, as its global rank."""
        with sanitizer.pin_scope("successor"):
            view = self._pin_view(backend)
            q = np.asarray([query], dtype=np.float64)
            rank = int(self._search_view(view, q, "left")[0])
            total = view.total
        self._count("successors")
        self._record_mix(4)
        return PointResult(rank=rank, found=rank < total)

    # -- observability -----------------------------------------------------
    def n_live_keys(self, backend: str | None = None) -> int:
        """Live occurrences across every level (oracle comparisons)."""
        with sanitizer.pin_scope("count"):
            return self._pin_view(backend).total

    def metrics(self) -> ServiceMetrics:
        """The typed observability tree, with the LSM node attached."""
        level_set = self._pin_level_set()
        memtable = level_set.memtable
        runs_per_level = level_set.runs_per_level()
        mem = memtable.view()
        live = mem.n_keys
        for run in level_set.runs:
            live += run.live_keys
            if mem.tombstones.size:
                # run occurrences the live memtable tombstones still shadow
                # (dedup against the run's own shadow set, as the fan-in does)
                extra = np.setdiff1d(mem.tombstones, run.shadow_keys,
                                     assume_unique=True)
                if extra.size:
                    live -= int(_occurrence_cum(run.snapshot.table.keys,
                                                extra)[-1])
        monitor = self.monitor
        read_amp = float(1 + level_set.n_runs)
        if monitor is not None:
            amp = monitor.channel(CH_READ_AMP)
            if amp.size:
                read_amp = float(np.mean(amp[:, 0]))
        with self._counts_lock:
            query_counts = dict(self._query_counts)
        lsm = LsmMetrics(
            level_set_version=level_set.version,
            memtable_keys=memtable.size,
            memtable_tombstones=memtable.tombstone_count,
            memtable_capacity=memtable.capacity,
            n_runs=level_set.n_runs,
            n_levels=len(runs_per_level),
            run_counts=runs_per_level,
            run_keys=level_set.keys_per_level(),
            live_keys=int(live),
            spills=self._spills,
            compactions=self.compactor.compactions,
            read_amplification=read_amp)
        return ServiceMetrics(
            service="lsm",
            shard_set_version=level_set.version,
            plan_revision=self.plan.revision,
            n_shards=1,
            imbalance=0.0,
            rebalances=0,
            rebalance_skipped=0,
            last_rebalance=None,
            pending_inserts=memtable.size + memtable.tombstone_count,
            query_counts=query_counts,
            shards=(),
            tiers=tier_metrics(monitor) if monitor is not None else (),
            lsm=lsm)

    # -- pipeline compatibility surface ------------------------------------
    def prewarm(self, backend: str | None = None,
                batch_sizes: Sequence[int] | None = None) -> None:
        """Warm per-run engines (and their dispatch tiers) off the hot path."""
        chosen = backend or self.default_backend
        level_set = self._pin_level_set()
        for run in level_set.runs:
            engine = run.handle.engine(chosen)
            warm = getattr(engine, "prewarm", None)
            if warm is not None:
                warm(batch_sizes=batch_sizes)

    def apply_plan(self, plan: "IndexPlan", *, prewarm: bool = False,
                   reshard: bool = True) -> "IndexPlan":
        """Adopt a re-planned ``IndexPlan`` in place (replanner surface).
        Engine opts and sizing knobs apply to runs built from now on;
        existing immutable runs keep serving unchanged."""
        with self._write_lock:
            self.plan = plan
            if plan.memtable_capacity:
                self.memtable_capacity = int(plan.memtable_capacity)
            if plan.level_fanout:
                self.level_fanout = int(plan.level_fanout)
                self.compactor.fanout = max(2, int(plan.level_fanout))
            self._engine_opts = _inject_monitor(
                plan.merge_engine_opts(None), self.monitor)
        if prewarm:
            self.prewarm()
        return plan

    def close(self) -> None:
        """Stop the background compactor (if running)."""
        self.compactor.stop()

    def __enter__(self) -> "LsmIndexService":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()
