"""Canonical index core: one segment table, one router, one engine per backend.

Module map (see ROADMAP.md):
  table.py    -- immutable ``SegmentTable`` + ``route_keys`` (THE router);
                 numpy-only, shared by every layer
  engine.py   -- ``LookupEngine`` registry: numpy / xla-window / xla-bisect /
                 pallas bounded-window search, ``DeviceIndex`` device form
  snapshot.py -- epoch publishing: Alg. 4 inserts -> ``publish()`` ->
                 ``ServingHandle`` atomic swap into serving

``table`` is imported eagerly (pure numpy); the engine/snapshot names are
resolved lazily (PEP 562) so host-only code -- including the tree's
``from repro.index.table import ...`` -- never pulls in jax.
"""
from .table import SegmentTable, build_shard_tables, numpy_lookup, route_keys

_ENGINE_NAMES = {
    "DeviceIndex", "LookupEngine", "LookupPlan", "available_backends",
    "device_index", "make_engine", "make_plan", "pad_keys",
    "pallas_lookup", "predict_positions", "register_backend", "xla_lookup",
}
_SNAPSHOT_NAMES = {"ServingHandle", "Snapshot", "SnapshotPublisher"}

__all__ = [
    "SegmentTable", "build_shard_tables", "numpy_lookup", "route_keys",
    *sorted(_ENGINE_NAMES), *sorted(_SNAPSHOT_NAMES),
]


def __getattr__(name):
    if name in _ENGINE_NAMES:
        from . import engine
        return getattr(engine, name)
    if name in _SNAPSHOT_NAMES:
        from . import snapshot
        return getattr(snapshot, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
