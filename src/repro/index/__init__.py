"""Canonical index core: one segment table, one router, one engine per backend.

The front door is declarative (SLO-driven, see ``fit.py``): write a
``FitSpec`` -- a latency budget, a storage budget, or an expert-pinned
error, plus workload hints -- and ``open_index(keys, spec)`` resolves it
through the Sec. 6 cost model into a ready-to-serve ``IndexService`` or
``ShardedIndexService``; ``plan(keys, spec)`` exposes the intermediate
``IndexPlan`` (with an ``explain()`` audit trail) for review first.

Module map (see ROADMAP.md):
  table.py    -- immutable ``SegmentTable`` + ``route_keys`` (THE router) +
                 the shard partition (``shard_boundaries``/``shard_partition``);
                 numpy-only, shared by every layer
  query.py    -- the typed query plane: ``PointResult``/``RangeResult`` and
                 the ``QueryVerbs`` mixin deriving point / range / count /
                 predecessor / successor from the one ``search`` primitive
  engine.py   -- ``LookupEngine`` registry: numpy / xla-window / xla-bisect /
                 pallas bounded-window search (point lookups *and* the
                 two-sided ``search`` rank primitive), ``DeviceIndex`` device
                 form, and ``DispatchEngine`` (batch-size-aware tier routing
                 with cost-model-derived default thresholds)
  snapshot.py -- epoch publishing: Alg. 4 inserts -> ``publish()`` ->
                 ``ServingHandle`` atomic swap into serving
  sharded.py  -- ``ShardedIndexService``: N key-partitioned writers with
                 per-shard epoch streams; ``pack_shard_tables`` device bridge
  lsm.py      -- ``LsmIndexService``: the tiered write plane (bounded
                 ``Memtable`` -> immutable learned runs -> background
                 ``Compactor``), one atomic versioned ``LevelSet`` manifest,
                 and the multi-level leftmost-rank fan-in for every verb
  device.py   -- ``DeviceShardedService``: the device-sharded serving plane
                 (replicated boundary router, ``shard_map`` collective
                 search under allgather / bucketed all_to_all exchange, and
                 delta epoch publish re-shipping only dirty shards' rows
                 via the versioned ``DeviceShardSet`` manifest)
  fit.py      -- ``FitSpec`` -> ``plan()`` -> ``IndexPlan`` -> ``open_index``:
                 the Sec. 6 cost model resolving SLOs into every knob above
  pipeline.py -- ``AsyncIndexService``/``open_pipeline``: the coalescing
                 async front door (concurrent callers fuse into one
                 fast-tier batch) + the background publish/rebalance cadence
  telemetry.py - ``Monitor`` (lock-free named-channel recorder, in-memory /
                 JSONL backends), the typed ``MetricsSnapshot`` tree
                 (``ServiceMetrics``), and ``Replanner`` -- the measure ->
                 re-fit -> re-plan feedback loop hot-swapping plans live

``table`` and ``query`` are imported eagerly (pure numpy); the
engine/snapshot/sharded/fit names are resolved lazily (PEP 562) so host-only
code -- including the tree's ``from repro.index.table import ...`` -- never
pulls in jax.
"""
from .query import PointResult, QueryVerbs, RangeResult
from .table import (SegmentTable, build_shard_tables, numpy_lookup,
                    numpy_search, route_keys, shard_boundaries,
                    shard_cut_indices, shard_partition)

_ENGINE_NAMES = {
    "DeviceIndex", "DispatchEngine", "LookupEngine", "LookupPlan",
    "available_backends", "device_index", "make_engine", "make_plan",
    "pad_keys", "pallas_lookup", "pallas_search", "predict_positions",
    "register_backend", "snap_leftmost", "snap_side", "xla_lookup",
    "xla_search",
}
_SNAPSHOT_NAMES = {"ServingHandle", "Snapshot", "SnapshotPublisher"}
_SHARDED_NAMES = {"PackedShardTables", "ShardSet", "ShardStats",
                  "ShardedIndexService", "pack_shard_tables"}
_FIT_NAMES = {"FitSpec", "IndexPlan", "InfeasibleSpecError", "PlanCandidate",
              "open_index", "plan"}
_LSM_NAMES = {"Compactor", "LevelSet", "LsmIndexService", "MemView",
              "Memtable", "MemtableFullError", "Run"}
_DEVICE_NAMES = {"DeviceShardSet", "DeviceShardedService",
                 "sharded_lookup_a2a", "sharded_lookup_allgather",
                 "sharded_search_a2a", "sharded_search_allgather"}
_PIPELINE_NAMES = {"AsyncIndexService", "PipelineClosed",
                   "PipelineOverloaded", "open_pipeline"}
_TELEMETRY_NAMES = {"DeviceMetrics", "JSONLBackend", "LsmMetrics",
                    "MemoryBackend", "MetricsSnapshot", "Monitor",
                    "PipelineMetrics", "Replanner", "ServiceMetrics",
                    "ShardMetrics", "TierMetrics", "tier_metrics"}

__all__ = [
    "PointResult", "QueryVerbs", "RangeResult", "SegmentTable",
    "build_shard_tables", "numpy_lookup", "numpy_search", "route_keys",
    "shard_boundaries", "shard_cut_indices", "shard_partition",
    *sorted(_ENGINE_NAMES), *sorted(_SNAPSHOT_NAMES), *sorted(_SHARDED_NAMES),
    *sorted(_FIT_NAMES), *sorted(_LSM_NAMES), *sorted(_DEVICE_NAMES),
    *sorted(_PIPELINE_NAMES), *sorted(_TELEMETRY_NAMES),
]


def __getattr__(name):
    if name in _ENGINE_NAMES:
        from . import engine
        return getattr(engine, name)
    if name in _SNAPSHOT_NAMES:
        from . import snapshot
        return getattr(snapshot, name)
    if name in _SHARDED_NAMES:
        from . import sharded
        return getattr(sharded, name)
    if name in _FIT_NAMES:
        from . import fit
        return getattr(fit, name)
    if name in _LSM_NAMES:
        from . import lsm
        return getattr(lsm, name)
    if name in _DEVICE_NAMES:
        from . import device
        return getattr(device, name)
    if name in _PIPELINE_NAMES:
        from . import pipeline
        return getattr(pipeline, name)
    if name in _TELEMETRY_NAMES:
        from . import telemetry
        return getattr(telemetry, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
