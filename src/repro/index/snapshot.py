"""Epoch-snapshot publishing: the route from Alg. 4 inserts to serving.

The mutable host ``FITingTree`` buffers inserts per segment (Sec. 5); device
and sharded serving run over an *immutable* ``SegmentTable``.  This module
connects the two:

    tree.insert(k) ...                 # Alg. 4, buffered, host-side
    snap = publisher.publish()         # flush dirty segments -> new table
    handle.install(snap)               # atomic swap; readers never block

``publish`` is incremental: only segments whose buffer is non-empty are merged
and re-segmented (ShrinkingCone over just that run, exactly Alg. 4 lines 5-9);
clean segments keep their fitted lines.  The resulting table satisfies Eq. 1
with the tree's segmentation budget err_seg <= error, so every engine backend
serves the bound unchanged.

``ServingHandle`` is the serving-side anchor: ``install`` swaps the current
(snapshot, engine-cache) pair with a single reference assignment, so an
in-flight ``lookup`` that already pinned the old pair keeps a fully consistent
view (epoch semantics, no torn reads, no reader locks).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING

import numpy as np

from repro.analysis.sanitizer import make_lock, published_array

from .engine import LookupEngine, make_engine
from .query import PointResult, RangeResult
from .table import SegmentTable

if TYPE_CHECKING:  # avoid a module-level cycle with repro.core
    from repro.core.tree import FITingTree


@dataclasses.dataclass(frozen=True)
class Snapshot:
    """One published epoch of the index.

    ``payload`` is the payload column parallel to ``table.keys`` for a
    non-clustered index (None for the clustered layout), so range scans can
    materialize values from the same immutable epoch they resolved ranks
    against."""
    table: SegmentTable
    epoch: int
    n_refit: int  # dirty segments re-segmented by this publish
    payload: np.ndarray | None = None

    @property
    def n_keys(self) -> int:
        return self.table.n_keys

    @classmethod
    def from_arrays(cls, keys, error: int, *, payload=None, epoch: int = 0,
                    mode: str = "paper",
                    assume_sorted: bool = False) -> "Snapshot":
        """Fit-and-publish in one step: a fresh epoch straight from raw
        arrays, bypassing the mutable tree (the LSM run-build path, bulk
        loads, tests).  Keys and payload are co-sorted unless
        ``assume_sorted``; both arrays freeze on publish."""
        arr = np.asarray(keys, np.float64).ravel()
        pay = None if payload is None else np.asarray(payload).ravel()
        if pay is not None and pay.size != arr.size:
            raise ValueError(f"payload length {pay.size} != key length "
                             f"{arr.size}")
        if arr.size and not assume_sorted:
            order = np.argsort(arr, kind="stable")
            arr = arr[order]
            if pay is not None:
                pay = pay[order]
        table = (SegmentTable.from_keys(arr, error, mode=mode,
                                        assume_sorted=True, epoch=epoch)
                 if arr.size else SegmentTable.empty(error, epoch=epoch))
        return cls(table=table, epoch=epoch, n_refit=table.n_segments,
                   payload=None if pay is None else published_array(pay))


class SnapshotPublisher:
    """Write-side: turns a mutable FITingTree into a stream of snapshots."""

    def __init__(self, tree: "FITingTree"):
        self.tree = tree
        self._epoch = 0

    @property
    def epoch(self) -> int:
        """Epoch of the last publish (0 = nothing published yet)."""
        return self._epoch

    def dirty_segments(self) -> list[int]:
        """Segments with buffered inserts not yet visible to serving."""
        return self.tree.dirty_segments()

    def publish(self) -> Snapshot:
        """Flush dirty segments and emit a fresh immutable snapshot.

        Cost is O(sum of dirty segment lengths) for the re-fit plus O(N + S)
        to assemble the flat arrays; clean segments are never re-segmented.
        """
        n_refit = self.tree.flush()
        self._epoch += 1
        table = self.tree.as_table(epoch=self._epoch)
        # freeze-on-publish: the payload column escapes into serving threads
        # with the table (whose arrays freeze at construction) -- a latent
        # in-place write through either must raise, not corrupt the epoch
        return Snapshot(table=table, epoch=self._epoch, n_refit=n_refit,
                        payload=published_array(self.tree.payload_column()))


class ServingHandle:
    """Read-side: pin-and-lookup over the latest installed snapshot.

    Engines are built lazily per backend per snapshot and cached alongside the
    snapshot they serve, so a swap atomically retires both the table and its
    compiled lookup closures.
    """

    def __init__(self, engine_opts: dict[str, dict] | None = None):
        self._engine_opts = engine_opts or {}
        self._lock = make_lock("ServingHandle._lock")
        self._state: tuple[Snapshot, dict[str, LookupEngine]] | None = None

    @property
    def epoch(self) -> int:
        state = self._state
        return 0 if state is None else state[0].epoch

    def current(self) -> Snapshot:
        state = self._state
        if state is None:
            raise RuntimeError("no snapshot installed yet")
        return state[0]

    def install(self, snapshot: Snapshot) -> None:
        """Atomic swap: one reference assignment publishes the new epoch."""
        self._state = (snapshot, {})

    def engine(self, backend: str = "numpy") -> LookupEngine:
        return self._engine_from(self._pin(), backend)

    def _engine_from(self, state: tuple[Snapshot, dict[str, LookupEngine]],
                     backend: str) -> LookupEngine:
        """Engine for an already-pinned (snapshot, cache) state, so a verb
        that also reads the snapshot (e.g. its payload column) resolves both
        against one consistent epoch even if ``install`` lands mid-call."""
        snapshot, engines = state
        eng = engines.get(backend)
        if eng is None:
            with self._lock:
                eng = engines.get(backend)
                if eng is None:
                    eng = make_engine(snapshot.table, backend,
                                      **self._engine_opts.get(backend, {}))
                    engines[backend] = eng
        return eng

    def lookup(self, queries, backend: str = "numpy") -> np.ndarray:
        """Rank of each query in the current snapshot, -1 if absent."""
        return self.engine(backend).lookup(queries)

    # ------------------------------------------------------- typed query plane
    def search(self, queries, side: str = "left",
               backend: str = "numpy") -> np.ndarray:
        """Insertion ranks (``searchsorted`` semantics) in the current
        snapshot -- the primitive every verb below derives from."""
        return self.engine(backend).search(queries, side)

    def point(self, queries, backend: str = "numpy") -> PointResult:
        return self.engine(backend).point(queries)

    def count(self, lo, hi, backend: str = "numpy") -> np.ndarray:
        return self.engine(backend).count(lo, hi)

    def range(self, lo, hi, *, materialize: bool = True,
              backend: str = "numpy") -> RangeResult:
        """Inclusive ``[lo, hi]`` scan over the current snapshot; payloads
        (non-clustered index) materialize from the same pinned snapshot the
        ranks were resolved against."""
        state = self._pin()
        snapshot = state[0]
        res = self._engine_from(state, backend).range(lo, hi,
                                                      materialize=materialize)
        if materialize and snapshot.payload is not None:
            res = dataclasses.replace(
                res, payload=snapshot.payload[res.lo_rank:res.hi_rank].copy())
        return res

    def predecessor(self, queries, backend: str = "numpy") -> PointResult:
        return self.engine(backend).predecessor(queries)

    def successor(self, queries, backend: str = "numpy") -> PointResult:
        return self.engine(backend).successor(queries)

    def _pin(self) -> tuple[Snapshot, dict[str, LookupEngine]]:
        state = self._state
        if state is None:
            raise RuntimeError("no snapshot installed yet")
        return state
