"""Post-SPMD HLO analysis: collective bytes with while-loop multiplicity.

GSPMD places per-layer collectives (FSDP all-gathers, TP reduce-scatters)
inside the scan's while body; a flat text scan counts them once.  This parser
builds the computation call graph (while body/condition, calls, fusions),
extracts each while's trip count from its condition's comparison constant,
and multiplies collective bytes by the product of enclosing trip counts.

Heuristic, text-based (the stable python API doesn't expose buffer
assignment), but validated against known scan structures in tests.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(pred|[sufc]\d+|bf16)\[([0-9,]*)\]")
_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_COLL_RE = re.compile(
    r"= (.*?) (all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(-start)?\(")
_REF_RE = re.compile(r"(?:condition|body|to_apply|calls)=%?([\w.\-]+)")
_WHILE_RE = re.compile(r"= .*? while\(.*?\), condition=%?([\w.\-]+), "
                       r"body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def split_computations(hlo: str) -> dict[str, list[str]]:
    """computation name -> its body lines."""
    comps: dict[str, list[str]] = {}
    cur = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_START.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
        else:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _entry_name(hlo: str, comps: dict[str, list[str]]) -> str | None:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
    if m and m.group(1) in comps:
        return m.group(1)
    return next(iter(comps), None)


def analyze_collectives(hlo: str) -> dict:
    """Per-type collective bytes/counts, loop-multiplied; plus raw (x1) sums."""
    comps = split_computations(hlo)
    entry = _entry_name(hlo, comps)

    # per-computation local collective sums + call edges
    local = {}
    edges = defaultdict(list)      # comp -> [(child, multiplier)]
    for name, lines in comps.items():
        loc = defaultdict(int)
        cnt = defaultdict(int)
        for ln in lines:
            cm = _COLL_RE.search(ln)
            if cm:
                b = shape_bytes(cm.group(1))
                # CPU-backend artifact: bf16 all-reduces are *promoted* to f32
                # (reducer named ...._promoted); a TPU reduces natively in
                # bf16, so count promoted ARs at half width.
                if cm.group(2) == "all-reduce" and "_promoted" in ln \
                        and "f32[" in cm.group(1):
                    b //= 2
                loc[cm.group(2)] += b
                cnt[cm.group(2)] += 1
            wm = _WHILE_RE.search(ln)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trip = _trip_count(comps.get(cond, []))
                edges[name].append((body, trip))
                edges[name].append((cond, trip))
            else:
                for ref in _REF_RE.findall(ln):
                    if ref in comps:
                        edges[name].append((ref, 1))
        local[name] = (dict(loc), dict(cnt))

    # multiplier of each computation = sum over call paths of trip products
    mult = defaultdict(float)
    if entry is not None:
        stack = [(entry, 1.0, 0)]
        while stack:
            node, m, depth = stack.pop()
            mult[node] += m
            if depth > 12:
                continue
            for child, f in edges.get(node, []):
                stack.append((child, m * f, depth + 1))

    out = {f"{c}_bytes": 0 for c in COLLECTIVES}
    out.update({f"{c}_count": 0 for c in COLLECTIVES})
    raw = {f"{c}_bytes": 0 for c in COLLECTIVES}
    for name, (loc, cnt) in local.items():
        for c in COLLECTIVES:
            if c in loc:
                out[f"{c}_bytes"] += int(loc[c] * max(mult.get(name, 1.0), 1.0))
                out[f"{c}_count"] += int(cnt[c] * max(mult.get(name, 1.0), 1.0))
                raw[f"{c}_bytes"] += loc[c]
    out["total_collective_bytes"] = sum(out[f"{c}_bytes"] for c in COLLECTIVES)
    out["total_collective_bytes_raw"] = sum(raw[f"{c}_bytes"]
                                            for c in COLLECTIVES)
    # ring-collective wire bytes per device: all-reduce moves ~2x its result
    # size (reduce-scatter + all-gather phases); the others move ~1x
    out["wire_bytes"] = (2 * out["all-reduce_bytes"]
                         + out["all-gather_bytes"]
                         + out["reduce-scatter_bytes"]
                         + out["all-to-all_bytes"]
                         + out["collective-permute_bytes"])
    return out


def _trip_count(cond_lines: list[str]) -> int:
    """Trip count from the loop condition: the largest compare constant."""
    best = 1
    for ln in cond_lines:
        if "compare" in ln or "constant" in ln:
            for c in _CONST_RE.findall(ln):
                best = max(best, int(c))
    return best
