"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

``input_specs`` returns (step_kind, args_shapes) -- weak-type-correct,
shardable, zero allocation; ``make_step_and_specs`` additionally binds the
step function and the in/out sharding trees for a mesh.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, ShapeSpec, get_config
from repro.models import init_caches, init_params
from repro.models.config import ModelConfig
from repro.launch import sharding as sh
from repro.serve.step import make_decode_step, make_prefill_step
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.step import make_train_step

SDS = jax.ShapeDtypeStruct


def param_shapes(cfg: ModelConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda k: init_params(cfg, k, dtype=dtype),
                          jax.random.key(0))


def cache_shapes(cfg: ModelConfig, batch: int, cache_len: int):
    return jax.eval_shape(
        functools.partial(init_caches, cfg, batch, cache_len))


def input_specs(arch: str, shape: str) -> tuple[str, dict[str, Any]]:
    """Returns (kind, shapes): every model input for this cell as SDS."""
    cfg = get_config(arch)
    s: ShapeSpec = SHAPES[shape]
    b, t = s.global_batch, s.seq_len
    mem = (SDS((b, cfg.memory_len, cfg.d_model), jnp.bfloat16)
           if cfg.memory_len else None)
    if s.kind == "train":
        batch = {"tokens": SDS((b, t), jnp.int32)}
        if mem is not None:
            batch["memory"] = mem
        return "train", {"batch": batch}
    if s.kind == "prefill":
        out = {"tokens": SDS((b, t), jnp.int32),
               "caches": cache_shapes(get_config(arch), b, t)}
        if mem is not None:
            out["memory"] = mem
        return "prefill", out
    # decode: one new token against a cache of seq_len
    out = {"tokens": SDS((b, 1), jnp.int32),
           "pos": SDS((b,), jnp.int32),
           "caches": cache_shapes(get_config(arch), b, t)}
    return "decode", out


def _with_act_sharding(fn, mesh, policy="2d"):
    from repro.models.model import activation_sharding
    dp_axes = ("pod", "data", "model") if policy == "zero3" else ("pod", "data")

    @functools.wraps(fn)
    def inner(*a, **k):
        with activation_sharding(mesh, dp_axes):
            return fn(*a, **k)
    return inner


def make_step_and_specs(arch: str, shape: str, mesh, *,
                        microbatches: int = 1, donate: bool = True,
                        policy: str = "2d"):
    """Builds (fn, arg_shapes, in_shardings, out_shardings) for jit+lower.
    policy: see launch/sharding.param_spec ("2d" | "zero3" | "tp")."""
    cfg = get_config(arch)
    kind, shapes = input_specs(arch, shape)
    p_shapes = param_shapes(cfg)
    p_sh = sh.param_shardings(mesh, p_shapes, policy)
    repl = NamedSharding(mesh, P())

    def data_sh(tree):
        return jax.tree.map(
            lambda l: NamedSharding(
                mesh, sh.batch_spec(mesh, l.shape[0], len(l.shape), policy)),
            tree)

    if kind == "train":
        opt_shapes = jax.eval_shape(lambda: init_opt_state(p_shapes))
        opt_sh = sh.opt_shardings(mesh, opt_shapes, policy)
        step = _with_act_sharding(
            make_train_step(cfg, AdamWConfig(), microbatches=microbatches),
            mesh, policy)
        args = (p_shapes, opt_shapes, shapes["batch"])
        in_sh = (p_sh, opt_sh, data_sh(shapes["batch"]))
        out_sh = (p_sh, opt_sh, jax.tree.map(lambda _: repl, {
            "grad_norm": 0, "lr": 0, "loss": 0}))
        donate_argnums = (0, 1) if donate else ()
        return step, args, in_sh, out_sh, donate_argnums

    b = shapes["tokens"].shape[0]
    c_sh = sh.cache_shardings(mesh, shapes["caches"], b)
    tok_sh = data_sh({"t": shapes["tokens"]})["t"]
    if kind == "prefill":
        step = _with_act_sharding(make_prefill_step(cfg), mesh, policy)
        args = [p_shapes, shapes["tokens"], shapes["caches"]]
        in_sh = [p_sh, tok_sh, c_sh]
        if "memory" in shapes:
            args.append(shapes["memory"])
            in_sh.append(data_sh({"m": shapes["memory"]})["m"])
        out_sh = (NamedSharding(mesh, sh.batch_spec(mesh, b, 1)), c_sh)
        donate_argnums = (2,) if donate else ()
        return step, tuple(args), tuple(in_sh), out_sh, donate_argnums

    step = _with_act_sharding(make_decode_step(cfg), mesh, policy)
    args = (p_shapes, shapes["tokens"], shapes["pos"], shapes["caches"])
    pos_sh = NamedSharding(mesh, sh.batch_spec(mesh, b, 1))
    in_sh = (p_sh, tok_sh, pos_sh, c_sh)
    out_sh = (pos_sh, c_sh)
    donate_argnums = (3,) if donate else ()
    return step, args, in_sh, out_sh, donate_argnums
