"""Training driver: config-driven, fault-tolerant, resumable.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b --smoke \
        --steps 200 --ckpt-dir /tmp/ckpt --resume

Fault tolerance (DESIGN.md Sec. 5):
  * checkpoints every --ckpt-every steps (async, atomic, crc-verified) +
    final; --resume restarts from the latest DONE checkpoint;
  * the data pipeline is step-addressed, so a resume replays the exact
    sample order (restart-determinism is asserted in tests/test_fault.py);
  * a heartbeat file (step + wallclock) is touched every step -- a cluster
    babysitter kills/relaunches ranks whose heartbeat stalls (straggler
    mitigation); --die-at-step N simulates a hard failure for tests;
  * elastic: the mesh is built from the devices present at startup, and
    checkpoints store logical arrays, so a resume may use a different
    device count (tests restore a 1-device run into a 4-device mesh).
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import manager as ckpt
from repro.configs import get_config, reduced
from repro.data.pipeline import DataPipeline, PipelineConfig, synthetic_corpus
from repro.launch import sharding as sh
from repro.launch.mesh import make_host_mesh
from repro.models import init_params
from repro.models.model import activation_sharding
from repro.train.compress import init_residual
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.step import make_train_step
from jax.sharding import NamedSharding, PartitionSpec as P


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-size)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", default="cosine",
                    choices=["cosine", "wsd", "const"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--die-at-step", type=int, default=-1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    mesh = make_host_mesh(model_parallel=args.model_parallel)
    dtype = jnp.dtype(args.dtype)

    corpus = synthetic_corpus(n_tokens=max(2_000_000,
                                           args.batch * (args.seq + 1) * 50),
                              vocab=cfg.vocab, seed=args.seed)
    pipe = DataPipeline(corpus, PipelineConfig(
        seq_len=args.seq, batch_size=args.batch, seed=args.seed))
    print(f"corpus: {corpus.n_tokens} tokens, {corpus.n_docs} docs; "
          f"doc-index: {pipe.doc_index.index_size_bytes()}B at "
          f"error={pipe.doc_index.error} "
          f"(dense table: {corpus.n_docs * 8}B)", flush=True)

    params = init_params(cfg, jax.random.key(args.seed), dtype=dtype)
    opt_cfg = AdamWConfig(lr=args.lr, schedule=args.schedule,
                          warmup_steps=max(2, args.steps // 20),
                          total_steps=args.steps)
    opt_state = init_opt_state(params)
    if args.compress:
        opt_state["residual"] = init_residual(params)

    start_step = 0
    ckpt_dir = pathlib.Path(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt_dir and args.resume:
        last = ckpt.latest_step(ckpt_dir)
        if last is not None:
            (params, opt_state), extra = ckpt.restore(
                ckpt_dir, last, (params, opt_state))
            pipe.check_state(extra["pipeline"])
            start_step = last
            print(f"resumed from step {last}", flush=True)

    p_sh = sh.param_shardings(mesh, jax.eval_shape(lambda: params))
    o_sh = sh.opt_shardings(mesh, jax.eval_shape(lambda: opt_state))
    if args.compress:   # residual shards like params
        o_sh["residual"] = sh.param_shardings(
            mesh, jax.eval_shape(lambda: opt_state["residual"]))
    params = jax.device_put(params, p_sh)
    opt_state = jax.device_put(opt_state, o_sh)
    data_spec = NamedSharding(mesh, sh.batch_spec(mesh, args.batch, 2))

    raw_step = make_train_step(cfg, opt_cfg, microbatches=args.microbatches,
                               compress=args.compress)

    def wrapped(p, o, b):
        with activation_sharding(mesh):
            return raw_step(p, o, b)

    repl = NamedSharding(mesh, P())
    step_fn = jax.jit(wrapped, in_shardings=(p_sh, o_sh, {"tokens": data_spec}),
                      out_shardings=(p_sh, o_sh,
                                     {"grad_norm": repl, "lr": repl,
                                      "loss": repl}),
                      donate_argnums=(0, 1))

    if ckpt_dir:
        ckpt_dir.mkdir(parents=True, exist_ok=True)
    saver = ckpt.AsyncSaver(ckpt_dir) if ckpt_dir else None
    hb = (ckpt_dir / "heartbeat.json") if ckpt_dir else None
    metrics_log = (ckpt_dir / "metrics.jsonl").open("a") if ckpt_dir else None
    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = pipe.batch_at(step)
        tokens = jax.device_put(batch["tokens"], data_spec)
        params, opt_state, m = step_fn(params, opt_state, {"tokens": tokens})
        loss = float(m["loss"])
        losses.append(loss)
        if hb:
            hb.write_text(json.dumps({"step": step, "t": time.time()}))
        if metrics_log and step % args.log_every == 0:
            metrics_log.write(json.dumps(
                {"step": step, "loss": loss,
                 "grad_norm": float(m["grad_norm"]),
                 "lr": float(m["lr"])}) + "\n")
            metrics_log.flush()
        if step % args.log_every == 0:
            print(f"step {step}: loss={loss:.4f} "
                  f"gnorm={float(m['grad_norm']):.3f} "
                  f"({(time.time()-t0)/(step-start_step+1):.2f}s/step)",
                  flush=True)
        if args.die_at_step == step:
            if saver:
                # deterministic fault injection: the failure is "after the
                # last checkpoint completed", not "racing the async writer"
                # (the torn-write case is covered by the atomicity design:
                # readers ignore dirs without a DONE marker)
                saver.wait()
            print(f"SIMULATED FAILURE at step {step}", flush=True)
            os._exit(42)
        if saver and (step + 1) % args.ckpt_every == 0:
            saver.save(step + 1, (params, opt_state),
                       extra={"pipeline": pipe.state_dict()})
    if saver:
        saver.save(args.steps, (params, opt_state),
                   extra={"pipeline": pipe.state_dict()})
        saver.wait()
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})", flush=True)
    return losses


if __name__ == "__main__":
    main()
