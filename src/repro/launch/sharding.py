"""Sharding rules: param/optimizer/activation/cache PartitionSpecs.

2D weight sharding (MaxText-style): FSDP over `data`, tensor parallel over
`model`, expert parallel (MoE expert dim) over `model`; `pod` is pure DP.
Rules are name+shape based over the init_params tree, so every architecture
gets coherent specs without per-arch spec trees.  GSPMD inserts collectives;
the dry-run HLO is where we verify what it chose (EXPERIMENTS.md SDry-run).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


FSDP, TP = "data", "model"


def _path_names(path) -> list[str]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(p.key)
        elif hasattr(p, "name"):
            out.append(p.name)
    return out


def _divisible(dim: int, mesh: Mesh, axis: str) -> bool:
    return dim % mesh.shape[axis] == 0


def param_spec(path, leaf, mesh: Mesh, policy: str = "2d") -> P:
    """PartitionSpec for one parameter leaf.

    policy="2d"    -- FSDP over `data` x TP over `model` (Megatron-style);
                      activations pay two TP all-reduces per layer.
    policy="zero3" -- weights sharded over BOTH axes on dim0, no tensor
                      parallelism: XLA gathers each layer's weights
                      (param-sized collectives) and computes locally; the
                      batch shards over every mesh axis.  Wins whenever
                      activation bytes/layer >> weight bytes/layer
                      (small-to-mid dense models at big B*T: SPerf cell B).
    policy="tp"    -- TP over `model` only, weights replicated over `data`
                      (no per-step weight gathers: the decode-serving policy).
    """
    names = _path_names(path)
    name = names[-1]
    shape = leaf.shape
    stacked = any(n.startswith("s") and n[1:].isdigit() for n in names)
    lead = (None,) if stacked else ()
    body = shape[1:] if stacked else shape

    if policy == "zero3" and len(body) >= 1:
        axes = tuple(a for a in ("data", "model") if a in mesh.axis_names)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        spec = [None] * len(body)
        if body[0] % size == 0:
            spec[0] = axes
        elif body[0] % mesh.shape["data"] == 0:
            spec[0] = "data"
        elif len(body) > 1 and body[1] % mesh.shape["data"] == 0:
            spec[1] = "data"
        return P(*(lead + tuple(spec)))

    def ok(spec_tail):
        # only shard divisible dims; replace non-divisible entries with None
        fixed = []
        for dim, ax in zip(body, spec_tail):
            if ax is None:
                fixed.append(None)
            elif isinstance(ax, tuple):
                size = int(np.prod([mesh.shape[a] for a in ax]))
                fixed.append(ax if dim % size == 0 else None)
            else:
                fixed.append(ax if _divisible(dim, mesh, ax) else None)
        return P(*(lead + tuple(fixed)))

    if name == "embed":
        return ok((TP, FSDP))
    if name == "unembed":
        return ok((FSDP, TP))
    if len(body) <= 1:
        return P(*(lead + (None,) * len(body)))
    # MoE experts: (E, D, F) / (E, F, D) -> EP over model
    if name in ("wi", "wg") and len(body) == 3:
        return ok((TP, FSDP, None))
    if name == "wo" and len(body) == 3:
        return ok((TP, None, FSDP))
    if name == "router":
        return ok((FSDP, None))
    # attention / mlp 2D mats: first proj (D, X) -> (fsdp, tp);
    # output proj back to d_model -> (tp, fsdp)
    if name in ("wq", "wk", "wv", "wi", "wg", "wx", "wy", "up", "wu"):
        return ok((FSDP, TP))
    if name in ("wo", "down"):
        return ok((TP, FSDP))
    # recurrent-family square/gate mats and mlstm internals: FSDP only --
    # their inner width doesn't split cleanly over TP (DESIGN.md Sec. 4 note)
    return ok((FSDP, None))


def _strip_axis(spec: P, axis: str) -> P:
    out = []
    for e in spec:
        if e == axis:
            out.append(None)
        elif isinstance(e, tuple):
            kept = tuple(a for a in e if a != axis)
            out.append(kept if kept else None)
        else:
            out.append(e)
    return P(*out)


def param_shardings(mesh: Mesh, params_shapes: Any, policy: str = "2d"):
    def pick(path, leaf):
        spec = param_spec(path, leaf, mesh,
                          policy if policy == "zero3" else "2d")
        if policy == "tp":      # weights replicated over `data`: serve policy
            spec = _strip_axis(spec, FSDP)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(pick, params_shapes)


def batch_spec(mesh: Mesh, batch: int, ndim: int, policy: str = "2d") -> P:
    """Shard the leading batch dim over every data-parallel axis that fits.
    zero3: no tensor axis is reserved, so the batch shards over `model` too."""
    pool = ("pod", "data", "model") if policy == "zero3" else ("pod", "data")
    axes = [a for a in pool if a in mesh.axis_names]
    size = int(np.prod([mesh.shape[a] for a in axes]))
    if batch % size == 0 and size > 1:
        return P(tuple(axes), *([None] * (ndim - 1)))
    if "data" in mesh.axis_names and batch % mesh.shape["data"] == 0:
        return P("data", *([None] * (ndim - 1)))
    return P(*([None] * ndim))


def cache_spec(mesh: Mesh, leaf, batch: int) -> P:
    """KV caches / recurrent states: batch over DP; then kv-heads or cache
    length over TP (sequence-parallel KV for small-batch long-context)."""
    shape = leaf.shape
    # leading stack-repeat dim, then batch
    assert len(shape) >= 2
    b_idx = 1
    spec = [None] * len(shape)
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    dp = int(np.prod([mesh.shape[a] for a in axes]))
    if shape[b_idx] % dp == 0 and dp > 1:
        spec[b_idx] = tuple(axes)
    elif shape[b_idx] % mesh.shape["data"] == 0:
        spec[b_idx] = "data"
    tp = mesh.shape[TP]
    # (R, B, L, Kv, hd): prefer kv-head sharding, else length (SP)
    if len(shape) == 5:
        if shape[3] % tp == 0:
            spec[3] = TP
        elif shape[2] % tp == 0:
            spec[2] = TP
    elif len(shape) >= 3 and shape[-1] % tp == 0 and spec[b_idx] != TP:
        spec[-1] = TP
    return P(*spec)


def cache_shardings(mesh: Mesh, caches_shapes: Any, batch: int):
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, cache_spec(mesh, leaf, batch)),
        caches_shapes)


def opt_shardings(mesh: Mesh, opt_shapes: Any, policy: str = "2d"):
    """Adam m/v mirror the param sharding; scalars (step) replicated.
    (policy="tp" keeps m/v FSDP-sharded anyway -- optimizer state need not
    be replicated even when weights are.)"""
    def pick(path, leaf):
        names = _path_names(path)
        if names and names[0] in ("m", "v"):
            return NamedSharding(mesh, param_spec(
                path[1:], leaf, mesh, policy if policy == "zero3" else "2d"))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(pick, opt_shapes)
