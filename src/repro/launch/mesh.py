"""Production mesh builder.  A FUNCTION (not a module constant) so importing
this module never touches jax device state (the dry-run forces 512 host
devices via XLA_FLAGS *before* any jax import; tests/benches see 1 device)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16) data x model single-pod; (2,16,16) pod x data x model multi-pod.

    The `pod` axis is pure data parallelism: only the gradient all-reduce
    crosses the data-center interconnect; FSDP weight gathers and TP
    collectives stay on intra-pod ICI (DESIGN.md Sec. 5)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axis names for this mesh (pod included if present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def make_host_mesh(n_devices: int | None = None, model_parallel: int = 1):
    """Small mesh over the actually-available devices (tests / examples)."""
    n = n_devices or len(jax.devices())
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))
