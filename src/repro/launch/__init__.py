"""Mesh, shardings, specs, dry-run and drivers."""
