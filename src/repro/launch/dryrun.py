import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count on first init).  For each cell we record:
  * compiled.memory_analysis()  -- bytes per device (proves it fits / doesn't)
  * compiled.cost_analysis()    -- HLO FLOPs / bytes for SRoofline
  * collective bytes parsed from the post-SPMD optimized HLO
into experiments/dryrun/<cell>.json; benchmarks/roofline.py consumes these.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-12b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod|--both-meshes]
"""
import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.launch.flops_count import count_flops
from repro.launch.hlo_analysis import analyze_collectives
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import make_step_and_specs

def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: pathlib.Path,
             microbatches: int = 1, tag: str = "", policy: str = "2d") -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = f"{arch}__{shape}__{mesh_name}" + (f"__{tag}" if tag else "")
    cfg = get_config(arch)
    ok, reason = shape_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name, "cell": cell,
           "microbatches": microbatches, "policy": policy}
    if not ok:
        rec.update(status="skipped", reason=reason)
        _write(out_dir, cell, rec)
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        step, args, in_sh, out_sh, donate = make_step_and_specs(
            arch, shape, mesh, microbatches=microbatches, policy=policy)
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        mem_rec = {}
        if mem is not None:
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "generated_code_size_in_bytes",
                         "alias_size_in_bytes"):
                mem_rec[attr] = int(getattr(mem, attr, 0) or 0)
        cost_rec = {}
        if cost:
            for k in ("flops", "bytes accessed", "transcendentals",
                      "optimal_seconds"):
                if k in cost:
                    cost_rec[k.replace(" ", "_")] = float(cost[k])
        hlo = compiled.as_text()
        jaxpr = jax.make_jaxpr(step)(*args)
        rec.update(status="ok", lower_s=round(t_lower, 1),
                   compile_s=round(t_compile, 1), memory=mem_rec,
                   cost=cost_rec, collectives=analyze_collectives(hlo),
                   jaxpr_flops_global=count_flops(jaxpr),
                   n_devices=mesh.devices.size)
    except Exception as e:  # noqa: BLE001 -- record the failure, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-4000:])
    _write(out_dir, cell, rec)
    return rec


def _write(out_dir: pathlib.Path, cell: str, rec: dict):
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{cell}.json").write_text(json.dumps(rec, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--policy", default="2d", choices=["2d", "zero3", "tp"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    out = pathlib.Path(args.out)

    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    meshes = [False, True] if (args.both_meshes or args.all) else \
        [args.multi_pod]

    n_ok = n_skip = n_err = 0
    for a, s in cells:
        for mp in meshes:
            # skip if already recorded (idempotent sweeps)
            mesh_name = "pod2x16x16" if mp else "pod16x16"
            cell = f"{a}__{s}__{mesh_name}" + (f"__{args.tag}" if args.tag else "")
            f = out / f"{cell}.json"
            if f.exists() and json.loads(f.read_text()).get("status") == "ok":
                print(f"[cached] {cell}")
                n_ok += 1
                continue
            rec = run_cell(a, s, mp, out, args.microbatches, args.tag,
                           args.policy)
            st = rec["status"]
            n_ok += st == "ok"
            n_skip += st == "skipped"
            n_err += st == "error"
            extra = ""
            if st == "ok":
                extra = (f"compile={rec['compile_s']}s "
                         f"flops={rec['cost'].get('flops', 0):.3e} "
                         f"coll={rec['collectives']['total_collective_bytes']:.3e}B")
            elif st == "error":
                extra = rec["error"][:200]
            print(f"[{st}] {cell} {extra}", flush=True)
    print(f"done: ok={n_ok} skipped={n_skip} errors={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
