"""Jaxpr-level FLOP counting with correct scan/loop multiplicities.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE regardless of
trip count (verified on this container: a 24-layer scanned model reports
~1/24th of its matmul FLOPs), so the roofline's compute term derives from the
jaxpr instead: dot_general/conv FLOPs, with scan bodies multiplied by their
length, remat/pjit/custom-vjp recursed.  This counts the *compiled program's*
work (remat recompute included) -- the MODEL_FLOPS/jaxpr_flops ratio in
SRoofline is exactly the remat/redundancy waste measure the brief asks for.
"""
from __future__ import annotations

import numpy as np


def _dot_flops(eqn) -> float:
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    m = np.prod([d for i, d in enumerate(a.shape)
                 if i not in lc and i not in lb], initial=1.0)
    n = np.prod([d for i, d in enumerate(b.shape)
                 if i not in rc and i not in rb], initial=1.0)
    k = np.prod([a.shape[i] for i in lc], initial=1.0)
    batch = np.prod([a.shape[i] for i in lb], initial=1.0)
    return 2.0 * batch * m * n * k


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    # flops = 2 * out_elems * (k_spatial * in_feat)
    k_elems = np.prod(rhs.shape, initial=1.0) / max(rhs.shape[-1], 1)
    return 2.0 * np.prod(out.shape, initial=1.0) * k_elems


_SUBJAXPR_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr",
                    "body_jaxpr", "branches")


def count_flops(jaxpr) -> float:
    """Total dot/conv FLOPs of a (Closed)Jaxpr, loop-aware."""
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    total = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            total += _dot_flops(eqn)
        elif name == "conv_general_dilated":
            total += _conv_flops(eqn)
        elif name == "scan":
            inner = count_flops(eqn.params["jaxpr"])
            total += inner * eqn.params["length"]
        elif name == "shard_map":
            # the body jaxpr is PER-SHARD work; scale to global by mesh size
            inner = count_flops(eqn.params["jaxpr"])
            total += inner * getattr(eqn.params["mesh"], "size", 1)
        elif name == "while":
            # bounded fori_loops: trip count unknown statically here; our
            # models use scan exclusively, so treat one trip (flagged by
            # callers if a while is ever seen)
            total += count_flops(eqn.params["body_jaxpr"])
        elif name == "cond":
            total += max(count_flops(b) for b in eqn.params["branches"])
        else:
            for pname in _SUBJAXPR_PARAMS:
                if pname in eqn.params:
                    v = eqn.params[pname]
                    if pname == "branches":
                        total += max(count_flops(b) for b in v)
                    else:
                        total += count_flops(v)
                    break
    return total
