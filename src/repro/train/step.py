"""Distributed train step: loss + grads + AdamW under GSPMD shardings.

Microbatching (gradient accumulation) via lax.scan keeps the per-step live
activation set at one microbatch; optional int8 error-feedback gradient
compression wraps the cross-pod reduction (train/compress.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import loss_fn
from repro.models.config import ModelConfig
from .optimizer import AdamWConfig, adamw_update


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *,
                    microbatches: int = 1, compress: bool = False):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    batch: {"tokens": (B, T+1) int32[, "memory": (B, M, D)]}.
    compress=True enables int8 error-feedback gradient compression; the
    residual is threaded through opt_state["residual"] (add it at init via
    compress.init_residual).
    """

    def grads_of(params, batch):
        def one(p, mb):
            return loss_fn(p, cfg, mb["tokens"], mb.get("memory"))
        if microbatches == 1:
            return jax.value_and_grad(one)(params, batch)
        # split leading batch dim into microbatches and accumulate
        def reshape(x):
            b = x.shape[0]
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])
        mbs = jax.tree.map(reshape, batch)

        def body(acc, mb):
            l, g = jax.value_and_grad(one)(params, mb)
            return jax.tree.map(jnp.add, acc, (l, g)), None

        zero = (jnp.zeros(()),
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
        (l, g), _ = jax.lax.scan(body, zero, mbs)
        inv = 1.0 / microbatches
        return l * inv, jax.tree.map(lambda x: x * inv, g)

    def train_step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        if compress:
            from .compress import compress_decompress
            grads, new_res = compress_decompress(grads,
                                                 opt_state["residual"])
        params, new_opt, metrics = adamw_update(
            params, grads, {k: v for k, v in opt_state.items()
                            if k != "residual"}, opt_cfg)
        if compress:
            new_opt["residual"] = new_res
        metrics["loss"] = loss
        return params, new_opt, metrics

    return train_step
