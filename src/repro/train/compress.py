"""int8 error-feedback gradient compression (cross-pod all-reduce trick).

On a multi-pod mesh the gradient all-reduce over the `pod` axis crosses the
data-center interconnect (~10x slower than ICI).  Quantizing pod-crossing
gradients to int8 with per-tensor scales cuts those bytes 4x (vs f32
accumulators); the *error-feedback residual* re-injects quantization error on
the next step, which keeps SGD/Adam convergence unbiased (Karimireddy et al.,
2019).

Numerics are exact to the wire format; on this container the actual reduction
still happens in XLA (the dry-run's collective bytes drop is what a real
deployment would see with a custom int8 reduction -- recorded in
EXPERIMENTS.md SPerf).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def init_residual(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_decompress(grads: Any, residual: Any) -> tuple[Any, Any]:
    """Returns (dequantized grads, new residual).  Per-tensor symmetric int8."""

    def one(g, r):
        g = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-30) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        dq = q.astype(jnp.float32) * scale
        return dq, g - dq

    flat, tdef = jax.tree.flatten(grads)
    rflat = jax.tree.leaves(residual)
    out = [one(g, r) for g, r in zip(flat, rflat)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))


# NOTE: the residual is jit-state -- it lives in opt_state["residual"]
# (train/step.py threads it through the step), NOT host-side.
