"""AdamW + LR schedules, implemented from scratch (no optax in this image).

State is a pytree {m, v, step}; m/v are fp32 and shard exactly like params
(launch/sharding.opt_shardings).  Schedules include WSD (warmup-stable-decay,
the MiniCPM paper's schedule) and cosine.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"          # "cosine" | "wsd" | "const"
    warmup_steps: int = 100
    total_steps: int = 10_000
    decay_frac: float = 0.1           # WSD: fraction of steps in decay phase


def schedule_fn(c: AdamWConfig) -> Callable[[jax.Array], jax.Array]:
    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / jnp.maximum(c.warmup_steps, 1), 1.0)
        if c.schedule == "const":
            return c.lr * warm
        if c.schedule == "cosine":
            t = jnp.clip((s - c.warmup_steps) /
                         jnp.maximum(c.total_steps - c.warmup_steps, 1), 0, 1)
            return c.lr * warm * (0.5 * (1 + jnp.cos(jnp.pi * t)))
        if c.schedule == "wsd":
            # warmup -> stable at lr -> sqrt-style decay in the final fraction
            decay_start = c.total_steps * (1.0 - c.decay_frac)
            t = jnp.clip((s - decay_start) /
                         jnp.maximum(c.total_steps - decay_start, 1), 0, 1)
            return c.lr * warm * (1.0 - t * (1.0 - 0.1))
        raise ValueError(c.schedule)
    return fn


def init_opt_state(params: Any) -> dict:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros32, params),
            "v": jax.tree.map(zeros32, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, c: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, c.grad_clip / (gnorm + 1e-9)) if c.grad_clip \
        else jnp.ones(())
    lr = schedule_fn(c)(step)
    b1c = 1.0 - c.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - c.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = c.b1 * m + (1 - c.b1) * g
        v = c.b2 * v + (1 - c.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        new_p = p.astype(jnp.float32) - lr * (
            mh / (jnp.sqrt(vh) + c.eps) + c.weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
