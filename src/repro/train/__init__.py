"""Train step, optimizer, schedules, compression."""
