"""Pallas TPU kernel for batched FITing-Tree lookups (the paper's hot path).

TPU-native formulation (DESIGN.md Sec. 2): after the (cheap, XLA-side) router
pass predicts each query's position, every query owns a +-error *window* of the
sorted key column.  Queries are bucketed by the key block their window starts
in; the kernel walks the key blocks sequentially and answers each block's
bucket with a **gather-free masked compare-reduce**:

    rank(q)  = window_start + #{ j in window : keys[j] < q }
    found(q) = any( j in window : keys[j] == q )

Because a window (2e+2 keys, e = error) never spans more than two consecutive
key blocks when KB >= 2e+2, each grid step DMAs exactly two KB-sized key blocks
HBM->VMEM plus its QCAP-query bucket, and writes the bucket's answers.  All
shapes are static; there is no gather, no branch, no revisit -- pure VPU
compare+sum over a (QCAP, 2*KB) tile.

Memory per grid step (VMEM): 2*KB*4 B of keys + QCAP*(4+4) B of queries/starts
+ QCAP*8 B of outputs -- a few tens of KB, far under the ~16 MB VMEM budget;
KB and QCAP are 128-aligned for the 8x128 VPU lanes.

Bucket overflow (more than QCAP windows starting in one block) is detected in
the wrapper and those queries fall back to the XLA bisect path (ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lookup_kernel(keys_a_ref, keys_b_ref, q_ref, qlo_ref,
                   rank_ref, found_ref, *, kb: int, window: int,
                   side: str = "left"):
    b = pl.program_id(0)
    base = b * kb
    keys2 = jnp.concatenate([keys_a_ref[...], keys_b_ref[...]])        # (2*KB,)
    q = q_ref[0, :]                                                    # (QCAP,)
    qlo = qlo_ref[0, :]                                                # (QCAP,) global
    j_global = base + jax.lax.iota(jnp.int32, 2 * kb)                  # (2*KB,)
    in_win = ((j_global[None, :] >= qlo[:, None]) &
              (j_global[None, :] < qlo[:, None] + window))             # (QCAP, 2KB)
    # side is static: "left" counts keys < q (rank of the first key >= q),
    # "right" counts keys <= q (one past the last key <= q) -- the same
    # masked compare-reduce serves point lookups and both search sides
    if side == "left":
        cnt = in_win & (keys2[None, :] < q[:, None])
    else:
        cnt = in_win & (keys2[None, :] <= q[:, None])
    eq = in_win & (keys2[None, :] == q[:, None])
    rank_ref[0, :] = qlo + jnp.sum(cnt.astype(jnp.int32), axis=1)
    found_ref[0, :] = jnp.any(eq, axis=1)


def fitting_lookup_pallas(keys_padded: jax.Array, q_bucketed: jax.Array,
                          qlo_bucketed: jax.Array, *, kb: int, window: int,
                          interpret: bool = True, side: str = "left"
                          ) -> tuple[jax.Array, jax.Array]:
    """Run the kernel over all key blocks.

    Args:
      keys_padded:  (n_blocks*KB,) f32, padded with +inf.
      q_bucketed:   (n_blocks, QCAP) f32 queries (+inf padding).
      qlo_bucketed: (n_blocks, QCAP) i32 global window starts
                    (must satisfy qlo // KB == block row).
      kb:           key block size (multiple of 128, >= window).
      window:       2*error + 2.
      side:         "left" counts keys < q (point lookups and left search),
                    "right" counts keys <= q (right search); static.
    Returns:
      rank:  (n_blocks, QCAP) i32 -- global rank of each bucketed query
             (the searchsorted insertion rank when the true rank is in the
             window; the wrapper's snap repairs straddling duplicate runs).
      found: (n_blocks, QCAP) bool.
    """
    n_blocks, qcap = q_bucketed.shape
    assert keys_padded.shape[0] == n_blocks * kb
    assert window <= kb, (window, kb)
    last = n_blocks - 1

    grid_spec = pl.GridSpec(
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((kb,), lambda b: (b,)),                     # keys block b
            pl.BlockSpec((kb,), lambda b, _l=last: (jnp.minimum(b + 1, _l),)),
            pl.BlockSpec((1, qcap), lambda b: (b, 0)),               # bucket queries
            pl.BlockSpec((1, qcap), lambda b: (b, 0)),               # bucket starts
        ],
        out_specs=[
            pl.BlockSpec((1, qcap), lambda b: (b, 0)),
            pl.BlockSpec((1, qcap), lambda b: (b, 0)),
        ],
    )
    kernel = functools.partial(_lookup_kernel, kb=kb, window=window, side=side)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, qcap), jnp.int32),
            jax.ShapeDtypeStruct((n_blocks, qcap), jnp.bool_),
        ],
        interpret=interpret,
    )(keys_padded, keys_padded, q_bucketed, qlo_bucketed)
