"""Pallas TPU kernel: blocked (flash) attention forward, causal / sliding
window / logit-softcap (gemma2/3) -- the serving attention for the 32k
prefill and long-context decode shapes.

Canonical online-softmax structure: grid = (B*H, Tq/bq, S/bk); the innermost
grid dim walks KV blocks while (acc, m, l) live in VMEM scratch across steps
(output block revisiting).  Per grid step VMEM = bq*hd + 2*bk*hd + bq*bk
floats; bq=bk=128-aligned for the MXU.  GQA is handled by the wrapper
(q heads grouped per kv head); backward is by design NOT provided -- training
uses the query-chunked XLA attention (models/blocks._attend) whose gradients
come from autodiff under remat (DESIGN.md §6).

Oracle: kernels/ref.attention_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int | None,
                  softcap: float | None, bq: int, bk: int, seq_k: int,
                  q_offset: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                     # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                     # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T) * scale                          # (bq, bk)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    qpos = qi * bq + jax.lax.iota(jnp.int32, bq)[:, None] + q_offset
    kpos = ki * bk + jax.lax.iota(jnp.int32, bk)[None, :]
    mask = kpos < seq_k
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                  # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(p, v)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    softcap: float | None = None, scale: float | None = None,
                    bq: int = 128, bk: int = 128, interpret: bool = True):
    """q: (B, H, Tq, hd); k, v: (B, Hkv, S, hd) with H % Hkv == 0.

    Returns (B, H, Tq, hd).  Query positions are aligned to the END of the
    key sequence (decode-friendly): q_offset = S - Tq.
    """
    b, h, tq, hd = q.shape
    _, hkv, s, _ = k.shape
    g = h // hkv
    scale = scale if scale is not None else hd ** -0.5
    tq_p = (tq + bq - 1) // bq * bq
    s_p = (s + bk - 1) // bk * bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, tq_p - tq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, s_p - s), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, s_p - s), (0, 0)))
    # fold batch+head into grid dim 0; map q head -> kv head
    qf = qp.reshape(b * h, tq_p, hd)
    kf = kp.reshape(b * hkv, s_p, hd)
    vf = vp.reshape(b * hkv, s_p, hd)

    grid = (b * h, tq_p // bq, s_p // bk)

    def q_map(i, j, kk):
        return (i, j, 0)

    def kv_map_fn(i, j, kk):
        bb = i // h
        hh = i % h
        return (bb * hkv + hh // g, kk, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, seq_k=s, q_offset=s - tq)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, bq, hd), q_map),
                  pl.BlockSpec((1, bk, hd), kv_map_fn),
                  pl.BlockSpec((1, bk, hd), kv_map_fn)],
        out_specs=pl.BlockSpec((1, bq, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((b * h, tq_p, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),   # acc
            pltpu.VMEM((bq, 1), jnp.float32),    # m (running max)
            pltpu.VMEM((bq, 1), jnp.float32),    # l (running denom)
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, tq_p, hd)[:, :, :tq]
