"""Pure-jnp oracles for the Pallas kernels.

Deliberately *independent* of the index machinery: ranks come from a full
searchsorted over the key column, so any interpolation/window/bucketing bug in
the kernel path shows up as a mismatch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lookup_ref(keys: jax.Array, queries: jax.Array) -> jax.Array:
    """Global rank of each query in the sorted `keys`, or -1 if absent."""
    rank = jnp.searchsorted(keys, queries, side="left")
    n = keys.shape[0]
    hit = (rank < n) & (keys[jnp.minimum(rank, n - 1)] == queries)
    return jnp.where(hit, rank, -1).astype(jnp.int32)


def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None,
                  softcap: float | None = None, scale: float | None = None):
    """Masked multi-head attention oracle.  q,k,v: (B, H, T, D) / (B, H, S, D)."""
    t, s = q.shape[-2], k.shape[-2]
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    logits = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    qpos = jnp.arange(t)[:, None] + (s - t)   # align ends (decode-friendly)
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhts,bhsd->bhtd", probs, v.astype(jnp.float32)
                      ).astype(q.dtype)


def rglru_ref(x, a_log, gate_x, gate_a):
    """RG-LRU oracle (RecurrentGemma Eq. 1-4), sequential scan over time.

    x, gate_x, gate_a: (B, T, D); a_log: (D,) learned log-decay.
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
    with a_t = exp(-c * softplus(a_log) * sigmoid(gate_a)), i_t = sigmoid(gate_x).
    """
    c = 8.0
    a = jnp.exp(-c * jax.nn.softplus(a_log)[None, None, :] *
                jax.nn.sigmoid(gate_a))
    gated = jax.nn.sigmoid(gate_x) * x
    mult = jnp.sqrt(jnp.clip(1.0 - a ** 2, 1e-12, None)).astype(jnp.float32)

    def step(h, inp):
        a_t, u_t = inp
        h = a_t * h + u_t
        return h, h

    u = (mult * gated.astype(jnp.float32))
    _, hs = jax.lax.scan(step, jnp.zeros(x.shape[::2], jnp.float32),
                         (jnp.moveaxis(a.astype(jnp.float32), 1, 0),
                          jnp.moveaxis(u, 1, 0)))
    return jnp.moveaxis(hs, 0, 1).astype(x.dtype)
