"""Pallas TPU kernels (validated with interpret=True on CPU; TPU is the target).

fitting_lookup -- the paper's hot path: batched learned-index probes
flash_attention -- blocked online-softmax attention (serving path)
rglru_scan -- blocked linear recurrence (RecurrentGemma serving path)
Each has a jit wrapper (ops.py) and a pure-jnp oracle (ref.py).
"""
from .ops import fitting_lookup, make_lookup_fn, make_plan
from .flash_attention import flash_attention
from .rglru_scan import rglru_scan_pallas

__all__ = ["fitting_lookup", "make_lookup_fn", "make_plan",
           "flash_attention", "rglru_scan_pallas"]
