"""Pallas TPU kernel: blocked linear recurrence for RG-LRU (RecurrentGemma).

h_t = a_t * h_{t-1} + u_t over time, independently per (batch, channel).
The channel dim is tiled into 128-lane blocks (grid = (B, W/bw)); each grid
step keeps its (T, bw) tile of a and u resident in VMEM and walks time with a
fori_loop carrying the (1, bw) state in registers/VMEM -- the memory-bound
roofline is one read of a,u + one write of h (3 * T * W * 4 B), with zero
HBM round-trips for the carried state (vs. 2x for a lax.scan whose carry
spills per step).

The associative-scan form (models/blocks._rglru_scan) remains the training
path (parallel depth log T); this kernel is the serving/long-context form
(sequential time, O(1) state) and the oracle for both is kernels/ref.rglru
/ _linear_scan_impl.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rglru_kernel(a_ref, u_ref, h0_ref, out_ref, hT_ref, *, t: int):
    h = h0_ref[0, :]                             # (bw,)

    def body(i, h):
        h = a_ref[0, i, :] * h + u_ref[0, i, :]
        out_ref[0, i, :] = h
        return h

    h = jax.lax.fori_loop(0, t, body, h)
    hT_ref[0, :] = h


def rglru_scan_pallas(u: jax.Array, a: jax.Array, h0: jax.Array | None = None,
                      *, bw: int = 128, interpret: bool = True):
    """u, a: (B, T, W) f32; h0: (B, W) initial state.  Returns (h, h_last)."""
    b, t, w = u.shape
    assert w % bw == 0, (w, bw)
    if h0 is None:
        h0 = jnp.zeros((b, w), jnp.float32)
    grid = (b, w // bw)
    in_specs = [
        pl.BlockSpec((1, t, bw), lambda i, j: (i, 0, j)),
        pl.BlockSpec((1, t, bw), lambda i, j: (i, 0, j)),
        pl.BlockSpec((1, bw), lambda i, j: (i, j)),
    ]
    out_specs = [
        pl.BlockSpec((1, t, bw), lambda i, j: (i, 0, j)),
        pl.BlockSpec((1, bw), lambda i, j: (i, j)),
    ]

    h, h_last = pl.pallas_call(
        functools.partial(_rglru_kernel, t=t),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=[jax.ShapeDtypeStruct((b, t, w), jnp.float32),
                   jax.ShapeDtypeStruct((b, w), jnp.float32)],
        interpret=interpret,
    )(a.astype(jnp.float32), u.astype(jnp.float32), h0.astype(jnp.float32))
    return h, h_last
