"""jit'd wrappers around the Pallas kernels (thin compatibility layer).

``fitting_lookup``: XLA prelude (router + interpolation + bucketing) ->
Pallas compare-reduce kernel -> scatter-back + bisect fallback for bucket
overflow.  The orchestration now lives once in ``repro.index.engine``
(``pallas_lookup`` / the ``pallas`` backend of ``make_engine``); this module
keeps the historical entry points.  Equivalent to ``ref.lookup_ref`` on every
input (tests sweep shapes/dtypes/errors); the kernel path answers all queries
whenever each key block starts at most QCAP windows (overflow is per-block,
flagged, and rare for non-adversarial batches).
"""
from __future__ import annotations

import functools

import jax

from repro.index.engine import (DeviceIndex, LookupPlan, make_plan, pad_keys,
                                pallas_lookup)

__all__ = ["LookupPlan", "make_plan", "pad_keys", "fitting_lookup",
           "make_lookup_fn"]


def make_lookup_fn(idx: DeviceIndex, *, qcap: int = 256, interpret: bool = True,
                   fallback: bool = True):
    """jit-compiled lookup closure over a fixed index (the serving path)."""
    return jax.jit(functools.partial(fitting_lookup, idx, qcap=qcap,
                                     interpret=interpret, fallback=fallback))


def fitting_lookup(idx: DeviceIndex, queries: jax.Array, *, qcap: int = 256,
                   interpret: bool = True, fallback: bool = True) -> jax.Array:
    """Batched point lookup via the Pallas kernel.  Returns ranks (-1 absent).

    ``idx.error`` must be a Python int (it sizes the kernel window), so jit
    this via ``make_lookup_fn`` (closure) rather than passing idx as a traced
    argument."""
    return pallas_lookup(idx, queries, qcap=qcap, interpret=interpret,
                         fallback=fallback)
