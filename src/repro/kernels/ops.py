"""jit'd wrappers around the Pallas kernels.

``fitting_lookup``: XLA prelude (router + interpolation + bucketing) ->
Pallas compare-reduce kernel -> scatter-back + bisect fallback for bucket
overflow.  Equivalent to ``ref.lookup_ref`` on every input (tests sweep
shapes/dtypes/errors); the kernel path answers all queries whenever each key
block starts at most QCAP windows (overflow is per-block, flagged, and rare
for non-adversarial batches).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.jax_index import DeviceIndex, lookup as _xla_lookup, predict_positions
from .fitting_lookup import fitting_lookup_pallas


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


class LookupPlan(NamedTuple):
    """Static geometry for a (N, error) pair."""
    kb: int         # key block size
    window: int     # 2*error + 2
    n_blocks: int
    n_pad: int


def make_plan(n_keys: int, error: int) -> LookupPlan:
    window = 2 * error + 2
    kb = max(128, _round_up(window, 128))
    n_pad = _round_up(max(n_keys, kb), kb)
    return LookupPlan(kb=kb, window=window, n_blocks=n_pad // kb, n_pad=n_pad)


def pad_keys(keys: jax.Array, plan: LookupPlan) -> jax.Array:
    pad = plan.n_pad - keys.shape[0]
    return jnp.pad(keys.astype(jnp.float32), (0, pad), constant_values=jnp.inf)


def make_lookup_fn(idx: DeviceIndex, *, qcap: int = 256, interpret: bool = True,
                   fallback: bool = True):
    """jit-compiled lookup closure over a fixed index (the serving path)."""
    return jax.jit(functools.partial(fitting_lookup, idx, qcap=qcap,
                                     interpret=interpret, fallback=fallback))


def fitting_lookup(idx: DeviceIndex, queries: jax.Array, *, qcap: int = 256,
                   interpret: bool = True, fallback: bool = True) -> jax.Array:
    """Batched point lookup via the Pallas kernel.  Returns ranks (-1 absent).

    ``idx.error`` must be a Python int (it sizes the kernel window), so jit
    this via ``make_lookup_fn`` (closure) rather than passing idx as a traced
    argument."""
    plan = make_plan(int(idx.keys.shape[0]), int(idx.error))
    keys_padded = pad_keys(idx.keys, plan)
    nq = queries.shape[0]
    queries = queries.astype(jnp.float32)

    # --- XLA prelude: router + interpolation -> window starts -> buckets
    pred = predict_positions(idx, queries)
    qlo = jnp.clip(pred - idx.error, 0, plan.n_pad - plan.window).astype(jnp.int32)
    blk = qlo // plan.kb                                    # owning key block
    order = jnp.argsort(blk, stable=True)
    blk_s = blk[order]
    slot = jnp.arange(nq, dtype=jnp.int32) - jnp.searchsorted(
        blk_s, blk_s, side="left").astype(jnp.int32)        # rank within bucket
    ok = slot < qcap
    q_b = jnp.full((plan.n_blocks, qcap), jnp.inf, jnp.float32)
    qlo_b = jnp.zeros((plan.n_blocks, qcap), jnp.int32)
    src_b = jnp.full((plan.n_blocks, qcap), -1, jnp.int32)
    slot_c = jnp.where(ok, slot, qcap - 1)
    q_b = q_b.at[blk_s, slot_c].set(jnp.where(ok, queries[order], jnp.inf))
    qlo_b = qlo_b.at[blk_s, slot_c].set(jnp.where(ok, qlo[order], 0))
    src_b = src_b.at[blk_s, slot_c].set(jnp.where(ok, order.astype(jnp.int32), -1))

    # --- Pallas kernel over key blocks
    rank_b, found_b = fitting_lookup_pallas(
        keys_padded, q_b, qlo_b, kb=plan.kb, window=plan.window,
        interpret=interpret)

    # --- scatter back
    res = jnp.full((nq,), jnp.iinfo(jnp.int32).min, jnp.int32)
    flat_src = src_b.reshape(-1)
    flat_ans = jnp.where(found_b.reshape(-1), rank_b.reshape(-1), -1)
    good = flat_src >= 0
    res = res.at[jnp.clip(flat_src, 0, None)].max(
        jnp.where(good, flat_ans, jnp.iinfo(jnp.int32).min))
    answered = res > jnp.iinfo(jnp.int32).min
    res = jnp.where(answered, res, -1)

    if fallback:
        # bucket-overflow queries (never bucketed) answered by the XLA bisect
        # path; lax.cond skips the work entirely when nothing overflowed.
        was_bucketed = jnp.zeros((nq,), bool).at[jnp.clip(flat_src, 0, None)].max(good)
        need = ~was_bucketed
        fb = jax.lax.cond(jnp.any(need),
                          lambda: _xla_lookup(idx, queries, "bisect"),
                          lambda: res)
        res = jnp.where(need, fb, res)
    return res
