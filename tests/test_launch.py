"""Launch-layer units: sharding rules, input specs, HLO analysis parsing."""
import jax
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.launch.hlo_analysis import analyze_collectives, shape_bytes
from repro.launch.sharding import batch_spec, cache_spec, param_spec
from repro.launch.specs import input_specs

def _abstract_mesh(sizes, names):
    try:
        return AbstractMesh(sizes, names)              # jax >= 0.4.38 signature
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))  # jax <= 0.4.37 signature


MESH = _abstract_mesh((16, 16), ("data", "model"))
MESH3 = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


class Leaf:
    def __init__(self, shape):
        self.shape = shape


class K:
    def __init__(self, key):
        self.key = key


def test_param_spec_rules():
    # embed (V, D): vocab->model, d->data
    assert param_spec((K("embed"),), Leaf((262144, 3840)), MESH) == \
        P("model", "data")
    # stacked attn wq: leading repeat dim unsharded
    path = (K("stacks"), K("s0"), K("b0"), K("attn"), K("wq"))
    assert param_spec(path, Leaf((8, 3840, 4096)), MESH) == \
        P(None, "data", "model")
    # moe experts: EP over model
    path = (K("stacks"), K("s0"), K("b0"), K("moe"), K("wi"))
    assert param_spec(path, Leaf((94, 128, 4096, 1536)), MESH) == \
        P(None, "model", "data", None)
    # non-divisible dims fall back to None: 36 heads % 16 != 0
    path = (K("stacks"), K("s0"), K("b0"), K("attn"), K("wq"))
    spec = param_spec(path, Leaf((40, 2304, 36 * 64)), MESH)
    assert spec == P(None, "data", ("model",)) or spec == P(None, "data", "model")


def test_param_spec_zero3():
    path = (K("stacks"), K("s0"), K("b0"), K("mlp"), K("wi"))
    spec = param_spec(path, Leaf((24, 2048, 8192)), MESH, policy="zero3")
    assert spec == P(None, ("data", "model"), None)


def test_batch_spec():
    assert batch_spec(MESH3, 256, 2) == P(("pod", "data"), None)
    assert batch_spec(MESH, 256, 2) == P(("data",), None)
    assert batch_spec(MESH, 1, 2) == P(None, None)      # long_500k: b=1
    assert batch_spec(MESH, 256, 2, policy="zero3") == \
        P(("data", "model"), None)


def test_cache_spec():
    # (R, B, L, Kv, hd): batch over dp, kv-heads over model when divisible
    s = cache_spec(MESH, Leaf((8, 128, 32768, 16, 128)), 128)
    assert s == P(None, ("data",), None, "model", None)
    # kv=1 (MQA): falls back to sequence sharding over model
    s = cache_spec(MESH, Leaf((8, 128, 32768, 1, 256)), 128)
    assert s == P(None, ("data",), "model", None, None)
    # b=1 long context: no batch sharding, seq over model
    s = cache_spec(MESH, Leaf((8, 1, 524288, 8, 256)), 1)
    assert s[1] is None and "model" in (s[2], s[3])


def test_input_specs_all_cells():
    """Every runnable (arch x shape) produces well-formed SDS trees."""
    n = 0
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape, spec in SHAPES.items():
            ok, _ = shape_applicable(cfg, shape)
            if not ok:
                continue
            kind, shapes = input_specs(arch, shape)
            n += 1
            if kind == "train":
                assert shapes["batch"]["tokens"].shape == \
                    (spec.global_batch, spec.seq_len)
            elif kind == "prefill":
                assert shapes["tokens"].shape == (spec.global_batch,
                                                  spec.seq_len)
                assert len(jax.tree.leaves(shapes["caches"])) > 0
            else:
                assert shapes["tokens"].shape == (spec.global_batch, 1)
                assert shapes["pos"].shape == (spec.global_batch,)
    assert n == 34          # 40 cells - 6 documented skips


def test_long500k_skips_documented():
    skipped = [a for a in ARCHS
               if not shape_applicable(get_config(a), "long_500k")[0]]
    assert sorted(skipped) == sorted([
        "internlm2-1.8b", "minicpm-2b", "arctic-480b", "qwen3-moe-235b-a22b",
        "llama-3.2-vision-11b", "whisper-medium"])


def test_shape_bytes():
    assert shape_bytes("bf16[16,1024]") == 16 * 1024 * 2
    assert shape_bytes("(f32[8,8], s32[4])") == 8 * 8 * 4 + 4 * 4
    assert shape_bytes("pred[100]") == 100


def test_hlo_analysis_synthetic():
    hlo = """
cond.1 (arg: (s32[], f32[4])) -> pred[] {
  %c = s32[] constant(12)
  ROOT %cmp = pred[] compare(%iter, %c), direction=LT
}

body.1 (arg: (s32[], f32[4])) -> (s32[], f32[4]) {
  %ag = f32[64,128] all-gather(%w), dimensions={0}
  %ar = f32[32,32] all-reduce(%x), to_apply=%add
}

ENTRY main (p: f32[4]) -> f32[4] {
  %w = (s32[], f32[4]) while(%t), condition=%cond.1, body=%body.1
  %ar2 = bf16[8] all-reduce(%y), to_apply=%add
}
"""
    res = analyze_collectives(hlo)
    assert res["all-gather_bytes"] == 12 * 64 * 128 * 4
    assert res["all-reduce_bytes"] == 12 * 32 * 32 * 4 + 8 * 2
    assert res["total_collective_bytes_raw"] == \
        64 * 128 * 4 + 32 * 32 * 4 + 8 * 2
    assert res["wire_bytes"] == 2 * res["all-reduce_bytes"] + \
        res["all-gather_bytes"]


def test_hlo_promoted_allreduce_halved():
    hlo = """
ENTRY main (p: f32[4]) -> f32[4] {
  %ar = f32[16] all-reduce(%y), to_apply=%add.clone_promoted
}
"""
    res = analyze_collectives(hlo)
    assert res["all-reduce_bytes"] == 16 * 4 // 2
