"""API-surface snapshot: the lazily-exported names and ``__all__`` stay in
sync, and every exported name actually resolves -- guarding the redesigned
surface (PEP 562 lazy modules) against silent drift."""
import importlib
import itertools

import pytest

LAZY_SETS = {
    "repro.index": ["_ENGINE_NAMES", "_SNAPSHOT_NAMES", "_SHARDED_NAMES",
                    "_FIT_NAMES", "_LSM_NAMES", "_DEVICE_NAMES",
                    "_PIPELINE_NAMES", "_TELEMETRY_NAMES"],
    "repro.core": ["_JAX_INDEX_NAMES"],
}

LAZY_HOMES = {  # lazy-set name -> submodule that must define those names
    "_ENGINE_NAMES": "repro.index.engine",
    "_SNAPSHOT_NAMES": "repro.index.snapshot",
    "_SHARDED_NAMES": "repro.index.sharded",
    "_FIT_NAMES": "repro.index.fit",
    "_LSM_NAMES": "repro.index.lsm",
    "_DEVICE_NAMES": "repro.index.device",
    "_PIPELINE_NAMES": "repro.index.pipeline",
    "_TELEMETRY_NAMES": "repro.index.telemetry",
    "_JAX_INDEX_NAMES": "repro.core.jax_index",
}


@pytest.mark.parametrize("modname", sorted(LAZY_SETS))
def test_all_covers_eager_and_lazy_names_exactly(modname):
    mod = importlib.import_module(modname)
    exported = list(mod.__all__)
    assert len(exported) == len(set(exported)), "duplicate names in __all__"
    lazy_sets = [getattr(mod, s) for s in LAZY_SETS[modname]]
    for a, b in itertools.combinations(lazy_sets, 2):
        assert not (a & b), "lazy-resolution sets overlap"
    lazy = set().union(*lazy_sets)
    assert lazy <= set(exported), \
        f"lazy names missing from __all__: {sorted(lazy - set(exported))}"
    eager = set(exported) - lazy
    missing = {n for n in eager if n not in vars(mod)}
    assert not missing, f"eagerly-exported names not defined: {sorted(missing)}"


@pytest.mark.parametrize("modname", [*sorted(LAZY_SETS), "repro.serve"])
def test_every_exported_name_resolves(modname):
    mod = importlib.import_module(modname)
    for name in mod.__all__:
        assert getattr(mod, name) is not None, name


@pytest.mark.parametrize("set_name", sorted(LAZY_HOMES))
def test_lazy_names_live_in_their_home_module(set_name):
    owner = next(m for m, sets in LAZY_SETS.items() if set_name in sets)
    names = getattr(importlib.import_module(owner), set_name)
    home = importlib.import_module(LAZY_HOMES[set_name])
    missing = [n for n in sorted(names) if not hasattr(home, n)]
    assert not missing, f"{LAZY_HOMES[set_name]} lacks {missing}"


def test_unknown_attribute_raises_attribute_error():
    import repro.core
    import repro.index
    for mod in (repro.index, repro.core):
        with pytest.raises(AttributeError, match="no attribute"):
            mod.definitely_not_exported


# The typed query plane's verb surface (repro.index.query): every engine
# backend, the serving handle, and both services must carry all of it --
# a backend or layer silently missing a verb would fracture the "identical
# answers everywhere" contract.
QUERY_VERBS = ("search", "point", "range", "count", "predecessor",
               "successor")


def test_query_verbs_on_every_backend_and_serving_layer():
    import numpy as np

    import repro.index as ri
    from repro.serve import IndexService

    keys = np.arange(64, dtype=np.float64)
    table = ri.SegmentTable.from_keys(keys, 8, assume_sorted=True)
    for backend in ri.available_backends():
        eng = ri.make_engine(table, backend)
        missing = [v for v in QUERY_VERBS if not callable(getattr(eng, v,
                                                                  None))]
        assert not missing, f"backend {backend} lacks verbs {missing}"
    svc = IndexService(keys, error=8)
    sharded = ri.ShardedIndexService(keys, error=8, n_shards=2,
                                     assume_sorted=True)
    lsm = ri.LsmIndexService(keys, error=8, assume_sorted=True)
    device = ri.DeviceShardedService(keys, error=8, device_count=1,
                                     assume_sorted=True)
    for layer in (svc, sharded, lsm, device, svc.handle):
        missing = [v for v in QUERY_VERBS if not callable(getattr(layer, v,
                                                                  None))]
        assert not missing, f"{type(layer).__name__} lacks verbs {missing}"


def test_metrics_surface_on_every_serving_layer():
    # the unified typed observability surface: metrics() everywhere, JSON
    # round-trip, and the legacy dict surfaces kept as deprecated wrappers
    import numpy as np

    import repro.index as ri
    from repro.serve import IndexService, Monitor

    keys = np.arange(256, dtype=np.float64)
    svc = IndexService(keys, error=8, monitor=Monitor())
    sharded = ri.ShardedIndexService(keys, error=8, n_shards=2,
                                     assume_sorted=True)
    device = ri.DeviceShardedService(keys, error=8, device_count=1,
                                     assume_sorted=True)
    for layer in (svc, sharded, device):
        m = layer.metrics()
        assert isinstance(m, ri.ServiceMetrics)
        assert m.schema_version == 1
        assert m.plan_revision == layer.plan.revision == 0
        assert len(m.shards) == m.n_shards
        assert ri.ServiceMetrics.from_json(m.to_json()) == m
    assert isinstance(device.metrics().device, ri.DeviceMetrics)
    with pytest.warns(DeprecationWarning):
        sharded.service_stats()
    with pytest.warns(DeprecationWarning):
        sharded.stats()


def test_query_result_types_exported_everywhere():
    import repro.index
    import repro.serve
    for mod in (repro.index, repro.serve):
        for name in ("PointResult", "RangeResult"):
            assert name in mod.__all__, (mod.__name__, name)
            assert getattr(mod, name) is not None
