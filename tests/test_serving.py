"""Serving substrate: paged KV allocator, compressed block tables, and the
continuous batcher (greedy decode == single-request reference)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import decode_step, init_caches, init_params, prefill
from repro.serve.batcher import ContinuousBatcher, Request
from repro.serve.paged_kv import (CompressedBlockTable, PagedKVCache,
                                  compressed_table)


# ----------------------------------------------------------------- paged kv
def test_paged_alloc_and_slots():
    pool = PagedKVCache(n_pages=16, page_size=4)
    pool.alloc_request(1)
    pool.append_token_capacity(1, 10)          # -> 3 pages
    assert len(pool.tables[1]) == 3
    slots = pool.physical_slots(1, np.arange(10))
    assert len(set(slots.tolist())) == 10
    pool.alloc_request(2)
    pool.append_token_capacity(2, 5)
    assert pool.utilization() == pytest.approx(5 / 16)
    pool.release(1)
    assert pool.utilization() == pytest.approx(2 / 16)


def test_paged_pool_exhaustion():
    pool = PagedKVCache(n_pages=2, page_size=4)
    pool.alloc_request(1)
    with pytest.raises(MemoryError):
        pool.append_token_capacity(1, 100)


def test_compressed_block_table():
    pool = PagedKVCache(n_pages=64, page_size=16)
    pool.alloc_request(5)
    pool.append_token_capacity(5, 512)          # contiguous: 32 pages
    ct = compressed_table(pool, 5)
    assert ct.size_bytes() == 24                # one run
    logical = np.arange(32)
    np.testing.assert_array_equal(ct.lookup(logical),
                                  np.asarray(pool.tables[5])[logical])
    # fragmented table still resolves exactly
    frag = [5, 6, 7, 30, 31, 2, 3, 4]
    ct2 = CompressedBlockTable(frag)
    np.testing.assert_array_equal(ct2.lookup(np.arange(8)), frag)
    assert ct2.size_bytes() == 3 * 24


# ------------------------------------------------------------------ batcher
def test_continuous_batcher_matches_sequential():
    cfg = reduced(get_config("internlm2-1.8b"))
    params = init_params(cfg, jax.random.key(0), dtype=jnp.float32)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab, size=l).astype(np.int32)
               for l in (7, 13, 5, 9, 11)]

    def reference(prompt, n_new=6):
        caches = init_caches(cfg, 1, 64, dtype=jnp.float32)
        logits, caches = prefill(params, cfg, jnp.asarray(prompt[None]),
                                 caches, last_only=True)
        toks = [int(np.argmax(np.asarray(logits[0, -1])))]
        pos = prompt.shape[0]
        for _ in range(n_new - 1):
            logits, caches = decode_step(
                params, cfg, jnp.asarray([[toks[-1]]], jnp.int32),
                jnp.asarray([pos], jnp.int32), caches)
            toks.append(int(np.argmax(np.asarray(logits[0, -1]))))
            pos += 1
        return toks

    b = ContinuousBatcher(cfg, params, n_slots=2, cache_len=64)
    for i, p in enumerate(prompts):
        b.submit(Request(rid=i, prompt=p, max_new=6))
    ticks = b.run_until_drained()
    assert len(b.completed) == 5
    assert ticks < 60
    for req in b.completed:
        assert req.out == reference(prompts[req.rid]), req.rid
