"""Serving substrate: paged KV allocator, compressed block tables, the
continuous batcher (greedy decode == single-request reference), and the
sharded index service (per-shard epochs, publish routing, no-op publish)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import decode_step, init_caches, init_params, prefill
from repro.serve import IndexService, ShardedIndexService
from repro.serve.batcher import ContinuousBatcher, Request
from repro.serve.paged_kv import (CompressedBlockTable, PagedKVCache,
                                  compressed_table)


# ----------------------------------------------------------------- paged kv
def test_paged_alloc_and_slots():
    pool = PagedKVCache(n_pages=16, page_size=4)
    pool.alloc_request(1)
    pool.append_token_capacity(1, 10)          # -> 3 pages
    assert len(pool.tables[1]) == 3
    slots = pool.physical_slots(1, np.arange(10))
    assert len(set(slots.tolist())) == 10
    pool.alloc_request(2)
    pool.append_token_capacity(2, 5)
    assert pool.utilization() == pytest.approx(5 / 16)
    pool.release(1)
    assert pool.utilization() == pytest.approx(2 / 16)


def test_paged_pool_exhaustion():
    pool = PagedKVCache(n_pages=2, page_size=4)
    pool.alloc_request(1)
    with pytest.raises(MemoryError):
        pool.append_token_capacity(1, 100)


def test_compressed_block_table():
    pool = PagedKVCache(n_pages=64, page_size=16)
    pool.alloc_request(5)
    pool.append_token_capacity(5, 512)          # contiguous: 32 pages
    ct = compressed_table(pool, 5)
    assert ct.size_bytes() == 24                # one run
    logical = np.arange(32)
    np.testing.assert_array_equal(ct.lookup(logical),
                                  np.asarray(pool.tables[5])[logical])
    # fragmented table still resolves exactly
    frag = [5, 6, 7, 30, 31, 2, 3, 4]
    ct2 = CompressedBlockTable(frag)
    np.testing.assert_array_equal(ct2.lookup(np.arange(8)), frag)
    assert ct2.size_bytes() == 3 * 24


# ------------------------------------------------------------------ batcher
def test_continuous_batcher_matches_sequential():
    cfg = reduced(get_config("internlm2-1.8b"))
    params = init_params(cfg, jax.random.key(0), dtype=jnp.float32)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab, size=l).astype(np.int32)
               for l in (7, 13, 5, 9, 11)]

    def reference(prompt, n_new=6):
        caches = init_caches(cfg, 1, 64, dtype=jnp.float32)
        logits, caches = prefill(params, cfg, jnp.asarray(prompt[None]),
                                 caches, last_only=True)
        toks = [int(np.argmax(np.asarray(logits[0, -1])))]
        pos = prompt.shape[0]
        for _ in range(n_new - 1):
            logits, caches = decode_step(
                params, cfg, jnp.asarray([[toks[-1]]], jnp.int32),
                jnp.asarray([pos], jnp.int32), caches)
            toks.append(int(np.argmax(np.asarray(logits[0, -1]))))
            pos += 1
        return toks

    b = ContinuousBatcher(cfg, params, n_slots=2, cache_len=64)
    for i, p in enumerate(prompts):
        b.submit(Request(rid=i, prompt=p, max_new=6))
    ticks = b.run_until_drained()
    assert len(b.completed) == 5
    assert ticks < 60
    for req in b.completed:
        assert req.out == reference(prompts[req.rid]), req.rid


# ------------------------------------------------------------ sharded index
def _index_keys(n, seed=0):
    rng = np.random.default_rng(seed)
    return np.sort(rng.choice(2 ** 23, size=n, replace=False)).astype(np.float64)


def test_inserts_land_in_owning_shard():
    keys = _index_keys(8000, seed=40)
    svc = ShardedIndexService(keys, error=64, n_shards=4, buffer_size=32,
                              assume_sorted=True)
    rng = np.random.default_rng(41)
    fresh = np.setdiff1d(
        rng.choice(2 ** 23, size=4000, replace=False).astype(np.float64), keys)
    picks = fresh[:: fresh.shape[0] // 60][:60]
    for k in picks:
        sid = svc.shard_of(float(k))
        svc.insert(float(k))
        # the owning shard's buffered-key set gained exactly this key
        assert any(k in b for b in svc.writers[sid].buffers), (k, sid)
        for other in range(svc.n_shards):
            if other != sid:
                assert not any(k in b for b in svc.writers[other].buffers)
    stats = svc.stats()
    assert sum(s.pending_inserts for s in stats) == picks.size
    # pending counters match the per-shard routing of the picks
    want = np.bincount([svc.shard_of(float(k)) for k in picks], minlength=4)
    assert [s.pending_inserts for s in stats] == want.tolist()


def test_publish_one_dirty_shard_leaves_other_epochs_untouched():
    keys = _index_keys(6000, seed=42)
    svc = ShardedIndexService(keys, error=64, n_shards=3, buffer_size=16,
                              assume_sorted=True)
    rng = np.random.default_rng(43)
    fresh = np.setdiff1d(
        rng.choice(2 ** 23, size=3000, replace=False).astype(np.float64), keys)
    mid = fresh[(fresh >= svc.boundaries[1]) & (fresh < svc.boundaries[2])][:8]
    for k in mid:
        svc.insert(float(k))
    snaps_before = [h.current() for h in svc.handles]
    published = svc.publish()
    assert list(published) == [1]
    assert svc.epochs() == [1, 2, 1]
    # untouched shards still serve the very same snapshot object
    assert svc.handles[0].current() is snaps_before[0]
    assert svc.handles[2].current() is snaps_before[2]
    assert np.all(svc.lookup(mid) >= 0)


def test_sharded_publish_subset_and_force():
    keys = _index_keys(4000, seed=44)
    svc = ShardedIndexService(keys, error=64, n_shards=2, buffer_size=16,
                              assume_sorted=True)
    assert svc.publish() == {}                      # nothing dirty: no-op
    assert svc.epochs() == [1, 1]
    forced = svc.publish(force=True)
    assert sorted(forced) == [0, 1] and svc.epochs() == [2, 2]
    rng = np.random.default_rng(45)
    fresh = np.setdiff1d(
        rng.choice(2 ** 23, size=2000, replace=False).astype(np.float64), keys)
    k0 = fresh[fresh < svc.boundaries[1]][0]
    svc.insert(float(k0))
    assert svc.publish(shards=[1]) == {}            # dirty shard excluded
    assert svc.pending_inserts == 1
    assert list(svc.publish(shards=[0])) == [0]
    assert svc.epochs() == [3, 2]


def test_index_service_publish_noop_when_clean():
    """Satellite fix: cadence loops may call publish() unconditionally."""
    keys = _index_keys(3000, seed=46)
    svc = IndexService(keys, error=64, buffer_size=16)
    snap1 = svc.publish()                           # clean: no-op
    assert svc.epoch == 1 and snap1.epoch == 1
    assert svc.handle.current() is snap1            # same installed snapshot
    new_key = float(np.setdiff1d(np.arange(2 ** 16, dtype=np.float64), keys)[0])
    svc.insert(new_key)
    assert svc.publish().epoch == 2                 # dirty: real epoch cut
    assert svc.publish().epoch == 2                 # clean again: no-op
    assert svc.lookup(np.asarray([new_key]))[0] >= 0


def test_sharded_auto_publish_cadence():
    keys = _index_keys(4000, seed=47)
    svc = ShardedIndexService(keys, error=64, n_shards=2, buffer_size=32,
                              publish_every=6, assume_sorted=True)
    rng = np.random.default_rng(48)
    fresh = np.setdiff1d(
        rng.choice(2 ** 23, size=2000, replace=False).astype(np.float64),
        keys)[:6]
    for k in fresh:
        svc.insert(float(k))
    assert svc.pending_inserts == 0                 # 6th insert triggered
    assert max(svc.epochs()) >= 2
    assert np.all(svc.lookup(fresh) >= 0)


def test_sharded_read_only_and_payload_guards():
    keys = _index_keys(2000, seed=49)
    svc = ShardedIndexService(keys, error=64, n_shards=2, assume_sorted=True)
    with pytest.raises(ValueError, match="read-only"):
        svc.insert(1.5)
    with pytest.raises(ValueError, match="publish_every requires"):
        ShardedIndexService(keys, error=64, n_shards=2, publish_every=5,
                            assume_sorted=True)
    svc2 = ShardedIndexService(keys, error=64, n_shards=2, buffer_size=8,
                               assume_sorted=True)
    with pytest.raises(ValueError, match="payload"):
        svc2.insert(1.5, value=b"x")


def test_publish_sees_direct_writer_inserts():
    """Writes through the public `tree` property (bypassing the service
    counter) must still mark the shard dirty and be published."""
    keys = _index_keys(2000, seed=50)
    svc = IndexService(keys, error=64, buffer_size=8)
    fresh = np.setdiff1d(np.arange(2 ** 16, dtype=np.float64), keys)
    k = float(fresh[0])
    svc.tree.insert(k)
    assert svc.publish().epoch == 2
    assert svc.lookup(np.asarray([k]))[0] >= 0
    burst = fresh[1:9]          # == buffer_size: may merge straight to pages
    for b in burst:
        svc.tree.insert(float(b))
    assert svc.publish().epoch == 3
    assert np.all(svc.lookup(burst) >= 0)
