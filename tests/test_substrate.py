"""Substrate tests: data pipeline (learned-index addressing), checkpointing
(atomicity, crc, elastic restore), int8 error-feedback compression, and the
fault-tolerance contract (die -> resume == uninterrupted run)."""
import json
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.data.pipeline import (DataPipeline, DocIndex, PipelineConfig,
                                 synthetic_corpus)
from repro.train.compress import compress_decompress, init_residual

SRC = str(pathlib.Path(__file__).parents[1] / "src")


# ------------------------------------------------------------------ pipeline
def test_doc_index_matches_searchsorted():
    corpus = synthetic_corpus(n_tokens=300_000, seed=3)
    di = DocIndex(corpus.boundaries, error=32)
    pos = np.random.default_rng(0).integers(0, corpus.n_tokens, size=5000)
    docs, offs = di.doc_of(pos)
    want = np.searchsorted(corpus.boundaries, pos, side="right") - 1
    np.testing.assert_array_equal(docs, want)
    np.testing.assert_array_equal(offs, pos - corpus.boundaries[want])
    assert di.index_size_bytes() < corpus.n_docs * 8


def test_pipeline_deterministic_and_resumable():
    corpus = synthetic_corpus(n_tokens=500_000, seed=1)
    mk = lambda: DataPipeline(corpus, PipelineConfig(seq_len=64, batch_size=4,
                                                     seed=7))
    p1, p2 = mk(), mk()
    for s in (0, 5, 11):
        np.testing.assert_array_equal(p1.batch_at(s)["tokens"],
                                      p2.batch_at(s)["tokens"])
    # different steps give different batches
    assert not np.array_equal(p1.batch_at(0)["tokens"],
                              p1.batch_at(1)["tokens"])


def test_pipeline_host_sharding_disjoint():
    corpus = synthetic_corpus(n_tokens=500_000, seed=1)
    a = DataPipeline(corpus, PipelineConfig(seq_len=64, batch_size=4,
                                            n_hosts=2, host_id=0, seed=7))
    b = DataPipeline(corpus, PipelineConfig(seq_len=64, batch_size=4,
                                            n_hosts=2, host_id=1, seed=7))
    sa = a._sample_ids(3)
    sb = b._sample_ids(3)
    assert set(sa).isdisjoint(set(sb))


def test_pipeline_prefetch_thread():
    corpus = synthetic_corpus(n_tokens=300_000, seed=2)
    p = DataPipeline(corpus, PipelineConfig(seq_len=64, batch_size=2))
    p.start(from_step=4)
    it = iter(p)
    s, batch = next(it)
    assert s == 4
    np.testing.assert_array_equal(batch["tokens"], p.batch_at(4)["tokens"])
    p.stop()


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": np.arange(10, dtype=np.float32),
            "b": {"c": np.ones((3, 4), np.int32), "d": np.float32(2.5)}}
    ckpt.save(tmp_path, 7, tree, extra={"note": "x"})
    assert ckpt.latest_step(tmp_path) == 7
    got, extra = ckpt.restore(tmp_path, 7, tree)
    assert extra["note"] == "x"
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(x, y), tree, got)


def test_checkpoint_crc_detects_corruption(tmp_path):
    tree = {"a": np.arange(100, dtype=np.float32)}
    d = ckpt.save(tmp_path, 1, tree)
    part = next(d.glob("part_*.npz"))
    raw = bytearray(part.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    part.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="corruption"):
        ckpt.restore(tmp_path, 1, tree)


def test_checkpoint_incomplete_ignored(tmp_path):
    tree = {"a": np.arange(4, dtype=np.float32)}
    ckpt.save(tmp_path, 3, tree)
    bad = tmp_path / "step_00000009"
    bad.mkdir()                       # no DONE marker -> must be ignored
    assert ckpt.latest_step(tmp_path) == 3


def test_async_saver_gc(tmp_path):
    s = ckpt.AsyncSaver(tmp_path, keep_last=2)
    tree = {"a": np.zeros(4, np.float32)}
    for step in (1, 2, 3, 4):
        s.save(step, tree)
    s.wait()
    s._gc()
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert kept == ["step_00000003", "step_00000004"]


# --------------------------------------------------------------- compression
def test_compression_error_feedback_unbiased():
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
    res = init_residual(g_true)
    acc = jnp.zeros((64, 64))
    n = 50
    for _ in range(n):
        dq, res = compress_decompress(g_true, res)
        acc = acc + dq["w"]
    # sum of dequantized grads ~= sum of true grads (error feedback closes gap)
    rel = float(jnp.abs(acc - n * g_true["w"]).max() /
                jnp.abs(g_true["w"]).max())
    assert rel < 0.05, rel


def test_compression_quantizes_to_int8_grid():
    g = {"w": jnp.asarray([[0.5, -1.0, 3.3]], jnp.float32)}
    dq, res = compress_decompress(g, init_residual(g))
    scale = 3.3 / 127.0
    q = np.asarray(dq["w"]) / scale
    np.testing.assert_allclose(q, np.round(q), atol=1e-4)


# ------------------------------------------------------------ fault tolerance
@pytest.mark.slow
def test_die_resume_matches_uninterrupted(tmp_path):
    """Kill at step 12, resume -> final metrics equal the uninterrupted run."""
    common = [sys.executable, "-m", "repro.launch.train", "--smoke",
              "--steps", "20", "--batch", "2", "--seq", "64",
              "--ckpt-every", "10", "--log-every", "1"]
    env = {"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"}

    r_full = subprocess.run(common + ["--ckpt-dir", str(tmp_path / "full")],
                            capture_output=True, text=True, timeout=900,
                            env=env)
    assert r_full.returncode == 0, r_full.stderr[-2000:]

    r_die = subprocess.run(common + ["--ckpt-dir", str(tmp_path / "fault"),
                                     "--die-at-step", "12"],
                           capture_output=True, text=True, timeout=900,
                           env=env)
    assert r_die.returncode == 42  # simulated hard failure
    r_res = subprocess.run(common + ["--ckpt-dir", str(tmp_path / "fault"),
                                     "--resume"],
                           capture_output=True, text=True, timeout=900,
                           env=env)
    assert r_res.returncode == 0, r_res.stderr[-2000:]
    assert "resumed from step 10" in r_res.stdout

    def last_losses(d):
        lines = (d / "metrics.jsonl").read_text().splitlines()
        return {json.loads(l)["step"]: json.loads(l)["loss"] for l in lines}

    full = last_losses(tmp_path / "full")
    fault = last_losses(tmp_path / "fault")
    # post-resume steps must match the uninterrupted run exactly
    for s in range(10, 20):
        assert abs(full[s] - fault[s]) < 1e-5, (s, full[s], fault[s])


@pytest.mark.slow
def test_train_with_compression_converges(tmp_path):
    """--compress (int8 EF grads) trains and checkpoints round-trip."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--smoke", "--steps",
         "12", "--batch", "2", "--seq", "64", "--compress", "--log-every",
         "1", "--ckpt-dir", str(tmp_path / "c"), "--ckpt-every", "6"],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"})
    assert r.returncode == 0, r.stderr[-2000:]
    lines = (tmp_path / "c" / "metrics.jsonl").read_text().splitlines()
    losses = [json.loads(l)["loss"] for l in lines]
    assert losses[-1] < losses[0]
