"""Telemetry + online re-planning (repro.index.telemetry).

Covers the PR's feedback loop end to end: the Monitor's ring semantics and
backends, least-squares recovery of known per-tier cost coefficients, the
Replanner's hysteresis (no flapping under repeated noisy measurements), and
the apply_plan hot-swap never tearing a concurrent reader (the same pinned-
ShardSet discipline the rebalance race test guards).
"""
import json
import threading

import numpy as np
import pytest

from repro.core.cost_model import (CostParams, calibrate, curve_crossings,
                                   fit_tier_curves, refit_params)
from repro.index.sharded import ShardedIndexService
from repro.index.table import SegmentTable, numpy_lookup
from repro.index.telemetry import (CH_SERVED_KEYS, CH_TIER_PREFIX,
                                   JSONLBackend, MemoryBackend, Monitor,
                                   Replanner, ServiceMetrics)


# ------------------------------------------------------------------- monitor
def test_ring_keeps_last_capacity_rows_in_order():
    mon = Monitor(MemoryBackend(capacity=4))
    for i in range(10):
        mon.record("ch", i, i * 10)
    rows = mon.channel("ch")
    np.testing.assert_array_equal(rows[:, 0], [6, 7, 8, 9])  # oldest-first
    assert mon.count("ch") == 10          # total includes dropped rows


def test_vector_channel_concatenates_samples():
    mon = Monitor()
    mon.record_many("keys", [1.0, 2.0])
    mon.record_many("keys", np.array([3.0]))
    np.testing.assert_array_equal(mon.channel("keys"), [1.0, 2.0, 3.0])


def test_disabled_monitor_records_nothing():
    mon = Monitor()
    mon.enabled = False
    mon.record("ch", 1.0)
    mon.record_many("keys", [1.0])
    assert mon.channels() == []


def test_jsonl_backend_persists_rows_on_flush(tmp_path):
    path = tmp_path / "telemetry.jsonl"
    mon = Monitor(JSONLBackend(path, capacity=8))
    mon.record("a", 1, 2)
    mon.record_many("k", [5.0, 6.0])
    assert mon.flush() == 2
    assert mon.flush() == 0               # nothing new since last flush
    mon.record("a", 3, 4)
    mon.close()                           # close flushes the remainder
    lines = [json.loads(x) for x in path.read_text().splitlines()]
    assert [(x["ch"], x["v"]) for x in lines] == [
        ("a", [1.0, 2.0]), ("k", [5.0, 6.0]), ("a", [3.0, 4.0])]


def test_concurrent_recording_loses_no_channel(tmp_path):
    mon = Monitor(MemoryBackend(capacity=1 << 14))
    n, threads = 2000, 4

    def hammer(t):
        for i in range(n):
            mon.record("ch", t, i)

    ts = [threading.Thread(target=hammer, args=(t,)) for t in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    # every append landed (capacity exceeds total): no torn rows, full count
    rows = mon.channel("ch")
    assert rows.shape == (n * threads, 2)
    assert mon.count("ch") == n * threads


# ------------------------------------------------------- curve fit + re-fit
def _synthetic_samples(rng, fixed, per, sizes, reps=16, noise=0.02):
    rows = []
    for b in sizes:
        ns = fixed + per * b
        rows += [(b, ns * (1 + rng.normal(0, noise))) for _ in range(reps)]
    return np.asarray(rows)


def test_fit_tier_curves_recovers_known_coefficients():
    rng = np.random.default_rng(3)
    truth = {"small": (50.0, 220.0), "medium": (30_000.0, 25.0),
             "large": (110_000.0, 2.0)}
    samples = {
        "small": _synthetic_samples(rng, *truth["small"], [1, 4, 16, 64]),
        "medium": _synthetic_samples(rng, *truth["medium"],
                                     [128, 512, 2048]),
        "large": _synthetic_samples(rng, *truth["large"],
                                    [4096, 16384, 65536])}
    curves = fit_tier_curves(samples)
    for tier, (fixed, per) in truth.items():
        got_f, got_p = curves[tier]
        assert got_p == pytest.approx(per, rel=0.15), tier
        # fixed costs are small relative to the sampled range; allow more
        assert got_f == pytest.approx(fixed, rel=0.5, abs=0.3 * fixed + 50)
    # the refit params reproduce the measured curves' routing decision
    cpu, tpu = refit_params(curves, error=64, n_segments=200)
    assert cpu.c_ns > 0 and tpu.vmem_step_ns > 0 and tpu.hbm_gbps > 0
    small_max, large_min = curve_crossings(curves)
    assert 1 <= small_max < large_min


def test_fit_tier_curves_skips_underdetermined_tiers():
    one_size = np.asarray([(64.0, 1000.0)] * 20)     # no slope information
    few = np.asarray([(1.0, 100.0), (64.0, 2000.0)])  # under min_samples
    curves = fit_tier_curves({"small": one_size, "medium": few})
    assert curves == {}
    assert fit_tier_curves({"medium": few}, min_samples=2)["medium"][1] > 0


def test_calibrate_returns_positive_measured_cost():
    keys = np.arange(20_000, dtype=np.float64)
    p = calibrate(keys, batch=256, repeats=2)
    assert isinstance(p, CostParams)
    assert p.c_ns > 0
    # measured per-probe cost on a real host is far from the hand-tuned 50ns
    assert p.c_ns != CostParams().c_ns


# ----------------------------------------------------------------- replanner
def _service_with_monitor(n=30_000, **kw):
    mon = Monitor()
    keys = np.sort(np.random.default_rng(0).uniform(0, 1e6, n))
    svc = ShardedIndexService(keys, error=64, n_shards=2, buffer_size=16,
                              backend="dispatch", monitor=mon,
                              assume_sorted=True, **kw)
    return svc, mon, keys


def _feed_measurements(mon, rng, noise=0.03):
    """Synthetic measured tier curves that disagree with the model: the
    medium tier is far cheaper than modeled, so the measured crossings sit
    elsewhere and the first replan has a real win to harvest."""
    truth = {"small": (100.0, 500.0), "medium": (5_000.0, 10.0),
             "large": (500_000.0, 9.0)}
    for tier, (fixed, per) in truth.items():
        sizes = {"small": [1, 8, 32], "medium": [128, 1024, 4096],
                 "large": [8192, 32768]}[tier]
        for b, ns in _synthetic_samples(rng, fixed, per, sizes,
                                        reps=12, noise=noise):
            mon.record(CH_TIER_PREFIX + tier, b, ns)


def test_replanner_applies_once_then_hysteresis_holds():
    svc, mon, _ = _service_with_monitor()
    rng = np.random.default_rng(11)
    svc.lookup(np.linspace(0, 1e6, 64))       # some served-keys samples
    svc.lookup(np.linspace(0, 1e6, 64))
    _feed_measurements(mon, rng)
    rp = Replanner(svc, interval_s=0.01, hysteresis=0.05)

    served = rp.replan()
    assert served is not None, f"first replan should win (win={rp.last_win})"
    assert svc.plan.revision >= 1
    assert rp.replans == 1
    rev = svc.plan.revision

    # repeated noisy measurements of the SAME reality: thresholds already sit
    # on the measured crossings, so no further swap fires (no flapping)
    for _ in range(4):
        _feed_measurements(mon, rng)
        assert rp.replan() is None, f"flapped (win={rp.last_win})"
    assert rp.replans == 1 and svc.plan.revision == rev
    assert rp.checks == 5


def test_replanner_step_is_rate_limited():
    svc, mon, _ = _service_with_monitor(n=5_000)
    rp = Replanner(svc, interval_s=3600.0)
    assert rp.step(now=0.0) is None       # nothing measured yet -> no-op
    before = rp.checks
    rp.step(now=1.0)                      # inside the interval: skipped
    assert rp.checks == before


def test_replanner_requires_a_monitor():
    keys = np.arange(1000, dtype=np.float64)
    svc = ShardedIndexService(keys, error=16, assume_sorted=True)
    with pytest.raises(ValueError, match="Monitor"):
        Replanner(svc)


# ------------------------------------------------------- hot-swap race test
@pytest.mark.slow
def test_reader_never_observes_torn_apply_plan_swap():
    """A Replanner-style apply_plan storm (threshold-only swaps interleaved
    with structural error/shard-count rebuilds) while a reader hammers
    lookups: any torn boundaries/handles/engine-opts view surfaces as a
    present key reported absent or non-monotonic global ranks."""
    rng = np.random.default_rng(23)
    base = np.sort(rng.choice(2 ** 20, size=12_000, replace=False)
                   ).astype(np.float64)
    svc = ShardedIndexService(base, error=64, n_shards=4, backend="dispatch",
                              monitor=Monitor(), assume_sorted=True)
    sample = base[::37]                   # sorted, distinct, always present
    failures: list[str] = []
    done = threading.Event()

    def reader():
        while not done.is_set():
            ranks = svc.lookup(sample)
            if np.any(ranks < 0):
                failures.append("present key reported absent")
                return
            if np.any(np.diff(ranks) <= 0):
                failures.append("non-monotonic global ranks (torn view)")
                return

    def swapper():
        for i in range(30):
            if i % 3 == 2:                # structural: re-segment + reshard
                p = svc.plan.replace(error=32 if svc.error == 64 else 64,
                                     n_shards=3 if svc.n_shards == 4 else 4)
            else:                         # lightweight: thresholds only
                p = svc.plan.replace(small_max=8 * (i + 1),
                                     large_min=8 * (i + 1) + 4096)
            svc.apply_plan(p)

    r = threading.Thread(target=reader)
    s = threading.Thread(target=swapper)
    r.start(); s.start()
    s.join(timeout=120)
    done.set()
    r.join(timeout=30)
    assert not failures, failures
    assert svc.plan.revision == 30        # every swap audited
    assert svc.shard_set.version == 31
    want = numpy_lookup(SegmentTable.from_keys(base, svc.error,
                                               assume_sorted=True), sample)
    np.testing.assert_array_equal(svc.lookup(sample), want)


# ------------------------------------------------------------ typed metrics
def test_metrics_snapshot_reflects_traffic_and_roundtrips():
    svc, mon, keys = _service_with_monitor(n=8_000)
    q = keys[::17][:256]
    for _ in range(10):
        svc.lookup(q)
    svc.range(float(keys[10]), float(keys[500]))
    m = svc.metrics()
    assert m.query_counts["points"] == 10 * q.size
    assert m.query_counts["ranges"] == 1
    assert m.tiers, "dispatch traffic should have recorded tier samples"
    assert sum(t.calls for t in m.tiers) >= 10
    m2 = ServiceMetrics.from_json(m.to_json())
    assert m2 == m
    assert mon.count(CH_SERVED_KEYS) >= 1


def test_metrics_snapshot_rejects_unknown_schema():
    svc, _, _ = _service_with_monitor(n=2_000)
    doc = json.loads(svc.metrics().to_json())
    doc["schema_version"] = 99
    with pytest.raises(ValueError, match="schema_version"):
        ServiceMetrics.from_json(json.dumps(doc))
