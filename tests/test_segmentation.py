"""Segmentation invariants: Alg. 1 / Alg. 2 / Theorem 3.1 / Sec. 3.4 bound."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (max_segments_bound, optimal_segmentation, shrinking_cone,
                        shrinking_cone_py, verify_segments)
from repro.core.datasets import iot_like, maps_like, step_data, uniform_keys


def _sorted_keys(draw_list):
    xs = np.sort(np.asarray(draw_list, dtype=np.float64))
    return xs


sorted_arrays = st.lists(
    st.floats(min_value=-1e9, max_value=1e9, allow_nan=False, allow_infinity=False),
    min_size=2, max_size=400,
).map(_sorted_keys)


@given(xs=sorted_arrays, error=st.integers(min_value=1, max_value=64))
@settings(max_examples=200, deadline=None)
def test_error_bound_invariant(xs, error):
    """Eq. 1: every key's interpolated position is within `error` of its rank."""
    segs = shrinking_cone(xs, error)
    assert verify_segments(xs, segs) <= error + 1e-6


@given(xs=sorted_arrays, error=st.integers(min_value=1, max_value=64))
@settings(max_examples=200, deadline=None)
def test_clamped_mode_bound_and_no_worse(xs, error):
    paper = shrinking_cone(xs, error, mode="paper")
    clamp = shrinking_cone(xs, error, mode="clamped")
    assert verify_segments(xs, clamp) <= error + 1e-6
    assert clamp.n_segments <= paper.n_segments


@given(xs=sorted_arrays, error=st.integers(min_value=1, max_value=64))
@settings(max_examples=150, deadline=None)
def test_fast_matches_reference(xs, error):
    """The chunked numpy scan reproduces the line-by-line Alg. 2 exactly."""
    fast = shrinking_cone(xs, error)
    ref = shrinking_cone_py(xs, error)
    np.testing.assert_array_equal(fast.base, ref.base)
    np.testing.assert_allclose(fast.slope, ref.slope, rtol=1e-12)


@given(xs=sorted_arrays, error=st.integers(min_value=1, max_value=32))
@settings(max_examples=60, deadline=None)
def test_optimal_not_worse_than_greedy(xs, error):
    greedy = shrinking_cone(xs, error)
    opt = optimal_segmentation(xs, error)
    assert opt <= greedy.n_segments
    assert opt >= 1


@given(xs=sorted_arrays, error=st.integers(min_value=1, max_value=32))
@settings(max_examples=60, deadline=None)
def test_optimal_segments_are_valid(xs, error):
    segs = optimal_segmentation(xs, error, return_segments=True)
    assert verify_segments(xs, segs) <= error + 1e-6


def test_theorem_3_1_min_segment_span():
    """A maximal segment covers >= error+1 locations (distinct keys, no dups)."""
    rng = np.random.default_rng(0)
    xs = np.sort(rng.uniform(0, 1e6, size=20_000))
    for error in (4, 16, 64):
        segs = shrinking_cone(xs, error)
        # all segments except possibly the last are maximal
        assert np.all(segs.count[:-1] >= error + 1)


def test_sec_3_4_segment_count_guarantee():
    rng = np.random.default_rng(1)
    xs = np.sort(rng.uniform(0, 1e6, size=50_000))
    for error in (8, 32, 128):
        segs = shrinking_cone(xs, error)
        assert segs.n_segments <= max_segments_bound(
            len(np.unique(xs)), xs.shape[0], error)


def test_worst_case_step_data():
    """Sec. 7.2 / Fig. 9: error < step -> ~1 segment per step; error >= step -> 1."""
    step = 100
    xs = step_data(n=50_000, step=step, jump=1e5, within=1.0)
    small = shrinking_cone(xs, error=step // 2)
    big = shrinking_cone(xs, error=2 * step)
    n_steps = 50_000 // step
    assert small.n_segments >= n_steps * 0.9
    assert big.n_segments <= max(3, n_steps // 50)


def test_linear_data_single_segment():
    xs = np.arange(10_000, dtype=np.float64) * 3.5
    segs = shrinking_cone(xs, error=2)
    assert segs.n_segments == 1
    assert verify_segments(xs, segs) <= 0.5


def test_duplicates_handled():
    xs = np.sort(np.repeat(np.arange(100, dtype=np.float64), 7))
    segs = shrinking_cone(xs, error=8)
    assert verify_segments(xs, segs) <= 8
    ref = shrinking_cone_py(xs, 8)
    np.testing.assert_array_equal(segs.base, ref.base)


def test_greedy_close_to_optimal_on_real_shapes():
    """Table 1 reproduction shape: ratio in ~[1.0, 2.0] on real-like data."""
    for make, err in ((iot_like, 10), (maps_like, 10), (uniform_keys, 10)):
        xs = make(20_000)
        greedy = shrinking_cone(xs, err).n_segments
        opt = optimal_segmentation(xs, err)
        assert opt <= greedy <= max(2.5 * opt, opt + 2), (make.__name__, greedy, opt)
