"""The LSM tiered write plane (``repro.index.lsm``).

Covers: the multi-level leftmost-rank fan-in against a ``np.searchsorted``
oracle on duplicate-heavy keys straddling memtable + runs (all five verbs,
before and after compaction), delete/upsert shadowing across spills
(tombstone-only spills included, payload newest-wins), ``insert_many`` ==
repeated ``insert``, a deliberately slowed compaction racing live readers
and a spilling writer (no torn ``LevelSet`` ever observed), the typed
``LsmMetrics`` node + lsm.* telemetry channels, the planner's write-mode
resolution (``write_heavy`` tri-state, ``open_index`` routing, knob/plan
clash), and the async pipeline's maintenance cadence driving compaction.
"""
import threading

import numpy as np
import pytest

from repro.index import (FitSpec, IndexPlan, LsmIndexService, Monitor,
                         ServiceMetrics, open_index, plan)
from repro.index.lsm import Memtable, MemtableFullError
from repro.index.telemetry import (CH_COMPACT, CH_MEMTABLE, CH_READ_AMP,
                                   CH_RUN_COUNT, CH_SPILL)


def _dup_heavy(rng, n, lim):
    """Integer-valued float keys from a small domain: duplicate-heavy."""
    return rng.integers(0, lim, size=n).astype(np.float64)


class _Oracle:
    """The live multiset as a plain sorted array, mirroring LSM semantics:
    ``delete`` drops every live occurrence, ``upsert`` leaves exactly one."""

    def __init__(self, keys=()):
        self.keys = np.sort(np.asarray(keys, np.float64))

    def insert(self, ks):
        self.keys = np.sort(np.concatenate(
            [self.keys, np.atleast_1d(np.asarray(ks, np.float64))]))

    def delete(self, k):
        self.keys = self.keys[self.keys != k]

    def upsert(self, k):
        self.delete(k)
        self.insert([k])


def _check_all_verbs(svc, oracle: _Oracle, probes: np.ndarray):
    keys = oracle.keys
    assert svc.n_live_keys() == keys.size
    for side in ("left", "right"):
        np.testing.assert_array_equal(
            svc.search(probes, side), np.searchsorted(keys, probes, side))
    for q in probes[:24]:
        l = int(np.searchsorted(keys, q, "left"))
        r = int(np.searchsorted(keys, q, "right"))
        p = svc.point(float(q))
        assert p.found == (r > l) and p.rank == (l if p.found else -1)
        pred = svc.predecessor(float(q))
        assert pred.rank == r - 1 and pred.found == (r > 0)
        suc = svc.successor(float(q))
        assert suc.rank == l and suc.found == (l < keys.size)
    lo = float(np.min(probes)) + 1.0
    hi = float(np.max(probes)) - 1.0
    a = int(np.searchsorted(keys, lo, "left"))
    b = int(np.searchsorted(keys, hi, "right"))
    assert int(svc.count(lo, hi)) == b - a
    rr = svc.range(lo, hi)
    assert (rr.lo_rank, rr.hi_rank) == (a, max(b, a))
    np.testing.assert_array_equal(rr.keys, keys[a:b])


# ------------------------------------------------------- fan-in vs the oracle
def test_fan_in_matches_searchsorted_oracle_across_levels():
    rng = np.random.default_rng(11)
    base = np.sort(_dup_heavy(rng, 600, 120))
    svc = LsmIndexService(base, error=16, assume_sorted=True,
                          memtable_capacity=32, level_fanout=3)
    oracle = _Oracle(base)
    probes = np.concatenate([_dup_heavy(rng, 64, 120),
                             rng.uniform(-5, 130, size=32)])
    for step in range(1200):
        op = rng.random()
        k = float(rng.integers(0, 120))
        if op < 0.55:
            svc.insert(k)
            oracle.insert([k])
        elif op < 0.75:
            svc.delete(k)
            oracle.delete(k)
        else:
            svc.upsert(k)
            oracle.upsert(k)
        if step % 97 == 0:
            svc.publish()
        if step % 211 == 0:
            _check_all_verbs(svc, oracle, probes)
    assert svc.level_set.n_runs > 1      # the workload actually tiered
    _check_all_verbs(svc, oracle, probes)
    svc.spill()
    while svc.compact(max_steps=4):
        pass
    _check_all_verbs(svc, oracle, probes)


def test_insert_many_equals_repeated_inserts():
    rng = np.random.default_rng(3)
    base = np.sort(_dup_heavy(rng, 300, 64))
    batch = _dup_heavy(rng, 500, 64)
    one = LsmIndexService(base, error=16, assume_sorted=True,
                          memtable_capacity=64)
    many = LsmIndexService(base, error=16, assume_sorted=True,
                           memtable_capacity=64)
    for k in batch:
        one.insert(float(k))
    assert many.insert_many(batch) == batch.size
    probes = np.arange(-1.0, 66.0, 0.5)
    assert one.n_live_keys() == many.n_live_keys() == base.size + batch.size
    for side in ("left", "right"):
        np.testing.assert_array_equal(one.search(probes, side),
                                      many.search(probes, side))


# ------------------------------------------------------------------ shadowing
def test_delete_and_upsert_shadow_older_levels():
    base = np.repeat(np.arange(8, dtype=np.float64), 3)     # 3 copies each
    svc = LsmIndexService(base, error=8, assume_sorted=True,
                          memtable_capacity=4)
    assert svc.spill() == 0               # nothing buffered: a no-op
    svc.insert(3.0)                       # 4th copy, newest level
    svc.delete(3.0)                       # kills memtable copy AND the run's
    assert int(svc.count(3.0, 3.0)) == 0
    assert svc.n_live_keys() == base.size - 3
    # tombstone-only fills still spill (auto at capacity, then forced) and
    # the spilled runs keep shadowing older levels with no live keys of
    # their own
    for k in (5.0, 6.0, 7.0, 1.0):
        svc.delete(k)
    svc.spill()
    assert int(svc.count(5.0, 7.0)) == 0
    assert int(svc.count(1.0, 1.0)) == 0
    assert svc.n_live_keys() == 3 * 3     # keys 0, 2, 4 survive
    # upsert: one live occurrence, everywhere, across all levels
    svc.upsert(4.0)
    assert int(svc.count(4.0, 4.0)) == 1
    while svc.compact(max_steps=4):
        pass
    assert int(svc.count(3.0, 3.0)) == 0
    assert int(svc.count(4.0, 4.0)) == 1
    assert svc.n_live_keys() == 7         # 0,0,0  2,2,2  4


def test_payload_newest_wins_across_spill_and_compaction():
    keys = np.arange(8, dtype=np.float64)
    svc = LsmIndexService(keys, error=8, assume_sorted=True,
                          memtable_capacity=4, payload=keys * 10)
    svc.upsert(5.0, 999.0)
    rr = svc.range(4.0, 6.0)
    np.testing.assert_array_equal(rr.keys, [4.0, 5.0, 6.0])
    np.testing.assert_array_equal(rr.payload, [40.0, 999.0, 60.0])
    svc.spill()
    while svc.compact(max_steps=4):
        pass
    rr = svc.range(4.0, 6.0)
    np.testing.assert_array_equal(rr.payload, [40.0, 999.0, 60.0])


def test_memtable_overflow_and_capacity_contract():
    mt = Memtable(4)
    for k in (3.0, 1.0, 2.0, 0.5):
        mt.insert(k)
    assert mt.is_full()
    with pytest.raises(MemtableFullError):
        mt.insert(9.0)
    np.testing.assert_array_equal(mt.view().keys, [0.5, 1.0, 2.0, 3.0])


# ------------------------------------------------- compaction vs reader race
def test_slow_compaction_never_tears_the_level_set():
    rng = np.random.default_rng(7)
    base = np.sort(_dup_heavy(rng, 400, 80))
    svc = LsmIndexService(base, error=16, assume_sorted=True,
                          memtable_capacity=16, level_fanout=4)
    oracle = _Oracle(base)
    low = _dup_heavy(rng, 5 * 16, 80)     # enough spills to arm a compaction
    for k in low:
        svc.insert(float(k))
    oracle.insert(low)
    assert svc.compactor.pick(svc.level_set.runs) is not None

    probes = np.arange(-1.0, 82.0, 0.25)
    want = {side: np.searchsorted(oracle.keys, probes, side)
            for side in ("left", "right")}
    in_merge, release = threading.Event(), threading.Event()

    def hook():
        in_merge.set()
        assert release.wait(10.0)

    svc.compactor._merge_hook = hook
    worker = threading.Thread(target=svc.compact, daemon=True)
    worker.start()
    assert in_merge.wait(10.0)
    try:
        # merge in flight: readers must see exactly the pre-merge truth
        for side in ("left", "right"):
            np.testing.assert_array_equal(svc.search(probes, side),
                                          want[side])
        # writer lands keys ABOVE the probe range mid-merge and spills:
        # the swap must reconcile runs prepended after the group was picked
        high = np.full(16, 500.0)
        svc.insert_many(high)
        oracle.insert(high)
        svc.spill()
        for side in ("left", "right"):
            np.testing.assert_array_equal(svc.search(probes, side),
                                          want[side])
    finally:
        release.set()
    worker.join(timeout=10.0)
    assert not worker.is_alive()
    svc.compactor._merge_hook = None
    _check_all_verbs(svc, oracle, probes)
    while svc.compact(max_steps=4):
        pass
    _check_all_verbs(svc, oracle, probes)


# -------------------------------------------------------- telemetry + metrics
def test_lsm_metrics_node_channels_and_json_round_trip():
    monitor = Monitor()
    rng = np.random.default_rng(5)
    svc = LsmIndexService(np.arange(64, dtype=np.float64), error=8,
                          assume_sorted=True, memtable_capacity=8,
                          level_fanout=2, monitor=monitor)
    for k in _dup_heavy(rng, 40, 64):
        svc.insert(float(k))
    svc.delete(2.0)
    svc.publish()
    svc.lookup(np.arange(16, dtype=np.float64))
    m = svc.metrics()
    assert m.service == "lsm" and m.lsm is not None
    lsm = m.lsm
    assert lsm.spills == svc.level_set.version - 1 - lsm.compactions >= 1
    assert len(lsm.run_counts) == len(lsm.run_keys) == lsm.n_levels
    assert sum(lsm.run_counts) == lsm.n_runs >= 1
    assert lsm.level_set_version == svc.version
    assert lsm.memtable_capacity == 8
    assert lsm.live_keys == svc.n_live_keys()
    assert m.pending_inserts == (svc.level_set.memtable.size
                                 + svc.level_set.memtable.tombstone_count)
    assert ServiceMetrics.from_json(m.to_json()) == m
    for ch in (CH_SPILL, CH_RUN_COUNT, CH_MEMTABLE, CH_READ_AMP):
        assert monitor.channel(ch).size, ch
    if lsm.compactions:
        assert monitor.channel(CH_COMPACT).size


# ------------------------------------------------------------------- planner
def test_write_heavy_spec_plans_the_lsm_mode():
    keys = np.sort(np.random.default_rng(1).uniform(0, 1e6, 4096))
    p = plan(keys, FitSpec(error=64, write_heavy=True, insert_rate=100_000))
    assert p.write_mode == "lsm" and p.n_shards == 1 and p.buffer_size == 0
    assert p.memtable_capacity == 25_000     # rate x 0.25 s, within clamps
    assert p.level_fanout >= 2
    report = p.explain()
    assert "write mode: lsm" in report and "write_heavy=True" in report
    svc = open_index(keys, FitSpec(error=64, write_heavy=True,
                                   insert_rate=100_000))
    assert isinstance(svc, LsmIndexService)
    assert svc.lookup(np.asarray([keys[7]]))[0] == 7


def test_error_one_with_inserts_resolves_to_lsm_by_default():
    keys = np.arange(512, dtype=np.float64)
    p = plan(keys, FitSpec(error=1, insert_rate=500))
    assert p.write_mode == "lsm" and p.buffer_size == 0
    assert "no Alg. 4 insert buffer" in p.explain()
    # pinning write_heavy=False keeps the historical loud failure
    with pytest.raises(ValueError, match="lift write_heavy=False"):
        plan(keys, FitSpec(error=1, insert_rate=500, write_heavy=False))


def test_lsm_plan_validation_and_knob_clash():
    with pytest.raises(ValueError, match="n_shards"):
        IndexPlan.from_knobs(error=64, write_mode="lsm", n_shards=2)
    with pytest.raises(ValueError, match="write_mode"):
        IndexPlan.from_knobs(error=64, write_mode="btree")
    p = IndexPlan.from_knobs(error=64, write_mode="lsm")
    with pytest.raises(TypeError, match="not both"):
        LsmIndexService(np.arange(8.0), error=8, plan=p)
    svc = LsmIndexService.from_plan(np.arange(8.0), p)
    assert svc.plan is p and svc.error == 64


# ------------------------------------------------------------------ pipeline
def test_async_maintenance_cadence_drives_compaction():
    from repro.serve import AsyncIndexService

    rng = np.random.default_rng(9)
    svc = LsmIndexService(np.sort(_dup_heavy(rng, 400, 100)), error=16,
                          assume_sorted=True, memtable_capacity=16,
                          level_fanout=2)
    with AsyncIndexService(svc, publish_interval_s=0.01,
                           flush_threshold=8, prewarm=False) as pipe:
        for k in _dup_heavy(rng, 200, 100):
            svc.insert(float(k))
        deadline = threading.Event()
        for _ in range(200):              # ~2 s budget for the cadence
            if svc.metrics().lsm.compactions:
                break
            deadline.wait(0.01)
        m = pipe.metrics()
    assert m.pipeline is not None
    assert m.pipeline.compactions >= 1
    assert svc.metrics().lsm.compactions >= 1
