"""Sec. 6 cost model: monotonicity, pessimism, the two choosers (incl.
infeasible budgets and alternate latency models), the segments-curve
learner's degenerate single-candidate form, and the dispatch tier
crossings derived from the same models."""

import math

from repro.core import (CostParams, FITingTree, TPUCostParams,
                        choose_error_for_latency, choose_error_for_space,
                        dispatch_thresholds, latency_ns, latency_ns_tpu,
                        learn_segments_fn, size_bytes, tier_cost_curves)
from repro.core.datasets import weblogs_like

P = CostParams(c_ns=50.0, fanout=16, fill=0.5, buffer_size=16)
CANDS = [16, 32, 64, 128, 256, 512, 1024, 4096, 16384]


def _segments_fn():
    keys = weblogs_like(100_000)
    return keys, learn_segments_fn(keys, CANDS, sample=None)


def test_latency_increases_with_error_at_fixed_segments():
    assert latency_ns(1024, 1000, P) > latency_ns(16, 1000, P)


def test_size_decreases_with_error():
    keys, fn = _segments_fn()
    sizes = [size_bytes(e, fn(e), P) for e in CANDS]
    assert all(a >= b for a, b in zip(sizes, sizes[1:]))


def test_size_model_is_pessimistic_but_close():
    """Fig. 10b: predicted size upper-bounds the real index size, within ~10x."""
    keys, fn = _segments_fn()
    for e in (64, 256, 1024):
        t = FITingTree(keys, error=e)
        predicted = size_bytes(e, fn(e), P)
        actual = t.index_size_bytes()
        assert predicted >= actual * 0.5
        assert predicted <= actual * 20


def test_choosers_respect_constraints():
    keys, fn = _segments_fn()
    e_lat = choose_error_for_latency(900.0, fn, CANDS, P)
    assert e_lat is not None
    assert latency_ns(e_lat, fn(e_lat), P) <= 900.0
    # smallest-size among feasible: any smaller-e candidate with ok latency is bigger
    for e in CANDS:
        if latency_ns(e, fn(e), P) <= 900.0:
            assert size_bytes(e_lat, fn(e_lat), P) <= size_bytes(e, fn(e), P)

    budget = 64 * 1024.0
    e_sz = choose_error_for_space(budget, fn, CANDS, P)
    assert e_sz is not None
    assert size_bytes(e_sz, fn(e_sz), P) <= budget
    for e in CANDS:
        if size_bytes(e, fn(e), P) <= budget:
            assert latency_ns(e_sz, fn(e_sz), P) <= latency_ns(e, fn(e), P)


def test_infeasible_returns_none():
    keys, fn = _segments_fn()
    assert choose_error_for_latency(1.0, fn, CANDS, P) is None
    assert choose_error_for_space(1.0, fn, CANDS, P) is None


def test_infeasible_budgets_with_latency_fn_and_empty_candidates():
    """Planner contract: the choosers signal infeasibility as None -- also
    under a substituted latency model and under an empty candidate sweep."""
    keys, fn = _segments_fn()
    tpu = TPUCostParams()
    tpu_lat = lambda e, s: latency_ns_tpu(e, s, tpu)  # noqa: E731
    assert choose_error_for_latency(1.0, fn, CANDS, P,
                                    latency_fn=tpu_lat) is None
    # feasible under the TPU model once the budget clears the DMA floor
    e = choose_error_for_latency(10 * tpu.dma_setup_ns, fn, CANDS, P,
                                 latency_fn=tpu_lat)
    assert e is not None
    assert latency_ns_tpu(e, fn(e), tpu) <= 10 * tpu.dma_setup_ns
    assert choose_error_for_latency(1e12, fn, [], P) is None
    assert choose_error_for_space(1e12, fn, [], P) is None


def test_learn_segments_fn_single_candidate_is_constant():
    """One measured error -> the log-log interpolation degenerates to a
    constant curve (np.interp clamps), not a crash or a zero."""
    keys, _ = _segments_fn()
    fn = learn_segments_fn(keys, [64], sample=None)
    s = fn(64)
    assert s >= 1
    assert fn(1) == fn(64) == fn(16384) == s


def test_dispatch_thresholds_ordering_and_tier_curves():
    """The tier crossings respect 0 <= small_max < large_min for any table
    shape, and the underlying curves have the fixed/marginal cost shape the
    dispatch design assumes (host: no fixed cost, highest marginal; pallas:
    highest fixed cost, lowest marginal)."""
    for error, segs in [(4, 2), (16, 200), (64, 1000), (1024, 50_000),
                        (16384, 2)]:
        small_max, large_min = dispatch_thresholds(error, segs)
        assert 0 <= small_max < large_min, (error, segs)
        curves = tier_cost_curves(error, segs)
        (f_s, p_s) = curves["small"]
        (f_m, p_m) = curves["medium"]
        (f_l, p_l) = curves["large"]
        assert f_s <= f_m <= f_l
        assert p_s > p_m
        if error <= 1024:       # a huge +-error window streams more HBM
            assert p_m > p_l    # bytes than the bisect's pointwise probes,
        else:                   # so pallas rightly loses its marginal edge
            assert large_min >= 1 << 31     # ...and is effectively disabled
    # a costlier host model pushes the device crossover earlier
    slow_host = CostParams(c_ns=500.0)
    fast_host = CostParams(c_ns=50.0)
    assert dispatch_thresholds(64, 1000, cpu=slow_host)[0] \
        <= dispatch_thresholds(64, 1000, cpu=fast_host)[0]
    # the host tier serves a published snapshot (no write buffers), so its
    # marginal cost must not include the Eq. 1 buffer-scan term
    p = CostParams()
    host = tier_cost_curves(64, 1000, cpu=p)["small"][1]
    assert host == latency_ns(64, 1000, p) \
        - p.c_ns * math.log2(max(p.buffer_size, 2))


def test_tpu_model_window_term_scales_with_error():
    tp = TPUCostParams()
    small = latency_ns_tpu(64, 1000, tp)
    large = latency_ns_tpu(65536, 1000, tp)
    assert large > small
    # the window DMA term should dominate for huge errors
    assert large - small > 0.5 * (2 * 65536 * 8) / tp.hbm_gbps
