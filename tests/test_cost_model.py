"""Sec. 6 cost model: monotonicity, pessimism, and the two choosers."""

from repro.core import (CostParams, FITingTree, TPUCostParams,
                        choose_error_for_latency, choose_error_for_space,
                        latency_ns, latency_ns_tpu, learn_segments_fn, size_bytes)
from repro.core.datasets import weblogs_like

P = CostParams(c_ns=50.0, fanout=16, fill=0.5, buffer_size=16)
CANDS = [16, 32, 64, 128, 256, 512, 1024, 4096, 16384]


def _segments_fn():
    keys = weblogs_like(100_000)
    return keys, learn_segments_fn(keys, CANDS, sample=None)


def test_latency_increases_with_error_at_fixed_segments():
    assert latency_ns(1024, 1000, P) > latency_ns(16, 1000, P)


def test_size_decreases_with_error():
    keys, fn = _segments_fn()
    sizes = [size_bytes(e, fn(e), P) for e in CANDS]
    assert all(a >= b for a, b in zip(sizes, sizes[1:]))


def test_size_model_is_pessimistic_but_close():
    """Fig. 10b: predicted size upper-bounds the real index size, within ~10x."""
    keys, fn = _segments_fn()
    for e in (64, 256, 1024):
        t = FITingTree(keys, error=e)
        predicted = size_bytes(e, fn(e), P)
        actual = t.index_size_bytes()
        assert predicted >= actual * 0.5
        assert predicted <= actual * 20


def test_choosers_respect_constraints():
    keys, fn = _segments_fn()
    e_lat = choose_error_for_latency(900.0, fn, CANDS, P)
    assert e_lat is not None
    assert latency_ns(e_lat, fn(e_lat), P) <= 900.0
    # smallest-size among feasible: any smaller-e candidate with ok latency is bigger
    for e in CANDS:
        if latency_ns(e, fn(e), P) <= 900.0:
            assert size_bytes(e_lat, fn(e_lat), P) <= size_bytes(e, fn(e), P)

    budget = 64 * 1024.0
    e_sz = choose_error_for_space(budget, fn, CANDS, P)
    assert e_sz is not None
    assert size_bytes(e_sz, fn(e_sz), P) <= budget
    for e in CANDS:
        if size_bytes(e, fn(e), P) <= budget:
            assert latency_ns(e_sz, fn(e_sz), P) <= latency_ns(e, fn(e), P)


def test_infeasible_returns_none():
    keys, fn = _segments_fn()
    assert choose_error_for_latency(1.0, fn, CANDS, P) is None
    assert choose_error_for_space(1.0, fn, CANDS, P) is None


def test_tpu_model_window_term_scales_with_error():
    tp = TPUCostParams()
    small = latency_ns_tpu(64, 1000, tp)
    large = latency_ns_tpu(65536, 1000, tp)
    assert large > small
    # the window DMA term should dominate for huge errors
    assert large - small > 0.5 * (2 * 65536 * 8) / tp.hbm_gbps
