"""Executed in a subprocess by test_distributed.py: shard_map expert-parallel
MoE == the pure-XLA dispatch, values and grads, incl. dense-residual."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import blocks as BL
from repro.models.act_ctx import activation_sharding
from repro.models.config import MoEConfig

mesh = jax.make_mesh((4, 2), ("data", "model"))
x = jax.random.normal(jax.random.key(1), (4, 16, 64), jnp.float32)

for arch, dense in (("qwen3-moe-235b-a22b", False), ("arctic-480b", True)):
    cfg = dataclasses.replace(
        reduced(get_config(arch)),
        moe=MoEConfig(8, 2, 64, dense_residual=dense, capacity_factor=8.0))
    p = BL.init_moe(cfg, jax.random.key(0), dtype=jnp.float32)
    ref = BL._apply_moe_xla(p, x, cfg)
    with activation_sharding(mesh):
        got = jax.jit(lambda p, x, c=cfg: BL.apply_moe(p, x, c))(p, x)
        g = jax.jit(jax.grad(
            lambda p, c=cfg: jnp.sum(BL.apply_moe(p, x, c) ** 2)))(p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)
    assert all(np.all(np.isfinite(np.asarray(l))) for l in jax.tree.leaves(g))
    print(f"{arch}: EP == XLA, grads finite")
print("ALL_OK")
