"""Shared test configuration.

When the real `hypothesis` package is unavailable (offline images; see
pyproject's dev extra for the declared dependency), install a deterministic,
minimal stand-in covering exactly the subset this suite uses: ``@given`` with
keyword strategies, ``@settings(max_examples=..., deadline=...)``, and
``st.integers / floats / booleans / sampled_from / lists / just`` with
``.map()``.  Draws are seeded per test function, so runs are reproducible.

The whole suite runs with the runtime concurrency sanitizer on by default
(``repro.analysis.sanitizer``: frozen published arrays, shard-set pin
tracking, lock-order watchdog).  Export ``REPRO_SANITIZE=0`` to measure or
debug without it; CI's bench jobs do exactly that.
"""
from __future__ import annotations

import importlib.util
import os
import random
import sys
import types
import zlib

os.environ.setdefault("REPRO_SANITIZE", "1")


def _install_hypothesis_stub() -> None:
    class SearchStrategy:
        def __init__(self, draw_fn):
            self._draw = draw_fn

        def draw(self, rnd: random.Random):
            return self._draw(rnd)

        def map(self, fn):
            return SearchStrategy(lambda rnd: fn(self._draw(rnd)))

    def integers(min_value=0, max_value=2 ** 32):
        return SearchStrategy(
            lambda rnd: rnd.randint(int(min_value), int(max_value)))

    def floats(min_value=0.0, max_value=1.0, allow_nan=False,
               allow_infinity=False, width=64):
        return SearchStrategy(
            lambda rnd: rnd.uniform(float(min_value), float(max_value)))

    def booleans():
        return SearchStrategy(lambda rnd: rnd.random() < 0.5)

    def sampled_from(elements):
        pool = list(elements)
        return SearchStrategy(lambda rnd: pool[rnd.randrange(len(pool))])

    def lists(elements, min_size=0, max_size=None):
        cap = int(max_size) if max_size is not None else int(min_size) + 10
        return SearchStrategy(
            lambda rnd: [elements.draw(rnd)
                         for _ in range(rnd.randint(int(min_size), cap))])

    def just(value):
        return SearchStrategy(lambda rnd: value)

    def settings(max_examples=100, deadline=None, **_ignored):
        def deco(fn):
            fn._stub_settings = {"max_examples": int(max_examples)}
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            # NOTE: no functools.wraps -- __wrapped__ would make pytest
            # introspect fn's signature and demand fixtures for the
            # strategy-provided parameters.
            def wrapper(*args, **kwargs):
                conf = (getattr(wrapper, "_stub_settings", None)
                        or getattr(fn, "_stub_settings", None)
                        or {"max_examples": 100})
                seed = zlib.crc32(fn.__qualname__.encode())
                rnd = random.Random(seed)
                for i in range(conf["max_examples"]):
                    example = {k: s.draw(rnd) for k, s in strategies.items()}
                    try:
                        fn(*args, **example, **kwargs)
                    except Exception as exc:
                        raise AssertionError(
                            f"falsifying example (run {i} of {fn.__name__}): "
                            f"{ {k: _short(v) for k, v in example.items()} }"
                        ) from exc
            for attr in ("__name__", "__qualname__", "__doc__", "__module__"):
                setattr(wrapper, attr, getattr(fn, attr))
            wrapper._stub_settings = getattr(fn, "_stub_settings", None)
            return wrapper
        return deco

    def _short(v, cap=200):
        r = repr(v)
        return r if len(r) <= cap else r[:cap] + "..."

    st_mod = types.ModuleType("hypothesis.strategies")
    for f in (integers, floats, booleans, sampled_from, lists, just):
        setattr(st_mod, f.__name__, f)
    st_mod.SearchStrategy = SearchStrategy

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st_mod
    hyp.__stub__ = True  # marker for debugging

    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod


if importlib.util.find_spec("hypothesis") is None:
    _install_hypothesis_stub()
