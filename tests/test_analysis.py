"""The analyzer analyzed: per-rule good/bad fixtures, suppression handling,
lock-order cycle detection, the end-to-end clean-on-src/repro gate, and the
runtime sanitizer (freeze-on-publish, PinTracker, lock-order watchdog)."""
from __future__ import annotations

import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import sanitizer
from repro.analysis.contracts import LOCK_ORDER, hot_path
from repro.analysis.invariants import RULES, Analyzer, check_source

SRC = Path(__file__).resolve().parent.parent / "src"


def codes(violations):
    return [v.rule for v in violations]


def check(src: str, path: str = "repro/somewhere/mod.py"):
    return check_source(textwrap.dedent(src), path)


# --------------------------------------------------------------------- RI001
class TestRI001FrozenMutation:
    def test_fires_on_annotated_param_store(self):
        vs = check("""
            def f(table: SegmentTable):
                table.epoch = 2
        """)
        assert codes(vs) == ["RI001"]
        assert "SegmentTable" in vs[0].message

    def test_fires_on_constructor_local_and_del(self):
        vs = check("""
            def f():
                snap = Snapshot(table=None, epoch=1, n_refit=0)
                snap.epoch = 2
                del snap.payload
        """)
        assert codes(vs) == ["RI001", "RI001"]

    def test_fires_on_object_setattr_outside_allowlist(self):
        vs = check("""
            def f(plan):
                object.__setattr__(plan, "revision", 99)
        """)
        assert codes(vs) == ["RI001"]

    def test_fires_on_self_store_in_frozen_class_method(self):
        vs = check("""
            class ShardSet:
                def grow(self):
                    self.version = self.version + 1
        """)
        assert codes(vs) == ["RI001"]

    def test_clean_on_init_and_builders(self):
        vs = check("""
            class ShardSet:
                def __post_init__(self):
                    object.__setattr__(self, "version", int(self.version))
            def g():
                table = SegmentTable.from_keys([1.0], 4)
                return table.n_segments
        """)
        assert vs == []

    def test_allowlisted_builder_is_clean(self):
        vs = check("""
            def device_index(table):
                object.__setattr__(table, "_device_cache", 1)
        """, path="src/repro/index/engine.py")
        assert vs == []

    def test_reassigned_local_is_not_frozen(self):
        vs = check("""
            def f():
                t = SegmentTable.empty(4)
                t = make_mutable_copy(t)
                t.epoch = 2
        """)
        assert vs == []


# --------------------------------------------------------------------- RI002
class TestRI002DoubleDeref:
    def test_fires_on_double_shard_set_read(self):
        vs = check("""
            class Svc:
                def lookup(self, q):
                    sid = route(self._shard_set.boundaries, q)
                    return self._shard_set.handles[0]
        """)
        assert codes(vs) == ["RI002"]
        assert "first read at line" in vs[0].message

    def test_fires_on_handle_suffix_field(self):
        vs = check("""
            def f(svc):
                a = svc.serving_handle.epoch
                b = svc.serving_handle.epoch
        """)
        assert codes(vs) == ["RI002"]

    def test_clean_when_pinned_once(self):
        vs = check("""
            class Svc:
                def lookup(self, q):
                    ss = self._shard_set
                    return route(ss.boundaries, q), ss.handles
                def install(self, new):
                    self._shard_set = new      # store, not a read
        """)
        assert vs == []

    def test_separate_methods_pin_independently(self):
        vs = check("""
            class Svc:
                def a(self):
                    return self._shard_set.version
                def b(self):
                    return self._shard_set.version
        """)
        assert vs == []


# --------------------------------------------------------------------- RI003
class TestRI003InplaceMutation:
    def test_fires_on_subscript_store_through_field(self):
        vs = check("""
            def f(snap):
                snap.table.keys[0] = -1.0
        """)
        assert codes(vs) == ["RI003"]

    def test_fires_on_alias_augassign_and_methods(self):
        vs = check("""
            def f(table):
                k = table.keys
                k[3:] = 0.0
                k += 1
                table.start_key.sort()
        """)
        assert codes(vs) == ["RI003", "RI003", "RI003"]

    def test_copy_breaks_the_alias(self):
        vs = check("""
            def f(table):
                k = table.keys.copy()
                k[0] = -1.0
                k.sort()
        """)
        assert vs == []

    def test_local_scratch_arrays_are_fine(self):
        vs = check("""
            def f(n):
                boundaries = np.empty(n)
                boundaries[0] = 1.0
                out = np.zeros(n)
                out[1:] = 2.0
                out.fill(0)
        """)
        assert vs == []


# --------------------------------------------------------------------- RI004
class TestRI004HostOnlyImports:
    def test_fires_on_module_scope_jax(self):
        vs = check("""
            import numpy as np
            import jax
        """, path="src/repro/index/table.py")
        assert codes(vs) == ["RI004"]

    def test_fires_on_transitive_accel_module(self):
        vs = check("""
            from repro.index.engine import make_engine
        """, path="src/repro/core/tree.py")
        assert codes(vs) == ["RI004"]

    def test_fires_on_relative_import_of_engine(self):
        vs = check("""
            from .engine import make_engine
        """, path="src/repro/index/telemetry.py")
        assert codes(vs) == ["RI004"]

    def test_clean_on_lazy_and_type_checking_imports(self):
        vs = check("""
            from typing import TYPE_CHECKING
            if TYPE_CHECKING:
                import jax
            def f():
                import jax.numpy as jnp
                return jnp
        """, path="src/repro/index/table.py")
        assert vs == []

    def test_non_host_modules_may_import_jax(self):
        vs = check("import jax\n", path="src/repro/index/engine.py")
        assert vs == []


# --------------------------------------------------------------------- RI005
class TestRI005HotPath:
    def test_fires_on_lock_acquisition(self):
        vs = check("""
            class M:
                @hot_path
                def record(self, v):
                    with self._make_lock:
                        pass
        """)
        assert codes(vs) == ["RI005"]

    def test_fires_on_logging_and_acquire(self):
        vs = check("""
            @hot_path
            def dispatch(q):
                logging.info("dispatching %s", q)
                some_lock.acquire()
        """)
        assert sorted(codes(vs)) == ["RI005", "RI005"]

    def test_undecorated_function_may_lock(self):
        vs = check("""
            class M:
                def _make(self):
                    with self._make_lock:
                        pass
        """)
        assert vs == []


# --------------------------------------------------------------------- RI006
class TestRI006DeprecatedStats:
    def test_fires_on_each_deprecated_surface(self):
        vs = check("""
            def f(svc, pipe):
                a = svc.stats()
                b = svc.service_stats()
                c = pipe.pipeline_stats()
        """)
        assert codes(vs) == ["RI006", "RI006", "RI006"]

    def test_metrics_is_clean(self):
        vs = check("""
            def f(svc):
                return svc.metrics().shards
        """)
        assert vs == []


# --------------------------------------------------------------------- RI007
class TestRI007LockOrder:
    def test_fires_on_declared_order_inversion(self):
        vs = check("""
            class ShardedIndexService:
                def bad(self):
                    with self._counts_lock:      # innermost rank
                        with self._write_lock:   # outermost rank: inversion
                            pass
        """)
        assert codes(vs) == ["RI007"]
        assert "declared order" in vs[0].message

    def test_fires_on_cycle_between_functions(self):
        vs = check("""
            def f():
                with a_lock:
                    with b_lock:
                        pass
            def g():
                with b_lock:
                    with a_lock:
                        pass
        """)
        assert codes(vs) == ["RI007"]
        assert "cycle" in vs[0].message

    def test_consistent_nesting_is_clean(self):
        vs = check("""
            class ShardedIndexService:
                def good(self):
                    with self._write_lock:
                        with self._counts_lock:
                            pass
            def h():
                with a_lock:
                    with b_lock:
                        pass
        """)
        assert vs == []


# --------------------------------------------------------- suppression + CLI
class TestSuppressionAndDriver:
    def test_allow_comment_suppresses_only_named_rule(self):
        vs = check("""
            def f(svc, table: SegmentTable):
                a = svc.stats()  # repro: allow[RI006]
                table.epoch = 2  # repro: allow[RI006]
        """)
        assert codes(vs) == ["RI001"]

    def test_allow_comment_takes_a_code_list(self):
        vs = check("""
            def f(svc, table: SegmentTable):
                table.epoch = svc.stats()  # repro: allow[RI001, RI006]
        """)
        assert vs == []

    def test_rule_table_covers_all_codes(self):
        assert sorted(RULES) == [f"RI00{i}" for i in range(1, 8)]

    def test_syntax_error_is_reported_not_raised(self):
        analyzer = Analyzer()
        assert analyzer.check_source("def broken(:\n", "bad.py") == []
        assert analyzer.errors and "syntax error" in analyzer.errors[0]

    def test_declared_lock_order_names_are_unique(self):
        assert len(set(LOCK_ORDER)) == len(LOCK_ORDER)


# ------------------------------------------------------------- end-to-end
class TestEndToEnd:
    def test_checker_runs_clean_on_src_repro(self):
        analyzer = Analyzer()
        analyzer.check_paths([str(SRC / "repro")])
        violations = analyzer.finish()
        assert violations == [], "\n".join(str(v) for v in violations)
        assert not analyzer.errors, analyzer.errors

    def test_cli_strict_exits_zero_on_src(self):
        proc = subprocess.run(
            [sys.executable, "-m", "repro.analysis", str(SRC), "--strict"],
            capture_output=True, text=True,
            cwd=SRC.parent, env={"PYTHONPATH": str(SRC), "PATH": "/usr/bin"})
        assert proc.returncode == 0, proc.stdout + proc.stderr

    def test_cli_reports_violations_with_exit_one(self, tmp_path):
        bad = tmp_path / "bad.py"
        bad.write_text("def f(svc):\n    return svc.stats()\n")
        proc = subprocess.run(
            [sys.executable, "-m", "repro.analysis", str(bad)],
            capture_output=True, text=True,
            env={"PYTHONPATH": str(SRC), "PATH": "/usr/bin"})
        assert proc.returncode == 1
        assert "RI006" in proc.stdout
        assert f"{bad}:2:" in proc.stdout


# ----------------------------------------------------------- sanitizer layer
@pytest.fixture
def sanitize_on():
    prev = sanitizer.set_enabled(True)
    try:
        yield
    finally:
        sanitizer.set_enabled(prev)


class TestSanitizerFreeze:
    def test_segment_table_arrays_are_frozen(self):
        from repro.index.table import SegmentTable
        t = SegmentTable.from_keys(np.linspace(0, 1000, 512), error=16)
        for name in ("start_key", "slope", "base", "seg_end", "keys"):
            arr = getattr(t, name)
            assert not arr.flags.writeable, name
            with pytest.raises(ValueError):
                arr[0] = -1

    def test_freeze_copies_scratch_views(self):
        scratch = np.arange(8, dtype=np.float64)
        frozen = sanitizer.freeze(scratch[2:5])
        assert not frozen.flags.writeable
        scratch[:] = -1.0                  # caller's buffer stays writable
        assert frozen[0] == 2.0            # ...and the published copy immune

    def test_mutating_a_served_table_raises(self):
        from repro.index import ShardedIndexService
        keys = np.sort(np.random.default_rng(3).uniform(0, 1e6, 4000))
        svc = ShardedIndexService(keys, error=32, n_shards=2, buffer_size=4,
                                  assume_sorted=True)
        snap = svc.handles[0].current()
        with pytest.raises(ValueError):
            snap.table.keys[0] = -1.0
        with pytest.raises(ValueError):
            svc.shard_set.boundaries[0] = 0.0

    def test_published_payload_is_frozen(self):
        from repro.index import ShardedIndexService
        keys = np.linspace(0, 100, 256)
        svc = ShardedIndexService(keys, error=8, n_shards=1, buffer_size=4,
                                  payload=np.arange(256), assume_sorted=True)
        payload = svc.handles[0].current().payload
        with pytest.raises(ValueError):
            payload[0] = 7

    def test_packed_shard_tables_are_frozen(self):
        from repro.index import pack_shard_tables
        from repro.index.table import SegmentTable
        packed = pack_shard_tables(
            [SegmentTable.from_keys(np.linspace(i, i + 50, 64), error=8)
             for i in (0, 100)])
        for arr in packed[:5]:
            with pytest.raises(ValueError):
                arr.flat[0] = -1


class TestPinTracker:
    def test_verbs_pass_under_tracking(self, sanitize_on):
        from repro.index import ShardedIndexService
        keys = np.sort(np.random.default_rng(5).uniform(0, 1e5, 2000))
        svc = ShardedIndexService(keys, error=16, n_shards=4, buffer_size=8,
                                  assume_sorted=True)
        q = keys[:64]
        assert (svc.lookup(q) >= 0).all()
        svc.search(q)
        svc.point(q)
        svc.count(q[:4], q[4:8])
        svc.range(float(keys[10]), float(keys[90]))
        svc.predecessor(q)
        svc.successor(q)

    def test_torn_read_across_rebalance_raises(self, sanitize_on):
        from repro.index import ShardedIndexService
        keys = np.sort(np.random.default_rng(6).uniform(0, 1e5, 2000))
        svc = ShardedIndexService(keys, error=16, n_shards=4, buffer_size=8,
                                  assume_sorted=True)
        with pytest.raises(sanitizer.PinViolation, match="torn|versions"):
            with sanitizer.pin_scope("torn-verb"):
                svc._pin_shard_set()
                svc.rebalance(force=True)   # version bump mid-operation
                svc._pin_shard_set()        # second deref sees the new set

    def test_observe_outside_scope_is_noop(self, sanitize_on):
        sanitizer.observe_pin(1)
        sanitizer.observe_pin(2)   # no open scope: nothing to violate


class TestLockWatchdog:
    def test_declared_order_inversion_raises(self, sanitize_on):
        inner = sanitizer.make_lock("ShardedIndexService._counts_lock")
        outer = sanitizer.make_rlock("ShardedIndexService._write_lock")
        with inner:
            with pytest.raises(sanitizer.LockOrderError,
                               match="declared order"):
                outer.acquire()

    def test_runtime_cycle_detected_without_declared_ranks(self, sanitize_on):
        a = sanitizer.make_lock("TestOnlyA._lock")
        b = sanitizer.make_lock("TestOnlyB._lock")
        with a:
            with b:            # records A -> B
                pass
        with b:
            with pytest.raises(sanitizer.LockOrderError, match="cycle"):
                a.acquire()    # B -> A closes the loop
        assert ("TestOnlyA._lock", "TestOnlyB._lock") in \
            sanitizer.lock_graph_edges()

    def test_consistent_order_passes_and_is_reentrant(self, sanitize_on):
        outer = sanitizer.make_rlock("ShardedIndexService._write_lock")
        inner = sanitizer.make_lock("ShardedIndexService._counts_lock")
        with outer:
            with outer:        # re-entrant acquire skips the order check
                with inner:
                    pass

    def test_serving_stack_flows_clean_under_watchdog(self, sanitize_on):
        from repro.index import ShardedIndexService
        from repro.index.telemetry import Monitor
        keys = np.sort(np.random.default_rng(7).uniform(0, 1e5, 3000))
        svc = ShardedIndexService(keys, error=16, n_shards=2, buffer_size=8,
                                  auto_rebalance=True, monitor=Monitor(),
                                  assume_sorted=True)
        for k in np.random.default_rng(8).uniform(0, 1e5, 64):
            svc.insert(float(k))
        svc.publish()
        svc.rebalance(force=True)
        svc.lookup(keys[:128])
        svc.metrics()

    def test_disabled_returns_plain_locks(self):
        prev = sanitizer.set_enabled(False)
        try:
            lock = sanitizer.make_lock("whatever._lock")
            assert not isinstance(lock, sanitizer._SanitizedLock)
        finally:
            sanitizer.set_enabled(prev)


class TestHotPathMarker:
    def test_decorator_is_a_runtime_noop(self):
        @hot_path
        def f(x):
            return x + 1
        assert f(1) == 2 and f.__hot_path__
