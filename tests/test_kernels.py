"""Pallas fitting_lookup kernel vs the pure-jnp oracle (interpret=True on CPU).

Sweeps shapes / errors / distributions / duplicates / overflow, per the brief.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import build_device_index
from repro.kernels.ops import fitting_lookup, make_plan
from repro.kernels.ref import lookup_ref


def _keys(n, seed=0, dist="uniform"):
    rng = np.random.default_rng(seed)
    if dist == "uniform":
        ks = np.sort(rng.choice(2 ** 23, size=n, replace=False))
    elif dist == "clustered":
        centers = rng.choice(2 ** 22, size=max(4, n // 200), replace=False)
        ks = np.sort((centers[rng.integers(0, len(centers), n)]
                      + rng.integers(0, 2 ** 10, n)))
    elif dist == "dups":
        ks = np.sort(rng.choice(2 ** 12, size=n, replace=True))
    return ks.astype(np.float64)


def _check(keys, error, queries, qcap=256):
    idx = build_device_index(keys, error)
    q = jnp.asarray(queries, jnp.float32)
    got = np.asarray(fitting_lookup(idx, q, qcap=qcap, interpret=True))
    want = np.asarray(lookup_ref(idx.keys, q))
    found = want >= 0
    # ranks of found queries must locate an equal key (with duplicates any
    # occurrence is a correct answer; lookup_ref returns the leftmost)
    ks32 = keys.astype(np.float32)
    assert np.array_equal(got >= 0, found), "presence mismatch"
    if found.any():
        np.testing.assert_array_equal(ks32[got[found]], np.asarray(q)[found])


@pytest.mark.parametrize("n", [100, 1000, 20_000])
@pytest.mark.parametrize("error", [4, 16, 64, 250])
def test_sweep_sizes_errors(n, error):
    keys = _keys(n, seed=n + error)
    rng = np.random.default_rng(1)
    q = np.concatenate([keys[rng.integers(0, n, size=128)],
                        keys[rng.integers(0, n, size=64)] + 0.5])
    _check(keys, error, q)


@pytest.mark.parametrize("dist", ["uniform", "clustered", "dups"])
def test_sweep_distributions(dist):
    keys = _keys(5000, seed=7, dist=dist)
    rng = np.random.default_rng(2)
    q = np.concatenate([keys[rng.integers(0, keys.shape[0], size=200)],
                        rng.uniform(0, 2 ** 23, size=100)])
    _check(keys, 32, q)


def test_bucket_overflow_fallback():
    """All queries in one block at qcap=128 -> overflow path must still answer."""
    keys = _keys(10_000, seed=3)
    q = np.repeat(keys[500], 300)  # 300 identical queries, one block
    _check(keys, 16, q, qcap=128)


def test_query_batch_edge_sizes():
    keys = _keys(2000, seed=4)
    for nq in (1, 2, 127, 128, 129):
        q = keys[np.arange(nq) % keys.shape[0]]
        _check(keys, 8, q)


def test_plan_geometry():
    p = make_plan(n_keys=1000, error=4)
    assert p.kb == 128 and p.window == 10 and p.n_pad % p.kb == 0
    p = make_plan(n_keys=10 ** 6, error=250)
    assert p.kb == 512 and p.kb >= p.window


def test_matches_ref_exactly_on_ranks_without_dups():
    keys = _keys(8000, seed=5)
    idx = build_device_index(keys, 64)
    rng = np.random.default_rng(6)
    q = jnp.asarray(keys[rng.integers(0, 8000, 400)], jnp.float32)
    got = np.asarray(fitting_lookup(idx, q, interpret=True))
    want = np.asarray(lookup_ref(idx.keys, q))
    np.testing.assert_array_equal(got, want)


@given(seed=st.integers(0, 25), error=st.sampled_from([4, 30, 120]),
       n=st.sampled_from([64, 500, 3000]))
@settings(max_examples=15, deadline=None)
def test_property_kernel_equals_oracle(seed, error, n):
    keys = _keys(n, seed=seed)
    rng = np.random.default_rng(seed + 1)
    q = np.concatenate([keys[rng.integers(0, n, size=64)],
                        rng.uniform(0, 2 ** 23, size=32)])
    idx = build_device_index(keys, error)
    got = np.asarray(fitting_lookup(idx, jnp.asarray(q, jnp.float32),
                                    interpret=True))
    want = np.asarray(lookup_ref(idx.keys, jnp.asarray(q, jnp.float32)))
    np.testing.assert_array_equal(got, want)
