"""Async coalescing front door (``repro.index.pipeline``): concurrent
callers get bit-identical answers to the single-thread oracle, flushes fire
on threshold *and* deadline, a full queue backpressures, the maintenance
cadence publishes off the request path, shutdown drains in-flight futures,
and a maintenance crash is surfaced -- plus the satellite fixes: the locked
query counters under hammer and ``DispatchEngine.prewarm``.

Timing-sensitive assertions use generous margins (seconds, not the
microsecond knobs under test) so CI runners never flake on scheduling jitter.
"""
import threading
import time

import numpy as np
import pytest

import repro.index as ri
from repro.index.pipeline import _bucket_size
from repro.serve import (AsyncIndexService, FitSpec, IndexService,
                         PipelineClosed, PipelineOverloaded,
                         ShardedIndexService, open_pipeline)


def _keys(n=512, seed=0):
    rng = np.random.default_rng(seed)
    return np.sort(rng.choice(n * 8, size=n, replace=False)).astype(np.float64)


# ------------------------------------------------- concurrency == the oracle
@pytest.mark.parametrize("backend", ri.available_backends())
def test_concurrent_callers_match_single_thread_oracle(backend):
    """N threads of mixed lookup/search traffic through the coalescing queue
    == the same calls made single-threaded on the bare service, bit for bit,
    on every backend."""
    keys = _keys()
    svc = IndexService(keys, error=16, backend=backend, assume_sorted=True)
    n_threads, per_thread = 6, 12
    barrier = threading.Barrier(n_threads)
    failures: list = []

    # small queue_depth bounds the padded bucket set (pallas compiles a
    # kernel per shape, and interpret mode on CPU is slow per compile)
    with AsyncIndexService(svc, flush_threshold=16, max_wait_us=2_000.0,
                           queue_depth=32, prewarm=False) as pipe:
        def caller(tid):
            rng = np.random.default_rng(100 + tid)
            try:
                barrier.wait(30)
                for _ in range(per_thread):
                    size = int(rng.integers(1, 6))
                    hits = keys[rng.integers(0, keys.size, size)]
                    misses = rng.uniform(keys[0], keys[-1], size)
                    q = np.where(rng.random(size) < 0.7, hits, misses)
                    verb = rng.integers(0, 3)
                    if verb == 0:
                        got, want = pipe.lookup(q, 60.0), svc.lookup(q)
                    else:
                        side = "left" if verb == 1 else "right"
                        got = pipe.search(q, side, 60.0)
                        want = svc.search(q, side)
                    if not np.array_equal(got, want):
                        failures.append((tid, q, got, want))
            except BaseException as exc:  # pragma: no cover - surfaced below
                failures.append((tid, exc))

        threads = [threading.Thread(target=caller, args=(t,))
                   for t in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(120)
        stats = pipe.pipeline_stats()
    assert not failures, failures[:3]
    assert stats["coalesced_queries"] > 0          # traffic actually coalesced
    assert stats["flushes"] >= 1


# -------------------------------------------------------------- flush paths
def test_deadline_flush_fires_with_partial_batch():
    svc = IndexService(_keys(), error=16, assume_sorted=True)
    with AsyncIndexService(svc, flush_threshold=10_000,
                           max_wait_us=50_000.0, prewarm=False) as pipe:
        q = _keys()[:3]
        t0 = time.perf_counter()
        got = pipe.lookup(q, timeout=30.0)          # can never hit threshold
        elapsed = time.perf_counter() - t0
        stats = pipe.pipeline_stats()
    np.testing.assert_array_equal(got, svc.lookup(q))
    assert stats["deadline_flushes"] >= 1
    assert stats["threshold_flushes"] == 0
    assert elapsed < 20.0                           # generous CI margin


def test_threshold_flush_and_inline_bypass():
    keys = _keys()
    svc = IndexService(keys, error=16, assume_sorted=True)
    with AsyncIndexService(svc, flush_threshold=8, max_wait_us=1e6,
                           prewarm=False) as pipe:
        # an over-threshold submission runs fused inline (already fast-tier)
        fut = pipe.lookup_async(keys[:32])
        assert fut.done()
        np.testing.assert_array_equal(fut.result(), svc.lookup(keys[:32]))
        # eight 1-query submissions trip the threshold without any deadline
        futs = [pipe.lookup_async(keys[i:i + 1]) for i in range(8)]
        for i, f in enumerate(futs):
            np.testing.assert_array_equal(f.result(30.0),
                                          svc.lookup(keys[i:i + 1]))
        stats = pipe.pipeline_stats()
    assert stats["inline_batches"] == 1
    assert stats["threshold_flushes"] >= 1


def test_shapes_and_empty_batches_preserved():
    keys = _keys()
    svc = IndexService(keys, error=16, assume_sorted=True)
    with AsyncIndexService(svc, flush_threshold=64, max_wait_us=500.0,
                           prewarm=False) as pipe:
        q2d = keys[:6].reshape(2, 3)
        got = pipe.lookup(q2d, timeout=30.0)
        assert got.shape == (2, 3)
        np.testing.assert_array_equal(got.ravel(), svc.lookup(keys[:6]))
        empty = pipe.lookup(np.empty(0), timeout=30.0)
        assert empty.shape == (0,) and empty.dtype == np.int64
        scalar = pipe.lookup(float(keys[5]), timeout=30.0)
        assert scalar.shape == () and scalar == 5


# ------------------------------------------------------------- backpressure
def test_full_queue_backpressures_then_drains_on_close():
    keys = _keys()
    svc = IndexService(keys, error=16, assume_sorted=True)
    # threshold never reached, deadline far away: the queue can only fill
    pipe = AsyncIndexService(svc, flush_threshold=128, queue_depth=128,
                             max_wait_us=10_000_000.0, prewarm=False)
    try:
        futs = [pipe.lookup_async(keys[4 * i:4 * i + 4]) for i in range(25)]
        with pytest.raises(PipelineOverloaded):
            pipe.lookup_async(keys[:32], timeout=0.2)   # 100 + 32 > 128
    finally:
        pipe.close()
    # close() drained the parked requests instead of abandoning them
    for i, f in enumerate(futs):
        np.testing.assert_array_equal(f.result(0),
                                      svc.lookup(keys[4 * i:4 * i + 4]))
    assert pipe.pipeline_stats()["drain_flushes"] >= 1


def test_close_drains_and_rejects_new_work():
    keys = _keys()
    svc = IndexService(keys, error=16, assume_sorted=True)
    pipe = AsyncIndexService(svc, flush_threshold=10_000,
                             max_wait_us=5_000_000.0, prewarm=False)
    futs = [pipe.lookup_async(keys[i:i + 2]) for i in range(6)]
    pipe.close()
    for i, f in enumerate(futs):
        np.testing.assert_array_equal(f.result(0), svc.lookup(keys[i:i + 2]))
    assert pipe.closed
    with pytest.raises(PipelineClosed):
        pipe.lookup_async(keys[:1])
    pipe.close()                                    # idempotent


def test_knob_validation():
    svc = IndexService(_keys(), error=16, assume_sorted=True)
    with pytest.raises(ValueError):
        AsyncIndexService(svc, flush_threshold=0, prewarm=False)
    with pytest.raises(ValueError):
        AsyncIndexService(svc, max_wait_us=0.0, prewarm=False)
    with pytest.raises(ValueError):
        AsyncIndexService(svc, flush_threshold=64, queue_depth=32,
                          prewarm=False)


# -------------------------------------------------------- maintenance cadence
@pytest.mark.slow
def test_cadence_publishes_dirty_shards_without_blocking_readers():
    keys = _keys(1024)
    svc = ShardedIndexService(keys, error=64, n_shards=2, buffer_size=16,
                              assume_sorted=True)
    new_key = float(keys[0]) + 0.5                  # lands in shard 0
    stop = threading.Event()
    reader_errors: list = []

    with AsyncIndexService(svc, flush_threshold=64, max_wait_us=500.0,
                           publish_interval_s=0.05, prewarm=False) as pipe:
        def reader():
            while not stop.is_set():
                if pipe.lookup(keys[:4], timeout=30.0)[0] != 0:
                    reader_errors.append("wrong rank")

        t = threading.Thread(target=reader)
        t.start()
        try:
            svc.insert(new_key)                     # dirty, not yet visible
            deadline = time.monotonic() + 20.0      # cadence is 0.05s
            # wait on the publish *counter*: the snapshot installs mid-
            # publish, before the maintenance thread's stats update lands
            stats = pipe.pipeline_stats()
            while time.monotonic() < deadline and stats["publishes"] < 1:
                time.sleep(0.01)
                stats = pipe.pipeline_stats()
            visible = pipe.lookup(np.array([new_key]), 30.0)[0] != -1
        finally:
            stop.set()
            t.join(30)
    assert visible, "maintenance cadence never published the dirty shard"
    assert not reader_errors
    assert stats["publishes"] >= 1
    assert stats["maintenance_ticks"] >= 1
    assert svc.pending_inserts == 0


def test_maintenance_crash_is_surfaced_to_callers(monkeypatch):
    svc = IndexService(_keys(), error=16, assume_sorted=True)

    def boom():
        raise RuntimeError("publish exploded")

    monkeypatch.setattr(svc, "publish", boom)
    pipe = AsyncIndexService(svc, publish_interval_s=0.02, prewarm=False)
    deadline = time.monotonic() + 20.0
    while time.monotonic() < deadline and not pipe.closed:
        time.sleep(0.01)
    assert pipe.closed
    with pytest.raises(PipelineClosed) as exc:
        pipe.lookup_async(np.array([1.0]))
    assert isinstance(exc.value.__cause__, RuntimeError)
    with pytest.raises(PipelineClosed):
        pipe.close()


# --------------------------------------------------------------- satellites
def test_query_counters_exact_under_thread_hammer():
    """The unlocked ``_query_counts`` increments lost updates under the async
    front door; the locked ``_count`` path must be exact."""
    keys = _keys(1024)
    svc = ShardedIndexService(keys, error=16, n_shards=2, assume_sorted=True)
    base = svc.service_stats()["query_counts"]
    n_threads, iters = 8, 100
    barrier = threading.Barrier(n_threads)

    def hammer():
        barrier.wait(30)
        for _ in range(iters):
            svc.lookup(keys[:3])
            svc.search(keys[:2])

    threads = [threading.Thread(target=hammer) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(120)
    counts = svc.service_stats()["query_counts"]
    assert counts["points"] - base["points"] == n_threads * iters * 3
    assert counts["searches"] - base["searches"] == n_threads * iters * 2


def test_dispatch_prewarm_builds_every_tier():
    keys = _keys()
    table = ri.SegmentTable.from_keys(keys, 16, assume_sorted=True)
    eng = ri.make_engine(table, "dispatch")
    assert not eng._engines                         # lazy until prewarmed
    eng.prewarm()
    built = set(eng._engines)
    assert len(built) >= 2                          # small + large at least
    # the warmed instances are the very ones dispatch routes to afterwards
    for size in (1, 10_000):
        assert eng.engine_for(size) in eng._engines.values()
    q = keys[:8]
    np.testing.assert_array_equal(eng.lookup(q),
                                  np.searchsorted(keys, q, side="left"))


def test_open_pipeline_takes_knobs_from_the_plan():
    keys = _keys(2048)
    spec = FitSpec(error=32)
    plan = ri.plan(keys, spec)
    assert plan.flush_threshold is not None and plan.max_wait_us is not None
    with open_pipeline(keys, spec, prewarm=False) as pipe:
        assert pipe.flush_threshold == plan.flush_threshold
        assert pipe.max_wait_us == plan.max_wait_us
        assert pipe.queue_depth == plan.queue_depth
        got = pipe.lookup(keys[:5], timeout=30.0)
        np.testing.assert_array_equal(got, np.arange(5))
        # explain() audits the pipeline knobs alongside the index knobs
        assert "async pipeline" in plan.explain()


def test_bucket_padding_is_pow2_and_bounded():
    assert _bucket_size(1) == 16
    assert _bucket_size(16) == 16
    assert _bucket_size(17) == 32
    assert _bucket_size(1000) == 1024


def test_service_stats_carries_pipeline_section():
    svc = IndexService(_keys(), error=16, assume_sorted=True)
    with AsyncIndexService(svc, flush_threshold=8, max_wait_us=500.0,
                           prewarm=False) as pipe:
        pipe.lookup(_keys()[:2], timeout=30.0)
        stats = pipe.service_stats()
    assert "pipeline" in stats and stats["pipeline"]["flushes"] >= 1
    assert stats["query_counts"]["points"] >= 2
