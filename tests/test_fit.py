"""SLO-driven construction: FitSpec validation, plan() vs a brute-force
cost-model oracle, JSON round trips, open_index routing, and planned-dispatch
lookups agreeing with the numpy oracle at every tier boundary."""
import dataclasses

import numpy as np
import pytest

from repro.core import TPUCostParams, latency_ns, size_bytes
from repro.core.datasets import lognormal_keys, uniform_keys
from repro.index import (FitSpec, IndexPlan, InfeasibleSpecError, numpy_lookup,
                         open_index, plan)
from repro.index.fit import brute_force_choice, planned_buffer
from repro.serve import IndexService, ShardedIndexService

CANDS = (8, 32, 128, 512, 2048)


def _duplicate_heavy(n=20_000, seed=5):
    rng = np.random.default_rng(seed)
    return np.sort(rng.choice(np.arange(n // 8, dtype=np.float64), size=n))


DATASETS = {
    "uniform": lambda: uniform_keys(20_000, seed=3),
    "lognormal": lambda: lognormal_keys(20_000, seed=4),
    "duplicate_heavy": _duplicate_heavy,
}


# ------------------------------------------------------------ spec validation
def test_spec_requires_exactly_one_objective():
    with pytest.raises(ValueError, match="exactly one objective"):
        FitSpec()
    with pytest.raises(ValueError, match="exactly one objective"):
        FitSpec(latency_budget_ns=500.0, error=64)
    with pytest.raises(ValueError, match="exactly one objective"):
        FitSpec(latency_budget_ns=500.0, storage_budget_bytes=1e6, error=64)


def test_spec_rejects_nonpositive_budgets_and_bad_hints():
    with pytest.raises(ValueError, match="latency_budget_ns must be > 0"):
        FitSpec(latency_budget_ns=0.0)
    with pytest.raises(ValueError, match="storage_budget_bytes must be > 0"):
        FitSpec(storage_budget_bytes=-5.0)
    with pytest.raises(ValueError, match="error must be >= 1"):
        FitSpec(error=0)
    with pytest.raises(ValueError, match="key_sample must be non-empty"):
        FitSpec(error=64, key_sample=())
    with pytest.raises(ValueError, match="insert_rate must be >= 0"):
        FitSpec(error=64, insert_rate=-1.0)
    with pytest.raises(ValueError, match="duplicate_density"):
        FitSpec(error=64, duplicate_density=1.0)
    with pytest.raises(ValueError, match="batch_sizes"):
        FitSpec(error=64, batch_sizes=(16, 0))
    with pytest.raises(ValueError, match="hardware"):
        FitSpec(error=64, hardware="gpu")
    with pytest.raises(ValueError, match="candidate_errors"):
        FitSpec(error=64, candidate_errors=())
    with pytest.raises(ValueError, match="segment_sample"):
        FitSpec(error=64, segment_sample=0)
    with pytest.raises(ValueError, match="segment_sample"):
        FitSpec(error=64, segment_sample=-5)


def test_spec_json_round_trip_equality():
    spec = FitSpec(latency_budget_ns=500.0, batch_sizes=[4, 2048],
                   insert_rate=1_000.0, duplicate_density=0.25,
                   key_sample=[1.0, 2.0, 5.5], n_keys_hint=10_000_000,
                   hardware="tpu",
                   tpu_params=TPUCostParams(hbm_gbps=1600.0),
                   candidate_errors=[16, 64, 256])
    again = FitSpec.from_json(spec.to_json())
    assert again == spec
    # list inputs normalize to tuples, so equality is structural
    assert isinstance(again.batch_sizes, tuple)
    with pytest.raises(ValueError, match="unknown FitSpec fields"):
        FitSpec.from_json('{"error": 64, "not_a_knob": 1}')
    with pytest.raises(ValueError, match="unknown FitSpec fields.*cpu_params"):
        FitSpec.from_json(
            '{"error": 64, "cpu_params": {"c_ns": 50.0, "bogus": 1}}')
    # numpy arrays are natural inputs for the workload hints; they must
    # normalize to JSON-serializable Python scalars
    np_spec = FitSpec(error=64, batch_sizes=np.array([1, 8, 64]),
                      key_sample=np.array([1.5, 2.5]),
                      candidate_errors=np.array([16, 64]))
    assert FitSpec.from_json(np_spec.to_json()) == np_spec


# ------------------------------------------------------- planner vs the oracle
@pytest.mark.parametrize("name", sorted(DATASETS))
@pytest.mark.parametrize("objective", ["latency", "space"])
def test_plan_matches_brute_force_oracle(name, objective):
    """The chooser-driven planner picks exactly the error an exhaustive
    sweep of the same cost model picks, on every dataset shape."""
    keys = DATASETS[name]()
    probe = plan(keys, FitSpec(error=64, candidate_errors=CANDS))
    lats = [c.latency_ns for c in probe.candidates]
    sizes = [c.size_bytes for c in probe.candidates]
    if objective == "latency":
        budgets = [(min(lats) + max(lats)) / 2, max(lats)]
        specs = [FitSpec(latency_budget_ns=b, candidate_errors=CANDS)
                 for b in budgets]
    else:
        budgets = [(min(sizes) + max(sizes)) / 2, max(sizes)]
        specs = [FitSpec(storage_budget_bytes=b, candidate_errors=CANDS)
                 for b in budgets]
    for spec in specs:
        got = plan(keys, spec)
        assert got.error == brute_force_choice(keys, spec)
        chosen = [c for c in got.candidates if c.chosen]
        assert len(chosen) == 1 and chosen[0].error == got.error
        assert chosen[0].feasible


def test_plan_candidates_audit_the_model():
    """Every candidate row reproduces the Sec. 6 formulas for the
    configuration the planner would *build*: segmentation and windows at
    err_seg = error - planned_buffer(error), buffer-scan term at the
    planned buffer."""
    keys = uniform_keys(20_000, seed=7)
    spec = FitSpec(latency_budget_ns=900.0, candidate_errors=CANDS)
    p = plan(keys, spec)
    for c in p.candidates:
        buf = planned_buffer(c.error)
        eff = dataclasses.replace(spec.cpu_params, buffer_size=buf)
        assert c.latency_ns == pytest.approx(
            latency_ns(c.error - buf, c.n_segments, eff))
        assert c.size_bytes == pytest.approx(
            size_bytes(c.error, c.n_segments, spec.cpu_params))
        assert c.feasible == (c.latency_ns <= 900.0)
    report = p.explain()
    assert "chosen" in report and f"error={p.error}" in report
    assert str(p.small_max) in report and str(p.large_min) in report


def test_built_service_satisfies_the_budget_under_its_own_model():
    """Regression: the plan is scored on the effective (err_seg, buffer)
    configuration, so the *actually built* snapshot -- which serves at
    err_seg with the planned buffer -- still fits the budget when the same
    Sec. 6 model is evaluated on its real segment count."""
    keys = uniform_keys(20_000, seed=18)
    budget = 700.0
    spec = FitSpec(latency_budget_ns=budget)
    p = plan(keys, spec)
    svc = open_index(keys, p)
    table = svc.handle.current().table
    assert table.error == p.error - p.buffer_size      # served at err_seg
    eff = dataclasses.replace(spec.cpu_params, buffer_size=p.buffer_size)
    modeled = latency_ns(table.error, table.n_segments, eff)
    # 5% headroom for the segments-curve interpolation between candidates
    assert modeled <= budget * 1.05


def test_infeasible_budgets_raise_with_tightest_achievable():
    keys = uniform_keys(20_000, seed=8)
    with pytest.raises(InfeasibleSpecError, match="tightest achievable") \
            as exc:
        plan(keys, FitSpec(latency_budget_ns=1e-3, candidate_errors=CANDS))
    assert exc.value.objective == "latency"
    assert exc.value.tightest > exc.value.budget
    with pytest.raises(InfeasibleSpecError, match="tightest achievable") \
            as exc:
        plan(keys, FitSpec(storage_budget_bytes=1.0, candidate_errors=CANDS))
    assert exc.value.objective == "space"
    assert exc.value.tightest > 1.0


def test_plan_from_key_sample_without_keys():
    keys = uniform_keys(20_000, seed=9)
    spec = FitSpec(latency_budget_ns=800.0,
                   key_sample=tuple(keys[::20]), n_keys_hint=keys.shape[0],
                   candidate_errors=CANDS)
    p = plan(None, spec)
    assert p.error in CANDS
    assert p.n_keys == keys[::20].shape[0]
    with pytest.raises(ValueError, match="needs keys"):
        plan(None, FitSpec(error=64))


def test_tpu_hardware_profile_uses_roofline_latency():
    keys = uniform_keys(20_000, seed=10)
    cpu_p = plan(keys, FitSpec(error=64, candidate_errors=CANDS))
    tpu_p = plan(keys, FitSpec(error=64, candidate_errors=CANDS,
                               hardware="tpu"))
    cpu_lat = {c.error: c.latency_ns for c in cpu_p.candidates}
    tpu_lat = {c.error: c.latency_ns for c in tpu_p.candidates}
    assert all(tpu_lat[e] != cpu_lat[e] for e in CANDS)
    # the DMA setup floor dominates small errors on TPU
    assert tpu_lat[8] > TPUCostParams().dma_setup_ns


# ------------------------------------------------------------------ open_index
def test_open_index_sharded_iff_plan_says_so():
    keys = uniform_keys(20_000, seed=11)
    single = plan(keys, FitSpec(error=64, candidate_errors=CANDS))
    assert single.n_shards == 1
    svc = open_index(keys, single)
    assert isinstance(svc, IndexService)

    write_hot = plan(keys, FitSpec(error=64, candidate_errors=CANDS,
                                   insert_rate=200_000.0))
    assert write_hot.n_shards > 1
    svc = open_index(keys, write_hot)
    assert isinstance(svc, ShardedIndexService)
    assert svc.n_shards == write_hot.n_shards
    with pytest.raises(TypeError, match="FitSpec or IndexPlan"):
        open_index(keys, {"error": 64})


def test_open_index_end_to_end_latency_and_space():
    """Acceptance: both SLO forms work insert -> publish -> lookup with no
    raw knob supplied by the caller."""
    rng = np.random.default_rng(12)
    keys = np.sort(rng.choice(2 ** 22, size=20_000,
                              replace=False)).astype(np.float64)
    fresh = np.setdiff1d(
        rng.choice(2 ** 22, size=256, replace=False).astype(np.float64),
        keys)[:64]
    for spec in (FitSpec(latency_budget_ns=700.0),
                 FitSpec(storage_budget_bytes=1e6),
                 FitSpec(latency_budget_ns=700.0, insert_rate=150_000.0)):
        svc = open_index(keys, spec)
        assert np.array_equal(svc.lookup(keys[::97]),
                              np.searchsorted(keys, keys[::97]))
        for k in fresh:
            svc.insert(float(k))
        svc.publish()
        union = np.sort(np.concatenate([keys, fresh]))
        got = svc.lookup(fresh)
        assert np.array_equal(got, np.searchsorted(union, fresh))


def test_open_index_sorts_unsorted_keys_and_payload_once():
    """open_index accepts unsorted keys (sorting exactly once, payload
    permuted alongside) and the built service serves correct ranks/values."""
    rng = np.random.default_rng(19)
    keys = rng.permutation(uniform_keys(5_000, seed=19))
    payload = keys * 2.0
    svc = open_index(keys, FitSpec(error=64, candidate_errors=CANDS),
                     payload=payload)
    srt = np.sort(keys)
    probe = srt[::173]
    ranks = svc.lookup(probe)
    assert np.array_equal(ranks, np.searchsorted(srt, probe))
    snap = svc.handle.current()
    assert np.array_equal(snap.table.keys, srt)


def test_raw_knob_constructors_carry_a_trivial_plan():
    keys = uniform_keys(5_000, seed=13)
    svc = IndexService(keys, error=64, buffer_size=8)
    assert svc.plan.objective == "raw" and svc.plan.error == 64
    sharded = ShardedIndexService(keys, 32, n_shards=3, buffer_size=4,
                                  backend="dispatch")
    assert sharded.plan.n_shards == 3 and sharded.plan.backend == "dispatch"
    with pytest.raises(TypeError, match="error=.*or plan="):
        ShardedIndexService(keys)


def test_raw_knobs_alongside_a_plan_are_rejected_loudly():
    """A plan fixes error/n_shards/buffer/backend/cadence; passing any of
    them beside plan= must fail, not be silently overwritten."""
    keys = uniform_keys(5_000, seed=13)
    p = IndexPlan.from_knobs(16, n_shards=2, buffer_size=4)
    with pytest.raises(TypeError, match="not both.*error"):
        ShardedIndexService(keys, 32, plan=p)
    with pytest.raises(TypeError, match="not both.*buffer_size, n_shards"):
        ShardedIndexService(keys, plan=p, n_shards=7, buffer_size=999)
    with pytest.raises(TypeError, match="not both.*backend"):
        IndexService(keys, plan=p, backend="numpy")


def test_open_index_policy_kwargs_reach_both_service_shapes():
    """The documented pass-through kwargs must work whether the planner
    resolves to one shard (IndexService) or many (sharded)."""
    keys = uniform_keys(5_000, seed=17)
    one = open_index(keys, FitSpec(error=64, candidate_errors=CANDS),
                     skew_threshold=3.0, auto_rebalance=True,
                     assume_sorted=True)
    assert isinstance(one, IndexService)
    many = open_index(keys, FitSpec(error=64, candidate_errors=CANDS,
                                    insert_rate=200_000.0),
                      skew_threshold=3.0, auto_rebalance=True,
                      assume_sorted=True)
    assert isinstance(many, ShardedIndexService)
    assert many.skew_threshold == 3.0 and many.auto_rebalance
    for svc in (one, many):
        assert np.array_equal(svc.lookup(keys[:16]), np.arange(16))


def test_index_service_forces_plan_to_one_shard():
    keys = uniform_keys(5_000, seed=14)
    multi = dataclasses.replace(plan(keys, FitSpec(error=64)), n_shards=4)
    svc = IndexService.from_plan(keys, multi)
    assert svc.plan.n_shards == 1
    assert np.array_equal(svc.lookup(keys[:32]), np.arange(32))


# ------------------------------------------- planned dispatch at the breakpoints
def test_planned_dispatch_matches_oracle_at_tier_boundaries():
    """Acceptance: with cost-model-planned thresholds, lookups agree with the
    numpy oracle at every tier boundary +-1, and every registered backend
    serves the same ranks through the planned service."""
    rng = np.random.default_rng(15)
    keys = np.sort(rng.choice(2 ** 22, size=3_000,
                              replace=False)).astype(np.float64)
    # a hardware profile with small launch/plan overheads keeps the planned
    # crossings tiny, so the pallas tier is exercised cheaply in interpret mode
    spec = FitSpec(error=16, candidate_errors=CANDS,
                   tpu_params=TPUCostParams(launch_ns=1200.0, plan_ns=300.0))
    p = plan(keys, spec)
    assert p.backend == "dispatch"
    assert 0 < p.small_max < p.large_min < 256
    svc = open_index(keys, p)
    eng = svc.handle.engine("dispatch")
    assert (eng.small_max, eng.large_min) == (p.small_max, p.large_min)

    table = svc.handle.current().table
    # absent probes at half-integers: exactly representable in f32, so the
    # f64 host tier and the f32 device tiers agree on membership
    absent = np.floor(rng.uniform(0, 2 ** 22, size=128)) + 0.5
    pool = np.concatenate([keys[rng.integers(0, keys.shape[0], 128)], absent])
    for size in sorted({1, p.small_max - 1, p.small_max, p.small_max + 1,
                        p.large_min - 1, p.large_min, p.large_min + 1}):
        if size < 1:
            continue
        q = pool[rng.integers(0, pool.shape[0], size)]
        want = numpy_lookup(table, q)
        assert eng.engine_for(size).backend == eng.backend_for(size)
        np.testing.assert_array_equal(
            svc.lookup(q), want,
            err_msg=f"batch {size} -> {eng.backend_for(size)}")
    q = pool[rng.integers(0, pool.shape[0], 64)]
    want = numpy_lookup(table, q)
    for backend in ("numpy", "xla-window", "xla-bisect", "pallas",
                    "dispatch"):
        np.testing.assert_array_equal(svc.lookup(q, backend), want,
                                      err_msg=backend)


def test_batch_size_hints_pick_the_tier_backend():
    keys = uniform_keys(20_000, seed=16)
    base = dict(latency_budget_ns=900.0, candidate_errors=CANDS)
    p = plan(keys, FitSpec(**base))
    assert p.backend == "dispatch"          # no hint -> mixed-size router
    tiny = plan(keys, FitSpec(**base, batch_sizes=(1, 2, 4)))
    assert tiny.backend == "numpy"
    huge = plan(keys, FitSpec(**base,
                              batch_sizes=(p.large_min, 4 * p.large_min)))
    assert huge.backend == "pallas"
    mid = plan(keys, FitSpec(**base, batch_sizes=(p.small_max + 1,
                                                  p.large_min - 1)))
    assert mid.backend == "xla-bisect"
