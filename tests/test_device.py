"""Device-sharded serving plane (repro.index.device).

The collective path (8 forced host devices, both exchange strategies, delta
publish buffer identity, publish/reader races) runs in a subprocess so the
forced device count never leaks into other tests; the planner integration,
validation surface, and telemetry node run in-process on a single device.
"""
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.index.device import DeviceShardedService
from repro.index.fit import FitSpec, IndexPlan, open_index, plan
from repro.index.telemetry import DeviceMetrics, ServiceMetrics


@pytest.mark.slow
def test_device_plane_8dev():
    script = pathlib.Path(__file__).parent / "_device_check.py"
    env = {"PYTHONPATH": str(pathlib.Path(__file__).parents[1] / "src"),
           "PATH": "/usr/bin:/bin", "REPRO_SANITIZE": "1"}
    res = subprocess.run([sys.executable, str(script)], capture_output=True,
                         text=True, timeout=600, env=env)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    assert "ALL_OK" in res.stdout


@pytest.fixture(scope="module")
def keys():
    rng = np.random.default_rng(3)
    return np.sort(rng.choice(rng.integers(0, 1 << 20, 400), 8_000)
                   .astype(np.float64))


def test_single_device_verbs_match_oracle(keys):
    svc = DeviceShardedService(keys, error=32, device_count=1,
                               buffer_size=8, assume_sorted=True)
    k32 = keys.astype(np.float32)
    q = np.concatenate([keys[::13], keys[::13] + 0.5])
    q32 = q.astype(np.float32)
    left = np.searchsorted(k32, q32, "left")
    right = np.searchsorted(k32, q32, "right")
    np.testing.assert_array_equal(svc.search(q, side="left"), left)
    np.testing.assert_array_equal(svc.search(q, side="right"), right)
    np.testing.assert_array_equal(svc.lookup(q),
                                  np.where(right > left, left, -1))
    rr = svc.range(float(keys[10]), float(keys[-10]))
    lo = int(np.searchsorted(k32, k32[10], "left"))
    hi = int(np.searchsorted(k32, k32[-10], "right"))
    assert (rr.lo_rank, rr.hi_rank) == (lo, hi)
    np.testing.assert_array_equal(rr.keys, keys[lo:hi])


def test_insert_publish_serves_delta(keys):
    svc = DeviceShardedService(keys, error=32, device_count=1,
                               buffer_size=8, assume_sorted=True)
    v0 = svc.device_set.version
    new_key = float(keys[len(keys) // 2]) + 0.25
    svc.insert(new_key)
    # buffered: invisible on device until publish
    assert svc.lookup(np.asarray([new_key]))[0] == -1
    svc.publish()
    assert svc.device_set.version == v0 + 1
    merged = np.sort(np.append(keys, new_key)).astype(np.float32)
    exp = np.searchsorted(merged, np.float32(new_key), "left")
    assert int(svc.search(np.asarray([new_key]))[0]) == int(exp)
    dm = svc.metrics().device
    assert dm.delta_publishes == 1 and dm.full_publishes == 1  # build + delta
    # with one device the dirty row IS the layout (delta == full); the
    # strict < case is asserted in _device_check.py under 8 devices
    assert dm.bytes_uploaded <= dm.bytes_full_equivalent


def test_plan_emits_device_backend(keys):
    spec = FitSpec(error=64, device_count=4, batch_sizes=(256, 1 << 16),
                   insert_rate=100.0)
    p = plan(keys, spec)
    assert p.backend == "device"
    assert p.device_count == 4 and p.n_shards == 4
    assert p.exchange in ("allgather", "a2a")
    text = p.explain()
    assert "device plane" in text and f"exchange={p.exchange}" in text


def test_plan_exchange_crossover_scales_with_batch(keys):
    # tiny batches -> allgather; huge batches push a2a's amortized win
    small = plan(keys, FitSpec(error=64, device_count=8, batch_sizes=(8,)))
    big = plan(keys, FitSpec(error=64, device_count=8,
                             batch_sizes=(1 << 20,)))
    assert small.exchange == "allgather"
    assert big.exchange == "a2a"


def test_open_index_routes_device_backend(keys):
    svc = open_index(keys, FitSpec(error=64, device_count=1))
    assert isinstance(svc, DeviceShardedService)
    assert svc.plan.backend == "device"
    q = keys[::31]
    np.testing.assert_array_equal(
        svc.search(q), np.searchsorted(keys.astype(np.float32),
                                       q.astype(np.float32), "left"))


def test_device_count_clamped_by_duplicates():
    # 3 distinct runs cannot fan out over 8 devices
    keys = np.repeat([1.0, 2.0, 3.0], 100)
    p = plan(keys, FitSpec(error=16, device_count=8, duplicate_density=0.99))
    assert p.device_count <= 3 and p.n_shards == p.device_count


def test_spec_and_plan_validation(keys):
    with pytest.raises(ValueError, match="write_heavy"):
        FitSpec(error=16, device_count=4, write_heavy=True)
    with pytest.raises(ValueError, match="device_count must be >= 1"):
        FitSpec(error=16, device_count=0)
    with pytest.raises(ValueError, match="lsm"):
        plan(keys, FitSpec(error=1, device_count=2, insert_rate=1000.0))
    with pytest.raises(ValueError, match="exchange"):
        IndexPlan.from_knobs(error=16).replace(exchange="bogus")
    with pytest.raises(ValueError, match="backend='device'"):
        DeviceShardedService(keys, plan=IndexPlan.from_knobs(error=16))
    with pytest.raises(TypeError, match="not both"):
        DeviceShardedService(
            keys, error=16,
            plan=plan(keys, FitSpec(error=16, device_count=1)))
    with pytest.raises(ValueError, match="exceeds"):
        DeviceShardedService(keys, error=16, device_count=10_000)


def test_metrics_device_node_round_trips(keys):
    svc = DeviceShardedService(keys, error=32, device_count=1,
                               assume_sorted=True)
    svc.search(keys[:64])
    m = svc.metrics()
    assert m.service == "device"
    assert isinstance(m.device, DeviceMetrics)
    assert m.device.n_devices == 1
    assert m.device.exchange == "allgather"
    assert m.device.allgather_calls >= 1
    assert ServiceMetrics.from_json(m.to_json()) == m
    with pytest.warns(DeprecationWarning):
        svc.stats()


def test_apply_plan_pins_device_count(keys):
    svc = DeviceShardedService(keys, error=32, device_count=1,
                               buffer_size=8, assume_sorted=True)
    v0 = svc.device_set.version
    new_plan = svc.plan.replace(error=64, buffer_size=16)
    applied = svc.apply_plan(new_plan)
    assert applied.revision == new_plan.revision
    assert svc.plan.device_count == 1 and svc.plan.backend == "device"
    assert svc.device_set.version > v0   # full re-upload
    q = keys[::17]
    np.testing.assert_array_equal(
        svc.search(q), np.searchsorted(keys.astype(np.float32),
                                       q.astype(np.float32), "left"))
