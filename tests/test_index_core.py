"""Unified index core: SegmentTable + engines + epoch-snapshot publishing.

Asserts (a) every registered engine backend agrees with the independent
``ref.lookup_ref`` oracle on shared property-based inputs, (b) the round trip
``build -> insert x k -> publish() -> pallas/xla/numpy lookup`` returns
identical ranks across backends, and (c) publishing preserves the Eq. 1 error
bound after inserts.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import FITingTree
from repro.core.jax_index import build_device_index
from repro.index import (SegmentTable, ServingHandle, SnapshotPublisher,
                         available_backends, device_index, make_engine,
                         route_keys)
from repro.kernels.ref import lookup_ref
from repro.serve import IndexService

ALL_BACKENDS = ("numpy", "xla-window", "xla-bisect", "pallas")


def _distinct_keys(n, seed=0, lim=2 ** 23):
    rng = np.random.default_rng(seed)
    return np.sort(rng.choice(lim, size=n, replace=False)).astype(np.float64)


def _oracle(keys: np.ndarray, queries: np.ndarray) -> np.ndarray:
    return np.asarray(lookup_ref(jnp.asarray(keys, jnp.float32),
                                 jnp.asarray(queries, jnp.float32)))


def test_backend_registry_complete():
    assert set(ALL_BACKENDS) <= set(available_backends())
    with pytest.raises(ValueError, match="unknown backend"):
        make_engine(SegmentTable.from_keys(np.arange(8.0), 4), "no-such")


@given(seed=st.integers(0, 40), error=st.sampled_from([4, 16, 63, 128]),
       n=st.sampled_from([64, 500, 3000]))
@settings(max_examples=15, deadline=None)
def test_property_all_backends_match_oracle(seed, error, n):
    keys = _distinct_keys(n, seed=seed)
    rng = np.random.default_rng(seed + 1)
    q = np.concatenate([keys[rng.integers(0, n, size=96)],
                        rng.uniform(0, 2 ** 23, size=32)])  # present + absent
    table = SegmentTable.from_keys(keys, error, assume_sorted=True)
    want = _oracle(keys, q)
    for backend in ALL_BACKENDS:
        got = np.asarray(make_engine(table, backend).lookup(q))
        np.testing.assert_array_equal(got, want, err_msg=backend)


def test_round_trip_insert_publish_identical_ranks():
    """Acceptance: build -> insert x k -> publish() -> every backend returns
    identical ranks, reflecting the inserts."""
    keys = _distinct_keys(4000, seed=2)
    rng = np.random.default_rng(3)
    fresh = np.setdiff1d(
        rng.choice(2 ** 23, size=2000, replace=False).astype(np.float64), keys)
    new = fresh[:600]
    tree = FITingTree(keys, error=64, buffer_size=16)
    for k in new:
        tree.insert(float(k))

    pub = SnapshotPublisher(tree)
    snap = pub.publish()
    union = np.sort(np.concatenate([keys, new]))
    np.testing.assert_array_equal(snap.table.keys, union)
    assert snap.epoch == 1 and snap.n_refit > 0

    q = np.concatenate([new[::5], keys[::97], fresh[600:700]])  # last are absent
    want = _oracle(union, q)
    results = {b: np.asarray(make_engine(snap.table, b).lookup(q))
               for b in ALL_BACKENDS}
    for b, got in results.items():
        np.testing.assert_array_equal(got, want, err_msg=b)


def test_publish_preserves_error_bound():
    """Eq. 1 must survive insert-heavy epochs (Sec. 5 budget)."""
    keys = _distinct_keys(8000, seed=5)
    tree = FITingTree(keys, error=32, buffer_size=8)
    pub = SnapshotPublisher(tree)
    rng = np.random.default_rng(6)
    for round_ in range(3):
        for k in rng.uniform(0, 2 ** 23, size=500):
            tree.insert(float(k))
        snap = pub.publish()
        assert snap.epoch == round_ + 1
        assert snap.table.max_abs_error() <= snap.table.error + 1e-6
        assert len(pub.dirty_segments()) == 0   # publish flushed everything


def test_serving_handle_atomic_swap():
    keys = _distinct_keys(2000, seed=7)
    tree = FITingTree(keys, error=64, buffer_size=16)
    pub = SnapshotPublisher(tree)
    handle = ServingHandle()
    handle.install(pub.publish())
    old = handle.current()

    new_key = float(np.setdiff1d(np.arange(2 ** 16, dtype=np.float64), keys)[0])
    tree.insert(new_key)
    assert handle.lookup(np.asarray([new_key]))[0] == -1  # not published yet

    handle.install(pub.publish())
    assert handle.epoch == 2
    assert handle.lookup(np.asarray([new_key]))[0] >= 0
    # the retired snapshot is immutable: still serves its own epoch correctly
    assert make_engine(old.table, "numpy").lookup(np.asarray([new_key]))[0] == -1


def test_index_service_epoch_visibility():
    keys = _distinct_keys(3000, seed=8)
    svc = IndexService(keys, error=64, buffer_size=16, backend="numpy")
    assert svc.epoch == 1
    new_key = float(np.setdiff1d(np.arange(2 ** 16, dtype=np.float64), keys)[0])
    svc.insert(new_key)
    assert svc.pending_inserts == 1
    assert svc.lookup(np.asarray([new_key]))[0] == -1
    svc.publish()
    assert svc.epoch == 2 and svc.pending_inserts == 0
    for backend in ALL_BACKENDS:
        assert svc.lookup(np.asarray([new_key]), backend)[0] >= 0


def test_index_service_auto_publish():
    keys = _distinct_keys(2000, seed=9)
    svc = IndexService(keys, error=64, buffer_size=32, backend="numpy",
                       publish_every=10)
    fresh = np.setdiff1d(np.arange(4000, dtype=np.float64), keys)[:10]
    for k in fresh:
        svc.insert(float(k))
    assert svc.epoch == 2                       # 10th insert cut an epoch
    assert np.all(svc.lookup(fresh) >= 0)


def test_router_single_source_of_truth():
    """Host tree routing and table routing are the same function."""
    keys = _distinct_keys(5000, seed=10)
    tree = FITingTree(keys, error=32)
    table = tree.as_table()
    q = np.random.default_rng(11).uniform(0, 2 ** 23, size=300)
    np.testing.assert_array_equal(
        table.route(q), route_keys(tree.start_keys, q))
    for k in q[:20]:
        assert tree._segment_of(float(k)) == int(table.route(k))


def test_device_index_matches_legacy_builder():
    keys = _distinct_keys(3000, seed=12)
    table = SegmentTable.from_keys(keys, 16, assume_sorted=True)
    via_table = device_index(table)
    via_legacy = build_device_index(keys, 16)
    for a, b in zip(via_table[:5], via_legacy[:5]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert via_table.error == via_legacy.error == 16


def test_snapshot_never_aliases_caller_buffer():
    """A published table must survive the caller scribbling over their keys."""
    keys = _distinct_keys(3000, seed=14)
    probe = float(keys[123])
    tree = FITingTree(keys, error=32, buffer_size=8, assume_sorted=True)
    snap = SnapshotPublisher(tree).publish()
    keys[123] = 9e9
    assert snap.table.keys[123] == probe
    assert make_engine(snap.table, "numpy").lookup(np.asarray([probe]))[0] == 123


def test_table_window_contains_every_key():
    keys = _distinct_keys(10_000, seed=13)
    table = SegmentTable.from_keys(keys, 24, assume_sorted=True)
    lo, hi = table.window(keys)
    true = np.arange(keys.shape[0])
    assert np.all((lo <= true) & (true < hi))
    assert table.max_abs_error() <= table.error + 1e-6


# ----------------------------------------------------------- dispatch engine
def test_dispatch_tier_selection_at_breakpoints():
    """backend_for is exact at both breakpoints (inclusive small_max,
    inclusive large_min)."""
    from repro.index import DispatchEngine
    table = SegmentTable.from_keys(_distinct_keys(512), 16, assume_sorted=True)
    eng = make_engine(table, "dispatch", small_max=8, large_min=64)
    assert isinstance(eng, DispatchEngine)
    assert eng.backend_for(0) == "numpy"
    assert eng.backend_for(8) == "numpy"          # == small_max: small tier
    assert eng.backend_for(9) == "xla-bisect"     # first medium size
    assert eng.backend_for(63) == "xla-bisect"    # last medium size
    assert eng.backend_for(64) == "pallas"        # == large_min: large tier
    assert eng.backend_for(10 ** 9) == "pallas"


def test_dispatch_agrees_with_numpy_oracle_at_every_breakpoint():
    """Acceptance: the dispatch path returns the numpy-oracle ranks for batch
    sizes straddling both tier boundaries (so every tier engine is exercised
    and agrees)."""
    keys = _distinct_keys(3000, seed=20)
    table = SegmentTable.from_keys(keys, 32, assume_sorted=True)
    eng = make_engine(table, "dispatch", small_max=8, large_min=32)
    oracle = make_engine(table, "numpy")
    rng = np.random.default_rng(21)
    pool = np.concatenate([keys[rng.integers(0, keys.shape[0], 64)],
                           rng.uniform(0, 2 ** 23, size=64)])
    for size in (1, 7, 8, 9, 31, 32, 64):
        q = pool[rng.integers(0, pool.shape[0], size)]
        assert eng.engine_for(size).backend == eng.backend_for(size)
        np.testing.assert_array_equal(
            np.asarray(eng.lookup(q)), oracle.lookup(q),
            err_msg=f"batch size {size} -> {eng.backend_for(size)}")


def test_dispatch_rejects_bad_config():
    table = SegmentTable.from_keys(np.arange(64.0), 8, assume_sorted=True)
    with pytest.raises(ValueError, match="small_max"):
        make_engine(table, "dispatch", small_max=100, large_min=10)
    with pytest.raises(ValueError, match="delegate to itself"):
        make_engine(table, "dispatch", small="dispatch")


# ------------------------------------------------------------ sharded service
def test_sharded_round_trip_all_backends_per_shard_epochs():
    """Acceptance: build sharded -> insert keys spanning >= 2 shards ->
    publish -> every registered backend returns the inserted keys, while an
    untouched shard's epoch number is unchanged."""
    from repro.index import ShardedIndexService
    keys = _distinct_keys(8000, seed=30)
    svc = ShardedIndexService(keys, error=64, n_shards=4, buffer_size=16,
                              assume_sorted=True)
    assert svc.epochs() == [1, 1, 1, 1]

    rng = np.random.default_rng(31)
    fresh = np.setdiff1d(
        rng.choice(2 ** 23, size=4000, replace=False).astype(np.float64), keys)
    into0 = fresh[fresh < svc.boundaries[1]][:40]       # shard 0
    into3 = fresh[fresh >= svc.boundaries[3]][:40]      # shard 3
    assert into0.size == 40 and into3.size == 40
    new = np.concatenate([into0, into3])
    for k in new:
        svc.insert(float(k))
    assert np.all(svc.lookup(new) == -1)                # not yet published

    published = svc.publish()
    assert sorted(published) == [0, 3]                  # only dirty shards
    assert svc.epochs() == [2, 1, 1, 2]                 # shards 1,2 untouched

    union = np.sort(np.concatenate([keys, new]))
    q = np.concatenate([new, keys[::113], fresh[2000:2032]])
    want = _oracle(union, q)
    for backend in (*ALL_BACKENDS, "dispatch"):
        got = svc.lookup(q, backend)
        np.testing.assert_array_equal(got, want, err_msg=backend)
        assert np.all(svc.lookup(new, backend) >= 0), backend


def test_sharded_global_ranks_survive_uneven_growth():
    """After shards grow by different amounts, the rank offsets must track
    the per-shard snapshot sizes, keeping global ranks == union searchsorted."""
    from repro.index import ShardedIndexService
    keys = _distinct_keys(6000, seed=32)
    svc = ShardedIndexService(keys, error=64, n_shards=3, buffer_size=32,
                              assume_sorted=True)
    rng = np.random.default_rng(33)
    fresh = np.setdiff1d(
        rng.choice(2 ** 23, size=6000, replace=False).astype(np.float64), keys)
    grow0 = fresh[fresh < svc.boundaries[1]][:90]       # shard 0 grows a lot
    grow2 = fresh[fresh >= svc.boundaries[2]][:10]      # shard 2 a little
    for k in np.concatenate([grow0, grow2]):
        svc.insert(float(k))
    svc.publish()
    union = np.sort(np.concatenate([keys, grow0, grow2]))
    q = np.concatenate([grow0[::7], grow2, keys[::211]])
    np.testing.assert_array_equal(svc.lookup(q), _oracle(union, q))
