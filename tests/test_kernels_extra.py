"""flash_attention + rglru_scan Pallas kernels vs pure-jnp oracles
(interpret=True), sweeping shapes/masks/dtypes per the brief."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import attention_ref
from repro.kernels.rglru_scan import rglru_scan_pallas
from repro.models.blocks import _linear_scan_impl


def _qkv(b, h, hkv, tq, s, hd, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (b, h, tq, hd), dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, hd), dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, hd), dtype)
    return q, k, v


def _ref(q, k, v, **kw):
    g = q.shape[1] // k.shape[1]
    kr = jnp.repeat(k, g, axis=1)
    vr = jnp.repeat(v, g, axis=1)
    return attention_ref(q, kr, vr, **kw)


@pytest.mark.parametrize("tq,s", [(128, 128), (256, 384), (100, 200)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_causal_shapes(tq, s, causal):
    q, k, v = _qkv(2, 4, 2, tq, s, 64)
    got = flash_attention(q, k, v, causal=causal, interpret=True)
    want = _ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_window_and_softcap():
    q, k, v = _qkv(1, 4, 4, 256, 256, 32, seed=3)
    got = flash_attention(q, k, v, causal=True, window=64, softcap=50.0,
                          interpret=True)
    want = _ref(q, k, v, causal=True, window=64, softcap=50.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_decode_one_query():
    """Tq=1 against a long KV (the decode shape): end-aligned positions."""
    q, k, v = _qkv(2, 8, 2, 1, 512, 64, seed=5)
    got = flash_attention(q, k, v, causal=True, interpret=True)
    want = _ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_bf16():
    q, k, v = _qkv(1, 2, 2, 128, 128, 64, seed=7, dtype=jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, interpret=True)
    want = _ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("b,t,w", [(2, 16, 128), (1, 100, 256), (3, 7, 384)])
def test_rglru_kernel_matches_scan(b, t, w):
    rng = np.random.default_rng(b + t)
    u = jnp.asarray(rng.normal(size=(b, t, w)), jnp.float32)
    a = jnp.asarray(rng.uniform(0.3, 0.99, size=(b, t, w)), jnp.float32)
    got, h_last = rglru_scan_pallas(u, a, interpret=True)
    want = _linear_scan_impl(u, a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(want[:, -1]),
                               rtol=1e-5, atol=1e-6)


def test_rglru_kernel_initial_state():
    rng = np.random.default_rng(0)
    u = jnp.asarray(rng.normal(size=(2, 8, 128)), jnp.float32)
    a = jnp.asarray(rng.uniform(0.5, 0.9, size=(2, 8, 128)), jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(2, 128)), jnp.float32)
    got, _ = rglru_scan_pallas(u, a, h0, interpret=True)
    # sequential reference with initial state
    h = np.asarray(h0)
    outs = []
    for ti in range(8):
        h = np.asarray(a[:, ti]) * h + np.asarray(u[:, ti])
        outs.append(h.copy())
    np.testing.assert_allclose(np.asarray(got),
                               np.stack(outs, axis=1), rtol=1e-5, atol=1e-6)
