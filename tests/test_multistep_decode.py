"""Multi-step decode correctness: teacher-forced decode for N steps must match
the full-forward logits at every position -- exercises ring-cache wraparound
and recurrent state threading (RG-LRU / mLSTM / sLSTM) across many steps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import decode_step, forward, init_caches, init_params, prefill

ARCHS = ["recurrentgemma-9b", "xlstm-350m", "gemma3-12b", "whisper-medium"]


@pytest.mark.parametrize("arch", ARCHS)
def test_teacher_forced_decode_matches_forward(arch):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.key(0), dtype=jnp.float32)
    rng = np.random.default_rng(1)
    T_PRE, T_DEC = 12, 14          # decode well past window=8 (ring wraps)
    toks = jnp.asarray(rng.integers(2, cfg.vocab, size=(2, T_PRE + T_DEC)),
                       jnp.int32)
    mem = None
    if cfg.memory_len:
        mem = jax.random.normal(jax.random.key(9),
                                (2, cfg.memory_len, cfg.d_model),
                                jnp.float32) * 0.02

    ref_logits, _ = forward(params, cfg, toks, memory=mem, mode="train",
                            remat=False)

    caches = init_caches(cfg, 2, T_PRE + T_DEC + 4, dtype=jnp.float32)
    _, caches = prefill(params, cfg, toks[:, :T_PRE], caches, memory=mem)
    for i in range(T_DEC):
        pos = jnp.full((2,), T_PRE + i, jnp.int32)
        logits, caches = decode_step(params, cfg, toks[:, T_PRE + i: T_PRE + i + 1],
                                     pos, caches, memory=mem)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(ref_logits[:, T_PRE + i]),
            rtol=3e-2, atol=3e-2,
            err_msg=f"{arch}: decode step {i} diverged")
