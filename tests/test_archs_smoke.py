"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes + finite values; plus
prefill->decode cache-consistency for every decodable family."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced
from repro.models import (decode_step, forward, init_caches, init_params,
                          loss_fn, prefill)

B, T = 2, 32


def _inputs(cfg, key):
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab)
    mem = None
    if cfg.memory_len:
        mem = jax.random.normal(jax.random.key(9), (B, cfg.memory_len,
                                                    cfg.d_model),
                                jnp.float32) * 0.02
    return toks, mem


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.key(0), dtype=jnp.float32)
    toks, mem = _inputs(cfg, jax.random.key(1))
    loss, grads = jax.value_and_grad(loss_fn)(params, cfg, toks, mem)
    assert np.isfinite(float(loss))
    # untrained loss should be near log(vocab)
    assert abs(float(loss) - math.log(cfg.vocab)) < 1.5
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g))) for g in flat)
    assert any(float(jnp.abs(g).max()) > 0 for g in flat)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes(arch):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.key(0), dtype=jnp.float32)
    toks, mem = _inputs(cfg, jax.random.key(1))
    logits, _ = forward(params, cfg, toks, memory=mem, mode="train")
    assert logits.shape == (B, T, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """Decoding token T given a prefill cache of [0..T) must produce the same
    logits as a full forward over [0..T] -- exercises every cache type."""
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.key(0), dtype=jnp.float32)
    toks, mem = _inputs(cfg, jax.random.key(1))
    cache_len = T + 8

    # full forward over all T tokens (teacher forcing reference)
    ref_logits, _ = forward(params, cfg, toks, memory=mem, mode="train",
                            remat=False)

    caches = init_caches(cfg, B, cache_len, dtype=jnp.float32)
    # prefill on the first T-1 tokens, then decode the T-th
    pre_logits, caches = prefill(params, cfg, toks[:, : T - 1], caches,
                                 memory=mem)
    np.testing.assert_allclose(np.asarray(pre_logits),
                               np.asarray(ref_logits[:, : T - 1]),
                               rtol=2e-2, atol=2e-2)
    pos = jnp.full((B,), T - 1, jnp.int32)
    dec_logits, _ = decode_step(params, cfg, toks[:, T - 1:], pos, caches,
                                memory=mem)
    np.testing.assert_allclose(np.asarray(dec_logits[:, 0]),
                               np.asarray(ref_logits[:, T - 1]),
                               rtol=2e-2, atol=2e-2)


def test_exact_layer_counts():
    expect = {"gemma3-12b": 48, "internlm2-1.8b": 24, "gemma2-27b": 46,
              "minicpm-2b": 40, "arctic-480b": 35, "qwen3-moe-235b-a22b": 94,
              "llama-3.2-vision-11b": 40, "recurrentgemma-9b": 38,
              "xlstm-350m": 24, "whisper-medium": 48}
    for arch, n in expect.items():
        assert get_config(arch).n_layers == n, arch


def test_param_counts_in_band():
    """Sanity: exact (eval_shape) param counts land on the advertised scale.

    whisper lands high (0.96B vs 769M): this repo uses gated-SwiGLU MLPs in
    every block (DESIGN.md deviation); llama-vision lands at 9.8B because the
    11B figure includes the stubbed vision encoder."""
    from repro.models.model import param_count
    bands = {"gemma3-12b": (9e9, 14e9), "internlm2-1.8b": (1.5e9, 2.3e9),
             "gemma2-27b": (22e9, 30e9), "minicpm-2b": (2e9, 3.3e9),
             "arctic-480b": (420e9, 520e9),
             "qwen3-moe-235b-a22b": (200e9, 260e9),
             "llama-3.2-vision-11b": (8e9, 12e9),
             "recurrentgemma-9b": (7e9, 11e9),
             "xlstm-350m": (2.5e8, 5e8), "whisper-medium": (5e8, 1.2e9)}
    for arch, (lo, hi) in bands.items():
        n = param_count(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"


def test_moe_routing_matches_dense_reference():
    """Sort-based dispatch == explicit per-token expert mix at high capacity."""
    from repro.models import blocks as BL
    from repro.models.config import MoEConfig
    cfg = reduced(get_config("qwen3-moe-235b-a22b"))
    import dataclasses
    cfg = dataclasses.replace(cfg, moe=MoEConfig(4, 2, 64, capacity_factor=8.0))
    p = BL.init_moe(cfg, jax.random.key(3), dtype=jnp.float32)
    x = jax.random.normal(jax.random.key(4), (2, 8, cfg.d_model), jnp.float32)
    got = BL.apply_moe(p, x, cfg)

    xt = x.reshape(-1, cfg.d_model)
    probs = jax.nn.softmax(xt @ p["router"], axis=-1)
    w, ids = jax.lax.top_k(probs, 2)
    w = w / w.sum(-1, keepdims=True)
    outs = []
    for e in range(4):
        h = jax.nn.silu(xt @ p["wg"][e]) * (xt @ p["wi"][e])
        outs.append(h @ p["wo"][e])
    dense = sum(jnp.where((ids == e).any(-1, keepdims=True),
                          (w * (ids == e)).sum(-1, keepdims=True), 0.0) * outs[e]
                for e in range(4))
    np.testing.assert_allclose(np.asarray(got.reshape(-1, cfg.d_model)),
                               np.asarray(dense), rtol=1e-4, atol=1e-5)
