"""Executed in a subprocess by test_device.py with 8 forced host devices.

Covers the ISSUE-10 device-plane contracts end to end:
  * all five query verbs vs the numpy searchsorted oracle, on
    duplicate-heavy keys whose equal runs straddle device cuts, under BOTH
    exchange strategies (allgather and bucketed all_to_all);
  * the a2a slack-overflow contract: a skew-adversarial stream (every query
    owned by one shard, slack=1) is still answered exactly -- the service
    resolves the overflow internally and only the telemetry sees it;
  * delta publish: a single-dirty-shard publish re-ships exactly one row,
    the clean shards' device buffers keep their identity
    (unsafe_buffer_pointer), and the uploaded bytes are < 1/4 of a full
    republish;
  * a concurrent publisher/reader race: no torn DeviceShardSet (the
    sanitizer's pin tracker is live via REPRO_SANITIZE=1, and every read
    stays bit-identical to one of the published epochs).
"""
import os
import threading

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("REPRO_SANITIZE", "1")

import jax
import numpy as np

from repro.index.device import DeviceShardedService

assert jax.device_count() == 8

rng = np.random.default_rng(7)
# duplicate-heavy: ~300 distinct values over 20k keys => long equal runs
# that straddle the equal-count device cuts
keys = np.sort(rng.choice(rng.integers(0, 1 << 20, 300), 20_000))
keys = keys.astype(np.float64)
k32 = keys.astype(np.float32)
queries = np.concatenate([keys[::11],
                          rng.integers(0, 1 << 20, 500).astype(np.float64)])
q32 = queries.astype(np.float32)


def oracle_side(q, side):
    return np.searchsorted(k32, q.astype(np.float32), side)


for xchg in ("allgather", "a2a"):
    svc = DeviceShardedService(keys, error=64, device_count=8,
                               buffer_size=16, exchange=xchg,
                               assume_sorted=True)
    left, right = oracle_side(queries, "left"), oracle_side(queries, "right")
    for side, exp in (("left", left), ("right", right)):
        np.testing.assert_array_equal(svc.search(queries, side=side), exp,
                                      err_msg=f"{xchg}/search/{side}")
    np.testing.assert_array_equal(svc.lookup(queries),
                                  np.where(right > left, left, -1),
                                  err_msg=f"{xchg}/lookup")
    pt = svc.point(queries)
    np.testing.assert_array_equal(pt.rank, np.where(right > left, left, -1))
    np.testing.assert_array_equal(pt.found, right > left)
    pred = svc.predecessor(queries)
    np.testing.assert_array_equal(pred.rank,
                                  np.where(right - 1 >= 0, right - 1, -1))
    succ = svc.successor(queries)
    np.testing.assert_array_equal(succ.rank,
                                  np.where(left < keys.size, left, -1))
    lo_q, hi_q = queries - 5.0, queries + 5.0
    np.testing.assert_array_equal(
        svc.count(lo_q, hi_q),
        np.maximum(oracle_side(hi_q, "right") - oracle_side(lo_q, "left"), 0))
    rr = svc.range(float(keys[100]), float(keys[15_000]))
    lo_r = int(oracle_side(keys[100:101], "left")[0])
    hi_r = int(oracle_side(keys[15_000:15_001], "right")[0])
    assert (rr.lo_rank, rr.hi_rank) == (lo_r, hi_r), xchg
    np.testing.assert_array_equal(rr.keys, keys[lo_r:hi_r])
    print(f"{xchg}: five verbs bit-identical to the oracle")

# ---- a2a skew-adversarial regression: every query owned by shard 0, no
# slack headroom; answers must STILL be exact (follow-up allgather pass),
# with the overflow visible only in telemetry
svc = DeviceShardedService(keys, error=64, device_count=8, exchange="a2a",
                           slack=1.0, assume_sorted=True)
skew = np.full(512, float(keys[0]))
np.testing.assert_array_equal(svc.search(skew, side="left"),
                              oracle_side(skew, "left"))
np.testing.assert_array_equal(svc.lookup(skew),
                              np.where(oracle_side(skew, "right")
                                       > oracle_side(skew, "left"),
                                       oracle_side(skew, "left"), -1))
dm = svc.metrics().device
assert dm.a2a_overflow_queries > 0, "skewed stream must overflow slack=1"
print(f"a2a skew-adversarial OK ({dm.a2a_overflow_queries} overflow "
      "queries resolved internally)")

# ---- delta publish: one dirty shard => one re-shipped row, clean rows
# keep buffer identity, uploaded bytes < 1/4 of a full republish
svc = DeviceShardedService(keys, error=64, device_count=8, buffer_size=16,
                           assume_sorted=True)
ds0 = svc.device_set
ptr0 = {name: [s.data.unsafe_buffer_pointer()
               for s in getattr(ds0, name).addressable_shards]
        for name in ("d_seg_start", "d_slope", "d_base", "d_seg_end",
                     "d_keys", "d_n_local")}
target = float(keys[0]) + 0.25           # routes to shard 0
dirty = svc.shard_of(target)
svc.insert(target)
m_before = svc.metrics().device
svc.publish()
ds1 = svc.device_set
assert ds1.version == ds0.version + 1
assert ds1.s_cap == ds0.s_cap and ds1.m_cap == ds0.m_cap, \
    "single insert must stay inside the padded capacities (delta-eligible)"
for name, before in ptr0.items():
    after = [s.data.unsafe_buffer_pointer()
             for s in getattr(ds1, name).addressable_shards]
    same = [i for i in range(8) if after[i] == before[i]]
    assert len(same) == 7 and dirty not in same, \
        f"{name}: clean rows must keep buffer identity, dirty row must not"
m_after = svc.metrics().device
assert m_after.delta_publishes == m_before.delta_publishes + 1
delta_bytes = m_after.bytes_uploaded - m_before.bytes_uploaded
full_bytes = (m_after.bytes_full_equivalent
              - m_before.bytes_full_equivalent)
assert delta_bytes * 4 < full_bytes, (delta_bytes, full_bytes)
# and the published insert is served
exp = np.searchsorted(np.sort(np.append(k32, np.float32(target))),
                      np.float32(target), "left")
assert int(svc.search(np.asarray([target]))[0]) == int(exp)
print(f"delta publish OK ({delta_bytes} B vs {full_bytes} B full, "
      f"ratio {delta_bytes / full_bytes:.3f})")

# ---- concurrent publish/reader race: readers pin one manifest per verb;
# every answer must be consistent with SOME published key set (before or
# after any in-flight publish), never a torn mix.  The pin tracker
# (REPRO_SANITIZE=1) independently asserts single-manifest reads.
svc = DeviceShardedService(keys, error=64, device_count=8, buffer_size=16,
                           exchange="allgather", assume_sorted=True)
probe = np.asarray([float(keys[0]), float(keys[-1]) + 10.0])
stop = threading.Event()
errors: list[BaseException] = []
inserted = []


def writer():
    try:
        base = float(keys[-1])
        for i in range(1, 41):
            svc.insert(base + i)           # always the last shard
            inserted.append(base + i)
            svc.publish()
    except BaseException as exc:  # noqa: BLE001 - surfaced by the assert
        errors.append(exc)
    finally:
        stop.set()


def reader():
    try:
        while not stop.is_set():
            r = svc.point(probe)
            # probe[0] is the global minimum: rank 0 in every epoch
            assert int(r.rank[0]) == 0 and bool(r.found[0])
            # probe[1] is greater than every key in every epoch: absent,
            # and its insertion rank equals that epoch's total key count
            n = int(svc.search(probe[1:])[0])
            assert not bool(r.found[1])
            assert keys.size <= n <= keys.size + 40
    except BaseException as exc:  # noqa: BLE001
        errors.append(exc)


threads = [threading.Thread(target=writer)] + \
    [threading.Thread(target=reader) for _ in range(2)]
for t in threads:
    t.start()
for t in threads:
    t.join(timeout=300)
assert not errors, errors
final = np.sort(np.concatenate([keys, inserted]))
np.testing.assert_array_equal(
    svc.search(final[:: 97]),
    np.searchsorted(final.astype(np.float32),
                    final[:: 97].astype(np.float32), "left"))
print(f"concurrent publish/reader race OK "
      f"({svc.metrics().device.publishes} publishes)")
print("ALL_OK")
