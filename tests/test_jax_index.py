"""Device-side index: batched lookup / bounds / range counts, both strategies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import build_device_index, lookup, predict_positions, range_count
from repro.core.jax_index import bound


def _keys(n=5000, seed=0, as_int=True):
    rng = np.random.default_rng(seed)
    # integer-valued keys < 2^23 so f32 interpolation is exact (see jax_index doc)
    ks = np.sort(rng.choice(2 ** 23, size=n, replace=False)).astype(np.float64)
    return ks


@pytest.mark.parametrize("strategy", ["window", "bisect"])
@pytest.mark.parametrize("error", [8, 64])
def test_lookup_finds_all(strategy, error):
    ks = _keys()
    idx = build_device_index(ks, error)
    q = jnp.asarray(ks[::7], jnp.float32)
    ranks = np.asarray(lookup(idx, q, strategy))
    assert np.all(ranks >= 0)
    np.testing.assert_array_equal(ks[ranks], ks[::7])


@pytest.mark.parametrize("strategy", ["window", "bisect"])
def test_lookup_absent_returns_minus_one(strategy):
    ks = _keys()
    idx = build_device_index(ks, 32)
    q = jnp.asarray(ks[::11] + 0.5, jnp.float32)
    assert np.all(np.asarray(lookup(idx, q, strategy)) == -1)


def test_predictions_within_error():
    ks = _keys(20_000, seed=3)
    e = 16
    idx = build_device_index(ks, e)
    pred = np.asarray(predict_positions(idx, jnp.asarray(ks, jnp.float32)))
    true = np.arange(ks.shape[0])
    # duplicates of boundary keys can be assigned the neighbour segment; allow +-e
    assert np.max(np.abs(pred - true)) <= e + 1


def test_bound_matches_numpy_searchsorted():
    ks = _keys(8000, seed=5)
    idx = build_device_index(ks, 32)
    rng = np.random.default_rng(7)
    q = np.sort(rng.uniform(ks[0], ks[-1], size=300)).astype(np.float32)
    got_l = np.asarray(bound(idx, jnp.asarray(q), "left"))
    got_r = np.asarray(bound(idx, jnp.asarray(q), "right"))
    ks32 = ks.astype(np.float32)
    np.testing.assert_array_equal(got_l, np.searchsorted(ks32, q, side="left"))
    np.testing.assert_array_equal(got_r, np.searchsorted(ks32, q, side="right"))


def test_range_count():
    ks = _keys(8000, seed=9)
    idx = build_device_index(ks, 64)
    lo = jnp.asarray(ks[100:110], jnp.float32)
    hi = jnp.asarray(ks[600:610], jnp.float32)
    got = np.asarray(range_count(idx, lo, hi))
    np.testing.assert_array_equal(got, 501)


def test_lookup_jits_and_caches():
    ks = _keys()
    idx = build_device_index(ks, 32)
    f = jax.jit(lambda q: lookup(idx, q, "window"))
    q = jnp.asarray(ks[:128], jnp.float32)
    r1 = f(q)
    r2 = f(q + 0)
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))


@given(seed=st.integers(0, 30), error=st.sampled_from([4, 16, 63, 128]))
@settings(max_examples=20, deadline=None)
def test_property_device_matches_host(seed, error):
    rng = np.random.default_rng(seed)
    ks = np.sort(rng.choice(2 ** 20, size=1000, replace=False)).astype(np.float64)
    idx = build_device_index(ks, error)
    q = ks[rng.integers(0, 1000, size=64)]
    ranks = np.asarray(lookup(idx, jnp.asarray(q, jnp.float32), "window"))
    np.testing.assert_array_equal(ks[ranks], q)
    ranks_b = np.asarray(lookup(idx, jnp.asarray(q, jnp.float32), "bisect"))
    np.testing.assert_array_equal(ranks, ranks_b)
