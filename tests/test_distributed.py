"""Multi-device (8 fake CPU devices) range-partitioned index, via subprocess
so the forced device count never leaks into other tests."""
import pathlib
import subprocess
import sys

import pytest


def _run_subprocess(name):
    script = pathlib.Path(__file__).parent / name
    env = {"PYTHONPATH": str(pathlib.Path(__file__).parents[1] / "src"),
           "PATH": "/usr/bin:/bin"}
    res = subprocess.run([sys.executable, str(script)], capture_output=True,
                         text=True, timeout=600, env=env)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    assert "ALL_OK" in res.stdout


@pytest.mark.slow
def test_distributed_lookup_8dev():
    _run_subprocess("_distributed_check.py")


@pytest.mark.slow
def test_moe_expert_parallel_8dev():
    _run_subprocess("_moe_ep_check.py")
