"""The typed query plane: search/point/range/count/predecessor/successor.

Asserts (a) every registered backend derives identical answers from its
bounded-window ``search`` primitive -- vs the ``np.searchsorted`` oracle on
duplicate-heavy data, random bounds, empty ranges, and bounds outside the key
domain; (b) the sharded service's stitched spans equal the single-table
oracle, including duplicate runs straddling shard cuts and a scan issued
concurrently with ``rebalance()``; (c) the legacy paths
(``core/tree.range_query``, ``core/jax_index.range_count``) now share the
``[lo, hi]``-inclusive boundary contract (leftmost rank at ``lo``, rightmost
at ``hi``); and (d) the serving layers carry the verbs: payload
materialization, epoch visibility, and the per-shape query counters.
"""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FITingTree, build_device_index, range_count
from repro.core.jax_index import bound
from repro.index import (FitSpec, InfeasibleSpecError, SegmentTable,
                         ShardedIndexService, make_engine, numpy_search, plan)
from repro.serve import IndexService, open_index

ALL_BACKENDS = ("numpy", "xla-window", "xla-bisect", "pallas", "dispatch")


def _dup_heavy_keys(n=4000, seed=0, lim=2 ** 20, run_len=300):
    """Sorted integer-valued keys (exact in f32) with heavy duplication plus
    one run far longer than any error bound, so it straddles segments (and,
    sharded, shard cuts)."""
    rng = np.random.default_rng(seed)
    base = rng.choice(lim, size=n, replace=False)
    dups = rng.choice(base, size=n // 2)
    long_run = np.full(run_len, base[n // 3])
    return np.sort(np.concatenate([base, dups, long_run]).astype(np.float64))


def _bounds_pool(keys, rng, m=40):
    """Range bounds of every flavor: present keys (incl. duplicates), gap
    values, and bounds outside the key domain on both sides."""
    present = keys[rng.integers(0, keys.shape[0], m)]
    gaps = np.round(rng.uniform(keys[0], keys[-1], m)) + 0.5
    outside = np.array([keys[0] - 10.0, keys[0] - 1.0,
                        keys[-1] + 1.0, keys[-1] + 10.0, -1e9, 1e9])
    return np.concatenate([present, gaps, outside])


# ------------------------------------------------------- backend agreement
@pytest.mark.parametrize("backend", ALL_BACKENDS)
@pytest.mark.parametrize("side", ["left", "right"])
def test_search_matches_searchsorted_oracle(backend, side):
    keys = _dup_heavy_keys(seed=1)
    table = SegmentTable.from_keys(keys, 32, assume_sorted=True)
    rng = np.random.default_rng(2)
    q = _bounds_pool(keys, rng, m=80)
    got = make_engine(table, backend).search(q, side)
    np.testing.assert_array_equal(got, np.searchsorted(keys, q, side=side))


def test_search_rejects_bad_side():
    table = SegmentTable.from_keys(np.arange(64.0), 8, assume_sorted=True)
    for backend in ALL_BACKENDS:
        with pytest.raises(ValueError, match="side"):
            make_engine(table, backend).search(np.asarray([1.0]), "middle")


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_count_and_predecessor_all_backends_vs_oracle(backend):
    """Acceptance: count / predecessor / successor are bit-identical across
    every backend, on random + empty + out-of-domain bounds."""
    keys = _dup_heavy_keys(seed=3)
    table = SegmentTable.from_keys(keys, 64, assume_sorted=True)
    rng = np.random.default_rng(4)
    eng = make_engine(table, backend)

    lo = _bounds_pool(keys, rng)
    hi = _bounds_pool(keys, rng)
    want = np.maximum(np.searchsorted(keys, hi, "right")
                      - np.searchsorted(keys, lo, "left"), 0)
    np.testing.assert_array_equal(eng.count(lo, hi), want, err_msg=backend)
    # inverted bounds are empty, never negative
    assert np.all(eng.count(hi, lo - 1) >= 0)

    q = _bounds_pool(keys, rng)
    pred = eng.predecessor(q)
    want_r = np.searchsorted(keys, q, "right") - 1
    np.testing.assert_array_equal(pred.rank, np.where(want_r >= 0, want_r, -1))
    np.testing.assert_array_equal(pred.found, want_r >= 0)
    suc = eng.successor(q)
    want_l = np.searchsorted(keys, q, "left")
    ok = want_l < keys.shape[0]
    np.testing.assert_array_equal(suc.rank, np.where(ok, want_l, -1))
    np.testing.assert_array_equal(suc.found, ok)


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_range_spans_and_materialization(backend):
    keys = _dup_heavy_keys(seed=5)
    table = SegmentTable.from_keys(keys, 32, assume_sorted=True)
    eng = make_engine(table, backend)
    rng = np.random.default_rng(6)
    for _ in range(8):
        lo, hi = np.sort(rng.choice(keys, 2))
        res = eng.range(float(lo), float(hi))
        exp = keys[(keys >= lo) & (keys <= hi)]     # [lo, hi] inclusive
        assert res.lo_rank == np.searchsorted(keys, lo, "left")
        assert res.hi_rank == np.searchsorted(keys, hi, "right")
        assert res.count == exp.shape[0]
        np.testing.assert_array_equal(res.keys, exp)
    # empty range in a gap, inverted range, out-of-domain range
    gap = float(np.round((keys[10] + keys[11]) / 2)) + 0.25
    for lo, hi in ((gap, gap), (float(keys[100]), float(keys[50]) - 1),
                   (keys[-1] + 5, keys[-1] + 9), (keys[0] - 9, keys[0] - 5)):
        res = eng.range(lo, hi)
        assert res.empty and res.count == 0 and res.keys.shape[0] == 0
    res = eng.range(1.0, 2.0, materialize=False)
    assert res.keys is None and res.payload is None
    with pytest.raises(ValueError, match="NaN"):
        eng.range(float("nan"), 1.0)


def test_point_is_typed_lookup():
    keys = _dup_heavy_keys(seed=7)
    table = SegmentTable.from_keys(keys, 32, assume_sorted=True)
    rng = np.random.default_rng(8)
    q = _bounds_pool(keys, rng)
    want = make_engine(table, "numpy").lookup(q)
    for backend in ALL_BACKENDS:
        res = make_engine(table, backend).point(q)
        np.testing.assert_array_equal(res.rank, want, err_msg=backend)
        np.testing.assert_array_equal(res.found, want >= 0, err_msg=backend)
    assert make_engine(table, "numpy").point(q).n_found == int((want >= 0).sum())


def test_empty_table_answers_every_verb():
    table = SegmentTable.empty(16)
    for backend in ALL_BACKENDS:
        eng = make_engine(table, backend)
        q = np.asarray([1.0, 2.0])
        np.testing.assert_array_equal(eng.search(q, "left"), [0, 0])
        np.testing.assert_array_equal(eng.search(q, "right"), [0, 0])
        assert not eng.point(q).found.any()
        np.testing.assert_array_equal(eng.count(q, q + 1), [0, 0])
        res = eng.range(0.0, 10.0)
        assert res.empty and res.keys.shape[0] == 0
        assert not eng.predecessor(q).found.any()
        assert not eng.successor(q).found.any()


# ------------------------------------------------ legacy path reconciliation
def test_tree_range_query_inclusive_and_duplicate_safe():
    """The legacy scan started at lo's *routed* segment, dropping duplicates
    of lo whose run began earlier; it now shares the plane's contract."""
    keys = _dup_heavy_keys(seed=9)
    t = FITingTree(keys, error=16, buffer_size=4, assume_sorted=True)
    values, counts = np.unique(keys, return_counts=True)
    run_val = float(values[np.argmax(counts)])      # the long run's value
    got = t.range_query(run_val, run_val)           # exactly the run
    exp = keys[keys == run_val]
    np.testing.assert_array_equal(got, exp)
    rng = np.random.default_rng(10)
    for _ in range(6):
        lo, hi = np.sort(rng.choice(keys, 2))
        np.testing.assert_array_equal(
            t.range_query(float(lo), float(hi)),
            keys[(keys >= lo) & (keys <= hi)])
    assert t.range_query(5.0, 4.0).shape[0] == 0    # inverted -> empty


def test_jax_range_count_inclusive_and_duplicate_safe():
    keys = _dup_heavy_keys(seed=11)
    idx = build_device_index(keys, 32)
    rng = np.random.default_rng(12)
    lo = np.sort(keys[rng.integers(0, keys.shape[0], 16)]).astype(np.float32)
    hi = np.sort(keys[rng.integers(0, keys.shape[0], 16)]).astype(np.float32)
    lo, hi = np.minimum(lo, hi), np.maximum(lo, hi)
    ks32 = keys.astype(np.float32)
    want = (np.searchsorted(ks32, hi, "right")
            - np.searchsorted(ks32, lo, "left"))
    got = np.asarray(range_count(idx, jnp.asarray(lo), jnp.asarray(hi)))
    np.testing.assert_array_equal(got, want)
    # inverted ranges count 0 instead of going negative
    got_inv = np.asarray(range_count(idx, jnp.asarray(hi + 1), jnp.asarray(lo)))
    assert np.all(got_inv == 0)
    # bound (the primitive the wrapper delegates to) is searchsorted-exact
    # even for duplicate runs longer than the window
    q = jnp.asarray(keys[rng.integers(0, keys.shape[0], 64)], jnp.float32)
    for side in ("left", "right"):
        np.testing.assert_array_equal(
            np.asarray(bound(idx, q, side)),
            np.searchsorted(ks32, np.asarray(q), side))


def test_numpy_search_is_the_tree_page_oracle():
    """numpy_search on the tree's snapshot == searchsorted over its pages."""
    keys = _dup_heavy_keys(seed=13)
    t = FITingTree(keys, error=32, assume_sorted=True)
    table = t.as_table()
    rng = np.random.default_rng(14)
    q = _bounds_pool(keys, rng)
    for side in ("left", "right"):
        np.testing.assert_array_equal(numpy_search(table, q, side),
                                      np.searchsorted(keys, q, side))


# ----------------------------------------------------------- serving layers
def test_service_range_sees_published_epochs_only():
    keys = np.sort(np.random.default_rng(15).choice(
        2 ** 20, size=3000, replace=False).astype(np.float64))
    svc = IndexService(keys, error=32, buffer_size=8, backend="numpy")
    gap = float(np.setdiff1d(np.arange(2 ** 16, dtype=np.float64), keys)[0])
    before = svc.count([gap - 0.5], [gap + 0.5])[0]
    assert before == 0
    svc.insert(gap)
    assert svc.count([gap - 0.5], [gap + 0.5])[0] == 0   # not yet published
    svc.publish()
    assert svc.count([gap - 0.5], [gap + 0.5])[0] == 1
    res = svc.range(gap, gap)
    assert res.count == 1 and res.keys[0] == gap
    assert svc.predecessor(np.asarray([gap])).rank[0] == res.lo_rank
    assert svc.successor(np.asarray([gap])).rank[0] == res.lo_rank


def test_service_range_materializes_payload():
    rng = np.random.default_rng(16)
    keys = np.sort(rng.choice(2 ** 20, size=2000, replace=False)
                   ).astype(np.float64)
    payload = (keys * 7).astype(np.int64)       # recomputable from the key
    svc = IndexService(keys, error=32, buffer_size=8, payload=payload)
    lo, hi = float(keys[300]), float(keys[700])
    res = svc.range(lo, hi)
    np.testing.assert_array_equal(res.payload, (res.keys * 7).astype(np.int64))
    # payloads ride through insert -> publish too
    gap = float(np.setdiff1d(np.arange(2 ** 16, dtype=np.float64), keys)[0])
    svc.insert(gap, int(gap * 7))
    svc.publish()
    res2 = svc.range(gap, gap)
    assert res2.payload[0] == int(gap * 7)
    # sharded payload stitching across a multi-shard span
    sh = ShardedIndexService(keys, error=32, n_shards=4, buffer_size=8,
                             payload=payload, assume_sorted=True)
    wide = sh.range(float(keys[10]), float(keys[-10]))
    np.testing.assert_array_equal(wide.payload,
                                  (wide.keys * 7).astype(np.int64))


def test_sharded_verbs_equal_single_table_oracle_on_duplicates():
    """Acceptance: stitched cross-shard spans == the single-table oracle on
    duplicate-heavy data, including runs straddling shard cuts."""
    keys = _dup_heavy_keys(seed=17, run_len=500)
    svc = ShardedIndexService(keys, error=32, n_shards=5, buffer_size=8,
                              assume_sorted=True)
    rng = np.random.default_rng(18)
    q = _bounds_pool(keys, rng, m=60)
    for side in ("left", "right"):
        np.testing.assert_array_equal(svc.search(q, side),
                                      np.searchsorted(keys, q, side))
    lo = _bounds_pool(keys, rng)
    hi = _bounds_pool(keys, rng)
    want = np.maximum(np.searchsorted(keys, hi, "right")
                      - np.searchsorted(keys, lo, "left"), 0)
    np.testing.assert_array_equal(svc.count(lo, hi), want)
    # spans crossing several shard boundaries, incl. the whole key space
    for lo_k, hi_k in ((float(keys[5]), float(keys[-5])),
                       (float(svc.boundaries[1]), float(svc.boundaries[-1])),
                       (keys[0] - 100, keys[-1] + 100)):
        res = svc.range(lo_k, hi_k)
        exp = keys[(keys >= lo_k) & (keys <= hi_k)]
        assert res.count == exp.shape[0]
        np.testing.assert_array_equal(res.keys, exp)
    pr = svc.predecessor(q)
    want_r = np.searchsorted(keys, q, "right") - 1
    np.testing.assert_array_equal(pr.rank, np.where(want_r >= 0, want_r, -1))


def test_sharded_verbs_after_growth_and_rebalance():
    """Spans stay oracle-exact after uneven shard growth and a forced recut
    (fresh ShardSet + handles)."""
    rng = np.random.default_rng(19)
    base = np.sort(rng.choice(2 ** 20, size=6000, replace=False)
                   ).astype(np.float64)
    svc = ShardedIndexService(base, error=64, n_shards=3, buffer_size=32,
                              assume_sorted=True)
    fresh = np.setdiff1d(rng.choice(2 ** 20, size=6000, replace=False
                                    ).astype(np.float64), base)
    grow = fresh[fresh < svc.boundaries[1]][:800]    # skew shard 0
    for k in grow:
        svc.insert(float(k))
    svc.publish()
    union = np.sort(np.concatenate([base, grow]))
    svc.rebalance(force=True)
    lo_k, hi_k = float(union[100]), float(union[-100])
    res = svc.range(lo_k, hi_k)
    exp = union[(union >= lo_k) & (union <= hi_k)]
    np.testing.assert_array_equal(res.keys, exp)
    q = union[rng.integers(0, union.shape[0], 64)]
    np.testing.assert_array_equal(svc.search(q, "left"),
                                  np.searchsorted(union, q, "left"))


@pytest.mark.slow
def test_scan_concurrent_with_rebalance_never_tears():
    """Acceptance: a range scan issued concurrently with rebalance() pins one
    ShardSet -- a torn view would surface as an unsorted/out-of-bounds key
    run or a count disagreeing with the materialized span."""
    rng = np.random.default_rng(20)
    base = np.sort(rng.choice(2 ** 20, size=10_000, replace=False)
                   ).astype(np.float64)
    svc = ShardedIndexService(base, error=64, n_shards=4, buffer_size=32,
                              publish_every=256, auto_rebalance=True,
                              skew_threshold=1.2, assume_sorted=True)
    hot = np.setdiff1d(
        rng.uniform(0, float(svc.boundaries[1]), 12_000).round(), base)[:5000]
    lo_k, hi_k = float(base[1000]), float(base[-1000])
    always = base[(base >= lo_k) & (base <= hi_k)]   # never removed
    failures: list[str] = []
    done = threading.Event()

    def reader():
        while not done.is_set():
            res = svc.range(lo_k, hi_k)
            if res.count != res.keys.shape[0]:
                failures.append(f"count {res.count} != materialized "
                                f"{res.keys.shape[0]} (torn span)")
                return
            if res.keys.shape[0] and (res.keys[0] < lo_k
                                      or res.keys[-1] > hi_k):
                failures.append("materialized keys escape [lo, hi]")
                return
            if np.any(np.diff(res.keys) < 0):
                failures.append("unsorted key run (mixed epochs)")
                return
            if res.keys.shape[0] < always.shape[0]:
                failures.append("published keys missing from span")
                return

    def writer():
        for k in hot:
            svc.insert(float(k))
        svc.publish()

    r = threading.Thread(target=reader)
    w = threading.Thread(target=writer)
    r.start(); w.start()
    w.join(timeout=120)
    done.set()
    r.join(timeout=30)
    assert not failures, failures
    assert svc.service_stats()["rebalances"] >= 1    # the race actually ran
    union = np.sort(np.concatenate([base, hot]))
    exp = union[(union >= lo_k) & (union <= hi_k)]
    np.testing.assert_array_equal(svc.range(lo_k, hi_k).keys, exp)


# ------------------------------------------------------------ observability
def test_query_counters_in_service_stats():
    keys = np.sort(np.random.default_rng(21).choice(
        2 ** 20, size=2000, replace=False).astype(np.float64))
    svc = ShardedIndexService(keys, error=32, n_shards=2, assume_sorted=True)
    assert svc.service_stats()["query_counts"] == {
        "points": 0, "ranges": 0, "counts": 0,
        "predecessors": 0, "successors": 0, "searches": 0}
    svc.lookup(keys[:7])                            # legacy front door
    svc.point(keys[:5])
    svc.range(float(keys[0]), float(keys[10]))
    svc.count(keys[:3], keys[1:4])
    svc.predecessor(keys[:2])
    svc.successor(keys[:1])
    svc.search(keys[:4], "right")                   # the raw primitive
    got = svc.service_stats()["query_counts"]
    assert got == {"points": 12, "ranges": 1, "counts": 3,
                   "predecessors": 2, "successors": 1, "searches": 4}
    # the one-shard facade exposes the same counters
    one = IndexService(keys, error=32)
    one.range(0.0, 1.0)
    assert one.service_stats()["query_counts"]["ranges"] == 1


# --------------------------------------------------------- planner plumbing
def test_fitspec_range_fraction_round_trip_and_validation():
    spec = FitSpec(latency_budget_ns=700.0, range_fraction=0.3,
                   range_scan_rows=128)
    assert FitSpec.from_json(spec.to_json()) == spec
    with pytest.raises(ValueError, match="range_fraction"):
        FitSpec(error=64, range_fraction=1.5)
    with pytest.raises(ValueError, match="range_scan_rows"):
        FitSpec(error=64, range_scan_rows=0)


def test_scan_heavy_plan_budgets_for_the_scan_term():
    keys = np.sort(np.random.default_rng(22).choice(
        2 ** 20, size=20_000, replace=False).astype(np.float64))
    point_plan = plan(keys, FitSpec(latency_budget_ns=600.0))
    scan_plan = plan(keys, FitSpec(latency_budget_ns=600.0,
                                   range_fraction=0.5, range_scan_rows=512))
    # the scan term eats budget the locate side must give back: same budget
    # resolves to a tighter (faster-locate) error, never a looser one
    assert scan_plan.error <= point_plan.error
    assert "range_fraction" in scan_plan.explain()
    # an impossible scan-dominated budget names the scan term
    with pytest.raises(InfeasibleSpecError, match="range-scan term"):
        plan(keys, FitSpec(latency_budget_ns=60.0, range_fraction=0.9,
                           range_scan_rows=4096))
    # range_fraction survives open_index's plan -> service round trip
    svc = open_index(keys, FitSpec(error=64, range_fraction=0.25))
    assert svc.plan.spec.range_fraction == 0.25
