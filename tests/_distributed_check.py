"""Executed in a subprocess by test_distributed.py with 8 host devices."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import build_sharded_index, lookup_a2a, lookup_allgather

assert jax.device_count() == 8

mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
keys = np.sort(rng.choice(2 ** 22, size=80_000, replace=False)).astype(np.float64)
si = build_sharded_index(keys, error=64, n_shards=8, mesh=mesh, axis="data")

# present + absent queries, deliberately skewed to shard 0 to exercise overflow
q_present = keys[rng.integers(0, keys.shape[0], size=192)]
q_absent = q_present[:64] + 0.5
queries = np.concatenate([q_present, q_absent])
rng.shuffle(queries)
queries = jnp.asarray(queries, jnp.float32)

expect = np.searchsorted(keys.astype(np.float32), np.asarray(queries), side="left")
present = keys.astype(np.float32)[np.minimum(expect, keys.shape[0] - 1)] == np.asarray(queries)
expect = np.where(present, expect, -1)

got_ag = np.asarray(lookup_allgather(si, queries, mesh, "data"))
np.testing.assert_array_equal(got_ag, expect)
print("allgather OK")

got_a2a, ok = lookup_a2a(si, queries, mesh, "data", slack=8.0)
got_a2a, ok = np.asarray(got_a2a), np.asarray(ok)
assert ok.all(), f"a2a dropped {np.sum(~ok)} queries at slack=8"
np.testing.assert_array_equal(got_a2a, expect)
print("a2a OK")

# skewed load with tiny slack: drops must be flagged, answered ones correct
skew = jnp.asarray(np.sort(keys[:256]), jnp.float32)  # all owned by shard 0
got_s, ok_s = lookup_a2a(si, skew, mesh, "data", slack=0.5)
got_s, ok_s = np.asarray(got_s), np.asarray(ok_s)
exp_s = np.searchsorted(keys.astype(np.float32), np.asarray(skew), side="left")
assert np.all(got_s[ok_s] == exp_s[ok_s])
print(f"a2a skew OK ({np.sum(~ok_s)} flagged drops)")
print("ALL_OK")
