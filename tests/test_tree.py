"""FITingTree behaviour: lookups (Alg. 3), inserts (Alg. 4), ranges, router."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import FITingTree, PackedRouter
from repro.core.datasets import iot_like, step_data


def _mk(n=5000, error=32, buffer_size=0, seed=0, payload=False):
    rng = np.random.default_rng(seed)
    keys = np.sort(rng.uniform(0, 1e7, size=n))
    pl = np.arange(n) * 10 if payload else None
    return keys, FITingTree(keys, error=error, buffer_size=buffer_size, payload=pl)


def test_lookup_finds_every_key():
    keys, t = _mk()
    for k in keys[:: 37]:
        assert t.lookup(k) is not None
    # absent keys
    rng = np.random.default_rng(1)
    absent = rng.uniform(1.1e7, 2e7, size=50)
    for k in absent:
        assert t.lookup(k) is None


def test_lookup_batch_matches_scalar():
    keys, t = _mk(n=20_000, error=64)
    q = keys[:: 11]
    ranks = t.lookup_batch(q)
    assert np.all(ranks >= 0)
    np.testing.assert_array_equal(keys[ranks], q)
    absent = q + 0.5
    assert np.all(t.lookup_batch(absent) == -1)


def test_error_invariant_after_build():
    keys, t = _mk(n=30_000, error=16)
    assert t.max_abs_error() <= t.err_seg + 1e-6


@given(seed=st.integers(0, 50), error=st.integers(8, 128))
@settings(max_examples=25, deadline=None)
def test_property_lookup_roundtrip(seed, error):
    rng = np.random.default_rng(seed)
    keys = np.sort(rng.uniform(0, 1e6, size=2000))
    t = FITingTree(keys, error=error)
    q = keys[rng.integers(0, 2000, size=100)]
    ranks = t.lookup_batch(q)
    assert np.all(ranks >= 0)
    np.testing.assert_array_equal(keys[ranks], q)


def test_insert_then_lookup():
    keys, t = _mk(n=10_000, error=64, buffer_size=16)
    rng = np.random.default_rng(2)
    new = rng.uniform(0, 1e7, size=2000)
    for k in new:
        t.insert(k)
    for k in new[:: 17]:
        assert t.lookup(k) is not None, k
    for k in keys[:: 97]:
        assert t.lookup(k) is not None, k
    # error bound still holds after merges (Sec. 5)
    assert t.max_abs_error() <= t.err_seg + 1e-6
    assert t.n_keys == 12_000


def test_insert_splits_segments():
    """Buffer overflow must trigger merge + re-segmentation (Alg. 4 lines 5-9)."""
    keys = np.arange(1000, dtype=np.float64)  # linear -> 1 segment
    t = FITingTree(keys, error=64, buffer_size=8)
    assert t.n_segments == 1
    # hammer one region with a highly non-linear burst
    for i in range(64):
        t.insert(500.0 + i * 1e-4)
    assert t.max_abs_error() <= t.err_seg + 1e-6
    assert t.n_keys == 1064


def test_range_query():
    keys, t = _mk(n=10_000, error=32, buffer_size=8)
    lo, hi = keys[1000], keys[1500]
    got = t.range_query(lo, hi)
    expect = keys[(keys >= lo) & (keys <= hi)]
    np.testing.assert_allclose(got, expect)
    # with buffered inserts inside the range
    mids = np.linspace(lo, hi, 5)
    for m in mids:
        t.insert(float(m))
    got2 = t.range_query(lo, hi)
    assert got2.shape[0] == expect.shape[0] + 5


def test_non_clustered_payload():
    keys, t = _mk(payload=True)
    res = t.lookup(keys[123])
    assert res is not None and res[2] == 1230


def test_router_equivalent_to_searchsorted():
    keys, t = _mk(n=50_000, error=16)
    q = np.sort(np.random.default_rng(3).uniform(0, 1e7, size=500))
    via_router = t.router.descend(q)
    direct = np.clip(np.searchsorted(t.start_keys, q, side="right") - 1, 0,
                     t.n_segments - 1)
    np.testing.assert_array_equal(via_router, direct)


def test_router_height_and_size():
    r = PackedRouter(np.arange(16 ** 3, dtype=np.float64), fanout=16)
    assert r.height == 3
    assert r.size_bytes() == (16 ** 3 + 16 ** 2 + 16) * 16


def test_index_size_orders_of_magnitude_smaller():
    """The paper's headline: index size << one entry per key (Sec. 7.1.2)."""
    keys = iot_like(200_000)
    t = FITingTree(keys, error=100)
    dense_bytes = keys.shape[0] * 16  # key + pointer per entry
    assert t.index_size_bytes() < dense_bytes / 100


def test_step_data_segments():
    keys = step_data(n=20_000, step=100)
    t_small = FITingTree(keys, error=50)
    t_big = FITingTree(keys, error=200)
    assert t_big.n_segments < t_small.n_segments / 20
