"""Adaptive shard rebalancing + the routing/edge-case bugfix sweep.

Covers: duplicate-safe boundary cuts (no run straddles a shard; sharded
lookups match the single-table numpy oracle on duplicate-heavy data across
every backend, before and after a rebalance), the tree-level
extract_range/splice_run migration path, skew detection and the atomic
ShardSet swap, empty-table lookups on every backend, the pack_shard_tables
empty-interior-shard boundary fix, and (slow) a writer+reader thread race
showing an auto-publish/rebalance mid-stream never yields a half-swapped
routing view.
"""
import threading

import numpy as np
import pytest

from repro.core.tree import FITingTree
from repro.index import (SegmentTable, ShardedIndexService, make_engine,
                         numpy_lookup, pack_shard_tables, route_keys,
                         shard_cut_indices, shard_partition)

FIVE_BACKENDS = ("numpy", "xla-window", "xla-bisect", "pallas", "dispatch")


def _dup_heavy_keys(n, seed=0, max_run=6, lim=2 ** 20):
    """Sorted integer-valued keys with duplicate runs of length <= max_run
    (exact in f32, runs shorter than the error bound)."""
    rng = np.random.default_rng(seed)
    uniq = np.sort(rng.choice(lim, size=n // 2, replace=False))
    reps = rng.integers(1, max_run + 1, size=uniq.shape[0])
    return np.repeat(uniq, reps)[:n].astype(np.float64)


def _fresh(rng, existing, lo, hi, count):
    cand = np.setdiff1d(np.unique(rng.integers(lo, hi, size=8 * count)
                                  ).astype(np.float64), existing)
    assert cand.shape[0] >= count
    return cand[:count]


# -------------------------------------------------- duplicate-safe boundaries
def test_cut_never_lands_mid_duplicate_run():
    rng = np.random.default_rng(3)
    for trial in range(30):
        n = int(rng.integers(16, 400))
        keys = np.sort(rng.integers(0, n // 2 + 2, size=n).astype(np.float64))
        for s in (2, 3, 5, 8):
            if np.unique(keys).shape[0] < s:
                continue
            cuts = shard_cut_indices(keys, s)
            assert cuts[0] == 0 and np.all(np.diff(cuts) > 0)
            for c in cuts[1:]:      # every cut starts a fresh unique run
                assert keys[c - 1] != keys[c], (trial, s, c)
            bounds, splits = shard_partition(keys, s)
            assert all(sp.shape[0] > 0 for sp in splits)
            np.testing.assert_array_equal(np.concatenate(splits), keys)


def test_cut_rejects_more_shards_than_distinct_keys():
    keys = np.array([1.0, 1.0, 2.0, 2.0, 2.0, 3.0])
    with pytest.raises(ValueError, match="distinct"):
        shard_cut_indices(keys, 4)
    # 3 shards is exactly feasible: one run each
    bounds, splits = shard_partition(keys, 3)
    np.testing.assert_array_equal(bounds, [1.0, 2.0, 3.0])
    assert [s.tolist() for s in splits] == [[1, 1], [2, 2, 2], [3]]


def test_issue_example_duplicate_straddle():
    """keys=[1,2,2,3], 2 shards: query 2 must return the leftmost rank 1,
    exactly as the unsharded table does (pre-fix it returned rank 2)."""
    keys = np.array([1.0, 2.0, 2.0, 3.0])
    table = SegmentTable.from_keys(keys, 8, assume_sorted=True)
    svc = ShardedIndexService(keys, error=8, n_shards=2, assume_sorted=True)
    assert numpy_lookup(table, [2.0])[0] == 1
    assert svc.lookup([2.0])[0] == 1
    assert svc.boundaries.tolist() == [1.0, 2.0]


def test_sharded_matches_single_table_oracle_on_duplicates_all_backends():
    """Acceptance: sharded lookups == single-table numpy oracle on
    duplicate-heavy keys, before AND after rebalance(), on all five
    backends.  Includes a duplicate run far longer than the error bound
    (which Eq. 1 forces to split across segments), so the leftmost-rank
    snap is exercised, not just the shard-cut fix."""
    error = 32
    keys = np.sort(np.concatenate([_dup_heavy_keys(3000, seed=5),
                                   np.full(3 * error, 2.0 ** 19)]))
    oracle_table = SegmentTable.from_keys(keys, error, assume_sorted=True)
    rng = np.random.default_rng(6)
    q = np.concatenate([keys[rng.integers(0, keys.shape[0], 120)],
                        rng.uniform(0, 2 ** 20, size=40), [2.0 ** 19]])
    want = numpy_lookup(oracle_table, q)
    # sanity: on present duplicated keys the oracle is the leftmost rank
    present = want >= 0
    np.testing.assert_array_equal(
        want[present], np.searchsorted(keys, q[present], side="left"))

    svc = ShardedIndexService(keys, error=error, n_shards=3, buffer_size=8,
                              assume_sorted=True)
    for backend in FIVE_BACKENDS:
        np.testing.assert_array_equal(svc.lookup(q, backend), want,
                                      err_msg=f"pre-rebalance {backend}")
    info = svc.rebalance(force=True)
    assert info is not None and svc.shard_set.version == 2
    for backend in FIVE_BACKENDS:
        np.testing.assert_array_equal(svc.lookup(q, backend), want,
                                      err_msg=f"post-rebalance {backend}")


# --------------------------------------------------- tree-level splice/extract
def test_extract_splice_roundtrip_with_payloads():
    keys = np.arange(0.0, 300.0)
    pay = (keys * 7).astype(np.int64)
    donor = FITingTree(keys, error=16, payload=pay, assume_sorted=True)
    run_k, run_p = donor.extract_range(100.0, 180.0)
    np.testing.assert_array_equal(run_k, np.arange(100.0, 180.0))
    np.testing.assert_array_equal(run_p, (run_k * 7).astype(np.int64))
    assert donor.n_keys == 220
    assert donor.max_abs_error() <= donor.err_seg + 1e-6
    assert donor.lookup(150.0) is None and donor.lookup(99.0) is not None

    taker = FITingTree(np.arange(400.0, 500.0), error=16,
                       payload=np.arange(400, 500) * 7, assume_sorted=True)
    taker.splice_run(run_k, run_p)
    assert taker.n_keys == 180
    assert taker.max_abs_error() <= taker.err_seg + 1e-6
    for probe in (100.0, 179.0, 400.0, 499.0):
        hit = taker.lookup(probe)
        assert hit is not None and hit[2] == int(probe * 7), probe
    # global ranks over the merged column match searchsorted
    tab = taker.as_table()
    np.testing.assert_array_equal(
        numpy_lookup(tab, run_k), np.searchsorted(tab.keys, run_k))


def test_extract_everything_leaves_valid_empty_tree():
    t = FITingTree(np.arange(50.0), error=8, buffer_size=4, assume_sorted=True)
    out_k, out_p = t.extract_range(-np.inf, np.inf)
    assert out_k.shape[0] == 50 and out_p is None
    assert t.n_keys == 0
    assert t.lookup(3.0) is None
    assert t.lookup_batch(np.arange(5.0)).tolist() == [-1] * 5
    t.splice_run(np.array([7.0, 9.0]))        # refill via the bulk path
    t.insert(8.0)                             # and via Alg. 4
    assert t.n_keys == 3 and t.lookup(9.0) is not None
    assert t.max_abs_error() <= t.err_seg + 1e-6


def test_splice_run_payload_guards():
    clustered = FITingTree(np.arange(20.0), error=8, assume_sorted=True)
    with pytest.raises(ValueError, match="clustered"):
        clustered.splice_run(np.array([30.0]), np.array([1]))
    keyed = FITingTree(np.arange(20.0), error=8,
                       payload=np.arange(20), assume_sorted=True)
    with pytest.raises(ValueError, match="payload"):
        keyed.splice_run(np.array([30.0]))


# ----------------------------------------------------------------- rebalancing
def _skewed_service(seed=11, n=8000, n_shards=4, hot_inserts=3000, **kw):
    rng = np.random.default_rng(seed)
    base = np.sort(rng.choice(2 ** 20, size=n, replace=False)).astype(np.float64)
    svc = ShardedIndexService(base, error=64, n_shards=n_shards,
                              buffer_size=16, assume_sorted=True, **kw)
    hot = _fresh(rng, base, 0, int(svc.boundaries[1]), hot_inserts)
    return svc, base, hot


def test_rebalance_recuts_skewed_shards():
    """Acceptance: after a skewed insert stream, rebalance brings max/mean
    keys-per-shard to <= 1.5 and lookups still match the union oracle."""
    svc, base, hot = _skewed_service(skew_threshold=1.5)
    for k in hot:
        svc.insert(float(k))
    svc.publish()
    assert svc.imbalance() > 1.5 and svc.needs_rebalance()
    epochs_before = svc.epochs()
    info = svc.rebalance()
    assert info is not None and info["moved_keys"] > 0
    assert info["imbalance_after"] <= 1.5
    loads = svc.shard_loads()
    assert loads.max() / loads.mean() <= 1.5
    assert svc.shard_set.version == 2
    assert all(e > b for e, b in zip(svc.epochs(), epochs_before))
    # boundaries changed and stayed strictly sorted
    assert np.all(np.diff(svc.boundaries) > 0)
    union = np.sort(np.concatenate([base, hot]))
    rng = np.random.default_rng(12)
    q = np.concatenate([hot[::11], base[::101],
                        rng.uniform(0, 2 ** 20, size=64)])
    want = numpy_lookup(SegmentTable.from_keys(union, 64, assume_sorted=True), q)
    np.testing.assert_array_equal(svc.lookup(q), want)
    # total keys conserved by the migration
    assert sum(w.n_keys for w in svc.writers) == union.shape[0]


def test_rebalance_noop_when_balanced():
    svc, *_ = _skewed_service(hot_inserts=1)
    assert svc.imbalance() < 1.1
    assert svc.rebalance() is None
    assert svc.shard_set.version == 1
    assert svc.service_stats()["rebalances"] == 0
    assert svc.rebalance(force=True) is not None      # force recuts anyway
    assert svc.shard_set.version == 2


def test_rebalance_moves_payloads_with_keys():
    rng = np.random.default_rng(21)
    base = np.sort(rng.choice(2 ** 20, size=4000, replace=False)).astype(np.float64)
    svc = ShardedIndexService(base, error=64, n_shards=4, buffer_size=16,
                              payload=(base * 3).astype(np.int64),
                              assume_sorted=True)
    hot = _fresh(rng, base, 0, int(svc.boundaries[1]), 1500)
    for k in hot:
        svc.insert(float(k), value=int(k) * 3)
    svc.publish()
    assert svc.rebalance(force=True) is not None
    for probe in np.concatenate([hot[::97], base[::499]]):
        sid = svc.shard_of(float(probe))
        hit = svc.writers[sid].lookup(float(probe))
        assert hit is not None and hit[2] == int(probe) * 3, probe


def test_auto_rebalance_triggers_on_publish():
    svc, base, hot = _skewed_service(seed=13, skew_threshold=1.3,
                                     auto_rebalance=True, publish_every=512)
    for k in hot:
        svc.insert(float(k))
    svc.publish()
    stats = svc.service_stats()
    assert stats["rebalances"] >= 1
    assert stats["imbalance"] <= 1.3 or not svc.needs_rebalance()
    union = np.sort(np.concatenate([base, hot]))
    q = np.concatenate([hot[::13], base[::211]])
    want = numpy_lookup(SegmentTable.from_keys(union, 64, assume_sorted=True), q)
    np.testing.assert_array_equal(svc.lookup(q), want)


def test_pending_pressure_feeds_skew_detection():
    svc, base, hot = _skewed_service(seed=14, hot_inserts=600,
                                     pending_weight=4.0)
    svc_flat, *_ = _skewed_service(seed=14, hot_inserts=600, pending_weight=0.0)
    for k in hot[:400]:
        svc.insert(float(k))
        svc_flat.insert(float(k))
    # unpublished pressure counts (scaled) with pending_weight > 0 only
    assert svc.imbalance() > svc_flat.imbalance()
    assert svc.shard_loads().sum() == pytest.approx(
        svc_flat.shard_loads().sum() + 4.0 * 400)


def test_rebalance_swap_is_atomic_and_old_view_stays_consistent():
    """A pinned ShardSet must keep serving its own epoch after a rebalance:
    same handles, same snapshots, same (old) boundaries."""
    svc, base, hot = _skewed_service(seed=15)
    old = svc.shard_set
    old_snaps = [h.current() for h in old.handles]
    for k in hot:
        svc.insert(float(k))
    svc.publish(shards=[0])
    assert svc.rebalance(force=True) is not None
    new = svc.shard_set
    assert new is not old and new.version == old.version + 1
    assert new.handles is not old.handles
    # the retired view is untouched: handles still hold their old snapshots
    for d, h in enumerate(old.handles):
        if d != 0:          # shard 0 was republished into the old set above
            assert h.current() is old_snaps[d]
    # and a lookup resolved manually against the old view is self-consistent
    engines = [h.engine("numpy") for h in old.handles]
    sizes = [e.table.n_keys for e in engines]
    offsets = np.concatenate([[0], np.cumsum(sizes)[:-1]]).astype(np.int64)
    q = base[::211]
    sid = route_keys(old.boundaries, q)
    for d in np.unique(sid):
        mask = sid == d
        local = engines[d].lookup(q[mask])
        assert np.all(local >= 0)
        got = local + offsets[d]
        assert np.all(np.diff(got) > 0)


def test_stats_reports_router_cut_and_snapshot_first_key():
    rng = np.random.default_rng(16)
    base = np.sort(rng.choice(2 ** 20, size=2000, replace=False) + 1000
                   ).astype(np.float64)
    svc = ShardedIndexService(base, error=64, n_shards=2, buffer_size=8,
                              assume_sorted=True)
    s0 = svc.stats()[0]
    assert s0.boundary == base[0] == s0.snapshot_first_key
    svc.insert(5.0)                       # below every key: routes to shard 0
    svc.publish()
    s0 = svc.stats()[0]
    assert s0.boundary == base[0]         # the router cut did not move...
    assert s0.snapshot_first_key == 5.0   # ...but the served data did
    assert svc.shard_of(5.0) == 0         # and `boundary` is what routes
    assert s0.version == 1
    svc.rebalance(force=True)
    s0 = svc.stats()[0]
    assert s0.version == 2
    assert s0.boundary == 5.0 == s0.snapshot_first_key  # recut from the data


def test_rebalance_skips_when_recut_cannot_help():
    """Three giant duplicate runs, one per shard: the duplicate-safe recut of
    the skewed view reproduces the current cuts, so rebalance must not churn
    a full republish -- it skips (counted), and only force swaps."""
    keys = np.repeat(np.array([1.0, 2.0, 3.0]), 40)
    svc = ShardedIndexService(keys, error=16, n_shards=3, buffer_size=8,
                              auto_rebalance=True, skew_threshold=1.05,
                              assume_sorted=True)
    for _ in range(30):                   # skew shard 2 with duplicates of 3
        svc.insert(3.0)
    svc.publish()                         # auto check fires -> skip, no swap
    assert svc.needs_rebalance()
    assert svc.service_stats()["rebalance_skipped"] >= 1
    assert svc.shard_set.version == 1
    assert svc.rebalance() is None
    info = svc.rebalance(force=True)      # force swaps even with no movement
    assert info is not None and info["moved_keys"] == 0
    assert svc.shard_set.version == 2
    assert svc.lookup([3.0])[0] == 80     # leftmost rank of the 3.0 run


# ------------------------------------------------------------ empty-table path
def test_empty_table_every_backend_returns_absent():
    for table in (SegmentTable.empty(16),
                  SegmentTable.from_keys(np.empty(0), 16)):
        assert table.n_keys == 0 and table.n_segments == 1
        q = np.array([0.0, 1.5, 2.0 ** 20])
        np.testing.assert_array_equal(numpy_lookup(table, q), [-1, -1, -1])
        for backend in FIVE_BACKENDS:
            got = np.asarray(make_engine(table, backend).lookup(q))
            np.testing.assert_array_equal(got, [-1, -1, -1], err_msg=backend)


def test_empty_tree_supports_inserts_and_batch_lookup():
    t = FITingTree(np.empty(0), error=16, buffer_size=4)
    assert t.n_keys == 0
    assert t.lookup(1.0) is None
    assert t.lookup_batch(np.array([1.0])).tolist() == [-1]
    assert t.range_query(0.0, 10.0).shape[0] == 0
    for k in (5.0, 1.0, 9.0, 3.0, 2.0):
        t.insert(k)
    t.flush()
    assert t.n_keys == 5
    assert t.max_abs_error() <= t.err_seg + 1e-6
    np.testing.assert_array_equal(t.lookup_batch(np.array([1.0, 3.0, 9.0])),
                                  [0, 2, 4])


# --------------------------------------------- pack_shard_tables empty shards
def test_pack_empty_interior_shard_inherits_next_boundary():
    mk = lambda lo, hi: SegmentTable.from_keys(np.arange(lo, hi, dtype=float),
                                               4, assume_sorted=True)
    tables = [mk(0, 10), SegmentTable.empty(4), mk(20, 30)]
    packed = pack_shard_tables(tables)
    np.testing.assert_array_equal(packed.boundaries, [0.0, 20.0, 20.0])
    assert np.all(np.diff(packed.boundaries) >= 0)  # route_keys precondition
    # routing: a query at the inherited boundary goes to the non-empty owner
    assert int(route_keys(packed.boundaries, 20.0)) == 2
    assert int(route_keys(packed.boundaries, 5.0)) == 0
    # trailing empty shards keep +inf (never routed to)
    packed2 = pack_shard_tables([mk(0, 10), SegmentTable.empty(4)])
    assert packed2.boundaries[0] == 0.0 and np.isinf(packed2.boundaries[1])
    assert int(route_keys(packed2.boundaries, 1e12)) == 0


# ------------------------------------------------- concurrency (writer/reader)
@pytest.mark.slow
def test_reader_never_observes_half_swapped_shard_set():
    """Satellite: auto-publish (publish_every) + auto-rebalance firing
    mid-insert-stream while a reader thread hammers lookups.  Any torn
    boundaries/handles/offsets view would surface as a present key reported
    absent or as non-monototic global ranks for sorted distinct queries."""
    rng = np.random.default_rng(17)
    base = np.sort(rng.choice(2 ** 20, size=12_000, replace=False)
                   ).astype(np.float64)
    svc = ShardedIndexService(base, error=64, n_shards=4, buffer_size=32,
                              publish_every=256, auto_rebalance=True,
                              skew_threshold=1.2, assume_sorted=True)
    hot = _fresh(rng, base, 0, int(svc.boundaries[1]), 6000)
    sample = base[::37]                     # sorted, distinct, always present
    failures: list[str] = []
    done = threading.Event()

    def reader():
        while not done.is_set():
            ranks = svc.lookup(sample)
            if np.any(ranks < 0):
                failures.append(f"present key reported absent: "
                                f"{sample[ranks < 0][:4]}")
                return
            if np.any(np.diff(ranks) <= 0):
                failures.append("non-monotonic global ranks (torn view)")
                return

    def writer():
        for k in hot:
            svc.insert(float(k))
        svc.publish()

    r = threading.Thread(target=reader)
    w = threading.Thread(target=writer)
    r.start(); w.start()
    w.join(timeout=120)
    done.set()
    r.join(timeout=30)
    assert not failures, failures
    assert svc.service_stats()["rebalances"] >= 1   # the race actually ran
    union = np.sort(np.concatenate([base, hot]))
    q = np.concatenate([hot[::29], sample])
    want = numpy_lookup(SegmentTable.from_keys(union, 64, assume_sorted=True), q)
    np.testing.assert_array_equal(svc.lookup(q), want)
