"""Chunkwise-parallel mLSTM == exact sequential recurrence (all chunk splits),
including state carry-through, so prefill/decode and train see the same math."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import blocks as BL
from repro.models.blocks import Ctx


def _setup(t, seed=0):
    cfg = dataclasses.replace(reduced(get_config("xlstm-350m")), mlstm_chunk=8)
    p = BL.init_mlstm(cfg, jax.random.key(seed), dtype=jnp.float32)
    x = jax.random.normal(jax.random.key(seed + 1), (2, t, cfg.d_model),
                          jnp.float32)
    return cfg, p, x


@pytest.mark.parametrize("t", [1, 7, 8, 24, 33])
def test_chunked_matches_sequential(t):
    cfg, p, x = _setup(t)
    out_c, cache_c = BL.apply_mlstm(p, x, cfg, Ctx("prefill"))
    # sequential path: force decode-mode math over the whole sequence
    out_s, cache_s = BL.apply_mlstm(p, x, cfg, Ctx("decode"))
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_s),
                               rtol=1e-4, atol=1e-5)
    for k in ("C", "n", "m"):
        np.testing.assert_allclose(np.asarray(cache_c[k]),
                                   np.asarray(cache_s[k]),
                                   rtol=1e-4, atol=1e-5)


def test_state_carry_across_calls():
    """prefill(x1) then prefill-with-state(x2) == prefill(concat(x1,x2))."""
    cfg, p, x = _setup(32, seed=3)
    full, cache_full = BL.apply_mlstm(p, x, cfg, Ctx("prefill"))
    a, cache_a = BL.apply_mlstm(p, x[:, :20], cfg, Ctx("prefill"))
    b, cache_b = BL.apply_mlstm(p, x[:, 20:], cfg,
                                Ctx("prefill", cache=cache_a))
    np.testing.assert_allclose(np.asarray(jnp.concatenate([a, b], 1)),
                               np.asarray(full), rtol=1e-4, atol=1e-5)
    for k in ("C", "n", "m"):
        np.testing.assert_allclose(np.asarray(cache_b[k]),
                                   np.asarray(cache_full[k]),
                                   rtol=1e-4, atol=1e-5)


def test_grad_through_chunked_form():
    cfg, p, x = _setup(24, seed=5)

    def f(p):
        out, _ = BL.apply_mlstm(p, x, cfg, Ctx("train"))
        return jnp.sum(out ** 2)

    g = jax.grad(f)(p)
    assert all(np.all(np.isfinite(np.asarray(l))) for l in jax.tree.leaves(g))
