"""End-to-end dry-run machinery: one real cell lowered + compiled at the
production 512-device multi-pod mesh, in a subprocess (device-count isolation).
Uses the fastest cell (xlstm decode) to keep CI time bounded."""
import json
import pathlib
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_dryrun_cell_multipod(tmp_path):
    env = {"PYTHONPATH": str(pathlib.Path(__file__).parents[1] / "src"),
           "PATH": "/usr/bin:/bin"}
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "xlstm-350m",
         "--shape", "decode_32k", "--multi-pod", "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=pathlib.Path(__file__).parents[1])
    assert res.returncode == 0, res.stdout + res.stderr
    rec = json.loads(
        (tmp_path / "xlstm-350m__decode_32k__pod2x16x16.json").read_text())
    assert rec["status"] == "ok"
    assert rec["n_devices"] == 512
    assert rec["jaxpr_flops_global"] > 0
    assert rec["collectives"]["wire_bytes"] > 0
    assert rec["memory"]["temp_size_in_bytes"] > 0
