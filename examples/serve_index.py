"""Serving the paper's index: batched point lookups through the Pallas kernel
(interpret mode on CPU) and the XLA window/bisect paths, plus the distributed
range-partitioned variant (run under 8 fake devices to see the collectives:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python examples/serve_index.py --distributed
)"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_device_index, lookup
from repro.kernels.ops import fitting_lookup
from repro.kernels.ref import lookup_ref


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--queries", type=int, default=4096)
    ap.add_argument("--error", type=int, default=64)
    ap.add_argument("--distributed", action="store_true")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    keys = np.sort(rng.choice(2 ** 23, size=args.n, replace=False)).astype(
        np.float64)
    q = jnp.asarray(keys[rng.integers(0, args.n, args.queries)], jnp.float32)
    idx = build_device_index(keys, args.error)

    got = np.asarray(fitting_lookup(idx, q[:256], interpret=True))
    want = np.asarray(lookup_ref(idx.keys, q[:256]))
    assert np.array_equal(got, want)
    print(f"Pallas kernel == oracle on {got.shape[0]} queries "
          f"(interpret mode)")

    for name, strat in (("window", "window"), ("bisect", "bisect")):
        f = jax.jit(lambda qq, s=strat: lookup(idx, qq, s))
        f(q).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(5):
            f(q).block_until_ready()
        dt = (time.perf_counter() - t0) / 5
        print(f"  {name:7s}: {dt/args.queries*1e9:8.0f} ns/query "
              f"({args.queries} queries/batch)")

    if args.distributed:
        from repro.core.distributed import build_sharded_index, lookup_allgather
        n_dev = len(jax.devices())
        mesh = jax.make_mesh((n_dev,), ("data",))
        si = build_sharded_index(keys, args.error, n_dev, mesh, "data")
        got = np.asarray(lookup_allgather(si, q[: n_dev * 32], mesh, "data"))
        want = np.searchsorted(keys.astype(np.float32), np.asarray(q[: n_dev * 32]))
        print(f"  distributed lookup over {n_dev} devices OK "
              f"({np.mean(got == want)*100:.0f}% exact)")


if __name__ == "__main__":
    main()
