"""Serving the paper's index through the unified core (repro.index).

The SLO-driven path (Sec. 6 -- the paper's actual user contract) is three
lines; no error / shard count / threshold picked by hand:

    spec = FitSpec(latency_budget_ns=500.0)     # or storage_budget_bytes=...
    svc = open_index(keys, spec)                # cost model resolves the rest
    svc.insert(k); svc.publish(); svc.lookup(q)

``plan(keys, spec).explain()`` shows the predicted latency/size of every
candidate error before anything is built.

The async front door (``repro.index.pipeline``) wraps any service in a
coalescing queue: concurrent callers' tiny probes fuse into one fast-tier
batch (threshold-or-deadline flush, knobs resolved by the plan, engines
prewarmed so the first flush skips the compile spike), and a background
cadence thread publishes buffered inserts / runs auto-rebalance off the
request path:

    pipe = AsyncIndexService(svc)       # or open_pipeline(keys, spec)
    pipe.lookup(q)                      # sync facade over lookup_async(q)
    pipe.close()                        # drains in-flight futures

The typed query plane (``repro.index.query``) answers more than point
membership -- the clustered layout gives predecessor search, and therefore
range scans, for free:

    svc.point(qs)            # typed membership: leftmost rank + found flag
    svc.range(lo, hi)        # inclusive [lo, hi]: global rank span +
                             #   materialized keys (and payloads)
    svc.count(los, his)      # span sizes only, nothing materialized
    svc.predecessor(qs)      # rank of the largest key <= q (rightmost)
    svc.successor(qs)        # rank of the smallest key >= q (leftmost)

All five verbs derive from one per-backend ``search(queries, side)``
primitive, so every backend (and the sharded service, which stitches spans
across shards) returns identical answers.  A scan-heavy workload tells the
SLO path so: ``FitSpec(latency_budget_ns=..., range_fraction=0.3,
range_scan_rows=512)`` folds the range-scan cost term (fixed predecessor
cost + per-row scan marginal) into every candidate's predicted latency and
the dispatch-tier crossings.

An ingest-heavy workload declares itself (``FitSpec(...,
write_heavy=True, insert_rate=...)``) and ``open_index`` builds the LSM
write plane instead (``repro.index.lsm``): writes land in a bounded sorted
memtable, spill into immutable learned runs, and a background compactor
merges + re-fits off the serving path -- reads fan in across all levels by
leftmost-rank merge, so every verb keeps its exact searchsorted semantics
(duplicates, deletes via tombstones, newest-level-wins upserts) while the
service absorbs insert floods the single Alg. 4 buffer cannot:

    svc = open_index(keys, FitSpec(error=64, write_heavy=True,
                                   insert_rate=100_000))
    svc.insert_many(batch)   # vectorized; spills are automatic
    svc.delete(k); svc.upsert(k, v)
    svc.metrics().lsm        # levels, runs, spills, read amplification

The telemetry plane (``repro.index.telemetry``) closes the Sec. 6 loop:
attach a ``Monitor`` (``open_index(keys, spec, monitor=Monitor())``) and the
dispatch tiers record measured (batch, wall_ns) samples on lock-free rings;
``svc.metrics()`` returns the typed ``MetricsSnapshot`` tree (JSON
round-trip), and a ``Replanner`` re-fits the tier cost curves from the
measurements, re-plans against the served keys, and hot-swaps thresholds /
shard count / pipeline knobs when the predicted win clears its hysteresis
bar -- inside an ``AsyncIndexService`` this runs on the maintenance cadence
thread (``open_pipeline(keys, spec, replan_interval_s=5.0)``).

Everything below the SLO demo is the expert raw-knob path:

  * one `SegmentTable`, every engine backend (numpy / xla-window / xla-bisect
    / pallas / dispatch) checked against the oracle and timed;
  * the epoch write path: buffered inserts -> publish() -> atomic snapshot
    swap, after which every backend serves the new keys;
  * the sharded service: N key-partitioned writers with per-shard epoch
    streams -- insert into some shards, publish, and watch only the dirty
    shards' epochs advance while the rest keep serving their old snapshot;
  * optionally the device-sharded serving plane (``repro.index.device``;
    run under 8 fake devices to see the collectives):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python examples/serve_index.py --distributed

    ``FitSpec(..., device_count=D)`` plans ``backend="device"`` and
    ``open_index`` builds a ``DeviceShardedService``: one shard per
    device, replicated boundary router, the two-sided ``search`` run
    under ``shard_map`` (the plan's cost model picks allgather for small
    batches, bucketed all_to_all past the modeled crossover --
    ``explain()`` shows the choice), and publish delta-uploads only the
    dirty shards' device rows (clean rows keep their buffers).  The
    seed-era ``core/distributed.py`` entry points are thin wrappers over
    the same kernels.

Shard-partitioning knobs (`ShardedIndexService`):
  * ``n_shards`` (CLI ``--shards``) -- equal-count contiguous key ranges; the
    replicated boundary router (first key per shard) is the paper's structure
    recursed once.  More shards = smaller per-shard tables and finer publish
    granularity, at the cost of more snapshots to manage.
  * ``buffer_size`` -- per-segment Alg. 4 insert buffer inside each shard's
    writer; the user-visible error bound still holds (err_seg = error -
    buffer_size).
  * ``publish_every`` -- auto-publish cadence: after this many buffered
    inserts (service-wide) the dirty shards republish.  ``publish()`` is
    always safe to call unconditionally: clean shards are skipped, and a
    fully clean service is a no-op.

Rebalancing knobs (shard boundaries are NOT frozen at construction):
  * ``skew_threshold`` (CLI ``--skew-threshold``) -- max/mean keys-per-shard
    ratio above which ``rebalance()`` recuts the boundaries (duplicate-safe:
    cuts snap to unique-key run starts) and migrates key runs between the
    shard writers; 1.0 is perfectly even, 2.0 the default trigger.
  * ``pending_weight`` -- how strongly unpublished per-shard inserts count
    toward the skew metric (pressure forecast for write-hot shards).
  * ``auto_rebalance`` -- run the skew check after every ``publish()``; the
    recut swaps boundaries + serving handles atomically as one versioned
    ``ShardSet``, so concurrent lookups never mix old routing with new
    offsets.  ``service_stats()`` exposes the version + rebalance counters.

The concurrency contracts behind all of this (immutable published
snapshots, read-once pinning of the ``ShardSet``, one global lock order)
are written down in ``docs/INVARIANTS.md`` and mechanically enforced:
``python -m repro.analysis src/ --strict`` checks the source statically,
and running any of this with ``REPRO_SANITIZE=1`` turns on the runtime
sanitizer (frozen served arrays, pin tracking, lock-order watchdog).

Backend-dispatch knobs (``backend="dispatch"``, see
``repro.index.engine.DispatchEngine``):
  * ``small_max`` -- batches up to this size stay on the host (``numpy``):
    no device round trip for tiny point probes.
  * ``large_min`` -- batches at least this size take the Pallas plan/
    bucketing kernel (``pallas``); in between, the XLA bisect path wins.
  * both default to the cost-model crossings for the table's error and
    segment count (``repro.core.cost_model.dispatch_thresholds``); a plan
    pins them explicitly, and hand-set values override everything.
  * per-tier engines are overridable (``small=``/``medium=``/``large=``) and
    receive ``engine_opts[backend]`` kwargs, e.g. the Pallas bucket capacity.
"""
import argparse
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.index import SegmentTable, available_backends, make_engine, plan
from repro.kernels.ref import lookup_ref
from repro.serve import (AsyncIndexService, FitSpec, IndexService, Monitor,
                         Replanner, ServiceMetrics, ShardedIndexService,
                         open_index)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--queries", type=int, default=4096)
    ap.add_argument("--error", type=int, default=64)
    ap.add_argument("--latency-ns", type=float, default=600.0,
                    help="lookup SLO for the FitSpec demo")
    ap.add_argument("--inserts", type=int, default=2000)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--skew-threshold", type=float, default=1.5)
    ap.add_argument("--distributed", action="store_true")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    keys = np.sort(rng.choice(2 ** 23, size=args.n, replace=False)).astype(
        np.float64)

    # --- the SLO-driven path: declare the budget, let Sec. 6 pick the knobs
    spec = FitSpec(latency_budget_ns=args.latency_ns)
    resolved = plan(keys, spec)          # review it, then build from it
    print(resolved.explain())
    svc = open_index(keys, resolved)
    probe = float(keys[0]) - 1.0
    svc.insert(probe)
    svc.publish()
    assert svc.lookup(np.array([probe]))[0] == 0
    print(f"  open_index: {type(svc).__name__} serving error="
          f"{svc.plan.error} (no knob hand-picked); insert -> publish -> "
          f"lookup OK\n")

    # --- the async front door: coalescing + the background publish cadence
    # 8 concurrent callers of tiny probes fuse into threshold/deadline
    # flushes (knobs from svc.plan); a daemon thread publishes buffered
    # inserts off the request path -- nobody calls publish() below.
    with AsyncIndexService(svc, publish_interval_s=0.2) as pipe:
        mismatches = []

        def caller(seed):
            r = np.random.default_rng(seed)
            for _ in range(32):
                qs = keys[r.integers(0, args.n, int(r.integers(1, 5)))]
                if not np.array_equal(pipe.lookup(qs, timeout=30.0),
                                      svc.lookup(qs)):
                    mismatches.append(seed)

        callers = [threading.Thread(target=caller, args=(t,))
                   for t in range(8)]
        for t in callers:
            t.start()
        for t in callers:
            t.join()
        assert not mismatches, "coalesced answers diverged from the oracle"
        cadence_key = float(keys[-1]) + 3.0
        svc.insert(cadence_key)
        deadline = time.perf_counter() + 10.0
        # wait for the publish *counter*, not just snapshot visibility --
        # the snapshot installs mid-publish, before the stats update lands
        st = pipe.metrics().pipeline
        while st.publishes < 1 and time.perf_counter() < deadline:
            time.sleep(0.05)
            st = pipe.metrics().pipeline
        assert st.publishes >= 1, "cadence thread never published"
        assert pipe.lookup(np.array([cadence_key]), 30.0)[0] != -1
    print(f"  async front door: 8 callers x 32 batches -> {st.flushes} "
          f"fused flushes ({st.threshold_flushes} threshold / "
          f"{st.deadline_flushes} deadline, max fused batch "
          f"{st.max_fused_batch}); background cadence made the insert "
          f"visible with no caller publish()\n")

    # --- the typed query plane: point vs range vs count -------------------
    # a scan-heavy SLO folds the range-scan cost term into the plan
    scan_spec = FitSpec(latency_budget_ns=max(args.latency_ns, 800.0),
                        range_fraction=0.3, range_scan_rows=512)
    scan_svc = open_index(keys, scan_spec)
    lo, hi = float(keys[len(keys) // 4]), float(keys[len(keys) // 2])
    res = scan_svc.range(lo, hi)            # inclusive [lo, hi], materialized
    n_only = scan_svc.count([lo], [hi])[0]  # same span, nothing materialized
    pt = scan_svc.point(keys[:4])
    pred = scan_svc.predecessor(np.asarray([hi + 0.5]))
    assert res.count == n_only == res.keys.shape[0]
    assert pt.found.all() and pred.found[0]
    print(f"  query plane: range [{lo:.0f}, {hi:.0f}] -> "
          f"[{res.lo_rank}, {res.hi_rank}) = {res.count} keys "
          f"(count-only agrees: {n_only}); point found {pt.n_found}/4; "
          f"predecessor({hi:.0f}+0.5) = rank {pred.rank[0]}")
    shapes = scan_svc.metrics().query_counts
    print(f"  query counters: {shapes}\n")

    # --- telemetry + online re-planning: measure -> re-fit -> hot-swap ----
    # a Monitor records per-tier (batch, wall_ns) samples on the dispatch
    # hot path (lock-free ring writes, ~0.5us); metrics() returns the typed
    # snapshot tree; a Replanner re-fits the tier cost curves from the
    # measurements and hot-swaps the plan when the predicted win is real.
    mon = Monitor()
    live = open_index(keys, FitSpec(error=args.error,
                                    batch_sizes=(1, 256, 1024)),
                      monitor=mon)
    for size in (1, 8, 32, 256, 1024):      # traffic across the tiers
        for _ in range(10):
            live.lookup(keys[rng.integers(0, args.n, size)])
    m = live.metrics()
    assert ServiceMetrics.from_json(m.to_json()) == m  # dashboard-ready
    print(f"  telemetry: plan rev {m.plan_revision}, "
          f"{sum(t.calls for t in m.tiers)} dispatched calls")
    for t in m.tiers:
        fit = (f"measured curve {t.fixed_ns:.0f} + {t.per_query_ns:.1f}*b ns"
               if t.per_query_ns is not None else "too few samples to fit")
        print(f"    tier {t.tier:6s}: {t.calls} calls, "
              f"mean batch {t.mean_batch:.0f}; {fit}")
    old_sm, old_lg = live.plan.small_max, live.plan.large_min
    rp = Replanner(live, interval_s=0.01, hysteresis=0.05,
                   min_tier_samples=8)
    served = rp.replan()                    # the maintenance cadence calls
    if served is not None:                  # rp.step() for you in a pipeline
        print(f"  replanner: measured curves beat the model by "
              f"{rp.last_win:.0%} on the observed mix -> hot-swapped "
              f"thresholds ({old_sm}, {old_lg}) -> ({served.small_max}, "
              f"{served.large_min}), plan rev {served.revision} "
              f"(readers never torn)\n")
    else:
        print(f"  replanner: predicted win {rp.last_win} below the "
              f"hysteresis bar -> plan kept (no flapping)\n")

    # --- the LSM write plane: declared ingest-heavy, built tiered ---------
    lsm = open_index(keys, FitSpec(error=args.error, write_heavy=True,
                                   insert_rate=50_000))
    flood = rng.uniform(float(keys[0]), float(keys[-1]),
                        size=4 * lsm.memtable_capacity)
    lsm.insert_many(flood)                   # spills cut runs automatically
    victim = float(keys[args.n // 2])
    lsm.delete(victim)                       # tombstone shadows every level
    assert not lsm.point(victim).found
    q16 = np.sort(flood[:16])
    assert np.all(lsm.lookup(q16) >= 0)      # spilled keys stay visible
    lsm.publish()                            # maintenance tick: spill+compact
    ml = lsm.metrics().lsm
    print(f"  lsm write plane: {type(lsm).__name__}, memtable "
          f"{ml.memtable_keys}/{ml.memtable_capacity}, {ml.n_runs} runs "
          f"over {ml.n_levels} levels ({ml.spills} spills, "
          f"{ml.compactions} compactions); delete + {flood.size} inserts "
          f"served exactly, read amp {ml.read_amplification:.1f}\n")

    # --- expert raw-knob path from here down
    q = jnp.asarray(keys[rng.integers(0, args.n, args.queries)], jnp.float32)
    table = SegmentTable.from_keys(keys, args.error, assume_sorted=True)

    want = np.asarray(lookup_ref(jnp.asarray(keys, jnp.float32), q[:256]))
    for backend in available_backends():
        eng = make_engine(table, backend)
        got = np.asarray(eng.lookup(q[:256]))
        assert np.array_equal(got, want), backend
        eng.lookup(q)                       # warm the compile cache
        t0 = time.perf_counter()
        for _ in range(5):
            np.asarray(eng.lookup(q))
        dt = (time.perf_counter() - t0) / 5
        print(f"  {backend:11s}: {dt/args.queries*1e9:8.0f} ns/query "
              f"({args.queries} queries/batch, == oracle)")

    # --- write path: insert -> publish -> every backend serves the new epoch
    svc = IndexService(keys, error=args.error, buffer_size=args.error // 2,
                       backend="xla-bisect")
    fresh = np.setdiff1d(
        rng.choice(2 ** 23, size=2 * args.inserts, replace=False).astype(
            np.float64), keys)[: args.inserts]
    for k in fresh:
        svc.insert(float(k))
    assert np.all(svc.lookup(fresh[:64]) == -1), "unpublished inserts invisible"
    t0 = time.perf_counter()
    snap = svc.publish()
    dt = time.perf_counter() - t0
    assert np.all(svc.lookup(fresh[:64]) >= 0)
    print(f"  publish: epoch {snap.epoch}, {args.inserts} inserts, "
          f"{snap.n_refit} segments re-fit, {dt*1e3:.1f} ms; "
          f"serving swapped atomically")

    # --- sharded serving: per-shard epoch streams, batch-size dispatch
    sharded = ShardedIndexService(keys, args.error, n_shards=args.shards,
                                  buffer_size=args.error // 2,
                                  backend="dispatch")
    fresh2 = np.setdiff1d(
        rng.choice(2 ** 23, size=4 * args.inserts, replace=False).astype(
            np.float64), np.concatenate([keys, fresh]))
    # write only into the first and last shard (half the inserts each)
    if args.shards > 1:
        half = max(1, args.inserts // 2)
        lo_hi = np.concatenate([
            fresh2[fresh2 < sharded.boundaries[1]][:half],
            fresh2[fresh2 >= sharded.boundaries[-1]][:half]])
    else:
        lo_hi = fresh2[: args.inserts]
    for k in lo_hi:
        sharded.insert(float(k))
    t0 = time.perf_counter()
    published = sharded.publish()
    dt = time.perf_counter() - t0
    epochs = sharded.epochs()
    assert np.all(sharded.lookup(lo_hi) >= 0)
    print(f"  sharded: {args.shards} shards, {lo_hi.size} inserts into "
          f"shards {sorted(published)}; publish {dt*1e3:.1f} ms touched "
          f"only those (epochs now {epochs})")
    for s in sharded.metrics().shards:
        print(f"    shard {s.shard}: epoch {s.epoch}, {s.n_segments} segs, "
              f"{s.n_keys} keys, {s.pending_inserts} pending")

    # --- adaptive rebalancing: a write-hot range skews one shard; recut
    if args.shards > 1:
        reb = ShardedIndexService(keys, args.error, n_shards=args.shards,
                                  buffer_size=args.error // 2,
                                  skew_threshold=args.skew_threshold)
        hot_n = max(args.inserts, args.n // args.shards)  # ~2x one shard
        hot = np.setdiff1d(
            rng.uniform(reb.boundaries[0], reb.boundaries[1],
                        size=3 * hot_n).astype(np.float64), keys)[:hot_n]
        for k in hot:
            reb.insert(float(k))
        reb.publish()
        before = reb.imbalance()
        tripped = reb.needs_rebalance()  # or auto_rebalance=True at build
        t0 = time.perf_counter()
        info = reb.rebalance(force=not tripped)   # demo always recuts
        dt = time.perf_counter() - t0
        assert np.all(reb.lookup(hot[: 256]) >= 0)
        why = "threshold tripped" if tripped else "forced for the demo"
        print(f"  rebalance ({why}): imbalance {before:.2f} -> "
              f"{info['imbalance_after']:.2f}, moved {info['moved_keys']} "
              f"keys in {dt*1e3:.1f} ms; ShardSet v{reb.shard_set.version} "
              f"swapped atomically (lookups still oracle-exact)")
        for s in reb.metrics().shards:
            print(f"    shard {s.shard}: cut {s.boundary:.0f} (routes), "
                  f"snapshot starts {s.snapshot_first_key:.0f}, "
                  f"{s.n_keys} keys, epoch {s.epoch}")

    # --- the device-sharded serving plane: shard_map fan-out + delta publish
    if args.distributed:
        n_dev = len(jax.devices())
        dev_plan = plan(keys, FitSpec(error=args.error, device_count=n_dev,
                                      batch_sizes=(args.queries,),
                                      insert_rate=1000.0))
        # the exchange strategy is a cost-model choice, audited by explain()
        print("  " + next(line.strip() for line in
                          dev_plan.explain().splitlines()
                          if "device plane" in line))
        dsvc = open_index(keys, dev_plan)
        qd = np.asarray(q[: n_dev * 32], np.float64)
        got = dsvc.lookup(qd)
        want = np.searchsorted(keys.astype(np.float32), qd.astype(np.float32))
        assert np.array_equal(got, want)
        dsvc.insert(float(keys[0]) + 0.5)        # dirties exactly one shard
        dsvc.publish()
        dm = dsvc.metrics().device
        print(f"  device plane: {type(dsvc).__name__} over {dm.n_devices} "
              f"devices, exchange={dm.exchange}; lookups == oracle; "
              f"uploaded {dm.bytes_uploaded} B vs "
              f"{dm.bytes_full_equivalent} B full-equivalent "
              f"({dm.delta_publishes} delta / {dm.full_publishes} full)")
        # the seed-era kernels remain as thin wrappers over the same plane
        from repro.core.distributed import build_sharded_index, lookup_allgather
        mesh = jax.make_mesh((n_dev,), ("data",))
        si = build_sharded_index(keys, args.error, n_dev, mesh, "data")
        legacy = np.asarray(lookup_allgather(si, q[: n_dev * 32], mesh,
                                             "data"))
        print(f"  legacy distributed wrapper over {n_dev} devices OK "
              f"({np.mean(legacy == want)*100:.0f}% exact)")


if __name__ == "__main__":
    main()
