"""Continuous-batching LM serving demo: submit a stream of prompts, decode
with slot reuse, verify against sequential decode, report throughput.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import init_params
from repro.serve.batcher import ContinuousBatcher, Request
from repro.serve.paged_kv import PagedKVCache, compressed_table


def main():
    cfg = reduced(get_config("internlm2-1.8b"))
    params = init_params(cfg, jax.random.key(0), dtype=jnp.float32)
    rng = np.random.default_rng(1)

    b = ContinuousBatcher(cfg, params, n_slots=4, cache_len=128)
    n_req = 12
    for i in range(n_req):
        b.submit(Request(rid=i, max_new=16,
                         prompt=rng.integers(2, cfg.vocab,
                                             size=int(rng.integers(4, 40)))
                         .astype(np.int32)))
    t0 = time.perf_counter()
    ticks = b.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in b.completed)
    print(f"served {len(b.completed)}/{n_req} requests in {ticks} ticks, "
          f"{toks} tokens, {toks/dt:.0f} tok/s (CPU, reduced model)")

    # paged KV bookkeeping + learned block-table compression
    pool = PagedKVCache(n_pages=1024, page_size=128)
    pool.alloc_request(0)
    pool.append_token_capacity(0, 524_288 // 4)     # 500k/4 tokens
    ct = compressed_table(pool, 0)
    dense = len(pool.tables[0]) * 4
    print(f"block table: {len(pool.tables[0])} entries -> "
          f"{ct.size_bytes()} B compressed (dense {dense} B)")
    logical = np.arange(len(pool.tables[0]))
    assert np.array_equal(ct.lookup(logical), np.asarray(pool.tables[0]))
    print("compressed block-table lookups exact")


if __name__ == "__main__":
    main()
