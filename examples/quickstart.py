"""Quickstart: build a FITing-Tree, look up keys, insert, pick error via the
cost model -- the paper's full lifecycle in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (CostParams, FITingTree, choose_error_for_latency,
                        choose_error_for_space, latency_ns, learn_segments_fn,
                        shrinking_cone, size_bytes)
from repro.core.datasets import iot_like


def main():
    print("=== FITing-Tree quickstart (IoT-shaped timestamps) ===")
    keys = iot_like(500_000)
    print(f"dataset: {keys.shape[0]} sorted keys "
          f"[{keys[0]:.0f} .. {keys[-1]:.0f}]")

    # 1. segmentation at a few error thresholds (Sec. 3)
    for e in (10, 100, 1000):
        segs = shrinking_cone(keys, e)
        print(f"  error={e:5d}: {segs.n_segments:6d} segments "
              f"({segs.size_bytes()} B vs dense {keys.shape[0]*16} B)")

    # 2. the index (Sec. 4): lookups hit a +-error window, never a full scan
    tree = FITingTree(keys, error=100, buffer_size=32)
    rng = np.random.default_rng(0)
    probe = keys[rng.integers(0, keys.shape[0], size=8)]
    for k in probe[:3]:
        sid, off, _ = tree.lookup(k)
        print(f"  lookup({k:.3f}) -> segment {sid}, offset {off}")
    ranks = tree.lookup_batch(probe)
    assert np.all(keys[ranks] == probe)
    print(f"  batched lookup of {probe.shape[0]} keys OK; "
          f"index={tree.index_size_bytes()} B, {tree.n_segments} segments")

    # 3. inserts (Sec. 5): buffered, bound maintained across merges
    for k in rng.uniform(keys[0], keys[-1], size=1000):
        tree.insert(k)
    assert tree.max_abs_error() <= tree.err_seg + 1e-6
    print(f"  1000 inserts; max abs error {tree.max_abs_error():.1f} "
          f"<= err_seg {tree.err_seg}; segments now {tree.n_segments}")

    # 4. cost model (Sec. 6): pick error from an SLA
    cands = [16, 64, 256, 1024, 4096]
    fn = learn_segments_fn(keys, cands)
    p = CostParams(c_ns=100.0)
    e_lat = choose_error_for_latency(2000.0, fn, cands, p)
    e_sz = choose_error_for_space(64 * 1024, fn, cands, p)
    print(f"  2000ns SLA -> error={e_lat} "
          f"(predicted {latency_ns(e_lat, fn(e_lat), p):.0f} ns)")
    print(f"  64KB budget -> error={e_sz} "
          f"(predicted {size_bytes(e_sz, fn(e_sz), p)/1024:.1f} KB)")


if __name__ == "__main__":
    main()
