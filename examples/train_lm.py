"""End-to-end driver: train a reduced LM for a few hundred steps through the
learned-index data pipeline, with a mid-run checkpoint + restore.

    PYTHONPATH=src python examples/train_lm.py [--arch internlm2-1.8b]
                                               [--steps 300]
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    losses = train_main([
        "--arch", args.arch, "--smoke", "--steps", str(args.steps),
        "--batch", "8", "--seq", "256", "--schedule", "wsd",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100", "--resume",
    ])
    drop = losses[0] - losses[-1]
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} (drop {drop:.3f})")
    if drop <= 0.5:
        sys.exit("loss did not improve enough -- investigate")
    print("OK: training converges through the learned-index pipeline")


if __name__ == "__main__":
    main()
