"""Benchmark plumbing: timing + CSV rows + JSON result files."""
from __future__ import annotations

import csv
import json
import pathlib
import time
from typing import Iterable

OUT_DIR = pathlib.Path(__file__).parent / "out"


def _jsonable(obj):
    """numpy scalars/arrays -> plain Python (json.dumps default hook)."""
    if hasattr(obj, "item") and getattr(obj, "ndim", 0) == 0:
        return obj.item()
    if hasattr(obj, "tolist"):
        return obj.tolist()
    raise TypeError(f"not JSON serializable: {type(obj).__name__}")


def write_json(name: str, obj, path: pathlib.Path | str | None = None
               ) -> pathlib.Path:
    """Write a benchmark result object as JSON (default: out/<name>.json)."""
    if path is None:
        OUT_DIR.mkdir(exist_ok=True)
        path = OUT_DIR / f"{name}.json"
    path = pathlib.Path(path)
    path.write_text(json.dumps(obj, indent=2, sort_keys=True,
                               default=_jsonable) + "\n")
    return path


def timeit(fn, *args, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall seconds of fn(*args)."""
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def write_csv(name: str, header: list[str], rows: Iterable[tuple]):
    OUT_DIR.mkdir(exist_ok=True)
    path = OUT_DIR / f"{name}.csv"
    with path.open("w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        for r in rows:
            w.writerow(r)
    return path


def emit(bench: str, metric: str, value: float, derived: str = ""):
    """The run.py contract: ``name,us_per_call,derived`` CSV lines."""
    print(f"{bench}.{metric},{value:.4g},{derived}")
