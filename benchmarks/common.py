"""Benchmark plumbing: timing + CSV rows."""
from __future__ import annotations

import csv
import pathlib
import time
from typing import Iterable

OUT_DIR = pathlib.Path(__file__).parent / "out"


def timeit(fn, *args, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall seconds of fn(*args)."""
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def write_csv(name: str, header: list[str], rows: Iterable[tuple]):
    OUT_DIR.mkdir(exist_ok=True)
    path = OUT_DIR / f"{name}.csv"
    with path.open("w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        for r in rows:
            w.writerow(r)
    return path


def emit(bench: str, metric: str, value: float, derived: str = ""):
    """The run.py contract: ``name,us_per_call,derived`` CSV lines."""
    print(f"{bench}.{metric},{value:.4g},{derived}")
