"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (the emit() contract) and writes
full result tables to benchmarks/out/*.csv.  Roofline analysis over the
dry-run artifacts lives in benchmarks/roofline.py (needs experiments/dryrun).
"""
from __future__ import annotations

import sys
import time
import traceback

MODULES = [
    "bench_segmentation",   # Table 1
    "bench_lookup",         # Fig 6
    "bench_insert",         # Fig 7
    "bench_nonlinearity",   # Fig 8
    "bench_worstcase",      # Fig 9
    "bench_costmodel",      # Fig 10
    "bench_scalability",    # Fig 11
    "bench_fillfactor",     # Fig 12
    "bench_breakdown",      # Fig 13
    "bench_kernel",         # Pallas lookup kernel
    "bench_sharded",        # sharded serving: qps vs shards, publish latency
    "bench_range",          # query plane: scan throughput, point-vs-range
]


def main() -> None:
    print("name,value,derived")
    failures = []
    for mod_name in MODULES:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            mod.run()
            print(f"# {mod_name} done in {time.time()-t0:.1f}s",
                  file=sys.stderr, flush=True)
        except Exception:
            failures.append(mod_name)
            print(f"# {mod_name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr, flush=True)
    if failures:
        raise SystemExit(f"failed benches: {failures}")


if __name__ == "__main__":
    main()
