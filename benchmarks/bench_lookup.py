"""Fig. 6: lookup latency vs index size -- A-tree / fixed paging / full /
binary search, on the three paper-shaped datasets."""
from __future__ import annotations

import numpy as np

from repro.core import FITingTree
from repro.core.datasets import iot_like, maps_like, weblogs_like
from repro.index import make_engine

from .baselines import BinarySearch, FixedPagedIndex, FullIndex
from .common import emit, timeit, write_csv

N = 500_000
NQ = 20_000
ERRORS = [16, 64, 256, 1024, 4096, 16384]
PAGES = [16, 64, 256, 1024, 4096, 16384]


def run(n: int = N, nq: int = NQ, errors=ERRORS, pages=PAGES):
    rows = []
    rng = np.random.default_rng(0)
    for name, make in [("weblogs", weblogs_like), ("iot", iot_like),
                       ("maps", maps_like)]:
        keys = make(n)
        q = keys[rng.integers(0, n, size=nq)]

        full = FullIndex(keys)
        t = timeit(full.lookup_batch, q)
        rows.append((name, "full", 0, full.size_bytes(), t / nq * 1e9))
        bs = BinarySearch(keys)
        t = timeit(bs.lookup_batch, q)
        rows.append((name, "binary", 0, 0, t / nq * 1e9))

        for e in errors:
            tree = FITingTree(keys, error=e, assume_sorted=True)
            eng = make_engine(tree.as_table(), "numpy")  # the canonical path
            t = timeit(eng.lookup, q)
            rows.append((name, "fiting", e, tree.index_size_bytes(),
                         t / nq * 1e9))
        for p in pages:
            fx = FixedPagedIndex(keys, page_size=p)
            sub = min(nq, 2000)
            t = timeit(fx.lookup_batch, q) if p >= 256 else \
                timeit(fx.lookup_batch, q[:sub]) * (nq / sub)
            rows.append((name, "fixed", p, fx.size_bytes(), t / nq * 1e9))
    write_csv("fig6_lookup", ["dataset", "method", "param", "size_bytes",
                              "ns_per_lookup"], rows)
    # headline: space ratio at comparable latency (error=256 vs full)
    for name in ("weblogs", "iot", "maps"):
        f_lat = next(r[4] for r in rows if r[0] == name and r[1] == "full")
        f_sz = next(r[3] for r in rows if r[0] == name and r[1] == "full")
        a = [r for r in rows if r[0] == name and r[1] == "fiting"]
        ok = [r for r in a if r[4] <= 2.0 * f_lat] or a[:1]
        best = min(ok, key=lambda r: r[3])
        emit("fig6", f"{name}_space_ratio", f_sz / max(best[3], 1),
             f"atree={best[3]}B@{best[4]:.0f}ns;full={f_sz}B@{f_lat:.0f}ns")
    return rows


if __name__ == "__main__":
    run()
