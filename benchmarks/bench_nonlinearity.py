"""Fig. 8: non-linearity ratio of each dataset across error scales."""
from __future__ import annotations

from repro.core.datasets import (iot_like, maps_like, non_linearity_ratio,
                                 weblogs_like)

from .common import emit, write_csv

N = 500_000
ERRORS = [10, 100, 1000, 10_000, 100_000]


def run():
    rows = []
    for name, make in [("iot", iot_like), ("weblogs", weblogs_like),
                       ("maps", maps_like)]:
        keys = make(N)
        for e in ERRORS:
            r = non_linearity_ratio(keys, e)
            rows.append((name, e, r))
        peak = max(r for (n, _, r) in rows if n == name)
        emit("fig8", f"{name}_peak_nonlinearity", peak)
    write_csv("fig8_nonlinearity", ["dataset", "error", "ratio"], rows)
    return rows


if __name__ == "__main__":
    run()
