"""SRoofline: three roofline terms per (arch x shape) from the dry-run cells.

    compute term    = jaxpr_FLOPs / (chips x 197 TFLOP/s bf16)
    memory term     = HBM_traffic / (chips x 819 GB/s)
    collective term = collective_bytes / (chips x 50 GB/s ICI)

Sources & caveats (documented per EXPERIMENTS.md):
  * FLOPs: jaxpr-level count (launch/flops_count.py), NOT XLA cost_analysis --
    XLA counts while bodies once (verified); the jaxpr count multiplies scan
    bodies by length and includes remat recompute, so
    MODEL_FLOPS/jaxpr_FLOPs is exactly the useful-compute fraction.
  * collective bytes: post-SPMD HLO parse with while-trip multiplication
    (launch/hlo_analysis.py); already per-device.
  * HBM traffic: analytic (params/optimizer/caches/residuals reads+writes --
    formulas below); XLA's 'bytes accessed' has the same while-body
    undercount so it is recorded but not used.

MODEL_FLOPS = 6*N_active*D(tokens) for train, 2*N_active*D for inference,
plus the attention term (4*B*T*S_eff*H*hd per layer, x3 for train).

Usage: PYTHONPATH=src python -m benchmarks.roofline [--mesh pod16x16]
Writes experiments/roofline.csv + experiments/roofline.md.
"""
from __future__ import annotations

import argparse
import json
import pathlib

PEAK_FLOPS = 197e12          # bf16 / chip (v5e)
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link

DRYRUN = pathlib.Path("experiments/dryrun")


def _model_flops_and_traffic(arch: str, shape: str, chips: int,
                             temp_dev: float, arg_dev: float):
    from repro.configs import SHAPES, get_config
    from repro.models.model import active_param_count, param_count

    cfg = get_config(arch)
    s = SHAPES[shape]
    n_act = active_param_count(cfg)
    n_tot = param_count(cfg)
    b, t = s.global_batch, s.seq_len
    hd, h = cfg.hd, cfg.n_heads

    # attention effective context per block type
    def s_eff(bt, q_len, ctx_len):
        if bt in ("attn", "enc", "moe", "self+cross"):
            return (ctx_len + 1) / 2 if s.kind == "train" else ctx_len
        if bt == "local":
            return min(cfg.window, ctx_len)
        if bt == "cross":
            return cfg.memory_len
        return 0  # recurrent blocks counted via 6ND already

    attn_layers = [(bt, r) for unit, r in
                   (tuple(cfg.stacks) + tuple(cfg.encoder_stacks))
                   for bt in unit]
    if s.kind == "train":
        tokens = b * t
        mf = 6.0 * n_act * tokens
        for bt, r in attn_layers:
            mf += 12.0 * b * t * s_eff(bt, t, t) * h * hd * r
            if bt == "self+cross":
                mf += 12.0 * b * t * cfg.memory_len * h * hd * r
        # traffic: params fwd+remat+bwd reads (3x2B) + grads f32 rw (8B) +
        # adam m,v rw (16B) + param write (2B) = 32 B/param, plus layer
        # residuals (write+read, bf16)
        traffic = 32.0 * n_tot / chips
        traffic += 4.0 * tokens * cfg.d_model * cfg.n_layers * 2 / chips
    elif s.kind == "prefill":
        tokens = b * t
        mf = 2.0 * n_act * tokens
        for bt, r in attn_layers:
            mf += 4.0 * b * t * ((t + 1) / 2 if bt not in ("local", "cross")
                                 else s_eff(bt, t, t)) * h * hd * r
            if bt == "self+cross":
                mf += 4.0 * b * t * cfg.memory_len * h * hd * r
        traffic = 2.0 * n_tot / chips            # params bf16 read
        traffic += arg_dev                        # cache write ~ cache size
        traffic += 4.0 * tokens * cfg.d_model * cfg.n_layers * 2 / chips
    else:  # decode: one token against a cache of t
        tokens = b * 1
        mf = 2.0 * n_act * tokens
        for bt, r in attn_layers:
            mf += 4.0 * b * 1 * s_eff(bt, 1, t) * h * hd * r
            if bt == "self+cross":
                mf += 4.0 * b * cfg.memory_len * h * hd * r
        # params read once + full cache read (+epsilon write)
        traffic = 2.0 * n_tot / chips + arg_dev
    return mf, traffic


def analyze(mesh_name: str = "pod16x16") -> list[dict]:
    rows = []
    for f in sorted(DRYRUN.glob(f"*__{mesh_name}.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") != "ok":
            if rec.get("status") == "skipped":
                rows.append({"arch": rec["arch"], "shape": rec["shape"],
                             "status": "skipped", "reason": rec["reason"]})
            continue
        chips = rec["n_devices"]
        temp = rec["memory"].get("temp_size_in_bytes", 0)
        arg = rec["memory"].get("argument_size_in_bytes", 0)
        jaxpr_flops = rec.get("jaxpr_flops_global", 0.0)
        coll = rec["collectives"].get(
            "wire_bytes", rec["collectives"]["total_collective_bytes"])
        mf, traffic = _model_flops_and_traffic(
            rec["arch"], rec["shape"], chips, temp, arg)
        t_c = jaxpr_flops / chips / PEAK_FLOPS
        t_m = traffic / HBM_BW
        t_x = coll / ICI_BW
        dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "status": "ok",
            "chips": chips,
            "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
            "dominant": dom,
            "model_flops": mf, "jaxpr_flops": jaxpr_flops,
            "useful_frac": mf / jaxpr_flops if jaxpr_flops else 0.0,
            "roofline_frac": max(t_c, t_m, t_x) and
            (mf / chips / PEAK_FLOPS) / max(t_c, t_m, t_x),
            "temp_gb_dev": temp / 1e9, "arg_gb_dev": arg / 1e9,
            "hlo_flops_raw": rec["cost"].get("flops", 0.0),
            "coll_bytes_dev": coll,
        })
    return rows


def _advice(r: dict) -> str:
    if r["dominant"] == "collective":
        return ("shrink FSDP all-gathers: larger per-step microbatch or "
                "2D-shard fewer tensors over `data`")
    if r["dominant"] == "memory":
        if "decode" in r["shape"] or "500k" in r["shape"]:
            return ("decode is weight/KV-bandwidth bound: quantize KV or "
                    "raise batch to amortize weight reads")
        return "fuse residual writes / relax remat policy to cut HBM traffic"
    if r["useful_frac"] < 0.5:
        return "compute-bound but <50% useful: relax remat (save mlp acts)"
    return "compute-bound near roofline: kernel-level tiling is the next lever"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    args = ap.parse_args()
    rows = analyze(args.mesh)
    suffix = "" if args.mesh == "pod16x16" else f"_{args.mesh}"
    out_csv = pathlib.Path(f"experiments/roofline{suffix}.csv")
    out_md = pathlib.Path(f"experiments/roofline{suffix}.md")
    hdr = ["arch", "shape", "dominant", "compute_s", "memory_s",
           "collective_s", "useful_frac", "roofline_frac", "temp_gb_dev"]
    with out_csv.open("w") as f:
        f.write(",".join(hdr) + "\n")
        for r in rows:
            if r["status"] != "ok":
                continue
            f.write(",".join(str(round(r[k], 6)) if isinstance(r[k], float)
                             else str(r[k]) for k in hdr) + "\n")
    lines = [f"# Roofline ({args.mesh}, v5e constants: 197TF bf16 / "
             f"819GB/s HBM / 50GB/s ICI)\n",
             "| arch | shape | compute (s) | memory (s) | collective (s) | "
             "dominant | useful frac | roofline frac | what would move it |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                         f"skipped | - | - | {r['reason'][:60]}... |")
        else:
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
                f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
                f"**{r['dominant']}** | {r['useful_frac']:.2f} | "
                f"{r['roofline_frac']:.2f} | {_advice(r)} |")
    out_md.write_text("\n".join(lines) + "\n")
    print(out_md.read_text())


if __name__ == "__main__":
    main()
