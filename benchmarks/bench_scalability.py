"""Fig. 11: lookup latency vs dataset scale (error = page = 100, like paper).

Resurrected off the seed-era ``FITingTree`` class onto the served plane:
the FITing-Tree row is an ``IndexService`` (the same construction every
other modern bench and the examples use), with the index size read from
the served snapshot's ``SegmentTable``.  Baselines are unchanged, so the
CSV keeps the seed's Fig. 11 shape (scale, method, ns/lookup, bytes).
"""
from __future__ import annotations

import numpy as np

from repro.core.datasets import weblogs_like
from repro.serve import IndexService

from .baselines import BinarySearch, FixedPagedIndex, FullIndex
from .common import emit, timeit, write_csv

NQ = 10_000
SCALES = (1, 2, 4, 8)
BASE = 125_000
ERROR = 100


def run(base: int = BASE, n_queries: int = NQ,
        scales: tuple[int, ...] = SCALES, error: int = ERROR):
    rows = []
    rng = np.random.default_rng(3)
    for s in scales:
        n = base * s
        keys = weblogs_like(n, days=365 * s)
        q = keys[rng.integers(0, n, size=n_queries)]
        svc = IndexService(keys, error, assume_sorted=True)
        size = svc.handle.current().table.size_bytes()
        fx = FixedPagedIndex(keys, page_size=error)
        rows.append((s, "fiting", timeit(svc.lookup, q) / n_queries * 1e9,
                     size))
        rows.append((s, "full", timeit(FullIndex(keys).lookup_batch, q)
                     / n_queries * 1e9, n * 16))
        rows.append((s, "binary", timeit(BinarySearch(keys).lookup_batch, q)
                     / n_queries * 1e9, 0))
        sub = max(1, n_queries // 5)
        t = timeit(fx.lookup_batch, q[:sub]) * (n_queries / sub)
        rows.append((s, "fixed", t / n_queries * 1e9, fx.size_bytes()))
    write_csv("fig11_scalability", ["scale", "method", "ns_per_lookup",
                                    "size_bytes"], rows)
    small = next(r[2] for r in rows if r[0] == scales[0] and r[1] == "fiting")
    big = next(r[2] for r in rows if r[0] == scales[-1] and r[1] == "fiting")
    emit("fig11", "latency_growth_1_to_8x", big / small)
    return rows


if __name__ == "__main__":
    run()
