"""Fig. 11: lookup latency vs dataset scale (error = page = 100, like paper)."""
from __future__ import annotations

import numpy as np

from repro.core import FITingTree
from repro.core.datasets import weblogs_like

from .baselines import BinarySearch, FixedPagedIndex, FullIndex
from .common import emit, timeit, write_csv

NQ = 10_000
SCALES = [1, 2, 4, 8]
BASE = 125_000


def run():
    rows = []
    rng = np.random.default_rng(3)
    for s in SCALES:
        n = BASE * s
        keys = weblogs_like(n, days=365 * s)
        q = keys[rng.integers(0, n, size=NQ)]
        tree = FITingTree(keys, error=100, assume_sorted=True)
        fx = FixedPagedIndex(keys, page_size=100)
        rows.append((s, "fiting", timeit(tree.lookup_batch, q) / NQ * 1e9,
                     tree.index_size_bytes()))
        rows.append((s, "full", timeit(FullIndex(keys).lookup_batch, q)
                     / NQ * 1e9, n * 16))
        rows.append((s, "binary", timeit(BinarySearch(keys).lookup_batch, q)
                     / NQ * 1e9, 0))
        t = timeit(fx.lookup_batch, q[:2000]) * (NQ / 2000)
        rows.append((s, "fixed", t / NQ * 1e9, fx.size_bytes()))
    write_csv("fig11_scalability", ["scale", "method", "ns_per_lookup",
                                    "size_bytes"], rows)
    small = next(r[2] for r in rows if r[0] == 1 and r[1] == "fiting")
    big = next(r[2] for r in rows if r[0] == 8 and r[1] == "fiting")
    emit("fig11", "latency_growth_1_to_8x", big / small)
    return rows


if __name__ == "__main__":
    run()
