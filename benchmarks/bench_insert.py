"""Fig. 7: insert throughput vs error threshold (buffer = error/2, Sec. 7.1.3)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import FITingTree
from repro.core.datasets import iot_like, weblogs_like
from repro.index import SnapshotPublisher

from .baselines import FixedPagedIndex
from .common import emit, write_csv

N = 200_000
N_INS = 20_000
ERRORS = [64, 256, 1024, 4096]


def run(n: int = N, n_ins: int = N_INS, errors=ERRORS):
    rows = []
    publish_rows = []
    rng = np.random.default_rng(1)
    for name, make in [("weblogs", weblogs_like), ("iot", iot_like)]:
        keys = make(n)
        lo, hi = keys[0], keys[-1]
        new = rng.uniform(lo, hi, size=n_ins)
        for e in errors:
            tree = FITingTree(keys, error=e, buffer_size=e // 2,
                              assume_sorted=True)
            t0 = time.perf_counter()
            for k in new:
                tree.insert(k)
            dt = time.perf_counter() - t0
            rows.append((name, "fiting", e, n_ins / dt))
            # epoch publish cost: dirty-segment flush + snapshot assembly
            pub = SnapshotPublisher(tree)
            t0 = time.perf_counter()
            snap = pub.publish()
            publish_rows.append((name, e, snap.n_refit,
                                 (time.perf_counter() - t0) * 1e3))
            fx = FixedPagedIndex(keys, page_size=e, buffer_size=e // 2)
            t0 = time.perf_counter()
            for k in new:
                fx.insert(k)
            dt = time.perf_counter() - t0
            rows.append((name, "fixed", e, n_ins / dt))
        e_head = 1024 if 1024 in errors else errors[-1]
        emit("fig7", f"{name}_inserts_per_s_e{e_head}",
             next(r[3] for r in rows if r[0] == name and r[1] == "fiting"
                  and r[2] == e_head))
    write_csv("fig7_insert", ["dataset", "method", "error", "inserts_per_s"],
              rows)
    write_csv("fig7_publish", ["dataset", "error", "segments_refit",
                               "publish_ms"], publish_rows)
    return rows


if __name__ == "__main__":
    run()
