"""Fig. 12: insert throughput vs per-segment buffer size (error fixed)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import FITingTree
from repro.core.datasets import weblogs_like

from .common import emit, write_csv

N = 200_000
N_INS = 20_000
ERROR = 2000
BUFFERS = [16, 64, 256, 1024]


def run():
    keys = weblogs_like(N)
    rng = np.random.default_rng(4)
    new = rng.uniform(keys[0], keys[-1], size=N_INS)
    rows = []
    for b in BUFFERS:
        tree = FITingTree(keys, error=ERROR, buffer_size=b, assume_sorted=True)
        t0 = time.perf_counter()
        for k in new:
            tree.insert(k)
        dt = time.perf_counter() - t0
        rows.append((b, N_INS / dt))
    write_csv("fig12_fillfactor", ["buffer_size", "inserts_per_s"], rows)
    emit("fig12", "throughput_gain_16_to_1024", rows[-1][1] / rows[0][1])
    return rows


if __name__ == "__main__":
    run()
