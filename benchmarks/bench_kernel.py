"""Pallas fitting_lookup kernel: correctness vs oracle + device-path timing
(XLA window/bisect strategies; interpret-mode kernel checked for equality,
its wall-clock is not meaningful on CPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_device_index, lookup
from repro.kernels.ops import fitting_lookup, make_plan
from repro.kernels.ref import lookup_ref

from .common import emit, timeit, write_csv

N = 100_000
NQ = 4096


def run():
    rng = np.random.default_rng(6)
    keys = np.sort(rng.choice(2 ** 23, size=N, replace=False)).astype(np.float64)
    q = jnp.asarray(keys[rng.integers(0, N, size=NQ)], jnp.float32)
    rows = []
    for e in (16, 64, 256):
        idx = build_device_index(keys, e)
        got = np.asarray(fitting_lookup(idx, q[:512], interpret=True))
        want = np.asarray(lookup_ref(idx.keys, q[:512]))
        assert np.array_equal(got, want), "kernel != oracle"
        f_win = jax.jit(lambda qq, i=idx: lookup(i, qq, "window"))
        f_bis = jax.jit(lambda qq, i=idx: lookup(i, qq, "bisect"))
        f_ref = jax.jit(lambda qq, i=idx: lookup_ref(i.keys, qq))
        t_win = timeit(lambda: f_win(q).block_until_ready()) / NQ * 1e9
        t_bis = timeit(lambda: f_bis(q).block_until_ready()) / NQ * 1e9
        t_ref = timeit(lambda: f_ref(q).block_until_ready()) / NQ * 1e9
        plan = make_plan(N, e)
        hbm_bytes = plan.window * 4  # per query window DMA on TPU
        rows.append((e, t_win, t_bis, t_ref, plan.kb, hbm_bytes))
        emit("kernel", f"window_ns_e{e}", t_win,
             f"bisect={t_bis:.0f}ns;full_searchsorted={t_ref:.0f}ns")
    write_csv("kernel_lookup", ["error", "window_ns", "bisect_ns",
                                "searchsorted_ns", "kb", "hbm_bytes_per_q"],
              rows)
    return rows


if __name__ == "__main__":
    run()
