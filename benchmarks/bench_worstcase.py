"""Fig. 9: adversarial step data -- index size cliff at error == step size."""
from __future__ import annotations

from repro.core import FITingTree
from repro.core.datasets import step_data

from .baselines import FixedPagedIndex, FullIndex
from .common import emit, write_csv

N = 200_000
STEP = 100
ERRORS = [10, 25, 50, 75, 99, 101, 150, 200, 400]


def run():
    keys = step_data(n=N, step=STEP)
    rows = []
    full = FullIndex(keys)
    rows.append(("full", 0, full.size_bytes()))
    for e in ERRORS:
        tree = FITingTree(keys, error=e, assume_sorted=True)
        fx = FixedPagedIndex(keys, page_size=max(e, 2))
        rows.append(("fiting", e, tree.index_size_bytes()))
        rows.append(("fixed", e, fx.size_bytes()))
    write_csv("fig9_worstcase", ["method", "error", "size_bytes"], rows)
    # cliff at error ~= step (paper Fig. 9b): segments anchor at their first
    # point, so spanning a step's 100-position jump needs error >= step-1
    below = next(r[2] for r in rows if r[0] == "fiting" and r[1] == 75)
    above = next(r[2] for r in rows if r[0] == "fiting" and r[1] == 101)
    emit("fig9", "size_cliff_ratio", below / max(above, 1),
         f"e75={below}B;e101={above}B")
    return rows


if __name__ == "__main__":
    run()
