"""Table 1: ShrinkingCone vs optimal DP segment counts across datasets/errors,
plus the beyond-paper clamped-cone mode (EXPERIMENTS.md SPerf).

Paper ran n=1e6 on 768GB RAM; we run n=20k on this container (DESIGN.md Sec. 8)
-- the ratio is the reproduction target (paper: 1.05-1.6)."""
from __future__ import annotations

import time

from repro.core import optimal_segmentation, shrinking_cone
from repro.core.datasets import (iot_like, lognormal_keys, maps_like,
                                 weblogs_like)

from .common import emit, write_csv

N = 20_000
DATASETS = [("iot", iot_like), ("weblogs", weblogs_like), ("maps", maps_like),
            ("lognormal", lognormal_keys)]
ERRORS = [10, 100]


def run():
    rows = []
    for name, make in DATASETS:
        keys = make(N)
        for err in ERRORS:
            t0 = time.perf_counter()
            greedy = shrinking_cone(keys, err).n_segments
            t_greedy = time.perf_counter() - t0
            clamped = shrinking_cone(keys, err, mode="clamped").n_segments
            t0 = time.perf_counter()
            opt = optimal_segmentation(keys, err)
            t_opt = time.perf_counter() - t0
            ratio = greedy / max(opt, 1)
            rows.append((name, err, greedy, clamped, opt, round(ratio, 3),
                         round(clamped / max(opt, 1), 3),
                         round(t_greedy * 1e3, 1), round(t_opt * 1e3, 1)))
            emit("table1", f"{name}_e{err}_ratio", ratio,
                 f"greedy={greedy};clamped={clamped};opt={opt}")
    write_csv("table1_segmentation", ["dataset", "error", "shrinking_cone",
                                      "clamped", "optimal", "ratio",
                                      "clamped_ratio", "greedy_ms",
                                      "optimal_ms"], rows)
    return rows


if __name__ == "__main__":
    run()
