"""LSM tiered write plane: sustained ingest vs the single-buffer path.

Measures the claim ``repro.index.lsm`` makes: the memtable -> run -> compaction
write plane absorbs an insert flood the single Alg. 4 buffer cannot, without
stalling concurrent readers -- spills cut immutable runs off the write path
and compaction merges them behind the atomic ``LevelSet`` swap, so readers
never wait on ingest.

Method, four phases on the same key distribution:

1. **Single-buffer sustainable rate**: closed-loop per-key inserts into an
   ``IndexService`` with a small Alg. 4 buffer and a publish cadence, so the
   measured rate honestly pays the periodic O(n) merge-and-refit.  This rate
   defines the flood target ``target = rate_factor x single_rate``.
2. **LSM baseline read p99**: the LSM service is warmed into its flood
   steady state (the same paced writer, briefly, with the background
   compactor live), then read p99 is measured with no concurrent writer --
   the read-only baseline over a representative leveled structure.  Read
   amplification is the LSM design's *known* cost and is reported as its own
   metric; the p99 budget tests what the subsystem actually claims, that
   concurrent ingest does not stall readers.
3. **Flood**: a writer thread paces ``insert_many`` chunks at ``target`` for
   ``flood_s`` seconds while a reader thread measures batch-lookup p99; the
   background compactor is live.  Phases 2-3 run on a fresh service per
   attempt, best of up to ``MAX_P99_ATTEMPTS``: ambient scheduler noise on a
   shared runner inflates an idle p99 estimate ~3x on occasion, so one noisy
   pass must not fail the bench -- a real regression reproduces on every
   attempt.  The same flood is then aimed at the single-buffer service.
4. **Correctness epilogue**: a mixed delete/upsert tail, then every verb is
   checked bit-for-bit against the ``np.searchsorted`` oracle over the
   surviving multiset -- both in the multi-run state and again after
   compaction drains the levels.

p99 is estimated as the median of per-window p99s (``P99_WINDOWS`` contiguous
windows over the measurement span), which keeps a single scheduler hiccup in
one window from defining the whole run's tail.

Asserted in-bench (the artifact fails loudly if the subsystem regresses):

* the LSM service sustains the flood: achieved ingest >= 0.95 x target,
  i.e. >= ~``rate_factor``x the single-buffer sustainable rate;
* concurrent read p99 under flood <= ``p99_budget`` x the read-only LSM
  baseline p99;
* the single-buffer path visibly degrades at the same target: achieved
  ingest < 0.5 x target *or* flooded p99 >= 1.5 x its own baseline;
* all verbs equal the oracle before and after compaction.

Results land in ``out/bench_lsm.json`` plus the usual ``emit`` lines.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from repro.core.datasets import weblogs_like
from repro.index.lsm import LsmIndexService
from repro.serve import IndexService

from .common import emit, write_json

N = 200_000
ERROR = 64
N_SINGLE_INSERTS = 4_000
READ_BATCH = 64
N_READ_BATCHES = 400
FLOOD_S = 1.5
RATE_FACTOR = 4.0
P99_BUDGET = 2.0
BUFFER_SIZE = 32              # must stay < error (Sec. 5 Alg. 4 bound)
PUBLISH_EVERY = 128
MEMTABLE_CAPACITY = 4_096
LEVEL_FANOUT = 4
WRITE_CHUNK_S = 0.01          # writer pacing quantum (chunk every ~10 ms)
P99_WINDOWS = 5               # median-of-windows p99 (noise-robust tail)
MAX_P99_ATTEMPTS = 3          # fresh warm+flood passes before failing p99


def _read_batches(rng: np.random.Generator, domain: np.ndarray,
                  batch: int, count: int) -> np.ndarray:
    """(count, batch) lookup batches: half present keys, half uniform."""
    lo, hi = float(domain[0]), float(domain[-1])
    hit = domain[rng.integers(0, domain.size, size=(count, batch))]
    miss = rng.uniform(lo, hi, size=(count, batch))
    take_hit = rng.random((count, batch)) < 0.5
    return np.where(take_hit, hit, miss)


def _window_p99(lat_s: list[float], windows: int = P99_WINDOWS) -> float:
    """Median of per-window p99s, in microseconds: one scheduler hiccup
    inflates one window, not the whole run's tail estimate."""
    lat_us = np.asarray(lat_s, np.float64) * 1e6
    return float(np.median([np.percentile(w, 99)
                            for w in np.array_split(lat_us, windows)]))


def _read_loop(svc, batches: np.ndarray, min_duration_s: float
               ) -> list[float]:
    """Per-batch ``lookup`` wall latencies, cycling the batch set until at
    least ``min_duration_s`` has elapsed (so a reader spans a whole flood)."""
    lat: list[float] = []
    t_start = time.perf_counter()
    i = 0
    while (time.perf_counter() - t_start < min_duration_s
           or len(lat) < batches.shape[0]):
        q = batches[i % batches.shape[0]]
        i += 1
        t0 = time.perf_counter()
        svc.lookup(q)
        lat.append(time.perf_counter() - t0)
    return lat


class _PacedWriter:
    """Writer thread feeding ``ingest(chunk)`` at ``rate`` keys/s in
    ``rate * WRITE_CHUNK_S`` chunks; records what it actually achieved."""

    def __init__(self, ingest, rng: np.random.Generator, lo: float, hi: float,
                 rate: float, duration_s: float):
        self.chunks: list[np.ndarray] = []
        self.achieved = 0.0
        self._thread = threading.Thread(
            target=self._loop, args=(ingest, rng, lo, hi, rate, duration_s),
            daemon=True)

    def _loop(self, ingest, rng, lo, hi, rate, duration_s):
        chunk = max(64, int(rate * WRITE_CHUNK_S))
        sent = 0
        t0 = time.perf_counter()
        while True:
            elapsed = time.perf_counter() - t0
            if elapsed >= duration_s:
                break
            if sent > elapsed * rate:          # ahead of schedule: hold pace
                time.sleep(WRITE_CHUNK_S / 4)
                continue
            keys = rng.uniform(lo, hi, size=chunk)
            ingest(keys)
            self.chunks.append(keys)
            sent += chunk
        self.achieved = sent / (time.perf_counter() - t0)

    def start(self):
        self._thread.start()

    def join(self):
        self._thread.join()


def _oracle_check(svc: LsmIndexService, oracle: np.ndarray,
                  probes: np.ndarray) -> None:
    """Every verb bit-identical to searchsorted over the live multiset."""
    assert svc.n_live_keys() == oracle.size
    for side in ("left", "right"):
        want = np.searchsorted(oracle, probes, side=side)
        got = svc.search(probes, side)
        assert np.array_equal(got, want), f"search({side}) diverged"
    for q in probes[:32]:
        l = int(np.searchsorted(oracle, q, "left"))
        r = int(np.searchsorted(oracle, q, "right"))
        p = svc.point(float(q))
        assert p.found == (r > l)
        assert p.rank == (l if p.found else -1)
    lo, hi = float(np.percentile(probes, 25)), float(np.percentile(probes, 75))
    assert int(svc.count(lo, hi)) == int(
        np.searchsorted(oracle, hi, "right") - np.searchsorted(oracle, lo,
                                                               "left"))
    rr = svc.range(lo, hi)
    assert np.array_equal(
        rr.keys, oracle[np.searchsorted(oracle, lo, "left"):
                        np.searchsorted(oracle, hi, "right")])
    mid = float(np.median(probes))
    assert svc.predecessor(mid).rank == int(
        np.searchsorted(oracle, mid, "right")) - 1
    assert svc.successor(mid).rank == int(np.searchsorted(oracle, mid,
                                                          "left"))


def run(n: int = N, error: int = ERROR,
        n_single_inserts: int = N_SINGLE_INSERTS,
        read_batch: int = READ_BATCH, n_read_batches: int = N_READ_BATCHES,
        flood_s: float = FLOOD_S, rate_factor: float = RATE_FACTOR,
        p99_budget: float = P99_BUDGET, buffer_size: int = BUFFER_SIZE,
        publish_every: int = PUBLISH_EVERY,
        memtable_capacity: int = MEMTABLE_CAPACITY,
        level_fanout: int = LEVEL_FANOUT, backend: str = "numpy",
        seed: int = 0):
    rng = np.random.default_rng(seed)
    base = np.sort(weblogs_like(n))
    lo, hi = float(base[0]), float(base[-1])
    results: dict = {"config": {
        "n": n, "error": error, "n_single_inserts": n_single_inserts,
        "read_batch": read_batch, "n_read_batches": n_read_batches,
        "flood_s": flood_s, "rate_factor": rate_factor,
        "p99_budget": p99_budget, "buffer_size": buffer_size,
        "publish_every": publish_every,
        "memtable_capacity": memtable_capacity,
        "level_fanout": level_fanout, "backend": backend}}

    # -- 1. single-buffer sustainable rate (closed loop, publishes paid) ----
    single = IndexService(base, error=error, buffer_size=buffer_size,
                          publish_every=publish_every, backend=backend,
                          assume_sorted=True)
    ins = rng.uniform(lo, hi, size=n_single_inserts)
    single.lookup(base[:read_batch])           # warm engines off the clock
    t0 = time.perf_counter()
    for k in ins:
        single.insert(float(k))
    single.publish()
    single_rate = n_single_inserts / (time.perf_counter() - t0)
    target = rate_factor * single_rate
    results["single_rate_keys_s"] = single_rate
    results["target_rate_keys_s"] = target

    batches = _read_batches(rng, base, read_batch, n_read_batches)
    single_base_p99 = _window_p99(_read_loop(single, batches, flood_s))

    # -- 2 + 3a. LSM warm + flood, fresh service per attempt ---------------
    # Best of up to MAX_P99_ATTEMPTS: either one pass meets both the ingest
    # and the p99 budget (the subsystem CAN serve the flood within budget,
    # which is the claim) or the regression reproduces on every attempt.
    lsm = None
    trials: list[dict] = []
    try:
        for attempt in range(MAX_P99_ATTEMPTS):
            if lsm is not None:
                lsm.close()
            lsm = LsmIndexService(base, error=error, assume_sorted=True,
                                  memtable_capacity=memtable_capacity,
                                  level_fanout=level_fanout, backend=backend,
                                  background_compaction=True)
            warmer = _PacedWriter(lsm.insert_many,
                                  np.random.default_rng(seed + 3
                                                        + 10 * attempt),
                                  lo, hi, target, 0.7 * flood_s)
            warmer.start()
            warmer.join()
            lsm.prewarm()
            lsm_base_p99 = _window_p99(_read_loop(lsm, batches, flood_s))

            writer = _PacedWriter(lsm.insert_many,
                                  np.random.default_rng(seed + 1
                                                        + 10 * attempt),
                                  lo, hi, target, flood_s)
            writer.start()
            lsm_flood_p99 = _window_p99(_read_loop(lsm, batches, flood_s))
            writer.join()
            lsm_achieved = writer.achieved
            trials.append({"baseline_p99_us": lsm_base_p99,
                           "flood_p99_us": lsm_flood_p99,
                           "achieved_keys_s": lsm_achieved})
            if (lsm_achieved >= 0.95 * target
                    and lsm_flood_p99 <= p99_budget * lsm_base_p99):
                break
        flood_chunks = writer.chunks
        m = lsm.metrics()
        results["lsm"] = {
            "baseline_p99_us": lsm_base_p99,
            "flood_p99_us": lsm_flood_p99,
            "achieved_keys_s": lsm_achieved,
            "attempts": trials,
            "spills": m.lsm.spills, "compactions": m.lsm.compactions,
            "n_runs_after": m.lsm.n_runs,
            "read_amplification": m.lsm.read_amplification,
        }

        # -- 3b. the same flood against the single-buffer path --------------
        def single_ingest(keys):
            for k in keys:
                single.insert(float(k))

        writer = _PacedWriter(single_ingest, np.random.default_rng(seed + 2),
                              lo, hi, target, flood_s)
        writer.start()
        single_flood_p99 = _window_p99(_read_loop(single, batches, flood_s))
        writer.join()
        single_achieved = writer.achieved
        results["single"] = {
            "baseline_p99_us": single_base_p99,
            "flood_p99_us": single_flood_p99,
            "achieved_keys_s": single_achieved,
        }

        # -- 4. correctness epilogue: mixed tail, then oracle equality ------
        oracle_parts = [base] + warmer.chunks + flood_chunks
        victims = base[rng.integers(0, base.size, size=32)]
        for k in victims:
            lsm.delete(float(k))
        upserted = rng.uniform(lo, hi, size=16)
        for k in upserted:
            lsm.upsert(float(k))
        live = np.concatenate(oracle_parts)
        live = live[~np.isin(live, victims)]
        live = live[~np.isin(live, upserted)]
        oracle = np.sort(np.concatenate([live, upserted]))
        probes = np.concatenate([oracle[rng.integers(0, oracle.size, 256)],
                                 rng.uniform(lo, hi, size=256)])
        _oracle_check(lsm, oracle, probes)      # multi-run, live memtable
        lsm.spill()
        while lsm.compact(max_steps=8):         # drain to the compacted floor
            pass
        _oracle_check(lsm, oracle, probes)      # post-compaction
        results["oracle_keys"] = int(oracle.size)
    finally:
        if lsm is not None:
            lsm.close()

    # -- assertions: the claims this subsystem exists to make ---------------
    sustains = lsm_achieved >= 0.95 * target
    p99_held = lsm_flood_p99 <= p99_budget * lsm_base_p99
    single_degrades = (single_achieved < 0.5 * target
                       or single_flood_p99 >= 1.5 * single_base_p99)
    results["assertions"] = {
        "lsm_sustains_target_ingest": bool(sustains),
        "lsm_flood_p99_within_budget": bool(p99_held),
        "single_buffer_degrades": bool(single_degrades),
        "verbs_match_oracle": True,             # _oracle_check already raised
    }
    assert sustains, (
        f"LSM ingest {lsm_achieved:.0f}/s < 0.95x target {target:.0f}/s "
        f"(= {rate_factor}x single-buffer {single_rate:.0f}/s)")
    assert p99_held, (
        f"LSM flood p99 {lsm_flood_p99:.0f}us > {p99_budget}x read-only "
        f"baseline {lsm_base_p99:.0f}us on all {len(trials)} attempts")
    assert single_degrades, (
        f"single-buffer path kept up at {rate_factor}x its own rate "
        f"({single_achieved:.0f}/s of {target:.0f}/s, p99 "
        f"{single_flood_p99:.0f}us vs {single_base_p99:.0f}us) -- the LSM "
        f"plane's advantage did not reproduce")

    emit("lsm", "single_rate_keys_s", single_rate, f"backend={backend}")
    emit("lsm", "lsm_achieved_keys_s", lsm_achieved,
         f"target={target:.0f}")
    emit("lsm", "lsm_flood_p99_us", lsm_flood_p99,
         f"baseline={lsm_base_p99:.1f}")
    emit("lsm", "single_flood_p99_us", single_flood_p99,
         f"baseline={single_base_p99:.1f}")
    write_json("bench_lsm", results)
    return results


if __name__ == "__main__":
    run()
