"""Async serving pipeline: open-loop arrival benchmark, coalescing on vs off.

Measures the claim ``repro.index.pipeline`` makes: many small concurrent
callers sustain far higher throughput when their point lookups are coalesced
into one fast-tier fused batch than when each caller pays its own service
call (Sec. 6: per-query cost collapses once the batch crosses the dispatch
threshold).

Method: an **open-loop** generator pre-schedules exponential inter-arrivals
at a fixed rate (so the load never slows down when the server falls behind),
then drives the same ``IndexService`` two ways --

* coalescing **off**: a worker pool, every request is its own
  ``svc.lookup`` call (direct per-caller dispatch);
* coalescing **on**: one submitter feeds ``AsyncIndexService.lookup_async``
  and the pipeline's flusher fuses queued requests into threshold/deadline
  batches.

Latency is ``completion - scheduled arrival`` (queueing delay included), so
a saturated server shows its backlog honestly.  Arrival rates are expressed
as multiples of the *measured* direct per-call capacity of this machine,
which makes the saturation structure machine-independent: at the top rate
the direct path is over capacity by construction while the coalescing path
rides the fused-batch cost curve.

Every driven result is compared bit-for-bit against the single-thread
oracle (``svc.lookup`` over all queries at once), and a second section
measures the first-flush latency spike with and without
``prewarm`` (eager tier-engine build + compile at the flush bucket).

Results land in ``out/bench_serving.json`` plus the usual ``emit`` lines.
"""
from __future__ import annotations

import itertools
import threading
import time

import numpy as np

from repro.core.datasets import weblogs_like
from repro.serve import AsyncIndexService, IndexService

from .common import emit, write_json

N = 200_000
ERROR = 64
N_REQUESTS = 6_000
RATE_FACTORS = (0.25, 1.0, 4.0)          # x measured direct per-call capacity
MAX_WAIT_US_SWEEP = (100.0, 500.0, 2000.0)
OFF_WORKERS = 8
FLUSH_THRESHOLD = 256
PREWARM_FLUSH = 512
CALIBRATION_CALLS = 512


def _percentiles(lat_s: np.ndarray) -> dict:
    lat_us = np.asarray(lat_s, np.float64) * 1e6
    return {"p50_us": float(np.percentile(lat_us, 50)),
            "p99_us": float(np.percentile(lat_us, 99))}


def _schedule(rng: np.random.Generator, rate: float, n: int) -> np.ndarray:
    """Open-loop arrival offsets: exponential inter-arrivals at ``rate``/s,
    fixed before the run starts so backlog never throttles the generator."""
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def _drive_direct(svc, queries, sched, n_workers: int):
    """Coalescing OFF: every request is its own synchronous service call."""
    n = len(queries)
    counter = itertools.count()
    finish = np.zeros(n)
    results: list = [None] * n
    t0 = time.perf_counter()

    def worker():
        while True:
            i = next(counter)
            if i >= n:
                return
            delay = t0 + sched[i] - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            results[i] = svc.lookup(queries[i])
            finish[i] = time.perf_counter()

    threads = [threading.Thread(target=worker) for _ in range(n_workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    latency = finish - (t0 + sched)
    return n / (finish.max() - t0), latency, results


def _drive_pipeline(pipe, queries, sched):
    """Coalescing ON: open-loop submitter; completions land via callbacks."""
    n = len(queries)
    finish = np.zeros(n)
    results: list = [None] * n
    futs = [None] * n

    def _done(fut, i):
        # runs on the flusher thread right after the scatter
        finish[i] = time.perf_counter()
        results[i] = fut.result()

    t0 = time.perf_counter()
    for i in range(n):
        delay = t0 + sched[i] - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        fut = pipe.lookup_async(queries[i])
        futs[i] = fut
        fut.add_done_callback(lambda f, i=i: _done(f, i))
    for fut in futs:
        fut.result(30.0)
    latency = finish - (t0 + sched)
    return n / (finish.max() - t0), latency, results


def _check_oracle(results, oracle: np.ndarray) -> bool:
    got = np.concatenate([np.atleast_1d(r) for r in results])
    return bool(np.array_equal(got, oracle))


def _first_flush_ms(keys: np.ndarray, error: int, flush: int, *,
                    prewarm: bool) -> float:
    """Wall ms until the first coalesced flush resolves, on the dispatch
    backend (whose large tier lazily builds + jit-compiles on first use)."""
    svc = IndexService(keys, error, backend="dispatch", assume_sorted=True,
                       engine_opts={"dispatch": {"small_max": 64,
                                                 "large_min": flush}})
    chunk = max(1, flush // 8)
    chunks = [keys[i:i + chunk] for i in range(0, flush, chunk)]
    # generous deadline: the first flush must be the *threshold* flush at the
    # prewarmed bucket, not a partial deadline flush at some other shape
    with AsyncIndexService(svc, flush_threshold=flush, max_wait_us=50_000.0,
                           prewarm=prewarm) as pipe:
        t0 = time.perf_counter()
        futs = [pipe.lookup_async(c) for c in chunks]
        for f in futs:
            f.result(60.0)
        return (time.perf_counter() - t0) * 1e3


def run(n: int = N, error: int = ERROR, n_requests: int = N_REQUESTS,
        rate_factors: tuple[float, ...] = RATE_FACTORS,
        max_wait_us_sweep: tuple[float, ...] = MAX_WAIT_US_SWEEP,
        off_workers: int = OFF_WORKERS,
        flush_threshold: int = FLUSH_THRESHOLD,
        prewarm_flush: int = PREWARM_FLUSH,
        backend: str = "numpy"):
    rng = np.random.default_rng(7)
    keys = weblogs_like(n)
    svc = IndexService(keys, error, backend=backend, assume_sorted=True)
    qpool = keys[rng.integers(0, n, size=n_requests)]
    queries = [qpool[i:i + 1] for i in range(n_requests)]
    oracle = svc.lookup(qpool)          # the single-thread fused ground truth

    # --- calibrate the direct path so arrival rates saturate by construction
    for q in queries[:64]:
        svc.lookup(q)
    t0 = time.perf_counter()
    for q in queries[:CALIBRATION_CALLS]:
        svc.lookup(q)
    per_call = (time.perf_counter() - t0) / min(CALIBRATION_CALLS, n_requests)
    capacity = 1.0 / per_call
    emit("serving", "direct_us_per_call", per_call * 1e6, f"backend={backend}")

    # --- the sweep: arrival rate x {off, on(max_wait_us...)} ----------------
    sweep = []
    headline = None
    for factor in sorted(rate_factors):
        rate = factor * capacity
        sched = _schedule(rng, rate, n_requests)

        qps_off, lat_off, res_off = _drive_direct(svc, queries, sched,
                                                  off_workers)
        assert _check_oracle(res_off, oracle), "direct drive diverged"
        off_row = {"rate_factor": factor, "arrival_qps": rate,
                   "mode": "direct", "qps": qps_off, "oracle_exact": True,
                   **_percentiles(lat_off)}
        sweep.append(off_row)
        emit("serving", f"qps_off_{factor:g}x", qps_off,
             f"p99_us={off_row['p99_us']:.0f}")

        best_on = None
        for wait in max_wait_us_sweep:
            with AsyncIndexService(svc, flush_threshold=flush_threshold,
                                   max_wait_us=wait, prewarm=False) as pipe:
                qps_on, lat_on, res_on = _drive_pipeline(pipe, queries, sched)
                pm = pipe.metrics().pipeline
            assert _check_oracle(res_on, oracle), "coalesced drive diverged"
            row = {"rate_factor": factor, "arrival_qps": rate,
                   "mode": "coalesce", "max_wait_us": wait, "qps": qps_on,
                   "oracle_exact": True, **_percentiles(lat_on),
                   "flushes": pm.flushes,
                   "threshold_flushes": pm.threshold_flushes,
                   "deadline_flushes": pm.deadline_flushes,
                   "max_fused_batch": pm.max_fused_batch}
            sweep.append(row)
            emit("serving", f"qps_on_{factor:g}x_wait{wait:g}us", qps_on,
                 f"p99_us={row['p99_us']:.0f}")
            if best_on is None or qps_on > best_on["qps"]:
                best_on = row
        headline = {"top_rate_factor": factor, "top_arrival_qps": rate,
                    "qps_off": qps_off, "qps_on_best": best_on["qps"],
                    "best_max_wait_us": best_on["max_wait_us"],
                    "speedup": best_on["qps"] / qps_off,
                    "p99_us_off": off_row["p99_us"],
                    "p99_us_on_best": best_on["p99_us"]}

    # the tentpole claim, enforced every run: at the top (over-capacity)
    # arrival rate the coalescing front door sustains strictly more qps
    assert headline["qps_on_best"] > headline["qps_off"], headline
    emit("serving", "top_rate_speedup", headline["speedup"],
         f"{headline['qps_on_best']:.0f} vs {headline['qps_off']:.0f} qps")

    # --- first-flush latency: prewarm kills the lazy-compile spike ----------
    cold_ms = _first_flush_ms(keys, error, prewarm_flush, prewarm=False)
    warm_ms = _first_flush_ms(keys, error, prewarm_flush, prewarm=True)
    assert warm_ms < cold_ms, (warm_ms, cold_ms)   # compile >> one warm flush
    emit("serving", "first_flush_cold_ms", cold_ms)
    emit("serving", "first_flush_prewarmed_ms", warm_ms)

    results = {
        "config": {"n": n, "error": error, "n_requests": n_requests,
                   "backend": backend, "off_workers": off_workers,
                   "flush_threshold": flush_threshold,
                   "prewarm_flush": prewarm_flush,
                   "rate_factors": list(rate_factors),
                   "max_wait_us_sweep": list(max_wait_us_sweep)},
        "calibration": {"direct_us_per_call": per_call * 1e6,
                        "direct_capacity_qps": capacity},
        "sweep": sweep,
        "headline": headline,
        "first_flush": {"cold_ms": cold_ms, "prewarmed_ms": warm_ms},
    }
    write_json("bench_serving", results)
    return results


if __name__ == "__main__":
    run()
