"""Fig. 13: lookup time split -- segment location (tree) vs in-segment search."""
from __future__ import annotations

import numpy as np

from repro.core import FITingTree
from repro.core.datasets import weblogs_like

from .common import emit, timeit, write_csv

N = 500_000
NQ = 20_000
ERRORS = [16, 256, 4096]


def run():
    keys = weblogs_like(N)
    rng = np.random.default_rng(5)
    q = keys[rng.integers(0, N, size=NQ)]
    rows = []
    for e in ERRORS:
        tree = FITingTree(keys, error=e, assume_sorted=True)

        def tree_search_only(qq):
            sid = np.clip(np.searchsorted(tree.start_keys, qq, "right") - 1,
                          0, tree.n_segments - 1)
            return sid

        t_tree = timeit(tree_search_only, q) / NQ * 1e9
        t_total = timeit(tree.lookup_batch, q) / NQ * 1e9
        rows.append((e, t_tree, max(t_total - t_tree, 0.0), t_total))
    write_csv("fig13_breakdown", ["error", "tree_ns", "segment_ns",
                                  "total_ns"], rows)
    emit("fig13", "tree_fraction_small_error", rows[0][1] / rows[0][3])
    emit("fig13", "tree_fraction_large_error", rows[-1][1] / rows[-1][3])
    return rows


if __name__ == "__main__":
    run()
