"""CI benchmark smoke: tiny-input runs of the lookup, insert, and sharded
benches, collected into one JSON artifact (``BENCH_smoke.json``).

Not a performance measurement -- inputs are deliberately small so the job
finishes in minutes on a CI runner.  The point is (a) the benchmark code
paths stay runnable on every PR and (b) the artifact gives a coarse
per-commit perf trajectory (same tiny workload, same schema) that can be
diffed across workflow runs.

    PYTHONPATH=src python -m benchmarks.smoke --out BENCH_smoke.json
"""
from __future__ import annotations

import argparse
import platform
import time

from . import (bench_device, bench_insert, bench_lookup, bench_lsm,
               bench_plan, bench_range, bench_rebalance, bench_replan,
               bench_scalability, bench_serving, bench_sharded)
from .common import write_json

TINY = {
    "lookup": (bench_lookup.run,
               dict(n=20_000, nq=2_000, errors=[64, 256], pages=[64, 256])),
    "insert": (bench_insert.run,
               dict(n=20_000, n_ins=2_000, errors=[64, 256])),
    "sharded": (bench_sharded.run,
                dict(n=20_000, n_queries=1_024, shard_counts=(1, 2, 4),
                     dirty_fracs=(0.0, 0.5, 1.0), publish_shards=4,
                     inserts_per_dirty_shard=64)),
    # skew_threshold is tighter than the default so the tiny stream still
    # trips at least one rebalance and the artifact tracks its cost
    "rebalance": (bench_rebalance.run,
                  dict(n=20_000, n_inserts=2_000, n_queries=1_024,
                       n_shards=4, publish_every=256, skew_threshold=1.1)),
    # planner quality: predicted-vs-measured across the error sweep plus
    # planned-vs-legacy dispatch thresholds on a mixed batch-size workload
    "plan": (bench_plan.run,
             dict(n=20_000, n_queries=512, candidates=(16, 64, 256, 1024),
                  batch_sizes=(1, 8, 64, 512))),
    # the telemetry/replan loop: calibrated latency_upper_bound_rate (>= 0.9
    # asserted), monitor hot-path overhead (<= 5% asserted), and the
    # workload-drift frozen-vs-replanned p50/p99 comparison (replanned p99
    # must win, asserted) -- so the artifact tracks calibration quality and
    # the feedback loop's health per PR
    "replan": (bench_replan.run,
               dict(n=20_000, n_queries=1_024, candidates=(16, 64, 256),
                    n_requests=40)),
    # the query plane: scan throughput vs selectivity + the point-vs-range
    # head-to-head, so the artifact tracks scan performance per PR
    "range": (bench_range.run,
              dict(n=20_000, selectivities=(1e-3, 1e-2, 1e-1),
                   scans_per_selectivity=10, head_to_head_rows=512)),
    # async front door: open-loop arrivals at 0.5x/3x the machine's measured
    # direct per-call capacity; asserts coalescing-on sustains more qps than
    # direct dispatch at the over-capacity rate, and that prewarm beats the
    # cold first flush
    "serving": (bench_serving.run,
                dict(n=20_000, n_requests=1_200, rate_factors=(0.5, 3.0),
                     max_wait_us_sweep=(100.0, 1000.0), flush_threshold=128,
                     prewarm_flush=256)),
    # Fig. 11 scalability off the modern served plane (two tiny scales keep
    # the latency-vs-scale CSV shape without CI-runner minutes)
    "scalability": (bench_scalability.run,
                    dict(base=20_000, n_queries=2_000, scales=(1, 2))),
    # the device-sharded serving plane: subprocess under forced host devices;
    # asserts the mesh-normalized a2a qps curve is monotone 1->8 devices,
    # every verb bit-identical to the oracle under both exchanges, and delta
    # publish < 1/4 of full-republish bytes on a single-dirty-shard stream
    "device": (bench_device.run,
               dict(n=50_000, n_queries=16_384, error=128,
                    device_counts=(1, 2, 4, 8), inserts=32)),
    # the tiered write plane: asserts the LSM service sustains a 4x
    # single-buffer insert flood with read p99 <= 2x its read-only baseline
    # while the single Alg. 4 buffer visibly degrades, and that every verb
    # stays bit-identical to the searchsorted oracle across levels
    "lsm": (bench_lsm.run,
            dict(n=20_000, n_single_inserts=1_500, n_read_batches=250,
                 flood_s=1.0)),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_smoke.json")
    args = ap.parse_args()

    report = {"python": platform.python_version(),
              "machine": platform.machine(), "benches": {}}
    for name, (fn, kwargs) in TINY.items():
        t0 = time.perf_counter()
        results = fn(**kwargs)
        report["benches"][name] = {
            "seconds": time.perf_counter() - t0,
            "params": kwargs,
            "results": results,
        }
    path = write_json("bench_smoke", report, path=args.out)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
