"""Device plane: lookup qps vs device count, delta vs full republish.

Measures the two claims ``repro.index.device`` makes, on CPU with forced
host devices (``XLA_FLAGS=--xla_force_host_platform_device_count=D``, the
same simulation the tests use):

(a) **collective search scales with the mesh.**  The bucketed all_to_all
    exchange gives each device ~``slack * Q / D`` queries of local work, so
    the per-device critical path -- the wall clock of a real D-device mesh
    -- shrinks as devices are added.  CI hosts are time-sliced (the forced
    host devices of one CPU run sequentially), so the measured host wall
    clock is the *sum* of per-device work; ``mesh_qps = Q * D / host_wall``
    recovers the per-device critical path a concurrent mesh would run.
    Both numbers are reported; the monotonicity assert is on ``mesh_qps``
    at a fixed large batch, same kernel at every D (D=1 pays the same
    bucketing machinery, so the curve isolates the fan-out, not the
    presence of collectives).

(b) **delta publish beats full republish on a single-dirty-shard stream.**
    An insert stream routed to ONE shard publishes by re-shipping one
    padded row; the bench asserts the uploaded bytes are < 1/4 of the
    full-republish equivalent (D=8 ships 1 row instead of 8) and compares
    wall latency against a full re-pack-and-upload of the same manifest.

Every device-plane verb is also asserted bit-identical to the numpy
``searchsorted`` oracle (f32 key contract) under BOTH exchange strategies
before any number is reported.

The measurement runs in a subprocess (``run()`` re-invokes this module with
the forced-device-count XLA flag), so importing jax in the parent process
never pins the device topology for other benches.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import tempfile
import time

from .common import emit, write_json

N = 500_000
NQ = 131_072
ERROR = 256
DEVICE_COUNTS = (1, 2, 4, 8)
SLACK = 1.5
INSERTS = 64


def _inner(n: int, n_queries: int, error: int,
           device_counts: tuple[int, ...], slack: float,
           inserts: int) -> dict:
    """Runs under the forced-device-count XLA flag (see ``run``)."""
    import jax
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.index.device import DeviceShardedService, sharded_search_a2a

    d_max = max(device_counts)
    assert jax.device_count() >= d_max, (jax.device_count(), d_max)
    assert n_queries % d_max == 0, "batch must tile the largest mesh"
    rng = np.random.default_rng(11)
    keys = np.sort(rng.integers(0, 1 << 23, n).astype(np.float64))
    k32 = keys.astype(np.float32)
    q = keys[rng.integers(0, n, n_queries)]
    q32 = q.astype(np.float32)

    def timeit(fn, *args, repeats=5, warmup=2):
        for _ in range(warmup):
            jax.block_until_ready(fn(*args))
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            ts.append(time.perf_counter() - t0)
        ts.sort()
        return ts[len(ts) // 2]

    # --- verb bit-identity vs the searchsorted oracle, both strategies ----
    left = np.searchsorted(k32, q32, "left")
    right = np.searchsorted(k32, q32, "right")
    for xchg in ("allgather", "a2a"):
        svc = DeviceShardedService(keys, error=error, device_count=d_max,
                                   exchange=xchg, assume_sorted=True)
        np.testing.assert_array_equal(svc.search(q, "left"), left, err_msg=xchg)
        np.testing.assert_array_equal(svc.search(q, "right"), right,
                                      err_msg=xchg)
        np.testing.assert_array_equal(svc.lookup(q),
                                      np.where(right > left, left, -1))
        pt = svc.point(q)
        np.testing.assert_array_equal(pt.found, right > left)
        np.testing.assert_array_equal(
            svc.predecessor(q).rank, np.where(right >= 1, right - 1, -1))
        np.testing.assert_array_equal(
            svc.successor(q).rank, np.where(left < n, left, -1))
        np.testing.assert_array_equal(
            svc.count(q - 2.0, q + 2.0),
            np.maximum(np.searchsorted(k32, (q + 2.0).astype(np.float32),
                                       "right")
                       - np.searchsorted(k32, (q - 2.0).astype(np.float32),
                                         "left"), 0))

    # --- (a) qps vs device count: same a2a kernel at every D --------------
    curve = []
    for d in device_counts:
        svc = DeviceShardedService(keys, error=error, device_count=d,
                                   exchange="a2a", slack=slack,
                                   assume_sorted=True)
        ds = svc.device_set
        mesh = Mesh(np.asarray(jax.devices()[:d]), ("data",))
        q_dev = jax.device_put(q32, NamedSharding(mesh, P("data")))

        def fn(ss, sl, ba, se, ke, nl, of, bo, qq, mesh=mesh):
            return sharded_search_a2a(ss, sl, ba, se, ke, nl, of, bo, qq,
                                      mesh=mesh, axis="data", error=error,
                                      side="left", slack=slack)[0]

        jfn = jax.jit(fn)
        wall = timeit(jfn, ds.d_seg_start, ds.d_slope, ds.d_base,
                      ds.d_seg_end, ds.d_keys, ds.d_n_local, ds.d_offsets,
                      ds.d_boundaries, q_dev)
        # sanity: the timed kernel answers exactly like the oracle
        got = np.asarray(jfn(ds.d_seg_start, ds.d_slope, ds.d_base,
                             ds.d_seg_end, ds.d_keys, ds.d_n_local,
                             ds.d_offsets, ds.d_boundaries, q_dev))
        np.testing.assert_array_equal(got, left)
        curve.append({"n_devices": d, "host_wall_ms": wall * 1e3,
                      "mesh_qps": n_queries * d / wall})
    for a, b in zip(curve, curve[1:]):
        assert b["mesh_qps"] > a["mesh_qps"], \
            (f"mesh qps must increase with device count: "
             f"{a['n_devices']}dev {a['mesh_qps']:.0f} -> "
             f"{b['n_devices']}dev {b['mesh_qps']:.0f}")

    # --- (b) delta vs full republish on a single-dirty-shard stream -------
    svc = DeviceShardedService(keys, error=error, device_count=d_max,
                               buffer_size=max(2, error // 4),
                               assume_sorted=True)
    lo = float(svc.boundaries[0])
    for i in range(inserts):            # every insert routes to shard 0
        svc.insert(lo + 0.25 + i * 1e-6)
    before = svc.metrics().device
    t0 = time.perf_counter()
    svc.publish()
    delta_ms = (time.perf_counter() - t0) * 1e3
    after = svc.metrics().device
    assert after.delta_publishes == before.delta_publishes + 1
    delta_bytes = after.bytes_uploaded - before.bytes_uploaded
    full_bytes = after.bytes_full_equivalent - before.bytes_full_equivalent
    assert delta_bytes * 4 < full_bytes, (delta_bytes, full_bytes)
    # full-republish latency: re-pack + upload the whole manifest (the
    # transfer the delta path avoids; private by design -- the service
    # never takes this path for a clean-boundary publish)
    t0 = time.perf_counter()
    jax.block_until_ready(svc._full_set(svc.device_set.version).d_keys)
    full_ms = (time.perf_counter() - t0) * 1e3

    return {
        "config": {"n": n, "n_queries": n_queries, "error": error,
                   "device_counts": list(device_counts), "slack": slack,
                   "inserts": inserts},
        "verbs_bit_identical": True,
        "qps_curve": curve,
        "publish": {"delta_bytes": delta_bytes, "full_bytes": full_bytes,
                    "bytes_ratio": delta_bytes / full_bytes,
                    "delta_ms": delta_ms, "full_ms": full_ms},
    }


def run(n: int = N, n_queries: int = NQ, error: int = ERROR,
        device_counts: tuple[int, ...] = DEVICE_COUNTS,
        slack: float = SLACK, inserts: int = INSERTS):
    """Spawn the measurement under the forced-device-count XLA flag and
    collect/emit its results (the smoke-wired entry point)."""
    params = dict(n=n, n_queries=n_queries, error=error,
                  device_counts=tuple(device_counts), slack=slack,
                  inserts=inserts)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{max(device_counts)}")
    env["REPRO_SANITIZE"] = "0"          # measuring, not debugging
    root = pathlib.Path(__file__).parents[1]
    env["PYTHONPATH"] = os.pathsep.join(
        [str(root / "src")] + env.get("PYTHONPATH", "").split(os.pathsep))
    with tempfile.TemporaryDirectory() as tmp:
        out = pathlib.Path(tmp) / "device.json"
        res = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_device", "--inner",
             "--params", json.dumps(params), "--out", str(out)],
            cwd=root, env=env, capture_output=True, text=True, timeout=1800)
        assert res.returncode == 0, res.stdout + "\n" + res.stderr
        results = json.loads(out.read_text())

    for row in results["qps_curve"]:
        emit("device", f"mesh_qps_{row['n_devices']}dev", row["mesh_qps"],
             f"host_wall_ms={row['host_wall_ms']:.1f}")
    pub = results["publish"]
    emit("device", "delta_vs_full_bytes_ratio", pub["bytes_ratio"],
         f"{pub['delta_bytes']}B_vs_{pub['full_bytes']}B")
    emit("device", "delta_publish_ms", pub["delta_ms"],
         f"full_republish_ms={pub['full_ms']:.1f}")
    write_json("bench_device", results)
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--inner", action="store_true")
    ap.add_argument("--params", default="{}")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.inner:
        params = json.loads(args.params)
        params["device_counts"] = tuple(params["device_counts"])
        results = _inner(**params)
        pathlib.Path(args.out).write_text(json.dumps(results))
    else:
        run()


if __name__ == "__main__":
    main()
