"""Range-scan performance: throughput vs selectivity, and the point-vs-range
head-to-head the typed query plane exists to win.

Two measurements over the unified core (``repro.index.query``):

* **scan throughput vs selectivity** -- ``range(lo, hi)`` resolves two
  bounded predecessor searches and then slices the clustered key column, so
  per-scan cost should be a fixed locate term plus a per-row copy; rows/s
  should *rise* with selectivity as the locate cost amortizes.
* **point-vs-range head-to-head** -- enumerating the keys of a span by
  probing every key as a point lookup (the only option before the query
  plane) vs issuing one ``range()`` (and one ``count()``, the
  no-materialization form).  The gap is the paper's Sec. 4.2 argument for
  the clustered page layout, measured.

Results are written as JSON (``out/bench_range.json``) via the
``benchmarks.common`` plumbing, plus the usual ``emit`` headline lines.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.datasets import weblogs_like
from repro.serve import IndexService

from .common import emit, write_json

N = 200_000
ERROR = 64
SELECTIVITIES = (1e-4, 1e-3, 1e-2, 1e-1)
SCANS_PER_SELECTIVITY = 50
HEAD_TO_HEAD_ROWS = 2048


def _scan_bounds(keys: np.ndarray, rng, selectivity: float, m: int
                 ) -> list[tuple[float, float]]:
    """m random [lo, hi] spans each covering ~selectivity of the key column."""
    n = keys.shape[0]
    span = max(1, int(round(selectivity * n)))
    starts = rng.integers(0, max(n - span, 1), size=m)
    return [(float(keys[s]), float(keys[min(s + span - 1, n - 1)]))
            for s in starts]


def run(n: int = N, error: int = ERROR,
        selectivities: tuple[float, ...] = SELECTIVITIES,
        scans_per_selectivity: int = SCANS_PER_SELECTIVITY,
        head_to_head_rows: int = HEAD_TO_HEAD_ROWS,
        backend: str = "numpy"):
    rng = np.random.default_rng(7)
    keys = weblogs_like(n)                  # same workload as the other benches
    svc = IndexService(keys, error=error, backend=backend, assume_sorted=True)

    # --- (a) scan throughput vs selectivity --------------------------------
    throughput = []
    for sel in selectivities:
        bounds = _scan_bounds(keys, rng, sel, scans_per_selectivity)
        svc.range(*bounds[0])               # warm engine caches
        rows = 0
        t0 = time.perf_counter()
        for lo, hi in bounds:
            rows += svc.range(lo, hi).count
        dt = time.perf_counter() - t0
        rows_per_s = rows / dt
        throughput.append({
            "selectivity": sel, "scans": len(bounds), "rows": rows,
            "rows_per_s": rows_per_s,
            "us_per_scan": dt / len(bounds) * 1e6})
        emit("range", f"rows_per_s_sel{sel:g}", rows_per_s,
             f"backend={backend}")

    # --- (b) point-vs-range head-to-head -----------------------------------
    span = min(head_to_head_rows, n // 2)
    s = int(rng.integers(0, n - span))
    lo, hi = float(keys[s]), float(keys[s + span - 1])
    probe = keys[s:s + span]                # the keys a point loop would probe

    def by_points():
        return svc.lookup(probe)

    def by_range():
        return svc.range(lo, hi)

    def by_count():
        return svc.count([lo], [hi])

    results_h2h = {}
    for name, fn in (("points", by_points), ("range", by_range),
                     ("count", by_count)):
        fn()                                # warm
        t0 = time.perf_counter()
        reps = 20
        for _ in range(reps):
            fn()
        us = (time.perf_counter() - t0) / reps * 1e6
        results_h2h[name] = us
        emit("range", f"h2h_{name}_us", us, f"rows={span}")
    emit("range", "h2h_speedup_range_vs_points",
         results_h2h["points"] / max(results_h2h["range"], 1e-9))

    results = {
        "config": {"n": n, "error": error, "backend": backend,
                   "head_to_head_rows": span},
        "scan_throughput": throughput,
        "head_to_head_us": results_h2h,
    }
    write_json("bench_range", results)
    return results


if __name__ == "__main__":
    run()
