"""Adaptive shard rebalancing under a skewed insert stream.

Measures the claim ``ShardedIndexService.rebalance`` makes: under write skew
(a 10:1 hot key range) a frozen partition lets one shard grow without bound
-- its publishes get slower and its larger table dominates lookup cost --
while adaptive recutting keeps keys-per-shard near-even at the price of
occasional migration work.  Two identical services consume the same skewed
stream, one with rebalancing off and one recutting whenever the skew
threshold trips; we record publish latency along the stream (mean/p95/max,
with rebalance time accounted separately so the comparison is honest),
end-state lookup throughput, and the final keys-per-shard imbalance.

Results are written as JSON (``out/bench_rebalance.json``) via the
``benchmarks.common`` plumbing, plus the usual ``emit`` headline lines.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.datasets import weblogs_like
from repro.index.sharded import ShardedIndexService

from .common import emit, timeit, write_json

N = 100_000
N_INSERTS = 20_000
NQ = 4096
ERROR = 64
N_SHARDS = 8
SKEW = 10.0
PUBLISH_EVERY = 512
SKEW_THRESHOLD = 1.5


def _skewed_stream(rng: np.random.Generator, n: int, hot_lo: float,
                   hot_hi: float, lo: float, hi: float, skew: float
                   ) -> np.ndarray:
    """Insert stream where a key is ``skew``x more likely to land in the hot
    range [hot_lo, hot_hi) than anywhere in [lo, hi)."""
    hot = rng.random(n) < skew / (skew + 1.0)
    return np.where(hot, rng.uniform(hot_lo, hot_hi, size=n),
                    rng.uniform(lo, hi, size=n))


def _percentile(xs: list[float], p: float) -> float:
    return float(np.percentile(np.asarray(xs), p)) if xs else 0.0


def run(n: int = N, n_inserts: int = N_INSERTS, n_queries: int = NQ,
        error: int = ERROR, n_shards: int = N_SHARDS, skew: float = SKEW,
        publish_every: int = PUBLISH_EVERY,
        skew_threshold: float = SKEW_THRESHOLD, backend: str = "numpy"):
    keys = weblogs_like(n)
    results = {"config": {"n": n, "n_inserts": n_inserts,
                          "n_queries": n_queries, "error": error,
                          "n_shards": n_shards, "skew": skew,
                          "publish_every": publish_every,
                          "skew_threshold": skew_threshold,
                          "backend": backend}}
    for mode in ("off", "on"):
        rng = np.random.default_rng(7)          # same stream both modes
        svc = ShardedIndexService(keys, error, n_shards=n_shards,
                                  buffer_size=max(2, error // 4),
                                  backend=backend,
                                  skew_threshold=skew_threshold,
                                  assume_sorted=True)
        hot_lo, hot_hi = float(svc.boundaries[0]), float(svc.boundaries[1])
        stream = _skewed_stream(rng, n_inserts, hot_lo, hot_hi,
                                float(keys[0]), float(keys[-1]), skew)
        publish_ms: list[float] = []
        rebalance_ms: list[float] = []
        for i, k in enumerate(stream):
            svc.insert(float(k))
            if (i + 1) % publish_every == 0:
                t0 = time.perf_counter()
                svc.publish()
                publish_ms.append((time.perf_counter() - t0) * 1e3)
                if mode == "on" and svc.needs_rebalance():
                    t0 = time.perf_counter()
                    svc.rebalance()
                    rebalance_ms.append((time.perf_counter() - t0) * 1e3)
        t0 = time.perf_counter()
        svc.publish()
        publish_ms.append((time.perf_counter() - t0) * 1e3)

        q = np.concatenate([
            keys[rng.integers(0, n, size=n_queries // 2)],
            stream[rng.integers(0, n_inserts, size=n_queries - n_queries // 2)]])
        t = timeit(svc.lookup, q)
        qps = n_queries / t
        loads = svc.shard_loads()
        m = svc.metrics()
        results[f"rebalance_{mode}"] = {
            "publish_ms_mean": float(np.mean(publish_ms)),
            "publish_ms_p95": _percentile(publish_ms, 95),
            "publish_ms_max": float(np.max(publish_ms)),
            "publishes": len(publish_ms),
            "rebalances": m.rebalances,
            "rebalance_ms_total": float(np.sum(rebalance_ms)),
            "queries_per_s": qps,
            "ns_per_query": t / n_queries * 1e9,
            "imbalance": m.imbalance,
            "max_keys_per_shard": int(loads.max()),
            "mean_keys_per_shard": float(loads.mean()),
            "shard_set_version": m.shard_set_version,
        }
        emit("rebalance", f"qps_{mode}", qps, f"backend={backend}")
        emit("rebalance", f"publish_ms_mean_{mode}",
             results[f"rebalance_{mode}"]["publish_ms_mean"])
        emit("rebalance", f"imbalance_{mode}", m.imbalance)
    write_json("bench_rebalance", results)
    return results


if __name__ == "__main__":
    run()
