"""Sharded serving: lookup throughput vs shard count, publish latency vs
dirty-shard fraction.

Measures the two claims ``repro.index.sharded`` makes: (a) reads scale with
key-partitioned shards because each query only touches its owning shard's
(smaller) table, and (b) publish cost is proportional to the number of
*dirty* shards, not the fleet size -- a clean shard's snapshot and epoch are
untouched.  Results are written as JSON (``out/bench_sharded.json``) via the
``benchmarks.common`` plumbing, plus the usual ``emit`` headline lines.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.datasets import weblogs_like
from repro.index.sharded import ShardedIndexService

from .common import emit, timeit, write_json

N = 200_000
NQ = 8192
ERROR = 64
SHARD_COUNTS = (1, 2, 4, 8)
DIRTY_FRACS = (0.0, 0.25, 0.5, 1.0)
PUBLISH_SHARDS = 8
INSERTS_PER_DIRTY_SHARD = 256


def run(n: int = N, n_queries: int = NQ, error: int = ERROR,
        shard_counts: tuple[int, ...] = SHARD_COUNTS,
        dirty_fracs: tuple[float, ...] = DIRTY_FRACS,
        publish_shards: int = PUBLISH_SHARDS,
        inserts_per_dirty_shard: int = INSERTS_PER_DIRTY_SHARD,
        backend: str = "numpy"):
    rng = np.random.default_rng(2)
    keys = weblogs_like(n)          # same workload as fig6/fig7 benches
    q = keys[rng.integers(0, n, size=n_queries)]

    # --- (a) lookup throughput vs shard count ------------------------------
    throughput = []
    for d in shard_counts:
        svc = ShardedIndexService(keys, error, n_shards=d, backend=backend,
                                  assume_sorted=True)
        t = timeit(svc.lookup, q)
        qps = n_queries / t
        throughput.append({"n_shards": d, "queries_per_s": qps,
                           "ns_per_query": t / n_queries * 1e9})
        emit("sharded", f"qps_{d}shards", qps, f"backend={backend}")

    # --- (b) publish latency vs dirty-shard fraction -----------------------
    publish = []
    for frac in dirty_fracs:
        svc = ShardedIndexService(keys, error, n_shards=publish_shards,
                                  buffer_size=max(2, error // 4),
                                  backend=backend, assume_sorted=True)
        n_dirty = int(round(frac * publish_shards))
        for sid in range(n_dirty):
            lo = svc.boundaries[sid]
            hi = (svc.boundaries[sid + 1] if sid + 1 < publish_shards
                  else keys[-1])
            cand = rng.uniform(lo, hi, size=inserts_per_dirty_shard)
            for k in cand:
                svc.insert(float(k))
        t0 = time.perf_counter()
        published = svc.publish()
        dt_ms = (time.perf_counter() - t0) * 1e3
        assert len(published) == n_dirty, (len(published), n_dirty)
        publish.append({"dirty_frac": frac, "dirty_shards": n_dirty,
                        "publish_ms": dt_ms,
                        "pending_flushed": inserts_per_dirty_shard * n_dirty})
        emit("sharded", f"publish_ms_dirty{n_dirty}of{publish_shards}", dt_ms)

    results = {
        "config": {"n": n, "n_queries": n_queries, "error": error,
                   "backend": backend, "publish_shards": publish_shards,
                   "inserts_per_dirty_shard": inserts_per_dirty_shard},
        "lookup_throughput": throughput,
        "publish_latency": publish,
    }
    write_json("bench_sharded", results)
    return results


if __name__ == "__main__":
    run()
