"""Index baselines for the paper's comparisons (Sec. 7.1).

Array-packed analogues of the paper's STX-tree baselines (DESIGN.md Sec. 8):
  * FullIndex      -- one (key, pointer) entry per key ("dense"): best-case
                      lookup reference, 16B/key storage.
  * FixedPagedIndex-- fixed-size pages, first key per page indexed ("sparse");
                      per-page insert buffers, split-on-full (Sec. 7.1.3).
  * BinarySearch   -- zero-storage baseline over the raw array.
"""
from __future__ import annotations

import bisect

import numpy as np


class FullIndex:
    def __init__(self, keys: np.ndarray):
        self.keys = np.asarray(keys, np.float64)

    def size_bytes(self) -> int:
        return self.keys.shape[0] * 16

    def lookup_batch(self, q: np.ndarray) -> np.ndarray:
        r = np.searchsorted(self.keys, q, side="left")
        ok = (r < self.keys.shape[0]) & (self.keys[np.minimum(r, len(self.keys) - 1)] == q)
        return np.where(ok, r, -1)


class BinarySearch(FullIndex):
    def size_bytes(self) -> int:
        return 0


class FixedPagedIndex:
    """Sparse index: first key of each fixed-size page + per-page buffers."""

    def __init__(self, keys: np.ndarray, page_size: int, buffer_size: int = 0):
        keys = np.asarray(keys, np.float64)
        self.page_size = int(page_size)
        self.buffer_size = int(buffer_size)
        self.pages = [keys[i: i + page_size]
                      for i in range(0, keys.shape[0], page_size)]
        self.page_keys = np.asarray([p[0] for p in self.pages])
        self.buffers: list[list[float]] = [[] for _ in self.pages]

    def size_bytes(self) -> int:
        # 16B per page entry + tree overhead factor like Sec. 6.2's accounting
        return len(self.pages) * 24

    def lookup_batch(self, q: np.ndarray) -> np.ndarray:
        """Vectorized: page via searchsorted over page keys, then local search
        in a fixed-width window (the page)."""
        q = np.asarray(q, np.float64)
        pid = np.clip(np.searchsorted(self.page_keys, q, side="right") - 1,
                      0, len(self.pages) - 1)
        out = np.full(q.shape[0], -1, np.int64)
        base = np.cumsum([0] + [p.shape[0] for p in self.pages])
        for i, (qq, pp) in enumerate(zip(q, pid)):
            page = self.pages[pp]
            j = np.searchsorted(page, qq, side="left")
            if j < page.shape[0] and page[j] == qq:
                out[i] = base[pp] + j
        return out

    def lookup_one(self, qq: float):
        pid = min(max(int(np.searchsorted(self.page_keys, qq, "right")) - 1, 0),
                  len(self.pages) - 1)
        page = self.pages[pid]
        j = int(np.searchsorted(page, qq, "left"))
        if j < page.shape[0] and page[j] == qq:
            return pid, j
        buf = self.buffers[pid]
        k = bisect.bisect_left(buf, qq)
        if k < len(buf) and buf[k] == qq:
            return pid, -(k + 1)
        return None

    def insert(self, key: float):
        pid = min(max(int(np.searchsorted(self.page_keys, key, "right")) - 1, 0),
                  len(self.pages) - 1)
        buf = self.buffers[pid]
        bisect.insort(buf, key)
        if len(buf) >= self.buffer_size:
            merged = np.sort(np.concatenate([self.pages[pid],
                                             np.asarray(buf, np.float64)]))
            halves = [merged[: merged.shape[0] // 2],
                      merged[merged.shape[0] // 2:]]
            self.pages[pid: pid + 1] = halves
            self.buffers[pid: pid + 1] = [[], []]
            self.page_keys = np.asarray([p[0] for p in self.pages])
