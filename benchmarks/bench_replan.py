"""Closed-loop re-planning under workload drift + cost-model calibration.

Three claims are tracked per PR (wired into ``benchmarks/smoke.py``):

1. **Calibration fixes the latency upper bound** -- the hand-tuned
   ``CostParams`` defaults under-predict host latency (the ``bench_plan``
   ``latency_upper_bound_rate`` ~0.5 finding).  Seeding ``c_ns`` from the
   one-shot ``cost_model.calibrate`` micro-benchmark must push the rate to
   >= 0.9 on the same sweep; the residual predicted/measured gap is recorded
   per candidate error (asserted here, not just reported).

2. **Telemetry is effectively free** -- the Monitor's ring-buffer hooks on
   the lookup hot path (per-tier timing + served-key sampling) must cost
   <= 5% qps vs the same service with recording disabled (asserted).

3. **The replanner beats a frozen plan under drift** -- phase A serves a
   calibration mix through a monitored service (all three dispatch tiers,
   including the interpret-mode pallas tier that the *model* thinks wins big
   batches but that is orders of magnitude slower on a CPU-only host); one
   ``Replanner.replan()`` pass re-fits the tier curves from the measured
   samples and hot-swaps the dispatch thresholds.  Phase B then runs a
   drifted workload (zipfian probes, batch mix shifted toward the big-batch
   tier) against the frozen and the replanned service: the replanned p99
   must beat the frozen p99 (asserted).

Results land in ``out/bench_replan.json`` plus the usual ``emit`` lines.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.cost_model import CostParams, calibrate, latency_ns
from repro.core.datasets import weblogs_like
from repro.index import FitSpec, make_engine, open_index, plan
from repro.index.fit import planned_buffer
from repro.index.table import SegmentTable
from repro.index.telemetry import Monitor, Replanner

from .common import emit, timeit, write_json

N = 100_000
NQ = 4_096
CANDIDATES = (16, 64, 256, 1024)
OVERHEAD_BATCH = 1024           # served from the (pinned) numpy tier
OVERHEAD_CALLS = 3200           # total timed lookups, split into alternating
#                                 enabled/disabled blocks
DRIFT_REQUESTS = 60


# ------------------------------------------------------------- 1. calibration
def _calibration_sweep(keys, q, candidates):
    """Predicted-vs-measured over the candidate error sweep, scored twice:
    with the hand-tuned default ``CostParams`` and with the calibrated ones
    (each candidate segmented at its buffer-effective error, exactly the
    planner's scoring form)."""
    cal = calibrate(keys)
    sweep = []
    for e in candidates:
        eff = max(1, e - planned_buffer(e))
        table = SegmentTable.from_keys(keys, eff, assume_sorted=True)
        eng = make_engine(table, "numpy")
        measured = timeit(eng.lookup, q) / q.size * 1e9
        pred_def = latency_ns(eff, table.n_segments, CostParams())
        pred_cal = latency_ns(eff, table.n_segments, cal)
        sweep.append({"error": e, "measured_ns": measured,
                      "predicted_ns_default": pred_def,
                      "predicted_ns_calibrated": pred_cal,
                      "gap_ratio_default": pred_def / measured,
                      "gap_ratio_calibrated": pred_cal / measured})
    rate_def = float(np.mean([s["predicted_ns_default"] >= s["measured_ns"]
                              for s in sweep]))
    rate_cal = float(np.mean([s["predicted_ns_calibrated"] >= s["measured_ns"]
                              for s in sweep]))
    return {"c_ns_default": CostParams().c_ns, "c_ns_calibrated": cal.c_ns,
            "sweep": sweep,
            "latency_upper_bound_rate_default": rate_def,
            "latency_upper_bound_rate": rate_cal}, cal


# ----------------------------------------------------- 2. telemetry overhead
def _overhead_check(keys, q):
    """One service, recording enabled vs disabled (the acceptance bar: qps
    regression vs monitor-disabled).  Same engine objects, same tier --
    toggling ``Monitor.enabled`` between short alternating timed blocks
    isolates exactly the recording cost.  Two choices keep the ~0.5us hook
    measurable at all: the thresholds are pinned so the batch serves from
    the numpy tier (host calls are deterministic; the device tiers' dispatch
    jitter and GC interplay swing end-to-end timings by several percent,
    an order more than the hook), and the median across round ratios shrugs
    off the occasional scheduler spike landing in one accumulator."""
    from repro.analysis import sanitizer
    assert not sanitizer.enabled(), \
        "run benchmarks with REPRO_SANITIZE=0: the runtime sanitizer's " \
        "pin/lock tracking would be charged against the 5% telemetry budget"
    batch = q[:OVERHEAD_BATCH]
    mon = Monitor()
    p = plan(keys, FitSpec(error=64, batch_sizes=(1, 256, 4096)),
             assume_sorted=True)
    svc = open_index(keys, p.replace(small_max=1 << 20, large_min=1 << 21),
                     monitor=mon, assume_sorted=True)
    block, rounds = 25, OVERHEAD_CALLS // 50
    for _ in range(20):                   # warm the tier's engine
        svc.lookup(batch)

    def timed_block(enabled):
        mon.enabled = enabled
        t0 = time.perf_counter_ns()
        for _ in range(block):
            svc.lookup(batch)
        return time.perf_counter_ns() - t0

    pairs = [(timed_block(False), timed_block(True)) for _ in range(rounds)]
    mon.enabled = True
    per_call = block * batch.size * 1e9
    qps_off = float(np.median([per_call / dis for dis, _ in pairs]))
    qps_on = float(np.median([per_call / on for _, on in pairs]))
    overhead = 1.0 - 1.0 / float(np.median([on / dis for dis, on in pairs]))
    assert overhead <= 0.05, \
        f"telemetry overhead {overhead:.1%} exceeds the 5% budget"
    return {"qps_monitor_off": qps_off, "qps_monitor_on": qps_on,
            "overhead_fraction": overhead}


# ------------------------------------------------------------------ 3. drift
def _drift_requests(rng, keys, heavy, n_requests):
    """The phase-B drifted workload: zipfian-skewed probe keys and a batch
    mix shifted toward the big-batch tier (10% heavy) -- the regime where a
    model-frozen dispatch config pays the interpret-mode pallas tier."""
    n = keys.size
    reqs = []
    for i in range(n_requests):
        size = heavy if i % 10 == 0 else (32 if i % 10 == 1 else 256)
        ranks = np.minimum(rng.zipf(1.5, size), n) - 1
        reqs.append(keys[ranks])
    return reqs


def _serve(svc, requests):
    lat_us = []
    for q in requests:
        t0 = time.perf_counter_ns()
        svc.lookup(q)
        lat_us.append((time.perf_counter_ns() - t0) / 1e3)
    a = np.asarray(lat_us)
    return {"p50_us": float(np.percentile(a, 50)),
            "p99_us": float(np.percentile(a, 99))}


def _drift_scenario(keys, rng, n_requests):
    spec = FitSpec(error=64, batch_sizes=(1, 256, 4096))
    p0 = plan(keys, spec, assume_sorted=True)
    # the smallest power-of-two batch the frozen plan routes to the big tier
    heavy = 1 << max(12, int(p0.large_min).bit_length())
    mon = Monitor(capacity=1 << 14)
    live = open_index(keys, p0, monitor=mon, assume_sorted=True)
    frozen = open_index(keys, p0, assume_sorted=True)
    warm_sizes = (8, 32, 256, 1024, heavy, 2 * heavy)
    for svc in (live, frozen):
        svc.prewarm(batch_sizes=warm_sizes)   # compiles outside the timings

    # phase A: calibration traffic through every tier on the live service
    pool = keys[rng.integers(0, keys.size, size=4 * heavy)]
    for size, reps in ((1, 8), (8, 8), (32, 8), (256, 8), (1024, 8),
                      (heavy, 5), (2 * heavy, 4)):
        for _ in range(reps):
            live.lookup(pool[:size])

    rp = Replanner(live, interval_s=0.01, hysteresis=0.1, min_tier_samples=5)
    served = rp.replan()
    assert served is not None, \
        f"replanner did not clear the hysteresis bar (win={rp.last_win})"
    # the swap installs fresh serving handles (fresh jit caches): compile the
    # post-swap tiers before timing, as AsyncIndexService.apply_plan's
    # prewarm path does when the swap happens on the maintenance thread
    live.prewarm(batch_sizes=warm_sizes)

    requests = _drift_requests(rng, keys, heavy, n_requests)
    frozen_lat = _serve(frozen, requests)
    live_lat = _serve(live, requests)
    assert live_lat["p99_us"] < frozen_lat["p99_us"], \
        f"replanned p99 {live_lat['p99_us']:.0f}us did not beat frozen " \
        f"{frozen_lat['p99_us']:.0f}us"

    tiers = {t.tier: {"fixed_ns": t.fixed_ns, "per_query_ns": t.per_query_ns}
             for t in live.metrics().tiers}
    return {"frozen": {"small_max": p0.small_max, "large_min": p0.large_min,
                       **frozen_lat},
            "replanned": {"small_max": served.small_max,
                          "large_min": served.large_min,
                          "revision": served.revision,
                          "predicted_win": rp.last_win, **live_lat},
            "heavy_batch": heavy,
            "measured_tier_curves": tiers}


def run(n: int = N, n_queries: int = NQ,
        candidates: tuple[int, ...] = CANDIDATES,
        n_requests: int = DRIFT_REQUESTS):
    keys = weblogs_like(n)
    rng = np.random.default_rng(7)
    q = keys[rng.integers(0, n, size=n_queries)]

    calibration, _ = _calibration_sweep(keys, q, candidates)
    rate = calibration["latency_upper_bound_rate"]
    assert rate >= 0.9, \
        f"calibrated latency_upper_bound_rate {rate} below the 0.9 bar"
    overhead = _overhead_check(keys, q)
    drift = _drift_scenario(keys, rng, n_requests)

    emit("replan", "latency_upper_bound_rate", rate,
         f"default={calibration['latency_upper_bound_rate_default']}")
    emit("replan", "telemetry_overhead_pct",
         overhead["overhead_fraction"] * 100)
    emit("replan", "p99_us_frozen", drift["frozen"]["p99_us"])
    emit("replan", "p99_us_replanned", drift["replanned"]["p99_us"],
         f"win={drift['replanned']['predicted_win']:.2f}")

    results = {"config": {"n": n, "n_queries": n_queries,
                          "candidates": list(candidates),
                          "n_requests": n_requests},
               "calibration": calibration,
               "telemetry_overhead": overhead,
               "drift": drift}
    write_json("bench_replan", results)
    return results


if __name__ == "__main__":
    run()
