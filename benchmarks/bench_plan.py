"""Planner quality: does the Sec. 6 plan predict what the built index does?

Three claims are tracked per PR (wired into ``benchmarks/smoke.py``):

1. **Prediction accuracy across the error sweep** -- for every candidate
   error the planner scored, build the index at that error and measure the
   host lookup latency; record measured vs the plan's predicted latency and
   size (the Fig. 10 methodology, but through the ``FitSpec -> plan()``
   audit trail instead of hand-rolled model calls).

2. **Calibrated vs hand-tuned cost constants** -- the stock ``CostParams``
   (c = 50ns/probe) is a guess about a host it has never seen, and on real
   hosts it under-predicts: ``latency_upper_bound_rate`` hovered near 0.5,
   i.e. the "upper bound" was a coin flip.  The planner run here seeds
   ``cpu_params`` from ``cost_model.calibrate(keys)`` (a one-shot ~100ms
   micro-benchmark of *this* host) and the sweep scores both models, so the
   artifact tracks the calibrated rate and the residual predicted/measured
   gap per candidate.  If the calibrated model proves the stock latency
   budget unachievable on this host, the run falls back to pinning the
   default plan's error and records that the budget was infeasible --
   a truthful model refusing an impossible SLO is the fix working.

3. **Planned vs default dispatch thresholds head-to-head** -- run the same
   mixed batch-size workload through a ``DispatchEngine`` with the
   cost-model-planned ``small_max``/``large_min`` and one pinned to the old
   magic constants (64 / 4096); record total time per configuration so the
   artifact shows whether the learned crossings actually help on this host.

Results land in ``out/bench_plan.json`` plus the usual ``emit`` lines.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.cost_model import CostParams, calibrate
from repro.core.datasets import weblogs_like
from repro.index import FitSpec, InfeasibleSpecError, make_engine, plan
from repro.index.fit import planned_buffer
from repro.index.table import SegmentTable

from .common import emit, timeit, write_json

N = 200_000
NQ = 4_096
CANDIDATES = (16, 64, 256, 1024, 4096)
BATCH_SIZES = (1, 4, 16, 64, 256, 1024)
LEGACY_THRESHOLDS = (64, 4096)   # the pre-planner magic constants


def run(n: int = N, n_queries: int = NQ,
        candidates: tuple[int, ...] = CANDIDATES,
        batch_sizes: tuple[int, ...] = BATCH_SIZES,
        latency_budget_ns: float = 800.0):
    keys = weblogs_like(n)
    rng = np.random.default_rng(11)
    q = keys[rng.integers(0, n, size=n_queries)]

    spec_default = FitSpec(latency_budget_ns=latency_budget_ns,
                           candidate_errors=candidates, segment_sample=None)
    p_default = plan(keys, spec_default)
    cal = calibrate(keys)
    spec_cal = dataclasses.replace(spec_default, cpu_params=cal)
    try:
        p = plan(keys, spec_cal)
        budget_feasible = True
    except InfeasibleSpecError:
        # the calibrated model says no candidate meets the stock budget on
        # this host; pin the default plan's error so the sweep and the
        # head-to-head still run, and record the refusal
        p = plan(keys, dataclasses.replace(spec_cal, latency_budget_ns=None,
                                           error=p_default.error))
        budget_feasible = False

    results = {"config": {"n": n, "n_queries": n_queries,
                          "candidates": list(candidates),
                          "batch_sizes": list(batch_sizes),
                          "latency_budget_ns": latency_budget_ns},
               "plan": {"error": p.error, "n_shards": p.n_shards,
                        "backend": p.backend, "small_max": p.small_max,
                        "large_min": p.large_min}}

    # --- 1+2. predicted vs measured across the candidate sweep, scored under
    # both cost models (each candidate built as the plan scores it: segmented
    # at err_seg = error - buffer, the form a published snapshot serves)
    cand_def = {c.error: c for c in p_default.candidates}
    cand_cal = {c.error: c for c in p.candidates}
    sweep = []
    for err in sorted(cand_cal):
        c, c0 = cand_cal[err], cand_def[err]
        eff_error = max(1, err - planned_buffer(err))
        table = SegmentTable.from_keys(keys, eff_error, assume_sorted=True)
        eng = make_engine(table, "numpy")
        measured_ns = timeit(eng.lookup, q) / n_queries * 1e9
        sweep.append({"error": err, "chosen": c.chosen,
                      "predicted_ns": c.latency_ns,
                      "predicted_ns_default": c0.latency_ns,
                      "measured_ns": measured_ns,
                      "gap_ratio": c.latency_ns / measured_ns,
                      "predicted_bytes": c.size_bytes,
                      "actual_bytes": table.size_bytes()})
    results["error_sweep"] = sweep
    ub_lat = float(np.mean([r["predicted_ns"] >= r["measured_ns"]
                            for r in sweep]))
    ub_def = float(np.mean([r["predicted_ns_default"] >= r["measured_ns"]
                            for r in sweep]))
    ub_sz = float(np.mean([r["predicted_bytes"] >= r["actual_bytes"]
                           for r in sweep]))
    emit("plan", "latency_upper_bound_rate", ub_lat, f"default={ub_def}")
    emit("plan", "size_upper_bound_rate", ub_sz)
    results["latency_upper_bound_rate"] = ub_lat
    results["latency_upper_bound_rate_default"] = ub_def
    results["size_upper_bound_rate"] = ub_sz
    # residual gap: >= 1 means the model still upper-bounds, how loosely
    results["calibration"] = {
        "c_ns_default": CostParams().c_ns, "c_ns_calibrated": cal.c_ns,
        "budget_feasible_under_calibrated_model": budget_feasible,
        "mean_gap_ratio_calibrated":
            float(np.mean([r["gap_ratio"] for r in sweep])),
        "mean_gap_ratio_default":
            float(np.mean([r["predicted_ns_default"] / r["measured_ns"]
                           for r in sweep]))}

    # --- 3. planned vs legacy-default dispatch thresholds, same workload
    table = SegmentTable.from_keys(keys, max(1, p.error - p.buffer_size),
                                   assume_sorted=True)
    head_to_head = {}
    for name, (small_max, large_min) in (
            ("planned", (p.small_max, p.large_min)),
            ("legacy_default", LEGACY_THRESHOLDS)):
        eng = make_engine(table, "dispatch", small_max=small_max,
                          large_min=large_min)
        for size in batch_sizes:             # warm every tier's compile cache
            eng.lookup(q[:size])

        def workload(eng=eng):
            for size in batch_sizes:
                eng.lookup(q[:size])

        total_s = timeit(workload)
        head_to_head[name] = {
            "small_max": small_max, "large_min": large_min,
            "total_ms": total_s * 1e3,
            "tiers": {str(s): eng.backend_for(s) for s in batch_sizes}}
        emit("plan", f"dispatch_total_ms_{name}", total_s * 1e3,
             f"small_max={small_max},large_min={large_min}")
    results["dispatch_head_to_head"] = head_to_head

    write_json("bench_plan", results)
    return results


if __name__ == "__main__":
    run()
