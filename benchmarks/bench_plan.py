"""Planner quality: does the Sec. 6 plan predict what the built index does?

Two claims are tracked per PR (wired into ``benchmarks/smoke.py``):

1. **Prediction accuracy across the error sweep** -- for every candidate
   error the planner scored, build the index at that error and measure the
   host lookup latency; record measured vs the plan's predicted latency and
   size (the Fig. 10 methodology, but through the ``FitSpec -> plan()``
   audit trail instead of hand-rolled model calls).

2. **Planned vs default dispatch thresholds head-to-head** -- run the same
   mixed batch-size workload through a ``DispatchEngine`` with the
   cost-model-planned ``small_max``/``large_min`` and one pinned to the old
   magic constants (64 / 4096); record total time per configuration so the
   artifact shows whether the learned crossings actually help on this host.

Results land in ``out/bench_plan.json`` plus the usual ``emit`` lines.
"""
from __future__ import annotations

import numpy as np

from repro.core.datasets import weblogs_like
from repro.index import FitSpec, make_engine, plan
from repro.index.fit import planned_buffer
from repro.index.table import SegmentTable

from .common import emit, timeit, write_json

N = 200_000
NQ = 4_096
CANDIDATES = (16, 64, 256, 1024, 4096)
BATCH_SIZES = (1, 4, 16, 64, 256, 1024)
LEGACY_THRESHOLDS = (64, 4096)   # the pre-planner magic constants


def run(n: int = N, n_queries: int = NQ,
        candidates: tuple[int, ...] = CANDIDATES,
        batch_sizes: tuple[int, ...] = BATCH_SIZES,
        latency_budget_ns: float = 800.0):
    keys = weblogs_like(n)
    rng = np.random.default_rng(11)
    q = keys[rng.integers(0, n, size=n_queries)]

    spec = FitSpec(latency_budget_ns=latency_budget_ns,
                   candidate_errors=candidates, segment_sample=None)
    p = plan(keys, spec)
    results = {"config": {"n": n, "n_queries": n_queries,
                          "candidates": list(candidates),
                          "batch_sizes": list(batch_sizes),
                          "latency_budget_ns": latency_budget_ns},
               "plan": {"error": p.error, "n_shards": p.n_shards,
                        "backend": p.backend, "small_max": p.small_max,
                        "large_min": p.large_min}}

    # --- 1. predicted vs measured across the candidate sweep (each candidate
    # built as the plan scores it: segmented at err_seg = error - buffer, the
    # form a published snapshot serves)
    sweep = []
    for c in p.candidates:
        eff_error = max(1, c.error - planned_buffer(c.error))
        table = SegmentTable.from_keys(keys, eff_error, assume_sorted=True)
        eng = make_engine(table, "numpy")
        measured_ns = timeit(eng.lookup, q) / n_queries * 1e9
        sweep.append({"error": c.error, "chosen": c.chosen,
                      "predicted_ns": c.latency_ns,
                      "measured_ns": measured_ns,
                      "predicted_bytes": c.size_bytes,
                      "actual_bytes": table.size_bytes()})
    results["error_sweep"] = sweep
    ub_lat = float(np.mean([r["predicted_ns"] >= r["measured_ns"]
                            for r in sweep]))
    ub_sz = float(np.mean([r["predicted_bytes"] >= r["actual_bytes"]
                           for r in sweep]))
    emit("plan", "latency_upper_bound_rate", ub_lat)
    emit("plan", "size_upper_bound_rate", ub_sz)
    results["latency_upper_bound_rate"] = ub_lat
    results["size_upper_bound_rate"] = ub_sz

    # --- 2. planned vs legacy-default dispatch thresholds, same workload
    table = SegmentTable.from_keys(keys, max(1, p.error - p.buffer_size),
                                   assume_sorted=True)
    head_to_head = {}
    for name, (small_max, large_min) in (
            ("planned", (p.small_max, p.large_min)),
            ("legacy_default", LEGACY_THRESHOLDS)):
        eng = make_engine(table, "dispatch", small_max=small_max,
                          large_min=large_min)
        for size in batch_sizes:             # warm every tier's compile cache
            eng.lookup(q[:size])

        def workload(eng=eng):
            for size in batch_sizes:
                eng.lookup(q[:size])

        total_s = timeit(workload)
        head_to_head[name] = {
            "small_max": small_max, "large_min": large_min,
            "total_ms": total_s * 1e3,
            "tiers": {str(s): eng.backend_for(s) for s in batch_sizes}}
        emit("plan", f"dispatch_total_ms_{name}", total_s * 1e3,
             f"small_max={small_max},large_min={large_min}")
    results["dispatch_head_to_head"] = head_to_head

    write_json("bench_plan", results)
    return results


if __name__ == "__main__":
    run()
