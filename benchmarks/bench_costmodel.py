"""Fig. 10: cost-model accuracy -- predicted vs measured latency and size."""
from __future__ import annotations

import numpy as np

from repro.core import (CostParams, FITingTree, latency_ns, learn_segments_fn,
                        size_bytes)
from repro.core.datasets import weblogs_like

from .common import emit, timeit, write_csv

N = 500_000
NQ = 20_000
ERRORS = [16, 64, 256, 1024, 4096]
# c calibrated like the paper: measured random-access penalty on this host.
# fill=1.0: the prediction must upper-bound the array-packed router (which is
# always 100% full), matching the paper's "pessimistic estimate" semantics.
P = CostParams(c_ns=120.0, fanout=16, fill=1.0, buffer_size=16)


def run():
    keys = weblogs_like(N)
    rng = np.random.default_rng(2)
    q = keys[rng.integers(0, N, size=NQ)]
    fn = learn_segments_fn(keys, ERRORS, sample=None)
    rows = []
    for e in ERRORS:
        tree = FITingTree(keys, error=e, assume_sorted=True)
        measured_ns = timeit(tree.lookup_batch, q) / NQ * 1e9
        pred_ns = latency_ns(e, fn(e), P)
        pred_sz = size_bytes(e, fn(e), P)
        act_sz = tree.index_size_bytes()
        rows.append((e, pred_ns, measured_ns, pred_sz, act_sz))
    write_csv("fig10_costmodel",
              ["error", "pred_latency_ns", "meas_latency_ns",
               "pred_size_bytes", "actual_size_bytes"], rows)
    # the paper's claim (Fig. 10): predictions upper-bound reality, tightly
    sz_ub = np.mean([r[3] >= r[4] * 0.95 for r in rows])
    lat_ub = np.mean([r[1] >= r[2] for r in rows])
    emit("fig10", "size_upper_bound_rate", float(sz_ub))
    emit("fig10", "latency_upper_bound_rate", float(lat_ub))
    emit("fig10", "size_rms_rel_err",
         float(np.sqrt(np.mean([((r[3] - r[4]) / r[4]) ** 2 for r in rows]))))
    return rows


if __name__ == "__main__":
    run()
